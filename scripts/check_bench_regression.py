"""Diff a fresh benchmark JSON against the committed perf baseline.

Compares the ``derived`` column (throughput: higher is better) of selected
rows by name prefix and fails when any regresses by more than the allowed
fraction. Row names embed grid sizes (``sweep.jax.warm.216cfg8lane``), so
matching is by prefix; a prefix present in only one file is reported and
skipped (grid shapes legitimately change across PRs).

Baselines are only comparable at the same scale: if the two files disagree
on the ``fast`` flag (smoke vs full benchmark scale), the check FAILS with
an actionable message — a mis-scaled committed baseline would otherwise
permanently self-disable the gate. Regenerate the committed baseline with
``make bench-baseline`` (FAST scale, matching CI's bench-smoke job).

Usage (the CI bench-smoke job and ``make bench-smoke`` run this)::

    python scripts/check_bench_regression.py BENCH_4.json BENCH_ci.json \
        [--rows sweep.jax.warm sweep.jax.lanes_per_sec] [--max-regression 0.3]
"""

from __future__ import annotations

import argparse
import json
import sys

#: Rows that gate CI (prefix match). Throughput of the batched backend is
#: the perf trajectory this repo tracks (ISSUE 4 acceptance).
DEFAULT_ROWS = ("sweep.jax.warm", "sweep.jax.lanes_per_sec")


def _find(doc: dict, prefix: str):
    rows = [b for b in doc.get("benches", [])
            if b["name"] == prefix or b["name"].startswith(prefix + ".")]
    return rows[0] if rows else None


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="Fail on benchmark throughput regression vs baseline")
    ap.add_argument("baseline", help="committed baseline JSON (BENCH_4.json)")
    ap.add_argument("current", help="freshly produced JSON (BENCH_ci.json)")
    ap.add_argument("--rows", nargs="+", default=list(DEFAULT_ROWS),
                    help="row-name prefixes to compare (derived column)")
    ap.add_argument("--max-regression", type=float, default=0.30,
                    help="allowed fractional drop in derived throughput "
                         "(default 0.30)")
    args = ap.parse_args(argv)

    try:
        with open(args.baseline) as f:
            base = json.load(f)
    except OSError as e:
        print(f"bench-diff: no baseline ({e}); skipping", file=sys.stderr)
        return 0
    with open(args.current) as f:
        cur = json.load(f)

    if base.get("fast") != cur.get("fast"):
        print(f"bench-diff: scale mismatch (baseline fast={base.get('fast')}"
              f", current fast={cur.get('fast')}) — the committed baseline "
              "must match the comparison scale; regenerate it with "
              "`make bench-baseline`", file=sys.stderr)
        return 1

    failures = []
    for prefix in args.rows:
        b, c = _find(base, prefix), _find(cur, prefix)
        if b is None or c is None:
            print(f"bench-diff: {prefix}: missing in "
                  f"{'baseline' if b is None else 'current'}; skipped")
            continue
        old, new = float(b["derived"]), float(c["derived"])
        if old <= 0:
            print(f"bench-diff: {prefix}: non-positive baseline {old}; "
                  "skipped")
            continue
        change = (new - old) / old
        status = "OK"
        if change < -args.max_regression:
            status = "REGRESSION"
            failures.append(prefix)
        print(f"bench-diff: {prefix}: {old:.4g} -> {new:.4g} "
              f"({change:+.1%}) {status}")
    if failures:
        print(f"bench-diff: FAILED rows: {', '.join(failures)} "
              f"(allowed drop {args.max_regression:.0%})", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
