"""Infrastructure module: sites, storage elements, links, files, replicas.

Mirrors the paper's infrastructure module (§4.1):

- ``StorageElement``: addresses a storage area, stores runtime data (used
  volume, stored replicas). Associated with one ``Site``; may have a capacity
  limit (the HCDC disk limit of Table 5) and a tape-style access latency.
- ``NetworkLink``: directional connection between two storage elements;
  tracks traffic and the number of active transfers; configured either with a
  shared ``bandwidth`` (divided among active transfers) or a per-transfer
  ``throughput`` (independent of the number of active transfers), plus an
  optional ``max_active`` transfer slot limit (paper Table 4: 100).
- ``File``: size + expiration + popularity; ``Replica``: (file, storage
  element) association with a partial ``size_done`` while transferring.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Iterable, Optional

KB = 1000.0
MB = 1000.0**2
GB = 1000.0**3
TB = 1000.0**4
PB = 1000.0**5

KiB = 1024.0
MiB = 1024.0**2
GiB = 1024.0**3
TiB = 1024.0**4


@dataclass
class File:
    """A transferable data object (paper: size + expiration time)."""

    fid: int
    size: float  # bytes
    expires_at: Optional[int] = None
    popularity: int = 1  # times the file will be processed (HCDC metric)


class Replica:
    """A file stored (fully or partially) at a storage element."""

    __slots__ = ("file", "se", "size_done")

    def __init__(self, file: File, se: "StorageElement", size_done: float = 0.0):
        self.file = file
        self.se = se
        self.size_done = size_done

    @property
    def complete(self) -> bool:
        return self.size_done >= self.file.size

    def __repr__(self) -> str:  # pragma: no cover
        return f"Replica({self.file.fid}@{self.se.name}, {self.size_done}/{self.file.size})"


class StorageElement:
    """A storage area with QoS properties and runtime accounting."""

    def __init__(
        self,
        name: str,
        site: "Site",
        limit: Optional[float] = None,
        access_latency: float = 0.0,
        latency_sampler=None,
    ):
        self.name = name
        self.site = site
        self.limit = limit  # bytes; None = unlimited
        self.access_latency = access_latency  # seconds (tape mount/position)
        self.latency_sampler = latency_sampler  # optional callable -> seconds
        self.used: float = 0.0  # bytes allocated (incl. in-flight reservations)
        self.replicas: Dict[int, Replica] = {}
        site.storage_elements[name] = self

    # -- capacity accounting -------------------------------------------------
    def can_allocate(self, size: float) -> bool:
        return self.limit is None or self.used + size <= self.limit

    def allocate(self, file: File) -> Replica:
        """Reserve space and create an (initially empty) replica."""
        if file.fid in self.replicas:
            raise ValueError(f"{file.fid} already at {self.name}")
        if not self.can_allocate(file.size):
            raise RuntimeError(f"{self.name} over limit")
        self.used += file.size
        r = Replica(file, self)
        self.replicas[file.fid] = r
        return r

    def add_complete_replica(self, file: File) -> Replica:
        r = self.allocate(file)
        r.size_done = file.size
        return r

    def delete(self, fid: int) -> None:
        r = self.replicas.pop(fid)
        self.used -= r.file.size

    def has_complete(self, fid: int) -> bool:
        r = self.replicas.get(fid)
        return r is not None and r.complete

    def sample_latency(self, rng) -> float:
        if self.latency_sampler is not None:
            return float(self.latency_sampler(rng))
        return float(self.access_latency)

    def __repr__(self) -> str:  # pragma: no cover
        return f"SE({self.name}, used={self.used/TB:.2f}TB)"


class Site:
    """A data centre pooling storage elements (WLCG 'site')."""

    def __init__(self, name: str):
        self.name = name
        self.storage_elements: Dict[str, StorageElement] = {}

    def se(self, name: str) -> StorageElement:
        return self.storage_elements[name]


class NetworkLink:
    """Directional link between two storage elements.

    Exactly one of ``bandwidth`` (shared; divided among active transfers) or
    ``throughput`` (per-transfer; independent of concurrency) must be set —
    the paper's two link modes (§4.1).
    """

    def __init__(
        self,
        src: StorageElement,
        dst: StorageElement,
        bandwidth: Optional[float] = None,  # bytes/s shared
        throughput: Optional[float] = None,  # bytes/s per transfer
        max_active: Optional[int] = None,
    ):
        if (bandwidth is None) == (throughput is None):
            raise ValueError("configure exactly one of bandwidth/throughput")
        self.src = src
        self.dst = dst
        self.bandwidth = bandwidth
        self.throughput = throughput
        self.max_active = max_active
        self.active: int = 0  # currently active transfers
        self.queued: int = 0  # transfers waiting for a slot
        self.traffic: float = 0.0  # total bytes moved over this link

    @property
    def name(self) -> str:
        return f"{self.src.name}->{self.dst.name}"

    def rate_per_transfer(self, n_active: Optional[int] = None) -> float:
        """Current bytes/s seen by one active transfer."""
        n = self.active if n_active is None else n_active
        if self.throughput is not None:
            return self.throughput
        if n <= 0:
            return self.bandwidth
        return self.bandwidth / n

    def has_slot(self) -> bool:
        return self.max_active is None or self.active < self.max_active

    def __repr__(self) -> str:  # pragma: no cover
        return f"Link({self.name}, active={self.active})"


def link_table(links: Iterable[NetworkLink]) -> Dict[tuple, NetworkLink]:
    return {(ln.src.name, ln.dst.name): ln for ln in links}
