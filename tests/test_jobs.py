"""Fault-tolerant job execution tests (``repro.sim.jobs`` +
``repro.sim.faults``): deterministic backoff, the registry state
machine, fault injection, crash/timeout recovery on the process pool,
lane-chunk jobs on the jax backend, and checkpointed resume through the
result cache.

The backoff and fault-plan draws are pure sha256 hashes, so every
assertion here is exact — no flaky timing-dependent retries. The pool
tests run a real spawned ``ProcessPoolExecutor`` at a tiny scenario
scale; the end-to-end bitwise test is the ISSUE acceptance criterion
(a crash/hang/transient-injected sweep converges to the byte-identical
result of the fault-free run).
"""

import os

import pytest

from repro.core.scenarios import expand_grid, with_seeds
from repro.obs.metrics import get_registry
from repro.sim.faults import (
    FaultPlan,
    FaultyBackend,
    JobTimeout,
    TransientFault,
    as_faults,
    parse_faults,
    raise_local_fault,
    unit_hash,
)
from repro.sim.jobs import (
    ABANDONED,
    DONE,
    FAILED,
    PENDING,
    RETRYABLE_KINDS,
    RUNNING,
    Job,
    JobRegistry,
    RetryPolicy,
    run_local_jobs,
)
from repro.sim.sweep import run_sweep


def _metrics_of(res):
    """Comparable payload: the full metrics dict + bill per result."""
    return [(r.spec, r.metrics, r.storage_usd, r.network_usd, r.ops_usd)
            for r in res.results]


def _small_grid(n=2, days=0.02, n_files=300):
    return expand_grid({"base": "III", "days": days, "n_files": n_files,
                        "cache_tb": [float(5 * (i + 1)) for i in range(n)]})


def _jax_grid(n_prices=1, n_egress=1, seeds=2):
    egress = ["internet", "direct", "interconnect"][:n_egress]
    specs = expand_grid({
        "base": "III", "days": 0.1, "n_files": 1000,
        "gcs_limit_tb": [10.0, 20.0, 40.0, 80.0],
        "egress": egress,
        "storage_price": [round(0.018 + 0.002 * i, 3)
                          for i in range(n_prices)],
    })
    return with_seeds(specs, seeds)


# --------------------------------------------------------------- backoff
def test_backoff_bounded_monotone_reproducible():
    policy = RetryPolicy(max_attempts=10, base_delay_s=0.05, multiplier=2.0,
                         max_delay_s=0.5, jitter=0.25, seed=3)
    delays = [policy.delay_s("jobA", a) for a in range(1, 11)]
    assert all(0.0 <= d <= 0.5 for d in delays)
    assert all(b >= a for a, b in zip(delays, delays[1:]))  # monotone
    # bitwise-reproducible: a fresh policy object reproduces every delay
    again = RetryPolicy(max_attempts=10, base_delay_s=0.05, multiplier=2.0,
                        max_delay_s=0.5, jitter=0.25, seed=3)
    assert [again.delay_s("jobA", a) for a in range(1, 11)] == delays
    # jitter decorrelates jobs (per job, not per attempt)
    assert policy.delay_s("jobB", 1) != delays[0]
    # ... and a different seed moves the jitter
    assert RetryPolicy(seed=4).delay_s("jobA", 1) != \
        RetryPolicy(seed=3).delay_s("jobA", 1)


def test_backoff_caps_at_max_delay():
    policy = RetryPolicy(max_attempts=30, base_delay_s=1.0, multiplier=10.0,
                         max_delay_s=7.0, jitter=1.0)
    assert policy.delay_s("j", 25) == 7.0


def test_retry_policy_validates():
    with pytest.raises(ValueError, match="max_attempts"):
        RetryPolicy(max_attempts=0)
    with pytest.raises(ValueError, match="multiplier"):
        RetryPolicy(multiplier=0.5)
    with pytest.raises(ValueError, match="delays"):
        RetryPolicy(base_delay_s=-1.0)
    with pytest.raises(ValueError, match="jitter"):
        RetryPolicy(jitter=-0.1)
    with pytest.raises(ValueError, match="1-based"):
        RetryPolicy().delay_s("j", 0)


def test_unit_hash_is_stable_and_uniform_range():
    # pinned value: the cross-process / cross-platform stability the
    # reproducibility guarantees rest on (sha256, not hash())
    assert unit_hash("x") == unit_hash("x")
    assert 0.0 <= unit_hash("x") < 1.0
    assert unit_hash("x") != unit_hash("y")


# ------------------------------------------------------------ fault plans
def test_parse_faults_round_trip_and_errors():
    plan = parse_faults("seed=7,crash=0.2,hang=0.1,transient=0.3,"
                        "hang_s=0.05,attempts=2,only=lanes")
    assert plan == FaultPlan(seed=7, crash=0.2, hang=0.1, transient=0.3,
                             hang_s=0.05, attempts=2, only="lanes")
    assert parse_faults("") == FaultPlan() and not FaultPlan().active
    with pytest.raises(ValueError, match="unknown fault field"):
        parse_faults("bogus=1")
    with pytest.raises(ValueError, match="key=value"):
        parse_faults("crash")
    with pytest.raises(ValueError, match="crash"):
        FaultPlan(crash=1.5)
    with pytest.raises(ValueError, match="<= 1"):
        FaultPlan(crash=0.5, hang=0.4, transient=0.3)
    with pytest.raises(ValueError, match="attempts"):
        FaultPlan(attempts=0)


def test_as_faults_coercions():
    plan = FaultPlan(crash=0.5)
    assert as_faults(None) is None
    assert as_faults(plan) is plan
    assert as_faults("crash=0.5") == plan
    assert as_faults({"crash": 0.5}) == plan
    with pytest.raises(TypeError):
        as_faults(17)


def test_directive_deterministic_exclusive_and_gated():
    plan = FaultPlan(seed=11, crash=0.3, hang=0.3, transient=0.3,
                     hang_s=2.5, attempts=1)
    ids = [f"job{i:03d}" for i in range(300)]
    first = [plan.directive(j, (), 1) for j in ids]
    assert first == [plan.directive(j, (), 1) for j in ids]  # deterministic
    kinds = [d["kind"] for d in first if d is not None]
    # one uniform draw partitioned across the three rates: every kind
    # fires, roughly at its configured probability
    for kind in ("crash", "hang", "transient"):
        assert 0.15 < kinds.count(kind) / len(ids) < 0.45
    hangs = [d for d in first if d is not None and d["kind"] == "hang"]
    assert hangs and all(d["seconds"] == 2.5 for d in hangs)
    # attempts gate: nothing injects past the first attempt
    assert all(plan.directive(j, (), 2) is None for j in ids)


def test_directive_only_filter_matches_id_or_labels():
    plan = FaultPlan(transient=1.0, only="needle")
    assert plan.directive("has-needle-inside", (), 1) is not None
    assert plan.directive("other", ("label-needle",), 1) is not None
    assert plan.directive("other", ("nope",), 1) is None
    # corruption draws share the filter
    assert not plan.corrupts("other", 1)


def test_raise_local_fault_hang_vs_deadline():
    slept = []
    with pytest.raises(JobTimeout):
        raise_local_fault({"kind": "hang", "seconds": 10.0}, 1.0,
                          slept.append)
    assert slept == [1.0]  # sleeps the deadline out, not the full hang
    slept.clear()
    raise_local_fault({"kind": "hang", "seconds": 0.5}, 2.0, slept.append)
    assert slept == [0.5]  # shorter than the deadline: just slow, no raise
    with pytest.raises(TransientFault):
        raise_local_fault({"kind": "transient"}, None, slept.append)


# ---------------------------------------------------------- registry
def test_registry_lifecycle_retry_then_abandon():
    clock = [100.0]
    policy = RetryPolicy(max_attempts=3, base_delay_s=2.0, multiplier=2.0,
                         max_delay_s=60.0, jitter=0.0)
    reg = JobRegistry(policy, clock=lambda: clock[0])
    job = reg.add(Job(job_id="j1", labels=("lbl",)))
    with pytest.raises(ValueError, match="duplicate"):
        reg.add(Job(job_id="j1"))
    assert reg.ready() == [job] and reg.unsettled()

    reg.mark_running(job)
    assert (job.state, job.attempts) == (RUNNING, 1)
    assert reg.mark_failed(job, "transient", "boom") is True
    assert job.state == FAILED and job.not_before == 102.0  # jitter=0
    assert reg.ready(now=101.0) == [] and reg.next_wake() == 102.0
    clock[0] = 102.5
    assert reg.ready() == [job]

    reg.mark_running(job)
    assert reg.mark_failed(job, "timeout", "slow") is True
    assert job.not_before == 102.5 + 4.0  # backoff grew with the attempt

    clock[0] = 120.0
    reg.mark_running(job)
    assert reg.mark_failed(job, "crash", "died") is False  # budget spent
    assert job.state == ABANDONED and not reg.unsettled()
    (failure,) = reg.failures()
    assert (failure.job_id, failure.kind, failure.attempts) == \
        ("j1", "crash", 3)
    assert failure.labels == ("lbl",) and len(failure.errors) == 3
    assert failure.as_dict()["errors"][0].startswith("attempt 1 [transient]")


def test_registry_generic_error_abandons_immediately():
    assert "error" not in RETRYABLE_KINDS
    reg = JobRegistry(RetryPolicy(max_attempts=5))
    job = reg.add(Job(job_id="j1"))
    reg.mark_running(job)
    assert reg.mark_failed(job, "error", "ValueError: bad") is False
    assert job.state == ABANDONED and job.attempts == 1


def test_registry_requeue_does_not_charge_an_attempt():
    reg = JobRegistry(RetryPolicy(max_attempts=2))
    job = reg.add(Job(job_id="j1"))
    before = get_registry().value("jobs.requeued")
    for _ in range(5):  # far past max_attempts: requeues are free
        reg.mark_running(job)
        reg.requeue_lost(job)
    assert (job.state, job.attempts) == (PENDING, 0)
    assert get_registry().value("jobs.requeued") == before + 5


def test_registry_publishes_state_gauges_and_counters():
    reg_m = get_registry()
    before_retries = reg_m.value("jobs.retries")
    before_abandoned = reg_m.value("jobs.abandoned")
    reg = JobRegistry(RetryPolicy(max_attempts=2, base_delay_s=0.0))
    a, b = reg.add(Job(job_id="a")), reg.add(Job(job_id="b"))
    reg.mark_running(a)
    reg.mark_done(a, result=41)
    reg.mark_running(b)
    reg.mark_failed(b, "transient", "x")
    assert reg_m.value("jobs.state", state=DONE) == 1
    assert reg_m.value("jobs.state", state=FAILED) == 1
    reg.mark_running(b)
    reg.mark_failed(b, "transient", "x")
    assert reg_m.value("jobs.state", state=ABANDONED) == 1
    assert reg_m.value("jobs.retries") == before_retries + 1
    assert reg_m.value("jobs.abandoned") == before_abandoned + 1


# ------------------------------------------------------ in-process executor
def test_run_local_jobs_retries_transients_to_success():
    calls = {}

    def run_one(job):
        calls[job.job_id] = calls.get(job.job_id, 0) + 1
        if job.job_id == "flaky" and calls[job.job_id] < 3:
            raise TransientFault("not yet")
        if job.job_id == "broken":
            raise ValueError("deterministic bug")
        return job.job_id.upper()

    jobs = [Job(job_id="ok"), Job(job_id="flaky"), Job(job_id="broken")]
    results, reg = run_local_jobs(
        jobs, run_one, policy=RetryPolicy(max_attempts=3, base_delay_s=0.0),
        sleep=lambda s: None)
    assert results == {"ok": "OK", "flaky": "FLAKY"}
    assert calls == {"ok": 1, "flaky": 3, "broken": 1}  # no retry on bugs
    (failure,) = reg.failures()
    assert failure.job_id == "broken" and failure.kind == "error"
    assert "ValueError" in failure.errors[0]


def test_run_local_jobs_on_done_checkpoints_each_success():
    journaled = []
    jobs = [Job(job_id=f"j{i}") for i in range(3)]
    results, _ = run_local_jobs(jobs, lambda job: job.job_id,
                                on_done=lambda job, out: journaled.append(out),
                                sleep=lambda s: None)
    assert journaled == ["j0", "j1", "j2"] and len(results) == 3


# ------------------------------------------- serial sweeps through the layer
def test_serial_sweep_fault_injection_converges_bitwise():
    specs = _small_grid(2)
    plain = run_sweep(specs, workers=1)
    injected = run_sweep(
        specs, workers=1,
        faults=FaultPlan(seed=5, transient=0.9, attempts=1),
        retry=RetryPolicy(max_attempts=3, base_delay_s=0.0))
    assert injected.ok
    assert _metrics_of(injected) == _metrics_of(plain)


def test_serial_sweep_partial_result_with_structured_failures(tmp_path):
    specs = _small_grid(2)
    res = run_sweep(
        specs, workers=1,
        faults=FaultPlan(transient=1.0, attempts=99, only="spec0000"),
        retry=RetryPolicy(max_attempts=2, base_delay_s=0.0))
    assert not res.ok and len(res.results) == 1
    assert res.results[0].spec == specs[1]
    (failure,) = res.failures
    assert (failure.job_id, failure.kind, failure.attempts) == \
        ("spec0000", "transient", 2)
    # the structured report travels through the JSON export
    out = tmp_path / "partial.json"
    res.to_json(str(out))
    import json

    doc = json.loads(out.read_text())
    assert doc["failures"][0]["job_id"] == "spec0000"
    assert len(doc["rows"]) == 1


# ------------------------------------------------------ process-pool executor
def test_pool_crash_recovery_converges_bitwise():
    specs = _small_grid(3)
    plain = run_sweep(specs, workers=2)
    before = get_registry().value("jobs.crashes")
    injected = run_sweep(
        specs, workers=2,
        faults=FaultPlan(seed=1, crash=1.0, attempts=1, only="spec0001"),
        retry=RetryPolicy(max_attempts=3, base_delay_s=0.01))
    assert injected.ok and _metrics_of(injected) == _metrics_of(plain)
    assert get_registry().value("jobs.crashes") >= before + 1


def test_pool_timeout_reaps_hung_worker():
    specs = _small_grid(3)
    plain = run_sweep(specs, workers=2)
    before = get_registry().value("jobs.timeouts")
    injected = run_sweep(
        specs, workers=2, job_timeout=1.0,
        faults=FaultPlan(seed=1, hang=1.0, hang_s=30.0, attempts=1,
                         only="spec0002"),
        retry=RetryPolicy(max_attempts=3, base_delay_s=0.01))
    assert injected.ok and _metrics_of(injected) == _metrics_of(plain)
    assert get_registry().value("jobs.timeouts") >= before + 1


# --------------------------------------------------- jax lane-chunk jobs
def test_jax_injected_sweep_bitwise_identical_216_configs():
    """ISSUE acceptance: the 216-config pricing grid under injected
    crashes, hangs, and transient faults converges to the byte-identical
    result of the fault-free run (same lane_chunk both sides)."""
    specs = _jax_grid(n_prices=9, n_egress=3, seeds=2)
    assert len(specs) == 216
    plain = run_sweep(specs, backend="jax", tick=60.0, lane_chunk=2)
    before = get_registry().value("jobs.retries")
    injected = run_sweep(
        specs, backend="jax", tick=60.0, lane_chunk=2, job_timeout=0.05,
        faults=FaultPlan(seed=11, crash=0.3, hang=0.3, transient=0.3,
                         hang_s=0.1, attempts=1),
        retry=RetryPolicy(max_attempts=3, base_delay_s=0.005,
                          max_delay_s=0.02))
    assert injected.ok and len(injected.results) == 216
    assert _metrics_of(injected) == _metrics_of(plain)
    assert get_registry().value("jobs.retries") > before  # faults did fire


def test_jax_abandoned_chunk_yields_partial_result():
    specs = _jax_grid()  # 8 specs, 8 dynamics lanes; chunk=2 -> 4 jobs
    res = run_sweep(
        specs, backend="jax", tick=60.0, lane_chunk=2,
        faults=FaultPlan(transient=1.0, attempts=99, only="lanes00002"),
        retry=RetryPolicy(max_attempts=2, base_delay_s=0.0))
    assert not res.ok
    assert len(res.results) == 6  # the abandoned chunk held 2 lanes
    (failure,) = res.failures
    assert (failure.job_id, failure.kind) == ("lanes00002", "transient")
    assert failure.attempts == 2


def test_jax_resume_recomputes_only_missing_lanes(tmp_path):
    specs = _jax_grid()
    cache_dir = str(tmp_path / "cache")
    # run 1: one chunk abandons; its completed peers journal into the cache
    run1 = run_sweep(
        specs, backend="jax", tick=60.0, lane_chunk=2, cache=cache_dir,
        faults=FaultPlan(transient=1.0, attempts=99, only="lanes00006"),
        retry=RetryPolicy(max_attempts=2, base_delay_s=0.0))
    assert not run1.ok and len(run1.results) == 6
    assert run1.lanes_simulated == 6
    # run 2 (the resume): identical request, faults gone — only the
    # missing lanes simulate, everything else is served from the journal
    run2 = run_sweep(specs, backend="jax", tick=60.0, lane_chunk=2,
                     cache=cache_dir, retry=RetryPolicy())
    assert run2.ok and len(run2.results) == 8
    assert run2.cache_hits == 6 and run2.lanes_simulated == 2
    # ... and the stitched result is bitwise the fault-free run
    fresh = run_sweep(specs, backend="jax", tick=60.0, lane_chunk=2)
    assert _metrics_of(run2) == _metrics_of(fresh)


def test_jax_corrupt_cache_reads_detected_and_recomputed(tmp_path):
    specs = _jax_grid()
    cache_dir = str(tmp_path / "cache")
    warm = run_sweep(specs, backend="jax", tick=60.0, cache=cache_dir)
    assert warm.lanes_simulated == 8
    before = get_registry().value("faults.injected", kind="corrupt")
    res = run_sweep(specs, backend="jax", tick=60.0, cache=cache_dir,
                    faults=FaultPlan(seed=2, corrupt=0.6))
    assert res.ok and len(res.results) == 8
    assert get_registry().value("faults.injected", kind="corrupt") > before
    assert res.lanes_simulated > 0  # corrupted entries were re-simulated
    assert res.lanes_simulated + res.cache_hits >= 8
    assert _metrics_of(res) == _metrics_of(warm)


def test_faulty_backend_corrupts_only_first_read():
    class MemBackend:
        def __init__(self):
            self.blobs = {}

        def read(self, name):
            return self.blobs.get(name)

        def write(self, name, data):
            self.blobs[name] = data

        def delete(self, name):
            self.blobs.pop(name, None)

    plan = FaultPlan(seed=0, corrupt=1.0)
    fb = FaultyBackend(MemBackend(), plan)
    assert fb.read("missing") is None
    payload = b"0123456789abcdef"
    fb.write("entry", payload)
    assert fb.read("entry") != payload   # first read: garbled
    assert fb.read("entry") == payload   # refreshed reads are clean
    fb.delete("entry")
    assert fb.read("entry") is None


def test_jax_resilient_path_rejects_device_round_robin():
    specs = _jax_grid()
    with pytest.raises(ValueError, match="devices"):
        run_sweep(specs, backend="jax", tick=60.0, lane_chunk=2,
                  devices=[object()], retry=RetryPolicy())


# ------------------------------------------------------------- env plumbing
def test_repro_faults_env_reaches_cli_default(monkeypatch):
    """The CLI wires ``$REPRO_FAULTS`` as the --faults default (soak
    entry point); a malformed plan must surface as a usage error."""
    import importlib.util
    import sys

    root = os.path.join(os.path.dirname(__file__), "..")
    spec = importlib.util.spec_from_file_location(
        "run_sweep_cli", os.path.join(root, "scripts", "run_sweep.py"))
    mod = importlib.util.module_from_spec(spec)
    monkeypatch.setitem(sys.modules, "run_sweep_cli", mod)
    monkeypatch.setenv("REPRO_FAULTS", "bogus=1")
    spec.loader.exec_module(mod)
    rc = mod.main(["--days", "0.02", "--files", "300", "--cache-tb", "5",
                   "--quiet"])
    assert rc == 2
