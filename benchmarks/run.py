"""Benchmark runner — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (plus per-row comparison
columns where the paper provides reference values).

  table2   bench_validation   (simulation correctness, 5 metrics)
  table6/7 bench_hcdc         (jobs done, volumes for cfg I/II/III)
  table8   bench_cost         (monthly GCS cost, cfg III)
  hotloop  bench_tick_engine  (transfer-manager tick engines)
  sweep    bench_sweep        (scenario-sweep engine: process configs/sec
                               + batched-backend lanes/sec)
  fleet    bench_fleet        (worker-fleet lane scaling: 1024/10k-lane
                               grids across a workers axis + bitwise
                               parity gate vs the serial registry path)
  roofline bench_roofline     (dry-run roofline terms per cell)

Env knobs: HCDC_RUNS (default 1), HCDC_DAYS (90), HCDC_FILES (1e6),
VALIDATION_RUNS (2), SWEEP_CONFIGS (8), FAST=1 (reduced scales for CI
smoke), BENCH_JSON=path (also write every row as a JSON document with
name/us_per_call/derived fields — the CI perf-trajectory artifact).

A bench module that raises does not abort the remaining modules, but the
runner exits non-zero so CI catches the breakage.
"""

from __future__ import annotations

import json
import os
import sys
import time
import traceback
from typing import Dict, List

# `python benchmarks/run.py` puts benchmarks/ (not the repo root) on
# sys.path; make `from benchmarks import bench_*` work from anywhere.
sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))


def main() -> int:
    fast = os.environ.get("FAST", "0") == "1"
    t0 = time.time()
    collected: List[Dict] = []
    failures: List[str] = []

    def section(name, fn):
        """Run one bench module; record rows, keep going on failure."""
        try:
            rows = fn()
        except Exception:
            traceback.print_exc()
            failures.append(name)
            print(f"# BENCH FAILED: {name}", flush=True)
            return
        collected.extend(rows)

    def validation():
        from benchmarks import bench_validation
        runs = int(os.environ.get("VALIDATION_RUNS", "1" if fast else "2"))
        horizon = 2.0 if fast else None
        rows = bench_validation.run(n_runs=runs, horizon_days=horizon)
        for r in rows:
            print(f"{r['name']},{r['us_per_call']:.0f},{r['derived']:.4g},"
                  f"paper={r['paper']:.4g},diff={r['diff_pct']:+.2f}%",
                  flush=True)
        return rows

    section("validation", validation)

    hruns = int(os.environ.get("HCDC_RUNS", "1"))
    days = int(os.environ.get("HCDC_DAYS", "5" if fast else "90"))
    files = int(os.environ.get("HCDC_FILES", "50000" if fast else "1000000"))

    def hcdc():
        from benchmarks import bench_hcdc
        rows = bench_hcdc.run(n_runs=hruns, days=days, n_files=files)
        for r in rows:
            ref = (f",paper={r['paper']:.4g},diff={r['diff_pct']:+.2f}%"
                   if r.get("paper") else "")
            print(f"{r['name']},{r['us_per_call']:.0f},"
                  f"{r['derived']:.4g}{ref}", flush=True)
        return rows

    section("hcdc", hcdc)

    def cost():
        from benchmarks import bench_cost
        rows = bench_cost.run(n_runs=hruns, days=days, n_files=files)
        for r in rows:
            print(f"{r['name']},{r['us_per_call']:.0f},{r['derived']:.4g},"
                  f"paper={r['paper']:.4g},diff={r['diff_pct']:+.2f}%",
                  flush=True)
        return rows

    section("cost", cost)

    def tick_engine():
        from benchmarks import bench_tick_engine
        rows = bench_tick_engine.run()
        for r in rows:
            print(f"{r['name']},{r['us_per_call']:.1f},{r['derived']:.4g}",
                  flush=True)
        return rows

    section("tick_engine", tick_engine)

    def sweep():
        from benchmarks import bench_sweep
        sweep_cfgs = int(os.environ.get("SWEEP_CONFIGS", "4" if fast else "8"))
        rows = bench_sweep.run(n_configs=sweep_cfgs,
                               days=0.1 if fast else 0.25, fast=fast)
        for r in rows:
            print(f"{r['name']},{r['us_per_call']:.0f},{r['derived']:.4g}",
                  flush=True)
        return rows

    section("sweep", sweep)

    def fleet():
        from benchmarks import bench_fleet
        rows = bench_fleet.run(fast=fast)
        for r in rows:
            print(f"{r['name']},{r['us_per_call']:.0f},{r['derived']:.4g}",
                  flush=True)
        return rows

    section("fleet", fleet)

    def roofline():
        from benchmarks import bench_roofline
        rows = bench_roofline.run()
        for r in rows:
            extra = ""
            if "dominant" in r:
                extra = (f",dom={r['dominant']},c={r['compute_s']:.3f}s,"
                         f"m={r['memory_s']:.3f}s,"
                         f"coll={r['collective_s']:.3f}s,"
                         f"useful={r['useful']:.3f}")
            d = r["derived"]
            d_str = f"{d:.4f}" if isinstance(d, float) else str(d)
            print(f"{r['name']},{r['us_per_call']:.0f},{d_str}{extra}",
                  flush=True)
        return rows

    section("roofline", roofline)

    wall = time.time() - t0
    print(f"# total benchmark wall time: {wall:.1f}s")

    json_path = os.environ.get("BENCH_JSON", "")
    if json_path:
        doc = {
            "wall_s": wall,
            "fast": fast,
            "failures": failures,
            "benches": [
                {"name": r["name"],
                 "us_per_call": float(r["us_per_call"]),
                 "derived": (float(r["derived"])
                             if isinstance(r["derived"], (int, float))
                             else str(r["derived"]))}
                for r in collected
            ],
        }
        if os.path.dirname(json_path):
            os.makedirs(os.path.dirname(json_path), exist_ok=True)
        with open(json_path, "w") as f:
            json.dump(doc, f, indent=2)
        print(f"# wrote {json_path} ({len(collected)} rows)")

    if failures:
        print(f"# FAILED benches: {', '.join(failures)}", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
