"""Cross-validation of the batched (jax) sweep backend against the
event-driven reference engine.

The two engines share catalogue and job-arrival randomness draw-for-draw
but differ in clocking (fixed tick vs. event jumps) and in the per-job
selection/duration stream interleaving, so agreement is statistical: the
per-lane tolerance is the paper's Table 2 validation tolerance (5%), the
same bar the reference engine itself is held to against the paper.
"""

import numpy as np
import pytest

from repro.core.hcdc import HCDCScenario
from repro.core.scenarios import (
    ScenarioSpec,
    build_config,
    expand_grid,
    pack_specs,
    with_seeds,
)
from repro.sim.batched import simulate_packed
from repro.sim.sweep import run_sweep

# Table 2 validation tolerance (fractional): the §4.2 bar for "the
# simulation reproduces the system" — reused as the per-lane parity bar.
TOL = 0.05

TINY = dict(days=0.25, n_files=1000)


def _close(a, b, tol=TOL, floor=1.0):
    return abs(a - b) <= tol * max(abs(a), abs(b), floor)


def _assert_lane_parity(ref, jx, tol=TOL):
    assert len(ref.results) == len(jx.results)
    for a, b in zip(ref.results, jx.results):
        assert b.spec == a.spec
        lbl = a.spec.label
        # A capacity-constrained cold tier amplifies realization noise:
        # *which* few files land in the small GCS window decides the
        # recall (egress) volume, so the cost bar doubles there.
        cost_tol = tol if a.spec.gcs_limit_tb is None or \
            a.spec.gcs_limit_tb == float("inf") else 2 * tol
        assert _close(a.jobs_done, b.jobs_done, tol), \
            f"{lbl}: jobs_done {a.jobs_done} vs {b.jobs_done}"
        assert _close(a.cost_usd, b.cost_usd, cost_tol), \
            f"{lbl}: cost {a.cost_usd} vs {b.cost_usd}"
        assert _close(a.metrics["download_pb"], b.metrics["download_pb"],
                      tol, floor=1e-6), f"{lbl}: download_pb"
        assert abs(a.metrics["jobs_submitted"]
                   - b.metrics["jobs_submitted"]) <= 3, \
            f"{lbl}: jobs_submitted"
        assert abs(a.metrics["job_waiting_h_mean"]
                   - b.metrics["job_waiting_h_mean"]) <= 0.05, \
            f"{lbl}: job_waiting_h_mean"


# ------------------------------------------------------------------ packing
def test_pack_specs_replicates_reference_catalogue():
    """The packed sizes/popularity replicate the event engine's host RNG
    draws bit-for-bit (modulo the f32 cast)."""
    spec = ScenarioSpec(base="III", cache_tb=20.0, seed=3, **TINY)
    grid = pack_specs([spec])
    sc = HCDCScenario(build_config(spec))
    for si, st in enumerate(sc.sites):
        np.testing.assert_allclose(grid.sizes[0, si], st.sizes, rtol=1e-6)
        np.testing.assert_array_equal(grid.pop[0, si], st.pop)
    assert grid.n_jobs[0].sum() > 0


def test_pack_specs_deduplicates_pricing_lanes():
    specs = expand_grid({
        "base": "III", "cache_tb": [10.0, 20.0],
        "egress": ["internet", "direct", "interconnect"],
        "storage_price": [None, 0.02], **TINY,
    })
    grid = pack_specs(specs)
    assert grid.n_specs == 12
    assert grid.n_lanes == 2  # only cache_tb changes the dynamics
    assert sorted(set(grid.lane_of.tolist())) == [0, 1]
    # every spec keeps its own cost model
    assert len(grid.cost_models) == 12


def test_pack_specs_workload_gets_own_dynamics_lane():
    """Workload reshapes the simulated job stream, so workload-only
    variants must NOT share a lane — unlike pricing-only variants."""
    specs = expand_grid({
        "base": "III", "cache_tb": 15.0,
        "workload": ["steady", "diurnal:amplitude=0.8"],
        "egress": ["internet", "direct"], **TINY,
    })
    grid = pack_specs(specs)
    assert grid.n_specs == 4
    assert grid.n_lanes == 2  # workload splits, egress does not
    # the compiled schedule is exported per lane: steady is exactly ones,
    # the diurnal lane is mean-preserving but non-constant
    steady_lane = int(grid.lane_of[specs.index(next(
        s for s in specs if s.workload == "steady"))])
    assert (grid.rate_mult[steady_lane] == 1.0).all()
    # (the 0.25-day horizon covers the rising quarter of the default
    # 24 h diurnal period, so the lane is >= 1 but clearly non-constant)
    other = grid.rate_mult[1 - steady_lane]
    assert other.max() > 1.5 and other.max() > other.min()
    # modulated lanes still carry jobs
    assert (grid.n_jobs > 0).all()


def test_pack_specs_rejects_nonuniform_and_curves():
    with pytest.raises(ValueError, match="uniform 'days'"):
        pack_specs([ScenarioSpec(days=0.25, n_files=100),
                    ScenarioSpec(days=0.5, n_files=100)])
    with pytest.raises(ValueError, match="uniform 'n_files'"):
        pack_specs([ScenarioSpec(days=0.25, n_files=100),
                    ScenarioSpec(days=0.25, n_files=200)])
    with pytest.raises(ValueError, match="curves"):
        pack_specs([ScenarioSpec(days=0.25, n_files=100, curves=True)])
    with pytest.raises(ValueError, match="tick"):
        pack_specs([ScenarioSpec(days=0.25, n_files=100)], tick=0.0)


def test_run_sweep_rejects_unknown_backend():
    with pytest.raises(ValueError, match="backend"):
        run_sweep([ScenarioSpec(**TINY)], backend="fortran")


# ------------------------------------------- lane chunking & shape buckets
def test_lane_chunked_bitwise_identical():
    """Chunked execution (ISSUE 4) splits lanes into fixed-size padded
    chunks; lanes never interact, so per-lane results must be *bitwise*
    identical to the unchunked run — including the odd-size last chunk."""
    specs = expand_grid({
        "base": "III", "cache_tb": [10.0, 15.0, 20.0, 25.0, 30.0],
        "seed": 7, **TINY,
    })
    whole = run_sweep(specs, backend="jax", tick=60.0)
    chunked = run_sweep(specs, backend="jax", tick=60.0, lane_chunk=2)
    for a, b in zip(whole.results, chunked.results):
        assert a.spec == b.spec
        assert a.metrics == b.metrics, a.spec.label
        assert a.cost_usd == b.cost_usd


def test_bucket_padding_bitwise_unchanged():
    """Rounding K/J up to power-of-two buckets (compile-cache stability)
    only adds window slots the validity mask rejects and job rows that
    never submit — every raw per-lane aggregate stays bitwise equal."""
    specs = expand_grid({"base": "III", "cache_tb": [15.0, 30.0], **TINY})
    bucketed = pack_specs(specs, tick=60.0)
    exact = pack_specs(specs, tick=60.0, bucket=False)
    # the bench/test catalogue is non-degenerate: bucketing actually pads
    assert bucketed.max_jobs_per_tick >= exact.max_jobs_per_tick
    assert bucketed.job_fid.shape[2] >= exact.job_fid.shape[2]
    assert bucketed.max_jobs_per_tick & (bucketed.max_jobs_per_tick - 1) == 0
    assert bucketed.job_fid.shape[2] & (bucketed.job_fid.shape[2] - 1) == 0
    out_b = simulate_packed(bucketed)
    out_e = simulate_packed(exact)
    assert set(out_b) == set(out_e)
    for key in out_e:
        if key in ("download_b", "wait_h_sum"):
            # f32 sums over the padded J axis: identical addends (padding
            # contributes exact zeros) but a different reduction-tree
            # shape — equal to summation-order ulp, not bitwise.
            np.testing.assert_allclose(out_b[key], out_e[key], rtol=1e-6,
                                       err_msg=key)
        else:
            np.testing.assert_array_equal(out_b[key], out_e[key],
                                          err_msg=key)


def test_shard_map_bitwise_identical():
    """``shard=True`` (ISSUE 10) runs each lane batch as one
    ``jax.shard_map`` program over the local device mesh; lane programs
    exchange no collectives, so per-lane results — including lanes
    replicated to pad the batch to a mesh multiple — must be *bitwise*
    identical to the per-chunk Python loop."""
    specs = expand_grid({
        "base": "III", "cache_tb": [10.0, 15.0, 20.0], "seed": 7, **TINY,
    })
    grid = pack_specs(specs, tick=60.0)
    plain = simulate_packed(grid)
    sharded = simulate_packed(grid, shard=True)
    assert set(plain) == set(sharded)
    for key in plain:
        np.testing.assert_array_equal(plain[key], sharded[key],
                                      err_msg=key)
    # chunked + sharded: chunk size rounds up to a mesh multiple
    chunked = simulate_packed(grid, lane_chunk=2, shard=True)
    for key in plain:
        np.testing.assert_array_equal(plain[key], chunked[key],
                                      err_msg=key)


def test_shard_excludes_devices_round_robin():
    import jax

    spec = ScenarioSpec(**TINY)
    grid = pack_specs([spec], tick=60.0)
    with pytest.raises(ValueError, match="shard"):
        simulate_packed(grid, shard=True, devices=jax.devices())


def test_lane_chunk_knob_validation():
    with pytest.raises(ValueError, match="lane_chunk"):
        run_sweep([ScenarioSpec(**TINY)], backend="jax", lane_chunk=0)
    with pytest.raises(ValueError, match="jax"):
        run_sweep([ScenarioSpec(**TINY)], backend="process", lane_chunk=4)
    with pytest.raises(ValueError, match="jax"):
        run_sweep([ScenarioSpec(**TINY)], backend="process", devices=[])
    with pytest.raises(ValueError, match="devices"):
        run_sweep([ScenarioSpec(**TINY)], backend="jax", devices=[])


def test_pack_specs_memoizes_catalogue_draws():
    """Lanes differing only in capacity limits replicate the same RNG
    stream, so the packed catalogue/job arrays must be identical (drawn
    once, shared) while capacity arrays still differ per lane."""
    specs = expand_grid({
        "base": "III", "cache_tb": [10.0, 20.0], "gcs_limit_tb": [None, 5.0],
        **TINY,
    })
    grid = pack_specs(specs)
    assert grid.n_lanes == 4
    for li in range(1, grid.n_lanes):
        np.testing.assert_array_equal(grid.sizes[0], grid.sizes[li])
        np.testing.assert_array_equal(grid.pop[0], grid.pop[li])
        np.testing.assert_array_equal(grid.job_fid[0], grid.job_fid[li])
        np.testing.assert_array_equal(grid.job_tail[0], grid.job_tail[li])
    assert len({tuple(r) for r in grid.disk_limit[:, :1].tolist()}) == 2


# ------------------------------------------------- reference cross-checks
@pytest.fixture(scope="module")
def small_grid():
    """8 dynamics lanes x pricing variants, covering cfg I/II/III, limited
    and unlimited tiers, both egress families."""
    specs = (expand_grid({
        "base": "III", "cache_tb": [10.0, 25.0, 60.0],
        "egress": ["internet", "direct"], "seed": 1, **TINY,
    }) + [
        ScenarioSpec(base="I", seed=2, **TINY),
        ScenarioSpec(base="II", seed=2, **TINY),
        ScenarioSpec(base="III", cache_tb=15.0, gcs_limit_tb=5.0,
                     seed=3, **TINY),
        ScenarioSpec(base="III", cache_tb=15.0, job_rate_scale=1.5,
                     seed=4, **TINY),
        ScenarioSpec(base="III", cache_tb=15.0, storage_price=0.02,
                     seed=4, **TINY),
    ])
    ref = run_sweep(specs, workers=2)
    jx = run_sweep(specs, backend="jax")
    return ref, jx


def test_jax_backend_matches_reference_per_lane(small_grid):
    ref, jx = small_grid
    _assert_lane_parity(ref, jx)


def test_jax_backend_volume_metrics_track_reference(small_grid):
    ref, jx = small_grid
    for a, b in zip(ref.results, jx.results):
        for key in ("gcs_to_disk_pb", "disk_to_gcs_pb", "gcs_used_pb"):
            assert _close(a.metrics[key], b.metrics[key], 2 * TOL,
                          floor=1e-4), f"{a.spec.label}: {key}"


def test_jax_backend_respects_config_structure(small_grid):
    _, jx = small_grid
    by_label = {r.spec.label: r for r in jx.results}
    cfg1 = next(r for r in jx.results if r.spec.base == "I")
    cfg2 = next(r for r in jx.results if r.spec.base == "II")
    assert cfg1.metrics["gcs_used_pb"] == 0.0
    assert cfg1.cost_usd == 0.0
    assert cfg2.metrics["gcs_to_disk_pb"] == 0.0
    limited = next(r for r in jx.results if r.spec.gcs_limit_tb == 5.0)
    assert limited.metrics["gcs_used_pb"] <= 5.0e12 / 1e15 + 1e-9
    # pricing-only variants share dynamics, not bills
    a = by_label["cfgIII,cache=10TB,egress=internet,seed=1"]
    b = by_label["cfgIII,cache=10TB,egress=direct,seed=1"]
    assert a.metrics["jobs_done"] == b.metrics["jobs_done"]
    assert a.metrics["gcs_to_disk_pb"] == b.metrics["gcs_to_disk_pb"]
    assert b.network_usd < a.network_usd


def test_jax_backend_deterministic(small_grid):
    """Same spec batch twice -> bitwise-identical results. (Different batch
    *shapes* may differ in the last float ulp: XLA reduction order.)"""
    _, jx = small_grid
    specs = [r.spec for r in jx.results][:4]
    once = run_sweep(specs, backend="jax")
    again = run_sweep(specs, backend="jax")
    for a, b in zip(once.results, again.results):
        assert a.metrics == b.metrics
        assert a.cost_usd == b.cost_usd


def test_jax_backend_tick_coarsening_stays_close(small_grid):
    """A coarser clock (30/60 s vs the 10 s generator interval) shifts
    event times by at most one tick; totals must stay within the parity
    bar. 60 s is the tick ``benchmarks/bench_sweep.py`` runs at."""
    _, jx = small_grid
    specs = [r.spec for r in jx.results]
    for tick, jobs_tol, cost_tol in ((30.0, 0.02, 0.04), (60.0, 0.02, 0.05)):
        coarse = run_sweep(specs, backend="jax", tick=tick)
        for a, b in zip(jx.results, coarse.results):
            assert _close(a.jobs_done, b.jobs_done, jobs_tol), \
                f"tick={tick}: {a.spec.label}"
            assert _close(a.cost_usd, b.cost_usd, cost_tol), \
                f"tick={tick}: {a.spec.label}"


# ------------------------------------------------------- workload parity
@pytest.fixture(scope="module")
def workload_grid(tmp_path_factory):
    """One spec per workload model (incl. a CSV trace), both backends."""
    trace = tmp_path_factory.mktemp("wl") / "trace.csv"
    trace.write_text("time_s,rate_mult\n0,1.5\n7200,0.5\n14400,2.0\n")
    wls = [
        "steady",
        "diurnal:amplitude=0.8,period_h=3",
        "campaign:period_h=2,duty=0.25,peak=2.5,off=0.5",
        "zipf-drift:power_end=1.5,steps=4",
        f"trace:{trace}",
    ]
    specs = [ScenarioSpec(base="III", cache_tb=15.0, seed=0, workload=w,
                          **TINY) for w in wls]
    ref = run_sweep(specs, workers=2)
    jx = run_sweep(specs, backend="jax")
    return ref, jx


def test_workload_models_match_reference_per_lane(workload_grid):
    """Every workload model agrees across backends: jobs at the Table 2
    bar; cost at the doubled bar, because at this 0.25-day quick-test
    horizon the reference engine's own cost realization noise is ~±6%
    (see the acceptance-grid note below) and rate modulation churns the
    cache harder. The slow 0.75-day test below applies the full 5% bar."""
    ref, jx = workload_grid
    for a, b in zip(ref.results, jx.results):
        lbl = a.spec.label
        assert _close(a.jobs_done, b.jobs_done, TOL), \
            f"{lbl}: jobs_done {a.jobs_done} vs {b.jobs_done}"
        assert _close(a.cost_usd, b.cost_usd, 2 * TOL), \
            f"{lbl}: cost {a.cost_usd} vs {b.cost_usd}"
        assert _close(a.metrics["download_pb"], b.metrics["download_pb"],
                      TOL, floor=1e-6), f"{lbl}: download_pb"


@pytest.mark.slow
def test_workload_models_acceptance_full_bar(tmp_path):
    """ISSUE 3 acceptance: per-lane jobs-done and bill totals for every
    workload model match across backends within the Table 2 5% tolerance
    (0.75-day horizon, where reference realization noise is ~±2%)."""
    trace = tmp_path / "trace.csv"
    trace.write_text("time_s,rate_mult\n0,1.5\n21600,0.5\n43200,2.0\n")
    wls = [
        "steady",
        "diurnal:amplitude=0.8,period_h=3",
        "campaign:period_h=2,duty=0.25,peak=2.5,off=0.5",
        "zipf-drift:power_end=1.5,steps=4",
        f"trace:{trace}",
    ]
    specs = [ScenarioSpec(base="III", cache_tb=15.0, seed=0, workload=w,
                          days=0.75, n_files=1000) for w in wls]
    ref = run_sweep(specs, workers=2)
    jx = run_sweep(specs, backend="jax")
    _assert_lane_parity(ref, jx)


def test_workload_job_streams_identical_across_backends(workload_grid):
    """Both backends derive the arrival stream from the same modulated
    count draws, so submissions match exactly, not just statistically."""
    ref, jx = workload_grid
    for a, b in zip(ref.results, jx.results):
        assert a.metrics["jobs_submitted"] == b.metrics["jobs_submitted"], \
            a.spec.workload


def test_workload_shapes_move_the_observables(workload_grid):
    """The axis actually does something: the trace's long-run mean is 4/3
    (1.5/0.5/2.0 over equal thirds), while the mean-1 shapes (diurnal and
    campaign over whole periods, rate-neutral zipf drift) keep the total."""
    ref, _ = workload_grid
    by = {r.spec.workload.partition(":")[0]: r for r in ref.results}
    steady = by["steady"].metrics["jobs_submitted"]
    assert by["trace"].metrics["jobs_submitted"] > 1.2 * steady
    assert by["zipf-drift"].metrics["jobs_submitted"] == steady
    assert by["campaign"].metrics["jobs_submitted"] == \
        pytest.approx(steady, rel=0.05)


# ------------------------------------------- tick_impl selection (ISSUE 7)
QUICK = dict(days=0.1, n_files=1000)


@pytest.fixture(scope="module")
def impl_grid():
    """A pricing-deduplicating grid run under every CPU-runnable
    tick_impl (same specs, tick=60 to keep the interpret path quick)."""
    specs = expand_grid({
        "base": "III", "cache_tb": [10.0, 25.0],
        "egress": ["internet", "direct"],
        "gcs_limit_tb": [None, 5.0], "seed": 1, **QUICK,
    })
    out = {impl: run_sweep(specs, backend="jax", tick=60.0, tick_impl=impl)
           for impl in ("jnp", "pallas_interpret", "auto")}
    return specs, out


def test_tick_impl_interpret_parity_small_grid(impl_grid):
    """The fused Pallas kernels (interpret mode) track the jnp oracle at
    the Table 2 bar. Agreement is statistical, not bitwise: the blocked
    GCS-admission cumsum reassociates floats, so capacity-boundary ties
    can admit a different file."""
    _, out = impl_grid
    _assert_lane_parity(out["jnp"], out["pallas_interpret"])


def test_tick_impl_auto_resolves_to_jnp_on_cpu(impl_grid):
    """On a CPU host "auto" must be the jnp program *bitwise* — never a
    silent interpret-mode fallback (registry resolution contract)."""
    import jax

    _, out = impl_grid
    if jax.default_backend() != "cpu":
        pytest.skip("auto resolves to the compiled kernel on accelerators")
    for a, b in zip(out["jnp"].results, out["auto"].results):
        assert a.spec == b.spec
        assert a.metrics == b.metrics, a.spec.label
        assert a.cost_usd == b.cost_usd


def test_tick_impl_interpret_deterministic(impl_grid):
    specs, out = impl_grid
    again = run_sweep(specs, backend="jax", tick=60.0,
                      tick_impl="pallas_interpret")
    for a, b in zip(out["pallas_interpret"].results, again.results):
        assert a.metrics == b.metrics, a.spec.label
        assert a.cost_usd == b.cost_usd


def test_tick_impl_interpret_parity_216_config_grid():
    """ISSUE 7 acceptance: interpret-mode kernels vs the jnp oracle on
    the 216-config bench pricing grid (4 cache x 3 egress x 9 prices x
    2 seeds — 8 dynamics lanes after pricing dedup), within the Table 2
    5% tolerance per config."""
    specs = with_seeds(expand_grid({
        "base": "III",
        "cache_tb": [10.0, 20.0, 40.0, 80.0],
        "egress": ["internet", "direct", "interconnect"],
        "storage_price": [round(0.018 + 0.002 * i, 3) for i in range(9)],
        **QUICK,
    }), 2)
    assert len(specs) == 216
    jnp_out = run_sweep(specs, backend="jax", tick=60.0, tick_impl="jnp")
    pal_out = run_sweep(specs, backend="jax", tick=60.0,
                        tick_impl="pallas_interpret")
    _assert_lane_parity(jnp_out, pal_out)


@pytest.mark.slow
def test_tick_impl_interpret_matches_reference_table2_bar():
    """Slow acceptance: the kernel path holds the same Table 2 bar
    against the event-driven *reference* engine that the jnp program is
    held to (0.75-day horizon; see the 64-config grid note)."""
    specs = with_seeds(expand_grid({
        "base": "III", "cache_tb": [10.0, 40.0],
        "egress": ["internet", "direct"],
        "days": 0.75, "n_files": 1000,
    }), 2)
    ref = run_sweep(specs, workers=2)
    pal = run_sweep(specs, backend="jax", tick_impl="pallas_interpret")
    _assert_lane_parity(ref, pal)


def test_tick_impl_knob_validation():
    with pytest.raises(ValueError, match="tick_impl"):
        run_sweep([ScenarioSpec(**TINY)], backend="jax",
                  tick_impl="fortran")
    with pytest.raises(ValueError, match="jax"):
        run_sweep([ScenarioSpec(**TINY)], backend="process",
                  tick_impl="pallas_interpret")
    # "auto" is the neutral default and valid for every backend
    run_sweep([ScenarioSpec(days=0.1, n_files=100)], backend="process",
              tick_impl="auto")


def test_simulate_packed_use_pallas_removed():
    """The use_pallas= alias is gone: the keyword no longer exists, and
    a legacy positional boolean in the tick_impl slot raises with the
    upgrade hint instead of routing through the removed shim."""
    spec = ScenarioSpec(base="III", cache_tb=15.0, seed=0, **QUICK)
    grid = pack_specs([spec], tick=60.0)
    with pytest.raises(TypeError, match="use_pallas"):
        simulate_packed(grid, use_pallas=False)
    with pytest.raises(ValueError, match="tick_impl"):
        simulate_packed(grid, False)


# ------------------------------------------- acceptance grid (64 configs)
@pytest.mark.slow
def test_jax_backend_matches_reference_64_config_grid():
    """ISSUE 2 acceptance: a >= 64-config grid agrees with the process
    backend per lane within the Table 2 tolerance for jobs done and the
    monthly-bill total.

    Horizon note: at 0.25 simulated days the *reference engine's own*
    seed-to-seed cost spread is ~±6% (recall volume on a churning cache is
    the noisiest observable), so a 5% per-lane bar is only meaningful once
    the horizon averages that noise down — 0.75 days brings it to ~±2%.
    """
    specs = with_seeds(expand_grid({
        "base": "III",
        "cache_tb": [10.0, 20.0, 40.0, 80.0],
        "egress": ["internet", "direct"],
        "storage_price": [None, 0.02],
        "days": 0.75, "n_files": 1000,
    }), 4)
    assert len(specs) == 64
    ref = run_sweep(specs, workers=2)
    jx = run_sweep(specs, backend="jax")
    _assert_lane_parity(ref, jx)


# ------------------------------------------------- series capture (ISSUE 8)
def test_record_series_off_is_bitwise_identical():
    """Capture off must trace the exact pre-capture program: every
    original output key is bitwise equal with and without capture, and
    the series buffers appear only when capture is on."""
    specs = with_seeds([ScenarioSpec(base="III", cache_tb=15.0, **QUICK)], 2)
    grid = pack_specs(specs, tick=60.0)
    plain = simulate_packed(grid)
    rec = simulate_packed(grid, record_series=6)
    assert not any(k.startswith("ser_") for k in plain)
    for k in plain:
        np.testing.assert_array_equal(plain[k], rec[k], err_msg=k)
    for k in ("ser_disk", "ser_gcs", "ser_queue", "ser_run", "ser_link"):
        assert k in rec


def test_record_series_chunked_matches_unchunked():
    specs = with_seeds([ScenarioSpec(base="III", cache_tb=15.0, **QUICK)], 2)
    grid = pack_specs(specs, tick=60.0)
    whole = simulate_packed(grid, record_series=6)
    chunked = simulate_packed(grid, record_series=6, lane_chunk=1)
    for k in whole:
        np.testing.assert_array_equal(whole[k], chunked[k], err_msg=k)


def test_record_series_validation():
    from repro.sim.batched import series_from_capture

    spec = ScenarioSpec(base="III", cache_tb=15.0, **QUICK)
    grid = pack_specs([spec], tick=60.0)
    with pytest.raises(ValueError, match="record_series"):
        simulate_packed(grid, record_series=0)
    out = simulate_packed(grid)  # capture off
    with pytest.raises(ValueError, match="record_series"):
        series_from_capture(grid, out, 0, None)
    with pytest.raises(KeyError, match="series buffers"):
        series_from_capture(grid, out, 0, 6)
    with pytest.raises(ValueError, match="record_series"):
        run_sweep([spec], backend="process", record_series=6)


def test_series_from_capture_schema():
    """Stride, sample count, names, and the ``TimeSeries`` conversion."""
    from repro.sim.batched import LINK_TYPES, series_from_capture

    spec = ScenarioSpec(base="III", cache_tb=15.0, seed=3, **QUICK)
    grid = pack_specs([spec], tick=60.0)
    stride = 7  # deliberately not dividing n_ticks
    out = simulate_packed(grid, record_series=stride)
    n_samples = (grid.n_ticks - 1) // stride + 1
    series = series_from_capture(grid, out, 0, stride)
    expect = {"gcs_used"}
    for name in grid.site_names:
        expect.add(f"{name}.disk_used")
        expect.add(f"{name}.running_jobs")
        expect.add(f"{name}.wait_queue")
        expect.update(f"{name}.link_active.{lk}" for lk in LINK_TYPES)
    assert set(series) == expect
    times = np.asarray(grid.times)[::stride]
    for name, ts in series.items():
        assert len(ts.times) == len(ts.values) == n_samples, name
        np.testing.assert_allclose(ts.times, times)
        assert min(ts.values) >= 0.0, name
    assert max(series[f"{grid.site_names[0]}.running_jobs"].values) > 0


def test_series_parity_with_event_engine():
    """Cross-backend series parity: the time-averaged occupancy and
    running-jobs series agree within the Table 2 bar (5%) on a 0.75-day
    horizon (the horizon that averages realization noise below the bar —
    see the 64-config grid's note). Point-sample extremes (``max``) stay
    unasserted: *when* the peak lands differs between the clocking
    models by design."""
    import dataclasses

    horizon = dict(days=0.75, n_files=1000)
    base_specs = [
        ScenarioSpec(base="III", cache_tb=15.0, seed=3, **horizon),
        ScenarioSpec(base="II", seed=2, **horizon),
    ]
    curve_specs = [dataclasses.replace(s, curves=True) for s in base_specs]
    ref = run_sweep(curve_specs, workers=2)
    jx = run_sweep(base_specs, backend="jax", record_series=360)
    for a, b in zip(ref.results, jx.results):
        assert a.series and b.series
        common = set(a.series) & set(b.series)
        # both backends record occupancy + running jobs under one schema
        assert {"gcs_used"} | {
            f"{s}.{k}" for s in ("Site-1", "Site-2")
            for k in ("disk_used", "running_jobs")} <= common
        for name in sorted(common):
            sa, sb = a.series[name], b.series[name]
            assert sa["n"] == sb["n"], name
            assert _close(sa["mean"], sb["mean"], TOL), \
                f"{a.spec.label}: {name} mean {sa['mean']} vs {sb['mean']}"


def test_run_sweep_jax_attaches_series_digests():
    specs = with_seeds([ScenarioSpec(base="III", cache_tb=15.0, **QUICK)], 2)
    plain = run_sweep(specs, backend="jax")
    rec = run_sweep(specs, backend="jax", record_series=6)
    assert all(not r.series for r in plain.results)
    for a, b in zip(plain.results, rec.results):
        assert b.series and "gcs_used" in b.series
        assert set(b.series["gcs_used"]) == {"n", "min", "mean", "max",
                                             "last"}
        # attaching digests must not perturb the simulation itself
        assert a.metrics == b.metrics
        assert a.cost_usd == b.cost_usd
