"""Production mesh definition.

A function (not a module-level constant) so importing this module never
touches jax device state. The dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` *before* any jax
import to obtain 512 placeholder devices; smoke tests and benchmarks see
the single real CPU device.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; 2 pods = 512 chips multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_debug_mesh(data: int = 1, model: int = 1):
    """Tiny mesh over however many (host) devices exist — for tests."""
    return jax.make_mesh((data, model), ("data", "model"))
