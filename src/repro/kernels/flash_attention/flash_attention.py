"""Pallas TPU kernel: blocked online-softmax attention (forward).

Classic flash attention adapted for TPU MXU tiling: queries tiled in
(BLOCK_Q x head_dim) VMEM blocks; each grid step loops over KV blocks with
``jax.lax.fori_loop``, maintaining the running max / normalizer / weighted
accumulator in f32. Causal + sliding-window masking is applied from block
position arithmetic (whole KV blocks outside the window are still visited
but fully masked — the simple variant; the §Perf iteration notes the
block-skip upgrade).

Supports GQA by mapping each Q-head grid index to its KV head. MXU
alignment: BLOCK_Q = BLOCK_K = 128; head_dim padded to 128 by the wrapper.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_Q = 128
BLOCK_K = 128
NEG_INF = -1e30


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, *, scale: float, causal: bool,
                 window: int, seq_len: int):
    qi = pl.program_id(2)
    q = q_ref[0, 0].astype(jnp.float32) * scale  # [BQ, hd]
    m = jnp.full((BLOCK_Q,), NEG_INF, dtype=jnp.float32)
    l = jnp.zeros((BLOCK_Q,), dtype=jnp.float32)
    acc = jnp.zeros((BLOCK_Q, q.shape[-1]), dtype=jnp.float32)

    q_pos = qi * BLOCK_Q + jax.lax.broadcasted_iota(jnp.int32, (BLOCK_Q, BLOCK_K), 0)

    n_kv = seq_len // BLOCK_K

    def body(kj, carry):
        m, l, acc = carry
        k = k_ref[0, 0, pl.ds(kj * BLOCK_K, BLOCK_K)].astype(jnp.float32)
        v = v_ref[0, 0, pl.ds(kj * BLOCK_K, BLOCK_K)].astype(jnp.float32)
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32)  # [BQ, BK]
        k_pos = kj * BLOCK_K + jax.lax.broadcasted_iota(
            jnp.int32, (BLOCK_Q, BLOCK_K), 1)
        rel = q_pos - k_pos
        mask = jnp.ones_like(s, dtype=jnp.bool_)
        if causal:
            mask = jnp.logical_and(mask, rel >= 0)
        if window > 0:
            mask = jnp.logical_and(mask, rel < window)
        s = jnp.where(mask, s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m - m_new)
        l_new = corr * l + p.sum(axis=-1)
        acc_new = corr[:, None] * acc + jnp.dot(
            p.astype(v.dtype), v, preferred_element_type=jnp.float32)
        return m_new, l_new, acc_new

    m, l, acc = jax.lax.fori_loop(0, n_kv, body, (m, l, acc))
    o_ref[0, 0] = (acc / jnp.maximum(l, 1e-30)[:, None]).astype(o_ref.dtype)


def flash_attention_pallas(q, k, v, *, causal: bool = True,
                           window: int = 0, interpret: bool = True):
    """q: [B, nh, T, hd]; k/v: [B, nkv, S, hd] with nh % nkv == 0.

    Returns [B, nh, T, hd]. T and S must be multiples of 128 (the ops
    wrapper pads); hd should be 128-aligned for MXU efficiency.
    """
    B, nh, T, hd = q.shape
    _, nkv, S, _ = k.shape
    assert T % BLOCK_Q == 0 and S % BLOCK_K == 0
    group = nh // nkv
    scale = hd ** -0.5

    kernel = functools.partial(
        _attn_kernel, scale=scale, causal=causal, window=window, seq_len=S)

    return pl.pallas_call(
        kernel,
        grid=(B, nh, T // BLOCK_Q),
        in_specs=[
            pl.BlockSpec((1, 1, BLOCK_Q, hd), lambda b, h, i: (b, h, i, 0)),
            pl.BlockSpec((1, 1, S, hd), lambda b, h, i, g=group: (b, h // g, 0, 0)),
            pl.BlockSpec((1, 1, S, hd), lambda b, h, i, g=group: (b, h // g, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, BLOCK_Q, hd), lambda b, h, i: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, nh, T, hd), q.dtype),
        interpret=interpret,
    )(q, k, v)
