"""Mixture-of-Experts layer with capacity-bucketed dispatch (EP-shardable).

Router: softmax top-k with load-balancing auxiliary loss. Dispatch groups
token assignments by expert via argsort and scatters them into a dense
[E, C, d] buffer (capacity C = ceil(T*k/E * capacity_factor)); overflow
drops (tracked). The [E, C, d] buffer carries a sharding constraint on E
("expert" logical axis -> mesh "model"), so under pjit the scatter/gather
lowers to the EP all-to-all. Expert FFNs run as one batched einsum.

arctic-480b additionally has a parallel dense residual MLP
(``moe_dense_ff``) whose output is added to the MoE output.
"""

from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.modules import dense_init, init_mlp, swiglu

Params = Dict[str, jnp.ndarray]


def init_moe(key, cfg: ModelConfig) -> Params:
    d, f, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    ks = jax.random.split(key, 5)
    p = {
        "router": dense_init(ks[0], (d, e), in_axis_size=d, dtype=jnp.float32),
        "w_gate": dense_init(ks[1], (e, d, f), in_axis_size=d, dtype=cfg.dtype),
        "w_up": dense_init(ks[2], (e, d, f), in_axis_size=d, dtype=cfg.dtype),
        "w_down": dense_init(ks[3], (e, f, d), in_axis_size=f, dtype=cfg.dtype),
    }
    if cfg.moe_dense_ff:
        p["dense_mlp"] = init_mlp(ks[4], d, cfg.moe_dense_ff, cfg.dtype)
    return p


def router_topk(logits: jnp.ndarray, k: int) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """logits: [T, E] -> (gates [T,k], idx [T,k], aux_loss scalar)."""
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    gates, idx = jax.lax.top_k(probs, k)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)
    # Switch-style load-balance loss: E * sum_e f_e * p_e
    E = logits.shape[-1]
    me = jnp.mean(probs, axis=0)  # mean router prob per expert
    ce = jnp.mean(
        jnp.sum(jax.nn.one_hot(idx, E, dtype=jnp.float32), axis=1), axis=0
    ) / k  # fraction of tokens per expert
    aux = E * jnp.sum(me * ce)
    return gates, idx, aux


def moe_dispatch(x: jnp.ndarray, idx: jnp.ndarray, capacity: int,
                 n_experts: int) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """x: [T, d]; idx: [T, k] -> buffer [E, C+1, d], (e_sel, pos) for combine.

    Position-in-expert via sort: stable-sort flattened assignments by expert
    id; position = rank - first_rank_of_expert (searchsorted over the sorted
    ids). Overflow tokens land in a dead COLUMN (position C) per expert —
    keeping the expert dim exactly E so the EP sharding constraint on the
    leading axis stays divisible by the mesh's model axis.
    """
    T, k = idx.shape
    e_flat = idx.reshape(-1)  # [T*k]
    order = jnp.argsort(e_flat, stable=True)
    sorted_e = e_flat[order]
    start = jnp.searchsorted(sorted_e, sorted_e, side="left")
    pos_sorted = jnp.arange(T * k, dtype=jnp.int32) - start.astype(jnp.int32)
    # invert the permutation: pos[order[i]] = pos_sorted[i]
    pos = jnp.zeros_like(pos_sorted).at[order].set(pos_sorted)
    pos = pos.reshape(T, k)
    p_sel = jnp.minimum(pos, capacity)  # overflow -> dead column C
    buf = jnp.zeros((n_experts, capacity + 1, x.shape[-1]), dtype=x.dtype)
    tok_idx = jnp.broadcast_to(jnp.arange(T)[:, None], (T, k))
    buf = buf.at[idx, p_sel].add(x[tok_idx])
    return buf, idx, p_sel


def moe_combine(expert_out: jnp.ndarray, gates: jnp.ndarray,
                e_sel: jnp.ndarray, p_sel: jnp.ndarray) -> jnp.ndarray:
    """expert_out: [E, C(+1), d]; gather back per (token, k), weight-sum.

    The dead column is zeroed before the gather so dropped tokens
    contribute nothing."""
    C1 = expert_out.shape[1]
    col = jnp.arange(C1)
    expert_out = jnp.where(col[None, :, None] < C1 - 1, expert_out, 0.0)
    picked = expert_out[e_sel, p_sel]  # [T, k, d]
    return jnp.einsum("tkd,tk->td", picked, gates.astype(picked.dtype))


def _moe_local_dispatch(p: Params, cfg: ModelConfig, xt: jnp.ndarray,
                        gates, idx, mesh) -> jnp.ndarray:
    """Shard-local dispatch + explicit all-to-all reshard (EP proper).

    XLA lowers a global scatter into an (E-replicated buffer + all-reduce)
    pair — for olmoe train that is ~1.2 TB of all-reduce wire per step.
    Instead: tokens reshape to [S, T/S, ...] with S = the dp shard count
    (so every sort/searchsorted/scatter is *within* a shard), the
    per-shard buffers [S, E, C_loc, d] carry (dp, model) sharding, and the
    transpose to [E, S*C_loc, d] with model-sharded E is the canonical
    dispatch all-to-all. Wire cost: (n-1)/n x buffer instead of
    2(n-1)/n x buffer x replication round-trips.
    """
    import numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P

    daxes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    S = int(np.prod([mesh.shape[a] for a in daxes])) if daxes else 1
    T, d = xt.shape
    E, k = cfg.n_experts, cfg.top_k
    if S <= 1 or T % S != 0:
        return None  # fall back to global dispatch
    T_loc = T // S
    cap = max(int((T_loc * k / E) * cfg.capacity_factor) + 1, min(T_loc, 4))
    wsc = jax.lax.with_sharding_constraint
    x3 = wsc(xt.reshape(S, T_loc, d), NamedSharding(mesh, P(daxes, None, None)))
    idx3 = idx.reshape(S, T_loc, k)
    gates3 = gates.reshape(S, T_loc, k)
    buf3, e3, p3 = jax.vmap(moe_dispatch, in_axes=(0, 0, None, None))(
        x3, idx3, cap, E)  # [S, E, C+1, d]
    from repro.parallel.ctx import ctx_option as _opt

    if _opt("no_ep"):
        # replicated experts: everything stays shard-local — zero MoE
        # collectives (right trade for small-expert archs like olmoe,
        # where per-device expert weights fit comfortably)
        buf3 = wsc(buf3, NamedSharding(mesh, P(daxes, None, None, None)))
        h = jnp.einsum("secd,edf->secf", buf3, p["w_gate"])
        u = jnp.einsum("secd,edf->secf", buf3, p["w_up"])
        eo3 = jnp.einsum("secf,efd->secd", jax.nn.silu(h) * u, p["w_down"])
        eo3 = wsc(eo3, NamedSharding(mesh, P(daxes, None, None, None)))
        out3 = jax.vmap(moe_combine)(eo3, gates3, e3, p3)
        return wsc(out3.reshape(T, d), NamedSharding(mesh, P(daxes, None)))
    buf3 = wsc(buf3, NamedSharding(mesh, P(daxes, "model", None, None)))
    C1 = cap + 1
    buf = buf3.transpose(1, 0, 2, 3).reshape(E, S * C1, d)
    buf = wsc(buf, NamedSharding(mesh, P("model", None, None)))  # <- A2A
    h = jnp.einsum("ecd,edf->ecf", buf, p["w_gate"])
    u = jnp.einsum("ecd,edf->ecf", buf, p["w_up"])
    eo = jnp.einsum("ecf,efd->ecd", jax.nn.silu(h) * u, p["w_down"])
    eo3 = eo.reshape(E, S, C1, d).transpose(1, 0, 2, 3)
    eo3 = wsc(eo3, NamedSharding(mesh, P(daxes, "model", None, None)))  # A2A back
    out3 = jax.vmap(moe_combine)(eo3, gates3, e3, p3)  # [S, T_loc, d]
    return wsc(out3.reshape(T, d), NamedSharding(mesh, P(daxes, None)))


def moe_layer(p: Params, cfg: ModelConfig, x: jnp.ndarray,
              shard_experts=None) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: [B, T, d] -> (out [B, T, d], aux_loss). ``shard_experts`` is an
    optional callable applying the EP sharding constraint to [E, C, d]."""
    from repro.parallel.ctx import ctx_option, current_mesh

    B, T, d = x.shape
    xt = x.reshape(B * T, d)
    logits = jnp.einsum("td,de->te", xt.astype(jnp.float32), p["router"])
    gates, idx, aux = router_topk(logits, cfg.top_k)
    out = None
    mesh = current_mesh()
    if ctx_option("moe_local_dispatch") and mesh is not None:
        out = _moe_local_dispatch(p, cfg, xt, gates, idx, mesh)
    if out is None:
        # dropless for tiny token counts (decode), capacity-bounded otherwise
        cap = max(int((B * T * cfg.top_k / cfg.n_experts) * cfg.capacity_factor) + 1,
                  min(B * T, 16))
        buf, e_sel, p_sel = moe_dispatch(xt, idx, cap, cfg.n_experts)
        if shard_experts is not None:
            buf = shard_experts(buf)
        h = jnp.einsum("ecd,edf->ecf", buf, p["w_gate"])
        u = jnp.einsum("ecd,edf->ecf", buf, p["w_up"])
        eo = jnp.einsum("ecf,efd->ecd", jax.nn.silu(h) * u, p["w_down"])
        if shard_experts is not None:
            eo = shard_experts(eo)
        out = moe_combine(eo, gates, e_sel, p_sel)
    out = out.reshape(B, T, d)
    if cfg.moe_dense_ff:
        dm = p["dense_mlp"]
        out = out + swiglu(x, dm["w_gate"], dm["w_up"], dm["w_down"])
    return out, aux
