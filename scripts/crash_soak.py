"""Crash-soak harness for the fault-tolerant sweep path (nightly CI).

Exercises the two resilience guarantees end-to-end through the real CLI
(``scripts/run_sweep.py``), not the library API, so process spawning,
signal handling, and the exit-code contract are all on the hook:

1. **Kill + resume** — launch a checkpointed sweep (``--resume`` with a
   result cache), SIGKILL it mid-run, re-run the identical command, and
   assert the rerun completes with every config present while serving
   the journaled prefix from cache (``cache_hits`` > 0 whenever the
   first run survived long enough to finish at least one job).
2. **Fault soak** — run a sweep to completion under deterministic fault
   injection (crashes, hangs, transient errors, corrupted cache reads
   via ``--faults``) with retries enabled, and assert a full,
   non-partial result (exit 0, no abandoned jobs).

Usage (defaults sized for a ~1-2 minute nightly job)::

    PYTHONPATH=src python scripts/crash_soak.py
    PYTHONPATH=src python scripts/crash_soak.py --kill-after 5 --keep

See docs/resilience.md for the fault-injection matrix and the resume
semantics being soaked here.
"""

from __future__ import annotations

import argparse
import json
import logging
import os
import shutil
import signal
import subprocess
import sys
import tempfile
import time

log = logging.getLogger("crash_soak")

SCRIPTS = os.path.dirname(os.path.abspath(__file__))
ROOT = os.path.dirname(SCRIPTS)


def _sweep_cmd(args: argparse.Namespace, cache_dir: str, json_out: str,
               extra: list) -> list:
    cmd = [sys.executable, os.path.join(SCRIPTS, "run_sweep.py"),
           "--base", "III", "--days", str(args.days),
           "--files", str(args.files),
           "--cache-tb", args.cache_tb, "--seeds", str(args.seeds),
           "--backend", args.backend,
           "--workers", str(args.workers),
           "--cache-dir", cache_dir, "--resume",
           "--json", json_out, "--quiet"]
    if args.backend == "jax":
        cmd += ["--tick", "60", "--lane-chunk", "2"]
    return cmd + extra


def _run(cmd: list, **kw) -> subprocess.CompletedProcess:
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    env.pop("REPRO_FAULTS", None)  # phases control injection explicitly
    return subprocess.run(cmd, env=env, cwd=ROOT, **kw)


def phase_kill_resume(args: argparse.Namespace, tmp: str) -> bool:
    """SIGKILL a checkpointed sweep mid-run, then resume it."""
    cache = os.path.join(tmp, "cache-kill")
    json_out = os.path.join(tmp, "resume.json")
    cmd = _sweep_cmd(args, cache, json_out, [])
    n_expected = len(args.cache_tb.split(",")) * args.seeds

    log.info("[kill+resume] launching: %s", " ".join(cmd))
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    env.pop("REPRO_FAULTS", None)
    # Own session + own log file, and the kill takes out the whole
    # process group: worker processes die with the parent (the scenario
    # being simulated is the machine going away, not a tidy shutdown),
    # and no orphan can sit on an inherited stdout pipe blocking
    # whatever is consuming this script's output.
    with open(os.path.join(tmp, "victim.log"), "w") as victim_log:
        proc = subprocess.Popen(cmd, env=env, cwd=ROOT,
                                stdout=victim_log,
                                stderr=subprocess.STDOUT,
                                start_new_session=True)
        time.sleep(args.kill_after)
    if proc.poll() is None:
        log.info("[kill+resume] SIGKILL (whole process group) after %.1fs",
                 args.kill_after)
        try:
            os.killpg(proc.pid, signal.SIGKILL)
        except ProcessLookupError:
            pass
        proc.wait()
        killed = True
    else:
        log.warning("[kill+resume] run finished in under %.1fs (rc=%d) — "
                    "increase the grid or lower --kill-after for a real "
                    "mid-run kill; resume check degrades to a warm re-run",
                    args.kill_after, proc.returncode)
        killed = proc.returncode != 0

    log.info("[kill+resume] resuming with the identical command ...")
    res = _run(cmd)
    if res.returncode != 0:
        log.error("[kill+resume] FAIL: resume exited %d", res.returncode)
        return False
    with open(json_out) as f:
        doc = json.load(f)
    n_rows = len(doc["rows"])
    hits = doc.get("cache_hits", 0)
    lanes = doc.get("lanes_simulated")
    log.info("[kill+resume] resume: %d/%d configs, cache_hits=%d, "
             "lanes_simulated=%s", n_rows, n_expected, hits, lanes)
    if n_rows != n_expected:
        log.error("[kill+resume] FAIL: %d of %d configs after resume",
                  n_rows, n_expected)
        return False
    if doc.get("failures"):
        log.error("[kill+resume] FAIL: abandoned jobs after resume: %s",
                  doc["failures"])
        return False
    if killed and hits == 0:
        # Not an error by itself (the kill may have landed before the
        # first job finished journaling) but the soak lost its point.
        log.warning("[kill+resume] kill landed before any job was "
                    "journaled (cache_hits=0) — raise --kill-after so "
                    "the resume actually skips work")
    log.info("[kill+resume] OK")
    return True


def phase_fault_soak(args: argparse.Namespace, tmp: str) -> bool:
    """Run to completion under crash/hang/transient/corrupt injection."""
    cache = os.path.join(tmp, "cache-faults")
    json_out = os.path.join(tmp, "faults.json")
    plan = (f"seed={args.fault_seed},crash=0.15,hang=0.1,transient=0.2,"
            f"corrupt=0.2,hang_s=0.5,attempts=1")
    cmd = _sweep_cmd(args, cache, json_out,
                     ["--faults", plan, "--retries", "4",
                      "--job-timeout", "30"])
    n_expected = len(args.cache_tb.split(",")) * args.seeds

    log.info("[fault soak] plan: %s", plan)
    res = _run(cmd)
    if res.returncode != 0:
        log.error("[fault soak] FAIL: exited %d (3 = partial result — a "
                  "job exhausted its retries)", res.returncode)
        return False
    with open(json_out) as f:
        doc = json.load(f)
    n_rows = len(doc["rows"])
    if n_rows != n_expected or doc.get("failures"):
        log.error("[fault soak] FAIL: %d of %d configs, failures=%s",
                  n_rows, n_expected, doc.get("failures"))
        return False
    log.info("[fault soak] OK: %d/%d configs under injection", n_rows,
             n_expected)
    return True


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="Kill/resume and fault-injection soak for run_sweep")
    ap.add_argument("--days", type=float, default=2.0,
                    help="horizon per config (~1s each on the process "
                         "backend); sized so the kill+resume run lasts "
                         "well past --kill-after")
    ap.add_argument("--files", type=int, default=1000)
    ap.add_argument("--cache-tb", default="5,10,20,40,80,160",
                    help="cache-size axis (with --seeds sets grid size)")
    ap.add_argument("--seeds", type=int, default=2)
    ap.add_argument("--backend", default="process",
                    choices=["process", "jax"],
                    help="process journals per config as each finishes "
                         "(finest kill/resume granularity, the default); "
                         "jax journals per lane chunk")
    ap.add_argument("--workers", type=int, default=2)
    ap.add_argument("--kill-after", type=float, default=5.0,
                    help="seconds before the whole-process-group SIGKILL "
                         "in the kill+resume phase (late enough that "
                         "some jobs have journaled, early enough that "
                         "some have not)")
    ap.add_argument("--fault-seed", type=int, default=7)
    ap.add_argument("--keep", action="store_true",
                    help="keep the scratch directory (prints its path)")
    args = ap.parse_args(argv)

    logging.basicConfig(level=logging.INFO,
                        format="%(levelname)s %(name)s: %(message)s")
    tmp = tempfile.mkdtemp(prefix="crash_soak.")
    log.info("scratch: %s", tmp)
    try:
        ok = phase_kill_resume(args, tmp)
        ok = phase_fault_soak(args, tmp) and ok
    finally:
        if args.keep:
            log.info("kept scratch dir: %s", tmp)
        else:
            shutil.rmtree(tmp, ignore_errors=True)
    log.info("crash soak: %s", "PASS" if ok else "FAIL")
    return 0 if ok else 1


if __name__ == "__main__":
    raise SystemExit(main())
