"""Decision-support layer tests: interval math, CI frontier, adaptive
refinement, break-even bisections, and the §5.3 acceptance run.

The numerical machinery (frontier membership, refinement, bisection
convergence) is tested against *synthetic* cost models via the solvers'
``evaluate`` injection point — no simulation, so the properties are exact.
The acceptance test at the bottom drives ``scripts/decide.py`` on the
216-config bench pricing grid for the paper's qualitative claim.
"""

import importlib.util
import json
import math
import os

import pytest

from repro.core.scenarios import (
    ScenarioSpec,
    dynamics_key,
    expand_grid,
    refine_levels,
    strip_seed,
    with_axis,
    with_seeds,
)
from repro.sim.decide import (
    Interval,
    OnPremDisk,
    ci_dominates,
    ci_frontier,
    decide,
    refine_frontier,
    solve_break_even_price,
    solve_displaced_disk,
    summarize,
)
from repro.sim.sweep import ScenarioResult, SweepDriver, SweepResult


# ------------------------------------------------------------ synthetic rig
def synth_result(spec, jobs, cost):
    """A ScenarioResult with just enough metrics for the decision layer."""
    return ScenarioResult(
        spec=spec,
        metrics={"jobs_done": jobs,
                 "Site-1.disk_used_pb": 0.004, "Site-2.disk_used_pb": 0.004},
        storage_usd=cost, network_usd=0.0, ops_usd=0.0,
        wall_s=0.0, events=0)


def make_eval(jobs_fn, cost_fn, log=None):
    """Synthetic ``Evaluate``: jobs/cost are functions of the spec."""
    def evaluate(specs):
        if log is not None:
            log.extend(specs)
        return SweepResult(results=[
            synth_result(s, jobs_fn(s), cost_fn(s)) for s in specs])
    return evaluate


def point(label_seed, jobs_samples, cost_samples, cache=10.0):
    spec = ScenarioSpec(base="III", days=0.1, n_files=100, cache_tb=cache)
    rs = [synth_result(ScenarioSpec(**{**spec.to_dict(), "seed": i}), j, c)
          for i, (j, c) in enumerate(zip(jobs_samples, cost_samples))]
    return summarize(rs)[0]


# ------------------------------------------------------------- intervals
def test_interval_single_sample_degenerates_to_point():
    iv = Interval.from_samples([42.0])
    assert (iv.mean, iv.lo, iv.hi, iv.sd, iv.n) == (42.0, 42.0, 42.0, 0.0, 1)


def test_interval_ci_width_and_overlap():
    iv = Interval.from_samples([10.0, 14.0], z=1.96)
    assert iv.mean == 12.0
    # sd = sqrt(8) ~ 2.828, half = 1.96 * sd / sqrt(2) = 1.96 * 2
    assert iv.hi - iv.lo == pytest.approx(2 * 1.96 * 2.0)
    other = Interval.from_samples([15.0, 16.0])
    assert iv.overlaps(other)
    assert not iv.overlaps(Interval.from_samples([100.0, 101.0]))
    shifted = iv.shifted(5.0)
    assert (shifted.mean, shifted.sd) == (17.0, iv.sd)


def test_summarize_groups_by_seed_and_keeps_order():
    base = ScenarioSpec(base="III", days=0.1, n_files=100, cache_tb=5.0)
    specs = with_seeds([base, with_axis(base, "cache_tb", 9.0)], 3)
    rs = [synth_result(s, 100 + s.seed, 10.0 * s.cache_tb) for s in specs]
    pts = summarize(rs)
    assert [p.spec.cache_tb for p in pts] == [5.0, 9.0]
    assert all(p.n_seeds == 3 for p in pts)
    assert pts[0].jobs.mean == pytest.approx(101.0)
    assert pts[0].spec == strip_seed(specs[0])


# --------------------------------------------------------------- frontier
def test_ci_dominates_requires_interval_separation():
    a = point(0, [100, 102], [10, 11])
    b = point(0, [90, 91], [20, 21], cache=20.0)
    assert ci_dominates(a, b)  # clearly better on both axes
    # overlapping jobs intervals -> no dominance either way
    c = point(0, [99, 103], [30, 31], cache=30.0)
    assert not ci_dominates(a, c) and not ci_dominates(c, a)


def test_ci_dominates_paired_samples_compare_on_means():
    # identical per-seed samples = the same experiment (pricing-deduped
    # lane / saturated plateau): deterministic comparison on cost means
    a = point(0, [100, 110], [10, 20], cache=10.0)
    b = point(0, [100, 110], [10, 20], cache=80.0)
    onprem = OnPremDisk(usd_per_tb_month=15.0)
    assert not ci_dominates(a, b)  # cloud cost ties exactly
    assert ci_dominates(a, b, cost_of=onprem.total_interval)
    assert not ci_dominates(b, a, cost_of=onprem.total_interval)


def test_ci_dominates_paired_pricing_variants_on_one_lane():
    """Price variants billed off one dynamics lane have identical jobs
    samples but different bills; the paired rule must let the per-seed
    strictly cheaper variant dominate even when cost CIs overlap."""
    spec = ScenarioSpec(base="III", days=0.1, n_files=100, cache_tb=10.0)
    cheap_spec = with_axis(spec, "storage_price", 0.018)
    rich_spec = with_axis(spec, "storage_price", 0.034)
    jobs = {0: 500.0, 1: 540.0}
    cheap = summarize([synth_result(
        ScenarioSpec(**{**cheap_spec.to_dict(), "seed": s}), jobs[s], c)
        for s, c in ((0, 100.0), (1, 140.0))])[0]
    rich = summarize([synth_result(
        ScenarioSpec(**{**rich_spec.to_dict(), "seed": s}), jobs[s], c)
        for s, c in ((0, 120.0), (1, 160.0))])[0]
    # wide, overlapping cost CIs — the independent-interval rule would
    # keep both; the paired rule sees strictly cheaper in every seed
    assert ci_dominates(cheap, rich)
    assert not ci_dominates(rich, cheap)
    # mixed per-seed signs -> genuinely ambiguous, no dominance
    mixed = summarize([synth_result(
        ScenarioSpec(**{**rich_spec.to_dict(), "seed": s}), jobs[s], c)
        for s, c in ((0, 90.0), (1, 160.0))])[0]
    assert not ci_dominates(cheap, mixed) and not ci_dominates(mixed, cheap)


def test_ci_frontier_keeps_indistinguishable_points():
    cheap = point(0, [100, 101], [10, 11])
    rich = point(0, [120, 121], [50, 51], cache=20.0)
    noisy = point(0, [80, 140], [30, 31], cache=30.0)  # wide jobs CI
    dominated = point(0, [80, 81], [60, 61], cache=40.0)
    front = ci_frontier([cheap, rich, noisy, dominated])
    labels = [p.spec.cache_tb for p in front]
    assert 40.0 not in labels  # strictly beaten by `rich`
    assert {10.0, 20.0, 30.0} <= set(labels)  # overlap keeps `noisy`
    # cost-ascending: cheap ($10) < noisy ($30) < rich ($50)
    assert labels == [10.0, 30.0, 20.0]


def test_ci_frontier_subset_monotone():
    """frontier(B) ∩ A ⊆ frontier(A) for A ⊆ B — the consistency property
    that guarantees refinement never discards a point a dense grid would
    keep (hypothesis-widened version in test_property.py)."""
    pts = [point(0, [100 + 7 * i, 104 + 6 * i],
                 [10 + 5 * (i % 4), 12 + 5 * (i % 4)], cache=float(i + 1))
           for i in range(8)]
    full = ci_frontier(pts)
    sub = pts[::2]
    sub_front = ci_frontier(sub)
    for p in full:
        if p in sub:
            assert p in sub_front


# ---------------------------------------------------- refinement helpers
def test_refine_levels_bisects_only_out_of_tolerance_gaps():
    mids = refine_levels([10.0, 20.0, 40.0, 80.0], [10.0], rel_tol=0.05)
    assert mids == [15.0]  # only the gap adjacent to the anchor
    mids = refine_levels([10.0, 20.0, 40.0, 80.0], [40.0], rel_tol=0.05)
    assert mids == [30.0, 60.0]
    # a gap within tolerance is left alone
    assert refine_levels([10.0, 10.5, 80.0], [10.0], rel_tol=0.05) == []
    # non-finite levels are never interpolated against
    assert refine_levels([10.0, float("inf")], [10.0], 0.05) == []
    assert refine_levels([10.0], [10.0], 0.05) == []


def test_with_axis_validates_and_dynamics_key_strips_pricing():
    s = ScenarioSpec(base="III", days=0.1, n_files=100, cache_tb=10.0,
                     egress="direct", storage_price=0.02, egress_price=0.03,
                     seed=3)
    assert with_axis(s, "cache_tb", 7.0).cache_tb == 7.0
    with pytest.raises(ValueError):
        with_axis(s, "days", 1.0)  # not a continuous axis
    with pytest.raises(ValueError):
        with_axis(s, "egress_price", -1.0)  # validation reruns
    k = dynamics_key(s)
    assert (k.egress, k.storage_price, k.egress_price) == \
        ("internet", None, None)
    assert k.seed == 3  # seeds are distinct dynamics lanes


# -------------------------------------------------------------- refinement
def _sat_jobs(spec):
    c = spec.cache_tb if spec.cache_tb is not None else 100.0
    return 1000.0 * (1.0 - math.exp(-c / 15.0)) + 2.0 * (spec.seed % 2)


def _sat_cost(spec):
    c = spec.cache_tb if spec.cache_tb is not None else 100.0
    price = spec.egress_price if spec.egress_price is not None else 0.08
    return 20.0 + 2000.0 * price * math.exp(-c / 30.0)


AXES = {"base": "III", "days": 0.1, "n_files": 100,
        "cache_tb": [5.0, 20.0, 40.0, 80.0],
        "egress": ["internet", "direct"]}


def test_refine_frontier_reaches_tolerance_with_fewer_lanes_than_dense():
    res = refine_frontier(AXES, make_eval(_sat_jobs, _sat_cost),
                          ("cache_tb",), n_seeds=2, rel_tol=0.05,
                          max_rounds=6)
    # tolerance reached: every frontier-adjacent gap <= rel_tol * span
    levels = res.axis_levels["cache_tb"]
    span = levels[-1] - levels[0]
    for p in res.frontier:
        v = p.spec.cache_tb
        i = levels.index(v)
        for j in (i - 1, i + 1):
            if 0 <= j < len(levels):
                assert abs(levels[j] - v) <= 0.05 * span + 1e-9
    # adaptive cost well under the equivalent dense grid
    assert res.lanes_used < res.dense_lanes
    assert res.lane_fraction <= 0.5
    assert not res.budget_hit
    # refinement never proposed values outside the coarse span
    assert levels[0] >= 5.0 and levels[-1] <= 80.0


def test_refine_frontier_respects_lane_budget():
    res = refine_frontier(AXES, make_eval(_sat_jobs, _sat_cost),
                          ("cache_tb",), n_seeds=2, rel_tol=0.01,
                          max_rounds=50, lane_budget=20)
    assert res.budget_hit
    assert res.lanes_used <= 20
    # resolved levels reflect only *evaluated* specs — the budget break
    # must not leave proposed-but-never-run midpoints inflating the
    # claimed resolution (and with it dense_lanes / lane_fraction)
    evaluated = {p.spec.cache_tb for p in res.points}
    assert set(res.axis_levels["cache_tb"]) <= evaluated


def test_refine_frontier_never_drops_dense_frontier_point():
    """Deterministic version of the property (hypothesis-widened in
    test_property.py): every point the refinement evaluated that a dense
    grid over the same resolved levels would keep on its frontier is on
    the refined frontier too."""
    evaluate = make_eval(_sat_jobs, _sat_cost)
    res = refine_frontier(AXES, make_eval(_sat_jobs, _sat_cost),
                          ("cache_tb",), n_seeds=2, rel_tol=0.05,
                          max_rounds=4)
    dense_axes = dict(AXES)
    dense_axes["cache_tb"] = res.axis_levels["cache_tb"]
    dense_specs = with_seeds(expand_grid(dense_axes), 2)
    dense_points = summarize(evaluate(dense_specs).results)
    dense_front_specs = {p.spec for p in ci_frontier(dense_points)}
    evaluated = {p.spec for p in res.points}
    refined_front = {p.spec for p in res.frontier}
    for spec in dense_front_specs & evaluated:
        assert spec in refined_front


def test_refine_frontier_rejects_bad_inputs():
    with pytest.raises(ValueError, match="seed"):
        refine_frontier({**AXES, "seed": [0, 1]},
                        make_eval(_sat_jobs, _sat_cost))
    with pytest.raises(ValueError, match="grid levels"):
        refine_frontier({**AXES, "cache_tb": [10.0]},
                        make_eval(_sat_jobs, _sat_cost))
    # a typo'd refine axis must error, not silently skip refinement
    with pytest.raises(ValueError, match="not present in the grid"):
        refine_frontier(AXES, make_eval(_sat_jobs, _sat_cost),
                        ("cache_tbb",))
    with pytest.raises(ValueError, match="axis must be one of"):
        refine_frontier({**AXES, "days": [0.1, 0.2]},
                        make_eval(_sat_jobs, _sat_cost), ("days",))
    # seed replication of zero would silently evaluate nothing and crash
    # deep in summarize; the chokepoint rejects it up front (exit 2 via
    # the CLI's ValueError wrapper)
    with pytest.raises(ValueError, match="n_seeds"):
        refine_frontier(AXES, make_eval(_sat_jobs, _sat_cost),
                        ("cache_tb",), n_seeds=0)
    with pytest.raises(ValueError, match="n_seeds"):
        decide(AXES, make_eval(_sat_jobs, _sat_cost), n_seeds=0)


def test_refine_billing_only_axis_reports_honest_lane_fraction():
    """A dense price grid re-bills the same dynamics lanes, so refining a
    PRICING_FIELDS axis must not inflate the lane-efficiency claim."""
    axes = {"base": "III", "days": 0.1, "n_files": 100, "cache_tb": 10.0,
            "storage_price": [0.018, 0.034]}
    res = refine_frontier(axes, make_eval(_sat_jobs, _sat_cost),
                          ("storage_price",), n_seeds=2, max_rounds=3)
    assert res.lanes_used == 2  # one lane per seed, all prices share it
    assert res.dense_lanes == 2
    assert res.lane_fraction == 1.0


# ------------------------------------------------------ break-even solvers
def baseline_point(jobs=999.0):
    spec = ScenarioSpec(base="I", days=0.1, n_files=100, gcs_limit_tb=0.0)
    return summarize([synth_result(spec, jobs + s, 0.0)
                      for s in range(2)])[0]


def test_displaced_disk_bisection_converges_to_threshold():
    """jobs(c) = 1000·(1−e^(−c/15)) crosses the baseline's CI lower bound
    at an analytically known cache size; the bisection must find it."""
    base = baseline_point(jobs=900.0)
    onprem = OnPremDisk(usd_per_tb_month=15.0)
    cand = ScenarioSpec(base="III", days=0.1, n_files=100, cache_tb=80.0)
    res = solve_displaced_disk(cand, base, make_eval(_sat_jobs, _sat_cost),
                               onprem, n_seeds=2, rel_tol=0.01,
                               max_rounds=32)
    assert res.converged and res.min_cache_tb is not None
    # analytic threshold: smallest c with jobs.hi >= base.jobs.lo
    # jobs.hi(c) = 1000(1-e^(-c/15)) + 1 + CI_half; solve for base.jobs.lo
    target = base.jobs.lo
    ci_half = res.candidate.jobs.hi - res.candidate.jobs.mean
    c_star = -15.0 * math.log(1.0 - (target - 1.0 - ci_half) / 1000.0)
    assert res.min_cache_tb == pytest.approx(c_star, abs=0.02 * 80.0)
    assert res.displaced_tb == (res.baseline_provisioned_tb
                                - res.candidate_provisioned_tb)


def test_displaced_disk_reports_unreachable_baseline():
    base = baseline_point(jobs=5000.0)  # more than the model can ever do
    cand = ScenarioSpec(base="III", days=0.1, n_files=100, cache_tb=80.0)
    res = solve_displaced_disk(cand, base, make_eval(_sat_jobs, _sat_cost),
                               OnPremDisk(), n_seeds=2)
    assert not res.converged and res.min_cache_tb is None
    assert "never matches" in res.note


def test_break_even_price_bisection_converges_to_linear_crossing():
    """cost(p) = 20 + 2000·p·e^(−c/30): the crossing with a fixed baseline
    total is analytic; bisection must land within tolerance."""
    base = baseline_point(jobs=900.0)
    onprem = OnPremDisk(usd_per_tb_month=0.0)  # isolate the cloud bill
    baseline_total = base.cost.mean  # = 0
    cand = ScenarioSpec(base="III", days=0.1, n_files=100, cache_tb=30.0)

    # shift the baseline total via a nonzero synthetic baseline cost
    def base_cost(spec):
        return 0.0 if spec.base == "I" else _sat_cost(spec)
    target_total = 60.0
    base2 = summarize([synth_result(
        ScenarioSpec(base="I", days=0.1, n_files=100, gcs_limit_tb=0.0,
                     seed=s), 900.0, target_total) for s in range(2)])[0]
    res = solve_break_even_price(cand, base2,
                                 make_eval(_sat_jobs, _sat_cost), onprem,
                                 lo=0.0, hi=0.12, n_seeds=2,
                                 rel_tol=0.001, max_rounds=40)
    assert res.converged and res.price is not None
    # 20 + 2000·p·e^(-1) = 60  =>  p = 40·e/2000
    p_star = 40.0 * math.e / 2000.0
    assert res.price == pytest.approx(p_star, abs=0.001 * 0.12 + 1e-6)
    assert baseline_total == 0.0


def test_bisections_report_non_convergence_when_rounds_exhaust():
    base = baseline_point(jobs=900.0)
    cand = ScenarioSpec(base="III", days=0.1, n_files=100, cache_tb=80.0)
    res = solve_displaced_disk(cand, base, make_eval(_sat_jobs, _sat_cost),
                               OnPremDisk(), n_seeds=2, rel_tol=1e-6,
                               max_rounds=4)
    assert res.min_cache_tb is not None and not res.converged
    base2 = summarize([synth_result(
        ScenarioSpec(base="I", days=0.1, n_files=100, gcs_limit_tb=0.0,
                     seed=s), 900.0, 60.0) for s in range(2)])[0]
    # cache 30 brackets the crossing inside [0, 0.12] (cache 80's small
    # e^(-c/30) factor keeps even the max price under the baseline)
    cand30 = ScenarioSpec(base="III", days=0.1, n_files=100, cache_tb=30.0)
    be = solve_break_even_price(cand30, base2,
                                make_eval(_sat_jobs, _sat_cost),
                                OnPremDisk(usd_per_tb_month=0.0),
                                lo=0.0, hi=0.12, n_seeds=2,
                                rel_tol=1e-9, max_rounds=4)
    assert be.price is not None and not be.converged


def test_break_even_price_reports_unbracketed_crossings():
    base = baseline_point(jobs=900.0)  # baseline total = 0
    onprem = OnPremDisk(usd_per_tb_month=0.0)
    cand = ScenarioSpec(base="III", days=0.1, n_files=100, cache_tb=30.0)
    res = solve_break_even_price(cand, base,
                                 make_eval(_sat_jobs, _sat_cost), onprem,
                                 lo=0.0, hi=0.12, n_seeds=2)
    assert res.price is None and "never breaks even" in res.note


# ------------------------------------------------------------ SweepDriver
def test_sweep_driver_memoizes_across_rounds():
    tiny = ScenarioSpec(base="III", days=0.05, n_files=300, cache_tb=5.0)
    specs = with_seeds([tiny], 2)
    driver = SweepDriver(backend="process", workers=1)
    first = driver.run(specs)
    assert driver.configs_run == 2 and driver.sweep_calls == 1
    assert driver.lanes_simulated == 2  # seeds are distinct lanes
    again = driver.run(specs + [specs[0]])
    assert driver.configs_run == 2  # nothing new simulated
    assert driver.sweep_calls == 1
    assert again.results[0].metrics == first.results[0].metrics
    assert again.results[2] is again.results[0]
    # pricing-only variant: new config, same dynamics lane
    priced = with_axis(specs[0], "egress_price", 0.01)
    driver.run([priced])
    assert driver.configs_run == 3
    assert driver.lanes_simulated == 2


# ----------------------------------------------- end-to-end decide() logic
def test_decide_on_synthetic_model_produces_consistent_report():
    log = []
    report = decide(AXES, make_eval(_sat_jobs, _sat_cost, log),
                    n_seeds=2, max_rounds=3,
                    onprem=OnPremDisk(usd_per_tb_month=15.0),
                    breakeven_range=(0.0, 0.12))
    # the default baseline is disk-only configuration I
    assert report.baseline.spec.base == "I"
    assert report.baseline.spec.gcs_limit_tb == 0.0
    assert report.frontier, "frontier must not be empty"
    assert report.displaced.rounds > 0
    md = report.to_markdown()
    assert "Adaptive refinement" in md and "frontier" in md.lower()
    doc = report.to_json_dict()
    assert isinstance(doc["claim_holds"], bool)
    assert doc["refine"]["lanes_used"] == report.refine.lanes_used
    json.dumps(doc)  # must be serializable as-is
    # breakeven probes must not leak into the frontier (their pricing is
    # hypothetical)
    for p in report.frontier:
        assert p.spec.egress_price is None


def test_decide_skips_break_even_when_no_candidate_matches_baseline():
    """When no cloud cache can reach the baseline's jobs-done, pricing a
    shortfall config is meaningless — the report must carry no break-even
    section (the displaced-disk note explains why)."""
    def low_jobs(spec):
        return 100.0 if spec.base != "I" else 5000.0  # candidates can't match

    report = decide(AXES, make_eval(low_jobs, _sat_cost), n_seeds=2,
                    max_rounds=1)
    assert report.displaced.min_cache_tb is None
    assert report.breakeven is None
    assert "never matches" in report.displaced.note
    assert not report.claim_holds()


# --------------------------------------------------- §5.3 acceptance (real)
def _load_decide_cli():
    path = os.path.join(os.path.dirname(__file__), "..", "scripts",
                        "decide.py")
    spec = importlib.util.spec_from_file_location("decide_cli", path)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def test_decide_cli_reproduces_paper_claim_on_bench_pricing_grid(tmp_path):
    """ISSUE 5 acceptance: ``scripts/decide.py`` on the 216-config bench
    pricing grid (4 cache x 3 egress x 9 storage prices x 2 seeds) finds a
    cloud-cache config on the frontier at lower on-prem disk capacity than
    the disk-only baseline at equal jobs-done within CI bounds, and the
    adaptive refinement uses <= 50% of the lanes of an equivalent dense
    grid."""
    cli = _load_decide_cli()
    out = tmp_path / "report.json"
    rc = cli.main(["--days", "0.1", "--files", "1000", "--max-rounds", "2",
                   "--quiet", "--json", str(out)])
    assert rc == 0
    doc = json.loads(out.read_text())
    # the default grid is the bench pricing grid: 216 configs
    n_grid = 4 * 3 * 9 * 2
    assert doc["stats"]["configs_run"] >= n_grid
    # paper's qualitative claim, on interval-overlap membership
    assert doc["claim_holds"] is True
    base = doc["baseline"]
    winners = [p for p in doc["frontier"]
               if p["onprem_tb"] < base["onprem_tb"]
               and p["jobs_hi"] >= base["jobs_lo"]]
    assert winners, "a frontier config must displace on-prem disk"
    # adaptive refinement lane efficiency: <= 50% of the dense equivalent
    assert doc["refine"]["lane_fraction"] <= 0.5, doc["refine"]
    # the displaced-disk headline is positive at this scale
    assert doc["displaced_disk"]["displaced_tb"] > 0


# ----------------------------------------------- degraded runs (ISSUE 9)
def test_decide_degrades_report_when_evaluator_lost_jobs():
    """A resilient evaluator that abandoned jobs (``.failures``) must
    degrade the report: claims refused, losses carried in stats, and the
    markdown saying so out loud (docs/resilience.md)."""
    from repro.sim.jobs import JobFailure

    ev = make_eval(lambda s: 1000.0 + 10.0 * (s.cache_tb or 0.0),
                   lambda s: 50.0 + (s.cache_tb or 0.0))
    ev.failures = [JobFailure(job_id="spec0003", labels=("cfg-x",),
                              kind="crash", attempts=3,
                              errors=["attempt 3 [crash]: worker died"])]
    axes = {"base": "III", "days": 0.1, "n_files": 100,
            "cache_tb": [5.0, 10.0]}
    report = decide(axes, ev, n_seeds=2, max_rounds=1,
                    breakeven_axis=None)
    assert report.degraded
    assert not report.claim_holds()
    assert report.to_json_dict()["degraded"] is True
    (lost,) = report.stats["failures"]
    assert (lost["job_id"], lost["kind"], lost["attempts"]) == \
        ("spec0003", "crash", 3)
    md = report.to_markdown()
    assert "Degraded run" in md and "UNDETERMINED" in md


def test_decide_clean_run_is_not_degraded():
    ev = make_eval(lambda s: 1000.0 + 10.0 * (s.cache_tb or 0.0),
                   lambda s: 50.0 + (s.cache_tb or 0.0))
    axes = {"base": "III", "days": 0.1, "n_files": 100,
            "cache_tb": [5.0, 10.0]}
    report = decide(axes, ev, n_seeds=2, max_rounds=1, breakeven_axis=None)
    assert not report.degraded
    assert "Degraded" not in report.to_markdown()
    assert report.to_json_dict()["degraded"] is False


def test_decide_refuses_when_baseline_evaluation_is_empty():
    """No baseline, no claim: an evaluator whose baseline sweep came
    back empty (every job abandoned) must raise, mentioning the loss."""
    from repro.sim.jobs import JobFailure

    def evaluate(specs):
        return SweepResult(results=[], failures=[
            JobFailure(job_id="spec0000", labels=(), kind="timeout",
                       attempts=3, errors=[])])

    axes = {"base": "III", "days": 0.1, "n_files": 100, "cache_tb": [5.0]}
    with pytest.raises(RuntimeError, match="baseline.*1 job"):
        decide(axes, evaluate, n_seeds=1, max_rounds=1,
               breakeven_axis=None)
