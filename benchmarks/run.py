"""Benchmark runner — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (plus per-row comparison
columns where the paper provides reference values).

  table2   bench_validation   (simulation correctness, 5 metrics)
  table6/7 bench_hcdc         (jobs done, volumes for cfg I/II/III)
  table8   bench_cost         (monthly GCS cost, cfg III)
  hotloop  bench_tick_engine  (transfer-manager tick engines)
  sweep    bench_sweep        (scenario-sweep engine, configs/sec)
  roofline bench_roofline     (dry-run roofline terms per cell)

Env knobs: HCDC_RUNS (default 1), HCDC_DAYS (90), HCDC_FILES (1e6),
VALIDATION_RUNS (2), SWEEP_CONFIGS (8), FAST=1 (reduced scales for CI
smoke).
"""

from __future__ import annotations

import os
import sys
import time


def main() -> None:
    fast = os.environ.get("FAST", "0") == "1"
    t0 = time.time()

    from benchmarks import bench_validation
    runs = int(os.environ.get("VALIDATION_RUNS", "1" if fast else "2"))
    horizon = 2.0 if fast else None
    for r in bench_validation.run(n_runs=runs, horizon_days=horizon):
        print(f"{r['name']},{r['us_per_call']:.0f},{r['derived']:.4g},"
              f"paper={r['paper']:.4g},diff={r['diff_pct']:+.2f}%", flush=True)

    from benchmarks import bench_hcdc
    hruns = int(os.environ.get("HCDC_RUNS", "1"))
    days = int(os.environ.get("HCDC_DAYS", "5" if fast else "90"))
    files = int(os.environ.get("HCDC_FILES",
                               "50000" if fast else "1000000"))
    for r in bench_hcdc.run(n_runs=hruns, days=days, n_files=files):
        ref = (f",paper={r['paper']:.4g},diff={r['diff_pct']:+.2f}%"
               if r.get("paper") else "")
        print(f"{r['name']},{r['us_per_call']:.0f},{r['derived']:.4g}{ref}",
              flush=True)

    from benchmarks import bench_cost
    for r in bench_cost.run(n_runs=hruns, days=days, n_files=files):
        print(f"{r['name']},{r['us_per_call']:.0f},{r['derived']:.4g},"
              f"paper={r['paper']:.4g},diff={r['diff_pct']:+.2f}%", flush=True)

    from benchmarks import bench_tick_engine
    for r in bench_tick_engine.run():
        print(f"{r['name']},{r['us_per_call']:.1f},{r['derived']:.4g}",
              flush=True)

    from benchmarks import bench_sweep
    sweep_cfgs = int(os.environ.get("SWEEP_CONFIGS", "4" if fast else "8"))
    for r in bench_sweep.run(n_configs=sweep_cfgs,
                             days=0.1 if fast else 0.25):
        print(f"{r['name']},{r['us_per_call']:.0f},{r['derived']:.4g}",
              flush=True)

    from benchmarks import bench_roofline
    rows = bench_roofline.run()
    for r in rows:
        extra = ""
        if "dominant" in r:
            extra = (f",dom={r['dominant']},c={r['compute_s']:.3f}s,"
                     f"m={r['memory_s']:.3f}s,coll={r['collective_s']:.3f}s,"
                     f"useful={r['useful']:.3f}")
        d = r["derived"]
        d_str = f"{d:.4f}" if isinstance(d, float) else str(d)
        print(f"{r['name']},{r['us_per_call']:.0f},{d_str}{extra}", flush=True)

    print(f"# total benchmark wall time: {time.time() - t0:.1f}s")


if __name__ == "__main__":
    main()
