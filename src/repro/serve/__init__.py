"""Serving substrate: KV/SSM caches, prefill/decode steps, batch engine."""

from repro.serve.engine import make_prefill_step, make_decode_step

__all__ = ["make_prefill_step", "make_decode_step"]
