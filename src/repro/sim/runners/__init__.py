"""Worker-fleet execution for registry jobs (the throughput half of
distributed sweep execution; ``docs/distributed.md``).

PR 9's resilience layer (``repro.sim.jobs``) made sweep work retryable
but still executed it through an in-process loop or an anonymous
``ProcessPoolExecutor``. This package is the runner/worker split that
drains the same ``JobRegistry`` through a *persistent* fleet:

- ``transport``: the pluggable seam between the dispatcher and one
  worker — a framed-pickle message protocol over a byte stream.
  ``SubprocessTransport`` speaks it to a spawned local worker process;
  ``LocalTransport`` runs the worker logic inline (tests, debugging);
  remote-host transports slot in behind the same five-method interface
  without touching the dispatcher (ROADMAP: remote workers).
- ``worker``: the worker-side main loop (``python -m
  repro.sim.runners.worker``) — receives an init context, builds the
  job runner once (scenario jobs or packed-grid lane chunks), then
  answers job frames with result frames carrying the worker's metrics
  snapshot delta.
- ``fleet``: ``run_fleet_jobs``, the dispatcher — assigns ready
  registry jobs to idle workers, polls for results, reaps deadline
  overruns by killing (and later respawning) the offending worker, and
  attributes a dead pipe to exactly the in-flight job it carried.

The dispatcher preserves every guarantee of the PR 9 executors — retry
with deterministic backoff, wall-clock deadlines, fault-directive
injection, per-job completion journaling — while improving on the pool's
crash story: one job per worker means worker death implicates exactly
one job, so no innocent work is ever requeued. Telemetry flows through
``repro.obs`` as ``workers.*`` (fleet lifecycle) and ``dispatch.*``
(job traffic) series; see ``docs/observability.md``.
"""

from repro.sim.runners.fleet import run_fleet_jobs
from repro.sim.runners.transport import (LocalTransport, SubprocessTransport,
                                         Transport, TransportError,
                                         resolve_transport)

__all__ = [
    "LocalTransport", "SubprocessTransport", "Transport", "TransportError",
    "resolve_transport", "run_fleet_jobs",
]
