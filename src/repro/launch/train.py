"""End-to-end training driver.

Wires together: arch config -> model init -> parallel plan/mesh ->
HCDC tiered data pipeline -> train_step -> checkpoint manager (+ restart)
-> failure detector. On CPU it runs reduced configs (examples/ and smoke
tests); on a real slice, the same driver with ``--mesh production``.

  PYTHONPATH=src python -m repro.launch.train --arch qwen3-4b --steps 20 \
      --reduced --batch 8 --seq 128
"""

from __future__ import annotations

import argparse
import time
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.ckpt.checkpoint import CheckpointManager
from repro.ckpt.failover import FailureDetector
from repro.configs import canonical, get_config, get_smoke_config
from repro.core.hotcold import MigrationPolicy
from repro.data.pipeline import SyntheticCorpus, TokenPipeline
from repro.data.tiered_store import TierSpec, TieredStore
from repro.launch.mesh import make_debug_mesh, make_production_mesh
from repro.models import init_params
from repro.parallel.sharding import ParallelPlan, plan_for
from repro.sim.cloud import GCSCostModel
from repro.train.optimizer import make_optimizer
from repro.train.train_step import make_train_step


def make_store() -> TieredStore:
    """Default HCDC tier topology (Table 4 rates scaled to shard sizes)."""
    return TieredStore(
        archival=TierSpec("tape", None, latency_s=1.0, bandwidth=60e6),
        cold=TierSpec("gcs", 50e9, latency_s=0.05, bandwidth=300e6,
                      cost_model=GCSCostModel()),
        hot=TierSpec("ssd", 2e9, latency_s=0.0, bandwidth=1e9),
        migration=MigrationPolicy(min_popularity=0),
    )


def train(arch: str, steps: int = 20, reduced: bool = True,
          batch: int = 8, seq: int = 128, ckpt_dir: Optional[str] = None,
          resume: bool = False, use_store: bool = True,
          log_every: int = 5) -> Dict[str, Any]:
    cfg = get_smoke_config(arch) if reduced else get_config(arch)
    mesh = make_debug_mesh(1, 1) if reduced else make_production_mesh()
    plan = ParallelPlan(microbatches=1) if reduced else plan_for(cfg, "train_4k", mesh)

    params = init_params(cfg, jax.random.PRNGKey(0))
    opt = make_optimizer(plan.optimizer)
    opt_state = opt.init(params)
    step_fn = jax.jit(make_train_step(cfg, plan, mesh))

    corpus = SyntheticCorpus(cfg.vocab_size, seq, batch, n_shards=4 * steps)
    store = make_store() if use_store else None
    pipeline = TokenPipeline(corpus, store=store, epochs=4)

    ckpt = CheckpointManager(ckpt_dir) if ckpt_dir else None
    start = 0
    if ckpt and resume and ckpt.latest_step() is not None:
        state, start, extra = ckpt.restore({"params": params, "opt": opt_state})
        params, opt_state = state["params"], state["opt"]
        pipeline.restore(extra.get("pipeline", {"position": start}))

    detector = FailureDetector(timeout_s=60.0)
    losses = []
    t0 = time.time()
    with mesh:
        for step in range(start, steps):
            batch_np = next(pipeline)
            batch_dev = {k: jnp.asarray(v) for k, v in batch_np.items()}
            params, opt_state, metrics = step_fn(params, opt_state, batch_dev)
            detector.heartbeat("worker-0", time.time())
            loss = float(metrics["loss"])
            losses.append(loss)
            if step % log_every == 0:
                print(f"step {step:5d} loss {loss:8.4f} "
                      f"gnorm {float(metrics['grad_norm']):8.3f}", flush=True)
            if ckpt and (step + 1) % 10 == 0:
                ckpt.save_async(step + 1, params, opt_state,
                                extra={"pipeline": pipeline.state()})
    if ckpt:
        ckpt.wait()
    out = {
        "losses": losses,
        "wall_s": time.time() - t0,
        "final_loss": losses[-1] if losses else None,
        "store_stats": dict(store.stats) if store else {},
        "data_wait_s": pipeline.prefetcher.total_wait_s if store else 0.0,
    }
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--full", dest="reduced", action="store_false")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", type=str, default=None)
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args()
    out = train(canonical(args.arch), steps=args.steps, reduced=args.reduced,
                batch=args.batch, seq=args.seq, ckpt_dir=args.ckpt_dir,
                resume=args.resume)
    print(f"done: final_loss={out['final_loss']:.4f} wall={out['wall_s']:.1f}s "
          f"store={out['store_stats']}")


if __name__ == "__main__":
    main()
