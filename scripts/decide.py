"""Decision-support CLI (paper §5.3): should you buy the cloud cache?

Drives ``repro.sim.decide`` against a candidate grid: adaptive frontier
refinement, the displaced-disk headline solve, and the break-even price
solve, emitting a markdown/JSON decision report.

The default grid is the benchmark 216-config pricing grid (4 cache sizes
x 3 egress options x 9 storage prices x 2 seeds)::

    PYTHONPATH=src python scripts/decide.py --days 0.25 --files 1000

Smoke-scale demo with a cross-backend check (``make decide-demo``)::

    PYTHONPATH=src python scripts/decide.py --days 0.1 --files 1000 \
        --cache-tb 5,20,80 --storage-price '' --max-rounds 2 --cross-check

When ``$GITHUB_STEP_SUMMARY`` is set (GitHub Actions), the markdown report
is appended to it so the decision table renders on the run's summary page.
"""

from __future__ import annotations

import argparse
import json
import logging
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core.scenarios import EGRESS_OPTIONS, ScenarioSpec
from repro.kernels.registry import TICK_IMPL_CHOICES
from repro.obs.logs import LOG_LEVELS, setup_logging
from repro.obs.metrics import get_registry
from repro.obs.trace import get_tracer
from repro.sim.decide import OnPremDisk, decide
from repro.sim.jobs import RetryPolicy
from repro.sim.sweep import SweepDriver, run_sweep

log = logging.getLogger("decide")

#: The benchmark pricing grid's storage-price axis (USD/GB-month). Must
#: stay in sync with ``benchmarks/bench_sweep.py`` (``_pricing_grid`` /
#: ``_decide_rows``) so the CLI default really is the bench grid.
BENCH_PRICES = ",".join(f"{0.018 + 0.002 * i:.3f}" for i in range(9))


# Same comma-list convention as scripts/run_sweep.py ('base' = keep the
# base configuration's value); duplicated because scripts are standalone.
def _floats(text: str) -> list:
    out = []
    for tok in text.split(","):
        tok = tok.strip().lower()
        if tok:
            out.append(None if tok == "base" else float(tok))
    return out


def _build_axes(args: argparse.Namespace) -> dict:
    axes: dict = {"base": args.base, "days": args.days,
                  "n_files": args.files}
    axes["cache_tb"] = _floats(args.cache_tb)
    if args.gcs_tb:
        axes["gcs_limit_tb"] = _floats(args.gcs_tb)
    if args.egress:
        axes["egress"] = [e.strip() for e in args.egress.split(",")]
    prices = _floats(args.storage_price)
    if prices:
        axes["storage_price"] = prices
    if args.workload:
        axes["workload"] = args.workload
    return axes


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="Cloud-vs-on-prem decision report (adaptive frontier "
                    "refinement + break-even solvers)")
    ap.add_argument("--base", default="III", choices=["I", "II", "III"])
    ap.add_argument("--days", type=float, default=0.25)
    ap.add_argument("--files", type=int, default=1000)
    ap.add_argument("--cache-tb", default="10,20,40,80",
                    help="coarse cache-size axis in TB (refined adaptively)")
    ap.add_argument("--gcs-tb", default="",
                    help="optional cold-tier limit axis in TB")
    ap.add_argument("--egress", default="internet,direct,interconnect",
                    help=f"egress options from {','.join(EGRESS_OPTIONS)}")
    ap.add_argument("--storage-price", default=BENCH_PRICES,
                    help="storage-price axis, USD/GB-month ('' = none)")
    ap.add_argument("--workload", default="",
                    help="access-pattern model applied to grid and baseline")
    ap.add_argument("--seeds", type=int, default=2,
                    help="replica seeds per config; metrics carry mean ± CI")
    ap.add_argument("--first-seed", type=int, default=0)
    ap.add_argument("--refine", action="append", metavar="AXIS",
                    help="continuous axes to refine (default: cache_tb)")
    ap.add_argument("--rel-tol", type=float, default=0.05,
                    help="frontier tolerance: stop when frontier-adjacent "
                         "axis gaps are within this fraction of the span")
    ap.add_argument("--max-rounds", type=int, default=3)
    ap.add_argument("--lane-budget", type=int, default=None,
                    help="stop refining before exceeding this many "
                         "simulated dynamics lanes")
    ap.add_argument("--disk-usd-tb-month", type=float, default=15.0,
                    help="on-prem disk TCO (USD per TB-month)")
    ap.add_argument("--breakeven-axis", default="egress_price",
                    choices=["egress_price", "storage_price", "none"])
    ap.add_argument("--breakeven-lo", type=float, default=0.0)
    ap.add_argument("--breakeven-hi", type=float, default=0.12)
    ap.add_argument("--cache-floor", type=float, default=None,
                    help="lower bound (TB) for the displaced-disk bisection")
    ap.add_argument("--baseline-base", default="I",
                    choices=["I", "II", "III"],
                    help="disk-only baseline configuration (default I)")
    ap.add_argument("--z", type=float, default=1.96,
                    help="CI critical value (default 1.96 = 95%%)")
    ap.add_argument("--cache-dir", default=os.environ.get("REPRO_CACHE_DIR"),
                    metavar="DIR",
                    help="persistent result-cache directory (default: "
                         "$REPRO_CACHE_DIR if set, else no cache). Warm "
                         "re-runs of the same grid simulate zero lanes — "
                         "see docs/simulation.md, 'Result cache'")
    ap.add_argument("--no-cache", action="store_true",
                    help="disable the result cache even if --cache-dir or "
                         "$REPRO_CACHE_DIR is set")
    ap.add_argument("--retries", type=int, default=None, metavar="N",
                    help="fault-tolerant sweeps: retry crashed/timed-out/"
                         "transiently-failing jobs up to N attempts; if a "
                         "job still fails the report is marked degraded "
                         "and the claim is refused (docs/resilience.md)")
    ap.add_argument("--job-timeout", type=float, default=None, metavar="S",
                    help="per-job wall-clock deadline in seconds")
    ap.add_argument("--faults", default=os.environ.get("REPRO_FAULTS"),
                    metavar="PLAN",
                    help="deterministic fault injection for resilience "
                         "testing, e.g. 'seed=7,crash=0.2,transient=0.2' "
                         "(default: $REPRO_FAULTS if set)")
    ap.add_argument("--resume", action="store_true",
                    help="journal each finished job into --cache-dir as it "
                         "completes so a killed invocation re-run with the "
                         "same flags recomputes only unfinished jobs "
                         "(requires --cache-dir; implies --retries 3)")
    ap.add_argument("--backend", default="jax",
                    choices=["jax", "process"])
    ap.add_argument("--tick", type=float, default=60.0,
                    help="jax-backend clock step, seconds (default 60); "
                         "distinct from --tick-impl (kernel choice)")
    ap.add_argument("--tick-impl", default="auto",
                    choices=TICK_IMPL_CHOICES,
                    help="jax-backend kernel implementation (auto = "
                         "compiled Pallas on an accelerator, jnp on CPU; "
                         "see docs/simulation.md, 'Kernel selection')")
    ap.add_argument("--lane-chunk", type=int, default=None)
    ap.add_argument("--workers", type=int, default=None)
    ap.add_argument("--transport", default=None,
                    choices=["subprocess", "local"],
                    help="run sweep jobs on a persistent worker fleet "
                         "(repro.sim.runners): 'subprocess' spawns "
                         "--workers local worker processes, 'local' "
                         "executes inline (docs/distributed.md)")
    ap.add_argument("--shard", action="store_true",
                    help="jax backend: shard_map each lane batch over "
                         "the local device mesh (docs/distributed.md)")
    ap.add_argument("--cross-check", action="store_true",
                    help="re-evaluate the baseline and final frontier on "
                         "the other backend; non-zero exit on disagreement")
    ap.add_argument("--check-tol-jobs", type=float, default=0.10,
                    help="cross-check jobs-done relative tolerance")
    ap.add_argument("--check-tol-cost", type=float, default=0.20,
                    help="cross-check cloud-cost relative tolerance")
    ap.add_argument("--json", dest="json_out", default="",
                    help="write the decision report as JSON")
    ap.add_argument("--report", default="",
                    help="write the markdown report to this path")
    ap.add_argument("--metrics-out", default="", metavar="PATH",
                    help="write the metrics-registry snapshot (Prometheus "
                         "text format, or JSON when PATH ends in .json)")
    ap.add_argument("--trace-out", default="", metavar="PATH",
                    help="enable span tracing and write Chrome trace-event "
                         "JSON (load in Perfetto / chrome://tracing)")
    ap.add_argument("--log-level", default="info", choices=LOG_LEVELS,
                    help="stderr logging verbosity (default info)")
    ap.add_argument("--quiet", action="store_true")
    args = ap.parse_args(argv)

    run_id = setup_logging(args.log_level)
    if args.trace_out:
        get_tracer().enable(run_id)

    try:
        axes = _build_axes(args)
        if not axes["cache_tb"]:
            raise ValueError("--cache-tb needs at least one value")
        baseline = ScenarioSpec(
            base=args.baseline_base, days=args.days, n_files=args.files,
            gcs_limit_tb=0.0,
            workload=args.workload or "steady")
    except ValueError as e:
        log.error("%s", e)
        return 2

    if args.tick_impl != "auto" and args.backend != "jax":
        log.error("--tick-impl requires --backend jax")
        return 2
    if args.shard and args.backend != "jax":
        log.error("--shard requires --backend jax")
        return 2
    cache_dir = None if args.no_cache else args.cache_dir
    if args.resume and not cache_dir:
        log.error("--resume needs a result cache (--cache-dir or "
                  "$REPRO_CACHE_DIR) to journal completed jobs into")
        return 2
    if args.retries is not None and args.retries < 1:
        log.error("--retries must be >= 1")
        return 2
    retry = None
    if args.retries is not None:
        retry = RetryPolicy(max_attempts=args.retries)
    elif args.resume:
        retry = RetryPolicy()  # engage the jobs layer so completions journal
    try:
        driver = SweepDriver(backend=args.backend, tick=args.tick,
                             workers=args.workers, tick_impl=args.tick_impl,
                             lane_chunk=args.lane_chunk, cache=cache_dir,
                             retry=retry, faults=args.faults,
                             job_timeout=args.job_timeout,
                             transport=args.transport, shard=args.shard)
    except ValueError as e:  # malformed --faults plan
        log.error("%s", e)
        return 2
    if args.faults and not args.quiet:
        log.info("fault injection: %s", args.faults)
    if cache_dir and not args.quiet:
        log.info("result cache at %s", cache_dir)
    if not args.quiet:
        n0 = len(axes["cache_tb"]) * len(axes.get("egress", [1])) * \
            max(len(axes.get("storage_price", [1])), 1) * args.seeds
        log.info("coarse grid %d configs, backend=%s, %d seed(s), "
                 "refining %s to rel_tol=%g",
                 n0, args.backend, args.seeds,
                 args.refine or ["cache_tb"], args.rel_tol)

    try:
        report = decide(
            axes, driver,
            baseline=baseline,
            refine=tuple(args.refine) if args.refine else ("cache_tb",),
            n_seeds=args.seeds, first_seed=args.first_seed,
            rel_tol=args.rel_tol, max_rounds=args.max_rounds,
            lane_budget=args.lane_budget,
            onprem=OnPremDisk(usd_per_tb_month=args.disk_usd_tb_month),
            breakeven_axis=(None if args.breakeven_axis == "none"
                            else args.breakeven_axis),
            breakeven_range=(args.breakeven_lo, args.breakeven_hi),
            cache_floor=args.cache_floor,
            z=args.z,
        )
    except ValueError as e:  # bad ranges/axes surface as CLI usage errors
        log.error("%s", e)
        return 2
    # decide() auto-fills the driver accounting (sweep_calls, configs_run,
    # lanes_simulated, cache_hits, sweep_wall_s, cache hit/miss counters);
    # record only the CLI-level context on top.
    if cache_dir:
        report.stats["cache_dir"] = cache_dir

    md = report.to_markdown()
    print(md)
    if args.report:
        if os.path.dirname(args.report):
            os.makedirs(os.path.dirname(args.report), exist_ok=True)
        with open(args.report, "w") as f:
            f.write(md)
        log.info("wrote %s", args.report)
    summary = os.environ.get("GITHUB_STEP_SUMMARY")
    if summary:
        with open(summary, "a") as f:
            f.write(md + "\n")
    if args.json_out:
        if os.path.dirname(args.json_out):
            os.makedirs(os.path.dirname(args.json_out), exist_ok=True)
        with open(args.json_out, "w") as f:
            json.dump(report.to_json_dict(), f, indent=2)
        log.info("wrote %s", args.json_out)
    if args.metrics_out:
        get_registry().dump(args.metrics_out)
        log.info("wrote %s", args.metrics_out)
    if args.trace_out:
        get_tracer().dump(args.trace_out)
        log.info("wrote %s (%d spans)", args.trace_out,
                 len(get_tracer().events))

    if report.degraded:
        n = len(report.stats.get("failures", []))
        log.error("decision report is DEGRADED: %d job(s) abandoned after "
                  "retries — the claim verdict is refused; re-run%s to "
                  "complete the grid (docs/resilience.md)", n,
                  " with --resume" if cache_dir else "")
        return 3

    if args.cross_check:
        other = "process" if args.backend == "jax" else "jax"
        # Check the *decision outputs* — baseline, chosen frontier config,
        # trimmed displaced-disk candidate — not every probe the solvers
        # visited: extreme bisection probes (sub-TB thrashing caches) sit
        # exactly where the fixed-tick and event-driven clocks legitimately
        # diverge, and are not part of the recommendation.
        points = [report.baseline]
        if report.chosen is not None:
            points.append(report.chosen)
        if report.displaced.candidate is not None:
            points.append(report.displaced.candidate)
        specs = list(dict.fromkeys(
            r.spec for p in points for r in p.results))
        if not args.quiet:
            log.info("cross-check: re-running %d configs on backend=%s ...",
                     len(specs), other)
        # The cross-check reads through the same cache (keys are
        # engine-fingerprinted, so the other backend's entries never
        # collide with this run's) — a warm nightly re-check is free.
        ref = run_sweep(specs, backend=other, tick=args.tick,
                        tick_impl=args.tick_impl if other == "jax" else "auto",
                        workers=args.workers, cache=cache_dir)
        mine = driver.run(specs)  # cached — no new simulation
        bad = []
        for a, b in zip(mine.results, ref.results):
            dj = abs(a.jobs_done - b.jobs_done) / max(b.jobs_done, 1.0)
            # absolute floor: a few-dollar bill shifts a lot relatively
            dc = abs(a.cost_usd - b.cost_usd) / max(b.cost_usd, 20.0)
            line = (f"  {a.spec.label:55s} jobs {a.jobs_done:8.0f} vs "
                    f"{b.jobs_done:8.0f} ({dj:+.1%})  cost "
                    f"${a.cost_usd:10,.2f} vs ${b.cost_usd:10,.2f} "
                    f"({dc:+.1%})")
            if dj > args.check_tol_jobs or dc > args.check_tol_cost:
                bad.append(line)
            elif not args.quiet:
                log.info("%s", line)
        if bad:
            log.error("cross-check FAILED (%d/%d configs beyond jobs "
                      "%.0f%% / cost %.0f%%):", len(bad), len(specs),
                      100 * args.check_tol_jobs, 100 * args.check_tol_cost)
            for line in bad:
                log.error("%s", line)
            return 1
        log.info("cross-check OK: %d configs agree within jobs %.0f%% / "
                 "cost %.0f%% on both backends", len(specs),
                 100 * args.check_tol_jobs, 100 * args.check_tol_cost)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
