"""Output module: persistent metric collection (paper §4.1).

The paper stores transfers, downloads/uploads (different format), and time
series to an output store. Here: in-memory collectors with CSV/JSON export,
downsampled time series for the Fig. 6/8 curves, and histograms for the
Fig. 7 waiting-time distributions.
"""

from __future__ import annotations

import csv
import io
import json
import os
import uuid
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np


def atomic_write_text(path: str, text: str) -> None:
    """Write a text file via tmp-file + ``os.replace`` atomic commit.

    Same durability contract as the result cache's ``LocalDirBackend``
    (``repro.sim.cache``): a reader sees either the previous complete
    file or the new complete file, never a truncated prefix, and an
    interrupted writer leaves the original untouched (plus at most a
    ``.tmp.`` orphan). Export paths use this so a crashed or killed run
    never publishes a torn results file.
    """
    if os.path.dirname(path):
        os.makedirs(os.path.dirname(path), exist_ok=True)
    tmp = f"{path}.tmp.{os.getpid()}.{uuid.uuid4().hex[:8]}"
    try:
        with open(tmp, "w", newline="") as f:
            f.write(text)
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, path)
    except OSError:
        try:
            os.remove(tmp)
        except OSError:
            pass
        raise


@dataclass
class TimeSeries:
    """Downsampled (time, value) series — used volume, transfers/hour, ..."""

    name: str
    times: List[int] = field(default_factory=list)
    values: List[float] = field(default_factory=list)

    def record(self, t: int, v: float) -> None:
        self.times.append(t)
        self.values.append(v)

    def to_arrays(self) -> Tuple[np.ndarray, np.ndarray]:
        return np.asarray(self.times), np.asarray(self.values)

    def summary(self) -> Dict[str, float]:
        """Scalar digest (min/mean/max/last) — per-config sweep reporting."""
        if not self.values:
            return {"n": 0.0, "min": 0.0, "mean": 0.0, "max": 0.0, "last": 0.0}
        a = np.asarray(self.values, dtype=np.float64)
        return {
            "n": float(len(a)),
            "min": float(a.min()),
            "mean": float(a.mean()),
            "max": float(a.max()),
            "last": float(a[-1]),
        }


@dataclass
class Histogram:
    name: str
    samples: List[float] = field(default_factory=list)

    def record(self, x: float) -> None:
        self.samples.append(x)

    def counts(self, bins: int = 30):
        return np.histogram(np.asarray(self.samples), bins=bins)

    @property
    def mean(self) -> float:
        return float(np.mean(self.samples)) if self.samples else 0.0


class OutputCollector:
    """Scenario-level metric sink."""

    def __init__(self) -> None:
        self.counters: Dict[str, float] = {}
        self.series: Dict[str, TimeSeries] = {}
        self.hists: Dict[str, Histogram] = {}

    def count(self, name: str, inc: float = 1.0) -> None:
        self.counters[name] = self.counters.get(name, 0.0) + inc

    def ts(self, name: str) -> TimeSeries:
        if name not in self.series:
            self.series[name] = TimeSeries(name)
        return self.series[name]

    def hist(self, name: str) -> Histogram:
        if name not in self.hists:
            self.hists[name] = Histogram(name)
        return self.hists[name]

    def summary(self) -> Dict[str, float]:
        out = dict(self.counters)
        for name, h in self.hists.items():
            out[f"{name}.mean"] = h.mean
            out[f"{name}.n"] = float(len(h.samples))
        return out

    def dump_json(self, path: str) -> None:
        doc = {
            "counters": self.counters,
            "hists": {k: {"mean": h.mean, "n": len(h.samples)} for k, h in self.hists.items()},
            "series": {
                k: {"t": s.times[-1] if s.times else 0, "n": len(s.times)}
                for k, s in self.series.items()
            },
        }
        with open(path, "w") as f:
            json.dump(doc, f, indent=2)


def write_csv(path: str, rows: Sequence[Dict[str, object]],
              fieldnames: Optional[Sequence[str]] = None) -> None:
    """Write dict rows as CSV; columns default to first-seen key order.

    Committed atomically (``atomic_write_text``): an interrupted run
    never leaves a truncated CSV at ``path``.
    """
    if fieldnames is None:
        seen: Dict[str, None] = {}
        for r in rows:
            for k in r:
                seen.setdefault(k)
        fieldnames = list(seen)
    buf = io.StringIO()
    w = csv.DictWriter(buf, fieldnames=list(fieldnames), restval="")
    w.writeheader()
    w.writerows(rows)
    atomic_write_text(path, buf.getvalue())


def mean_and_error(per_run_values: List[float]) -> Tuple[float, float, float]:
    """(mean, std%, standard-error%) across runs — the paper's Table 6/7/8
    presentation."""
    a = np.asarray(per_run_values, dtype=np.float64)
    m = float(a.mean())
    if len(a) < 2 or m == 0.0:
        return m, 0.0, 0.0
    sd = float(a.std(ddof=1))
    se = sd / np.sqrt(len(a))
    return m, 100.0 * sd / m, 100.0 * se / m
