"""Unit tests for ``scripts/check_bench_regression.py``.

The CI gate must distinguish "passed" (0) from "regressed" (1), "baseline
at the wrong scale" (3), and "no baseline" (4) — previously the last two
shared codes with failure and success respectively, so a workflow could
not tell a skipped comparison from a green one.
"""

import importlib.util
import json
import os

import pytest


def _load():
    path = os.path.join(os.path.dirname(__file__), "..", "scripts",
                        "check_bench_regression.py")
    spec = importlib.util.spec_from_file_location("check_bench", path)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


@pytest.fixture(scope="module")
def cli():
    return _load()


def _write(path, fast, rows):
    doc = {"fast": fast,
           "benches": [{"name": n, "us_per_call": 1.0, "derived": d}
                       for n, d in rows]}
    path.write_text(json.dumps(doc))
    return str(path)


def test_exit_ok_within_tolerance(cli, tmp_path):
    base = _write(tmp_path / "base.json", True,
                  [("sweep.jax.warm.216cfg8lane", 100.0)])
    cur = _write(tmp_path / "cur.json", True,
                 [("sweep.jax.warm.216cfg8lane", 80.0)])  # -20% < 30%
    rc = cli.main([base, cur, "--rows", "sweep.jax.warm", "--summary", ""])
    assert rc == cli.EXIT_OK == 0


def test_exit_regression_on_throughput_drop(cli, tmp_path):
    base = _write(tmp_path / "base.json", True, [("sweep.jax.warm", 100.0)])
    cur = _write(tmp_path / "cur.json", True, [("sweep.jax.warm", 50.0)])
    rc = cli.main([base, cur, "--rows", "sweep.jax.warm", "--summary", ""])
    assert rc == cli.EXIT_REGRESSION == 1


def test_exit_scale_mismatch_is_distinct(cli, tmp_path):
    base = _write(tmp_path / "base.json", False, [("sweep.jax.warm", 100.0)])
    cur = _write(tmp_path / "cur.json", True, [("sweep.jax.warm", 100.0)])
    rc = cli.main([base, cur, "--rows", "sweep.jax.warm", "--summary", ""])
    assert rc == cli.EXIT_SCALE_MISMATCH == 3
    # distinct from both success and regression
    assert rc not in (cli.EXIT_OK, cli.EXIT_REGRESSION, cli.EXIT_NO_BASELINE)


def test_exit_no_baseline_is_distinct(cli, tmp_path):
    cur = _write(tmp_path / "cur.json", True, [("sweep.jax.warm", 100.0)])
    rc = cli.main([str(tmp_path / "missing.json"), cur,
                   "--rows", "sweep.jax.warm", "--summary", ""])
    assert rc == cli.EXIT_NO_BASELINE == 4
    assert rc not in (cli.EXIT_OK, cli.EXIT_REGRESSION,
                      cli.EXIT_SCALE_MISMATCH)


def test_exit_no_current_when_results_file_missing(cli, tmp_path):
    base = _write(tmp_path / "base.json", True, [("sweep.jax.warm", 100.0)])
    rc = cli.main([base, str(tmp_path / "never_written.json"),
                   "--rows", "sweep.jax.warm", "--summary", ""])
    assert rc == cli.EXIT_NO_CURRENT == 5
    assert rc not in (cli.EXIT_OK, cli.EXIT_REGRESSION,
                      cli.EXIT_SCALE_MISMATCH, cli.EXIT_NO_BASELINE)


def test_missing_row_is_skipped_not_failed(cli, tmp_path):
    base = _write(tmp_path / "base.json", True, [("sweep.jax.warm", 100.0)])
    cur = _write(tmp_path / "cur.json", True,
                 [("sweep.jax.warm", 99.0), ("sweep.other", 1.0)])
    rc = cli.main([base, cur, "--rows", "sweep.jax.warm", "sweep.gone",
                   "--summary", ""])
    assert rc == cli.EXIT_OK


def test_markdown_summary_written_with_deltas(cli, tmp_path):
    base = _write(tmp_path / "base.json", True, [("sweep.jax.warm", 100.0)])
    cur = _write(tmp_path / "cur.json", True, [("sweep.jax.warm", 50.0)])
    summary = tmp_path / "summary.md"
    rc = cli.main([base, cur, "--rows", "sweep.jax.warm",
                   "--summary", str(summary)])
    assert rc == cli.EXIT_REGRESSION
    text = summary.read_text()
    assert "| `sweep.jax.warm` |" in text
    assert "-50.0%" in text and "REGRESSION" in text


def test_table_only_mode_skips_comparison(cli, tmp_path):
    cur = _write(tmp_path / "cur.json", False, [("sweep.jax.warm", 123.0)])
    summary = tmp_path / "summary.md"
    rc = cli.main(["-", cur, "--rows", "sweep.jax.warm", "sweep.gone",
                   "--summary", str(summary)])
    assert rc == cli.EXIT_OK
    text = summary.read_text()
    assert "123" in text and "missing" in text
