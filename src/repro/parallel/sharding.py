"""Sharding rules: param-tree paths -> PartitionSpec on the production mesh.

Mesh axes (see ``repro.launch.mesh``): optional ``pod`` (cross-pod data
parallel), ``data`` (in-pod data parallel / FSDP / sequence), ``model``
(tensor/expert parallel).

Parallelism modes composed here:
- TP: heads / ffn / vocab / experts / d_inner -> "model".
- DP: batch -> ("pod", "data").
- FSDP (ZeRO-3): the non-TP weight axis additionally -> ("pod", "data")
  for large archs (plan.fsdp), giving per-layer all-gathers under scan.
- ZeRO-1/2: optimizer state and grad-accumulators inherit param shardings
  (+ FSDP axis), so state bytes scale 1/chips.
- SP: long-context decode shards global-layer KV caches over "data"
  (distributed flash-decode: XLA inserts the partial-softmax combine).
- EP: MoE expert dim of the [E, C, d] dispatch buffer -> "model"
  (all-to-all at dispatch/combine).

A rule maps a param-path suffix to axis names per tensor dim; divisibility
is checked against the mesh and falls back to replication per-axis.

The sweep backend reuses this module for its (much simpler) device
layout: ``lane_mesh``/``LANES_AXIS`` build the one-axis ``"lanes"`` mesh
that ``repro.sim.batched`` shard_maps scenario lanes over
(``run_sweep(..., shard=True)``; see ``docs/distributed.md``).
"""

from __future__ import annotations

import dataclasses
import re
from dataclasses import dataclass
from typing import Any, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.config import ModelConfig

DP_AXES = ("pod", "data")  # flattened data-parallel axes (pod may be absent)

#: Mesh axis name of the sweep backend's scenario-lane dimension
#: (``repro.sim.batched``: one lane = one scenario; lanes never interact).
LANES_AXIS = "lanes"


def lane_mesh(n_devices: Optional[int] = None,
              devices: Optional[Any] = None) -> Mesh:
    """One-axis ``"lanes"`` mesh for the batched sweep backend.

    The sweep's lane dimension is embarrassingly parallel (lanes never
    interact), so its mesh is the degenerate one-axis case of the
    model meshes above: ``shard_map`` over ``P("lanes")`` splits the
    lane batch across devices with no collectives in the program.
    ``n_devices`` takes the first N local devices (default: all);
    ``devices`` supplies an explicit device list instead.
    """
    if devices is None:
        devices = jax.local_devices()
        if n_devices is not None:
            if n_devices > len(devices):
                raise ValueError(
                    f"lane_mesh: {n_devices} devices requested but only "
                    f"{len(devices)} local devices are visible")
            devices = devices[:n_devices]
    elif n_devices is not None and n_devices != len(devices):
        raise ValueError("pass n_devices or devices, not both")
    devices = list(devices)
    if not devices:
        raise ValueError("lane_mesh needs at least one device")
    return Mesh(np.array(devices), (LANES_AXIS,))


@dataclass(frozen=True)
class ParallelPlan:
    """Per-(arch x shape) distribution decisions."""

    fsdp: bool = False            # shard weights' non-TP axis over data
    microbatches: int = 1         # grad-accumulation steps in train_step
    seq_shard_cache: bool = False # long-context: shard KV cache seq over data
    shard_activation_seq: bool = False  # Megatron-SP style boundary sharding
    remat_policy: str = "nothing" # "nothing" | "dots" (perf knob)
    optimizer: str = "adamw"      # "adamw" | "adafactor" (fits 100B+ on v5e)
    grad_accum_dtype: str = "f32" # "f32" | "bf16" (perf knob: halves accum traffic)
    attn_chunk_threshold: int = 0 # >0: override chunked-attention threshold
    moe_local_dispatch: bool = False  # shard-local dispatch + explicit A2A
    no_ep: bool = False           # replicate experts (small-expert archs):
                                  # routing stays shard-local, zero A2A


def dp_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in DP_AXES if a in mesh.axis_names)


def dp_size(mesh: Mesh) -> int:
    return int(np.prod([mesh.shape[a] for a in dp_axes(mesh)]))


def tp_size(mesh: Mesh) -> int:
    return int(mesh.shape["model"])


def _fits(dim: int, size: int) -> bool:
    return dim % size == 0 and dim >= size


# --------------------------------------------------------------------- rules
# (path regex, per-dim logical axes). Dims counted from the END of the shape
# so the leading scan/stack [L] dim never matters. Tokens: "tp" (model),
# "fsdp" (data axes when plan.fsdp), None (replicated).
_PARAM_RULES = [
    (r"embed$", ("tp", "fsdp")),              # [V, d] vocab-parallel
    (r"lm_head$", ("fsdp", "tp")),            # [d, V]
    (r"frontend_proj$", (None, "tp")),
    (r"attn/wq$", ("fsdp", "tp", None)),      # [d, nh, hd]
    (r"attn/wk$", ("fsdp", "tp", None)),
    (r"attn/wv$", ("fsdp", "tp", None)),
    (r"attn/wo$", ("tp", None, "fsdp")),      # [nh, hd, d]
    (r"cross/wq$", ("fsdp", "tp", None)),
    (r"cross/wk$", ("fsdp", "tp", None)),
    (r"cross/wv$", ("fsdp", "tp", None)),
    (r"cross/wo$", ("tp", None, "fsdp")),
    (r"(attn|cross)/b[qkv]$", ("tp", None)),
    (r"mlp/w_gate$", ("fsdp", "tp")),         # [d, f]
    (r"mlp/w_up$", ("fsdp", "tp")),
    (r"mlp/w_down$", ("tp", "fsdp")),         # [f, d]
    (r"dense_mlp/w_gate$", ("fsdp", "tp")),
    (r"dense_mlp/w_up$", ("fsdp", "tp")),
    (r"dense_mlp/w_down$", ("tp", "fsdp")),
    (r"moe/router$", ("fsdp", None)),         # [d, E]
    (r"moe/w_gate$", ("ep", "fsdp", "tp_ff")),  # [E, d, f]
    (r"moe/w_up$", ("ep", "fsdp", "tp_ff")),
    (r"moe/w_down$", ("ep", "tp_ff", "fsdp")),  # [E, f, d]
    (r"ssm/in_proj$", ("fsdp", "tp")),        # [d, 2di]
    (r"ssm/conv_w$", (None, "tp")),           # [K, di]
    (r"ssm/conv_b$", ("tp",)),
    (r"ssm/x_proj$", ("tp", None)),           # [di, dtr+2n]
    (r"ssm/dt_proj_w$", (None, "tp")),        # [dtr, di]
    (r"ssm/dt_proj_b$", ("tp",)),
    (r"ssm/A_log$", ("tp", None)),            # [di, N]
    (r"ssm/D$", ("tp",)),
    (r"ssm/out_proj$", ("tp", "fsdp")),       # [di, d]
    (r"norm", (None,)),                        # any norm scale: replicated
]


def _path_str(path) -> str:
    keys = []
    for k in path:
        if hasattr(k, "key"):
            keys.append(str(k.key))
        elif hasattr(k, "idx"):
            keys.append(str(k.idx))
        else:
            keys.append(str(k))
    return "/".join(keys)


def _resolve_axis(token: Optional[str], dim: int, mesh: Mesh,
                  plan: ParallelPlan):
    if token is None:
        return None
    if token == "tp" or token == "ep" or token == "tp_ff":
        # EP shards experts on "model"; tp_ff is the fallback for the expert
        # ffn dims (unused when "ep" applies — only one of them gets "model").
        if plan.no_ep and token in ("ep", "tp_ff"):
            return None  # fully replicated experts (dispatch stays local)
        return "model" if _fits(dim, tp_size(mesh)) else None
    if token == "fsdp":
        if not plan.fsdp:
            return None
        axes = dp_axes(mesh)
        return axes if _fits(dim, dp_size(mesh)) else None
    raise ValueError(token)


def spec_for_param(path_s: str, shape: Tuple[int, ...], mesh: Mesh,
                   plan: ParallelPlan) -> P:
    for pat, tokens in _PARAM_RULES:
        if re.search(pat, path_s):
            ndims = len(shape)
            spec: list = [None] * ndims
            offset = ndims - len(tokens)  # leading [L] stack dims replicated
            if offset < 0:
                return P()
            used = set()
            ep_applied = any(
                t == "ep" and _fits(shape[offset + i], tp_size(mesh))
                for i, t in enumerate(tokens)
            )
            for i, tok in enumerate(tokens):
                if tok == "tp_ff" and ep_applied:
                    continue  # experts already consume the model axis
                if tok == "ep" and not ep_applied:
                    continue
                ax = _resolve_axis(tok, shape[offset + i], mesh, plan)
                if ax is None:
                    continue
                flat = ax if isinstance(ax, tuple) else (ax,)
                if any(a in used for a in flat):
                    continue  # an axis may shard only one dim
                used.update(flat)
                spec[offset + i] = ax
            return P(*spec)
    return P()


def param_shardings(mesh: Mesh, plan: ParallelPlan, params_shape) -> Any:
    """params_shape: pytree of ShapeDtypeStruct (from jax.eval_shape)."""

    def one(path, leaf):
        return NamedSharding(mesh, spec_for_param(_path_str(path), leaf.shape,
                                                  mesh, plan))

    return jax.tree_util.tree_map_with_path(one, params_shape)


# ----------------------------------------------------------------- activations
def batch_spec(mesh: Mesh, batch_size: int) -> P:
    axes = [a for a in dp_axes(mesh)]
    # use the largest prefix of (pod, data) that divides the batch
    while axes and batch_size % int(np.prod([mesh.shape[a] for a in axes])):
        axes.pop()
    return P(tuple(axes)) if axes else P()


def batch_shardings(mesh: Mesh, batch_tree) -> Any:
    def one(leaf):
        return NamedSharding(mesh, batch_spec(mesh, leaf.shape[0]))

    return jax.tree_util.tree_map(one, batch_tree)


def cache_shardings(mesh: Mesh, plan: ParallelPlan, cfg: ModelConfig,
                    cache_tree) -> Any:
    """KV/SSM cache shardings for serving.

    kv k/v: [B, S, nkv, hd] — B over dp if divisible; else (long-context
    batch=1) S over "data" when plan.seq_shard_cache; nkv over "model" when
    divisible. ssm h: [B, di, N] — di over "model". conv: [B, K-1, di]."""

    def one(path, leaf):
        ps = _path_str(path)
        shape = leaf.shape
        if re.search(r"kv/(k|v)$", ps) or re.search(r"cross_kv", ps):
            # [B, S, nkv, hd] or stacked [L, B, S, nkv, hd]
            off = len(shape) - 4
            if off < 0:
                return NamedSharding(mesh, P())
            b, s, nkv = shape[off], shape[off + 1], shape[off + 2]
            spec = [None] * len(shape)
            baxes = batch_spec(mesh, b)
            spec[off] = baxes[0] if len(baxes) else None
            if (spec[off] is None and plan.seq_shard_cache
                    and _fits(s, mesh.shape["data"])):
                spec[off + 1] = "data"  # SP: distributed flash-decode
            if _fits(nkv, tp_size(mesh)):
                spec[off + 2] = "model"
            elif _fits(s, tp_size(mesh)) and spec[off + 1] is None:
                # kv heads don't divide TP (arctic/command-r/mistral kv=8,
                # hymba kv=5): shard the sequence dim over "model" instead —
                # decode attends to a partial KV range per chip and XLA
                # combines the partial softmax (flash-decode style). Applies
                # whether or not the batch dim is also data-sharded.
                spec[off + 1] = "model"
            return NamedSharding(mesh, P(*spec))
        if re.search(r"ssm/h$", ps):
            # [B, di, N] or stacked [L, B, di, N]
            off = len(shape) - 3
            spec = [None] * len(shape)
            baxes = batch_spec(mesh, shape[off])
            spec[off] = baxes[0] if len(baxes) else None
            if _fits(shape[off + 1], tp_size(mesh)):
                spec[off + 1] = "model"
            return NamedSharding(mesh, P(*spec))
        if re.search(r"ssm/conv$", ps):
            # [B, K-1, di] or stacked [L, B, K-1, di]
            off = len(shape) - 3
            spec = [None] * len(shape)
            baxes = batch_spec(mesh, shape[off])
            spec[off] = baxes[0] if len(baxes) else None
            if _fits(shape[off + 2], tp_size(mesh)):
                spec[off + 2] = "model"
            return NamedSharding(mesh, P(*spec))
        return NamedSharding(mesh, P())

    return jax.tree_util.tree_map_with_path(one, cache_tree)


def expert_sharder(mesh: Mesh):
    """Sharding constraint for the MoE [E, C, d] dispatch buffer (EP)."""

    def shard(buf):
        e = buf.shape[0]
        if _fits(e, tp_size(mesh)):
            return jax.lax.with_sharding_constraint(
                buf, NamedSharding(mesh, P("model", None, None)))
        return buf

    return shard


def activation_seq_sharder(mesh: Mesh, plan: ParallelPlan):
    """Megatron-SP style: shard the sequence dim of layer-boundary
    activations over "model" (they are all-gathered inside the block)."""

    if not plan.shard_activation_seq:
        return None

    def shard(x):  # x: [B, T, d]
        if x.ndim == 3 and _fits(x.shape[1], tp_size(mesh)):
            baxes = batch_spec(mesh, x.shape[0])
            b0 = baxes[0] if len(baxes) else None
            return jax.lax.with_sharding_constraint(
                x, NamedSharding(mesh, P(b0, "model", None)))
        return x

    return shard


# --------------------------------------------------------------------- plans
# Parameter-count driven defaults; overridable per arch in launch configs.
def plan_for(cfg: ModelConfig, shape_name: str, mesh: Mesh) -> ParallelPlan:
    """Defaults bake in the confirmed §Perf iterations (EXPERIMENTS.md):
    shard-local MoE dispatch (kills the scatter all-reduce), expert
    replication for small-expert MoE (no_ep), bf16 grad accumulation."""
    params_b = cfg.param_count() * 2  # bf16 bytes
    n_dev = mesh.size
    big = params_b / n_dev > 2e9  # > ~2 GB/device of raw weights under TP-only
    # total expert weight bytes decide EP vs replication (§Perf cell 2)
    expert_b = (cfg.n_layers * cfg.n_experts * 3 * cfg.d_model * cfg.d_ff * 2
                if cfg.family == "moe" else 0)
    is_decode = shape_name in ("decode_32k", "long_500k")
    no_ep = cfg.family == "moe" and expert_b < 30e9
    plan = ParallelPlan(
        # no_ep replicates expert weights -> FSDP-shard them for memory
        fsdp=big or params_b > 60e9 * 2 or no_ep,
        microbatches=1,
        optimizer="adafactor" if params_b > 200e9 * 2 else "adamw",
        grad_accum_dtype="bf16",
        # local dispatch pays off when each dp shard carries enough tokens;
        # decode steps (<= a few tokens/shard) keep the global path
        moe_local_dispatch=cfg.family == "moe" and not is_decode,
        no_ep=no_ep,
    )
    if shape_name == "train_4k":
        gb = 256
        if cfg.family == "moe":
            # confirmed §Perf knee: bigger microbatches give each dp shard
            # enough tokens for efficient dispatch (arctic it4/it5, olmoe it6)
            micro = 4 if no_ep else 8
        else:
            # per-device microbatch of 1 row keeps scan-carry activations small
            micro = max(1, gb // dp_size(mesh))
        plan = dataclasses.replace(plan, microbatches=micro)
    if shape_name == "long_500k":
        plan = dataclasses.replace(plan, seq_shard_cache=True)
    return plan
