"""Benchmark: paper Tables 6 & 7 (+ Fig 6/8 curves) — HCDC configurations.

Runs configurations I/II/III at full scale (90 days, 1e6 files/site) and
prints jobs done, download volume (Table 6) and per-site transfer volumes
(Table 7) against the paper's numbers.
"""

from __future__ import annotations

import argparse
import time
from typing import Dict, List

import numpy as np

from repro.core.hcdc import (
    HCDCScenario,
    PAPER_TABLE6,
    PAPER_TABLE7,
    make_config,
)
from repro.sim.engine import DAY
from repro.sim.output import mean_and_error


def run(n_runs: int = 1, days: int = 90, n_files: int = 1_000_000,
        curves: bool = False) -> List[Dict]:
    rows: List[Dict] = []
    for name in ("I", "II", "III"):
        per: Dict[str, List[float]] = {}
        wall = []
        for seed in range(n_runs):
            cfg = make_config(name, simulated_time=days * DAY,
                              n_files_per_site=n_files, seed=11 + seed,
                              curves=curves)
            t0 = time.time()
            m = HCDCScenario(cfg).run()
            wall.append(time.time() - t0)
            for k, v in m.items():
                per.setdefault(k, []).append(v)
        refs = {**PAPER_TABLE6.get(name, {}), **PAPER_TABLE7.get(name, {})}
        for k in ("jobs_done", "download_pb", "Site-1.tape_to_disk_pb",
                  "Site-2.tape_to_disk_pb", "gcs_to_disk_pb", "gcs_used_pb"):
            if k not in per:
                continue
            mean, sd, se = mean_and_error(per[k])
            ref = refs.get(k)
            rows.append({
                "name": f"cfg{name}.{k}",
                "us_per_call": float(np.mean(wall)) * 1e6,
                "derived": mean,
                "paper": ref,
                "diff_pct": (100.0 * (mean - ref) / ref) if ref else None,
                "sd_pct": sd,
            })
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--runs", type=int, default=1)
    ap.add_argument("--days", type=int, default=90)
    ap.add_argument("--files", type=int, default=1_000_000)
    args = ap.parse_args()
    for r in run(args.runs, args.days, args.files):
        ref = f",paper={r['paper']:.4g},diff={r['diff_pct']:+.2f}%" \
            if r["paper"] else ""
        print(f"{r['name']},{r['us_per_call']:.0f},{r['derived']:.4g}{ref}")


if __name__ == "__main__":
    main()
