"""Sharded, atomic, async-capable checkpointing.

Layout: ``<dir>/step_<N>/`` with one ``.npy`` per flattened param path
(host-local shard in multi-host deployments; full arrays here) plus a
``manifest.json`` (tree structure, dtypes, pipeline state, step). Writes
go to ``step_<N>.tmp`` and rename atomically — a crash mid-save never
corrupts the latest durable step (restart-safe). ``save_async`` hands the
write to a background thread after device_get, overlapping I/O with the
next step's compute (the standard large-scale trick).
"""

from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


def _flatten(tree) -> List[Tuple[str, Any]]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = []
    for path, leaf in flat:
        keys = []
        for k in path:
            keys.append(str(getattr(k, "key", getattr(k, "idx", k))))
        out.append(("/".join(keys), leaf))
    return out


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None

    # ----------------------------------------------------------------- save
    def save(self, step: int, params, opt_state=None,
             extra: Optional[Dict[str, Any]] = None) -> str:
        tmp = os.path.join(self.dir, f"step_{step}.tmp")
        final = os.path.join(self.dir, f"step_{step}")
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        manifest = {"step": step, "arrays": [], "extra": extra or {}}
        state = {"params": params}
        if opt_state is not None:
            state["opt"] = opt_state
        for name, leaf in _flatten(state):
            arr = np.asarray(jax.device_get(leaf))
            fn = name.replace("/", "__") + ".npy"
            np.save(os.path.join(tmp, fn), arr)
            manifest["arrays"].append({"path": name, "file": fn,
                                       "dtype": str(arr.dtype),
                                       "shape": list(arr.shape)})
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        os.replace(tmp, final) if not os.path.exists(final) else shutil.rmtree(tmp)
        self._gc()
        return final

    def save_async(self, step: int, params, opt_state=None,
                   extra: Optional[Dict[str, Any]] = None) -> None:
        # device_get on the caller thread (consistent snapshot), I/O async
        self.wait()
        snap_p = jax.device_get(params)
        snap_o = jax.device_get(opt_state) if opt_state is not None else None
        self._thread = threading.Thread(
            target=self.save, args=(step, snap_p, snap_o, extra), daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self) -> None:
        steps = self.steps()
        for s in steps[: max(0, len(steps) - self.keep)]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s}"))

    # -------------------------------------------------------------- restore
    def steps(self) -> List[int]:
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_") and not name.endswith(".tmp"):
                try:
                    out.append(int(name.split("_")[1]))
                except ValueError:
                    pass
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.steps()
        return steps[-1] if steps else None

    def restore(self, template, step: Optional[int] = None):
        """template: pytree of like-shaped arrays (e.g. from init or
        eval_shape); returns (state, step, extra)."""
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError("no checkpoint found")
        d = os.path.join(self.dir, f"step_{step}")
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        by_name = {a["path"]: a for a in manifest["arrays"]}
        flat_t = jax.tree_util.tree_flatten_with_path(template)[0]
        leaves = []
        for path, leaf in flat_t:
            keys = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                            for k in path)
            rec = by_name[keys]
            arr = np.load(os.path.join(d, rec["file"]))
            if arr.dtype.kind == "V":
                # custom dtypes (bfloat16, fp8) round-trip as raw void
                # bytes; view back using the manifest's dtype name
                import ml_dtypes

                arr = arr.view(np.dtype(getattr(ml_dtypes, rec["dtype"])))
            leaves.append(jnp.asarray(arr) if hasattr(leaf, "dtype") else arr)
        tree = jax.tree_util.tree_unflatten(
            jax.tree_util.tree_structure(template), leaves)
        return tree, step, manifest.get("extra", {})
