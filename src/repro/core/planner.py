"""Decision tool (paper §6): simulate before you buy.

The paper proposes using the simulation to balance variable parameters
(GCS limit, disk limit) against cost and job throughput. ``sweep``
runs the HCDC scenario across a grid of limits and returns the
(jobs done, disk used, cloud cost) frontier; ``recommend`` picks the
cheapest configuration that achieves a target job-throughput fraction of
the unlimited-disk baseline (configuration I).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional

from repro.core.hcdc import HCDCScenario, make_config
from repro.sim.engine import DAY
from repro.sim.infrastructure import TB


@dataclass
class SweepPoint:
    disk_limit_tb: float
    gcs_limited: bool
    jobs_done: float
    download_pb: float
    disk_used_pb: float
    gcs_used_pb: float
    cloud_cost_usd: float

    @property
    def cost_per_job(self) -> float:
        return self.cloud_cost_usd / max(self.jobs_done, 1.0)


def run_point(disk_limit_tb: Optional[float], use_gcs: bool,
              days: int = 30, n_files: int = 200_000, seed: int = 0) -> SweepPoint:
    cfg = make_config("III" if use_gcs else ("I" if disk_limit_tb is None else "II"),
                      simulated_time=days * DAY, n_files_per_site=n_files,
                      seed=seed)
    if disk_limit_tb is not None:
        for s in cfg.sites:
            s.disk_limit = disk_limit_tb * TB
    m = HCDCScenario(cfg).run()
    cost = sum(v for k, v in m.items()
               if k.endswith("storage_usd") or k.endswith("network_usd"))
    return SweepPoint(
        disk_limit_tb=disk_limit_tb if disk_limit_tb is not None else float("inf"),
        gcs_limited=not use_gcs,
        jobs_done=m["jobs_done"],
        download_pb=m["download_pb"],
        disk_used_pb=m["Site-1.disk_used_pb"] + m["Site-2.disk_used_pb"],
        gcs_used_pb=m["gcs_used_pb"],
        cloud_cost_usd=cost,
    )


def sweep(disk_limits_tb: List[float], days: int = 30,
          n_files: int = 200_000, seed: int = 0) -> List[SweepPoint]:
    points = [run_point(None, False, days, n_files, seed)]  # baseline (cfg I)
    for lim in disk_limits_tb:
        points.append(run_point(lim, True, days, n_files, seed))
    return points


def recommend(points: List[SweepPoint],
              min_throughput_frac: float = 0.98) -> SweepPoint:
    base = points[0].jobs_done
    feasible = [p for p in points[1:]
                if p.jobs_done >= min_throughput_frac * base]
    if not feasible:
        return points[0]
    return min(feasible, key=lambda p: (p.disk_used_pb, p.cloud_cost_usd))
