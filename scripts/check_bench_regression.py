"""Diff a fresh benchmark JSON against the committed perf baseline.

Compares the ``derived`` column (throughput: higher is better) of selected
rows by name prefix and fails when any regresses by more than the allowed
fraction. Row names embed grid sizes (``sweep.jax.warm.216cfg8lane``), so
matching is by prefix; a prefix present in only one file is reported and
skipped (grid shapes legitimately change across PRs).

Baselines are only comparable at the same scale: if the two files disagree
on the ``fast`` flag (smoke vs full benchmark scale), the check exits with
``EXIT_SCALE_MISMATCH`` and an actionable message — a mis-scaled committed
baseline would otherwise permanently self-disable the gate. Regenerate the
committed baseline with ``make bench-baseline`` (FAST scale, matching CI's
bench-smoke job).

Exit codes (CI distinguishes "skipped" from "passed"/"failed"):

- 0 ``EXIT_OK``              — all compared rows within tolerance
- 1 ``EXIT_REGRESSION``      — at least one row regressed
- 3 ``EXIT_SCALE_MISMATCH``  — baseline/current ``fast`` flags differ
- 4 ``EXIT_NO_BASELINE``     — baseline file absent/unreadable
- 5 ``EXIT_NO_CURRENT``      — fresh results file absent/unreadable

When ``--summary PATH`` is given (or ``$GITHUB_STEP_SUMMARY`` is set, as
on GitHub Actions), a markdown table of the compared rows, their deltas,
and pass/fail is appended there, so the perf trajectory is readable on the
workflow run page without downloading artifacts. ``--baseline -`` skips
the comparison entirely and just tabulates the current file (the nightly
full-scale run, which has no committed full-scale baseline).

Usage (the CI bench-smoke job and ``make bench-smoke`` run this)::

    python scripts/check_bench_regression.py BENCH_4.json BENCH_ci.json \
        [--rows sweep.jax.warm sweep.jax.lanes_per_sec] [--max-regression 0.3]
"""

from __future__ import annotations

import argparse
import json
import os
import sys
from typing import List, Optional

EXIT_OK = 0
EXIT_REGRESSION = 1
EXIT_SCALE_MISMATCH = 3
EXIT_NO_BASELINE = 4
EXIT_NO_CURRENT = 5  # the fresh results file itself is absent/unreadable

#: Rows that gate CI (prefix match). Throughput of the batched backend is
#: the perf trajectory this repo tracks (ISSUE 4 acceptance); the decide
#: rows track the decision layer's lane efficiency (ISSUE 5).
#:
#: Never add ``tick.pallas.*`` rows here: on this CPU container those are
#: interpret-mode artifacts (Pallas traced through XLA — a plumbing and
#: parity path, ISSUE 7), so their "throughput" measures interpreter
#: overhead, not kernel speed. Gating bench-smoke on one would fail PRs
#: over noise in a number nobody optimizes. The nightly table-only run
#: may still *report* them (``--baseline -``).
DEFAULT_ROWS = ("sweep.jax.warm", "sweep.jax.lanes_per_sec")


def _find(doc: dict, prefix: str):
    rows = [b for b in doc.get("benches", [])
            if b["name"] == prefix or b["name"].startswith(prefix + ".")]
    return rows[0] if rows else None


def _write_summary(path: str, lines: List[str]) -> None:
    with open(path, "a") as f:
        f.write("\n".join(lines) + "\n")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="Fail on benchmark throughput regression vs baseline")
    ap.add_argument("baseline",
                    help="committed baseline JSON (BENCH_4.json); '-' "
                         "tabulates the current file without comparing")
    ap.add_argument("current", help="freshly produced JSON (BENCH_ci.json)")
    ap.add_argument("--rows", nargs="+", default=list(DEFAULT_ROWS),
                    help="row-name prefixes to compare (derived column)")
    ap.add_argument("--max-regression", type=float, default=0.30,
                    help="allowed fractional drop in derived throughput "
                         "(default 0.30)")
    ap.add_argument("--summary", default=os.environ.get(
                        "GITHUB_STEP_SUMMARY", ""),
                    help="append a markdown result table to this file "
                         "(default: $GITHUB_STEP_SUMMARY when set)")
    args = ap.parse_args(argv)

    try:
        with open(args.current) as f:
            cur = json.load(f)
    except OSError as e:
        # e.g. the bench run crashed before writing its JSON; a clean code
        # (not a traceback's generic 1 == EXIT_REGRESSION) keeps the CI
        # outcome classification honest
        print(f"bench-diff: no current results ({e})", file=sys.stderr)
        return EXIT_NO_CURRENT

    md: List[str] = ["### Benchmark regression check", ""]

    if args.baseline == "-":
        md += ["_No baseline comparison (table-only mode)._", "",
               "| row | derived |", "|---|---|"]
        for prefix in args.rows:
            c = _find(cur, prefix)
            val = f"{float(c['derived']):.4g}" if c else "missing"
            name = c["name"] if c else prefix
            print(f"bench-diff: {name}: {val}")
            md.append(f"| `{name}` | {val} |")
        if args.summary:
            _write_summary(args.summary, md)
        return EXIT_OK

    base: Optional[dict] = None
    try:
        with open(args.baseline) as f:
            base = json.load(f)
    except OSError as e:
        print(f"bench-diff: no baseline ({e}); skipping", file=sys.stderr)
        if args.summary:
            _write_summary(args.summary, md + [
                f"_Skipped: baseline `{args.baseline}` missing._"])
        return EXIT_NO_BASELINE

    if base.get("fast") != cur.get("fast"):
        print(f"bench-diff: scale mismatch (baseline fast={base.get('fast')}"
              f", current fast={cur.get('fast')}) — the committed baseline "
              "must match the comparison scale; regenerate it with "
              "`make bench-baseline`", file=sys.stderr)
        if args.summary:
            _write_summary(args.summary, md + [
                f"_Scale mismatch: baseline fast={base.get('fast')}, "
                f"current fast={cur.get('fast')} — regenerate with "
                "`make bench-baseline`._"])
        return EXIT_SCALE_MISMATCH

    md += [f"Tolerance: {args.max_regression:.0%} drop in `derived` "
           "(throughput).", "",
           "| row | baseline | current | delta | status |",
           "|---|---|---|---|---|"]
    failures = []
    for prefix in args.rows:
        b, c = _find(base, prefix), _find(cur, prefix)
        if b is None or c is None:
            where = "baseline" if b is None else "current"
            print(f"bench-diff: {prefix}: missing in {where}; skipped")
            md.append(f"| `{prefix}` | — | — | — | skipped "
                      f"(missing in {where}) |")
            continue
        old, new = float(b["derived"]), float(c["derived"])
        if old <= 0:
            print(f"bench-diff: {prefix}: non-positive baseline {old}; "
                  "skipped")
            md.append(f"| `{prefix}` | {old:.4g} | {new:.4g} | — | skipped "
                      "(non-positive baseline) |")
            continue
        change = (new - old) / old
        status = "OK"
        if change < -args.max_regression:
            status = "REGRESSION"
            failures.append(prefix)
        print(f"bench-diff: {prefix}: {old:.4g} -> {new:.4g} "
              f"({change:+.1%}) {status}")
        icon = "✅" if status == "OK" else "❌"
        md.append(f"| `{b['name']}` | {old:.4g} | {new:.4g} | "
                  f"{change:+.1%} | {icon} {status} |")
    if args.summary:
        _write_summary(args.summary, md)
    if failures:
        print(f"bench-diff: FAILED rows: {', '.join(failures)} "
              f"(allowed drop {args.max_regression:.0%})", file=sys.stderr)
        return EXIT_REGRESSION
    return EXIT_OK


if __name__ == "__main__":
    raise SystemExit(main())
