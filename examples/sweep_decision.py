"""Worked §5.3 decision example: is commercial cloud cache worth buying?

Uses the decision-support layer (``repro.sim.decide``) end-to-end instead
of eyeballing a fixed grid: a disk-only baseline is compared against a
coarse cloud-cache grid that is adaptively refined around its
cost/throughput frontier (seed replicas give every number a ± CI), the
cheapest matching configuration's cache is trimmed to the smallest size
that still holds the baseline's throughput (the displaced on-prem disk is
the paper's headline quantity), and a bisection on the flat egress-price
axis finds where the cloud option breaks even with buying disk.

    PYTHONPATH=src python examples/sweep_decision.py

The same workflow at CLI scale: ``scripts/decide.py``; methodology:
``docs/decision.md``.
"""

import sys

sys.path.insert(0, "src")

from repro.sim.decide import OnPremDisk, decide
from repro.sim.sweep import SweepDriver

DAYS, FILES, SEEDS = 0.25, 2000, 2


def main() -> None:
    # Candidate grid: configuration III (100 TB cache + GCS cold tier in
    # the paper; cache size swept here) across the §5.3 egress pricing
    # alternatives. The coarse cache axis is deliberately sparse — the
    # refinement fills in the frontier region on its own.
    axes = {
        "base": "III", "days": DAYS, "n_files": FILES,
        "cache_tb": [5.0, 20.0, 100.0],
        "egress": ["internet", "direct", "interconnect"],
    }
    driver = SweepDriver(backend="jax", tick=30.0)
    onprem = OnPremDisk(usd_per_tb_month=15.0)

    print(f"deciding over {3 * 3 * SEEDS}-config coarse grid "
          f"({DAYS:g} days, {FILES} files/site, {SEEDS} seeds) ...")
    report = decide(axes, driver, n_seeds=SEEDS, onprem=onprem,
                    rel_tol=0.05, max_rounds=3)
    report.stats.update(
        sweep_calls=driver.sweep_calls,
        configs_run=driver.configs_run,
        lanes_simulated=driver.lanes_simulated,
        sweep_wall_s=round(driver.wall_s, 2),
    )
    print()
    print(report.to_markdown())

    d = report.displaced
    if d.min_cache_tb is not None:
        print(f"decision: buy a {d.min_cache_tb:g} TB/site hot cache with "
              f"'{d.candidate.spec.egress}' egress — "
              f"${d.cloud_budget_usd:,.2f} of cloud spend displaces "
              f"{d.displaced_tb:,.1f} TB of on-prem disk at the baseline's "
              "throughput (within CI).")
    else:
        print("decision: stay on-prem at this scale; no cloud candidate "
              "matches the baseline's throughput.")


# The guard stays: the cross-backend path spawns worker processes that
# re-import this module, and an unguarded run would recurse into the pool
# bootstrap.
if __name__ == "__main__":
    main()
