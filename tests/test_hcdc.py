"""HCDC scenario behaviour tests (reduced scale; paper §5)."""

import pytest

from repro.core.hcdc import HCDCScenario, make_config
from repro.sim.engine import DAY

DAYS = 3
FILES = 20_000


@pytest.fixture(scope="module")
def runs():
    out = {}
    for name in ("I", "II", "III"):
        cfg = make_config(name, simulated_time=DAYS * DAY,
                          n_files_per_site=FILES, seed=5)
        sc = HCDCScenario(cfg)
        out[name] = (sc, sc.run())
    return out


def test_job_throughput_ordering(runs):
    """cfg II (limited disk, no cloud) finishes fewer jobs; cfg III
    recovers cfg I's throughput (the paper's headline claim)."""
    jI, jII, jIII = (runs[k][1]["jobs_done"] for k in ("I", "II", "III"))
    assert jII < jI
    assert jIII >= 0.97 * jI


def test_disk_limit_never_exceeded(runs):
    for name in ("II", "III"):
        sc, _ = runs[name]
        for st in sc.sites:
            assert st.disk.limit is not None
            assert st.disk.used <= st.disk.limit + 1


def test_gcs_only_used_in_cfg_iii(runs):
    assert runs["I"][1]["gcs_used_pb"] == 0
    assert runs["II"][1]["gcs_used_pb"] == 0
    assert runs["III"][1]["gcs_used_pb"] > 0
    assert runs["III"][1]["gcs_to_disk_pb"] >= 0


def test_volume_conservation(runs):
    """Downloads equal the summed sizes of finished jobs' inputs; every
    replica on GCS was migrated exactly once (no deletion in cfg III)."""
    for name in ("I", "II", "III"):
        sc, m = runs[name]
        assert m["download_pb"] > 0
        # GCS volume == migrated bytes (paper: nothing deleted at GCS);
        # the small residue is migrations still in flight at sim end.
        assert abs(m["gcs_used_pb"] - m["disk_to_gcs_pb"]) <= \
            0.01 * m["gcs_used_pb"] + 1e-12


def test_cfg_i_disk_grows_monotonically(runs):
    sc, m = runs["I"]
    # unlimited disk, nothing deleted: used == everything ever transferred
    for st in sc.sites:
        assert st.disk.used >= 0.99 * (st.tape_disk_bytes)


def test_tape_only_source_in_cfg_ii(runs):
    _, m = runs["II"]
    assert m["gcs_to_disk_pb"] == 0


def test_consumers_never_negative(runs):
    for name in ("I", "II", "III"):
        sc, _ = runs[name]
        for st in sc.sites:
            assert int(st.consumers.min()) >= 0


def test_link_active_bounded(runs):
    for name in ("I", "II", "III"):
        sc, _ = runs[name]
        for st in sc.sites:
            for link in (st.l_tape_disk, st.l_gcs_disk, st.l_disk_gcs):
                if link is not None and link.max_active:
                    assert link.active <= link.max_active


def test_monthly_bills_emitted_for_cfg_iii():
    cfg = make_config("III", simulated_time=35 * DAY,
                      n_files_per_site=5_000, seed=2)
    sc = HCDCScenario(cfg)
    sc.run()
    assert len(sc.gcs.bills) == 2  # one full 30-day month + partial
    assert sc.gcs.bills[0].storage_usd >= 0
    assert sc.gcs.bills[0].network_usd >= 0


def test_migration_policy_threshold():
    """Popularity-threshold migration (beyond-paper §2.2 variation)."""
    from repro.core.hotcold import MigrationPolicy

    cfg = make_config("III", simulated_time=2 * DAY,
                      n_files_per_site=5_000, seed=2)
    cfg.migration_policy = MigrationPolicy(min_popularity=50)  # migrate none
    sc = HCDCScenario(cfg)
    m = sc.run()
    assert m["gcs_used_pb"] == 0.0


def test_running_jobs_counter_and_series():
    """The per-site ``running`` counter (jobs between data-ready and
    completion, ISSUE 8) stays non-negative, shows up as an hourly
    ``{site}.running_jobs`` series with ``curves=True``, and — being
    RNG-free bookkeeping — leaves every simulation observable
    bit-identical to a curves-off run."""
    kw = dict(simulated_time=DAY // 2, n_files_per_site=2000, seed=3)
    cfg = make_config("III", **kw)
    cfg.curves = True
    sc = HCDCScenario(cfg)
    metrics = sc.run()
    for st in sc.sites:
        ts = sc.out.series[f"{st.spec.name}.running_jobs"]
        assert len(ts.values) > 0
        assert min(ts.values) >= 0.0
        assert max(ts.values) > 0.0
        assert st.running >= 0
    plain = make_config("III", **kw)
    assert HCDCScenario(plain).run() == metrics
