"""Equivalence of attention implementation paths (plain / chunked /
window-sliced) and the trip-count HLO cost parser."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.models.attention as A
from repro.models.config import ModelConfig


def _cfg(**kw):
    base = dict(name="t", family="dense", n_layers=1, d_model=64, n_heads=4,
                n_kv_heads=2, d_ff=128, vocab_size=128, remat=False)
    base.update(kw)
    return ModelConfig(**base)


def _qkv(cfg, B, T, key):
    p = A.init_attention(key, cfg)
    x = jax.random.normal(key, (B, T, cfg.d_model), dtype=jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32), (B, T))
    return p, x, pos


@pytest.mark.parametrize("window", [None, 16])
def test_chunked_equals_plain(window, monkeypatch):
    cfg = _cfg(dtype=jnp.float32)
    key = jax.random.PRNGKey(0)
    B, T = 2, 128
    p, x, pos = _qkv(cfg, B, T, key)
    w = None if window is None else jnp.int32(window)
    plain = A.attention(p, cfg, x, pos, window=w)
    monkeypatch.setattr(A, "CHUNKED_ATTN_THRESHOLD", 64)
    monkeypatch.setattr(A, "ATTN_CHUNK", 32)
    chunked = A.attention(p, cfg, x, pos, window=w)
    np.testing.assert_allclose(np.asarray(plain), np.asarray(chunked),
                               atol=2e-5, rtol=2e-5)


def test_window_slice_equals_masked(monkeypatch):
    """Static-int window (KV band slicing) == traced-window masking."""
    cfg = _cfg(dtype=jnp.float32)
    key = jax.random.PRNGKey(1)
    B, T, W = 1, 256, 32
    p, x, pos = _qkv(cfg, B, T, key)
    monkeypatch.setattr(A, "CHUNKED_ATTN_THRESHOLD", 64)
    monkeypatch.setattr(A, "ATTN_CHUNK", 64)
    sliced = A.attention(p, cfg, x, pos, window=W)            # static int
    masked = A.attention(p, cfg, x, pos, window=jnp.int32(W))  # traced
    np.testing.assert_allclose(np.asarray(sliced), np.asarray(masked),
                               atol=2e-5, rtol=2e-5)


def test_hlo_cost_parser_trip_counts():
    """The while-loop trip multiplication on a real compiled scan."""
    from repro.roofline.hlo_cost import analyze_hlo

    L, M, K = 7, 16, 32

    def f(ws, x):
        def body(x, w):
            return jnp.tanh(x @ w), None

        x, _ = jax.lax.scan(body, x, ws)
        return x

    ws = jnp.zeros((L, K, K), jnp.float32)
    x = jnp.zeros((M, K), jnp.float32)
    txt = jax.jit(f).lower(ws, x).compile().as_text()
    res = analyze_hlo(txt)
    # dot flops = 2*M*K*K per layer, x L trips
    expected = 2 * M * K * K * L
    assert res["flops"] == pytest.approx(expected, rel=0.01), res["flops"]


def test_hlo_cost_parser_collective_factors():
    from repro.roofline.hlo_cost import HloCostModel

    hlo = """HloModule m, entry_computation_layout={()->f32[128]{0}}

ENTRY %main (p: f32[128]) -> f32[128] {
  %p = f32[128]{0} parameter(0)
  ROOT %ar = f32[128]{0} all-reduce(%p), replica_groups=[4,4]<=[16], to_apply=%add
}

%add (a: f32[], b: f32[]) -> f32[] {
  %a = f32[] parameter(0)
  %b = f32[] parameter(1)
  ROOT %s = f32[] add(%a, %b)
}
"""
    res = HloCostModel(hlo).entry_cost()
    # all-reduce of 512 bytes over groups of 4: 2 * 3/4 * 512 = 768
    assert res["collective_wire_bytes"] == pytest.approx(768.0)
