"""Pallas TPU kernel: chunked Mamba selective scan.

Recurrence: h_t = dA_t * h_{t-1} + dBu_t;  y_t = sum_n C_{t,n} h_{t,n}.

TPU adaptation (vs. the CUDA kernel of the paper's SSM lineage): the scan
is chunked along time; the grid is (batch, d_inner blocks, time chunks)
with time innermost. TPU grids execute sequentially, so the carry h lives
in a VMEM scratch ref that persists across time-chunk grid steps (reset at
chunk 0). Within a chunk the recurrence runs as an unrolled fori_loop over
[D_BLOCK, N] VREG tiles — d_inner is the vector axis (128 lanes), the
tiny state dim N rides along in sublanes.

Emitting y (not h) keeps HBM traffic at O(T x d_inner) instead of
O(T x d_inner x N) — the key memory win over materializing the scanned
state like the jnp associative-scan reference does.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

D_BLOCK = 128   # d_inner lanes per grid step
T_CHUNK = 256   # time steps per grid step


def _scan_kernel(dA_ref, dBu_ref, C_ref, y_ref, h_scratch):
    tc = pl.program_id(2)

    @pl.when(tc == 0)
    def _init():
        h_scratch[...] = jnp.zeros_like(h_scratch)

    h = h_scratch[...]  # [D_BLOCK, N] f32

    def step(t, carry):
        h, = carry
        dA = dA_ref[0, t]        # [D_BLOCK, N]
        dBu = dBu_ref[0, t]      # [D_BLOCK, N]
        c = C_ref[0, t]          # [N]
        h = dA * h + dBu
        y = jnp.sum(h * c[None, :], axis=-1)  # [D_BLOCK]
        y_ref[0, t] = y
        return (h,)

    (h,) = jax.lax.fori_loop(0, dA_ref.shape[1], step, (h,))
    h_scratch[...] = h


def mamba_scan_pallas(dA, dBu, C, *, interpret: bool = True):
    """dA, dBu: [B, T, D, N] f32; C: [B, T, N] f32 -> y [B, T, D] f32.

    T must be a multiple of T_CHUNK and D of D_BLOCK (ops wrapper pads).
    """
    B, T, D, N = dA.shape
    assert T % T_CHUNK == 0 and D % D_BLOCK == 0
    grid = (B, D // D_BLOCK, T // T_CHUNK)

    return pl.pallas_call(
        _scan_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, T_CHUNK, D_BLOCK, N),
                         lambda b, d, t: (b, t, d, 0)),
            pl.BlockSpec((1, T_CHUNK, D_BLOCK, N),
                         lambda b, d, t: (b, t, d, 0)),
            pl.BlockSpec((1, T_CHUNK, N), lambda b, d, t: (b, t, 0)),
        ],
        out_specs=pl.BlockSpec((1, T_CHUNK, D_BLOCK),
                               lambda b, d, t: (b, t, d)),
        out_shape=jax.ShapeDtypeStruct((B, T, D), jnp.float32),
        # persistent carry across time chunks for this (b, d) lane block:
        # TPU grids run sequentially with time innermost, so the scratch
        # survives from chunk t to t+1 of the same (b, d) block.
        scratch_shapes=[pltpu.VMEM((D_BLOCK, N), jnp.float32)],
        interpret=interpret,
    )(dA, dBu, C)
