"""Fleet worker: the subprocess side of the runner/worker split.

Run as ``python -m repro.sim.runners.worker`` with frames on
stdin/stdout (``repro.sim.runners.transport``). Protocol, in order:

1. ``{"op": "init", "ctx": {...}}`` — the shared job context, sent
   once. ``ctx["kind"]`` picks the runner: ``"scenario"`` executes
   ``ScenarioSpec`` payloads through ``repro.sim.sweep.run_scenario``;
   ``"lanes"`` executes packed-grid lane-chunk payloads through one
   compiled program built from the context's static shapes
   (``repro.sim.batched.lane_chunk_runner``) — the big shared tick-grid
   arrays ship once here, never per job.
2. ``{"op": "ready", "startup_s": ...}`` back — import + runner-build
   time, observed into the ``workers.startup_s`` histogram.
3. Job frames ``{"op": "job", "job_id", "payload", "directive"}``,
   each answered by a result frame ``{"op": "result", "job_id", "ok",
   "result" | ("kind", "error"), "metrics"}``. ``metrics`` is this
   worker's registry snapshot delta (snapshot-then-reset), merged by
   the dispatcher so a fleet sweep's telemetry matches a serial run's.
4. ``{"op": "stop"}`` (or stdin EOF) ends the loop.

Fault directives (``repro.sim.faults``) are acted out with real worker
semantics, mirroring the pool path's ``perform_in_worker``: ``crash``
is ``os._exit`` (the dispatcher sees the pipe close mid-job and charges
exactly this job), ``hang`` sleeps through the dispatcher's deadline,
``transient`` fails the attempt retryably via the result frame.

stdout discipline: the frame channel is stdout, so the worker re-points
file descriptor 1 at stderr before touching any library — a stray
``print`` (or a chatty import) degrades to a log line instead of
corrupting the stream.
"""

from __future__ import annotations

import os
import sys
import time
from typing import Any, Callable, Dict

from repro.obs.metrics import get_registry, snapshot_and_reset
from repro.sim.faults import TransientFault, perform_in_worker
from repro.sim.runners.transport import recv_frame, send_frame


class ProtocolError(RuntimeError):
    """Protocol violation inside the worker (kills it; the dispatcher
    sees EOF and charges the in-flight job)."""


def build_runner(ctx: Dict[str, Any]) -> Callable[[Any], Any]:
    """Build the payload runner for one init context (shared with
    ``LocalTransport``, which runs it inline in the dispatcher)."""
    kind = ctx.get("kind", "scenario")
    if kind == "scenario":
        from repro.sim.sweep import run_scenario

        return lambda payload: run_scenario(payload)
    if kind == "lanes":
        from repro.sim.batched import lane_chunk_runner

        return lane_chunk_runner(ctx)
    raise ValueError(f"unknown worker context kind {kind!r}")


def attempt(runner: Callable[[Any], Any], msg: Dict[str, Any],
            snapshot: bool = True) -> Dict[str, Any]:
    """Run one job message to its result frame.

    ``crash``/``hang`` directives must be acted out by the caller (they
    are about the *worker*, not the attempt); ``transient`` raises here
    and folds into a retryable not-ok frame, and any other exception
    becomes a non-retryable ``"error"`` frame — the same kind split
    ``repro.sim.jobs`` applies. ``snapshot=False`` skips the metrics
    round trip for in-process execution, where the work already landed
    in the caller's registry.
    """
    job_id = msg.get("job_id")
    frame: Dict[str, Any] = {"op": "result", "job_id": job_id}
    try:
        directive = msg.get("directive")
        if directive is not None and directive["kind"] == "transient":
            raise TransientFault("injected transient fault")
        result = runner(msg["payload"])
    except TransientFault as e:
        frame.update(ok=False, kind="transient", error=str(e))
    except Exception as e:
        frame.update(ok=False, kind="error",
                     error=f"{type(e).__name__}: {e}")
    else:
        frame.update(ok=True, result=result)
    frame["metrics"] = snapshot_and_reset() if snapshot else None
    return frame


def main() -> int:
    # Claim the frame channel before anything can print: keep the real
    # stdout privately, then alias fd 1 to stderr for the rest of the
    # process (imports, user code, jax logging).
    out = os.fdopen(os.dup(1), "wb")
    os.dup2(2, 1)
    sys.stdout = sys.stderr
    inp = sys.stdin.buffer
    os.environ.setdefault("JAX_PLATFORMS", "cpu")
    t0 = time.monotonic()
    init = recv_frame(inp)
    if init.get("op") != "init":
        raise ProtocolError(f"expected init frame, got {init!r}")
    runner = build_runner(init["ctx"])
    get_registry().reset()  # startup noise is not job work
    send_frame(out, {"op": "ready", "startup_s": time.monotonic() - t0})
    while True:
        try:
            msg = recv_frame(inp)
        except EOFError:
            return 0
        op = msg.get("op")
        if op == "stop":
            return 0
        if op != "job":
            raise ProtocolError(f"unexpected frame {op!r}")
        directive = msg.get("directive")
        if directive is not None and directive["kind"] in ("crash", "hang"):
            perform_in_worker(directive)  # crash exits 23; hang sleeps
        send_frame(out, attempt(runner, msg))


if __name__ == "__main__":
    sys.exit(main())
