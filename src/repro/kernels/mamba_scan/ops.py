"""Jitted wrapper for the Mamba chunked scan (padding + dispatch)."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.mamba_scan.mamba_scan import (
    D_BLOCK,
    T_CHUNK,
    mamba_scan_pallas,
)
from repro.kernels.mamba_scan.ref import mamba_scan_ref


@functools.partial(jax.jit, static_argnames=("use_pallas", "interpret"))
def mamba_scan(dA, dBu, C, *, use_pallas: bool = True,
               interpret: bool = True):
    """dA, dBu: [B, T, D, N] f32; C: [B, T, N] f32 -> y [B, T, D] f32."""
    if not use_pallas:
        return mamba_scan_ref(dA, dBu, C)
    B, T, D, N = dA.shape
    pt = (-T) % T_CHUNK
    pd = (-D) % D_BLOCK
    if pt or pd:
        # dA pads with 1.0 (identity decay) so the carry stays valid.
        dA = jnp.pad(dA, ((0, 0), (0, pt), (0, pd), (0, 0)),
                     constant_values=1.0)
        dBu = jnp.pad(dBu, ((0, 0), (0, pt), (0, pd), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pt), (0, 0)))
    y = mamba_scan_pallas(dA, dBu, C)
    return y[:, :T, :D]
