"""Workload-subsystem tests: schedule math, spec-parse validation, and
event-engine physics of the non-stationary access patterns."""

import numpy as np
import pytest

from repro.core.scenarios import ScenarioSpec, build_config
from repro.sim.sweep import run_scenario
from repro.sim.workload import (
    Campaign,
    Diurnal,
    SteadyPoisson,
    ZipfDrift,
    parse_workload,
)

TINY = dict(days=0.1, n_files=500)


# ---------------------------------------------------------------- schedules
def test_steady_schedule_is_exact_identity():
    """The steady default must be a bitwise no-op on the count stream —
    the regression-identity guarantee for pre-workload results."""
    sched = SteadyPoisson().compile(1000, 10.0)
    assert (sched.rate_mult == 1.0).all()
    assert sched.sel_power is None
    counts = np.maximum(np.random.default_rng(0).normal(0.63, 0.37, 1000), 0)
    assert ((counts * sched.rate_mult) == counts).all()  # bitwise


def test_diurnal_schedule_mean_preserving_and_bounded():
    # 1 h period on a 10 s grid: one full period every 360 ticks
    sched = Diurnal(amplitude=1.0, period_h=1.0).compile(3600, 10.0)
    assert sched.rate_mult.min() >= 0.0
    assert sched.rate_mult.max() <= 2.0
    assert sched.rate_mult[:3600 // 10 * 10].mean() == pytest.approx(1.0, abs=1e-9)
    # phase shifts the wave
    shifted = Diurnal(amplitude=1.0, period_h=1.0, phase_h=0.25).compile(360, 10.0)
    assert not np.allclose(shifted.rate_mult, sched.rate_mult[:360])


def test_campaign_schedule_duty_cycle():
    sched = Campaign(period_h=1.0, duty=0.25, peak=3.0, off=0.5).compile(720, 10.0)
    assert set(np.unique(sched.rate_mult)) == {0.5, 3.0}
    assert (sched.rate_mult == 3.0).mean() == pytest.approx(0.25)
    # peak phase leads each period
    assert (sched.rate_mult[:90] == 3.0).all()
    assert (sched.rate_mult[90:360] == 0.5).all()


def test_zipf_drift_schedule_steps_between_powers():
    sched = ZipfDrift(power_start=3.5, power_end=1.5, steps=5).compile(500, 10.0)
    assert (sched.rate_mult == 1.0).all()  # rate untouched
    powers = np.unique(sched.sel_power)
    assert len(powers) == 5
    assert sched.sel_power[0] == pytest.approx(3.5)
    assert sched.sel_power[-1] == pytest.approx(1.5)
    assert (np.diff(sched.sel_power) <= 0).all()  # monotone drift


def test_zipf_drift_reaches_power_end_on_short_horizons():
    """steps clamps to the tick count, so the drift always lands on
    power_end even when segments would be shorter than a tick."""
    sched = ZipfDrift(power_start=3.0, power_end=1.0, steps=8).compile(5, 10.0)
    assert sched.sel_power[0] == pytest.approx(3.0)
    assert sched.sel_power[-1] == pytest.approx(1.0)


def test_trace_schedule_step_function_and_hold(tmp_path):
    p = tmp_path / "trace.csv"
    p.write_text("time_s,rate_mult\n0,1.0\n100,2.0\n250,0.5\n")
    sched = parse_workload(f"trace:{p}").compile(40, 10.0)
    assert (sched.rate_mult[:10] == 1.0).all()
    assert (sched.rate_mult[10:25] == 2.0).all()
    assert (sched.rate_mult[25:] == 0.5).all()  # last value held
    # a trace starting after t=0 backfills with its first value
    q = tmp_path / "late.csv"
    q.write_text("time_s,rate_mult\n50,4.0\n")
    late = parse_workload(f"trace:{q}").compile(10, 10.0)
    assert (late.rate_mult == 4.0).all()


# --------------------------------------------------- parse-time validation
def test_parse_workload_rejects_unknown_names_and_params():
    with pytest.raises(ValueError, match="unknown workload 'poison'"):
        parse_workload("poison")
    with pytest.raises(ValueError, match="unknown parameter"):
        parse_workload("diurnal:amp=0.5")
    with pytest.raises(ValueError, match="is not a number"):
        parse_workload("diurnal:amplitude=big")
    with pytest.raises(ValueError, match="key=value"):
        parse_workload("campaign:duty")


def test_parse_workload_rejects_out_of_range_params():
    with pytest.raises(ValueError, match="amplitude"):
        parse_workload("diurnal:amplitude=1.5")
    with pytest.raises(ValueError, match="duty"):
        parse_workload("campaign:duty=0")
    with pytest.raises(ValueError, match="steps"):
        parse_workload("zipf-drift:steps=0")
    with pytest.raises(ValueError, match="steps"):
        parse_workload("zipf-drift:steps=1")  # can't drift in one segment
    with pytest.raises(ValueError, match="powers must be > 0"):
        parse_workload("zipf-drift:power_end=-1")


def test_parse_workload_trace_errors_are_actionable(tmp_path):
    with pytest.raises(ValueError, match="needs a CSV path"):
        parse_workload("trace")
    with pytest.raises(ValueError, match="not found"):
        parse_workload("trace:/no/such/file.csv")
    bad_header = tmp_path / "h.csv"
    bad_header.write_text("tick,mult\n0,1\n")
    with pytest.raises(ValueError, match="header"):
        parse_workload(f"trace:{bad_header}")
    not_numeric = tmp_path / "n.csv"
    not_numeric.write_text("time_s,rate_mult\n0,fast\n")
    with pytest.raises(ValueError, match="not numeric"):
        parse_workload(f"trace:{not_numeric}")
    unsorted = tmp_path / "u.csv"
    unsorted.write_text("time_s,rate_mult\n100,1\n50,2\n")
    with pytest.raises(ValueError, match="does not increase"):
        parse_workload(f"trace:{unsorted}")
    negative = tmp_path / "neg.csv"
    negative.write_text("time_s,rate_mult\n0,-1\n")
    with pytest.raises(ValueError, match="negative rate_mult"):
        parse_workload(f"trace:{negative}")
    empty = tmp_path / "e.csv"
    empty.write_text("time_s,rate_mult\n")
    with pytest.raises(ValueError, match="no data rows"):
        parse_workload(f"trace:{empty}")


def test_trace_reparsed_when_file_changes(tmp_path):
    """Editing a trace CSV must be picked up (and re-validated) by the
    next parse — trace models bypass the parse_workload cache."""
    p = tmp_path / "t.csv"
    p.write_text("time_s,rate_mult\n0,1.0\n")
    assert parse_workload(f"trace:{p}").compile(5, 10.0).rate_mult[0] == 1.0
    # different length, so the (path, mtime, size) cache key always moves
    # even on filesystems with coarse mtime granularity
    p.write_text("time_s,rate_mult\n0,3.25\n")
    assert parse_workload(f"trace:{p}").compile(5, 10.0).rate_mult[0] == 3.25
    p.write_text("time_s,rate_mult\nnope\n")
    with pytest.raises(ValueError, match="malformed"):
        parse_workload(f"trace:{p}")


def test_scenario_spec_validates_workload_at_parse_time():
    """The sweep fails up front on a bad workload — never in a worker."""
    with pytest.raises(ValueError, match="unknown workload"):
        ScenarioSpec(workload="flashmob", **TINY)
    with pytest.raises(ValueError, match="amplitude"):
        ScenarioSpec(workload="diurnal:amplitude=7", **TINY)
    with pytest.raises(ValueError, match="not found"):
        ScenarioSpec(workload="trace:/missing.csv", **TINY)


def test_spec_label_and_config_carry_workload():
    spec = ScenarioSpec(workload="diurnal:amplitude=0.8", **TINY)
    assert "wl=diurnal:amplitude=0.8" in spec.label
    assert "wl=" not in ScenarioSpec(**TINY).label  # steady stays implicit
    cfg = build_config(spec)
    assert cfg.workload == Diurnal(amplitude=0.8)
    assert spec.to_dict()["workload"] == "diurnal:amplitude=0.8"


# -------------------------------------------------- event-engine physics
def test_campaign_duty_cycle_scales_submissions():
    """peak=1/off=0 at duty=0.5 halves the arrival stream."""
    steady = run_scenario(ScenarioSpec(base="I", **TINY))
    half = run_scenario(ScenarioSpec(
        base="I", workload="campaign:period_h=0.5,duty=0.5,peak=1,off=0",
        **TINY))
    ratio = half.metrics["jobs_submitted"] / steady.metrics["jobs_submitted"]
    assert 0.4 < ratio < 0.6


def test_diurnal_preserves_long_run_rate():
    """Full-period sinusoid: same total submissions within a few %."""
    steady = run_scenario(ScenarioSpec(base="I", **TINY))
    # horizon 0.1 d = 2.4 h -> integer number of 0.6 h periods
    diurnal = run_scenario(ScenarioSpec(
        base="I", workload="diurnal:amplitude=1,period_h=0.6", **TINY))
    ratio = (diurnal.metrics["jobs_submitted"]
             / steady.metrics["jobs_submitted"])
    assert 0.93 < ratio < 1.07


def test_zipf_drift_widens_unique_file_footprint():
    """Flattening popularity over time touches more unique files, so more
    cold (tape) traffic at the same arrival rate."""
    spec = ScenarioSpec(base="II", cache_tb=15.0, **TINY)
    steady = run_scenario(spec)
    drift = run_scenario(ScenarioSpec(
        base="II", cache_tb=15.0,
        workload="zipf-drift:power_start=3.5,power_end=1,steps=4", **TINY))
    assert (drift.metrics["jobs_submitted"]
            == steady.metrics["jobs_submitted"])  # rate untouched
    tape = [sum(r.metrics[k] for k in r.metrics
                if k.endswith(".tape_to_disk_pb")) for r in (drift, steady)]
    assert tape[0] > tape[1]


def test_trace_replay_doubles_rate(tmp_path):
    p = tmp_path / "x2.csv"
    p.write_text("time_s,rate_mult\n0,2.0\n")
    steady = run_scenario(ScenarioSpec(base="I", **TINY))
    doubled = run_scenario(ScenarioSpec(base="I", workload=f"trace:{p}",
                                        **TINY))
    ratio = (doubled.metrics["jobs_submitted"]
             / steady.metrics["jobs_submitted"])
    assert 1.9 < ratio < 2.1
