"""Batched scenario-sweep engine (paper §5.3: the decision workflow).

The paper's stated purpose for the simulation is "to assist with the
decision process of using commercial cloud storage": compare many scenario
variants — hot-cache sizes, egress pricing/peering options, job arrival
rates, seeds — on a cost vs. throughput frontier. This module turns the
single-run ``HCDCScenario`` into that instrument:

- ``run_scenario(spec)``: one ``ScenarioSpec`` -> ``ScenarioResult``
  (metrics, monthly-bill breakdown, time-series digests, run stats). Specs
  are built via ``repro.core.scenarios`` and executed on the analytic
  ``EventDrivenTransferService`` fast path, so a reduced-scale config runs
  in seconds.
- ``run_sweep(specs)``: executes a batch with process-level parallelism
  (simulations are pure Python and CPU-bound, so threads would serialize on
  the GIL). Results are deterministic per spec — a parallel sweep is
  bit-identical to running each config serially with the same seed.
- ``SweepResult``: ordered results + CSV/JSON export + Pareto-front
  extraction (minimize cloud cost, maximize jobs done) + seed aggregation
  in the paper's Table 6/7/8 mean/sd% presentation.
"""

from __future__ import annotations

import json
import os
import time
from dataclasses import dataclass, field, replace
from typing import TYPE_CHECKING, Any, Callable, Dict, List, Optional, Sequence

from repro.obs.metrics import get_registry, snapshot_and_reset
from repro.obs.trace import get_tracer
from repro.sim.cloud import sum_bills
from repro.sim.output import atomic_write_text, mean_and_error, write_csv

if TYPE_CHECKING:  # repro.core imports repro.sim; keep runtime acyclic
    from repro.core.scenarios import ScenarioSpec


@dataclass
class ScenarioResult:
    """Outcome of one simulated configuration (picklable)."""

    spec: ScenarioSpec
    metrics: Dict[str, float]
    storage_usd: float
    network_usd: float
    ops_usd: float
    wall_s: float
    events: int
    series: Dict[str, Dict[str, float]] = field(default_factory=dict)
    #: Raw per-month billing inputs: ``{"gb_seconds": [...], "egress_bytes":
    #: [...], "class_a": [...], "class_b": [...], "full_months": int}``.
    #: Pricing-independent — feeding them through
    #: ``repro.sim.cloud.bills_from_monthly_totals`` under any cost model
    #: re-bills the run bit-exactly, which is how the persistent result
    #: cache (``repro.sim.cache``) serves pricing variants of one stored
    #: dynamics lane. Empty for synthetic results that never simulated.
    monthly: Dict[str, Any] = field(default_factory=dict)

    @property
    def cost_usd(self) -> float:
        return self.storage_usd + self.network_usd + self.ops_usd

    @property
    def jobs_done(self) -> float:
        return self.metrics["jobs_done"]

    @property
    def jobs_per_day(self) -> float:
        return self.jobs_done / self.spec.days

    def row(self) -> Dict[str, Any]:
        """Flat record for CSV/JSON export."""
        m = self.metrics
        r: Dict[str, Any] = {"label": self.spec.label}
        r.update(self.spec.to_dict())
        del r["curves"]
        r.update(
            jobs_done=m["jobs_done"],
            jobs_per_day=self.jobs_per_day,
            job_waiting_h_mean=m["job_waiting_h_mean"],
            download_pb=m["download_pb"],
            tape_to_disk_pb=sum(v for k, v in m.items()
                                if k.endswith(".tape_to_disk_pb")),
            gcs_to_disk_pb=m["gcs_to_disk_pb"],
            disk_to_gcs_pb=m["disk_to_gcs_pb"],
            gcs_used_pb=m["gcs_used_pb"],
            storage_usd=self.storage_usd,
            network_usd=self.network_usd,
            ops_usd=self.ops_usd,
            cost_usd=self.cost_usd,
            cost_per_kjob=1e3 * self.cost_usd / max(m["jobs_done"], 1.0),
            wall_s=self.wall_s,
            events=self.events,
        )
        return r


def _worker_init() -> None:
    """Initializer for spawned sweep workers.

    Pin JAX (should any import chain pull it in) to CPU before the worker
    touches a task: an accelerator-probing child process can hang on
    device initialization while the parent holds the device — the same
    failure class as the moe multi-device subprocess hang. An inherited
    JAX_PLATFORMS (e.g. the parent exported ``tpu``) is deliberately
    overridden: workers only ever need numpy, so CPU is always right.
    """
    os.environ["JAX_PLATFORMS"] = "cpu"
    # Fresh baseline for the worker's process-global metrics registry so
    # the per-task snapshot deltas it returns contain only its own work.
    get_registry().reset()


def run_scenario(spec: ScenarioSpec) -> ScenarioResult:
    """Build and run one configuration; the sweep's unit of work.

    Top-level (not a closure) so ``ProcessPoolExecutor`` can pickle it; all
    randomness is derived from ``spec.seed``, so the result is independent
    of which process runs it.
    """
    # Deferred imports: repro.core depends on repro.sim, so importing it at
    # module scope would make ``repro.sim`` circular.
    from repro.core.hcdc import HCDCScenario
    from repro.core.scenarios import build_config

    cfg = build_config(spec)
    t0 = time.perf_counter()
    with get_tracer().span("run_scenario", label=spec.label):
        scenario = HCDCScenario(cfg)
        metrics = scenario.run()
    wall = time.perf_counter() - t0
    reg = get_registry()
    reg.inc("scenario.runs", help="Event-engine scenario executions")
    reg.observe("scenario.wall_s", wall,
                help="Per-scenario event-engine wall time (s)")
    bill = sum_bills(scenario.gcs.bills)
    series = {name: ts.summary() for name, ts in scenario.out.series.items()}
    raw = scenario.gcs.monthly_raw
    monthly = {
        "gb_seconds": [float(r[0]) for r in raw],
        "egress_bytes": [float(r[1]) for r in raw],
        "class_a": [int(r[2]) for r in raw],
        "class_b": [int(r[3]) for r in raw],
        "full_months": int(scenario.gcs.full_months_closed),
    }
    return ScenarioResult(
        spec=spec,
        metrics=metrics,
        storage_usd=bill.storage_usd,
        network_usd=bill.network_usd,
        ops_usd=bill.ops_usd,
        wall_s=wall,
        events=scenario.sim.events_executed,
        series=series,
        monthly=monthly,
    )


def _run_scenario_with_metrics(spec: ScenarioSpec):
    """Pool-worker task: the result plus the worker registry's snapshot
    delta (snapshot-then-reset), so the parent can ``merge`` it and a
    parallel sweep's metrics match a serial run's. Top-level for pickling.
    """
    result = run_scenario(spec)
    return result, snapshot_and_reset()


def pareto_indices(costs: Sequence[float],
                   values: Sequence[float]) -> List[int]:
    """Indices of the non-dominated (min cost, max value) points.

    Returned sorted by cost ascending; of points with identical (cost,
    value) only the first is kept, so the front is a strictly increasing
    cost/value staircase.
    """
    if len(costs) != len(values):
        raise ValueError("costs and values must have equal length")
    order = sorted(range(len(costs)), key=lambda i: (costs[i], -values[i]))
    front: List[int] = []
    best = float("-inf")
    for i in order:
        if values[i] > best:
            front.append(i)
            best = values[i]
    return front


@dataclass
class SweepResult:
    """Ordered results of one sweep (same order as the input specs).

    A sweep that lost work to exhausted retries is *partial*: the failed
    specs are simply absent from ``results`` and described in
    ``failures`` (``repro.sim.jobs.JobFailure`` reports — job id, spec
    labels, failure kind, attempt count, error trail). Callers that
    require completeness check ``ok`` / ``failures`` instead of relying
    on an exception; see ``docs/resilience.md``.
    """

    results: List[ScenarioResult]
    wall_s: float = 0.0
    #: Distinct dynamics lanes actually *simulated* to answer this call
    #: (``None`` when the call ran without get-or-compute accounting). A
    #: fully warm cache read reports 0 here.
    lanes_simulated: Optional[int] = None
    #: Distinct requested specs answered from the persistent result cache.
    cache_hits: int = 0
    #: Structured reports of jobs that exhausted their retry budget
    #: (``repro.sim.jobs.JobFailure``); empty for a complete sweep.
    failures: List[Any] = field(default_factory=list)

    def __len__(self) -> int:
        return len(self.results)

    @property
    def ok(self) -> bool:
        """True when no sweep work was abandoned (the result is
        complete with respect to the requested specs)."""
        return not self.failures

    #: Below this wall-clock floor a throughput rate is noise, not signal.
    WALL_S_FLOOR = 1e-3

    @property
    def configs_per_sec(self) -> Optional[float]:
        """Throughput, or ``None`` when ``wall_s`` is under the 1 ms
        floor — a fully cache-warm (or empty) sweep finishes in
        microseconds, and dividing by that produces a meaningless
        6-digit "rate"."""
        if self.wall_s < self.WALL_S_FLOOR:
            return None
        return len(self.results) / self.wall_s

    # -- frontier ------------------------------------------------------------
    def pareto_front(self) -> List[ScenarioResult]:
        """Cost/throughput frontier: min cloud cost, max jobs done."""
        idx = pareto_indices([r.cost_usd for r in self.results],
                             [r.jobs_done for r in self.results])
        return [self.results[i] for i in idx]

    # -- tabulation ----------------------------------------------------------
    def rows(self) -> List[Dict[str, Any]]:
        front = {id(r) for r in self.pareto_front()}
        out = []
        for r in self.results:
            row = r.row()
            row["pareto"] = int(id(r) in front)
            out.append(row)
        return out

    def aggregate_seeds(self) -> List[Dict[str, Any]]:
        """Group by spec-minus-seed; mean and sd% across seeds (the paper's
        Table 6/7/8 multi-run presentation)."""
        groups: Dict[ScenarioSpec, List[ScenarioResult]] = {}
        for r in self.results:
            groups.setdefault(replace(r.spec, seed=0), []).append(r)
        rows = []
        for key, rs in groups.items():
            jobs_m, jobs_sd, _ = mean_and_error([r.jobs_done for r in rs])
            cost_m, cost_sd, _ = mean_and_error([r.cost_usd for r in rs])
            row: Dict[str, Any] = {"label": key.label.rsplit(",seed=", 1)[0]}
            row.update(key.to_dict())
            del row["curves"], row["seed"]
            row.update(n_seeds=len(rs), jobs_done_mean=jobs_m,
                       jobs_done_sd_pct=jobs_sd, cost_usd_mean=cost_m,
                       cost_usd_sd_pct=cost_sd,
                       cost_per_kjob_mean=1e3 * cost_m / max(jobs_m, 1.0))
            rows.append(row)
        return rows

    # -- export --------------------------------------------------------------
    def to_csv(self, path: str) -> None:
        write_csv(path, self.rows())

    def pareto_to_csv(self, path: str) -> None:
        write_csv(path, [r.row() for r in self.pareto_front()])

    def to_json(self, path: str) -> None:
        """JSON export, committed atomically (tmp file + ``os.replace``)
        like every other export path — a killed run never publishes a
        truncated document."""
        doc = {
            "wall_s": self.wall_s,
            "rows": self.rows(),
            "pareto": [r.spec.label for r in self.pareto_front()],
            "series": {r.spec.label: r.series
                       for r in self.results if r.series},
        }
        if self.configs_per_sec is not None:
            doc["configs_per_sec"] = self.configs_per_sec
        if self.lanes_simulated is not None:
            doc["lanes_simulated"] = self.lanes_simulated
            doc["cache_hits"] = self.cache_hits
        if self.failures:
            doc["failures"] = [f.as_dict() for f in self.failures]
        atomic_write_text(path, json.dumps(doc, indent=2))


def _jobs_engaged(backend: str, retry: Any, faults: Any,
                  transport: Any = None) -> bool:
    """Whether this call routes through the ``repro.sim.jobs`` layer.

    The process backend always does — crash recovery and partial results
    cost it nothing. The jax backend engages only when resilience or
    fleet execution was asked for (``retry``/``faults``/``transport``):
    its plain path runs the whole grid as few large device programs, and
    keeping that path untouched keeps the warm-throughput overhead of
    this feature at zero.
    """
    return (backend == "process" or retry is not None
            or faults is not None or transport is not None)


def _journal_to_cache(cache: Any, backend: str, tick: float,
                      tick_impl: Optional[str]) -> Callable:
    """A per-job completion hook that checkpoints results into the
    persistent cache as they finish (the resume mechanism: a killed run
    re-executed with the same cache recomputes only unfinished jobs).

    Dedups by cache key across calls so pricing variants of one dynamics
    lane still produce a single write, exactly like the bulk
    ``cache.store`` the non-journaled path uses.
    """
    from repro.core.scenarios import cache_key

    seen: set = set()

    def journal(pairs) -> None:
        fresh = []
        for spec, result in pairs:
            if not result.monthly:
                continue
            key = cache_key(spec, backend=backend, tick=tick,
                            tick_impl=tick_impl)
            if key not in seen:
                seen.add(key)
                fresh.append((spec, result))
        if fresh:
            cache.store(fresh, backend=backend, tick=tick,
                        tick_impl=tick_impl)

    return journal


def run_sweep(specs: Sequence[ScenarioSpec], workers: Optional[int] = None,
              progress: Optional[Callable[[int, int, ScenarioResult], None]]
              = None, backend: str = "process",
              tick: float = 10.0, tick_impl: str = "auto",
              lane_chunk: Optional[int] = None,
              devices: Optional[Sequence[Any]] = None,
              cache: Optional[Any] = None,
              record_series=None,
              retry: Optional[Any] = None,
              faults: Optional[Any] = None,
              job_timeout: Optional[float] = None,
              transport: Optional[Any] = None,
              shard: bool = False,
              _journal: Optional[Callable] = None) -> SweepResult:
    """Execute every spec; results keep the input order.

    ``backend`` selects the execution engine:

    - ``"process"`` (default): the event-driven reference engine, one
      Python process per config. Ground truth; bit-deterministic per seed.
    - ``"jax"``: the fixed-tick lane-per-scenario engine
      (``repro.sim.batched``) — the whole grid runs as one ``jit`` +
      ``vmap`` program. Requires uniform ``days``/``n_files`` across the
      grid and matches the reference statistically (Table 2 tolerance),
      not bitwise; ``tick`` sets its clock step in seconds.

    ``tick_impl`` (jax backend only) selects the tick-engine *kernel
    implementation* — ``"jnp"`` | ``"pallas"`` | ``"pallas_interpret"``
    | ``"auto"`` (``repro.kernels.registry``; ``"auto"`` resolves to the
    compiled Pallas kernels on an accelerator and the jnp program on
    CPU). Not to be confused with ``tick``, the clock-step *duration*.

    ``workers``: process count for the process backend; ``None`` uses all
    CPUs (capped at the batch size), ``0``/``1`` runs serially in-process
    (useful under profilers and in tests of determinism).

    ``lane_chunk``/``devices`` (jax backend only): execute the packed
    grid's dynamics lanes in fixed-size chunks — bounded device memory
    and one compile reused across chunks and grids — optionally round-
    robined over several devices. Per-lane results are bitwise identical
    to the unchunked path.

    ``cache``: a ``repro.sim.cache.ResultCache`` (or a cache-directory
    path) turns the call into get-or-compute: specs whose dynamics entry
    is already stored are served from the cache (re-billed for their
    pricing fields, bit-identical to a fresh run on the same engine),
    only the misses are simulated, and their results are stored back.
    ``SweepResult.lanes_simulated``/``cache_hits`` report the split.
    ``tick_impl`` is resolved to its concrete implementation *before*
    keying, so entries from different kernel implementations never
    cross-serve (``"jnp"`` keeps the legacy key: it is bitwise the
    pre-registry engine).

    ``record_series`` (jax backend only): per-tick series capture —
    ``True`` samples every tick, an int is the sample stride in ticks;
    each result then carries the event-engine-schema summary digests in
    ``.series`` (see ``repro.sim.batched.series_from_capture``). The
    process backend records series via ``spec.curves`` instead.

    ``retry``/``faults``/``job_timeout`` (see ``docs/resilience.md``):
    fault-tolerant execution through ``repro.sim.jobs``. ``retry`` is a
    ``jobs.RetryPolicy`` (bounded deterministic exponential backoff);
    ``faults`` a ``faults.FaultPlan`` (or spec string / dict) injecting
    seeded crashes / hangs / transient errors / corrupt cache reads;
    ``job_timeout`` a per-attempt wall-clock deadline in seconds. The
    process backend always runs through the job layer (a worker crash
    costs retries, not the sweep); the jax backend shards its packed
    grid into lane-chunk jobs when ``retry`` or ``faults`` is given.
    Work that exhausts its retry budget is *dropped, not fatal*: the
    returned ``SweepResult`` is partial, with the losses described in
    ``SweepResult.failures``. With ``cache`` set, completions are
    journaled per job, so re-running a killed sweep against the same
    cache recomputes only the unfinished jobs (checkpointed resume).

    ``transport`` (see ``docs/distributed.md``): run the jobs on a
    persistent worker fleet (``repro.sim.runners``) instead of the
    serial executor / anonymous pool — ``"subprocess"`` spawns local
    worker processes, ``"local"`` executes inline (tests), a callable is
    a custom ``Transport`` factory (the remote-host seam). Works with
    both backends (the jax backend fans its lane-chunk jobs across the
    fleet) and composes with ``retry``/``faults``/``job_timeout``.

    ``shard`` (jax backend only): run each lane batch as one
    ``jax.shard_map`` program over the local device mesh
    (``repro.parallel.sharding.lane_mesh``) instead of the per-chunk
    Python loop. Per-lane results stay bitwise identical (lane programs
    exchange no collectives). Mutually exclusive with ``devices``.
    """
    if backend != "jax" and tick_impl != "auto":
        raise ValueError("tick_impl applies to backend='jax' only")
    if backend != "jax" and record_series not in (None, False):
        raise ValueError("record_series applies to backend='jax' only "
                         "(the process backend records curves via "
                         "spec.curves)")
    if shard and backend != "jax":
        raise ValueError("shard applies to backend='jax' only")
    from repro.sim.faults import as_faults

    faults = as_faults(faults)
    impl_name: Optional[str] = None
    if backend == "jax":
        from repro.kernels.registry import resolve_tick_impl

        impl_name = resolve_tick_impl(tick_impl).name
    if cache is not None:
        from repro.core.scenarios import dynamics_key
        from repro.sim.cache import ResultCache, as_cache  # imports us

        cache = as_cache(cache)
        if faults is not None and faults.corrupt > 0.0:
            # Corrupt-read injection wraps a *local* view of the caller's
            # backend (the caller's ResultCache object is not mutated);
            # the cache detects the garbage, drops the entry, recomputes.
            from repro.sim.faults import FaultyBackend

            cache = ResultCache(FaultyBackend(cache.backend, faults))
        specs = list(specs)
        t0 = time.perf_counter()
        engaged = _jobs_engaged(backend, retry, faults, transport)
        hits = cache.fetch(specs, backend=backend, tick=tick,
                           tick_impl=impl_name)
        miss = [s for s in dict.fromkeys(specs) if s not in hits]
        computed: Dict["ScenarioSpec", ScenarioResult] = {}
        failures: List[Any] = []
        if miss:
            journal = (_journal_to_cache(cache, backend, tick, impl_name)
                       if engaged else None)
            res = run_sweep(miss, workers=workers, progress=progress,
                            backend=backend, tick=tick,
                            tick_impl=impl_name or "auto",
                            lane_chunk=lane_chunk, devices=devices,
                            record_series=record_series,
                            retry=retry, faults=faults,
                            job_timeout=job_timeout, transport=transport,
                            shard=shard, _journal=journal)
            # Key by result spec, not input order: a partial result has
            # fewer entries than ``miss`` and zip would misalign them.
            computed = {r.spec: r for r in res.results}
            failures = list(res.failures)
            if not engaged:
                # The plain jax path has no per-job journal; store in bulk.
                cache.store(computed.items(), backend=backend, tick=tick,
                            tick_impl=impl_name)
        merged = {**hits, **computed}
        return SweepResult(
            results=[merged[s] for s in specs if s in merged],
            wall_s=time.perf_counter() - t0,
            lanes_simulated=len({dynamics_key(s) for s in computed}),
            cache_hits=len(hits),
            failures=failures)
    if backend == "jax":
        from repro.sim.batched import run_sweep_jax  # deferred: needs jax

        return run_sweep_jax(specs, tick=tick, progress=progress,
                             tick_impl=impl_name,
                             lane_chunk=lane_chunk, devices=devices,
                             record_series=record_series,
                             retry=retry, faults=faults,
                             job_timeout=job_timeout, workers=workers,
                             transport=transport, shard=shard,
                             journal=_journal)
    if lane_chunk is not None or devices is not None:
        raise ValueError("lane_chunk/devices apply to backend='jax' only")
    if backend != "process":
        raise ValueError(f"unknown backend {backend!r} "
                         "(expected 'process' or 'jax')")
    from repro.sim import jobs as joblib

    specs = list(specs)
    if workers is None:
        workers = min(len(specs), os.cpu_count() or 1)
    t0 = time.perf_counter()
    # One job per distinct spec (duplicates in the request are answered
    # from the same result), executed through the registry so a worker
    # failure costs retries — never the completed portion of the sweep.
    unique = list(dict.fromkeys(specs))
    policy = retry if retry is not None else joblib.RetryPolicy()
    jobs_list = [joblib.Job(job_id=f"spec{i:04d}", payload=s,
                            labels=(s.label,), timeout_s=job_timeout)
                 for i, s in enumerate(unique)]
    on_done = None
    if _journal is not None:
        def on_done(job, result):
            _journal([(job.payload, result)])
    if transport is not None:
        from repro.sim.runners import run_fleet_jobs

        _res, registry = run_fleet_jobs(
            jobs_list, workers=max(1, min(workers, len(unique))),
            transport=transport, ctx={"kind": "scenario"},
            policy=policy, faults=faults,
            progress=progress, on_done=on_done)
    elif workers <= 1 or len(unique) <= 1:
        def run_one(job):
            return run_scenario(job.payload)

        _res, registry = joblib.run_local_jobs(
            jobs_list, run_one, policy=policy, faults=faults,
            progress=progress, on_done=on_done)
    else:
        # Spawned (not forked) pool: callers may have JAX loaded, whose
        # thread pools make forked children deadlock-prone; the sweep
        # worker itself only needs numpy, so spawn startup stays cheap.
        _res, registry = joblib.run_process_jobs(
            jobs_list, workers=workers, policy=policy, faults=faults,
            progress=progress, on_done=on_done)
    by_spec = {job.payload: job.result for job in registry.jobs.values()
               if job.state == joblib.DONE}
    return SweepResult(
        results=[by_spec[s] for s in specs if s in by_spec],
        wall_s=time.perf_counter() - t0,
        failures=registry.failures())


class SweepDriver:
    """Iterative ``run_sweep`` front-end with cross-round memoization.

    The decision-support layer (``repro.sim.decide``) calls the sweep *in a
    loop* — adaptive grid refinement, break-even bisection — where
    successive rounds re-request many already-simulated specs plus a few
    new ones. The driver executes only the unseen specs (one ``run_sweep``
    call per round, so new specs still batch into one packed grid on the
    jax backend, whose K/J shape bucketing keeps the compiled program
    cached across rounds) and answers the rest from memory.

    It also keeps the books the decision layer reports on:

    - ``lanes_simulated``: distinct dynamics lanes ever *simulated* (the
      ``repro.core.scenarios.dynamics_key`` identity — the
      backend-independent lane-efficiency denominator). Note the memo is
      per exact spec: pricing-only variants of a memoized spec arriving
      in a *later* call still re-simulate their lane (``pack_specs``
      dedups within one packed grid only) unless a persistent cache
      serves them, which is why the decide solvers batch each round's
      pricing probes into one call;
    - ``configs_run`` / ``sweep_calls`` / ``wall_s``: raw work counters —
      cache-served specs never count as work;
    - ``cache_hits``: specs answered from the persistent result cache.

    ``cache`` (a ``repro.sim.cache.ResultCache`` or a cache-directory
    path) adds a persistent lookup tier between the in-memory memo and
    the engines: memo -> cache -> simulate. Simulated results are stored
    back, so a re-run of the same workflow — same process or next week's
    CI job — answers entirely from disk (``lanes_simulated`` stays 0).
    """

    def __init__(self, backend: str = "jax", tick: float = 10.0,
                 workers: Optional[int] = None,
                 tick_impl: str = "auto",
                 lane_chunk: Optional[int] = None,
                 devices: Optional[Sequence[Any]] = None,
                 progress: Optional[Callable[[int, int, ScenarioResult],
                                             None]] = None,
                 cache: Optional[Any] = None,
                 record_series=None,
                 retry: Optional[Any] = None,
                 faults: Optional[Any] = None,
                 job_timeout: Optional[float] = None,
                 transport: Optional[Any] = None,
                 shard: bool = False):
        if backend != "jax" and tick_impl != "auto":
            raise ValueError("tick_impl applies to backend='jax' only")
        if backend != "jax" and record_series not in (None, False):
            raise ValueError("record_series applies to backend='jax' only")
        if shard and backend != "jax":
            raise ValueError("shard applies to backend='jax' only")
        from repro.sim.faults import as_faults

        self.backend = backend
        self.tick = tick
        self.tick_impl = tick_impl
        self.record_series = record_series
        #: resolved lazily on first run (importing jax to resolve
        #: ``"auto"`` is deferred until the jax backend actually runs)
        self._impl_name: Optional[str] = None
        self.workers = workers
        self.lane_chunk = lane_chunk
        self.devices = devices
        self.progress = progress
        self.retry = retry
        self.faults = as_faults(faults)
        self.job_timeout = job_timeout
        self.transport = transport
        self.shard = shard
        if cache is not None:
            from repro.sim.cache import as_cache  # deferred: imports us

            cache = as_cache(cache)
        self.cache = cache
        self._memo: Dict["ScenarioSpec", ScenarioResult] = {}
        self._lane_keys: set = set()
        self.sweep_calls = 0
        self.configs_run = 0
        self.cache_hits = 0
        self.wall_s = 0.0
        #: cumulative ``JobFailure`` reports across every round; the
        #: decision layer reads this to degrade its claims
        self.failures: List[Any] = []

    @property
    def lanes_simulated(self) -> int:
        return len(self._lane_keys)

    def __call__(self, specs: Sequence["ScenarioSpec"]) -> SweepResult:
        return self.run(specs)

    def _resolved_impl(self) -> Optional[str]:
        """The concrete ``tick_impl`` name for cache keying (jax backend
        only; resolving ``"auto"`` imports jax, so it happens on first
        use and is then pinned for the driver's lifetime)."""
        if self.backend != "jax":
            return None
        if self._impl_name is None:
            from repro.kernels.registry import resolve_tick_impl

            self._impl_name = resolve_tick_impl(self.tick_impl).name
        return self._impl_name

    def run(self, specs: Sequence["ScenarioSpec"]) -> SweepResult:
        """Results for ``specs`` in order, simulating only the unseen ones."""
        from repro.core.scenarios import dynamics_key

        specs = list(specs)
        new = [s for s in dict.fromkeys(specs) if s not in self._memo]
        t0 = time.perf_counter()
        hits = 0
        if new and self.cache is not None:
            served = self.cache.fetch(new, backend=self.backend,
                                      tick=self.tick,
                                      tick_impl=self._resolved_impl())
            self._memo.update(served)
            hits = len(served)
            self.cache_hits += hits
            new = [s for s in new if s not in served]
        lanes_before = len(self._lane_keys)
        round_failures: List[Any] = []
        if new:
            engaged = _jobs_engaged(self.backend, self.retry, self.faults,
                                    self.transport)
            journal = None
            if self.cache is not None and engaged:
                journal = _journal_to_cache(self.cache, self.backend,
                                            self.tick,
                                            self._resolved_impl())
            res = run_sweep(new, workers=self.workers,
                            progress=self.progress, backend=self.backend,
                            tick=self.tick,
                            tick_impl=self._resolved_impl() or "auto",
                            lane_chunk=self.lane_chunk,
                            devices=self.devices,
                            record_series=self.record_series,
                            retry=self.retry, faults=self.faults,
                            job_timeout=self.job_timeout,
                            transport=self.transport, shard=self.shard,
                            _journal=journal)
            self.sweep_calls += 1
            self.configs_run += len(res.results)
            self.wall_s += res.wall_s
            # Key by result spec, not request order: a partial result
            # has fewer entries than ``new`` and zip would misalign.
            for result in res.results:
                self._memo[result.spec] = result
                self._lane_keys.add(dynamics_key(result.spec))
            round_failures = list(res.failures)
            self.failures.extend(round_failures)
            if self.cache is not None and not engaged:
                self.cache.store(((r.spec, r) for r in res.results),
                                 backend=self.backend, tick=self.tick,
                                 tick_impl=self._resolved_impl())
        reg = get_registry()
        reg.set_gauge("lanes.simulated", self.lanes_simulated,
                      help="Distinct dynamics lanes simulated by the "
                           "driver (0 = fully cache-warm)")
        reg.set_gauge("configs.run", self.configs_run,
                      help="Specs actually executed by the driver")
        reg.set_gauge("sweep.calls", self.sweep_calls,
                      help="run_sweep invocations issued by the driver")
        reg.set_gauge("sweep.wall_s", self.wall_s,
                      help="Cumulative driver simulation wall time (s)")
        return SweepResult(results=[self._memo[s] for s in specs
                                    if s in self._memo],
                           wall_s=time.perf_counter() - t0,
                           lanes_simulated=len(self._lane_keys) - lanes_before,
                           cache_hits=hits,
                           failures=round_failures)
