"""Dispatcher <-> worker transports (the pluggable seam of the fleet).

A transport owns the channel to exactly one worker. The dispatcher
(``repro.sim.runners.fleet``) drives it through five methods::

    start(init_msg)   spawn/attach the worker, deliver the init context
    send(msg)         deliver one message (job frames, the stop frame)
    poll()            -> ("frame", msg) | ("eof",) | None   (non-blocking)
    kill()            tear the worker down *now* (deadline reaping)
    alive             False once the channel is known dead

Messages are plain dicts moved as *frames*: an 8-byte big-endian length
prefix followed by a pickle payload (numpy arrays ride along
efficiently). ``("eof",)`` reports a dead channel — a crashed, killed,
or cleanly exited worker — exactly once; with one job in flight per
worker, the dispatcher attributes it to precisely that job.

``SubprocessTransport`` is the local fleet: one spawned
``python -m repro.sim.runners.worker`` per transport, frames over its
stdin/stdout pipes, a daemon reader thread feeding the poll queue.
``LocalTransport`` executes the same worker logic inline in the
dispatcher process (no pickling, no process) — the determinism-test and
debugging path. A remote-host transport only needs to speak the same
five methods to slot in (ROADMAP: remote workers); ``resolve_transport``
accepts any zero-argument factory for that reason.
"""

from __future__ import annotations

import os
import pickle
import queue
import struct
import subprocess
import sys
import threading
import time
from collections import deque
from typing import Any, BinaryIO, Callable, Dict, Optional, Tuple

_LEN = struct.Struct(">Q")


class TransportError(RuntimeError):
    """The channel to a worker failed (send on a dead pipe, bad frame)."""


def send_frame(stream: BinaryIO, msg: Any) -> None:
    """Write one length-prefixed pickle frame and flush."""
    payload = pickle.dumps(msg, protocol=pickle.HIGHEST_PROTOCOL)
    stream.write(_LEN.pack(len(payload)) + payload)
    stream.flush()


def recv_frame(stream: BinaryIO) -> Any:
    """Read one frame; raises ``EOFError`` on a closed stream."""
    header = _read_exact(stream, _LEN.size)
    (n,) = _LEN.unpack(header)
    return pickle.loads(_read_exact(stream, n))


def _read_exact(stream: BinaryIO, n: int) -> bytes:
    buf = b""
    while len(buf) < n:
        chunk = stream.read(n - len(buf))
        if not chunk:
            raise EOFError("stream closed mid-frame"
                           if buf else "stream closed")
        buf += chunk
    return buf


class Transport:
    """Interface every fleet transport implements (see module docstring)."""

    def start(self, init_msg: Dict[str, Any]) -> None:
        raise NotImplementedError

    def send(self, msg: Dict[str, Any]) -> None:
        raise NotImplementedError

    def poll(self) -> Optional[Tuple]:
        raise NotImplementedError

    def kill(self) -> None:
        raise NotImplementedError

    @property
    def alive(self) -> bool:
        raise NotImplementedError


class SubprocessTransport(Transport):
    """One spawned local worker process, frames over its stdio pipes.

    The child runs ``python -m repro.sim.runners.worker`` with
    ``PYTHONPATH`` extended to wherever this ``repro`` package was
    imported from and ``JAX_PLATFORMS=cpu`` pinned (an accelerator-
    probing child can hang on device init while the parent holds the
    device — the same policy as ``repro.sim.sweep._worker_init``).
    stderr is inherited, so worker logs land in the parent's; stdout is
    the frame channel (the worker re-points stray prints at stderr). A
    daemon thread drains stdout into the poll queue so ``poll`` never
    blocks; worker death surfaces as one ``("eof",)`` event.
    """

    def __init__(self, python: Optional[str] = None,
                 env: Optional[Dict[str, str]] = None):
        self._python = python or sys.executable
        self._env_extra = dict(env or {})
        self._proc: Optional[subprocess.Popen] = None
        self._events: "queue.Queue[Tuple]" = queue.Queue()
        self._alive = False
        self._eof_seen = False

    def start(self, init_msg: Dict[str, Any]) -> None:
        import repro

        # ``repro`` may be a namespace package (no __init__.py), whose
        # ``__file__`` is None — locate it through ``__path__`` instead.
        pkg_dir = (os.path.dirname(repro.__file__)
                   if getattr(repro, "__file__", None)
                   else next(iter(repro.__path__)))
        src_root = os.path.dirname(os.path.abspath(pkg_dir))
        env = dict(os.environ)
        prior = env.get("PYTHONPATH")
        env["PYTHONPATH"] = (src_root if not prior
                             else src_root + os.pathsep + prior)
        env["JAX_PLATFORMS"] = "cpu"
        env.update(self._env_extra)
        self._proc = subprocess.Popen(
            [self._python, "-m", "repro.sim.runners.worker"],
            stdin=subprocess.PIPE, stdout=subprocess.PIPE, env=env)
        self._alive = True
        threading.Thread(target=self._read_loop, daemon=True).start()
        self.send(init_msg)

    def _read_loop(self) -> None:
        stream = self._proc.stdout
        try:
            while True:
                self._events.put(("frame", recv_frame(stream)))
        except (EOFError, OSError, pickle.UnpicklingError):
            self._events.put(("eof",))

    def send(self, msg: Dict[str, Any]) -> None:
        if not self._alive or self._proc is None:
            raise TransportError("transport is not alive")
        try:
            send_frame(self._proc.stdin, msg)
        except (BrokenPipeError, OSError) as e:
            self._alive = False
            raise TransportError(f"send to worker failed: {e}") from e

    def poll(self) -> Optional[Tuple]:
        try:
            event = self._events.get_nowait()
        except queue.Empty:
            return None
        if event[0] == "eof":
            self._alive = False
            if self._eof_seen:  # deliver a dead channel exactly once
                return None
            self._eof_seen = True
        return event

    def kill(self) -> None:
        self._alive = False
        proc = self._proc
        if proc is None or proc.poll() is not None:
            return
        try:
            proc.terminate()
            proc.wait(timeout=2.0)
        except Exception:
            try:
                proc.kill()
                proc.wait(timeout=2.0)
            except Exception:
                pass

    @property
    def alive(self) -> bool:
        return self._alive


class LocalTransport(Transport):
    """Worker logic executed inline in the dispatcher process.

    ``send`` runs the job synchronously and queues the result frame for
    the next ``poll`` — same protocol, no process, no pickling — so
    fleet tests assert bitwise determinism without subprocess variance.
    Fault directives are acted out with in-process semantics: ``crash``
    marks the channel dead and queues the ``("eof",)`` the dispatcher
    expects (without killing the dispatcher!); ``hang`` sleeps its full
    duration before the job runs — inline work cannot be preempted, so
    the deadline is enforced by the dispatcher's next poll pass, exactly
    like ``repro.sim.jobs.run_local_jobs``'s simulated deadlines.
    """

    def __init__(self):
        self._runner: Optional[Callable] = None
        self._events: deque = deque()
        self._alive = False

    def start(self, init_msg: Dict[str, Any]) -> None:
        from repro.sim.runners import worker

        self._runner = worker.build_runner(init_msg["ctx"])
        self._alive = True
        self._events.append(("frame", {"op": "ready", "startup_s": 0.0}))

    def send(self, msg: Dict[str, Any]) -> None:
        if not self._alive:
            raise TransportError("transport is not alive")
        if msg.get("op") == "stop":
            self._alive = False
            return
        from repro.sim.runners import worker

        directive = msg.get("directive")
        if directive is not None and directive["kind"] == "crash":
            self._alive = False
            self._events.append(("eof",))
            return
        if directive is not None and directive["kind"] == "hang":
            time.sleep(float(directive["seconds"]))
        # snapshot=False: inline work already lands in the dispatcher's
        # own registry — a snapshot/merge round trip would steal its
        # counters when the frame is dropped (deadline overrun).
        self._events.append(
            ("frame", worker.attempt(self._runner, msg, snapshot=False)))

    def poll(self) -> Optional[Tuple]:
        if not self._events:
            return None
        return self._events.popleft()

    def kill(self) -> None:
        self._alive = False
        self._events.clear()

    @property
    def alive(self) -> bool:
        return self._alive


def resolve_transport(transport: Any) -> Callable[[], Transport]:
    """Coerce a ``transport=`` argument to a zero-arg transport factory.

    ``"subprocess"`` (the default fleet) and ``"local"`` name the
    built-ins; any callable passes through — the seam a remote-host
    transport plugs into.
    """
    if transport in (None, "subprocess"):
        return SubprocessTransport
    if transport == "local":
        return LocalTransport
    if callable(transport):
        return transport
    raise ValueError(f"unknown transport {transport!r} "
                     "(expected 'subprocess', 'local', or a factory)")


__all__ = [
    "LocalTransport", "SubprocessTransport", "Transport", "TransportError",
    "recv_frame", "resolve_transport", "send_frame",
]
