"""Serve a small model with batched requests (continuous batching loop).

Greedy-decodes a wave of prompts through prefill + decode steps with
per-layer KV caches (ring buffers on sliding-window archs).

    PYTHONPATH=src python examples/serve_small.py [--arch hymba_1_5b]
"""

import argparse
import sys
import time

sys.path.insert(0, "src")

import jax

from repro.configs import canonical, get_smoke_config
from repro.models import init_params
from repro.serve.engine import Request, ServeLoop


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", type=str, default="hymba_1_5b")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--max-new", type=int, default=8)
    args = ap.parse_args()

    cfg = get_smoke_config(canonical(args.arch))
    params = init_params(cfg, jax.random.PRNGKey(0))
    loop = ServeLoop(cfg, params, batch_slots=4, max_len=128)

    reqs = []
    for i in range(args.requests):
        prompt = jax.random.randint(jax.random.PRNGKey(100 + i), (12,), 0,
                                    cfg.vocab_size)
        reqs.append(Request(rid=i, prompt=prompt, max_new=args.max_new))

    t0 = time.time()
    out = loop.run(reqs)
    dt = time.time() - t0
    total_tokens = sum(len(v) for v in out.values())
    for rid in sorted(out):
        print(f"request {rid}: {out[rid]}")
    print(f"\n{len(reqs)} requests, {total_tokens} tokens in {dt:.2f}s "
          f"({total_tokens / dt:.1f} tok/s on CPU, reduced config)")


if __name__ == "__main__":
    main()
