"""Validation scenario (paper §4.2, Tables 1 and 2).

Three sites, 1000 initial replicas each, two outgoing links per site
(full mesh of 6 directional links), per-transfer throughput 8.10 MB/s.
Each generator tick (10 s), per link, a number of transfers is generated
from the fitted exponential (lambda = 3.33437); source files are selected
uniformly among files not already at (or in flight to) the destination;
after a completed transfer the destination replica is deleted so the file
becomes selectable again. File sizes ~ Exp(lambda = 0.61972) GiB clamped to
[10.23 MB, 13.73 GB].

Unit note (documented in EXPERIMENTS.md): the internally consistent reading
of Table 2 is a *per-second* total transfer rate of 1.80 (traffic 3.11 GB/s
= 1.80/s x 1.73 GB; concurrency 1.80/s x 214 s x 8.10 MB/s = 3.12 GB/s),
i.e. per link-tick the generated count has mean 0.29995 x 10. The table's
"No./10s" unit label only reconciles with the traffic and duration rows
under this reading.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Set, Tuple

import numpy as np

from repro.sim.distributions import BoundedExponential, FractionalCounter
from repro.sim.engine import HOUR, DAY, BaseSimulation, Schedulable
from repro.sim.infrastructure import GB, GiB, File, NetworkLink, Site, StorageElement
from repro.sim.output import OutputCollector
from repro.sim.transfer import EventDrivenTransferService


@dataclass
class ValidationConfig:
    simulated_time: int = 59 * DAY + 19 * HOUR
    gen_interval: int = 10
    n_sites: int = 3
    initial_replicas: int = 1000
    throughput: float = 8.10e6  # bytes/s per transfer (MB = 1e6)
    size_lam: float = 0.61972  # per GiB
    size_lo: float = 10.23e6 / GiB  # GiB
    size_hi: float = 13.73e9 / GiB  # GiB
    rate_lam: float = 3.33437  # exp sample; mean 0.29995 per link per second
    per_second_rate: bool = True  # see unit note above
    seed: int = 0


class ValidationScenario:
    """Builds and runs the §4.2 scenario; exposes Table-2 metrics."""

    def __init__(self, cfg: ValidationConfig):
        self.cfg = cfg
        self.rng = np.random.default_rng(cfg.seed)
        self.sim = BaseSimulation(seed=cfg.seed)
        self.out = OutputCollector()
        self.sites: List[Site] = []
        self.links: List[NetworkLink] = []
        self._size_dist = BoundedExponential(
            cfg.size_lam, cfg.size_lo, cfg.size_hi, unit=GiB
        )
        self._next_fid = 0
        self._files: Dict[str, List[File]] = {}  # per-site file pools
        self._in_flight: Set[Tuple[int, str]] = set()  # (fid, dst SE)
        self._build()

    # -- infrastructure -------------------------------------------------------
    def _build(self) -> None:
        cfg = self.cfg
        ses: List[StorageElement] = []
        for i in range(cfg.n_sites):
            site = Site(f"site-{i+1}")
            se = StorageElement("DATADISK", site)
            self.sites.append(site)
            ses.append(se)
            pool = []
            for _ in range(cfg.initial_replicas):
                f = self._new_file()
                se.add_complete_replica(f)
                pool.append(f)
            self._files[se.site.name] = pool
        for i, src in enumerate(ses):
            for j, dst in enumerate(ses):
                if i != j:
                    self.links.append(
                        NetworkLink(src, dst, throughput=cfg.throughput)
                    )
        self.svc = EventDrivenTransferService(self.sim, self.rng)

    def _new_file(self) -> File:
        self._next_fid += 1
        size = float(self._size_dist.sample(self.rng))
        return File(self._next_fid, size)

    # -- generator ------------------------------------------------------------
    def _make_generator(self) -> Schedulable:
        scenario = self

        class Generator(Schedulable):
            def __init__(self) -> None:
                super().__init__(interval=scenario.cfg.gen_interval)
                self.counters = {ln.name: FractionalCounter()
                                 for ln in scenario.links}

            def on_update(self, sim: BaseSimulation, now: int) -> None:
                cfg = scenario.cfg
                scale = cfg.gen_interval if cfg.per_second_rate else 1
                for link in scenario.links:
                    x = scenario.rng.exponential(1.0 / cfg.rate_lam) * scale
                    n = self.counters[link.name].emit(x)
                    for _ in range(n):
                        scenario._generate_transfer(sim, now, link)

        return Generator()

    def _generate_transfer(self, sim: BaseSimulation, now: int,
                           link: NetworkLink) -> None:
        pool = self._files[link.src.site.name]
        dst = link.dst
        # Uniform-randomly select a source file not already at / in flight to
        # the destination; create a new file if the candidate does not qualify
        # (paper §4.2: "In case no replica meets the select conditions, a new
        # replica is created"). A single draw (rather than retrying) is the
        # reading that reproduces Table 2's unbiased 1.73 GB mean: retrying
        # around in-flight files biases selection against large files, whose
        # transfers occupy the in-flight set longer.
        file: Optional[File] = None
        cand = pool[int(self.rng.integers(len(pool)))]
        if cand.fid not in dst.replicas and (cand.fid, dst.name) not in self._in_flight:
            file = cand
        if file is None:
            file = self._new_file()
            link.src.add_complete_replica(file)
            pool.append(file)
        self._in_flight.add((file.fid, dst.name))
        self.out.count("transfers_created")

        def done(sim: BaseSimulation, t_now: int, t) -> None:
            self._in_flight.discard((file.fid, dst.name))
            self.out.count("transfers_done")
            self.out.count("bytes_done", file.size)
            self.out.hist("file_size").record(file.size)
            self.out.hist("duration").record(t.duration)
            # Delete the destination replica again so the file can be
            # re-transferred (paper §4.2).
            dst.delete(file.fid)

        self.svc.submit(file, link, on_complete=done)

    # -- run + metrics ---------------------------------------------------------
    def run(self) -> Dict[str, float]:
        self.sim.schedule(self._make_generator(), 0)
        self.sim.run(self.cfg.simulated_time)
        return self.metrics()

    def metrics(self) -> Dict[str, float]:
        t = max(self.sim.now, 1)
        done = self.out.counters.get("transfers_done", 0.0)
        vol = self.out.counters.get("bytes_done", 0.0)
        return {
            # Table 2 rows (simulated):
            "file_size_gb": self.out.hist("file_size").mean / GB,
            "transfers_per_s": done / t,
            "throughput_mb_s": self.cfg.throughput / 1e6,
            "traffic_gb_s": vol / t / GB,
            "duration_s": self.out.hist("duration").mean,
            "transfers_done": done,
        }


# Paper Table 2 reference values (simulated column).
PAPER_TABLE2 = {
    "file_size_gb": 1.73,
    "transfers_per_s": 1.80,
    "throughput_mb_s": 8.01,
    "traffic_gb_s": 3.11,
    "duration_s": 214.10,
}
