"""Optimizers (functional, optax-style minimal) + gradient compression.

- ``adamw``: AdamW with f32 moments. Under the sharding rules the moments
  inherit param shardings (+ FSDP axis), i.e. ZeRO-1.
- ``adafactor``: factored second moment (row/col statistics) for 100B+
  archs where full f32 Adam state cannot fit v5e HBM.
- ``compress_gradients``: int8 stochastic-rounding quantisation with error
  feedback (distributed-optimization trick; applied before cross-pod
  reduction when enabled).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, NamedTuple, Tuple

import jax
import jax.numpy as jnp


class Optimizer(NamedTuple):
    init: Callable[[Any], Any]
    update: Callable[[Any, Any, Any], Tuple[Any, Any]]  # (grads, state, params)


@dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    # adafactor
    decay: float = 0.8
    clip_threshold: float = 1.0


def adamw(cfg: OptConfig = OptConfig()) -> Optimizer:
    def init(params):
        def zeros(p):
            return jnp.zeros(p.shape, dtype=jnp.float32)
        return {
            "m": jax.tree.map(zeros, params),
            "v": jax.tree.map(zeros, params),
            "step": jnp.zeros((), dtype=jnp.int32),
        }

    def update(grads, state, params):
        step = state["step"] + 1
        b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
        b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

        def upd(g, m, v, p):
            g = g.astype(jnp.float32)
            m = cfg.b1 * m + (1 - cfg.b1) * g
            v = cfg.b2 * v + (1 - cfg.b2) * g * g
            mh = m / b1c
            vh = v / b2c
            delta = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
            return m, v, (p.astype(jnp.float32) - cfg.lr * delta).astype(p.dtype)

        flat_g, tdef = jax.tree.flatten(grads)
        flat_m = tdef.flatten_up_to(state["m"])
        flat_v = tdef.flatten_up_to(state["v"])
        flat_p = tdef.flatten_up_to(params)
        out = [upd(g, m, v, p) for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
        new_m = tdef.unflatten([o[0] for o in out])
        new_v = tdef.unflatten([o[1] for o in out])
        new_p = tdef.unflatten([o[2] for o in out])
        return new_p, {"m": new_m, "v": new_v, "step": step}

    return Optimizer(init, update)


def adafactor(cfg: OptConfig = OptConfig()) -> Optimizer:
    """Factored second-moment optimizer (Shazeer & Stern 2018, simplified)."""

    def _factored(shape) -> bool:
        return len(shape) >= 2 and shape[-1] > 1 and shape[-2] > 1

    def init(params):
        def one(p):
            if _factored(p.shape):
                return {
                    "vr": jnp.zeros(p.shape[:-1], dtype=jnp.float32),
                    "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], dtype=jnp.float32),
                }
            return {"v": jnp.zeros(p.shape, dtype=jnp.float32)}

        return {"stats": jax.tree.map(one, params,
                                      is_leaf=lambda x: hasattr(x, "shape")),
                "step": jnp.zeros((), dtype=jnp.int32)}

    def update(grads, state, params):
        step = state["step"] + 1
        beta = 1.0 - (step.astype(jnp.float32)) ** (-cfg.decay)

        def one(g, st, p):
            g = g.astype(jnp.float32)
            g2 = g * g + 1e-30
            if "vr" in st:
                vr = beta * st["vr"] + (1 - beta) * jnp.mean(g2, axis=-1)
                vc = beta * st["vc"] + (1 - beta) * jnp.mean(g2, axis=-2)
                denom = jnp.maximum(jnp.mean(vr, axis=-1, keepdims=True), 1e-30)
                prec = (vr[..., None] / denom[..., None]) * vc[..., None, :]
                upd = g * jax.lax.rsqrt(prec + 1e-30)
                new_st = {"vr": vr, "vc": vc}
            else:
                v = beta * st["v"] + (1 - beta) * g2
                upd = g * jax.lax.rsqrt(v + 1e-30)
                new_st = {"v": v}
            # update clipping (RMS <= clip_threshold)
            rms = jnp.sqrt(jnp.mean(upd * upd) + 1e-30)
            upd = upd / jnp.maximum(1.0, rms / cfg.clip_threshold)
            newp = (p.astype(jnp.float32)
                    - cfg.lr * (upd + cfg.weight_decay * p.astype(jnp.float32)))
            return new_st, newp.astype(p.dtype)

        flat_g, tdef = jax.tree.flatten(grads)
        flat_s = tdef.flatten_up_to(state["stats"])
        flat_p = tdef.flatten_up_to(params)
        out = [one(g, s, p) for g, s, p in zip(flat_g, flat_s, flat_p)]
        return (tdef.unflatten([o[1] for o in out]),
                {"stats": tdef.unflatten([o[0] for o in out]), "step": step})

    return Optimizer(init, update)


def make_optimizer(name: str, cfg: OptConfig = OptConfig()) -> Optimizer:
    return {"adamw": adamw, "adafactor": adafactor}[name](cfg)


# -------------------------------------------------------- grad compression
def compress_gradients(grads, error_state):
    """int8 quantisation with error feedback.

    Returns (quantised-dequantised grads, new error state). When enabled,
    this runs *before* the cross-pod all-reduce so 8-bit tensors cross the
    slow inter-pod links; the residual stays local and is re-added next
    step (error feedback keeps the scheme unbiased over time).
    """

    def one(g, e):
        g32 = g.astype(jnp.float32) + e
        scale = jnp.maximum(jnp.max(jnp.abs(g32)), 1e-12) / 127.0
        q = jnp.clip(jnp.round(g32 / scale), -127, 127).astype(jnp.int8)
        deq = q.astype(jnp.float32) * scale
        return deq.astype(g.dtype), g32 - deq

    if error_state is None:
        error_state = jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads)
    out = jax.tree.map(one, grads, error_state)
    new_g = jax.tree.map(lambda t: t[0], out, is_leaf=lambda x: isinstance(x, tuple))
    new_e = jax.tree.map(lambda t: t[1], out, is_leaf=lambda x: isinstance(x, tuple))
    return new_g, new_e
