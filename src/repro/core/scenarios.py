"""Scenario-config parameterization (paper §5.3 decision workflow).

The paper's decision process compares many HCDC variants — cache (disk)
sizes, cloud egress pricing/peering options, job arrival rates, replica
seeds — against cost and throughput. ``ScenarioSpec`` is the flat,
picklable description of one such variant; ``build_config`` materialises it
into an ``HCDCConfig``; ``expand_grid`` produces the Cartesian product of
spec axes for ``repro.sim.sweep``.

A spec is deliberately a *parameterization*, not a config: it stays tiny
(plain scalars, trivially serialisable to YAML/JSON/CSV and across process
boundaries), while the heavyweight ``HCDCConfig`` (policies, site lists,
distributions) is rebuilt deterministically inside each worker.
"""

from __future__ import annotations

import itertools
import math
from dataclasses import asdict, dataclass, fields, replace
from typing import Any, Dict, Iterable, List, Mapping, Optional, Sequence

from repro.core.hcdc import HCDCConfig, make_config
from repro.sim.cloud import PEERING_PRICES
from repro.sim.engine import DAY
from repro.sim.infrastructure import TB

#: Valid ``ScenarioSpec.egress`` values: tiered internet egress or one of
#: the paper's §5.3 peering alternatives.
EGRESS_OPTIONS = ("internet",) + tuple(sorted(PEERING_PRICES))


@dataclass(frozen=True)
class ScenarioSpec:
    """One point of the §5.3 decision grid.

    ``None`` always means "keep the base configuration's value"; use
    ``float('inf')`` to request an explicitly unlimited cache/cold tier.
    """

    base: str = "III"  # Table 5 configuration name: I | II | III
    days: float = 2.0  # simulated horizon
    n_files: int = 20_000  # catalogue size per site
    seed: int = 0
    cache_tb: Optional[float] = None  # per-site hot (disk) cache limit, TB
    gcs_limit_tb: Optional[float] = None  # cold-tier limit, TB (0 = disabled)
    egress: str = "internet"  # internet | direct | interconnect
    storage_price: Optional[float] = None  # USD per GB-month override
    job_rate_scale: float = 1.0  # scales the job arrival rate
    curves: bool = False  # record Fig 6/8 time series

    def __post_init__(self) -> None:
        if self.base not in ("I", "II", "III"):
            raise ValueError(f"unknown base configuration {self.base!r}")
        if self.egress not in EGRESS_OPTIONS:
            raise ValueError(
                f"egress must be one of {EGRESS_OPTIONS}, got {self.egress!r}")
        if not self.days or self.days <= 0:
            raise ValueError(f"days must be > 0, got {self.days!r}")
        if self.n_files <= 0:
            raise ValueError(f"n_files must be > 0, got {self.n_files!r}")
        if not self.job_rate_scale or self.job_rate_scale <= 0:
            raise ValueError(
                f"job_rate_scale must be > 0, got {self.job_rate_scale!r}")

    @property
    def label(self) -> str:
        """Compact human-readable identifier, stable across runs."""
        cache = ("base" if self.cache_tb is None
                 else "inf" if math.isinf(self.cache_tb)
                 else f"{self.cache_tb:g}TB")
        parts = [f"cfg{self.base}", f"cache={cache}", f"egress={self.egress}"]
        if self.gcs_limit_tb is not None:
            gcs = "inf" if math.isinf(self.gcs_limit_tb) else f"{self.gcs_limit_tb:g}TB"
            parts.append(f"gcs={gcs}")
        if self.storage_price is not None:
            parts.append(f"stor={self.storage_price:g}")
        if self.job_rate_scale != 1.0:
            parts.append(f"rate={self.job_rate_scale:g}x")
        parts.append(f"seed={self.seed}")
        return ",".join(parts)

    def to_dict(self) -> Dict[str, Any]:
        return asdict(self)


def build_config(spec: ScenarioSpec) -> HCDCConfig:
    """Materialise a spec into a fully independent ``HCDCConfig``."""
    cfg = make_config(spec.base,
                      simulated_time=int(spec.days * DAY),
                      n_files_per_site=spec.n_files,
                      seed=spec.seed,
                      curves=spec.curves)
    if spec.cache_tb is not None:
        limit = None if math.isinf(spec.cache_tb) else spec.cache_tb * TB
        for site in cfg.sites:
            site.disk_limit = limit
    if spec.gcs_limit_tb is not None:
        cfg.gcs_limit = (None if math.isinf(spec.gcs_limit_tb)
                         else spec.gcs_limit_tb * TB)
    if spec.egress != "internet":
        cfg.cost_model = replace(cfg.cost_model, peering=spec.egress)
    if spec.storage_price is not None:
        cfg.cost_model = replace(cfg.cost_model,
                                 storage_per_gb_month=spec.storage_price)
    if spec.job_rate_scale != 1.0:
        # Scaling mu and sigma together scales the truncated-normal mean
        # exactly: max(kX, 0) = k max(X, 0) for k > 0.
        cfg.jobs_mu *= spec.job_rate_scale
        cfg.jobs_sigma *= spec.job_rate_scale
    return cfg


_SPEC_FIELDS = {f.name for f in fields(ScenarioSpec)}


def expand_grid(axes: Mapping[str, Any]) -> List[ScenarioSpec]:
    """Cartesian product of spec axes into a spec list.

    Values may be scalars (fixed for the whole sweep) or sequences (swept).
    ``{"cache_tb": [50, 100], "egress": ["internet", "direct"], "seed":
    [0, 1], "days": 1}`` expands to 2 x 2 x 2 = 8 specs. Axis order in the
    result follows the mapping's iteration order, last axis fastest.
    """
    unknown = set(axes) - _SPEC_FIELDS
    if unknown:
        raise ValueError(f"unknown spec fields: {sorted(unknown)} "
                         f"(valid: {sorted(_SPEC_FIELDS)})")
    names: List[str] = []
    levels: List[Sequence[Any]] = []
    for name, value in axes.items():
        if isinstance(value, (list, tuple)):
            names.append(name)
            levels.append(value)
        else:
            names.append(name)
            levels.append([value])
    return [ScenarioSpec(**dict(zip(names, combo)))
            for combo in itertools.product(*levels)]


def specs_from_mapping(doc: Mapping[str, Any]) -> List[ScenarioSpec]:
    """Parse a sweep document (already-loaded YAML/JSON) into specs.

    Two accepted shapes::

        {"axes": {...}, "days": 1, ...}     # grid + shared fixed fields
        {"scenarios": [{...}, {...}], ...}  # explicit spec list + shared

    Shared top-level fields apply to every spec unless the axis/scenario
    overrides them.
    """
    doc = dict(doc)
    axes = doc.pop("axes", None)
    scenarios = doc.pop("scenarios", None)
    shared = {k: v for k, v in doc.items() if k in _SPEC_FIELDS}
    extra = set(doc) - _SPEC_FIELDS
    if extra:
        raise ValueError(f"unknown top-level fields: {sorted(extra)}")
    if (axes is None) == (scenarios is None):
        raise ValueError("provide exactly one of 'axes' or 'scenarios'")
    if axes is not None:
        merged = dict(shared)
        merged.update(axes)
        return expand_grid(merged)
    specs = []
    for s in scenarios:
        s = dict(s)
        unknown = set(s) - _SPEC_FIELDS
        if unknown:
            raise ValueError(f"unknown scenario fields: {sorted(unknown)} "
                             f"(valid: {sorted(_SPEC_FIELDS)})")
        specs.append(ScenarioSpec(**{**shared, **s}))
    return specs


def with_seeds(specs: Iterable[ScenarioSpec], n_seeds: int,
               first_seed: int = 0) -> List[ScenarioSpec]:
    """Replicate each spec across ``n_seeds`` consecutive seeds."""
    return [replace(s, seed=first_seed + k)
            for s in specs for k in range(n_seeds)]
