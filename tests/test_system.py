"""End-to-end behaviour tests for the paper's system."""

import numpy as np

from repro.core.hcdc import HCDCScenario, make_config
from repro.core.validation import ValidationConfig, ValidationScenario
from repro.sim.engine import DAY, HOUR


def test_validation_scenario_short_run_matches_analytics():
    """A 12-hour validation run reproduces the configured rates
    (full two-month runs against Table 2 live in benchmarks)."""
    cfg = ValidationConfig(simulated_time=12 * HOUR, seed=7)
    m = ValidationScenario(cfg).run()
    # transfer generation rate: 6 links x 0.29995/s = 1.7997/s
    assert abs(m["transfers_per_s"] - 1.80) / 1.80 < 0.05
    # mean file size ~ 1.73 GB (unbiased exp mean in GiB)
    assert abs(m["file_size_gb"] - 1.733) / 1.733 < 0.05
    # duration = size / throughput
    assert abs(m["duration_s"] - m["file_size_gb"] * 1e9 / 8.10e6) < 10


def test_hcdc_cloud_cache_recovers_throughput():
    """The paper's headline: limited disk + cloud cache (III) keeps the job
    throughput of unlimited disk (I), while limited disk alone (II) loses
    throughput. Reduced scale: 2 days, 20k files."""
    results = {}
    for name in ("I", "II", "III"):
        cfg = make_config(name, simulated_time=2 * DAY,
                          n_files_per_site=20_000, seed=9)
        results[name] = HCDCScenario(cfg).run()
    jI, jII, jIII = (results[k]["jobs_done"] for k in ("I", "II", "III"))
    assert jIII >= 0.97 * jI
    assert jII <= jIII
    # cloud cache absorbed the reuse traffic
    assert results["III"]["gcs_used_pb"] > 0
    assert results["III"]["month1.storage_usd"] > 0


def test_train_driver_with_hcdc_store_runs():
    from repro.launch.train import train

    out = train("hymba_1_5b", steps=6, batch=2, seq=16, use_store=True,
                log_every=100)
    assert out["final_loss"] is not None and np.isfinite(out["final_loss"])
    stats = out["store_stats"]
    assert stats["archival_reads"] + stats["cold_hits"] + stats["hot_hits"] > 0


def test_planner_recommends_feasible_point():
    from repro.core.planner import recommend, sweep

    points = sweep([100.0], days=2, n_files=10_000, seed=1)
    rec = recommend(points, min_throughput_frac=0.9)
    assert rec in points
