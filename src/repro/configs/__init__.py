"""Architecture registry: ``get_config(arch_id)`` / ``--arch <id>``.

One module per assigned architecture with the exact published config, plus
``smoke_config()`` — a reduced same-family config for CPU tests.
"""

from __future__ import annotations

import importlib
from typing import Dict, List

from repro.models.config import ModelConfig

ARCHITECTURES: List[str] = [
    "arctic_480b",
    "olmoe_1b_7b",
    "falcon_mamba_7b",
    "command_r_35b",
    "qwen3_4b",
    "gemma3_27b",
    "mistral_large_123b",
    "hymba_1_5b",
    "phi_3_vision_4_2b",
    "seamless_m4t_large_v2",
]

_ALIAS = {a.replace("_", "-"): a for a in ARCHITECTURES}


def canonical(arch: str) -> str:
    a = arch.replace("-", "_").replace(".", "_")
    if a not in ARCHITECTURES:
        raise KeyError(f"unknown arch {arch!r}; known: {ARCHITECTURES}")
    return a


def get_config(arch: str) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{canonical(arch)}")
    return mod.CONFIG


def get_smoke_config(arch: str) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{canonical(arch)}")
    return mod.smoke_config()


def all_configs() -> Dict[str, ModelConfig]:
    return {a: get_config(a) for a in ARCHITECTURES}
