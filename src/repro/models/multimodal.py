"""Modality frontend STUBS (per assignment: backbone only).

``[vlm]`` (phi-3-vision) and ``[audio]`` (seamless-m4t) entries specify the
transformer backbone; the CLIP/speech frontends are stubs whose
*precomputed* patch/frame embeddings arrive via ``input_specs()``. These
helpers generate synthetic embeddings with the right shapes/dtypes for
smoke tests and document the contract.
"""

from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig


def synthetic_frontend(cfg: ModelConfig, key, batch: int) -> jnp.ndarray:
    """[B, frontend_tokens, frontend_dim] stand-in for CLIP patch embeddings."""
    assert cfg.frontend == "vision"
    return jax.random.normal(
        key, (batch, cfg.frontend_tokens, cfg.frontend_dim), dtype=jnp.float32
    ).astype(cfg.dtype)


def synthetic_frames(cfg: ModelConfig, key, batch: int, n_frames: int) -> jnp.ndarray:
    """[B, n_frames, frontend_dim] stand-in for speech-encoder frame features."""
    assert cfg.frontend == "audio"
    return jax.random.normal(
        key, (batch, n_frames, cfg.frontend_dim), dtype=jnp.float32
    ).astype(cfg.dtype)


def frontend_spec(cfg: ModelConfig, batch: int, n_tokens: int) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct((batch, n_tokens, cfg.frontend_dim), cfg.dtype)
