"""Failure detection + elastic rescale planning.

On real fleets this wraps the cluster manager; here it is the
deterministic control logic, unit-tested and driven by the training loop:

- ``FailureDetector``: heartbeat registry; a worker silent past
  ``timeout_s`` is declared failed. The training driver polls
  ``failed_workers()`` each step.
- ``ElasticPlanner``: given surviving device count, picks the largest
  feasible mesh (data axis shrinks first — TP size is fixed by the model's
  head/ffn divisibility), rescales the global batch or the microbatch
  count, and reports the re-lower spec. Restart resumes from the latest
  durable checkpoint step + the data pipeline position (both in the
  checkpoint manifest), so a failure costs at most one checkpoint
  interval.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List


class FailureDetector:
    def __init__(self, timeout_s: float = 60.0):
        self.timeout_s = timeout_s
        self._last: Dict[str, float] = {}
        self._failed: set = set()

    def heartbeat(self, worker: str, now: float) -> None:
        if worker not in self._failed:
            self._last[worker] = now

    def failed_workers(self, now: float) -> List[str]:
        for w, t in self._last.items():
            if now - t > self.timeout_s:
                self._failed.add(w)
        return sorted(self._failed)

    def healthy(self, now: float) -> List[str]:
        bad = set(self.failed_workers(now))
        return sorted(w for w in self._last if w not in bad)


@dataclass
class RescalePlan:
    data: int
    model: int
    pods: int
    global_batch: int
    microbatches: int
    note: str = ""

    @property
    def devices(self) -> int:
        return self.data * self.model * self.pods


class ElasticPlanner:
    """Choose a new mesh after failures (or scale-up)."""

    def __init__(self, model_tp: int = 16, chips_per_host: int = 4):
        self.model_tp = model_tp
        self.chips_per_host = chips_per_host

    def plan(self, surviving_chips: int, global_batch: int,
             pods: int = 1) -> RescalePlan:
        tp = self.model_tp
        per_pod = surviving_chips // pods
        data = max(1, per_pod // tp)
        # data axis must divide the global batch; shrink to the largest
        # power-of-two divisor if needed
        while data > 1 and global_batch % (data * pods):
            data -= 1
        micro = max(1, global_batch // (data * pods))
        return RescalePlan(
            data=data, model=tp, pods=pods, global_batch=global_batch,
            microbatches=micro,
            note=(f"rescaled to {pods}x{data}x{tp} from {surviving_chips} "
                  f"surviving chips"),
        )
