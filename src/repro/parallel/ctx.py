"""Activation-sharding context: anchors SPMD propagation inside models.

Model code is mesh-agnostic; step builders install a context
(``sharding_ctx``) and the model calls ``shard_batch(x)`` at layer
boundaries. Without these anchors XLA may choose contraction-parallel
layouts when FSDP shards a weight's contracting dim — replicating the
batch across the data axis (observed on arctic: 16x redundant attention).
With the anchor, the partitioner must keep activations batch-sharded and
therefore all-gathers weights per layer (true FSDP semantics).
"""

from __future__ import annotations

import threading
from contextlib import contextmanager
from typing import Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_TLS = threading.local()


def _axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


@contextmanager
def sharding_ctx(mesh: Optional[Mesh], **options):
    prev = getattr(_TLS, "mesh", None)
    prev_opt = getattr(_TLS, "options", {})
    _TLS.mesh = mesh
    _TLS.options = options
    try:
        yield
    finally:
        _TLS.mesh = prev
        _TLS.options = prev_opt


def current_mesh() -> Optional[Mesh]:
    return getattr(_TLS, "mesh", None)


def ctx_option(name: str, default=None):
    return getattr(_TLS, "options", {}).get(name, default)


def dp_shard_count() -> int:
    mesh = current_mesh()
    if mesh is None:
        return 1
    return int(np.prod([mesh.shape[a] for a in _axes(mesh)])) if _axes(mesh) else 1


def shard_batch(x):
    """Constrain dim 0 (batch/rows) of an activation to the dp axes."""
    mesh = current_mesh()
    if mesh is None or not hasattr(x, "shape") or x.ndim < 1:
        return x
    axes = _axes(mesh)
    if not axes:
        return x
    n = int(np.prod([mesh.shape[a] for a in axes]))
    if n <= 1 or x.shape[0] % n != 0:
        # try the in-pod data axis alone
        if "data" in axes and x.shape[0] % mesh.shape["data"] == 0 \
                and mesh.shape["data"] > 1:
            spec = P("data", *([None] * (x.ndim - 1)))
            return jax.lax.with_sharding_constraint(
                x, NamedSharding(mesh, spec))
        return x
    spec = P(axes, *([None] * (x.ndim - 1)))
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
