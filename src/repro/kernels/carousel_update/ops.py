"""Jitted wrappers for the carousel tick kernel.

``carousel_tick`` executes one transfer-manager tick under the
``tick_impl`` selection axis (``repro.kernels.registry``): ``"jnp"``
runs the jnp reference, ``"pallas"`` the compiled kernel,
``"pallas_interpret"`` the kernel in interpret mode, and ``"auto"``
resolves per host (compiled on an accelerator, jnp on CPU — never
silently interpret). The pre-registry ``use_pallas=``/``interpret=``
aliases are gone; a boolean in the ``tick_impl`` slot raises with the
upgrade hint.

``simulate_ticks`` scans the tick over many steps — the fully
vectorized tick engine (the accelerator-native equivalent of the
paper's transfer-manager loop) used by the throughput benchmark.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.carousel_update.carousel_update import carousel_tick_pallas
from repro.kernels.carousel_update.ref import carousel_tick_ref
from repro.kernels.registry import resolve_tick_impl


@functools.partial(jax.jit, static_argnames=("use_kernel", "interpret"))
def _carousel_tick(link_id, active, done, total, bw, mode, dt,
                   use_kernel: bool, interpret: bool):
    if use_kernel:
        return carousel_tick_pallas(link_id, active, done, total, bw, mode,
                                    dt, interpret=interpret)
    return carousel_tick_ref(link_id, active, done, total, bw, mode, dt)


def carousel_tick(link_id, active, done, total, bw, mode, dt,
                  tick_impl: str = "auto"):
    """One transfer-manager tick; implementation selected by ``tick_impl``
    (resolved outside the jitted core so ``"auto"`` probes the platform
    exactly once per call, never inside a trace)."""
    impl = resolve_tick_impl(tick_impl)
    return _carousel_tick(link_id, active, done, total, bw, mode, dt,
                          use_kernel=impl.use_kernel,
                          interpret=impl.interpret)


@functools.partial(jax.jit, static_argnames=("n_ticks",))
def simulate_ticks(link_id, active, done, total, bw, mode, dt, n_ticks: int):
    """Run n_ticks of the tick engine; transfers complete and deactivate."""

    def body(carry, _):
        act, dn = carry
        new_done, completed, _ = carousel_tick_ref(link_id, act, dn, total,
                                                   bw, mode, dt)
        act = jnp.logical_and(act, jnp.logical_not(completed))
        return (act, new_done), completed.sum()

    (act, dn), completions = jax.lax.scan(body, (active, done),
                                          None, length=n_ticks)
    return act, dn, completions
