"""Deterministic fault injection for the sweep execution layer.

Real distributed-cache deployments treat component failure as the steady
state; the execution layer that reproduces the paper's decision workflow
should be exercised the same way. This module provides a seed-driven
fault plan that the job layer (``repro.sim.jobs``) consults before every
job attempt: whether *this* attempt of *this* job crashes its worker,
hangs past its deadline, raises a transient exception, or reads corrupted
bytes from the persistent result cache is a pure function of
``(plan.seed, job_id, attempt)`` — no RNG state, no wall clock — so a
fault-injected run is exactly reproducible and a test can assert its
converged output bitwise against a fault-free run.

The plan reaches the execution layer through ``run_sweep(faults=...)``
(accepting a ``FaultPlan``, a spec string, or a dict) and, for CLI soak
runs, through the ``REPRO_FAULTS`` environment variable, e.g.::

    REPRO_FAULTS="seed=7,crash=0.2,hang=0.1,transient=0.3,hang_s=0.05"

Every executor honors the same plan: the serial and pool paths consult
it in-process, and the worker fleet (``repro.sim.runners``,
``docs/distributed.md``) ships the directive with each job frame so a
``crash`` kills the real subprocess and a ``hang`` trips the real
deadline reaper. See ``docs/resilience.md`` for the full injection
matrix.
"""

from __future__ import annotations

import hashlib
from dataclasses import dataclass, replace
from typing import Any, Dict, Optional, Sequence, Tuple

from repro.obs.metrics import get_registry


class TransientFault(RuntimeError):
    """Injected one-shot failure: the attempt raises, a retry succeeds."""


class WorkerCrash(RuntimeError):
    """Injected worker death (in-process executors raise this; pool
    workers ``os._exit`` so the parent sees ``BrokenProcessPool``)."""


class JobTimeout(RuntimeError):
    """A job attempt exceeded its wall-clock deadline and was reaped."""


def unit_hash(text: str) -> float:
    """Deterministic uniform draw in [0, 1) from a string.

    SHA-256 based, so it is stable across processes, platforms, and
    Python hash randomization — the property the bitwise-reproducibility
    guarantees of the fault plan and retry backoff rest on.
    """
    digest = hashlib.sha256(text.encode("utf-8")).digest()
    return int.from_bytes(digest[:8], "big") / 2.0 ** 64


_RATE_FIELDS = ("crash", "hang", "transient", "corrupt")


@dataclass(frozen=True)
class FaultPlan:
    """Seed-driven injection plan; immutable and hashable.

    Rates are independent per-attempt probabilities except that at most
    one of ``crash``/``hang``/``transient`` fires for a given attempt
    (one uniform draw partitioned across the three, in that order), so
    their sum must stay <= 1. ``corrupt`` applies to cache reads, not
    job attempts, and draws separately per cache entry.

    ``attempts`` gates injection to the first N attempts of each job
    (default 1): with a retry budget above N, every fault-injected job
    converges to its fault-free result — the property the end-to-end
    bitwise test relies on. ``only`` restricts injection to jobs whose
    id or labels contain the substring (``""`` = all jobs).
    """

    seed: int = 0
    crash: float = 0.0
    hang: float = 0.0
    transient: float = 0.0
    corrupt: float = 0.0
    #: inject only on the first N attempts of each job
    attempts: int = 1
    #: how long an injected hang sleeps (seconds) before the deadline
    #: machinery reaps it
    hang_s: float = 5.0
    #: substring filter on job id / labels; empty = every job
    only: str = ""

    def __post_init__(self) -> None:
        for name in _RATE_FIELDS:
            v = getattr(self, name)
            if not 0.0 <= v <= 1.0:
                raise ValueError(f"{name} must be in [0, 1], got {v!r}")
        if self.crash + self.hang + self.transient > 1.0 + 1e-9:
            raise ValueError("crash + hang + transient must be <= 1 "
                             "(one draw is partitioned across them)")
        if self.attempts < 1:
            raise ValueError(f"attempts must be >= 1, got {self.attempts!r}")
        if self.hang_s < 0:
            raise ValueError(f"hang_s must be >= 0, got {self.hang_s!r}")

    @property
    def active(self) -> bool:
        return any(getattr(self, name) > 0.0 for name in _RATE_FIELDS)

    def _selected(self, job_id: str, labels: Sequence[str]) -> bool:
        if not self.only:
            return True
        return self.only in job_id or any(self.only in lb for lb in labels)

    def directive(self, job_id: str, labels: Sequence[str],
                  attempt: int) -> Optional[Dict[str, Any]]:
        """The fault (if any) to inject into this attempt of this job.

        Returns ``None`` (no fault) or ``{"kind": "crash" | "hang" |
        "transient", ...}``; hang directives carry ``"seconds"``. One
        uniform draw per (job, attempt) is partitioned across the three
        rates, so the kinds are mutually exclusive and each fires with
        exactly its configured probability.
        """
        if attempt > self.attempts or not self._selected(job_id, labels):
            return None
        u = unit_hash(f"{self.seed}:{job_id}:{attempt}")
        if u < self.crash:
            return {"kind": "crash"}
        if u < self.crash + self.hang:
            return {"kind": "hang", "seconds": self.hang_s}
        if u < self.crash + self.hang + self.transient:
            return {"kind": "transient"}
        return None

    def corrupts(self, name: str, read_number: int) -> bool:
        """Whether the ``read_number``-th read of cache entry ``name``
        returns corrupted bytes. Only the first read of an entry can be
        corrupted: the cache treats corruption as a miss (delete +
        recompute + rewrite), so the refreshed entry must read back
        clean for the run to converge."""
        if read_number != 1 or not self._selected(name, ()):
            return False
        return unit_hash(f"{self.seed}:corrupt:{name}") < self.corrupt


def parse_faults(text: str) -> FaultPlan:
    """Parse the ``REPRO_FAULTS`` / ``--faults`` spec string.

    Comma-separated ``key=value`` pairs over the ``FaultPlan`` fields::

        "seed=7,crash=0.2,hang=0.1,transient=0.3,hang_s=0.05,only=lanes"
    """
    plan = FaultPlan()
    fields = {"seed": int, "attempts": int, "hang_s": float, "only": str}
    fields.update({name: float for name in _RATE_FIELDS})
    updates: Dict[str, Any] = {}
    for part in text.split(","):
        part = part.strip()
        if not part:
            continue
        if "=" not in part:
            raise ValueError(f"bad fault spec {part!r} (expected key=value)")
        key, _, value = part.partition("=")
        key = key.strip()
        if key not in fields:
            raise ValueError(f"unknown fault field {key!r} "
                             f"(expected one of {sorted(fields)})")
        updates[key] = fields[key](value.strip())
    return replace(plan, **updates)


def as_faults(faults: Any) -> Optional[FaultPlan]:
    """Coerce ``None`` / ``FaultPlan`` / spec string / dict to a plan."""
    if faults is None or isinstance(faults, FaultPlan):
        return faults
    if isinstance(faults, str):
        return parse_faults(faults)
    if isinstance(faults, dict):
        return FaultPlan(**faults)
    raise TypeError(f"cannot interpret {faults!r} as a FaultPlan")


def raise_local_fault(directive: Dict[str, Any], timeout_s: Optional[float],
                      sleep) -> None:
    """Act out a directive inside an in-process executor.

    ``crash`` and ``transient`` raise their exception types. ``hang``
    sleeps: if the hang outlasts the job's deadline the executor reaps
    it as a ``JobTimeout`` after sleeping the deadline out (we cannot
    preempt in-process work, so the deadline is simulated); a hang
    shorter than the deadline is just a slow attempt and returns
    normally.
    """
    kind = directive["kind"]
    if kind == "crash":
        raise WorkerCrash("injected worker crash")
    if kind == "transient":
        raise TransientFault("injected transient fault")
    if kind == "hang":
        seconds = float(directive["seconds"])
        budget = seconds if timeout_s is None else min(seconds, timeout_s)
        sleep(budget)
        if timeout_s is not None and seconds > timeout_s:
            raise JobTimeout(
                f"injected hang ({seconds:g}s) exceeded the "
                f"{timeout_s:g}s job deadline")
        return
    raise ValueError(f"unknown fault directive {directive!r}")


def perform_in_worker(directive: Optional[Dict[str, Any]]) -> None:
    """Act out a directive inside a pool worker process.

    ``crash`` kills the process outright (``os._exit``), which the
    parent observes as ``BrokenProcessPool`` — the real failure mode a
    dying worker produces. ``hang`` sleeps for its duration; the parent's
    deadline monitor reaps the job and recycles the pool if the sleep
    outlasts ``timeout_s``. ``transient`` raises and travels back
    through the future like any task exception.
    """
    if directive is None:
        return
    import os
    import time

    kind = directive["kind"]
    if kind == "crash":
        os._exit(23)
    elif kind == "hang":
        time.sleep(float(directive["seconds"]))
    elif kind == "transient":
        raise TransientFault("injected transient fault")
    else:
        raise ValueError(f"unknown fault directive {directive!r}")


class FaultyBackend:
    """``StorageBackend`` wrapper that corrupts reads per the plan.

    Exercises the result cache's corruption-as-miss path
    (``repro.sim.cache``): a corrupted entry is detected by the payload
    checksum, deleted, recomputed, and rewritten — only the *first* read
    of an entry is ever corrupted (see ``FaultPlan.corrupts``), so the
    refreshed entry reads back clean and the run converges. Writes and
    deletes pass through untouched.
    """

    def __init__(self, inner: Any, plan: FaultPlan):
        self.inner = inner
        self.plan = plan
        self._reads: Dict[str, int] = {}

    def read(self, name: str) -> Optional[bytes]:
        data = self.inner.read(name)
        if data is None:
            return None
        n = self._reads[name] = self._reads.get(name, 0) + 1
        if self.plan.corrupts(name, n):
            get_registry().inc("faults.injected", kind="corrupt",
                              help="Faults injected by the active plan")
            # Garble rather than truncate-to-empty so the payload still
            # parses far enough to reach the checksum comparison.
            half = len(data) // 2
            return data[:half] + bytes(reversed(data[half:]))
        return data

    def write(self, name: str, data: bytes) -> None:
        self.inner.write(name, data)

    def delete(self, name: str) -> None:
        self.inner.delete(name)


__all__: Tuple[str, ...] = (
    "FaultPlan", "FaultyBackend", "JobTimeout", "TransientFault",
    "WorkerCrash", "as_faults", "parse_faults", "perform_in_worker",
    "raise_local_fault", "unit_hash",
)
