"""Token pipeline: synthetic corpus -> sharded global batches.

``SyntheticCorpus`` generates deterministic token shards (seeded per shard
id, so any worker can regenerate any shard — convenient for elastic
rescale and restart). ``TokenPipeline`` composes the corpus with the HCDC
``TieredStore``: each global step consumes one shard through the carousel
prefetcher and yields a host-side numpy batch ready for device_put with
the batch sharding.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Iterator, List, Optional

import numpy as np

from repro.data.tiered_store import Shard, SlidingWindowPrefetcher, TieredStore


@dataclass
class SyntheticCorpus:
    vocab_size: int
    seq_len: int
    batch: int          # rows per shard (= global batch per step)
    n_shards: int = 1024

    def shard_sizes(self) -> List[Shard]:
        size = self.batch * (self.seq_len + 1) * 4  # int32 tokens
        return [Shard(sid, float(size)) for sid in range(self.n_shards)]

    def materialize(self, sid: int) -> Dict[str, np.ndarray]:
        rng = np.random.default_rng(sid)
        toks = rng.integers(0, self.vocab_size,
                            (self.batch, self.seq_len + 1), dtype=np.int32)
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}


class TokenPipeline:
    def __init__(self, corpus: SyntheticCorpus,
                 store: Optional[TieredStore] = None,
                 epochs: int = 1, seed: int = 0):
        self.corpus = corpus
        self.store = store
        rng = np.random.default_rng(seed)
        schedule: List[int] = []
        for _ in range(epochs):
            schedule.extend(rng.permutation(corpus.n_shards).tolist())
        self.schedule = schedule
        if store is not None:
            store.register(corpus.shard_sizes())
            self.prefetcher = SlidingWindowPrefetcher(store, schedule)
        else:
            self.prefetcher = None
        self._i = 0

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        return self

    def __next__(self) -> Dict[str, np.ndarray]:
        if self._i >= len(self.schedule):
            raise StopIteration
        if self.prefetcher is not None:
            sid, _wait = self.prefetcher.next_shard()
        else:
            sid = self.schedule[self._i]
        self._i += 1
        return self.corpus.materialize(sid)

    def state(self) -> Dict[str, int]:
        """Checkpointable position (restart resumes mid-epoch)."""
        return {"position": self._i}

    def restore(self, state: Dict[str, int]) -> None:
        self._i = int(state["position"])
        if self.prefetcher is not None:
            self.prefetcher.pos = self._i
