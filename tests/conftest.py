import os
import sys

# Tests see the single real CPU device (the dry-run sets its own 512-device
# flag in a subprocess); keep memory modest and deterministic.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
