"""GQA attention: training/prefill masked attention + KV-cache decode.

Variants handled by flags: qk-norm (qwen3), sliding-window masks
(gemma3 5:1 local:global, hymba local+3-global), attention bias, logit
softcap. Training/prefill uses a masked full-score reference path (clean
HLO for the dry-run roofline); the Pallas flash kernel in
``repro.kernels.flash_attention`` is the TPU hot-spot implementation and is
validated against this path. Decode attends one query position against a
length-S cache (optionally ring-buffered for local layers).
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.modules import apply_rope, dense_init, init_rms_norm, rms_norm

Params = Dict[str, jnp.ndarray]


def init_attention(key, cfg: ModelConfig, n_kv: Optional[int] = None) -> Params:
    d, hd = cfg.d_model, cfg.hd
    nh = cfg.n_heads
    nkv = n_kv if n_kv is not None else cfg.n_kv_heads
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], (d, nh, hd), in_axis_size=d, dtype=cfg.dtype),
        "wk": dense_init(ks[1], (d, nkv, hd), in_axis_size=d, dtype=cfg.dtype),
        "wv": dense_init(ks[2], (d, nkv, hd), in_axis_size=d, dtype=cfg.dtype),
        "wo": dense_init(ks[3], (nh, hd, d), in_axis_size=nh * hd, dtype=cfg.dtype),
    }
    if cfg.attn_bias:
        p["bq"] = jnp.zeros((nh, hd), dtype=cfg.dtype)
        p["bk"] = jnp.zeros((nkv, hd), dtype=cfg.dtype)
        p["bv"] = jnp.zeros((nkv, hd), dtype=cfg.dtype)
    if cfg.qk_norm:
        p["q_norm"] = init_rms_norm(hd)
        p["k_norm"] = init_rms_norm(hd)
    return p


def _project_qkv(p: Params, cfg: ModelConfig, x: jnp.ndarray,
                 positions: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    q = jnp.einsum("btd,dhk->bthk", x, p["wq"])
    k = jnp.einsum("btd,dhk->bthk", x, p["wk"])
    v = jnp.einsum("btd,dhk->bthk", x, p["wv"])
    if cfg.attn_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def _expand_kv(k: jnp.ndarray, n_heads: int) -> jnp.ndarray:
    """[B, S, nkv, hd] -> [B, S, nh, hd] by repeating each KV head."""
    nkv = k.shape[-2]
    if nkv == n_heads:
        return k
    return jnp.repeat(k, n_heads // nkv, axis=-2)


def causal_window_mask(q_pos: jnp.ndarray, k_pos: jnp.ndarray,
                       window: Optional[jnp.ndarray]) -> jnp.ndarray:
    """[Tq, Tk] bool; window None/0 => full causal, else i-j < window."""
    rel = q_pos[:, None] - k_pos[None, :]
    mask = rel >= 0
    if window is not None:
        mask &= rel < window
    return mask


# Sequences longer than this use the chunked online-softmax path so the
# [T, S] score matrix is never materialized (prefill_32k would need ~80 GB
# per device otherwise). Env-overridable: the §Perf iterations drop it to
# 2048 for archs whose (replicated-head) score tensors dominate memory.
import os as _os

CHUNKED_ATTN_THRESHOLD = int(_os.environ.get("REPRO_ATTN_CHUNK_THRESHOLD",
                                             "8192"))
ATTN_CHUNK = int(_os.environ.get("REPRO_ATTN_CHUNK", "1024"))


def _chunked_attention(q, k, v, positions, window, causal: bool):
    """Softmax over Q chunks; scores per chunk: [B, h, C, S'].

    When ``window`` is a STATIC int (Python-loop serving path for
    sliding-window archs), each Q chunk only slices the [chunk_start -
    window + 1, chunk_end] KV band — S' = C + window instead of the full
    sequence. For hymba's 29/32 local layers at 32k prefill that is a 16x
    score-bytes reduction (§Perf iteration it4_winslice)."""
    B, T, H, D = q.shape
    S = k.shape[1]
    C = min(ATTN_CHUNK, T)
    n_chunks = T // C
    qc = q.reshape(B, n_chunks, C, H, D).swapaxes(0, 1)  # [n, B, C, H, D]
    pc = positions[0].reshape(n_chunks, C)
    k_pos_full = positions[0]
    static_window = isinstance(window, int) and 0 < window < S

    def chunk(carry, inp):
        qb, pb = inp  # [B, C, H, D], [C]
        if static_window:
            span = C + window  # KV band covering this chunk's lookback
            start = jnp.clip(pb[0] - window + 1, 0, S - span)
            kb = jax.lax.dynamic_slice_in_dim(k, start, span, axis=1)
            vb = jax.lax.dynamic_slice_in_dim(v, start, span, axis=1)
            k_pos = jax.lax.dynamic_slice_in_dim(k_pos_full, start, span)
        else:
            kb, vb, k_pos = k, v, k_pos_full
        s = jnp.einsum("bchd,bshd->bhcs", qb.astype(jnp.float32),
                       kb.astype(jnp.float32))
        rel = pb[:, None] - k_pos[None, :]
        mask = jnp.ones(rel.shape, dtype=bool)
        if causal:
            mask &= rel >= 0
        if window is not None:
            mask &= rel < window
        s = jnp.where(mask[None, None], s, -1e30)
        w = jax.nn.softmax(s, axis=-1)
        ob = jnp.einsum("bhcs,bshd->bchd", w, vb.astype(jnp.float32))
        return carry, ob.astype(q.dtype)

    _, out = jax.lax.scan(chunk, None, (qc, pc))
    return out.swapaxes(0, 1).reshape(B, T, H, D)


def attention_core(p: Params, cfg: ModelConfig, q, k, v,
                   positions: jnp.ndarray,
                   window: Optional[jnp.ndarray] = None,
                   causal: bool = True) -> jnp.ndarray:
    """Attention from projected q/k/v ([B, T, h, hd]); returns [B, T, d]."""
    T = q.shape[1]
    k = _expand_kv(k, cfg.n_heads)
    v = _expand_kv(v, cfg.n_heads)
    scale = cfg.hd ** -0.5
    if T >= CHUNKED_ATTN_THRESHOLD and cfg.attn_logit_softcap is None:
        out = _chunked_attention(q * scale, k, v, positions, window, causal)
        return jnp.einsum("bthk,hkd->btd", out, p["wo"])
    scores = jnp.einsum("bthk,bshk->bhts", q, k) * scale
    if cfg.attn_logit_softcap:
        c = cfg.attn_logit_softcap
        scores = jnp.tanh(scores / c) * c
    if causal:
        mask = causal_window_mask(positions[0], positions[0], window)
        scores = jnp.where(mask[None, None], scores, jnp.finfo(jnp.float32).min)
    w = jax.nn.softmax(scores.astype(jnp.float32), axis=-1).astype(q.dtype)
    out = jnp.einsum("bhts,bshk->bthk", w, v)
    return jnp.einsum("bthk,hkd->btd", out, p["wo"])


def attention(p: Params, cfg: ModelConfig, x: jnp.ndarray,
              positions: jnp.ndarray,
              window: Optional[jnp.ndarray] = None,
              causal: bool = True) -> jnp.ndarray:
    """Training/prefill attention. x: [B, T, d]; window: scalar or None.

    ``window`` may be a traced scalar (scan-over-layers passes
    ``where(is_global, T, w)``), keeping heterogeneous local/global stacks in
    one homogeneous scan.
    """
    q, k, v = _project_qkv(p, cfg, x, positions)
    return attention_core(p, cfg, q, k, v, positions, window, causal)


# ----------------------------------------------------------------- decode
def init_kv_cache(cfg: ModelConfig, batch: int, length: int,
                  n_kv: Optional[int] = None) -> Dict[str, jnp.ndarray]:
    nkv = n_kv if n_kv is not None else cfg.n_kv_heads
    shape = (batch, length, nkv, cfg.hd)
    return {
        "k": jnp.zeros(shape, dtype=cfg.dtype),
        "v": jnp.zeros(shape, dtype=cfg.dtype),
    }


def decode_attention(p: Params, cfg: ModelConfig, x: jnp.ndarray,
                     cache: Dict[str, jnp.ndarray], t: jnp.ndarray,
                     window: Optional[jnp.ndarray] = None) -> Tuple[jnp.ndarray, Dict]:
    """One-token decode. x: [B, 1, d]; cache k/v: [B, S, nkv, hd]; t: current
    position (scalar int). Ring-buffer addressing: slot = t mod S (exact for
    local layers with S == window; for global layers S >= max positions)."""
    B, _, _ = x.shape
    S = cache["k"].shape[1]
    pos = jnp.full((B, 1), t, dtype=jnp.int32)
    q, k_new, v_new = _project_qkv(p, cfg, x, pos)
    slot = jnp.mod(t, S)
    k = jax.lax.dynamic_update_slice_in_dim(cache["k"], k_new, slot, axis=1)
    v = jax.lax.dynamic_update_slice_in_dim(cache["v"], v_new, slot, axis=1)
    new_cache = {"k": k, "v": v}
    kx = _expand_kv(k, cfg.n_heads)
    vx = _expand_kv(v, cfg.n_heads)
    scale = cfg.hd ** -0.5
    scores = jnp.einsum("bthk,bshk->bhts", q, kx) * scale  # [B, h, 1, S]
    if cfg.attn_logit_softcap:
        c = cfg.attn_logit_softcap
        scores = jnp.tanh(scores / c) * c
    # Valid slots: written positions within the causal window.
    s_idx = jnp.arange(S)
    # Position stored in slot s (ring): the latest p <= t with p mod S == s.
    stored_pos = t - jnp.mod(t - s_idx, S)
    valid = stored_pos >= 0
    if window is not None:
        valid &= (t - stored_pos) < window
    scores = jnp.where(valid[None, None, None], scores, jnp.finfo(jnp.float32).min)
    w = jax.nn.softmax(scores.astype(jnp.float32), axis=-1).astype(x.dtype)
    out = jnp.einsum("bhts,bshk->bthk", w, vx)
    return jnp.einsum("bthk,hkd->btd", out, p["wo"]), new_cache


# ------------------------------------------------------------ cross-attn
def init_cross_attention(key, cfg: ModelConfig) -> Params:
    d, hd, nh = cfg.d_model, cfg.hd, cfg.n_heads
    ks = jax.random.split(key, 4)
    return {
        "wq": dense_init(ks[0], (d, nh, hd), in_axis_size=d, dtype=cfg.dtype),
        "wk": dense_init(ks[1], (d, cfg.n_kv_heads, hd), in_axis_size=d, dtype=cfg.dtype),
        "wv": dense_init(ks[2], (d, cfg.n_kv_heads, hd), in_axis_size=d, dtype=cfg.dtype),
        "wo": dense_init(ks[3], (nh, hd, d), in_axis_size=nh * hd, dtype=cfg.dtype),
    }


def cross_attention(p: Params, cfg: ModelConfig, x: jnp.ndarray,
                    enc_kv: Tuple[jnp.ndarray, jnp.ndarray]) -> jnp.ndarray:
    """x: [B, T, d]; enc_kv: precomputed (k, v) [B, S, nkv, hd]."""
    k, v = enc_kv
    q = jnp.einsum("btd,dhk->bthk", x, p["wq"])
    kx = _expand_kv(k, cfg.n_heads)
    vx = _expand_kv(v, cfg.n_heads)
    scores = jnp.einsum("bthk,bshk->bhts", q, kx) * (cfg.hd ** -0.5)
    w = jax.nn.softmax(scores.astype(jnp.float32), axis=-1).astype(x.dtype)
    out = jnp.einsum("bhts,bshk->bthk", w, vx)
    return jnp.einsum("bthk,hkd->btd", out, p["wo"])


def encode_cross_kv(p: Params, cfg: ModelConfig,
                    enc_out: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    k = jnp.einsum("bsd,dhk->bshk", enc_out, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", enc_out, p["wv"])
    return k, v
