"""ISSUE 6: persistent content-addressed result cache + provenance store.

Correctness-first battery for ``repro.sim.cache``:

- key semantics: invariant under pricing-only field changes, distinct for
  every dynamics-affecting change, engine-fingerprinted, stable across
  process restarts (the hypothesis properties live in
  ``tests/test_property.py``);
- bit-exact round trips on both engines, including pricing variants
  re-billed from a shared dynamics entry;
- adversarial durability: truncated/zero-byte/garbage/wrong-schema-version
  entries fall back to recompute (never crash, never serve bad data) and
  the repaired entry is rewritten; concurrent same-key writers publish
  one valid entry;
- end-to-end warm-cache accounting through ``run_sweep(cache=...)``,
  ``SweepDriver(cache=...)``, ``decide()``, and the 216-config
  ``scripts/decide.py`` grid (``lanes_simulated == 0`` on re-run).
"""

from __future__ import annotations

import importlib.util
import json
import multiprocessing
import os
import subprocess
import sys

import pytest

from repro.core.scenarios import (
    RESULT_SCHEMA_VERSION,
    ScenarioSpec,
    cache_key,
    engine_fingerprint,
    expand_grid,
    with_axis,
    with_seeds,
)
from repro.sim.cache import (
    LocalDirBackend,
    ResultCache,
    as_cache,
    entry_name,
)
from repro.sim.decide import decide
from repro.sim.sweep import SweepDriver, run_scenario, run_sweep

#: Smallest spec that still exercises cache dynamics + billing.
TINY = dict(base="III", days=0.05, n_files=300, cache_tb=5.0)

#: Quick cross-backend parity grid (2 lanes x 2 pricing x 2 seeds).
QUICK_AXES = {"base": "III", "days": 0.1, "n_files": 1000,
              "cache_tb": [5.0, 20.0], "egress": ["internet", "direct"]}


@pytest.fixture(scope="module")
def tiny_result():
    """One freshly simulated (spec, result) pair, shared by the battery."""
    spec = ScenarioSpec(**TINY)
    return spec, run_scenario(spec)


def _entry_path(root, spec, backend="process", tick=None) -> str:
    return os.path.join(str(root),
                        entry_name(cache_key(spec, backend=backend,
                                             tick=tick)))


def _same_result(a, b) -> None:
    """Bitwise equality of everything a sweep consumer can observe."""
    assert a.spec == b.spec
    assert a.metrics == b.metrics
    assert (a.storage_usd, a.network_usd, a.ops_usd) == \
        (b.storage_usd, b.network_usd, b.ops_usd)
    assert a.events == b.events
    assert a.series == b.series
    assert a.monthly == b.monthly


# ------------------------------------------------------------ key semantics
def test_cache_key_invariant_under_pricing_fields():
    spec = ScenarioSpec(**TINY)
    for field, value in [("egress", "direct"), ("egress", "interconnect"),
                         ("storage_price", 0.020), ("egress_price", 0.01)]:
        assert cache_key(with_axis(spec, "cache_tb", 5.0)) == \
            cache_key(spec)  # identity sanity
        variant = ScenarioSpec(**{**TINY, field: value})
        assert cache_key(variant) == cache_key(spec), field
        assert cache_key(variant, "jax", 60.0) == \
            cache_key(spec, "jax", 60.0), field


def test_cache_key_distinct_for_every_dynamics_field():
    spec = ScenarioSpec(**TINY)
    base_key = cache_key(spec)
    for field, value in [("base", "I"), ("days", 0.1), ("n_files", 500),
                         ("seed", 1), ("cache_tb", 10.0),
                         ("gcs_limit_tb", 50.0), ("job_rate_scale", 2.0),
                         ("workload", "diurnal"), ("curves", True)]:
        variant = ScenarioSpec(**{**TINY, field: value})
        assert cache_key(variant) != base_key, field


def test_cache_key_fingerprints_the_engine():
    spec = ScenarioSpec(**TINY)
    keys = {cache_key(spec, "process"), cache_key(spec, "jax", 10.0),
            cache_key(spec, "jax", 60.0)}
    assert len(keys) == 3  # engines and tick steps never cross-serve
    # the process engine is tick-free; jax defaults to the 10 s tick
    assert cache_key(spec, "process", 60.0) == cache_key(spec, "process")
    assert cache_key(spec, "jax", None) == cache_key(spec, "jax", 10.0)
    assert engine_fingerprint("jax", 60.0) == "jax:60"
    with pytest.raises(ValueError):
        engine_fingerprint("cuda")


def test_engine_fingerprint_tick_impl_axis():
    """ISSUE 7: the kernel implementation is part of the engine identity.
    ``"jnp"`` (and the ``None`` default) keep the pre-registry fingerprint
    — the jnp program IS the legacy engine bit-for-bit, so existing
    entries stay warm — while the Pallas impls get their own suffix (XLA
    fuses the kernel trace differently: ulp-level divergence; and the
    blocked admission cumsum reassociates floats)."""
    assert engine_fingerprint("jax", 60.0, "jnp") == "jax:60"
    assert engine_fingerprint("jax", 60.0, None) == "jax:60"
    assert engine_fingerprint("jax", 60.0, "pallas") == "jax:60:pallas"
    assert engine_fingerprint("jax", 60.0, "pallas_interpret") == \
        "jax:60:pallas_interpret"
    assert engine_fingerprint("process") == "process"
    # "auto" must be resolved per host BEFORE keying: an auto-keyed entry
    # written on a CPU host would silently cross-serve on a TPU host
    with pytest.raises(ValueError, match="auto"):
        engine_fingerprint("jax", 60.0, "auto")


def test_cache_key_stable_under_tick_impl_axis():
    """Key-stability contract: adding the tick_impl axis moved no
    existing key (jnp/None), and resolved impls never collide."""
    spec = ScenarioSpec(**TINY)
    legacy = cache_key(spec, "jax", 60.0)
    assert cache_key(spec, "jax", 60.0, tick_impl="jnp") == legacy
    assert cache_key(spec, "jax", 60.0, tick_impl=None) == legacy
    keys = {legacy,
            cache_key(spec, "jax", 60.0, tick_impl="pallas"),
            cache_key(spec, "jax", 60.0, tick_impl="pallas_interpret"),
            cache_key(spec, "jax", 10.0, tick_impl="pallas")}
    assert len(keys) == 4
    assert cache_key(spec, "process") == \
        cache_key(spec, "process", tick_impl=None)


def test_tick_impl_entries_never_cross_serve(tmp_path):
    """A lane simulated by the Pallas kernels must not serve a jnp
    request (or vice versa) — the impls are only statistically equal."""
    spec = ScenarioSpec(**TINY)
    specs = [spec]
    fresh = run_sweep(specs, backend="jax", tick=60.0,
                      tick_impl="pallas_interpret")
    cache = ResultCache(tmp_path)
    assert cache.store(zip(specs, fresh.results), backend="jax", tick=60.0,
                       tick_impl="pallas_interpret") == 1
    assert cache.get(spec, backend="jax", tick=60.0) is None
    assert cache.get(spec, backend="jax", tick=60.0,
                     tick_impl="jnp") is None
    served = cache.get(spec, backend="jax", tick=60.0,
                       tick_impl="pallas_interpret")
    assert served is not None
    _same_result(served, fresh.results[0])
    # the manifest records which kernels produced the entry
    name = entry_name(cache_key(spec, "jax", 60.0,
                                tick_impl="pallas_interpret"))
    doc = json.loads(open(os.path.join(str(tmp_path), name)).read())
    assert doc["manifest"]["tick_impl"] == "pallas_interpret"
    assert doc["manifest"]["engine"] == "jax:60:pallas_interpret"


def test_sweep_cache_keys_by_resolved_impl(tmp_path):
    """``run_sweep(cache=...)`` resolves "auto" before keying, so a warm
    re-run with the explicit resolved name hits the same entries."""
    from repro.kernels.registry import resolve_tick_impl

    specs = with_seeds([ScenarioSpec(**TINY)], 2)
    cold = run_sweep(specs, backend="jax", tick=60.0, cache=str(tmp_path))
    assert cold.lanes_simulated == 2 and cold.cache_hits == 0
    resolved = resolve_tick_impl("auto").name
    warm = run_sweep(specs, backend="jax", tick=60.0, tick_impl=resolved,
                     cache=str(tmp_path))
    assert warm.lanes_simulated == 0 and warm.cache_hits == 2
    for a, b in zip(cold.results, warm.results):
        _same_result(a, b)
    # a different concrete impl is a cold start, not a cross-serve
    other = "pallas_interpret" if resolved != "pallas_interpret" else "jnp"
    cold2 = run_sweep(specs, backend="jax", tick=60.0, tick_impl=other,
                      cache=str(tmp_path))
    assert cold2.cache_hits == 0 and cold2.lanes_simulated == 2


def test_cache_key_stable_across_process_restart():
    """Keys are pure content hashes: a fresh interpreter (fresh PYTHONHASHSEED)
    derives the same key for the same spec."""
    spec = ScenarioSpec(**{**TINY, "seed": 3})
    code = ("from repro.core.scenarios import ScenarioSpec, cache_key; "
            f"print(cache_key(ScenarioSpec(base='III', days={TINY['days']}, "
            f"n_files={TINY['n_files']}, cache_tb={TINY['cache_tb']}, "
            "seed=3), backend='jax', tick=60.0))")
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    env["JAX_PLATFORMS"] = "cpu"
    env["PYTHONHASHSEED"] = "random"
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env=env, check=True, timeout=120)
    assert out.stdout.strip() == cache_key(spec, backend="jax", tick=60.0)


# ------------------------------------------------- round trips (bit-exact)
def test_roundtrip_is_bitwise_on_process_backend(tmp_path, tiny_result):
    spec, fresh = tiny_result
    cache = ResultCache(tmp_path)
    assert cache.put(spec, fresh)
    served = ResultCache(tmp_path).get(spec)  # fresh instance: disk only
    assert served is not None
    _same_result(served, fresh)
    assert served.wall_s == fresh.wall_s  # provenance carries the cost paid


def test_pricing_variant_served_from_shared_entry_is_bitwise(tmp_path,
                                                             tiny_result):
    spec, fresh = tiny_result
    cache = ResultCache(tmp_path)
    cache.put(spec, fresh)
    for field, value in [("egress", "direct"), ("egress_price", 0.01),
                         ("storage_price", 0.020)]:
        variant = ScenarioSpec(**{**TINY, field: value})
        served = cache.get(variant)
        assert served is not None, field  # same dynamics entry serves it
        _same_result(served, run_scenario(variant))
    assert cache.stats.writes == 1  # one lane entry served four ways


def test_roundtrip_is_bitwise_on_jax_backend(tmp_path):
    specs = with_seeds(expand_grid(QUICK_AXES), 2)
    fresh = run_sweep(specs, backend="jax", tick=60.0)
    cache = ResultCache(tmp_path)
    assert cache.store(zip(specs, fresh.results),
                       backend="jax", tick=60.0) == 4  # lanes, not configs
    for spec, r in zip(specs, fresh.results):
        _same_result(cache.get(spec, backend="jax", tick=60.0), r)


def test_engine_entries_never_cross_serve(tmp_path, tiny_result):
    spec, fresh = tiny_result
    cache = ResultCache(tmp_path)
    cache.put(spec, fresh, backend="process")
    assert cache.get(spec, backend="jax", tick=60.0) is None
    assert cache.get(spec, backend="jax", tick=10.0) is None
    assert cache.get(spec, backend="process") is not None


def test_synthetic_results_are_never_stored(tmp_path, tiny_result):
    """Results without raw monthly totals (hand-built, never simulated)
    cannot be re-billed and must not populate the store."""
    from repro.sim.sweep import ScenarioResult

    spec, _ = tiny_result
    fake = ScenarioResult(spec=spec, metrics={"jobs_done": 1.0},
                          storage_usd=0.0, network_usd=0.0, ops_usd=0.0,
                          wall_s=0.0, events=0)
    cache = ResultCache(tmp_path)
    assert not cache.put(spec, fake)
    assert cache.store([(spec, fake)]) == 0
    assert cache.get(spec) is None


def test_entry_manifest_records_provenance(tmp_path, tiny_result):
    spec, fresh = tiny_result
    ResultCache(tmp_path).put(spec, fresh)
    doc = json.loads(open(_entry_path(tmp_path, spec)).read())
    assert doc["schema_version"] == RESULT_SCHEMA_VERSION
    man = doc["manifest"]
    assert man["engine"] == "process"
    assert man["spec"]["egress"] == "internet"  # dynamics key, not variants
    assert man["spec"]["cache_tb"] == TINY["cache_tb"]
    for field in ("package_version", "python", "numpy", "host",
                  "created_unix", "wall_s"):
        assert field in man, field


# ------------------------------------------------------------- durability
def _truncate(path):
    data = open(path, "rb").read()
    open(path, "wb").write(data[:len(data) // 2])


def _zero(path):
    open(path, "wb").close()


def _garbage(path):
    open(path, "wb").write(b"\x00\xffnot json at all {{{")


def _wrong_version(path):
    doc = json.load(open(path))
    doc["schema_version"] = RESULT_SCHEMA_VERSION + 999
    json.dump(doc, open(path, "w"))


def _mangled_payload(path):
    doc = json.load(open(path))
    doc["payload"]["monthly"]["egress_bytes"] = doc["payload"]["monthly"][
        "egress_bytes"] + [1.0]  # array lengths disagree
    json.dump(doc, open(path, "w"))


@pytest.mark.parametrize("mangle", [_truncate, _zero, _garbage,
                                    _wrong_version, _mangled_payload],
                         ids=["truncated", "zero-byte", "garbage",
                              "wrong-schema-version", "mangled-payload"])
def test_corrupted_entry_falls_back_to_recompute(tmp_path, tiny_result,
                                                 mangle):
    spec, fresh = tiny_result
    ResultCache(tmp_path).put(spec, fresh)
    path = _entry_path(tmp_path, spec)
    mangle(path)
    cache = ResultCache(tmp_path)
    assert cache.get(spec) is None  # never crash, never serve bad data
    assert cache.stats.corrupt == 1 and cache.stats.hits == 0
    assert not os.path.exists(path)  # bad entry dropped...
    res = run_sweep([spec], workers=1, cache=cache)  # ...recompute repairs
    assert res.lanes_simulated == 1 and res.cache_hits == 0
    _same_result(res.results[0], fresh)
    assert os.path.exists(path)
    served = cache.get(spec)
    assert served is not None
    _same_result(served, fresh)


def _put_loop(cache_dir, spec, result, n):
    from repro.sim.cache import ResultCache

    cache = ResultCache(cache_dir)
    for _ in range(n):
        cache.put(spec, result)


def test_concurrent_writers_publish_one_valid_entry(tmp_path, tiny_result):
    """Two processes hammering the same key: every read along the way sees
    a complete entry (write-to-temp + atomic rename), and exactly one
    published file remains."""
    spec, fresh = tiny_result
    ctx = multiprocessing.get_context("spawn")
    procs = [ctx.Process(target=_put_loop,
                         args=(str(tmp_path), spec, fresh, 20))
             for _ in range(2)]
    for p in procs:
        p.start()
    for p in procs:
        p.join(timeout=180)
    assert all(p.exitcode == 0 for p in procs)
    names = sorted(LocalDirBackend(str(tmp_path)).names())
    assert names == [entry_name(cache_key(spec))]
    served = ResultCache(tmp_path).get(spec)
    assert served is not None
    _same_result(served, fresh)
    # no half-written temp files survive a clean run
    leftovers = [f for _, _, fs in os.walk(tmp_path) for f in fs
                 if ".tmp." in f]
    assert leftovers == []


# ----------------------------------------------- end-to-end warm accounting
def test_run_sweep_get_or_compute_accounting(tmp_path):
    specs = with_seeds([ScenarioSpec(**TINY)], 2)
    cold = run_sweep(specs, workers=1, cache=str(tmp_path))
    assert cold.lanes_simulated == 2 and cold.cache_hits == 0
    warm = run_sweep(specs, workers=1, cache=str(tmp_path))
    assert warm.lanes_simulated == 0 and warm.cache_hits == 2
    for a, b in zip(cold.results, warm.results):
        _same_result(a, b)
    # a never-requested pricing variant rides a stored dynamics lane
    priced = with_axis(specs[0], "egress_price", 0.01)
    res = run_sweep([priced], workers=1, cache=str(tmp_path))
    assert res.cache_hits == 1 and res.lanes_simulated == 0
    _same_result(res.results[0], run_scenario(priced))


@pytest.mark.parametrize("backend,tick", [("process", 10.0), ("jax", 60.0)])
def test_warm_driver_rerun_is_bitwise_and_simulates_nothing(tmp_path,
                                                            backend, tick):
    """The quick cross-backend parity grid twice through ``SweepDriver``
    with a tmpdir cache: the second (fresh) driver simulates zero lanes
    and reproduces the cold ``SweepResult`` bit-exactly."""
    specs = with_seeds(expand_grid(QUICK_AXES), 2)
    kw = dict(backend=backend, tick=tick, workers=1, cache=str(tmp_path))
    cold_drv = SweepDriver(**kw)
    cold = cold_drv.run(specs)
    assert cold_drv.lanes_simulated == 4  # 2 cache sizes x 2 seeds
    assert cold.cache_hits == 0
    warm_drv = SweepDriver(**kw)  # fresh driver: empty memo, disk only
    warm = warm_drv.run(specs)
    assert warm.lanes_simulated == 0
    assert warm.cache_hits == len(set(specs))
    assert warm_drv.configs_run == 0 and warm_drv.lanes_simulated == 0
    for a, b in zip(cold.results, warm.results):
        _same_result(a, b)


def test_driver_cache_serves_late_pricing_variants(tmp_path):
    """The in-memory memo re-simulates pricing variants that arrive in a
    later round (``pack_specs`` dedups within one call only); the
    persistent cache serves them from the stored lane instead."""
    specs = with_seeds([ScenarioSpec(**TINY)], 2)
    driver = SweepDriver(backend="process", workers=1, cache=str(tmp_path))
    driver.run(specs)
    assert driver.lanes_simulated == 2 and driver.configs_run == 2
    priced = with_axis(specs[0], "egress_price", 0.01)
    res = driver.run([priced])
    assert driver.lanes_simulated == 2  # no new lane simulated
    assert driver.configs_run == 2  # no new config simulated
    assert res.cache_hits == 1 and driver.cache_hits == 1
    _same_result(res.results[0], run_scenario(priced))


def test_warm_decide_workflow_simulates_zero_lanes(tmp_path):
    """A full ``decide()`` workflow re-run on a warm cache — refinement
    rounds, displaced-disk bisection, break-even pricing probes — answers
    everything from disk: the warm run's probe sequence is identical
    because every served result is bitwise identical."""
    axes = {"base": "III", "days": 0.05, "n_files": 300,
            "cache_tb": [5.0, 20.0], "egress": ["internet", "direct"]}
    kw = dict(backend="process", workers=1, cache=str(tmp_path))
    cold_drv = SweepDriver(**kw)
    cold = decide(axes, cold_drv, n_seeds=2, max_rounds=2)
    assert cold_drv.lanes_simulated > 0
    assert cold.stats["lanes_simulated"] == cold_drv.lanes_simulated
    warm_drv = SweepDriver(**kw)
    warm = decide(axes, warm_drv, n_seeds=2, max_rounds=2)
    assert warm_drv.lanes_simulated == 0 and warm_drv.configs_run == 0
    assert warm.stats["lanes_simulated"] == 0
    assert warm.stats["configs_run"] == 0
    assert warm.stats["cache_hits"] == warm_drv.cache_hits > 0
    assert warm.stats["cache"]["corrupt"] == 0
    cold_doc, warm_doc = cold.to_json_dict(), warm.to_json_dict()
    for section in ("baseline", "chosen", "frontier", "displaced_disk",
                    "break_even", "claim_holds"):
        assert warm_doc[section] == cold_doc[section], section


def _load_decide_cli():
    path = os.path.join(os.path.dirname(__file__), "..", "scripts",
                        "decide.py")
    spec = importlib.util.spec_from_file_location("decide_cli_cache", path)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def test_decide_cli_warm_rerun_serves_216_grid_from_cache(tmp_path):
    """ISSUE 6 acceptance: a warm re-run of the 216-config ``decide.py``
    grid simulates zero lanes and reproduces the cold decision report."""
    cli = _load_decide_cli()
    cache_dir = tmp_path / "cache"
    cold_out, warm_out = tmp_path / "cold.json", tmp_path / "warm.json"
    args = ["--days", "0.1", "--files", "1000", "--max-rounds", "2",
            "--quiet", "--cache-dir", str(cache_dir)]
    assert cli.main(args + ["--json", str(cold_out)]) == 0
    cold = json.loads(cold_out.read_text())
    n_grid = 4 * 3 * 9 * 2
    assert cold["stats"]["configs_run"] >= n_grid
    assert cold["stats"]["lanes_simulated"] > 0
    assert cli.main(args + ["--json", str(warm_out)]) == 0
    warm = json.loads(warm_out.read_text())
    assert warm["stats"]["lanes_simulated"] == 0
    assert warm["stats"]["configs_run"] == 0
    assert warm["stats"]["cache_hits"] >= n_grid
    for section in ("baseline", "chosen", "frontier", "displaced_disk",
                    "break_even", "claim_holds"):
        assert warm[section] == cold[section], section


def test_as_cache_coercions(tmp_path):
    cache = as_cache(str(tmp_path))
    assert isinstance(cache, ResultCache)
    assert as_cache(cache) is cache
    assert as_cache(None) is None
    assert isinstance(as_cache(LocalDirBackend(str(tmp_path))), ResultCache)
