"""Unified model: decoder LMs (dense/MoE/SSM/hybrid) and enc-dec backbones.

Training (`forward`/`loss_fn`) scans over layer-stacked params with
optional remat — one layer's HLO regardless of depth, so 88-layer models
lower/compile fast. Per-layer heterogeneity (gemma3's 5:1 local:global
windows, hymba's 3 global layers) rides along as a scanned int32 window
array, keeping the stack homogeneous.

Serving (`prefill`/`decode_step`) walks layers in a Python loop with
*per-layer* caches, so local-attention layers keep ring buffers of window
length while global layers keep full-length caches — the sub-quadratic
memory that makes `long_500k` feasible for SSM/hybrid/mostly-local archs.
"""

from __future__ import annotations

from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import attention as attn_mod
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models.config import ModelConfig
from repro.parallel.ctx import shard_batch
from repro.models.modules import (
    cross_entropy_loss,
    dense_init,
    init_embedding,
    init_mlp,
    init_rms_norm,
    rms_norm,
    swiglu,
)

Params = Dict[str, Any]

_FULL_WINDOW = jnp.iinfo(jnp.int32).max // 2  # "no window" sentinel


# --------------------------------------------------------------------- init
def _init_layer(key, cfg: ModelConfig, cross: bool = False) -> Params:
    ks = jax.random.split(key, 8)
    p: Params = {"norm1": init_rms_norm(cfg.d_model)}
    if cfg.has_attention:
        p["attn"] = attn_mod.init_attention(ks[0], cfg)
    if cfg.family in ("dense", "vlm", "audio"):
        p["mlp"] = init_mlp(ks[1], cfg.d_model, cfg.d_ff, cfg.dtype)
        p["norm2"] = init_rms_norm(cfg.d_model)
    elif cfg.family == "moe":
        p["moe"] = moe_mod.init_moe(ks[2], cfg)
        p["norm2"] = init_rms_norm(cfg.d_model)
    elif cfg.family == "ssm":
        p["ssm"] = ssm_mod.init_ssm(ks[3], cfg)
        del p["norm1"]
        p["norm1"] = init_rms_norm(cfg.d_model)
    elif cfg.family == "hybrid":
        p["ssm"] = ssm_mod.init_ssm(ks[3], cfg)
        p["mlp"] = init_mlp(ks[1], cfg.d_model, cfg.d_ff, cfg.dtype)
        p["norm2"] = init_rms_norm(cfg.d_model)
        p["norm_attn_out"] = init_rms_norm(cfg.d_model)
        p["norm_ssm_out"] = init_rms_norm(cfg.d_model)
    if cross:
        p["cross"] = attn_mod.init_cross_attention(ks[4], cfg)
        p["norm_cross"] = init_rms_norm(cfg.d_model)
    return p


def _init_encoder_layer(key, cfg: ModelConfig) -> Params:
    ks = jax.random.split(key, 2)
    return {
        "norm1": init_rms_norm(cfg.d_model),
        "attn": attn_mod.init_attention(ks[0], cfg),
        "mlp": init_mlp(ks[1], cfg.d_model, cfg.d_ff, cfg.dtype),
        "norm2": init_rms_norm(cfg.d_model),
    }


def init_params(cfg: ModelConfig, key) -> Params:
    k_emb, k_layers, k_out, k_enc, k_fe = jax.random.split(key, 5)
    layer_keys = jax.random.split(k_layers, cfg.n_layers)
    layers = jax.vmap(
        lambda k: _init_layer(k, cfg, cross=cfg.is_enc_dec)
    )(layer_keys)
    p: Params = {
        "embed": init_embedding(k_emb, cfg.vocab_size, cfg.d_model, cfg.dtype),
        "layers": layers,
        "final_norm": init_rms_norm(cfg.d_model),
    }
    if not cfg.tie_embeddings:
        p["lm_head"] = dense_init(k_out, (cfg.d_model, cfg.vocab_size),
                                  in_axis_size=cfg.d_model, dtype=cfg.dtype)
    if cfg.is_enc_dec:
        enc_keys = jax.random.split(k_enc, cfg.encoder_layers)
        p["encoder"] = {
            "layers": jax.vmap(lambda k: _init_encoder_layer(k, cfg))(enc_keys),
            "final_norm": init_rms_norm(cfg.d_model),
        }
    if cfg.frontend is not None:
        p["frontend_proj"] = dense_init(
            k_fe, (cfg.frontend_dim, cfg.d_model),
            in_axis_size=cfg.frontend_dim, dtype=cfg.dtype)
    return p


def layer_windows(cfg: ModelConfig, full: Optional[int] = None) -> jnp.ndarray:
    """Per-layer attention window (int32[L]); _FULL_WINDOW = global."""
    w = []
    for i in range(cfg.n_layers):
        if cfg.is_global_layer(i) or cfg.sliding_window is None:
            w.append(full if full is not None else _FULL_WINDOW)
        else:
            w.append(cfg.sliding_window)
    return jnp.asarray(w, dtype=jnp.int32)


# ------------------------------------------------------------------ forward
def _layer_apply(cfg: ModelConfig, lp: Params, x: jnp.ndarray,
                 positions: jnp.ndarray, window: jnp.ndarray,
                 enc_kv=None, shard_experts=None) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """One transformer block. Returns (x, aux_loss)."""
    aux = jnp.zeros((), dtype=jnp.float32)
    h = rms_norm(x, lp["norm1"], cfg.norm_eps)
    if cfg.family == "ssm":
        return x + ssm_mod.ssm_block(lp["ssm"], cfg, h), aux
    if cfg.family == "hybrid":
        a = attn_mod.attention(lp["attn"], cfg, h, positions, window=window)
        s = ssm_mod.ssm_block(lp["ssm"], cfg, h)
        mix = 0.5 * (rms_norm(a, lp["norm_attn_out"], cfg.norm_eps)
                     + rms_norm(s, lp["norm_ssm_out"], cfg.norm_eps))
        x = x + mix
    else:
        x = x + attn_mod.attention(lp["attn"], cfg, h, positions, window=window)
    if enc_kv is not None:
        hc = rms_norm(x, lp["norm_cross"], cfg.norm_eps)
        x = x + attn_mod.cross_attention(lp["cross"], cfg, hc, enc_kv)
    h2 = rms_norm(x, lp["norm2"], cfg.norm_eps)
    if cfg.family == "moe":
        mo, aux = moe_mod.moe_layer(lp["moe"], cfg, h2, shard_experts=shard_experts)
        x = x + mo
    else:
        x = x + swiglu(h2, lp["mlp"]["w_gate"], lp["mlp"]["w_up"], lp["mlp"]["w_down"])
    return x, aux


def _encode(cfg: ModelConfig, params: Params, enc_in: jnp.ndarray) -> jnp.ndarray:
    """Bidirectional encoder over [B, S, d] inputs (audio frontend stub)."""
    positions = jnp.broadcast_to(
        jnp.arange(enc_in.shape[1], dtype=jnp.int32), enc_in.shape[:2])

    def body(x, lp):
        h = rms_norm(x, lp["norm1"], cfg.norm_eps)
        x = x + attn_mod.attention(lp["attn"], cfg, h, positions, causal=False)
        h2 = rms_norm(x, lp["norm2"], cfg.norm_eps)
        x = x + swiglu(h2, lp["mlp"]["w_gate"], lp["mlp"]["w_up"], lp["mlp"]["w_down"])
        return shard_batch(x), None

    if cfg.remat:
        body = jax.checkpoint(body)
    x, _ = jax.lax.scan(body, enc_in, params["encoder"]["layers"])
    return rms_norm(x, params["encoder"]["final_norm"], cfg.norm_eps)


def embed_inputs(cfg: ModelConfig, params: Params, batch: Dict[str, jnp.ndarray]):
    """Token (+ stub-frontend) embedding. Returns (x, positions)."""
    tokens = batch["tokens"]
    x = params["embed"][tokens].astype(cfg.dtype)
    if cfg.frontend is not None and cfg.frontend != "audio" and "frontend" in batch:
        fe = jnp.einsum("bsf,fd->bsd", batch["frontend"].astype(cfg.dtype),
                        params["frontend_proj"])
        x = jnp.concatenate([fe, x], axis=1)
    positions = jnp.broadcast_to(
        jnp.arange(x.shape[1], dtype=jnp.int32), x.shape[:2])
    return shard_batch(x), positions


def forward(cfg: ModelConfig, params: Params, batch: Dict[str, jnp.ndarray],
            shard_experts=None) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (logits [B, T, V], aux_loss)."""
    x, positions = embed_inputs(cfg, params, batch)
    if cfg.is_enc_dec:
        enc_in = batch["enc_input"]
        if cfg.frontend == "audio":
            enc_in = jnp.einsum("bsf,fd->bsd", enc_in.astype(cfg.dtype),
                                params["frontend_proj"])
        enc_out = _encode(cfg, params, enc_in)
    windows = layer_windows(cfg)

    def body(x, scanned):
        lp, w = scanned
        ekv = None
        if cfg.is_enc_dec:
            ekv = attn_mod.encode_cross_kv(lp["cross"], cfg, enc_out)
        x, aux = _layer_apply(cfg, lp, x, positions, w, enc_kv=ekv,
                              shard_experts=shard_experts)
        return shard_batch(x), aux

    if cfg.remat:
        body = jax.checkpoint(body)
    x, auxes = jax.lax.scan(body, x, (params["layers"], windows))
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = jnp.einsum("btd,dv->btv", x, head.astype(cfg.dtype))
    return logits, jnp.sum(auxes)


def loss_fn(cfg: ModelConfig, params: Params, batch: Dict[str, jnp.ndarray],
            shard_experts=None) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    logits, aux = forward(cfg, params, batch, shard_experts=shard_experts)
    labels = batch["labels"]
    if logits.shape[1] != labels.shape[1]:  # stub frontend prefix: text tail only
        logits = logits[:, logits.shape[1] - labels.shape[1]:]
    mask = batch.get("mask")
    ce = cross_entropy_loss(logits, labels, mask)
    total = ce + cfg.router_aux_weight * aux
    return total, {"ce": ce, "aux": aux}


# ------------------------------------------------------------------ serving
def uniform_cache(cfg: ModelConfig) -> bool:
    """True when every layer's cache has the same shape — then serving
    scans over stacked layers (bounded liveness: one layer's weights are
    gathered at a time under FSDP, and the HLO stays depth-independent).
    Sliding-window archs (gemma3, hymba) keep per-layer ring buffers of
    different lengths and walk layers in a Python loop instead."""
    return cfg.sliding_window is None


def init_cache(cfg: ModelConfig, batch: int, max_len: int) -> Dict[str, Any]:
    """Caches: stacked [L, ...] for uniform archs; per-layer list with ring
    buffers for local-attention layers otherwise."""
    if uniform_cache(cfg):
        entry: Dict[str, Any] = {}
        L = cfg.n_layers

        def stack(tree):
            return jax.tree.map(
                lambda a: jnp.zeros((L,) + a.shape, a.dtype), tree)

        if cfg.has_attention:
            entry["kv"] = stack(attn_mod.init_kv_cache(cfg, batch, max_len))
        if cfg.has_ssm:
            entry["ssm"] = stack(ssm_mod.init_ssm_cache(cfg, batch))
        cache: Dict[str, Any] = {"layers": entry}
        if cfg.is_enc_dec:
            cache["cross_kv"] = None  # filled by prefill (stacked)
        return cache
    layers: List[Dict[str, Any]] = []
    for i in range(cfg.n_layers):
        entry = {}
        if cfg.has_attention:
            if cfg.is_global_layer(i) or cfg.sliding_window is None:
                s = max_len
            else:
                s = min(cfg.sliding_window, max_len)
            entry["kv"] = attn_mod.init_kv_cache(cfg, batch, s)
        if cfg.has_ssm:
            entry["ssm"] = ssm_mod.init_ssm_cache(cfg, batch)
        layers.append(entry)
    cache = {"layers": layers}
    if cfg.is_enc_dec:
        cache["cross_kv"] = None
    return cache


def _layer_slice(params: Params, i: int) -> Params:
    return jax.tree.map(lambda a: a[i], params["layers"])


def _prefill_layer(cfg: ModelConfig, lp: Params, x, positions, window,
                   entry: Dict[str, Any], enc_out,
                   loop_path: bool = False):
    """One FUSED layer of prefill: computes the block output and the cache
    entry in a single pass (q/k/v projected once, the SSM scan run once —
    §Perf iteration: the naive version recomputed every block via
    ``_layer_apply`` after capturing caches, doubling prefill compute and
    bytes). Returns (x_out, new_cache_entry, ekv)."""
    T = x.shape[1]
    new_entry: Dict[str, Any] = {}
    ekv = None
    if cfg.is_enc_dec:
        ekv = attn_mod.encode_cross_kv(lp["cross"], cfg, enc_out)
    h = rms_norm(x, lp["norm1"], cfg.norm_eps)
    attn_out = None
    if cfg.has_attention:
        q, k, v = attn_mod._project_qkv(lp["attn"], cfg, h, positions)
        S = entry["kv"]["k"].shape[1]
        if S >= T:
            new_entry["kv"] = {
                "k": jax.lax.dynamic_update_slice_in_dim(entry["kv"]["k"], k, 0, axis=1),
                "v": jax.lax.dynamic_update_slice_in_dim(entry["kv"]["v"], v, 0, axis=1),
            }
        else:  # ring buffer shorter than prompt: keep the tail
            tail_k, tail_v = k[:, T - S:], v[:, T - S:]
            roll = (T - S) % S  # align ring slots with position mod S
            idx = jnp.mod(jnp.arange(S) + roll, S)
            new_entry["kv"] = {
                "k": jnp.zeros_like(entry["kv"]["k"]).at[:, idx].set(tail_k),
                "v": jnp.zeros_like(entry["kv"]["v"]).at[:, idx].set(tail_v),
            }
        attn_out = attn_mod.attention_core(lp["attn"], cfg, q, k, v,
                                           positions, window)
    ssm_out = None
    if cfg.has_ssm:
        sp = lp["ssm"]
        xz = jnp.einsum("btd,de->bte", h, sp["in_proj"])
        u, z = jnp.split(xz, 2, axis=-1)
        u_act = jax.nn.silu(ssm_mod._causal_conv1d(u, sp["conv_w"],
                                                   sp["conv_b"]))
        dA, dBu, Cm = ssm_mod._ssm_inputs(sp, cfg, u_act)
        y, h_final = ssm_mod.ssm_scan_y(dA, dBu, Cm.astype(jnp.float32),
                                        force_chunk=loop_path)
        new_entry["ssm"] = {"h": h_final,
                            "conv": u[:, -(cfg.ssm_conv - 1):, :]}
        y = y + sp["D"] * u_act.astype(jnp.float32)
        y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype)
        ssm_out = jnp.einsum("btd,de->bte", y, sp["out_proj"])
    # combine per family (mirrors _layer_apply)
    if cfg.family == "ssm":
        return x + ssm_out, new_entry, ekv
    if cfg.family == "hybrid":
        mix = 0.5 * (rms_norm(attn_out, lp["norm_attn_out"], cfg.norm_eps)
                     + rms_norm(ssm_out, lp["norm_ssm_out"], cfg.norm_eps))
        x = x + mix
    else:
        x = x + attn_out
    if ekv is not None:
        hc = rms_norm(x, lp["norm_cross"], cfg.norm_eps)
        x = x + attn_mod.cross_attention(lp["cross"], cfg, hc, ekv)
    h2 = rms_norm(x, lp["norm2"], cfg.norm_eps)
    if cfg.family == "moe":
        mo, _ = moe_mod.moe_layer(lp["moe"], cfg, h2)
        x = x + mo
    else:
        x = x + swiglu(h2, lp["mlp"]["w_gate"], lp["mlp"]["w_up"],
                       lp["mlp"]["w_down"])
    return x, new_entry, ekv


def prefill(cfg: ModelConfig, params: Params, batch: Dict[str, jnp.ndarray],
            cache: Dict[str, Any]) -> Tuple[jnp.ndarray, Dict[str, Any]]:
    """Run the full prompt, filling caches. Returns (last-token logits, cache).

    Uniform-cache archs scan over stacked layers (bounded liveness + small
    HLO); sliding-window archs walk layers in a Python loop with per-layer
    ring buffers."""
    x, positions = embed_inputs(cfg, params, batch)
    enc_out = None
    if cfg.is_enc_dec:
        enc_in = batch["enc_input"]
        if cfg.frontend == "audio":
            enc_in = jnp.einsum("bsf,fd->bsd", enc_in.astype(cfg.dtype),
                                params["frontend_proj"])
        enc_out = _encode(cfg, params, enc_in)
    windows = layer_windows(cfg)
    stacked = not isinstance(cache["layers"], list)
    if stacked:
        def body(x, scanned):
            lp, w, entry = scanned
            x, new_entry, ekv = _prefill_layer(cfg, lp, x, positions, w,
                                               entry, enc_out)
            return shard_batch(x), (new_entry, ekv)

        x, (new_layers, ekvs) = jax.lax.scan(
            body, x, (params["layers"], windows, cache["layers"]))
        new_cache: Dict[str, Any] = {"layers": new_layers}
        if cfg.is_enc_dec:
            new_cache["cross_kv"] = ekvs
    else:
        new_list = []
        cross = [] if cfg.is_enc_dec else None
        for i in range(cfg.n_layers):
            lp = _layer_slice(params, i)
            # static window in the loop path: lets chunked attention slice
            # the KV band instead of masking full-width scores
            w_i = (None if (cfg.is_global_layer(i) or cfg.sliding_window is None)
                   else int(cfg.sliding_window))
            x, new_entry, ekv = _prefill_layer(cfg, lp, x, positions,
                                               w_i,
                                               cache["layers"][i], enc_out,
                                               loop_path=True)
            new_list.append(new_entry)
            if cross is not None:
                cross.append(ekv)
        new_cache = {"layers": new_list}
        if cfg.is_enc_dec:
            new_cache["cross_kv"] = cross
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = jnp.einsum("bd,dv->bv", x[:, -1], head.astype(cfg.dtype))
    return logits, new_cache


def _decode_layer(cfg: ModelConfig, lp: Params, x, entry: Dict[str, Any],
                  t, window, cross_kv=None):
    """One layer of single-token decode: returns (x_out, new_entry)."""
    new_entry: Dict[str, Any] = {}
    h = rms_norm(x, lp["norm1"], cfg.norm_eps)
    if cfg.family == "ssm":
        s_out, new_entry["ssm"] = ssm_mod.ssm_decode_step(
            lp["ssm"], cfg, h, entry["ssm"])
        return x + s_out, new_entry
    if cfg.family == "hybrid":
        a_out, new_entry["kv"] = attn_mod.decode_attention(
            lp["attn"], cfg, h, entry["kv"], t, window=window)
        s_out, new_entry["ssm"] = ssm_mod.ssm_decode_step(
            lp["ssm"], cfg, h, entry["ssm"])
        mix = 0.5 * (rms_norm(a_out, lp["norm_attn_out"], cfg.norm_eps)
                     + rms_norm(s_out, lp["norm_ssm_out"], cfg.norm_eps))
        x = x + mix
    else:
        a_out, new_entry["kv"] = attn_mod.decode_attention(
            lp["attn"], cfg, h, entry["kv"], t, window=window)
        x = x + a_out
    if cfg.is_enc_dec:
        hc = rms_norm(x, lp["norm_cross"], cfg.norm_eps)
        x = x + attn_mod.cross_attention(lp["cross"], cfg, hc, cross_kv)
    h2 = rms_norm(x, lp["norm2"], cfg.norm_eps)
    if cfg.family == "moe":
        mo, _ = moe_mod.moe_layer(lp["moe"], cfg, h2)
        x = x + mo
    else:
        x = x + swiglu(h2, lp["mlp"]["w_gate"], lp["mlp"]["w_up"],
                       lp["mlp"]["w_down"])
    return x, new_entry


def decode_step(cfg: ModelConfig, params: Params, tokens: jnp.ndarray,
                cache: Dict[str, Any], t: jnp.ndarray
                ) -> Tuple[jnp.ndarray, Dict[str, Any]]:
    """One decode step. tokens: [B, 1]; t: scalar current position."""
    x = params["embed"][tokens].astype(cfg.dtype)
    windows = layer_windows(cfg)
    stacked = not isinstance(cache["layers"], list)
    if stacked:
        cross = cache.get("cross_kv")

        def body(x, scanned):
            if cfg.is_enc_dec:
                lp, w, entry, ckv = scanned
            else:
                lp, w, entry = scanned
                ckv = None
            x, new_entry = _decode_layer(cfg, lp, x, entry, t, w,
                                         cross_kv=ckv)
            return x, new_entry

        xs = ((params["layers"], windows, cache["layers"], cross)
              if cfg.is_enc_dec else
              (params["layers"], windows, cache["layers"]))
        x, new_layers = jax.lax.scan(body, x, xs)
        new_cache: Dict[str, Any] = {"layers": new_layers}
        if cfg.is_enc_dec:
            new_cache["cross_kv"] = cross
    else:
        new_list: List[Dict[str, Any]] = []
        for i in range(cfg.n_layers):
            lp = _layer_slice(params, i)
            ckv = cache["cross_kv"][i] if cfg.is_enc_dec else None
            x, new_entry = _decode_layer(cfg, lp, x, cache["layers"][i], t,
                                         windows[i], cross_kv=ckv)
            new_list.append(new_entry)
        new_cache = {"layers": new_list}
        if cfg.is_enc_dec:
            new_cache["cross_kv"] = cache["cross_kv"]
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = jnp.einsum("btd,dv->btv", x, head.astype(cfg.dtype))
    return logits[:, -1], new_cache
