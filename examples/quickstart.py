"""Quickstart: the HCDC model in 60 seconds.

1. Runs the paper's three configurations at reduced scale and prints the
   headline result (cloud cold-tier cache recovers the job throughput that
   a disk limit destroys).
2. Runs the §6 decision tool: given a disk budget, should you buy cloud
   cache, and what does it cost?

    PYTHONPATH=src python examples/quickstart.py
"""

import sys

sys.path.insert(0, "src")

from repro.core.hcdc import HCDCScenario, make_config
from repro.core.planner import recommend, sweep
from repro.sim.engine import DAY

DAYS, FILES = 4, 40_000

print("=== HCDC configurations (paper Table 5, reduced scale) ===")
results = {}
for name, desc in [("I", "unlimited disk, no cloud"),
                   ("II", "100 TB disk, no cloud"),
                   ("III", "100 TB disk + cloud cold tier")]:
    cfg = make_config(name, simulated_time=DAYS * DAY,
                      n_files_per_site=FILES, seed=0)
    m = HCDCScenario(cfg).run()
    results[name] = m
    cost = sum(v for k, v in m.items() if k.endswith("_usd"))
    print(f"cfg {name:3s} ({desc:32s}): jobs={m['jobs_done']:7.0f} "
          f"downloads={m['download_pb']:6.3f} PB  disk_used="
          f"{m['Site-1.disk_used_pb'] + m['Site-2.disk_used_pb']:6.3f} PB  "
          f"cloud_cost=${cost:,.0f}")

jI, jII, jIII = (results[k]["jobs_done"] for k in ("I", "II", "III"))
print(f"\nheadline: disk limit costs {100 * (1 - jII / jI):.1f}% of job "
      f"throughput; adding the cloud cold tier recovers it to "
      f"{100 * jIII / jI:.1f}% of baseline.")

print("\n=== decision tool (paper §6): disk-limit sweep ===")
points = sweep([50.0, 100.0], days=2, n_files=20_000, seed=1)
for p in points:
    lim = "inf" if p.disk_limit_tb == float("inf") else f"{p.disk_limit_tb:.0f}TB"
    print(f"disk={lim:6s} jobs={p.jobs_done:7.0f} disk_used={p.disk_used_pb:6.3f} PB "
          f"cloud=${p.cloud_cost_usd:,.0f}")
rec = recommend(points, min_throughput_frac=0.95)
lim = "inf" if rec.disk_limit_tb == float("inf") else f"{rec.disk_limit_tb:.0f}TB"
print(f"recommended: disk={lim} (>=95% of baseline throughput at minimal "
      f"disk + cloud cost)")
