"""Shared NN building blocks (functional, pytree params)."""

from __future__ import annotations

from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

Params = Dict[str, jnp.ndarray]

# Logical axis names used for sharding annotations (see repro.parallel).
# Weight leaves carry a `.sharding_spec` side table keyed by param path.


def rms_norm(x: jnp.ndarray, scale: jnp.ndarray, eps: float) -> jnp.ndarray:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return ((x * jax.lax.rsqrt(var + eps)) * (1.0 + scale.astype(jnp.float32))).astype(dt)


def init_rms_norm(d: int) -> jnp.ndarray:
    return jnp.zeros((d,), dtype=jnp.float32)


def dense_init(key, shape, in_axis_size: Optional[int] = None, dtype=jnp.bfloat16):
    fan_in = in_axis_size if in_axis_size is not None else shape[0]
    std = 1.0 / np.sqrt(fan_in)
    return (jax.random.normal(key, shape, dtype=jnp.float32) * std).astype(dtype)


def rope_frequencies(hd: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, hd, 2, dtype=jnp.float32) / hd))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: [..., T, H, hd]; positions: [..., T] int32."""
    hd = x.shape[-1]
    freqs = rope_frequencies(hd, theta)  # [hd/2]
    angles = positions[..., :, None, None].astype(jnp.float32) * freqs  # [..., T, 1, hd/2]
    sin, cos = jnp.sin(angles), jnp.cos(angles)
    x1, x2 = x[..., : hd // 2], x[..., hd // 2:]
    rotated = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return rotated.astype(x.dtype)


def swiglu(x: jnp.ndarray, w_gate: jnp.ndarray, w_up: jnp.ndarray,
           w_down: jnp.ndarray) -> jnp.ndarray:
    g = jnp.einsum("...d,df->...f", x, w_gate)
    u = jnp.einsum("...d,df->...f", x, w_up)
    h = jax.nn.silu(g) * u
    return jnp.einsum("...f,fd->...d", h, w_down)


def init_mlp(key, d: int, d_ff: int, dtype) -> Params:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w_gate": dense_init(k1, (d, d_ff), dtype=dtype),
        "w_up": dense_init(k2, (d, d_ff), dtype=dtype),
        "w_down": dense_init(k3, (d_ff, d), in_axis_size=d_ff, dtype=dtype),
    }


def init_embedding(key, vocab: int, d: int, dtype) -> jnp.ndarray:
    return (jax.random.normal(key, (vocab, d), dtype=jnp.float32)).astype(dtype)


def cross_entropy_loss(logits: jnp.ndarray, labels: jnp.ndarray,
                       mask: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """logits: [B, T, V] (possibly vocab-sharded under SPMD); labels: [B, T]."""
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = logz - gold
    if mask is not None:
        nll = nll * mask
        return jnp.sum(nll) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(nll)
