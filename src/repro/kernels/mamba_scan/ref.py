"""Pure-jnp oracle: associative scan over time (same math as
``repro.models.ssm.ssm_scan_ref``, reduced to y output)."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def mamba_scan_ref(dA, dBu, C):
    """dA, dBu: [B, T, D, N]; C: [B, T, N] -> y [B, T, D] f32."""

    def combine(a, b):
        a_d, a_h = a
        b_d, b_h = b
        return a_d * b_d, b_d * a_h + b_h

    _, h = jax.lax.associative_scan(combine, (dA, dBu), axis=1)
    return jnp.einsum("btdn,btn->btd", h, C)
