"""Pure-jnp oracle for the carousel tick (matches the paper's tick math
and the scalar update of ``repro.sim.transfer.BandwidthTransferManager``)."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def carousel_tick_ref(link_id, active, done, total, bw, mode, dt):
    """Same contract as carousel_tick_pallas."""
    m = bw.shape[0]
    act = active.astype(jnp.float32)
    counts = jax.ops.segment_sum(act, link_id, num_segments=m)
    bw_i = bw[link_id]
    mode_i = mode[link_id]
    counts_i = counts[link_id]
    shared = bw_i / jnp.maximum(counts_i, 1.0)
    rate = jnp.where(mode_i > 0, bw_i, shared)
    new_done = jnp.minimum(total, done + act * rate * dt)
    completed = jnp.logical_and(new_done >= total, active)
    return new_done, completed, counts
