"""Direct coverage for ``repro.sim.output`` (ISSUE 8 satellite).

These collectors were previously exercised only through
``core/hcdc.py``; the batched backend's series capture
(``repro.sim.batched.series_from_capture``) now also emits
``TimeSeries``, so the schema's edge cases get their own tests.
"""

import numpy as np
import pytest

from repro.sim.output import (
    Histogram,
    OutputCollector,
    TimeSeries,
    mean_and_error,
)


class TestTimeSeries:
    def test_record_preserves_insertion_order(self):
        ts = TimeSeries("disk_used")
        for t, v in [(0, 1.0), (3600, 2.5), (7200, 2.0)]:
            ts.record(t, v)
        assert ts.times == [0, 3600, 7200]
        assert ts.values == [1.0, 2.5, 2.0]

    def test_to_arrays_round_trip(self):
        ts = TimeSeries("x", times=[1, 2, 3], values=[9.0, 8.0, 7.0])
        t, v = ts.to_arrays()
        np.testing.assert_array_equal(t, [1, 2, 3])
        np.testing.assert_array_equal(v, [9.0, 8.0, 7.0])

    def test_summary_digest(self):
        ts = TimeSeries("x", times=[0, 1, 2, 3],
                        values=[4.0, 1.0, 3.0, 2.0])
        s = ts.summary()
        assert s == {"n": 4.0, "min": 1.0, "mean": 2.5, "max": 4.0,
                     "last": 2.0}

    def test_summary_last_is_positional_not_extremal(self):
        # 'last' must be the final recorded value, whatever its rank.
        ts = TimeSeries("x", times=[0, 1], values=[100.0, -5.0])
        assert ts.summary()["last"] == -5.0

    def test_empty_series_summary_is_zeros(self):
        s = TimeSeries("empty").summary()
        assert s == {"n": 0.0, "min": 0.0, "mean": 0.0, "max": 0.0,
                     "last": 0.0}

    def test_empty_series_to_arrays(self):
        t, v = TimeSeries("empty").to_arrays()
        assert t.size == 0 and v.size == 0


class TestHistogram:
    def test_counts_and_bins(self):
        h = Histogram("wait")
        for x in [0.0, 0.5, 1.0, 1.5, 2.0]:
            h.record(x)
        counts, edges = h.counts(bins=4)
        assert counts.sum() == 5
        assert len(edges) == 5
        assert edges[0] == 0.0 and edges[-1] == 2.0

    def test_mean(self):
        h = Histogram("wait")
        for x in [1.0, 2.0, 6.0]:
            h.record(x)
        assert h.mean == pytest.approx(3.0)

    def test_empty_mean_is_zero(self):
        assert Histogram("empty").mean == 0.0


class TestOutputCollector:
    def test_ts_and_hist_are_memoized_per_name(self):
        out = OutputCollector()
        assert out.ts("a") is out.ts("a")
        assert out.hist("h") is out.hist("h")
        out.ts("a").record(0, 1.0)
        assert out.series["a"].values == [1.0]

    def test_count_accumulates(self):
        out = OutputCollector()
        out.count("jobs")
        out.count("jobs", 2.0)
        assert out.counters["jobs"] == 3.0

    def test_summary_folds_hists(self):
        out = OutputCollector()
        out.count("jobs", 5.0)
        out.hist("wait").record(2.0)
        out.hist("wait").record(4.0)
        s = out.summary()
        assert s["jobs"] == 5.0
        assert s["wait.mean"] == pytest.approx(3.0)
        assert s["wait.n"] == 2.0


def test_mean_and_error_single_run_has_no_spread():
    m, sd, se = mean_and_error([7.0])
    assert (m, sd, se) == (7.0, 0.0, 0.0)


def test_mean_and_error_percentages():
    m, sd_pct, se_pct = mean_and_error([9.0, 11.0])
    assert m == pytest.approx(10.0)
    sd = np.std([9.0, 11.0], ddof=1)
    assert sd_pct == pytest.approx(100.0 * sd / 10.0)
    assert se_pct == pytest.approx(100.0 * sd / np.sqrt(2) / 10.0)


# ------------------------------------------------ atomic exports (ISSUE 9)
class TestAtomicWriteText:
    def test_writes_and_replaces(self, tmp_path):
        from repro.sim.output import atomic_write_text

        path = tmp_path / "sub" / "table.csv"  # parent dir auto-created
        atomic_write_text(str(path), "v1")
        assert path.read_text() == "v1"
        atomic_write_text(str(path), "v2")
        assert path.read_text() == "v2"
        assert list(tmp_path.rglob("*.tmp.*")) == []

    def test_failed_commit_leaves_original_and_no_orphans(self, tmp_path,
                                                          monkeypatch):
        """A crash between tmp-write and commit must never publish a torn
        file: the original survives byte-for-byte and the tmp file is
        cleaned up."""
        from repro.sim import output as out_mod

        path = tmp_path / "table.csv"
        out_mod.atomic_write_text(str(path), "original")

        def exploding_replace(src, dst):
            raise OSError("disk gone")

        monkeypatch.setattr(out_mod.os, "replace", exploding_replace)
        with pytest.raises(OSError, match="disk gone"):
            out_mod.atomic_write_text(str(path), "replacement")
        assert path.read_text() == "original"
        assert [p.name for p in tmp_path.iterdir()] == ["table.csv"]

    def test_write_csv_commits_atomically(self, tmp_path, monkeypatch):
        """``write_csv`` (and through it every sweep export) rides the
        same tmp+replace commit."""
        from repro.sim import output as out_mod

        path = tmp_path / "rows.csv"
        out_mod.write_csv(str(path), [{"a": 1, "b": 2}])
        first = path.read_text()
        assert first.splitlines()[0] == "a,b"

        monkeypatch.setattr(out_mod.os, "replace",
                            lambda s, d: (_ for _ in ()).throw(OSError("no")))
        with pytest.raises(OSError):
            out_mod.write_csv(str(path), [{"a": 9, "b": 9}])
        assert path.read_text() == first
