"""Sharding-rule unit tests (pure logic — duck-typed mesh, no devices)."""

from jax.sharding import PartitionSpec as P

from repro.parallel.sharding import ParallelPlan, spec_for_param


class FakeMesh:
    """Duck-typed stand-in: spec_for_param only reads shape/axis_names."""

    def __init__(self, shape):
        self.shape = dict(shape)
        self.axis_names = tuple(shape)
        self.size = 1
        for v in shape.values():
            self.size *= v


MESH1 = FakeMesh({"data": 16, "model": 16})
MESH2 = FakeMesh({"pod": 2, "data": 16, "model": 16})


def test_embed_vocab_parallel():
    spec = spec_for_param("embed", (151936, 2560), MESH1, ParallelPlan())
    assert spec == P("model", None)


def test_attention_heads_tp_when_divisible():
    spec = spec_for_param("layers/attn/wq", (36, 2560, 32, 128), MESH1,
                          ParallelPlan())
    assert spec == P(None, None, "model", None)


def test_attention_heads_replicated_when_not_divisible():
    # arctic: 56 heads, model=16 -> replicate head dim
    spec = spec_for_param("layers/attn/wq", (35, 7168, 56, 128), MESH1,
                          ParallelPlan())
    assert spec == P(None, None, None, None)


def test_fsdp_adds_data_axis():
    plan = ParallelPlan(fsdp=True)
    spec = spec_for_param("layers/attn/wq", (35, 7168, 56, 128), MESH1, plan)
    assert spec == P(None, ("data",), None, None)
    spec2 = spec_for_param("layers/attn/wq", (35, 7168, 56, 128), MESH2, plan)
    assert spec2 == P(None, ("pod", "data"), None, None)


def test_moe_experts_ep_sharded():
    spec = spec_for_param("layers/moe/w_gate", (35, 128, 7168, 4864), MESH1,
                          ParallelPlan())
    assert spec == P(None, "model", None, None)


def test_moe_ffn_tp_fallback_when_experts_not_divisible():
    # 12 experts % 16 != 0 -> model axis falls through to the ffn dim
    spec = spec_for_param("layers/moe/w_gate", (4, 12, 256, 512), MESH1,
                          ParallelPlan())
    assert spec == P(None, None, None, "model")


def test_ssm_d_inner_tp():
    spec = spec_for_param("layers/ssm/out_proj", (64, 8192, 4096), MESH1,
                          ParallelPlan())
    assert spec == P(None, "model", None)


def test_norms_replicated():
    for path in ("layers/norm1", "final_norm", "layers/norm_attn_out"):
        spec = spec_for_param(path, (64, 4096), MESH1, ParallelPlan())
        assert spec == P(*([None] * 2)) or spec == P(None, None)


def test_axis_used_once():
    # mlp w_down [f, d] with fsdp: tp on f, data on d — never the same axis
    plan = ParallelPlan(fsdp=True)
    spec = spec_for_param("layers/mlp/w_down", (36, 9728, 2560), MESH1, plan)
    assert spec == P(None, "model", ("data",))


def test_opt_state_paths_match_param_rules():
    spec = spec_for_param("m/layers/mlp/w_gate", (36, 2560, 9728), MESH1,
                          ParallelPlan(fsdp=True))
    assert spec == P(None, ("data",), "model")
