"""Mamba-1 selective SSM block (falcon-mamba; hymba's SSM heads).

Structure: in_proj -> (x, z); causal depthwise conv1d + silu on x;
x -> (dt_low, B, C); dt = softplus(dt_proj(dt_low)); A = -exp(A_log);
recurrence h_t = exp(dt_t A) h_{t-1} + dt_t B_t x_t; y_t = C_t . h_t + D x_t;
out = (y * silu(z)) @ out_proj.

Training path uses ``jax.lax.associative_scan`` over time (the
reference/dry-run path); ``repro.kernels.mamba_scan`` is the chunked
two-phase Pallas kernel validated against it. Decode keeps h as explicit
state ([B, d_inner, N]) and applies one recurrence step.

TP note: every op is elementwise or diagonal over d_inner, so d_inner is
the tensor-parallel axis (in_proj column-parallel, out_proj row-parallel).
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.modules import dense_init

Params = Dict[str, jnp.ndarray]


def init_ssm(key, cfg: ModelConfig) -> Params:
    d, di, n, dtr, kc = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.dtr, cfg.ssm_conv
    ks = jax.random.split(key, 6)
    # S4D-real initialisation for A: A[d, n] = -(1..n)
    a = jnp.tile(jnp.arange(1, n + 1, dtype=jnp.float32)[None, :], (di, 1))
    return {
        "in_proj": dense_init(ks[0], (d, 2 * di), in_axis_size=d, dtype=cfg.dtype),
        "conv_w": dense_init(ks[1], (kc, di), in_axis_size=kc, dtype=cfg.dtype),
        "conv_b": jnp.zeros((di,), dtype=cfg.dtype),
        "x_proj": dense_init(ks[2], (di, dtr + 2 * n), in_axis_size=di, dtype=cfg.dtype),
        "dt_proj_w": dense_init(ks[3], (dtr, di), in_axis_size=dtr, dtype=cfg.dtype),
        "dt_proj_b": jnp.full((di,), -4.6, dtype=jnp.float32),  # softplus ~= 0.01
        "A_log": jnp.log(a),
        "D": jnp.ones((di,), dtype=jnp.float32),
        "out_proj": dense_init(ks[4], (di, d), in_axis_size=di, dtype=cfg.dtype),
    }


def _causal_conv1d(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Depthwise causal conv. x: [B, T, di]; w: [K, di]."""
    K = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    out = jnp.zeros_like(x)
    for k in range(K):  # K is tiny (4): unrolled taps
        out = out + xp[:, k: k + x.shape[1], :] * w[k]
    return out + b


def _ssm_inputs(p: Params, cfg: ModelConfig, u: jnp.ndarray):
    """u: [B, T, di] (post conv+silu). Returns dA [B,T,di,N] decay, dBu, C."""
    n = cfg.ssm_state
    dbc = jnp.einsum("btd,dk->btk", u, p["x_proj"])
    dt_low, Bm, Cm = jnp.split(dbc, [cfg.dtr, cfg.dtr + n], axis=-1)
    dt = jax.nn.softplus(
        jnp.einsum("btr,rd->btd", dt_low, p["dt_proj_w"]).astype(jnp.float32)
        + p["dt_proj_b"]
    )  # [B, T, di] f32
    A = -jnp.exp(p["A_log"])  # [di, N] f32
    dA = jnp.exp(dt[..., None] * A)  # [B, T, di, N]
    dBu = (dt * u.astype(jnp.float32))[..., None] * Bm.astype(jnp.float32)[..., None, :]
    return dA, dBu, Cm


def ssm_scan_ref(dA: jnp.ndarray, dBu: jnp.ndarray) -> jnp.ndarray:
    """Associative scan over T of h_t = dA_t * h_{t-1} + dBu_t."""

    def combine(a, b):
        a_d, a_h = a
        b_d, b_h = b
        return a_d * b_d, b_d * a_h + b_h

    _, h = jax.lax.associative_scan(combine, (dA, dBu), axis=1)
    return h  # [B, T, di, N]


# Long sequences never materialize [B, T, di, N]: the scan runs in time
# chunks carrying only [B, di, N] (the jnp mirror of the Pallas kernel's
# chunked two-phase structure). 32k-prefill peak drops ~T/CHUNK-fold.
# Threshold 8192: at 4k-train the plain associative scan is cheaper
# (§Perf: chunking falcon train_4k regressed memory bytes 2x — refuted).
SSM_CHUNK_THRESHOLD = 8192
SSM_CHUNK = 1024


def ssm_scan_y(dA: jnp.ndarray, dBu: jnp.ndarray, Cm: jnp.ndarray,
               h0: Optional[jnp.ndarray] = None,
               force_chunk: bool = False):
    """Returns (y [B, T, di], h_final [B, di, N]); chunked for long T.

    Chunking only pays when the caller's per-layer liveness is unbounded
    (the Python-loop serving path: hymba/gemma heterogeneous stacks) —
    under scan-over-layers the plain associative scan costs fewer bytes
    (§Perf: chunked falcon prefill regressed the bytes proxy 6x, refuted).
    The inter-chunk carry folds into the chunk's first step
    (dBu'_0 = dBu_0 + dA_0*h) — so no cumprod tensor is ever built."""
    B, T, di, N = dA.shape
    if (not force_chunk) or T < 2 * SSM_CHUNK or T % SSM_CHUNK != 0:
        if h0 is not None:
            dBu = dBu.at[:, 0].add(dA[:, 0] * h0)
        h = ssm_scan_ref(dA, dBu)
        y = jnp.einsum("btdn,btn->btd", h, Cm)
        return y, h[:, -1]
    h0 = h0 if h0 is not None else jnp.zeros((B, di, N), dA.dtype)
    nc = T // SSM_CHUNK
    dA_c = dA.reshape(B, nc, SSM_CHUNK, di, N).swapaxes(0, 1)
    dBu_c = dBu.reshape(B, nc, SSM_CHUNK, di, N).swapaxes(0, 1)
    C_c = Cm.reshape(B, nc, SSM_CHUNK, N).swapaxes(0, 1)

    def chunk(h, inp):
        da, dbu, c = inp
        dbu = dbu.at[:, 0].add(da[:, 0] * h)  # carry enters step 0
        hseq = ssm_scan_ref(da, dbu)  # [B, Tc, di, N]
        y = jnp.einsum("btdn,btn->btd", hseq, c)
        return hseq[:, -1], y

    h_final, ys = jax.lax.scan(chunk, h0, (dA_c, dBu_c, C_c))
    y = ys.swapaxes(0, 1).reshape(B, T, di)
    return y, h_final


def ssm_block(p: Params, cfg: ModelConfig, x: jnp.ndarray,
              scan_fn=None) -> jnp.ndarray:
    """x: [B, T, d] -> [B, T, d]. ``scan_fn(dA, dBu) -> h`` is pluggable so
    the Pallas chunked kernel can replace the reference associative scan."""
    xz = jnp.einsum("btd,de->bte", x, p["in_proj"])
    u, z = jnp.split(xz, 2, axis=-1)
    u = jax.nn.silu(_causal_conv1d(u, p["conv_w"], p["conv_b"]))
    dA, dBu, Cm = _ssm_inputs(p, cfg, u)
    if scan_fn is not None:
        h = scan_fn(dA, dBu)  # [B, T, di, N] f32 (pluggable kernel)
        y = jnp.einsum("btdn,btn->btd", h, Cm.astype(jnp.float32))
    else:
        y, _ = ssm_scan_y(dA, dBu, Cm.astype(jnp.float32))
    y = y + p["D"] * u.astype(jnp.float32)
    y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype)
    return jnp.einsum("btd,de->bte", y, p["out_proj"])


# ----------------------------------------------------------------- decode
def init_ssm_cache(cfg: ModelConfig, batch: int) -> Dict[str, jnp.ndarray]:
    return {
        "h": jnp.zeros((batch, cfg.d_inner, cfg.ssm_state), dtype=jnp.float32),
        "conv": jnp.zeros((batch, cfg.ssm_conv - 1, cfg.d_inner), dtype=cfg.dtype),
    }


def ssm_decode_step(p: Params, cfg: ModelConfig, x: jnp.ndarray,
                    cache: Dict[str, jnp.ndarray]) -> Tuple[jnp.ndarray, Dict]:
    """x: [B, 1, d]; cache h: [B, di, N], conv: [B, K-1, di]."""
    xz = jnp.einsum("btd,de->bte", x, p["in_proj"])
    u, z = jnp.split(xz, 2, axis=-1)  # [B, 1, di]
    # conv over the last K inputs
    hist = jnp.concatenate([cache["conv"], u], axis=1)  # [B, K, di]
    u_c = jnp.einsum("bkd,kd->bd", hist, p["conv_w"]) + p["conv_b"]
    u_c = jax.nn.silu(u_c)[:, None, :]  # [B, 1, di]
    new_conv = hist[:, 1:, :]
    dA, dBu, Cm = _ssm_inputs(p, cfg, u_c)
    h = dA[:, 0] * cache["h"] + dBu[:, 0]  # [B, di, N]
    y = jnp.einsum("bdn,bn->bd", h, Cm[:, 0].astype(jnp.float32))
    y = y + p["D"] * u_c[:, 0].astype(jnp.float32)
    y = (y * jax.nn.silu(z[:, 0].astype(jnp.float32))).astype(x.dtype)
    out = jnp.einsum("bd,de->be", y, p["out_proj"])[:, None, :]
    return out, {"h": h, "conv": new_conv}
