"""Jitted wrapper: padding to MXU tiles + kernel/ref dispatch."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.flash_attention import (
    BLOCK_K,
    BLOCK_Q,
    flash_attention_pallas,
)
from repro.kernels.flash_attention.ref import attention_ref


@functools.partial(jax.jit,
                   static_argnames=("causal", "window", "use_pallas",
                                    "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    use_pallas: bool = True, interpret: bool = True):
    """Pads T/S to 128 multiples, runs the kernel, slices back."""
    if not use_pallas:
        return attention_ref(q, k, v, causal=causal, window=window)
    B, nh, T, hd = q.shape
    S = k.shape[2]
    pt = (-T) % BLOCK_Q
    ps = (-S) % BLOCK_K
    qp = jnp.pad(q, ((0, 0), (0, 0), (0, pt), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, 0), (0, ps), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, 0), (0, ps), (0, 0)))
    # Padded KV columns sit at positions > any real query position, so the
    # causal mask removes them; padded Q rows are sliced off below.
    out = flash_attention_pallas(qp, kp, vp, causal=causal, window=window,
                                 interpret=interpret)
    return out[:, :, :T]
