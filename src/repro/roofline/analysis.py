"""Three-term roofline from the compiled dry-run.

    compute term    = HLO_FLOPs / (chips x peak_FLOP/s)
    memory term     = HLO_bytes / (chips x HBM_bw)
    collective term = collective_bytes / (chips x link_bw)

``cost_analysis()`` provides HLO_FLOPs / bytes (whole-program, i.e. summed
over all chips' SPMD program x chips — XLA reports per-program; we treat
it as per-chip since the SPMD program IS the per-chip program).
Collective bytes are parsed from the compiled HLO text: every
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute
op's result shape, weighted by the standard ring-algorithm wire factors:

    all-gather      (n-1)/n x output bytes
    reduce-scatter  (n-1)/n x input bytes
    all-reduce      2(n-1)/n x bytes        (RS + AG)
    all-to-all      (n-1)/n x bytes
    collective-permute 1 x bytes

Hardware constants (TPU v5e): 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI.
"""

from __future__ import annotations

import re
from dataclasses import dataclass
from typing import Any, Dict

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1,
    "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "tuple": 0, "token": 0, "opaque": 0,
}


@dataclass(frozen=True)
class HW:
    peak_flops: float = 197e12  # bf16 / chip
    hbm_bw: float = 819e9       # bytes/s / chip
    ici_bw: float = 50e9        # bytes/s / link


_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_OP_RE = re.compile(
    r"=\s*((?:\([^)]*\))|(?:\S+))\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(")
_GROUPS_RE = re.compile(r"replica_groups=\{([^}]*)\}")
_GROUPS_SHAPE_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(shape_str: str) -> float:
    """Bytes of 'bf16[128,4096]' or tuple '(bf16[2], f32[4])'."""
    total = 0.0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        b = _DTYPE_BYTES.get(dt)
        if b is None:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * b
    return total


def collective_bytes(hlo_text: str) -> Dict[str, Any]:
    """Parse compiled HLO; sum wire bytes per collective kind.

    Group size n is taken from replica_groups when present (iota form
    [groups,n] or explicit lists); wire factors per docstring."""
    per_kind: Dict[str, float] = {}
    count: Dict[str, int] = {}
    for line in hlo_text.splitlines():
        m = _OP_RE.search(line)
        if m is None:
            continue
        if "-done(" in line:
            continue  # bytes counted at -start (async pairs)
        shape_str, kind = m.group(1), m.group(2)
        nbytes = _shape_bytes(shape_str)
        # group size
        n = None
        gm = _GROUPS_SHAPE_RE.search(line)
        if gm:
            n = int(gm.group(2))
        else:
            gm2 = _GROUPS_RE.search(line)
            if gm2 and gm2.group(1).strip():
                first = gm2.group(1).split("}")[0].strip("{} ")
                n = len([x for x in first.split(",") if x.strip() != ""])
        if not n or n <= 1:
            n = 2  # conservative floor when groups are implicit
        frac = (n - 1) / n
        if kind == "all-reduce":
            wire = 2 * frac * nbytes
        elif kind == "collective-permute":
            wire = nbytes
        else:
            wire = frac * nbytes
        per_kind[kind] = per_kind.get(kind, 0.0) + wire
        count[kind] = count.get(kind, 0) + 1
    total = sum(per_kind.values())
    return {"total_wire_bytes": total, "per_kind": per_kind, "count": count}


def model_flops(kind: str, cfg, shape: Dict[str, Any]) -> float:
    """MODEL_FLOPS = 6*N*D (train) / 2*N*D (inference), N = active params."""
    n = cfg.active_param_count()
    batch, seq = shape["batch"], shape["seq"]
    if kind == "train":
        tokens = batch * seq
        return 6.0 * n * tokens
    if kind == "prefill":
        tokens = batch * seq
        return 2.0 * n * tokens
    return 2.0 * n * batch  # decode: one token per sequence


def roofline_report(kind: str, cfg, shape: Dict[str, Any], n_chips: int,
                    flops: float, bytes_accessed: float,
                    coll: Dict[str, Any], hw: HW = HW()) -> Dict[str, Any]:
    """cost_analysis numbers are for the per-chip SPMD program."""
    t_compute = flops / hw.peak_flops
    t_memory = bytes_accessed / hw.hbm_bw
    t_coll = (coll.get("total_wire_bytes", 0.0)) / hw.ici_bw
    terms = {"compute_s": t_compute, "memory_s": t_memory,
             "collective_s": t_coll}
    dominant = max(terms, key=terms.get)
    mf = model_flops(kind, cfg, shape)
    useful = mf / (flops * n_chips) if flops else 0.0
    bound = max(t_compute, t_memory, t_coll)
    mfu_bound = (mf / n_chips / hw.peak_flops) / bound if bound else 0.0
    return {
        **terms,
        "dominant": dominant,
        "model_flops": mf,
        "hlo_flops_per_chip": flops,
        "useful_flops_ratio": useful,
        "roofline_fraction": mfu_bound,  # model-FLOPs utilisation bound
    }
