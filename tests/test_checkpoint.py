"""Checkpoint/restart + failover tests."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt.checkpoint import CheckpointManager
from repro.ckpt.failover import ElasticPlanner, FailureDetector


def _state(seed=0):
    k = jax.random.PRNGKey(seed)
    return {
        "w": jax.random.normal(k, (4, 8)),
        "layers": {"a": jnp.arange(6, dtype=jnp.float32),
                   "b": [jnp.ones((2,)), jnp.zeros((3,), jnp.int32)]},
    }


def test_save_restore_roundtrip(tmp_path):
    cm = CheckpointManager(str(tmp_path))
    params = _state(0)
    opt = {"m": jax.tree.map(jnp.zeros_like, params)}
    cm.save(7, params, opt, extra={"pipeline": {"position": 3}})
    restored, step, extra = cm.restore({"params": params, "opt": opt})
    assert step == 7
    assert extra["pipeline"]["position"] == 3
    for a, b in zip(jax.tree.leaves(restored["params"]),
                    jax.tree.leaves(params)):
        np.testing.assert_array_equal(a, b)


def test_no_tmp_dirs_after_save(tmp_path):
    cm = CheckpointManager(str(tmp_path))
    cm.save(1, _state(1))
    assert not [d for d in os.listdir(tmp_path) if d.endswith(".tmp")]


def test_gc_keeps_latest(tmp_path):
    cm = CheckpointManager(str(tmp_path), keep=2)
    for s in (1, 2, 3, 4):
        cm.save(s, _state(s))
    assert cm.steps() == [3, 4]
    assert cm.latest_step() == 4


def test_async_save_then_restore(tmp_path):
    cm = CheckpointManager(str(tmp_path))
    params = _state(2)
    cm.save_async(5, params)
    cm.wait()
    restored, step, _ = cm.restore({"params": params})
    assert step == 5
    np.testing.assert_array_equal(restored["params"]["w"], params["w"])


def test_restore_missing_raises(tmp_path):
    cm = CheckpointManager(str(tmp_path))
    with pytest.raises(FileNotFoundError):
        cm.restore({"params": _state(0)})


def test_train_driver_resume_equivalence(tmp_path):
    """Crash-restart from checkpoint reproduces the uninterrupted run."""
    from repro.launch.train import train

    d = str(tmp_path / "ck")
    full = train("qwen3_4b", steps=12, batch=2, seq=16, ckpt_dir=None,
                 use_store=False, log_every=100)
    train("qwen3_4b", steps=10, batch=2, seq=16, ckpt_dir=d,
          use_store=False, log_every=100)
    resumed = train("qwen3_4b", steps=12, batch=2, seq=16, ckpt_dir=d,
                    resume=True, use_store=False, log_every=100)
    # resumed run covers steps 10..11; loss trajectory must match the tail
    assert len(resumed["losses"]) == 2
    np.testing.assert_allclose(resumed["losses"], full["losses"][10:],
                               rtol=2e-2, atol=2e-2)


def test_failure_detector_timeout():
    det = FailureDetector(timeout_s=5.0)
    det.heartbeat("w0", 0.0)
    det.heartbeat("w1", 0.0)
    det.heartbeat("w0", 8.0)
    assert det.failed_workers(9.0) == ["w1"]
    assert det.healthy(9.0) == ["w0"]
    # failed workers stay failed even if they come back
    det.heartbeat("w1", 10.0)
    assert "w1" in det.failed_workers(11.0)


def test_elastic_planner_shrinks_data_axis():
    p = ElasticPlanner(model_tp=16)
    plan = p.plan(surviving_chips=192, global_batch=256)  # lost 64 of 256
    assert plan.model == 16
    assert plan.data <= 12
    assert plan.devices <= 192
    assert 256 % (plan.data * plan.pods) == 0
