"""Trip-count-aware cost analysis of compiled HLO text.

``compiled.cost_analysis()`` counts every computation ONCE — under
scan-over-layers (a `while` loop) it reports one layer body instead of
L x M executions, making FLOPs/bytes/collectives wrong by orders of
magnitude. This module re-derives the three roofline inputs from the HLO
text with loop trip counts applied:

- splits the module into computations and builds a per-computation symbol
  table (header params + op definitions) so operand shapes resolve even
  though the compiled print omits them at use sites;
- extracts each while loop's trip count from its condition region
  (jax scans lower to `lt(i, constant)` inductions; the bound is the
  largest s32 constant in the region);
- walks the call tree multiplying costs by trip counts:
    * dot FLOPs: 2 x numel(result) x contracted lhs dims,
    * bytes accessed: operand + result bytes per op, skipping
      data-movement-free ops (tuple/GTE/parameter/bitcast/constant) —
      fusions count their boundary tensors once, matching
      cost_analysis semantics,
    * collective wire bytes with ring-algorithm factors:
        all-gather/all-to-all: (n-1)/n x full buffer
        reduce-scatter:        (n-1)/n x full (pre-scatter) buffer
        all-reduce:            2(n-1)/n x buffer
        collective-permute:    1 x buffer.

All numbers describe the per-chip SPMD program.
"""

from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1, "f8e5m2fnuz": 1,
    "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_SHAPE_TOKEN = re.compile(r"\b([a-z]\d*[a-z0-9]*)\[([\d,]*)\]")
_PARAM_DECL = re.compile(r"([\w\.\-]+):\s*(\([^)]*\)|[a-z]\d*[a-z0-9]*\[[\d,]*\])")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(.*)$")
_OPNAME_RE = re.compile(r"^(\([^=]*?\)|\S+)\s+([\w\-]+)\(")
_WHILE_RE = re.compile(r"condition=%?([\w\.\-]+),\s*body=%?([\w\.\-]+)")
_CALL_RE = re.compile(r"(?:calls=|to_apply=)%?([\w\.\-]+)")
_CONST_RE = re.compile(r"constant\((\d+)\)")
_OPERAND_RE = re.compile(r"%([\w\.\-]+)")
_GROUPS_SHAPE_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")
_LHS_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")

_FREE_OPS = {
    "tuple", "get-tuple-element", "parameter", "bitcast", "constant",
    "after-all", "partition-id", "replica-id", "iota", "reshape",
    "opt-barrier", "custom-call",  # custom-call: layout markers on CPU
}
_COLLECTIVES = {
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
}


def _numel(dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n


def _shape_bytes(shape_str: str) -> float:
    total = 0.0
    for m in _SHAPE_TOKEN.finditer(shape_str):
        b = _DTYPE_BYTES.get(m.group(1))
        if b:
            total += _numel(m.group(2)) * b
    return total


@dataclass
class _Op:
    name: str
    kind: str
    result_shape: str
    operands: List[str]
    line: str


@dataclass
class CompCost:
    flops: float = 0.0
    bytes: float = 0.0
    coll_bytes: float = 0.0
    coll_per_kind: Dict[str, float] = field(default_factory=dict)
    coll_count: Dict[str, int] = field(default_factory=dict)
    whiles: List[Tuple[str, str]] = field(default_factory=list)
    calls: List[str] = field(default_factory=list)


class _Computation:
    def __init__(self, name: str, header: str, lines: List[str]):
        self.name = name
        self.lines = lines
        self.symtab: Dict[str, str] = {}
        for m in _PARAM_DECL.finditer(header):
            self.symtab[m.group(1)] = m.group(2)
        self.ops: List[_Op] = []
        for line in lines:
            dm = _DEF_RE.match(line)
            if not dm:
                continue
            name_, rhs = dm.group(1), dm.group(2)
            rhs = rhs.strip()
            # result shape: tuple "(...)" (may contain /*index=N*/ comments)
            # or a single token; find it by paren balancing.
            if rhs.startswith("("):
                depth, i = 0, 0
                for i, ch in enumerate(rhs):
                    if ch == "(":
                        depth += 1
                    elif ch == ")":
                        depth -= 1
                        if depth == 0:
                            break
                result_shape = rhs[: i + 1]
                rest = rhs[i + 1:].strip()
            else:
                sp = rhs.find(" ")
                if sp < 0:
                    continue
                result_shape = rhs[:sp]
                rest = rhs[sp + 1:].strip()
            om = re.match(r"([\w\-]+)\(", rest)
            if not om:
                continue
            opname = om.group(1)
            self.symtab[name_] = result_shape
            operand_str = rest[om.end() - 1:]
            # cut trailing attributes for operand scan (operands come first)
            operands = _OPERAND_RE.findall(
                operand_str.split("metadata=")[0].split("calls=")[0]
                .split("to_apply=")[0].split("condition=")[0])
            self.ops.append(_Op(name_, opname, result_shape, operands, line))

    def shape_of(self, sym: str) -> str:
        return self.symtab.get(sym, "")


def _group_size(line: str) -> int:
    gm = _GROUPS_SHAPE_RE.search(line)
    if gm:
        return int(gm.group(2))
    gl = _GROUPS_LIST_RE.search(line)
    if gl:
        return len([x for x in gl.group(1).split(",") if x.strip()])
    return 2  # conservative floor when groups are implicit


def _collective_wire(op: _Op, comp: _Computation) -> float:
    kind = op.kind.replace("-start", "")
    n = _group_size(op.line)
    if n <= 1:
        return 0.0
    frac = (n - 1) / n
    rbytes = _shape_bytes(op.result_shape)
    if kind == "all-reduce":
        return 2 * frac * rbytes
    if kind == "collective-permute":
        return rbytes
    if kind == "all-gather":
        return frac * rbytes  # result = gathered buffer
    if kind == "reduce-scatter":
        # result = shard; wire = (n-1)/n x full input
        in_bytes = sum(_shape_bytes(comp.shape_of(o)) for o in op.operands)
        return frac * (in_bytes if in_bytes else rbytes * n)
    if kind == "all-to-all":
        return frac * rbytes
    return 0.0


def _dot_flops(op: _Op, comp: _Computation) -> float:
    if not op.operands:
        return 0.0
    lhs_shape = comp.shape_of(op.operands[0])
    sm = _SHAPE_TOKEN.search(lhs_shape)
    if not sm:
        return 0.0
    lhs_dims = [int(d) for d in sm.group(2).split(",") if d]
    contract = 1
    mc = _LHS_CONTRACT_RE.search(op.line)
    if mc:
        for idx in mc.group(1).split(","):
            if idx and int(idx) < len(lhs_dims):
                contract *= lhs_dims[int(idx)]
    return 2.0 * _shape_bytes(op.result_shape) / max(
        _DTYPE_BYTES.get(_SHAPE_TOKEN.search(op.result_shape).group(1), 1), 1
    ) * contract


def _split(hlo: str) -> Tuple[Dict[str, _Computation], Optional[str]]:
    comps: Dict[str, _Computation] = {}
    entry: Optional[str] = None
    cur_name: Optional[str] = None
    cur_header = ""
    cur_lines: List[str] = []
    header_re = re.compile(r"^(ENTRY\s+)?%?([\w\.\-]+)\s*(\(.*)$")
    for line in hlo.splitlines():
        if not line.strip():
            continue
        if not line.startswith(" ") and ("->" in line) and "{" in line:
            m = header_re.match(line)
            if m:
                cur_name = m.group(2)
                cur_header = m.group(3)
                cur_lines = []
                if m.group(1):
                    entry = cur_name
                continue
        if line.strip() == "}":
            if cur_name is not None:
                comps[cur_name] = _Computation(cur_name, cur_header, cur_lines)
            cur_name = None
            continue
        if cur_name is not None:
            cur_lines.append(line)
    return comps, entry


class HloCostModel:
    def __init__(self, hlo_text: str):
        self.comps, self.entry = _split(hlo_text)
        self.raw: Dict[str, CompCost] = {}
        for name, comp in self.comps.items():
            c = CompCost()
            for op in comp.ops:
                base_kind = op.kind.replace("-start", "").replace("-done", "")
                if op.kind == "while":
                    wm = _WHILE_RE.search(op.line)
                    if wm:
                        c.whiles.append((wm.group(1), wm.group(2)))
                    continue
                if base_kind in _COLLECTIVES:
                    if op.kind.endswith("-done"):
                        continue
                    wire = _collective_wire(op, comp)
                    c.coll_bytes += wire
                    c.coll_per_kind[base_kind] = c.coll_per_kind.get(base_kind, 0.0) + wire
                    c.coll_count[base_kind] = c.coll_count.get(base_kind, 0) + 1
                    # collective still moves HBM bytes locally
                    c.bytes += _shape_bytes(op.result_shape)
                    continue
                cm = _CALL_RE.search(op.line)
                if cm:
                    c.calls.append(cm.group(1))
                if op.kind == "dot":
                    c.flops += _dot_flops(op, self.comps[name])
                if op.kind in _FREE_OPS:
                    continue
                nbytes = _shape_bytes(op.result_shape)
                for o in op.operands:
                    nbytes += _shape_bytes(self.comps[name].shape_of(o))
                c.bytes += nbytes
            self.raw[name] = c
        self._memo: Dict[str, Tuple[float, float, float, Dict[str, float], Dict[str, int]]] = {}

    def _trip(self, cond_name: str) -> int:
        comp = self.comps.get(cond_name)
        if comp is None:
            return 1
        consts = []
        for line in comp.lines:
            for m in _CONST_RE.finditer(line):
                consts.append(int(m.group(1)))
        return max(consts) if consts else 1

    def _resolve(self, name: str, depth: int = 0):
        if name in self._memo:
            return self._memo[name]
        if depth > 64 or name not in self.raw:
            return (0.0, 0.0, 0.0, {}, {})
        c = self.raw[name]
        flops, nbytes, coll = c.flops, c.bytes, c.coll_bytes
        per_kind = dict(c.coll_per_kind)
        counts = dict(c.coll_count)
        for callee in c.calls:
            f2, _, c2, pk2, ct2 = self._resolve(callee, depth + 1)
            flops += f2
            coll += c2
            for k, v in pk2.items():
                per_kind[k] = per_kind.get(k, 0.0) + v
            for k, v in ct2.items():
                counts[k] = counts.get(k, 0) + v
        for cond, body in c.whiles:
            trip = self._trip(cond)
            f2, b2, c2, pk2, ct2 = self._resolve(body, depth + 1)
            flops += trip * f2
            nbytes += trip * b2
            coll += trip * c2
            for k, v in pk2.items():
                per_kind[k] = per_kind.get(k, 0.0) + trip * v
            for k, v in ct2.items():
                counts[k] = counts.get(k, 0) + trip * v
        out = (flops, nbytes, coll, per_kind, counts)
        self._memo[name] = out
        return out

    def entry_cost(self) -> Dict[str, object]:
        entry = self.entry
        if entry is None and self.raw:
            entry = max(self.raw, key=lambda n: self.raw[n].bytes)
        flops, nbytes, coll, per_kind, counts = self._resolve(entry)
        return {
            "flops": flops,
            "bytes_accessed": nbytes,
            "collective_wire_bytes": coll,
            "collective_per_kind": per_kind,
            "collective_counts": counts,
            "entry": entry,
        }


def analyze_hlo(hlo_text: str) -> Dict[str, object]:
    return HloCostModel(hlo_text).entry_cost()
