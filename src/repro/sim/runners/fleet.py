"""The fleet dispatcher: drain a ``JobRegistry`` through persistent workers.

``run_fleet_jobs`` is the third ``repro.sim.jobs`` executor, next to
``run_local_jobs`` (serial in-process) and ``run_process_jobs``
(anonymous pool). Same contract — ``(results by job_id, registry)``,
abandoned jobs reported via ``registry.failures()`` instead of raising —
different execution model: up to ``workers`` *persistent* workers, each
reached through its own ``Transport``, each initialized once with the
shared job context and then fed jobs one at a time.

What one-job-per-worker buys over the pool:

- **Exact crash attribution.** A dead pipe implicates precisely the job
  that worker carried; nothing is requeued as collateral damage (the
  pool's ``BrokenProcessPool`` fails every in-flight future at once and
  has to guess).
- **Surgical deadline reaping.** A deadline overrun kills *that*
  worker; its peers keep running (the pool recycles wholesale).
- **Amortized startup.** Workers import + build their runner once
  (``init`` frame) and the big shared arrays ship once, not per job —
  the property that makes lane-chunk jobs on the jax backend cheap to
  distribute.

Faults (``repro.sim.faults``) inject per attempt exactly as on the
other executors: the directive rides the job frame and the worker acts
it out (``crash`` = ``os._exit`` -> EOF here; ``hang`` sleeps into the
deadline; ``transient`` returns a retryable not-ok frame). Worker
metrics snapshots ride each result frame and merge into the
dispatcher's registry.

Telemetry (``docs/observability.md``): ``workers.spawned`` /
``workers.alive`` / ``workers.lost`` / ``workers.killed{reason}`` /
``workers.startup_s`` for fleet lifecycle, ``dispatch.jobs`` /
``dispatch.results`` / ``dispatch.roundtrip_s`` for job traffic.
"""

from __future__ import annotations

import time
from collections import deque
from typing import (Any, Callable, Dict, List, Optional, Sequence, Tuple)

from repro.obs.metrics import get_registry
from repro.obs.trace import get_tracer
from repro.sim.faults import FaultPlan
from repro.sim.jobs import Job, JobRegistry, RetryPolicy
from repro.sim.runners.transport import (Transport, TransportError,
                                         resolve_transport)


class _Slot:
    """One fleet seat: a live transport and its in-flight job (if any)."""

    __slots__ = ("transport", "job")

    def __init__(self, transport: Transport):
        self.transport = transport
        self.job: Optional[Job] = None


def run_fleet_jobs(jobs: Sequence[Job], *, workers: int,
                   transport: Any = "subprocess",
                   ctx: Optional[Dict[str, Any]] = None,
                   prepare: Optional[Callable[[Job], Any]] = None,
                   policy: Optional[RetryPolicy] = None,
                   registry: Optional[JobRegistry] = None,
                   faults: Optional[FaultPlan] = None,
                   progress: Optional[Callable[[int, int, Any], None]] = None,
                   on_done: Optional[Callable[[Job, Any], None]] = None,
                   poll_s: float = 0.05,
                   ) -> Tuple[Dict[str, Any], JobRegistry]:
    """Run registry jobs on a persistent worker fleet.

    ``transport`` selects the channel per worker: ``"subprocess"``
    (default; spawned local worker processes), ``"local"`` (inline
    execution, for tests), or any zero-arg factory returning a
    ``Transport`` (the remote-host seam). ``ctx`` is the shared init
    context every worker receives once (default: scenario jobs);
    ``prepare(job)`` builds the per-job wire payload (default:
    ``job.payload`` as-is) — the lane-chunk path uses it to slice each
    job's lanes out of the grid instead of shipping the whole grid.

    Workers spawn lazily up to ``workers`` as ready jobs appear, are
    killed individually when their job exceeds its ``timeout_s``, and
    are respawned while work remains. ``on_done`` fires after each
    success (the checkpoint-journaling hook); ``progress(done, total,
    result)`` after each success too. Shutdown sends each worker a stop
    frame, then reaps it.
    """
    if workers < 1:
        raise ValueError(f"workers must be >= 1, got {workers!r}")
    factory = resolve_transport(transport)
    reg = registry or JobRegistry(policy)
    for job in jobs:
        reg.add(job)
    total = len(reg.jobs)
    results: Dict[str, Any] = {}
    metrics = get_registry()
    tracer = get_tracer()
    init_msg = {"op": "init", "ctx": ctx or {"kind": "scenario"}}
    fleet: List[_Slot] = []
    n_done = 0

    def payload_of(job: Job) -> Any:
        return prepare(job) if prepare is not None else job.payload

    def publish_alive() -> None:
        metrics.set_gauge("workers.alive", len(fleet),
                          help="Fleet workers currently alive")

    def spawn() -> Optional[_Slot]:
        try:
            t = factory()
            t.start(init_msg)
        except Exception as e:  # spawn failure: report, don't spin
            metrics.inc("workers.spawn_failures",
                        help="Fleet workers that failed to start")
            tracer.instant("worker.spawn_failed", error=str(e))
            return None
        slot = _Slot(t)
        fleet.append(slot)
        metrics.inc("workers.spawned", help="Fleet workers spawned")
        publish_alive()
        return slot

    def drop(slot: _Slot, kill: bool = True) -> None:
        if kill:
            slot.transport.kill()
        if slot in fleet:
            fleet.remove(slot)
        publish_alive()

    def assign(slot: _Slot, job: Job) -> bool:
        reg.mark_running(job)
        job.injected = (faults.directive(job.job_id, job.labels,
                                         job.attempts)
                        if faults is not None else None)
        msg = {"op": "job", "job_id": job.job_id,
               "payload": payload_of(job), "directive": job.injected}
        try:
            slot.transport.send(msg)
        except TransportError:
            # Never delivered: the job is blameless, the channel is not.
            reg.requeue_lost(job)
            drop(slot)
            return False
        slot.job = job
        metrics.inc("dispatch.jobs",
                    help="Jobs dispatched to fleet workers")
        return True

    def handle(slot: _Slot, event: Tuple) -> None:
        nonlocal n_done
        if event[0] == "eof":
            job = slot.job
            slot.job = None
            metrics.inc("workers.lost",
                        help="Fleet workers that died unexpectedly")
            if job is not None:
                # One job per worker: a dead pipe implicates exactly it.
                reg.mark_failed(job, "crash", "worker died (channel EOF)")
            drop(slot, kill=True)
            return
        msg = event[1]
        op = msg.get("op")
        if op == "ready":
            metrics.observe("workers.startup_s",
                            float(msg.get("startup_s", 0.0)),
                            help="Worker import + runner-build time (s)")
            return
        if op != "result":
            return
        job = slot.job
        if job is None or msg.get("job_id") != job.job_id:
            return  # stale frame from a reassigned seat; drop it
        slot.job = None
        if (job.timeout_s is not None and job.started_at is not None
                and reg.clock() - job.started_at > job.timeout_s):
            # The frame beat the reaper but the deadline still stands
            # (an in-line transport's injected hang lands here). The
            # worker proved responsive, so it keeps its seat.
            reg.mark_failed(job, "timeout",
                            f"result arrived after the "
                            f"{job.timeout_s:g}s deadline")
            return
        metrics.merge(msg.get("metrics"))
        metrics.inc("dispatch.results",
                    help="Result frames received from fleet workers")
        if job.started_at is not None:
            metrics.observe("dispatch.roundtrip_s",
                            reg.clock() - job.started_at,
                            help="Dispatch-to-result round trip (s)")
        if msg.get("ok"):
            result = msg.get("result")
            reg.mark_done(job, result)
            results[job.job_id] = result
            n_done += 1
            tracer.instant("job.attempt", job=job.job_id,
                           attempt=job.attempts, state="done")
            if on_done is not None:
                on_done(job, result)
            if progress is not None:
                progress(n_done, total, result)
        else:
            reg.mark_failed(job, msg.get("kind", "error"),
                            msg.get("error", "unknown worker failure"))

    try:
        while reg.unsettled():
            now = reg.clock()
            # -- deadline reaping: kill only the offending worker ---------
            for slot in list(fleet):
                job = slot.job
                if (job is not None and job.timeout_s is not None
                        and job.started_at is not None
                        and now - job.started_at > job.timeout_s):
                    slot.job = None
                    reg.mark_failed(
                        job, "timeout",
                        f"exceeded the {job.timeout_s:g}s deadline")
                    metrics.inc("workers.killed", reason="deadline",
                                help="Fleet workers killed by the "
                                     "dispatcher")
                    drop(slot)
            # -- assign ready jobs to idle seats, spawning as needed ------
            ready = deque(reg.ready(now))
            for slot in list(fleet):
                if not ready:
                    break
                if slot.job is None and slot.transport.alive:
                    assign(slot, ready.popleft())
            spawn_denied = False
            while ready and len(fleet) < workers and not spawn_denied:
                slot = spawn()
                if slot is None:
                    spawn_denied = True
                    break
                assign(slot, ready.popleft())
            # -- poll every seat; handle whatever arrived -----------------
            got = False
            for slot in list(fleet):
                while True:
                    event = slot.transport.poll()
                    if event is None:
                        break
                    got = True
                    handle(slot, event)
            if got:
                continue
            if any(slot.job is not None for slot in fleet):
                time.sleep(min(poll_s, 0.02))
                continue
            wake = reg.next_wake()
            if wake is None:
                break
            if spawn_denied:
                # Nothing in flight and workers cannot start: abandon the
                # remainder rather than spinning forever.
                for job in reg.ready(reg.clock()):
                    reg.mark_running(job)
                    reg.mark_failed(job, "error",
                                    "no fleet worker could be started")
                continue
            time.sleep(min(max(wake - now, 0.0), poll_s))
    finally:
        for slot in list(fleet):
            try:
                slot.transport.send({"op": "stop"})
            except Exception:
                pass
            slot.transport.kill()
        fleet.clear()
        publish_alive()
    return results, reg


__all__ = ["run_fleet_jobs"]
