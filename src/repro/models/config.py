"""Unified model configuration covering all assigned architecture families."""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Any, Optional, Tuple

import jax.numpy as jnp


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None  # default: d_model // n_heads
    # -- attention variants ---------------------------------------------------
    qk_norm: bool = False            # qwen3
    attn_bias: bool = False
    attn_logit_softcap: Optional[float] = None
    sliding_window: Optional[int] = None  # local-attention window (gemma3, hymba)
    global_every: Optional[int] = None    # every k-th layer global (gemma3: 6)
    global_layers: Tuple[int, ...] = ()   # explicit global layer ids (hymba)
    rope_theta: float = 10_000.0
    # -- MoE -------------------------------------------------------------------
    n_experts: int = 0
    top_k: int = 0
    moe_dense_ff: int = 0            # arctic: parallel dense-residual MLP width
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01
    # -- SSM (mamba-1) ----------------------------------------------------------
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_expand: int = 2
    dt_rank: Optional[int] = None    # default: ceil(d_model / 16)
    # -- hybrid (hymba: parallel attn + ssm heads in each block) ----------------
    hybrid: bool = False
    # -- encoder-decoder (seamless backbone) -------------------------------------
    encoder_layers: int = 0          # > 0 => enc-dec
    # -- modality frontend stubs --------------------------------------------------
    frontend: Optional[str] = None   # "vision" | "audio"
    frontend_tokens: int = 0         # tokens contributed by the stub frontend
    frontend_dim: int = 0            # embedding dim delivered by the frontend
    # -- misc ---------------------------------------------------------------------
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    dtype: Any = jnp.bfloat16
    remat: bool = True

    # ----------------------------------------------------------------- helpers
    @property
    def hd(self) -> int:
        return self.head_dim if self.head_dim is not None else self.d_model // self.n_heads

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def dtr(self) -> int:
        return self.dt_rank if self.dt_rank is not None else -(-self.d_model // 16)

    @property
    def is_enc_dec(self) -> bool:
        return self.encoder_layers > 0

    @property
    def has_attention(self) -> bool:
        return self.family != "ssm"

    @property
    def has_ssm(self) -> bool:
        return self.family in ("ssm", "hybrid")

    def is_global_layer(self, i: int) -> bool:
        """Static per-layer attention pattern (full vs sliding window)."""
        if self.sliding_window is None:
            return True
        if self.global_layers:
            return i in self.global_layers
        if self.global_every:
            # gemma3 pattern: 5 local then 1 global, repeating.
            return (i % self.global_every) == (self.global_every - 1)
        return False

    def layer_globals(self) -> Tuple[bool, ...]:
        return tuple(self.is_global_layer(i) for i in range(self.n_layers))

    def param_count(self) -> int:
        """Analytic parameter count (embeddings + layers), for roofline math."""
        hd, d = self.hd, self.d_model
        emb = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        per_layer = 0
        if self.has_attention:
            q = d * self.n_heads * hd
            kv = 2 * d * self.n_kv_heads * hd
            o = self.n_heads * hd * d
            per_layer += q + kv + o
        if self.family == "moe":
            per_layer += self.n_experts * 3 * d * self.d_ff + d * self.n_experts
            if self.moe_dense_ff:
                per_layer += 3 * d * self.moe_dense_ff
        elif self.d_ff:
            per_layer += 3 * d * self.d_ff
        if self.has_ssm:
            di, n, dtr = self.d_inner, self.ssm_state, self.dtr
            per_layer += 2 * d * di          # in_proj (x, z)
            per_layer += di * self.ssm_conv  # conv
            per_layer += di * (dtr + 2 * n)  # x -> (dt, B, C)
            per_layer += dtr * di + di       # dt_proj
            per_layer += di * n + di         # A_log, D
            per_layer += di * d              # out_proj
        per_layer += 2 * d  # norms
        total = emb + self.n_layers * per_layer
        if self.is_enc_dec:
            # encoder layers: self-attn + mlp; decoder adds cross-attn.
            enc_layer = 4 * d * self.n_heads * hd + 3 * d * self.d_ff + 2 * d
            total += self.encoder_layers * enc_layer
            total += self.n_layers * (2 * d * self.n_kv_heads * hd + 2 * d * self.n_heads * hd)
        return int(total)

    def active_param_count(self) -> int:
        """Active (per-token) parameters — MoE counts top_k experts only."""
        if self.family != "moe":
            return self.param_count()
        d = self.d_model
        inactive = self.n_layers * (self.n_experts - self.top_k) * 3 * d * self.d_ff
        return int(self.param_count() - inactive)

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)
