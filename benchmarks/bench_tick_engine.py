"""Benchmark: transfer-manager tick engines (paper §4.1 hot loop).

Compares ticks/second of (a) the Python scalar tick manager (the paper's
C++ loop analogue), (b) the vectorized jnp reference, (c) the Pallas
carousel kernel in interpret mode. On TPU, (c) compiles to the MXU one-hot
matmul form; interpret-mode numbers here only validate plumbing, while the
jnp path shows the vectorization win that motivates the kernel.
"""

from __future__ import annotations

import time
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.carousel_update.ops import carousel_tick, simulate_ticks


def run(n_transfers: int = 4096, n_links: int = 64,
        n_ticks: int = 200) -> List[Dict]:
    rng = np.random.default_rng(0)
    link_id = jnp.asarray(rng.integers(0, n_links, n_transfers), jnp.int32)
    active = jnp.ones(n_transfers, bool)
    total = jnp.asarray(rng.exponential(1e9, n_transfers).astype(np.float32))
    done = jnp.zeros(n_transfers, jnp.float32)
    bw = jnp.asarray(rng.uniform(1e6, 1e8, n_links).astype(np.float32))
    mode = jnp.asarray(rng.integers(0, 2, n_links), jnp.int32)

    rows = []

    # python scalar loop (paper-equivalent semantics)
    t0 = time.time()
    d = np.asarray(done).copy()
    act = np.ones(n_transfers, bool)
    counts = np.bincount(link_id[act], minlength=n_links)
    for _ in range(20):
        rate = np.where(mode[link_id] > 0, bw[link_id],
                        bw[link_id] / np.maximum(counts[link_id], 1))
        d = np.minimum(total, d + act * rate * 1.0)
    t_py = (time.time() - t0) / 20
    rows.append({"name": "tick.python_vectorized_numpy",
                 "us_per_call": t_py * 1e6,
                 "derived": n_transfers / t_py})

    # jnp scanned engine
    f = jax.jit(lambda: simulate_ticks(link_id, active, done, total, bw,
                                       mode, 1.0, n_ticks=n_ticks))
    f()  # compile
    t0 = time.time()
    jax.block_until_ready(f())
    t_scan = (time.time() - t0) / n_ticks
    rows.append({"name": "tick.jnp_scanned",
                 "us_per_call": t_scan * 1e6,
                 "derived": n_transfers / t_scan})

    # pallas interpret (plumbing validation; TPU target form)
    t0 = time.time()
    out = carousel_tick(link_id, active, done, total, bw, mode, 1.0,
                        use_pallas=True)
    jax.block_until_ready(out)
    t_pallas = time.time() - t0
    rows.append({"name": "tick.pallas_interpret",
                 "us_per_call": t_pallas * 1e6,
                 "derived": n_transfers / t_pallas})
    return rows


def main() -> None:
    for r in run():
        print(f"{r['name']},{r['us_per_call']:.1f},{r['derived']:.4g}")


if __name__ == "__main__":
    main()
