"""HCDC tiered store + token pipeline tests."""

import numpy as np

from repro.core.hotcold import ColdDeletionPolicy, MigrationPolicy
from repro.data.pipeline import SyntheticCorpus, TokenPipeline
from repro.data.tiered_store import (
    Shard,
    SlidingWindowPrefetcher,
    TierSpec,
    TieredStore,
)
from repro.sim.cloud import GCSCostModel


def _store(hot_limit=1000.0, cold_limit=5000.0, migrate_min=0):
    return TieredStore(
        archival=TierSpec("tape", None, latency_s=10.0, bandwidth=10.0),
        cold=TierSpec("gcs", cold_limit, latency_s=1.0, bandwidth=100.0,
                      cost_model=GCSCostModel()),
        hot=TierSpec("ssd", hot_limit, latency_s=0.0, bandwidth=1000.0),
        migration=MigrationPolicy(min_popularity=migrate_min),
        cold_deletion=ColdDeletionPolicy(0.9),
    )


def test_second_epoch_hits_cold_tier():
    store = _store()
    shards = [Shard(i, 100.0, popularity=2) for i in range(20)]
    store.register(shards)
    schedule = list(range(20)) * 2  # two epochs
    pf = SlidingWindowPrefetcher(store, schedule)
    stats = pf.drain()
    assert stats["archival_reads"] == 20   # first epoch only
    assert stats["cold_hits"] == 20        # second epoch from cold
    assert stats["cold_egress_usd"] > 0


def test_hot_window_bounded():
    store = _store(hot_limit=350.0)
    store.register([Shard(i, 100.0) for i in range(10)])
    pf = SlidingWindowPrefetcher(store, list(range(10)))
    while True:
        try:
            pf.next_shard()
        except StopIteration:
            break
        assert store.hot_window.used <= 350.0


def test_migration_policy_blocks_unpopular():
    store = _store(migrate_min=5)
    store.register([Shard(0, 100.0, popularity=1),
                    Shard(1, 100.0, popularity=9)])
    pf = SlidingWindowPrefetcher(store, [0, 1])
    pf.drain()
    assert 0 not in store.cold_window
    assert 1 in store.cold_window


def test_cold_tier_trim_lru():
    store = _store(cold_limit=250.0)
    store.register([Shard(i, 100.0, popularity=9) for i in range(5)])
    pf = SlidingWindowPrefetcher(store, list(range(5)))
    pf.drain()
    # capacity threshold 0.9 x 250 = 225 -> at most 2 shards resident
    assert store.cold_window.used <= 225.0
    assert len(store.cold_window) <= 2


def test_pipeline_deterministic_and_restorable():
    corpus = SyntheticCorpus(vocab_size=100, seq_len=8, batch=2, n_shards=6)
    p1 = TokenPipeline(corpus, store=None, epochs=1, seed=3)
    [next(p1) for _ in range(3)]  # advance three batches
    state = p1.state()
    b4 = next(p1)
    p2 = TokenPipeline(corpus, store=None, epochs=1, seed=3)
    p2.restore(state)
    b4b = next(p2)
    np.testing.assert_array_equal(b4["tokens"], b4b["tokens"])
    # shard materialisation deterministic by sid
    np.testing.assert_array_equal(
        corpus.materialize(0)["tokens"], corpus.materialize(0)["tokens"])


def test_pipeline_with_store_counts_hits():
    corpus = SyntheticCorpus(vocab_size=50, seq_len=4, batch=1, n_shards=4)
    store = _store(hot_limit=1e9, cold_limit=1e9)
    p = TokenPipeline(corpus, store=store, epochs=3, seed=0)
    n = 0
    for _ in p:
        n += 1
    assert n == 12
    assert store.stats["archival_reads"] == 4
    assert store.stats["cold_hits"] == 8
