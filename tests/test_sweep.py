"""Sweep-engine tests: batch == serial runs, Pareto front, grid expansion."""

import csv

import pytest

from repro.core.hcdc import CONFIG_III, HCDCScenario
from repro.core.scenarios import (
    ScenarioSpec,
    build_config,
    expand_grid,
    specs_from_mapping,
    with_seeds,
)
from repro.sim.cloud import sum_bills
from repro.sim.sweep import (
    SweepResult,
    pareto_indices,
    run_scenario,
    run_sweep,
)

# Reduced scale shared by the cross-validation tests (seconds per config).
TINY = dict(days=0.25, n_files=3000)


# --------------------------------------------------------------------- grid
def test_expand_grid_cartesian_product():
    specs = expand_grid({
        "base": "III", "days": 1.0, "n_files": 1000,
        "cache_tb": [10.0, 20.0, 50.0],
        "egress": ["internet", "direct"],
        "seed": [0, 1],
    })
    assert len(specs) == 3 * 2 * 2
    assert len(set(specs)) == len(specs)  # all distinct
    assert {s.cache_tb for s in specs} == {10.0, 20.0, 50.0}
    assert all(s.days == 1.0 and s.n_files == 1000 for s in specs)
    # last axis fastest (seed varies first)
    assert (specs[0].seed, specs[1].seed) == (0, 1)
    assert specs[0].cache_tb == specs[1].cache_tb


def test_expand_grid_rejects_unknown_fields():
    with pytest.raises(ValueError, match="unknown spec fields"):
        expand_grid({"cache_gb": [1]})


def test_specs_from_mapping_axes_and_scenarios():
    by_axes = specs_from_mapping({
        "days": 0.5, "n_files": 100,
        "axes": {"cache_tb": [5.0, 10.0], "seed": [0, 1]},
    })
    assert len(by_axes) == 4
    assert all(s.days == 0.5 and s.n_files == 100 for s in by_axes)

    by_list = specs_from_mapping({
        "days": 0.5,
        "scenarios": [{"cache_tb": 5.0}, {"cache_tb": 10.0, "days": 1.0}],
    })
    assert [s.cache_tb for s in by_list] == [5.0, 10.0]
    assert [s.days for s in by_list] == [0.5, 1.0]  # scenario overrides shared

    with pytest.raises(ValueError, match="exactly one"):
        specs_from_mapping({"days": 1})
    with pytest.raises(ValueError, match="exactly one"):
        specs_from_mapping({"axes": {}, "scenarios": []})


def test_spec_validates_fields():
    with pytest.raises(ValueError, match="base"):
        ScenarioSpec(base="IV")
    with pytest.raises(ValueError, match="egress"):
        ScenarioSpec(egress="carrier-pigeon")


def test_workload_axis_expands_and_validates():
    """The workload axis sweeps like any other spec field, and bad models
    fail at mapping-parse time (not inside a worker)."""
    specs = specs_from_mapping({
        "days": 0.5, "n_files": 100,
        "axes": {"workload": ["steady", "diurnal:amplitude=0.5"],
                 "seed": [0, 1]},
    })
    assert len(specs) == 4
    assert {s.workload for s in specs} == {"steady", "diurnal:amplitude=0.5"}
    with pytest.raises(ValueError, match="unknown workload"):
        specs_from_mapping({"days": 0.5,
                            "scenarios": [{"workload": "stampede"}]})


def test_with_seeds_replicates():
    specs = with_seeds([ScenarioSpec(cache_tb=5.0)], 3, first_seed=10)
    assert [s.seed for s in specs] == [10, 11, 12]
    assert all(s.cache_tb == 5.0 for s in specs)


# ------------------------------------------------------------------- config
def test_build_config_applies_spec():
    spec = ScenarioSpec(base="III", days=1.0, n_files=500, cache_tb=25.0,
                        egress="interconnect", storage_price=0.02,
                        job_rate_scale=2.0, gcs_limit_tb=float("inf"))
    cfg = build_config(spec)
    assert all(s.disk_limit == 25.0e12 for s in cfg.sites)
    assert cfg.gcs_limit is None  # inf -> unlimited
    assert cfg.cost_model.peering == "interconnect"
    assert cfg.cost_model.storage_per_gb_month == 0.02
    assert cfg.jobs_mu == pytest.approx(2 * 0.63366)


def test_build_config_leaves_module_constants_untouched():
    """Regression: make_config must not share mutable sub-configs with the
    CONFIG_* constants (dataclasses.replace copies shallowly)."""
    before = [s.disk_limit for s in CONFIG_III.sites]
    peering_before = CONFIG_III.cost_model.peering
    cfg = build_config(ScenarioSpec(base="III", cache_tb=1.0,
                                    egress="direct"))
    cfg.sites[0].disk_limit = 123.0
    cfg.cost_model.peering = "interconnect"
    assert [s.disk_limit for s in CONFIG_III.sites] == before
    assert CONFIG_III.cost_model.peering == peering_before


# ---------------------------------------------------- batch == serial runs
def test_sweep_matches_individual_runs():
    """A parallel sweep over N configs must reproduce N individual
    ``HCDCScenario`` runs exactly (same seeds -> identical metrics, cost
    and transfer totals)."""
    specs = [
        ScenarioSpec(base="III", cache_tb=10.0, seed=0, **TINY),
        ScenarioSpec(base="III", cache_tb=20.0, egress="interconnect",
                     seed=1, **TINY),
        ScenarioSpec(base="II", seed=2, **TINY),
    ]
    swept = run_sweep(specs, workers=2)
    assert len(swept) == len(specs)
    for spec, res in zip(specs, swept.results):
        assert res.spec == spec  # order preserved
        scenario = HCDCScenario(build_config(spec))
        metrics = scenario.run()
        assert metrics == res.metrics  # bit-identical, incl. transfer totals
        bill = sum_bills(scenario.gcs.bills)
        assert bill.storage_usd == res.storage_usd
        assert bill.network_usd == res.network_usd
        assert bill.ops_usd == res.ops_usd
        assert res.cost_usd == bill.total


def test_sweep_serial_equals_parallel():
    specs = with_seeds([ScenarioSpec(base="III", cache_tb=10.0, **TINY)], 2)
    serial = run_sweep(specs, workers=1)
    parallel = run_sweep(specs, workers=2)
    for a, b in zip(serial.results, parallel.results):
        assert a.metrics == b.metrics
        assert a.cost_usd == b.cost_usd


def test_run_scenario_deterministic_for_seed():
    spec = ScenarioSpec(base="III", cache_tb=10.0, seed=7, **TINY)
    a, b = run_scenario(spec), run_scenario(spec)
    assert a.metrics == b.metrics and a.cost_usd == b.cost_usd


# ------------------------------------------------------------------- pareto
def test_pareto_front_hand_built():
    #           A        B        C        D          E        F
    costs = [1.0, 2.0, 3.0, 2.5, 4.0, 1.0]
    values = [10.0, 20.0, 15.0, 25.0, 25.0, 5.0]
    # A dominates F (same cost, more value); D dominates C and E;
    # the front is the strictly increasing staircase A -> B -> D.
    assert pareto_indices(costs, values) == [0, 1, 3]


def test_pareto_duplicates_and_errors():
    assert pareto_indices([1.0, 1.0], [5.0, 5.0]) == [0]  # one representative
    assert pareto_indices([], []) == []
    with pytest.raises(ValueError):
        pareto_indices([1.0], [1.0, 2.0])


def test_sweep_result_front_and_rows(tmp_path):
    spec = ScenarioSpec(base="III", cache_tb=10.0, **TINY)
    res = run_scenario(spec)

    def clone(cost_scale, jobs):
        import copy

        r = copy.deepcopy(res)
        r.network_usd = res.network_usd * cost_scale
        r.metrics = dict(res.metrics, jobs_done=jobs)
        return r

    sweep = SweepResult(results=[clone(1.0, 100), clone(2.0, 300),
                                 clone(3.0, 200)], wall_s=1.0)
    front = sweep.pareto_front()
    assert [r.jobs_done for r in front] == [100, 300]
    rows = sweep.rows()
    assert [r["pareto"] for r in rows] == [1, 1, 0]
    csv_path = tmp_path / "sweep.csv"
    sweep.to_csv(str(csv_path))
    with open(csv_path) as f:
        read = list(csv.DictReader(f))
    assert len(read) == 3
    assert float(read[1]["jobs_done"]) == 300
    assert read[0]["egress"] == "internet"


def test_aggregate_seeds_groups_and_averages():
    specs = with_seeds([ScenarioSpec(base="III", cache_tb=10.0, **TINY)], 2)
    sweep = run_sweep(specs, workers=1)
    agg = sweep.aggregate_seeds()
    assert len(agg) == 1
    row = agg[0]
    assert row["n_seeds"] == 2
    expect = sum(r.jobs_done for r in sweep.results) / 2
    assert row["jobs_done_mean"] == pytest.approx(expect)
    assert "seed" not in row


def test_curves_produce_series_digests(tmp_path):
    res = run_scenario(ScenarioSpec(base="III", cache_tb=10.0, curves=True,
                                    **TINY))
    assert "gcs_used" in res.series
    digest = res.series["gcs_used"]
    assert digest["n"] > 0 and digest["max"] >= digest["min"]
    sweep = SweepResult(results=[res], wall_s=1.0)
    out = tmp_path / "sweep.json"
    sweep.to_json(str(out))
    import json

    doc = json.loads(out.read_text())
    assert doc["series"][res.spec.label]["gcs_used"]["n"] == digest["n"]


# -------------------------------------------------------- telemetry (obs)
def test_pool_workers_merge_metric_snapshots():
    """Spawned workers carry their own registry; the parent folds each
    task's snapshot delta back in, so a parallel sweep's counters match
    a serial run's."""
    from repro.obs.metrics import get_registry

    reg = get_registry()
    specs = with_seeds([ScenarioSpec(base="III", cache_tb=10.0, **TINY)], 3)
    reg.reset()
    run_sweep(specs, workers=2)
    parallel_runs = reg.value("scenario.runs")
    par_hist = reg.snapshot()["histograms"]["scenario.wall_s"]
    reg.reset()
    run_sweep(specs, workers=1)
    serial_runs = reg.value("scenario.runs")
    reg.reset()
    assert parallel_runs == serial_runs == float(len(specs))
    assert par_hist["count"] == len(specs)


def test_configs_per_sec_floor(tmp_path):
    """Below the 1 ms wall-clock floor the throughput rate is noise:
    the property reports ``None`` and the JSON export omits the field."""
    import json

    res = run_scenario(ScenarioSpec(base="III", cache_tb=10.0, **TINY))
    fast = SweepResult(results=[res], wall_s=SweepResult.WALL_S_FLOOR / 2)
    assert fast.configs_per_sec is None
    slow = SweepResult(results=[res], wall_s=2.0)
    assert slow.configs_per_sec == pytest.approx(0.5)
    f1, f2 = tmp_path / "fast.json", tmp_path / "slow.json"
    fast.to_json(str(f1))
    slow.to_json(str(f2))
    assert "configs_per_sec" not in json.loads(f1.read_text())
    assert json.loads(f2.read_text())["configs_per_sec"] == \
        pytest.approx(0.5)


# ------------------------------------------------------------ spec physics
def test_job_rate_scale_scales_submissions():
    base = run_scenario(ScenarioSpec(base="I", **TINY))
    double = run_scenario(ScenarioSpec(base="I", job_rate_scale=2.0, **TINY))
    ratio = double.metrics["jobs_submitted"] / base.metrics["jobs_submitted"]
    assert 1.8 < ratio < 2.2


def test_peering_reduces_network_cost():
    internet = run_scenario(ScenarioSpec(base="III", cache_tb=5.0, **TINY))
    peered = run_scenario(ScenarioSpec(base="III", cache_tb=5.0,
                                       egress="interconnect", **TINY))
    # identical seed/config -> identical traffic, cheaper flat price
    assert peered.metrics["jobs_done"] == internet.metrics["jobs_done"]
    assert peered.network_usd < internet.network_usd


def test_sweep_result_ok_and_failures_serialization(tmp_path):
    """Partial results (ISSUE 9): ``ok`` flips on any abandoned job and
    the structured failure report rides every JSON export."""
    import json

    from repro.sim.jobs import JobFailure

    spec = ScenarioSpec(base="III", cache_tb=10.0, **TINY)
    res = run_scenario(spec)
    complete = SweepResult(results=[res], wall_s=1.0)
    assert complete.ok and complete.failures == []

    partial = SweepResult(
        results=[res], wall_s=1.0,
        failures=[JobFailure(job_id="spec0001", labels=(spec.label,),
                             kind="timeout", attempts=3,
                             errors=["attempt 3 [timeout]: deadline"])])
    assert not partial.ok
    out = tmp_path / "partial.json"
    partial.to_json(str(out))
    doc = json.loads(out.read_text())
    assert doc["failures"] == [partial.failures[0].as_dict()]
    clean = tmp_path / "complete.json"
    complete.to_json(str(clean))
    assert "failures" not in json.loads(clean.read_text())


def test_sweep_result_failures_block_round_trips(tmp_path):
    """The exported failures block carries every ``JobFailure`` field
    losslessly: a report consumer can rebuild the exact loss records
    from the JSON document alone."""
    import json

    from repro.sim.jobs import JobFailure

    spec = ScenarioSpec(base="III", cache_tb=10.0, **TINY)
    res = run_scenario(spec)
    failures = [
        JobFailure(job_id="spec0001", labels=(spec.label,), kind="crash",
                   attempts=3,
                   errors=["attempt 2 [crash]: worker died",
                           "attempt 3 [crash]: worker died (channel EOF)"]),
        JobFailure(job_id="lanes0004", labels=("a", "b"), kind="timeout",
                   attempts=1, errors=["attempt 1 [timeout]: deadline"]),
    ]
    out = tmp_path / "partial.json"
    SweepResult(results=[res], wall_s=1.0,
                failures=failures).to_json(str(out))
    doc = json.loads(out.read_text())
    restored = [JobFailure(job_id=d["job_id"], labels=tuple(d["labels"]),
                           kind=d["kind"], attempts=d["attempts"],
                           errors=list(d["errors"]))
                for d in doc["failures"]]
    assert restored == failures
