"""Engine + transfer-manager unit tests (paper §4.1)."""

import numpy as np
import pytest

from repro.sim.engine import BaseSimulation, Schedulable, HOUR
from repro.sim.infrastructure import (
    File, NetworkLink, Site, StorageElement, GB, MB,
)
from repro.sim.transfer import (
    BandwidthTransferManager,
    DurationTransferManager,
    EventDrivenTransferService,
)


class Ticker(Schedulable):
    def __init__(self, interval):
        super().__init__(interval=interval)
        self.fired = []

    def on_update(self, sim, now):
        self.fired.append(now)


def test_event_loop_ordering_and_intervals():
    sim = BaseSimulation()
    t = Ticker(10)
    sim.schedule(t, 0)
    order = []
    sim.call_at(25, lambda s, n: order.append(("a", n)))
    sim.call_at(5, lambda s, n: order.append(("b", n)))
    sim.run(30)
    assert t.fired == [0, 10, 20, 30]
    assert order == [("b", 5), ("a", 25)]


def test_cannot_schedule_in_past():
    sim = BaseSimulation()
    sim.call_at(10, lambda s, n: None)
    sim.run(10)
    with pytest.raises(ValueError):
        sim.call_at(5, lambda s, n: None)


def _make_link(throughput=None, bandwidth=None, max_active=None,
               latency=0.0):
    site = Site("s1")
    src = StorageElement("SRC", site, access_latency=latency)
    dst = StorageElement("DST", site)
    return NetworkLink(src, dst, throughput=throughput, bandwidth=bandwidth,
                       max_active=max_active), src, dst


def test_event_driven_transfer_completion_time():
    sim = BaseSimulation()
    svc = EventDrivenTransferService(sim, np.random.default_rng(0))
    link, src, dst = _make_link(throughput=10 * MB, latency=60.0)
    f = File(1, 100 * MB)
    src.add_complete_replica(f)
    done_at = []
    svc.submit(f, link, on_complete=lambda s, n, t: done_at.append(n))
    sim.run(HOUR)
    assert done_at == [70]  # 60 s latency + 10 s transfer
    assert dst.has_complete(1)
    assert link.traffic == f.size


def test_max_active_queue_fifo():
    sim = BaseSimulation()
    svc = EventDrivenTransferService(sim, np.random.default_rng(0))
    link, src, dst = _make_link(throughput=10 * MB, max_active=2)
    order = []
    for i in range(5):
        f = File(i, 100 * MB)
        src.add_complete_replica(f)
        svc.submit(f, link, on_complete=lambda s, n, t: order.append(t.file.fid))
    assert link.active == 2 and link.queued == 3
    sim.run(HOUR)
    assert order == [0, 1, 2, 3, 4]
    assert link.active == 0 and link.queued == 0


def test_queue_keying_not_shared_across_same_named_links():
    """Regression: two sites' TAPE->DISK links must not share a queue."""
    sim = BaseSimulation()
    svc = EventDrivenTransferService(sim, np.random.default_rng(0))
    l1, s1, _ = _make_link(throughput=10 * MB, max_active=1)
    l2, s2, _ = _make_link(throughput=10 * MB, max_active=1)
    assert l1.name == l2.name  # same names by construction
    for i, (link, src) in enumerate([(l1, s1), (l2, s2)] * 2):
        f = File(i, 50 * MB)
        src.add_complete_replica(f)
        svc.submit(f, link)
    sim.run(HOUR)
    assert l1.active == 0 and l2.active == 0
    assert max(l1.queued, l2.queued) == 0


def test_tick_manager_matches_event_driven_for_throughput_links():
    """The analytic fast path must reproduce the tick engine's results."""
    rng = np.random.default_rng(3)
    sizes = rng.exponential(200 * MB, 40).clip(10 * MB, 2 * GB)

    def run_tick():
        sim = BaseSimulation()
        mgr = BandwidthTransferManager(interval=1, rng=rng)
        link, src, dst = _make_link(throughput=25 * MB, max_active=5)
        times = {}
        for i, sz in enumerate(sizes):
            f = File(i, float(sz))
            src.add_complete_replica(f)
            mgr.submit(sim, f, link,
                       on_complete=lambda s, n, t: times.__setitem__(t.file.fid, n))
        sim.schedule(mgr, 0)
        sim.run(6 * HOUR)
        return times

    def run_event():
        sim = BaseSimulation()
        svc = EventDrivenTransferService(sim, rng)
        link, src, dst = _make_link(throughput=25 * MB, max_active=5)
        times = {}
        for i, sz in enumerate(sizes):
            f = File(i, float(sz))
            src.add_complete_replica(f)
            svc.submit(f, link,
                       on_complete=lambda s, n, t: times.__setitem__(t.file.fid, n))
        sim.run(6 * HOUR)
        return times

    t_tick, t_event = run_tick(), run_event()
    assert set(t_tick) == set(t_event)
    # tick engine grants queued successors their slot only at tick
    # boundaries, so each queue hop can lag up to 1 s; with 40 transfers
    # over 5 slots the chain depth is 8 -> allow ~1 s per hop.
    for fid in t_tick:
        assert abs(t_tick[fid] - t_event[fid]) <= 12


def test_bandwidth_sharing_divides_rate():
    sim = BaseSimulation()
    mgr = BandwidthTransferManager(interval=1)
    link, src, dst = _make_link(bandwidth=100 * MB)
    done = {}
    for i in range(4):
        f = File(i, 100 * MB)
        src.add_complete_replica(f)
        mgr.submit(sim, f, link,
                   on_complete=lambda s, n, t: done.__setitem__(t.file.fid, n))
    sim.schedule(mgr, 0)
    sim.run(HOUR)
    # 4 transfers share 100 MB/s -> each runs at 25 MB/s -> ~4 s
    assert all(3 <= v <= 5 for v in done.values())


def test_duration_manager_completes_on_schedule():
    sim = BaseSimulation()
    mgr = DurationTransferManager(duration=30, interval=1)
    link, src, dst = _make_link(throughput=1 * MB)
    f = File(1, 500 * MB)
    src.add_complete_replica(f)
    done = []
    mgr.submit(sim, f, link, on_complete=lambda s, n, t: done.append(n))
    sim.schedule(mgr, 0)
    sim.run(100)
    assert done and abs(done[0] - 30) <= 1


def test_storage_element_limit_enforced():
    site = Site("s")
    se = StorageElement("DISK", site, limit=100 * MB)
    se.add_complete_replica(File(1, 80 * MB))
    assert not se.can_allocate(30 * MB)
    with pytest.raises(RuntimeError):
        se.allocate(File(2, 30 * MB))
    se.delete(1)
    assert se.used == 0
