"""Simulation module: event loop, clock, schedulables (paper §4).

The paper's engine schedules *events* (subprograms) at discrete integer time
points (smallest step: one second). Each event-loop iteration executes every
event of the current time point and advances the clock to the next scheduled
time point — i.e. the clock jumps, it does not tick through empty seconds.

``Schedulable`` is the base class for every event; on execution it may
reschedule itself (``interval``) or schedule new events. ``BaseSimulation``
owns the heap, the clock, and the run loop, and is specialised by scenario
implementations (the built-in one is configuration-file driven, per the
paper; here scenarios are Python config dataclasses in ``repro.core``).
"""

from __future__ import annotations

import heapq
import itertools
from dataclasses import dataclass, field
from typing import Callable, Optional

from repro.obs.metrics import get_registry

SECOND = 1
MINUTE = 60 * SECOND
HOUR = 60 * MINUTE
DAY = 24 * HOUR


class Schedulable:
    """Base class for every event that is scheduled during a run.

    Subclasses implement ``on_update(sim, now)``. If ``interval`` is set the
    event reschedules itself every ``interval`` seconds (the paper's transfer
    generator / transfer manager pattern).
    """

    def __init__(self, interval: Optional[int] = None, priority: int = 0):
        self.interval = interval
        self.priority = priority
        self.cancelled = False

    def on_update(self, sim: "BaseSimulation", now: int) -> None:
        raise NotImplementedError

    def cancel(self) -> None:
        self.cancelled = True


@dataclass(order=True)
class _HeapEntry:
    time: int
    priority: int
    seq: int
    event: Schedulable = field(compare=False)


class BaseSimulation:
    """Owns the clock and the event heap; executes the event loop.

    The smallest time step is one second (integer clock). Every iteration of
    the loop pops all events scheduled for the current earliest time point,
    executes them (ordered by ``priority``, then schedule order), and lets
    self-rescheduling events re-enter the heap.
    """

    def __init__(self, seed: int = 0):
        self._heap: list[_HeapEntry] = []
        self._seq = itertools.count()
        self.now: int = 0
        self.seed = seed
        self._stop_time: Optional[int] = None
        self.events_executed: int = 0  # run-loop work metric (sweep/bench)

    # -- scheduling ---------------------------------------------------------
    def schedule(self, event: Schedulable, at: int) -> None:
        if at < self.now:
            raise ValueError(f"cannot schedule in the past ({at} < {self.now})")
        heapq.heappush(
            self._heap, _HeapEntry(int(at), event.priority, next(self._seq), event)
        )

    def schedule_in(self, event: Schedulable, delay: int) -> None:
        self.schedule(event, self.now + int(delay))

    def call_at(self, when: int, fn: Callable[["BaseSimulation", int], None],
                priority: int = 0) -> Schedulable:
        ev = _FnEvent(fn, priority=priority)
        self.schedule(ev, when)
        return ev

    # -- run loop -----------------------------------------------------------
    def run(self, until: int) -> None:
        """Run the event loop until the clock passes ``until`` (seconds)."""
        self._stop_time = int(until)
        executed_before = self.events_executed
        heap = self._heap
        while heap and heap[0].time <= self._stop_time:
            now = heap[0].time
            self.now = now
            # Execute every event of this time point.
            while heap and heap[0].time == now:
                entry = heapq.heappop(heap)
                ev = entry.event
                if ev.cancelled:
                    continue
                self.events_executed += 1
                ev.on_update(self, now)
                if ev.interval is not None and not ev.cancelled:
                    self.schedule(ev, now + ev.interval)
        self.now = self._stop_time
        # One delta increment per run() call, not per event — the loop
        # body stays registry-free.
        get_registry().inc("engine.events",
                           self.events_executed - executed_before,
                           help="Event-loop pops executed")

    def pending_events(self) -> int:
        return sum(1 for e in self._heap if not e.event.cancelled)


class _FnEvent(Schedulable):
    def __init__(self, fn: Callable[[BaseSimulation, int], None], priority: int = 0):
        super().__init__(interval=None, priority=priority)
        self._fn = fn

    def on_update(self, sim: BaseSimulation, now: int) -> None:
        self._fn(sim, now)
