"""Lane-per-scenario batched sweep backend (``run_sweep(..., backend="jax")``).

The event-driven reference engine (``repro.core.hcdc``) runs one scenario
per Python interpreter; the §5.3 decision workflow wants *grids* of
scenarios. This module runs an entire packed grid as **one** ``jit`` +
``vmap`` JAX program: lane ``l`` is one ``ScenarioSpec``, every lane steps
a shared fixed-tick clock, and per-lane transfer/link state advances
through the carousel tick math — either the scatter-free one-hot jnp
formulation (``tick_impl="jnp"``, the numerical oracle and CPU fast
path) or the fused lane-blocked Pallas kernels
(``repro.kernels.lane_tick``; ``tick_impl="pallas"`` compiled on an
accelerator, ``"pallas_interpret"`` as the CI-runnable parity path).
The implementation axis is the ``tick_impl`` registry
(``repro.kernels.registry``; ``"auto"`` resolves per host) threaded
down from ``run_sweep``/``SweepDriver``. The paper's billing
quantities — GCS
byte-seconds, tiered egress volume, class A/B operation counts — are
accumulated on device per 30-day month bucket and folded into the
existing ``GCSCostModel`` / ``MonthlyBill`` machinery on the way out, so
``backend="jax"`` returns the same ``SweepResult`` shape as the process
backend.

The tick program is **site-vectorized**: every per-site quantity lives in
an ``[S, ...]`` array and the per-tick candidate windows (this tick's job
arrivals, the waiting-queue heads) run as K/W-step prefix recurrences over
``[S, K]``/``[S, W]`` vectors, so the traced program size is O(K+W) —
independent of the site count — and shared-capacity admission (the GCS
cold tier) is a prefix-sum gate over the site-major flattened candidate
array. Consumer counts are maintained *incrementally* (O(S·K) scatters at
submission plus O(S·F) elementwise updates at file arrival) instead of a
per-tick O(S·J) segment-sum over the whole job table.

Large grids execute in bounded device memory through **lane chunking**
(``run_sweep(..., lane_chunk=)``): lanes are split into fixed-size chunks
(the last chunk padded by replication), every chunk reuses one compiled
program, and chunks round-robin across devices when more than one is
visible. ``shard=True`` replaces that Python-loop round-robin with one
``jax.shard_map`` program over a ``"lanes"`` device mesh
(``repro.parallel.sharding.lane_mesh``), and ``transport=``/``workers=``
drain lane-chunk jobs through the persistent worker fleet
(``repro.sim.runners``) — both bitwise-preserving; see
``docs/distributed.md``. ``pack_specs`` rounds the K/J job-window
shapes up to power-of-two buckets so data-dependent shapes stop forcing
recompiles.

Workloads (``repro.sim.workload``): a spec's access-pattern model
compiles to a deterministic per-generator-tick rate/popularity schedule
that ``pack_specs`` folds into the packed per-lane job stream
(``jobs_per_tick``, ``job_*``; the multipliers are exported as
``PackedGrid.rate_mult``), so non-stationary arrival shapes ride through
this backend with zero device-program changes and the grid stays a single
jit+vmap program. Workload-differing specs get distinct dynamics lanes;
only pricing-only variants share one.

Fidelity contract (cross-validated in ``tests/test_batched.py``): the
packed grid replicates the reference engine's catalogue and job-arrival
randomness draw-for-draw, while per-job file selection and run durations
come from the continuation of the same per-lane stream; the fixed tick
quantizes event times by at most one ``dt``. Per-lane jobs-done and bill
totals therefore agree with the event-driven engine within the paper's
Table 2 validation tolerance rather than bitwise (see
``docs/simulation.md`` for when the two clocks can diverge).

Per-tick phase order mirrors the reference generator: transfer advance +
completions -> link-slot FIFO admission -> hot-tier deletions & hot->cold
migrations -> job submissions -> pending-job resolution -> waiting-queue
(disk window) FIFO admission -> storage integration.
"""

from __future__ import annotations

import functools
import time
from typing import TYPE_CHECKING, Callable, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import lane_tick
from repro.kernels.registry import TickImpl, resolve_tick_impl
from repro.obs.metrics import get_registry
from repro.obs.trace import get_tracer
from repro.sim.cloud import bills_from_monthly_totals
from repro.sim.output import TimeSeries
from repro.sim.sweep import ScenarioResult, SweepResult

if TYPE_CHECKING:  # repro.core imports repro.sim; keep runtime acyclic
    from repro.core.scenarios import PackedGrid, ScenarioSpec

# File-location states; must match repro.core.hcdc.
ABSENT, IN_FLIGHT, PRESENT = 0, 1, 2

#: Disk-window (waiting queue) admissions attempted per site per tick. The
#: event engine admits any number per tick; bounding the vectorized window
#: is safe because arrivals are ~0.64 jobs/tick/site (Table 3), far below
#: it — a burst simply drains over the next few ticks.
WAIT_ADMITS_PER_TICK = 4

#: Refinement passes of the shared-GCS admission gate. The reference
#: engine's greedy scan admits every *individually* fitting candidate (a
#: too-big file is skipped, not head-blocking); each prefix-sum pass over
#: the site-major flattened candidate vector admits the next fitting run
#: past a blocker. The passes are shared across sites (the per-site
#: unrolled predecessor gave each site its own three), so a tick with
#: many oversized blockers can under-admit a later site — bounded and
#: self-healing: capacity is never exceeded, and a starved candidate is
#: recomputed as a candidate next tick with fresh passes (a >= 1-tick
#: migration delay in a pathological tick, inside the statistical
#: fidelity contract).
GCS_ADMIT_PASSES = 3

_INF = jnp.float32(jnp.inf)
_NEG_INF = jnp.float32(-jnp.inf)
_BIG_TICKET = jnp.int32(2 ** 30)

#: Per-site link-type order of the captured link-activity series (the
#: ``3 * site + type`` link-id layout).
LINK_TYPES = ("tape_to_disk", "gcs_to_disk", "disk_to_gcs")


def _normalize_record(record_series, n_ticks: int):
    """Normalize a ``record_series=`` argument to ``(stride, n_samples)``
    (or ``None`` when capture is off). ``True`` samples every tick; an
    int samples every that-many ticks (tick 0 always sampled)."""
    if record_series is None or record_series is False:
        return None
    stride = 1 if record_series is True else int(record_series)
    if stride < 1:
        raise ValueError(f"record_series must be >= 1, got {record_series!r}")
    return stride, (n_ticks - 1) // stride + 1


def _lane_step_fns(S: int, K: int, n_months: int, impl: TickImpl,
                   record=None):
    """Build the per-lane tick body and post-scan reduction (closures over
    the static dimensions and the resolved tick implementation).

    Vectorization notes: the per-tick candidate sets (this tick's job
    arrivals, the waiting-queue window) are tiny, so their sequential
    semantics — later candidates see earlier reservations — are computed
    as K/W-step prefix recurrences over ``[S, K]``/``[S, W]`` vectors (all
    sites advance together; the traced program is O(K+W), not O(S·(K+W))),
    and the results land in the big ``[S, F]`` state arrays through *one*
    scatter per array. Scatters use duplicate-safe combinators (``add`` of
    deltas, ``max``/``min`` for flags) because the same file id can appear
    several times in a candidate window.

    Consumer counts (jobs holding a file on the hot tier) are incremental:

    - ``pend_cnt``/``pend_tail`` [S,F]: count and max run-tail of jobs
      submitted whose input file is not yet on disk (+1/+max scatters over
      the K-window at submission; zeroed elementwise when the file
      arrives);
    - ``fin_max`` [S,F]: max analytic finish time (``ready + tail``) over
      jobs whose input is on disk (max-scatter at submission onto present
      files; elementwise ``now + pend_tail`` fold when a file arrives).

    A file has no consumers iff ``pend_cnt == 0`` and ``fin_max <= now`` —
    exactly the condition the previous per-tick segment-sum over the whole
    [S, J] job table computed, at a fraction of the cost.

    When ``impl.use_kernel`` the transfer advance (+ its completion
    billing), the shared-GCS admission scan (+ the GB-second storage
    integration) and the K/W candidate-window recurrences run as the
    fused ``repro.kernels.lane_tick`` Pallas kernels; the surrounding
    scatter/bookkeeping program is shared between implementations.

    ``record`` (``(stride, n_samples)`` or ``None``) turns on per-tick
    series capture: ring buffers sized ``[n_samples + 1, ...]`` ride in
    the scan carry and every tick writes its end-of-tick observables —
    disk/GCS occupancy, waiting-queue depth, running jobs, per-link
    active transfers — at ``t // stride`` when ``t`` is a sample tick
    and into the final *trash slot* otherwise (dropped by ``post_fn``),
    so the per-tick cost stays O(S) and memory O(n_samples * S) per
    lane. With ``record=None`` the carry, the traced program, and the
    results are byte-for-byte the pre-capture ones.
    """
    use_kernel = impl.use_kernel
    interpret = impl.interpret

    def tick_fn(state, xs, const):
        now, dt, month, t, jobs_now = xs
        (sizes, pop, job_fid, job_submit_tick, job_tail, disk_limit,
         gcs_enabled, gcs_limit, min_pop, bw, slots, latency, mode) = const
        F = sizes.shape[1]
        J = job_fid.shape[1]
        st = dict(state)
        site_rows = jnp.arange(S, dtype=jnp.int32)

        # -- consumer snapshot (jobs submitted strictly before this tick
        # that have not finished by ``now``; deletions run before
        # submissions in the reference generator, so this tick's arrivals
        # are excluded — their scatters land at the end of the tick).
        no_cons = (st["pend_cnt"] == 0) & (st["fin_max"] <= now)

        # -- advance transfers one tick (the carousel tick math). A file
        # only ever transfers on its own site's three links (link id =
        # 3*site + type), so the per-link active counts are a one-hot
        # reduction over the link-type axis with no scatter (XLA:CPU
        # expands scatters into O(S·F)-trip sequential loops that
        # dominated the tick before this formulation). The kernel path
        # fuses the same math with the completion billing below in one
        # per-site Pallas block (``lane_tick.transfer_tick``).
        now_prev = now - dt
        t_active = st["tr_slot"] & (st["tr_start"] <= now_prev + 0.5)
        ltype = st["tr_link"] % 3  # 0 tape->disk, 1 gcs->disk, 2 disk->gcs
        loc_onehot = ltype[:, :, None] == jnp.arange(3, dtype=jnp.int32)
        if use_kernel:
            month_onehot = (jnp.arange(n_months, dtype=jnp.int32)
                            == month).astype(jnp.float32)
            (new_done, comp_f, tape_add, recall_add, mig_add,
             egress_add, cls_a_add, cls_b_add) = lane_tick.transfer_tick(
                st["tr_link"], t_active, st["tr_done"], st["tr_total"],
                sizes, bw, mode, dt, month_onehot, interpret=interpret)
            comp = comp_f > 0.5
        else:
            act_f = t_active.astype(jnp.float32)
            counts = jnp.sum(act_f[:, :, None] * loc_onehot,
                             axis=1).reshape(-1)  # [M], M = 3*S
            bw_i = bw[st["tr_link"]]
            shared = bw_i / jnp.maximum(counts[st["tr_link"]], 1.0)
            rate = jnp.where(mode[st["tr_link"]] > 0, bw_i, shared)
            new_done = jnp.minimum(st["tr_total"],
                                   st["tr_done"] + act_f * rate * dt)
            comp = (new_done >= st["tr_total"]) & t_active
        comp_tape = comp & (ltype == 0)
        comp_recall = comp & (ltype == 1)
        comp_mig = comp & (ltype == 2)
        inbound = comp_tape | comp_recall

        st["disk_state"] = jnp.where(inbound, PRESENT, st["disk_state"])
        st["gcs_state"] = jnp.where(comp_mig, PRESENT, st["gcs_state"])
        if use_kernel:  # billing deltas came fused out of the kernel
            st["tape_b"] += tape_add
            st["gcsdisk_b"] += recall_add
            st["diskgcs_b"] += mig_add
            st["egress_mo"] += egress_add
            st["cls_a_mo"] += cls_a_add
            st["cls_b_mo"] += cls_b_add
        else:
            st["tape_b"] += jnp.sum(sizes * comp_tape, axis=1)
            st["gcsdisk_b"] += jnp.sum(sizes * comp_recall, axis=1)
            recall_bytes = jnp.sum(sizes * comp_recall)
            st["egress_mo"] = st["egress_mo"].at[month].add(recall_bytes)
            st["cls_b_mo"] = st["cls_b_mo"].at[month].add(
                jnp.sum(comp_recall).astype(jnp.float32))
            st["diskgcs_b"] += jnp.sum(sizes * comp_mig, axis=1)
            st["cls_a_mo"] = st["cls_a_mo"].at[month].add(
                jnp.sum(comp_mig).astype(jnp.float32))
        # migrated with no remaining consumer: drop the hot copy now
        drop_hot = comp_mig & no_cons & (st["disk_state"] == PRESENT)
        st["disk_used"] -= jnp.sum(sizes * drop_hot, axis=1)
        st["disk_state"] = jnp.where(drop_hot, ABSENT, st["disk_state"])
        st["tr_slot"] = st["tr_slot"] & ~comp
        st["tr_done"] = jnp.where(comp, 0.0, new_done)
        st["tr_total"] = jnp.where(comp, _INF, st["tr_total"])
        st["tr_start"] = jnp.where(comp, _INF, st["tr_start"])

        # arrived files resolve their pending jobs (ready is assigned in
        # the pending step below with the same ``now``): the pending count
        # folds into the analytic finish horizon.
        resolve = inbound & (st["pend_cnt"] > 0)
        st["fin_max"] = jnp.where(
            resolve, jnp.maximum(st["fin_max"], now + st["pend_tail"]),
            st["fin_max"])
        st["pend_cnt"] = jnp.where(inbound, 0, st["pend_cnt"])
        st["pend_tail"] = jnp.where(inbound, 0.0, st["pend_tail"])

        # -- link-slot FIFO admission (tickets are contiguous per link).
        # Link-indexed counters live as [S, 3] matrices (site x link type)
        # so every update is a static column slice, never a scatter.
        occ3 = jnp.sum(st["tr_slot"].astype(jnp.float32)[:, :, None]
                       * loc_onehot, axis=1)  # [S, 3] active-slot counts
        occ = occ3.reshape(-1)
        free = jnp.maximum(slots - occ, 0.0)
        n_q = (st["lq_next"] - st["lq_serve"]).astype(jnp.float32)
        admit = jnp.minimum(free, n_q).astype(jnp.int32)
        new_serve = st["lq_serve"] + admit
        adm_row = st["lq_queued"] & \
            (st["lq_ticket"] < new_serve[st["tr_link"]])
        st["tr_slot"] = st["tr_slot"] | adm_row
        st["tr_start"] = jnp.where(adm_row, now + latency[st["tr_link"]],
                                   st["tr_start"])
        st["lq_queued"] = st["lq_queued"] & ~adm_row
        st["lq_serve"] = new_serve
        occ3 = (occ + admit.astype(jnp.float32)).reshape(S, 3)
        lqn3 = st["lq_next"].reshape(S, 3)   # working [S, 3] views; the
        lqs3 = st["lq_serve"].reshape(S, 3)  # flat [M] state is written
        slots3 = slots.reshape(S, 3)         # back after the windows
        lat3 = latency.reshape(S, 3)

        # -- hot-tier deletions + hot->cold migrations --------------------
        limited = jnp.isfinite(disk_limit)[:, None]
        cand = no_cons & (st["disk_state"] == PRESENT) & limited
        gs = st["gcs_state"]
        migratable = gcs_enabled & (gs == ABSENT) & (pop >= min_pop)
        delete = cand & (~gcs_enabled | (gs == PRESENT)
                         | ((gs == ABSENT) & ~(pop >= min_pop)))
        want_mig = cand & migratable
        # Shared GCS capacity: a prefix-sum admission gate over the
        # site-major flattened candidate vector (one cumsum covers every
        # site; earlier candidates' admissions are visible to later ones),
        # refined over a few passes so a too-big blocker does not head-
        # block the fitting candidates behind it. The kernel path runs
        # each pass as one Pallas call over the sequential site grid,
        # byte totals carried across site blocks and the previous
        # pass's mask re-entering as an aliased input, fusing the
        # end-of-tick GB-second integration; its blocked cumsum
        # reassociates the float totals, so admission matches the jnp
        # program statistically (capacity-boundary ties), not bitwise.
        if use_kernel:
            mig_f, gcs_used, gbsec_add = lane_tick.gcs_admit(
                want_mig, sizes, st["gcs_used"], gcs_limit, dt,
                month_onehot, n_passes=GCS_ADMIT_PASSES,
                interpret=interpret)
            mig = mig_f > 0.5
        else:
            want_flat = want_mig.reshape(-1)
            sizes_flat = sizes.reshape(-1)
            admitted_flat = jnp.zeros((S * F,), bool)
            gcs_used = st["gcs_used"]
            for _ in range(GCS_ADMIT_PASSES):
                rem = want_flat & ~admitted_flat
                csum = jnp.cumsum(sizes_flat * rem)
                new = rem & (gcs_used + csum <= gcs_limit)
                gcs_used = gcs_used + jnp.sum(sizes_flat * new)
                admitted_flat = admitted_flat | new
            mig = admitted_flat.reshape(S, F)
        st["gcs_used"] = gcs_used
        st["gcs_state"] = jnp.where(mig, IN_FLIGHT, gs)
        st["disk_used"] -= jnp.sum(sizes * delete, axis=1)
        st["disk_state"] = jnp.where(delete, ABSENT, st["disk_state"])
        # submit migrations on each site's disk->gcs link (FIFO: direct
        # slots only while the link queue is empty, overflow queues)
        mlink = 3 * site_rows + 2  # [S]
        rank = jnp.cumsum(mig.astype(jnp.float32), axis=1) - 1.0
        q_empty = (lqn3[:, 2] == lqs3[:, 2])[:, None]
        free_m = jnp.maximum(slots3[:, 2] - occ3[:, 2], 0.0)[:, None]
        direct = mig & q_empty & (rank < free_m)
        queued = mig & ~direct
        qrank = jnp.cumsum(queued.astype(jnp.int32), axis=1) - 1
        st["tr_slot"] = st["tr_slot"] | direct
        st["tr_link"] = jnp.where(mig, mlink[:, None], st["tr_link"])
        st["tr_total"] = jnp.where(mig, sizes, st["tr_total"])
        st["tr_done"] = jnp.where(mig, 0.0, st["tr_done"])
        st["tr_start"] = jnp.where(direct, now, st["tr_start"])
        st["lq_ticket"] = jnp.where(
            queued, lqn3[:, 2][:, None] + qrank, st["lq_ticket"])
        st["lq_queued"] = st["lq_queued"] | queued
        lqn3 = lqn3.at[:, 2].add(
            jnp.sum(queued, axis=1).astype(jnp.int32))
        occ3 = occ3.at[:, 2].add(jnp.sum(direct, axis=1).astype(jnp.float32))

        # =================================================================
        # Candidate-window planning, site-batched. This tick's job arrivals
        # (K per site) and the waiting-queue heads (W per site) are tiny
        # windows; their sequential semantics — later candidates see
        # earlier reservations — run as K/W-step prefix recurrences over
        # [S, K]/[S, W] vectors, and every resulting state change is
        # DEFERRED and applied below as a single duplicate-safe scatter
        # per array.
        # =================================================================
        W = WAIT_ADMITS_PER_TICK
        plans = []  # per group: dict of planned per-candidate [S, C] vecs

        def plan_links(fids, fire, occ3):
            """Assign link slots / FIFO queue tickets to fired candidates
            (``fids``/``fire`` are [S, C]; candidate windows only touch
            their own site's tape->disk / gcs->disk links, so all sites
            plan in parallel).

            Mutates only the small [S, 3] occupancy/ticket counters;
            returns the per-candidate plan (direct slot, queue ticket,
            start time).
            """
            from_gcs = gcs_enabled & (
                jnp.take_along_axis(st["gcs_state"], fids, axis=1)
                == PRESENT)
            link_local = jnp.where(from_gcs, 1, 0)
            direct = jnp.zeros_like(fire)
            queued = jnp.zeros_like(fire)
            tstart = jnp.full(fire.shape, jnp.inf, jnp.float32)
            lq_val = jnp.zeros(fire.shape, jnp.int32)
            nonlocal lqn3
            for loc in (0, 1):  # tape->disk, gcs->disk
                mask = fire & (link_local == loc)
                q_empty = (lqn3[:, loc] == lqs3[:, loc])[:, None]
                free_m = jnp.maximum(slots3[:, loc] - occ3[:, loc],
                                     0.0)[:, None]
                rk = jnp.cumsum(mask.astype(jnp.float32), axis=1) - 1.0
                d = mask & q_empty & (rk < free_m)
                qd = mask & ~d
                qrk = jnp.cumsum(qd.astype(jnp.int32), axis=1) - 1
                direct = direct | d
                queued = queued | qd
                tstart = jnp.where(d, now + lat3[:, loc][:, None], tstart)
                lq_val = jnp.where(qd, lqn3[:, loc][:, None] + qrk,
                                   lq_val)
                lqn3 = lqn3.at[:, loc].add(
                    jnp.sum(qd, axis=1).astype(jnp.int32))
                occ3 = occ3.at[:, loc].add(
                    jnp.sum(d, axis=1).astype(jnp.float32))
            rows = site_rows[:, None] * F + fids
            return occ3, dict(rows=rows, fire=fire,
                             m_vec=3 * site_rows[:, None] + link_local,
                             direct=direct, queued=queued, tstart=tstart,
                             lq_val=lq_val)

        # -- group 1: job submissions for this tick (only the first arrival
        # of a file starts its transfer; later same-tick jobs attach) -----
        started = jnp.zeros((S, 0), bool)
        g1_fids = jnp.zeros((S, 0), jnp.int32)
        if K > 0:
            ks = jnp.arange(K, dtype=jnp.int32)
            jpos = st["ptr"][:, None] + ks[None, :]  # [S, K]
            jid = jnp.minimum(jpos, J - 1)
            valid = (jpos < J) & \
                (jnp.take_along_axis(job_submit_tick, jid, axis=1) == t)
            fids = jnp.take_along_axis(job_fid, jid, axis=1)
            g1_fids = fids
            # same[s, k, j]: an earlier valid window slot j < k carries the
            # same file — slot k attaches instead of starting a transfer.
            same = (fids[:, None, :] == fids[:, :, None]) \
                & valid[:, None, :] & (ks[None, None, :] < ks[None, :, None])
            first = valid & ~jnp.any(same, axis=2)
            size = jnp.take_along_axis(sizes, fids, axis=1)
            ds = jnp.take_along_axis(st["disk_state"], fids, axis=1)
            ww = jnp.take_along_axis(st["wq_wait"], fids, axis=1)
            tailw = jnp.take_along_axis(job_tail, jid, axis=1)
            absent = first & (ds == ABSENT)
            if use_kernel:
                started_f, extra = lane_tick.window_admit(
                    absent, size, st["disk_used"], disk_limit,
                    fifo=False, interpret=interpret)
                started = started_f > 0.5
            else:
                started_cols = []
                extra = jnp.zeros((S,), jnp.float32)
                for k in range(K):  # prefix recurrence over the window;
                    fit = st["disk_used"] + extra + size[:, k] \
                        <= disk_limit   # all sites advance together
                    st_k = absent[:, k] & fit
                    started_cols.append(st_k)
                    extra = extra + jnp.where(st_k, size[:, k], 0.0)
                started = jnp.stack(started_cols, axis=1)  # [S, K]
            st["disk_used"] = st["disk_used"] + extra
            to_wait = absent & ~started & ~ww
            wrank = jnp.cumsum(to_wait.astype(jnp.int32), axis=1) - 1
            occ3, plan = plan_links(fids, started, occ3)
            plan["to_wait"] = to_wait
            plan["wq_val"] = jnp.where(to_wait,
                                       st["wq_next"][:, None] + wrank, 0)
            st["wq_next"] = st["wq_next"] + \
                jnp.sum(to_wait, axis=1).astype(jnp.int32)
            plan["stale"] = jnp.zeros_like(started)
            # incremental consumer deltas: window jobs whose file is on
            # disk are ready this tick (analytic finish now + tail); the
            # rest join the pending pool on their file.
            ready_now = valid & (ds == PRESENT)
            plan["pend_add"] = valid & ~ready_now
            plan["fin_val"] = jnp.where(ready_now, now + tailw, _NEG_INF)
            plan["tail"] = tailw
            plans.append(plan)
        st["ptr"] = st["ptr"] + jobs_now

        # -- group 2: waiting-queue admission — strict FIFO on the disk
        # window; the head blocks admission until its file fits (§5.2).
        # Planned from the pre-scatter queue state: entries started above
        # (queue-jump) are excluded by fid comparison; entries enqueued
        # above are not yet visible (they join next tick, matching a tail
        # position in the FIFO).
        tickets = jnp.where(st["wq_wait"], st["wq_ticket"], _BIG_TICKET)
        neg, idx = jax.lax.top_k(-tickets, W)  # [S, W] lowest tickets
        validw = neg > -_BIG_TICKET
        jumped = jnp.zeros(idx.shape, bool)
        if K > 0:
            started_fid = jnp.where(started, g1_fids, -1)  # [S, K]
            jumped = jnp.any(idx[:, :, None] == started_fid[:, None, :],
                             axis=2)
        ds = jnp.take_along_axis(st["disk_state"], idx, axis=1)
        stale = validw & ((ds != ABSENT) | jumped)
        size = jnp.take_along_axis(sizes, idx, axis=1)
        if use_kernel:
            admitted_f, extra = lane_tick.window_admit(
                validw & ~stale, size, st["disk_used"], disk_limit,
                fifo=True, interpret=interpret)
            admitted = admitted_f > 0.5
        else:
            adm_cols = []
            extra = jnp.zeros((S,), jnp.float32)
            blocked = jnp.zeros((S,), bool)
            for k in range(W):  # FIFO prefix recurrence, sites together
                fit = st["disk_used"] + extra + size[:, k] <= disk_limit
                live = validw[:, k] & ~stale[:, k]
                adm = live & fit & ~blocked
                blocked = blocked | (live & ~fit)
                adm_cols.append(adm)
                extra = extra + jnp.where(adm, size[:, k], 0.0)
            admitted = jnp.stack(adm_cols, axis=1)  # [S, W]
        st["disk_used"] = st["disk_used"] + extra
        occ3, plan = plan_links(idx, admitted, occ3)
        plan["stale"] = stale
        plans.append(plan)

        st["lq_next"] = lqn3.reshape(-1)

        # -- pending jobs whose input is on disk enter queued -> running;
        # completion is analytic (ready + download + duration). Planned
        # starts only flip ABSENT -> IN_FLIGHT, so the pre-scatter
        # disk_state is PRESENT-accurate here. ----------------------------
        pending = (job_submit_tick <= t) & (st["job_ready"] >= _INF)
        on_disk = jnp.take_along_axis(st["disk_state"], job_fid,
                                      axis=1) == PRESENT
        st["job_ready"] = jnp.where(pending & on_disk, now, st["job_ready"])

        # -- apply the planned windows: one scatter per state array.
        # XLA:CPU expands each scatter into a sequential per-row loop, so
        # rows are kept to the minimum: transfer/link plans scatter over
        # both windows; the submission-only fields (wait-queue joins and
        # the incremental consumer counters) exist only in the K-window
        # and scatter over a third of the rows.
        def cat(key):
            return jnp.concatenate([p[key].reshape(-1) for p in plans])

        rows = cat("rows")
        fire = cat("fire")
        stale = cat("stale")
        m_vec = cat("m_vec")
        direct = cat("direct")
        queued = cat("queued")
        tstart = cat("tstart")
        lq_val = cat("lq_val")
        size_c = sizes.reshape(-1)[rows]

        def flat(name, update):
            st[name] = update(st[name].reshape(-1)).reshape(S, F)

        cur_link = st["tr_link"].reshape(-1)[rows]
        cur_lqt = st["lq_ticket"].reshape(-1)[rows]
        flat("disk_state", lambda a: a.at[rows].add(
            jnp.where(fire, IN_FLIGHT - ABSENT, 0)))
        # started/stale entries leave the wait queue (new waiters join in
        # the K-window block below, preserving the min-before-max order)
        flat("wq_wait", lambda a: a.at[rows].min(~(fire | stale)))
        flat("tr_link", lambda a: a.at[rows].add(
            jnp.where(fire, m_vec - cur_link, 0)))
        flat("tr_total", lambda a: a.at[rows].min(
            jnp.where(fire, size_c, _INF)))
        flat("tr_slot", lambda a: a.at[rows].max(direct))
        flat("tr_start", lambda a: a.at[rows].min(tstart))
        flat("lq_ticket", lambda a: a.at[rows].add(
            jnp.where(queued, lq_val - cur_lqt, 0)))
        flat("lq_queued", lambda a: a.at[rows].max(queued))

        if K > 0:  # K-window-only scatters (wait-queue joins + consumers)
            g1 = plans[0]
            rows1 = g1["rows"].reshape(-1)
            to_wait = g1["to_wait"].reshape(-1)
            wq_val = g1["wq_val"].reshape(-1)
            pend_add = g1["pend_add"].reshape(-1)
            fin_val = g1["fin_val"].reshape(-1)
            tail_c = g1["tail"].reshape(-1)
            cur_wqt = st["wq_ticket"].reshape(-1)[rows1]
            flat("wq_wait", lambda a: a.at[rows1].max(to_wait))
            flat("wq_ticket", lambda a: a.at[rows1].add(
                jnp.where(to_wait, wq_val - cur_wqt, 0)))
            # incremental consumer counters (visible from the next tick
            # on, matching the reference's deletions-before-submissions)
            flat("pend_cnt", lambda a: a.at[rows1].add(
                jnp.where(pend_add, 1, 0)))
            flat("pend_tail", lambda a: a.at[rows1].max(
                jnp.where(pend_add, tail_c, 0.0)))
            flat("fin_max", lambda a: a.at[rows1].max(fin_val))

        # -- integrate stored cloud volume (GB-seconds) per month ---------
        # (kernel path: fused into ``gcs_admit`` above — ``gcs_used`` is
        # final for the tick once admission has run)
        if use_kernel:
            st["gbsec_mo"] += gbsec_add
        else:
            st["gbsec_mo"] = st["gbsec_mo"].at[month].add(
                st["gcs_used"] / 1e9 * dt)

        # -- opt-in series capture (end-of-tick observables) --------------
        if record is not None:
            stride, n_samples = record
            idx = jnp.where(t % stride == 0, t // stride,
                            jnp.int32(n_samples))
            queue = jnp.sum(st["wq_wait"], axis=1).astype(jnp.float32)
            running = jnp.sum(
                (st["job_ready"] < _INF)
                & (st["job_ready"] + job_tail > now),
                axis=1).astype(jnp.float32)
            active3 = jnp.sum(
                st["tr_slot"].astype(jnp.float32)[:, :, None]
                * ((st["tr_link"] % 3)[:, :, None]
                   == jnp.arange(3, dtype=jnp.int32)), axis=1)  # [S, 3]
            upd = jax.lax.dynamic_update_index_in_dim
            st["ser_disk"] = upd(st["ser_disk"], st["disk_used"], idx, 0)
            st["ser_gcs"] = upd(st["ser_gcs"], st["gcs_used"], idx, 0)
            st["ser_queue"] = upd(st["ser_queue"], queue, idx, 0)
            st["ser_run"] = upd(st["ser_run"], running, idx, 0)
            st["ser_link"] = upd(st["ser_link"], active3, idx, 0)
        return st, None

    def post_fn(st, lane, horizon):
        (sizes, job_fid, job_submit_time, job_tail) = lane
        ready = st["job_ready"] < _INF
        done = ready & (st["job_ready"] + job_tail <= horizon)
        job_sizes = jnp.take_along_axis(sizes, job_fid, axis=1)
        wait_h = (st["job_ready"] - job_submit_time) / 3600.0
        series = {}
        if record is not None:
            n_samples = record[1]  # drop the trash slot
            series = {k: st[k][:n_samples]
                      for k in ("ser_disk", "ser_gcs", "ser_queue",
                                "ser_run", "ser_link")}
        return {
            **series,
            "jobs_done_site": jnp.sum(done, axis=1),
            "download_b": jnp.sum(job_sizes * ready, axis=1),
            "wait_h_sum": jnp.sum(jnp.where(ready, wait_h, 0.0)),
            "wait_n": jnp.sum(ready),
            "disk_used": st["disk_used"],
            "gcs_used": st["gcs_used"],
            "tape_b": st["tape_b"],
            "gcsdisk_b": st["gcsdisk_b"],
            "diskgcs_b": st["diskgcs_b"],
            "egress_mo": st["egress_mo"],
            "cls_a_mo": st["cls_a_mo"],
            "cls_b_mo": st["cls_b_mo"],
            "gbsec_mo": st["gbsec_mo"],
        }

    return tick_fn, post_fn


def _build_lane_sim(S: int, K: int, n_months: int, impl_name: str,
                    record=None):
    """The single-lane simulation function (closure over the static
    dimensions): 5 shared tick-grid arguments + the 15 ``_LANE_FIELDS``
    arrays -> the per-lane aggregate dict. ``_grid_program`` vmaps it
    over the lane axis; ``_shard_program`` additionally shard_maps the
    vmapped program over a device mesh."""
    tick_fn, post_fn = _lane_step_fns(S, K, n_months,
                                      resolve_tick_impl(impl_name),
                                      record=record)

    def lane_sim(times, dts, month_idx, t_idx, horizon,
                 disk_limit, gcs_enabled, gcs_limit, min_pop,
                 bw, slots, latency, mode, sizes, pop,
                 job_fid, job_submit_tick, job_submit_time, job_tail,
                 jobs_per_tick):
        F = sizes.shape[1]
        J = job_fid.shape[1]
        M = bw.shape[0]
        const = (sizes, pop, job_fid, job_submit_tick, job_tail,
                 disk_limit, gcs_enabled, gcs_limit, min_pop,
                 bw, slots, latency, mode)
        init = dict(
            disk_state=jnp.zeros((S, F), jnp.int32),
            gcs_state=jnp.zeros((S, F), jnp.int32),
            disk_used=jnp.zeros((S,), jnp.float32),
            gcs_used=jnp.float32(0.0),
            tr_slot=jnp.zeros((S, F), bool),
            tr_link=jnp.zeros((S, F), jnp.int32),
            tr_done=jnp.zeros((S, F), jnp.float32),
            tr_total=jnp.full((S, F), jnp.inf, jnp.float32),
            tr_start=jnp.full((S, F), jnp.inf, jnp.float32),
            lq_ticket=jnp.zeros((S, F), jnp.int32),
            lq_queued=jnp.zeros((S, F), bool),
            lq_serve=jnp.zeros((M,), jnp.int32),
            lq_next=jnp.zeros((M,), jnp.int32),
            wq_wait=jnp.zeros((S, F), bool),
            wq_ticket=jnp.zeros((S, F), jnp.int32),
            wq_next=jnp.zeros((S,), jnp.int32),
            pend_cnt=jnp.zeros((S, F), jnp.int32),
            pend_tail=jnp.zeros((S, F), jnp.float32),
            fin_max=jnp.zeros((S, F), jnp.float32),
            job_ready=jnp.full((S, J), jnp.inf, jnp.float32),
            ptr=jnp.zeros((S,), jnp.int32),
            tape_b=jnp.zeros((S,), jnp.float32),
            gcsdisk_b=jnp.zeros((S,), jnp.float32),
            diskgcs_b=jnp.zeros((S,), jnp.float32),
            egress_mo=jnp.zeros((n_months,), jnp.float32),
            cls_a_mo=jnp.zeros((n_months,), jnp.float32),
            cls_b_mo=jnp.zeros((n_months,), jnp.float32),
            gbsec_mo=jnp.zeros((n_months,), jnp.float32),
        )
        if record is not None:
            n_samples = record[1]  # +1 = the non-sample-tick trash slot
            init.update(
                ser_disk=jnp.zeros((n_samples + 1, S), jnp.float32),
                ser_gcs=jnp.zeros((n_samples + 1,), jnp.float32),
                ser_queue=jnp.zeros((n_samples + 1, S), jnp.float32),
                ser_run=jnp.zeros((n_samples + 1, S), jnp.float32),
                ser_link=jnp.zeros((n_samples + 1, S, 3), jnp.float32),
            )
        final, _ = jax.lax.scan(
            lambda c, xs: tick_fn(c, xs, const), init,
            (times, dts, month_idx, t_idx, jobs_per_tick))
        return post_fn(final, (sizes, job_fid, job_submit_time, job_tail),
                       horizon)

    return lane_sim


#: vmap axes of ``lane_sim``: 5 shared tick-grid args + 15 lane arrays.
_LANE_AXES = (None, None, None, None, None) + (0,) * 15


@functools.lru_cache(maxsize=16)
def _grid_program(S: int, K: int, n_months: int, impl_name: str,
                  record=None):
    """The jitted lane-vmapped simulation (cached per static shape family,
    concrete ``tick_impl`` name, and series-capture configuration; XLA
    additionally retraces per concrete array shape — ``pack_specs``'s
    K/J power-of-two bucketing and ``lane_chunk`` keep those shapes
    stable across grids)."""
    lane_sim = _build_lane_sim(S, K, n_months, impl_name, record)
    return jax.jit(jax.vmap(lane_sim, in_axes=_LANE_AXES))


@functools.lru_cache(maxsize=16)
def _shard_program(S: int, K: int, n_months: int, impl_name: str,
                   record, n_shards: int):
    """The sharded grid program: ``shard_map`` of the lane-vmapped
    simulation over a ``n_shards``-device ``"lanes"`` mesh
    (``repro.parallel.sharding.lane_mesh``).

    Each device runs the identical vmapped per-lane program on its
    1/``n_shards`` slice of the lane batch — lanes never interact, so
    there are no collectives and per-lane results are bitwise identical
    to the unsharded program (asserted in ``tests/test_batched.py``).
    The lane-axis extent of every lane argument must divide
    ``n_shards``; callers pad by replicating the last lane, exactly as
    the chunked path does. The 5 shared tick-grid arguments are
    replicated to every device."""
    from jax.experimental.shard_map import shard_map

    from repro.parallel.sharding import LANES_AXIS, lane_mesh

    lane_sim = _build_lane_sim(S, K, n_months, impl_name, record)
    mesh = lane_mesh(n_shards)
    P = jax.sharding.PartitionSpec
    in_specs = (P(),) * 5 + (P(LANES_AXIS),) * 15
    sharded = shard_map(jax.vmap(lane_sim, in_axes=_LANE_AXES),
                        mesh=mesh, in_specs=in_specs,
                        out_specs=P(LANES_AXIS))
    return jax.jit(sharded)


#: Per-lane array attributes of ``PackedGrid``, in ``lane_sim`` argument
#: order (after the five shared tick-grid arguments).
_LANE_FIELDS = ("disk_limit", "gcs_enabled", "gcs_limit", "min_migrate_pop",
                "link_bw", "link_slots", "link_latency", "link_mode",
                "sizes", "pop", "job_fid", "job_submit_tick",
                "job_submit_time", "job_tail", "jobs_per_tick")


def simulate_packed(grid: "PackedGrid", tick_impl: str = "auto",
                    lane_chunk: Optional[int] = None,
                    devices: Optional[Sequence] = None,
                    record_series=None, shard: bool = False):
    """Run a packed grid on device; returns the raw per-lane aggregate dict
    (numpy arrays, lane-leading).

    ``tick_impl`` selects the tick-engine implementation
    (``repro.kernels.registry``): ``"jnp"`` | ``"pallas"`` |
    ``"pallas_interpret"`` | ``"auto"`` (compiled Pallas on an
    accelerator, jnp on CPU — never silently interpret mode). The
    pre-registry ``use_pallas=``/``interpret=`` aliases are gone; a
    boolean landing in the ``tick_impl`` slot raises with the upgrade
    hint (``resolve_tick_impl``).

    ``lane_chunk`` bounds device memory: lanes execute in fixed-size
    chunks (the last chunk padded by replicating its final lane; padded
    results are discarded), every chunk reusing one compiled program.
    Per-lane results are bitwise identical to the unchunked path — lanes
    never interact. ``devices`` (default: all local devices) receives the
    chunks round-robin when more than one is present.

    ``record_series`` (``True`` = sample every tick, an int = sample
    stride in ticks, default off) adds the end-of-tick series buffers to
    the result — ``ser_disk``/``ser_queue``/``ser_run`` ``[L, T_sample,
    S]``, ``ser_gcs`` ``[L, T_sample]``, ``ser_link`` ``[L, T_sample,
    S, 3]`` — at O(T_sample * S) device memory per lane; convert with
    ``series_from_capture``. Capture off traces the exact pre-capture
    program, so those results stay bitwise identical.

    ``shard=True`` replaces the per-chunk Python loop's device
    round-robin with **one** ``shard_map`` program over a ``"lanes"``
    device mesh (``repro.parallel.sharding.lane_mesh`` over all local
    devices): the lane batch is padded to a multiple of the mesh size
    (replicating the last lane) and each device runs its slice of the
    same vmapped program — no collectives, so per-lane results stay
    bitwise identical to the unsharded path. ``lane_chunk`` still
    bounds memory (each chunk runs sharded, its size rounded up to a
    mesh multiple); ``devices=`` is the round-robin path's knob and is
    rejected together with ``shard``.
    """
    impl = resolve_tick_impl(tick_impl)
    record = _normalize_record(record_series, grid.n_ticks)
    if lane_chunk is not None and lane_chunk <= 0:
        raise ValueError(f"lane_chunk must be > 0, got {lane_chunk!r}")
    if shard and devices is not None:
        raise ValueError("shard=True builds a lane mesh over the local "
                         "devices; devices= applies to the round-robin "
                         "path only")
    devices = list(devices) if devices is not None else jax.local_devices()
    if not devices:
        raise ValueError("devices must be a non-empty sequence")
    L = grid.n_lanes
    n_shards = len(devices) if shard else 0
    if not shard and lane_chunk is None and len(devices) > 1:
        lane_chunk = -(-L // len(devices))  # spread one chunk per device

    tracer = get_tracer()
    S, K = len(grid.site_names), grid.max_jobs_per_tick
    if n_shards:
        program = _shard_program(S, K, grid.n_months, impl.name, record,
                                 n_shards)
    else:
        program = _grid_program(S, K, grid.n_months, impl.name, record)
    T = grid.n_ticks
    shared = (np.asarray(grid.times), np.asarray(grid.dts),
              np.asarray(grid.month_idx), np.arange(T, dtype=np.int32),
              np.float32(grid.horizon))
    lanes = [np.asarray(getattr(grid, name)) for name in _LANE_FIELDS]

    def pad_lanes(chunk, n, C):
        """Pad a ``n``-lane slice to ``C`` by replicating its last lane
        (padded results are discarded; lanes never interact)."""
        if n >= C:
            return chunk
        return [np.concatenate([a] + [a[-1:]] * (C - n), axis=0)
                for a in chunk]

    if lane_chunk is None or lane_chunk >= L:
        C = -(-L // n_shards) * n_shards if n_shards else L
        with tracer.span("simulate_packed", lanes=L, ticks=T,
                         tick_impl=impl.name, chunks=1, shards=n_shards):
            out = program(*shared, *pad_lanes(lanes, L, C))
            return {k: np.asarray(v)[:L] for k, v in out.items()}

    C = int(lane_chunk)
    if n_shards:
        C = -(-C // n_shards) * n_shards  # each chunk shards evenly
    chunk_outs = []
    for ci, start in enumerate(range(0, L, C)):
        stop = min(start + C, L)
        chunk = pad_lanes([a[start:stop] for a in lanes], stop - start, C)
        dev = devices[ci % len(devices)]
        with tracer.span("simulate_packed.chunk", chunk=ci,
                         lanes=stop - start, tick_impl=impl.name,
                         shards=n_shards):
            if len(devices) > 1 and not n_shards:
                # commit every argument so each chunk dispatches (and can
                # execute concurrently) on its own device
                args = [jax.device_put(a, dev)
                        for a in (*shared, *chunk)]
                chunk_outs.append(program(*args))
            else:
                chunk_outs.append(program(*shared, *chunk))
    out = {k: np.concatenate([np.asarray(o[k]) for o in chunk_outs],
                             axis=0)[:L]
           for k in chunk_outs[0]}
    return out


def _lane_result(grid: "PackedGrid", out: dict, si: int,
                 wall_s: float, lane_base: int = 0) -> ScenarioResult:
    """Fold one spec's dynamics-lane aggregates into a ``ScenarioResult``
    with the same metric keys the event-driven ``HCDCScenario.metrics``
    emits. Several specs may share one simulated lane (pricing-only
    variants); each is billed with its own cost model.

    ``lane_base`` shifts the lane index when ``out`` holds only a chunk
    of the grid's lanes (the resilient lane-chunk job path journals
    results per chunk, before the full arrays exist)."""
    spec = grid.specs[si]
    li = int(grid.lane_of[si]) - lane_base
    names = grid.site_names
    jobs_done_site = out["jobs_done_site"][li]
    m = {
        "jobs_done": float(jobs_done_site.sum()),
        "jobs_submitted": float(grid.n_jobs[li].sum()),
        "download_pb": float(out["download_b"][li].sum()) / 1e15,
        "gcs_to_disk_pb": float(out["gcsdisk_b"][li].sum()) / 1e15,
        "disk_to_gcs_pb": float(out["diskgcs_b"][li].sum()) / 1e15,
        "gcs_used_pb": float(out["gcs_used"][li]) / 1e15,
        "job_waiting_h_mean": (float(out["wait_h_sum"][li])
                               / max(float(out["wait_n"][li]), 1.0)),
    }
    for s, name in enumerate(names):
        m[f"{name}.tape_to_disk_pb"] = float(out["tape_b"][li, s]) / 1e15
        m[f"{name}.jobs_done"] = float(jobs_done_site[s])
        m[f"{name}.disk_used_pb"] = float(out["disk_used"][li, s]) / 1e15
    bills = bills_from_monthly_totals(
        grid.cost_models[si], out["gbsec_mo"][li], out["egress_mo"][li],
        out["cls_a_mo"][li], out["cls_b_mo"][li], grid.full_months)
    for i, bill in enumerate(bills):
        m[f"month{i+1}.storage_usd"] = bill.storage_usd
        m[f"month{i+1}.network_usd"] = bill.network_usd
    # Raw monthly billing inputs (pricing-independent): exact float()
    # images of the device aggregates, so re-billing them through
    # ``bills_from_monthly_totals`` — the result cache's serve path —
    # reproduces the bills above bit-exactly under any cost model.
    monthly = {
        "gb_seconds": [float(x) for x in out["gbsec_mo"][li]],
        "egress_bytes": [float(x) for x in out["egress_mo"][li]],
        "class_a": [float(x) for x in out["cls_a_mo"][li]],
        "class_b": [float(x) for x in out["cls_b_mo"][li]],
        "full_months": int(grid.full_months),
    }
    return ScenarioResult(
        spec=spec,
        metrics=m,
        storage_usd=sum(b.storage_usd for b in bills),
        network_usd=sum(b.network_usd for b in bills),
        ops_usd=sum(b.ops_usd for b in bills),
        wall_s=wall_s,
        events=grid.n_ticks,
        monthly=monthly,
    )


def series_from_capture(grid: "PackedGrid", out: Dict[str, np.ndarray],
                        si: int, record_series) -> Dict[str, "TimeSeries"]:
    """Convert one spec's on-device series buffers to ``TimeSeries``.

    ``out`` must come from a ``simulate_packed(..., record_series=...)``
    call with the *same* ``record_series`` value. Names match the event
    engine's ``OutputCollector`` where both backends record the
    observable — ``"{site}.disk_used"``, ``"gcs_used"``,
    ``"{site}.running_jobs"`` — plus JAX-only series:
    ``"{site}.wait_queue"`` (distinct files with waiting jobs) and
    ``"{site}.link_active.{tape_to_disk,gcs_to_disk,disk_to_gcs}"``
    (transfer slots active on each link type).
    """
    record = _normalize_record(record_series, grid.n_ticks)
    if record is None:
        raise ValueError(
            "series_from_capture requires the record_series value the "
            f"grid was simulated with, got {record_series!r}")
    if "ser_disk" not in out:
        raise KeyError(
            "no series buffers in this result — was simulate_packed "
            "called with record_series on?")
    stride, _ = record
    li = int(grid.lane_of[si])
    times = [float(t) for t in np.asarray(grid.times)[::stride]]

    series: Dict[str, TimeSeries] = {}

    def add(name: str, values: np.ndarray) -> None:
        series[name] = TimeSeries(name, times=list(times),
                                  values=[float(v) for v in values])

    add("gcs_used", out["ser_gcs"][li])
    for s, name in enumerate(grid.site_names):
        add(f"{name}.disk_used", out["ser_disk"][li, :, s])
        add(f"{name}.running_jobs", out["ser_run"][li, :, s])
        add(f"{name}.wait_queue", out["ser_queue"][li, :, s])
        for k, link in enumerate(LINK_TYPES):
            add(f"{name}.link_active.{link}", out["ser_link"][li, :, s, k])
    return series


#: Default lane-chunk size for the resilient job path when the caller
#: did not pick one: small enough that an abandoned job loses little
#: work, large enough that per-chunk dispatch overhead stays trivial.
_RESILIENT_LANE_CHUNK = 8

#: Default lane-chunk size on the worker fleet: each chunk pays a frame
#: round trip, so fleet chunks are bigger than the in-process resilient
#: default (a lost chunk still re-runs in seconds).
_FLEET_LANE_CHUNK = 64


def lane_chunk_runner(ctx: Dict) -> Callable:
    """Build the worker-side runner for lane-chunk job payloads.

    ``ctx`` is the fleet init context built by ``_simulate_packed_jobs``:
    static shapes (``S``/``K``/``n_months``), the *concrete* tick-impl
    name (resolved in the dispatcher so ``"auto"`` cannot diverge per
    host), the normalized series-capture config, the shard count (0 =
    unsharded), and the 5 shared tick-grid arrays — shipped once at
    init, never per job. Each payload is ``{"chunk": [...15 lane
    arrays...], "n": valid_lanes}``, already padded to the program's
    chunk size by the dispatcher; the runner executes the same compiled
    program the serial path uses and truncates the padding, so fleet
    results are bitwise identical to serial ones.
    """
    impl = resolve_tick_impl(ctx["tick_impl"])
    n_shards = int(ctx.get("shard", 0))
    builder_args = (ctx["S"], ctx["K"], ctx["n_months"], impl.name,
                    ctx["record"])
    if n_shards:
        program = _shard_program(*builder_args, n_shards)
    else:
        program = _grid_program(*builder_args)
    shared = tuple(ctx["shared"])

    def run(payload):
        out = program(*shared, *payload["chunk"])
        return {k: np.asarray(v)[:payload["n"]] for k, v in out.items()}

    return run


def _simulate_packed_jobs(grid: "PackedGrid", *, tick_impl: str,
                          lane_chunk: Optional[int], record_series,
                          faults, retry, job_timeout,
                          journal: Optional[Callable],
                          workers: Optional[int] = None,
                          transport=None, shard: bool = False):
    """Run a packed grid as retryable lane-chunk jobs.

    Each job executes one fixed-size slice of the grid's dynamics lanes
    through the same compiled program the plain chunked path uses, so a
    converged fault-injected run is bitwise identical to a fault-free
    one (lanes never interact; see ``simulate_packed``). Completed
    chunks are journaled through ``journal`` as they land (checkpointed
    resume); abandoned chunks leave their lanes out of the stitched
    output and are reported via the returned registry.

    ``transport`` engages the worker fleet (``repro.sim.runners``): up
    to ``workers`` persistent workers each compile the chunk program
    once (the shared tick-grid arrays ship once in the init context)
    and are fed per-chunk lane slices — the grid itself never crosses
    the wire whole. ``shard`` makes every chunk execute as one
    ``shard_map`` program over the local-device lane mesh (composable
    with the fleet: the flag rides the init context, so each worker
    shards over *its* local devices).

    Returns ``(out, registry, missing_lanes)`` where ``out`` has the
    ``simulate_packed`` shape (zero-filled for missing lanes — callers
    must skip those via ``missing_lanes``).
    """
    from repro.sim import jobs as joblib

    impl = resolve_tick_impl(tick_impl)
    record = _normalize_record(record_series, grid.n_ticks)
    if lane_chunk is not None and lane_chunk <= 0:
        raise ValueError(f"lane_chunk must be > 0, got {lane_chunk!r}")
    L = grid.n_lanes
    if lane_chunk is not None:
        C = int(lane_chunk)
    else:
        C = min(L, _FLEET_LANE_CHUNK if transport is not None
                else _RESILIENT_LANE_CHUNK)
    n_shards = len(jax.local_devices()) if shard else 0
    if n_shards:
        C = -(-C // n_shards) * n_shards  # chunks shard evenly
    S, K = len(grid.site_names), grid.max_jobs_per_tick
    T = grid.n_ticks
    shared = (np.asarray(grid.times), np.asarray(grid.dts),
              np.asarray(grid.month_idx), np.arange(T, dtype=np.int32),
              np.float32(grid.horizon))
    lanes = [np.asarray(getattr(grid, name)) for name in _LANE_FIELDS]

    spec_of_chunk: Dict[tuple, list] = {}
    jobs_list = []
    for start in range(0, L, C):
        stop = min(start + C, L)
        sis = [si for si in range(grid.n_specs)
               if start <= int(grid.lane_of[si]) < stop]
        labels = tuple(grid.specs[si].label for si in sis)
        jobs_list.append(joblib.Job(job_id=f"lanes{start:05d}",
                                    payload=(start, stop), labels=labels,
                                    timeout_s=job_timeout))
        spec_of_chunk[(start, stop)] = sis

    tracer = get_tracer()

    def slice_chunk(start: int, stop: int):
        chunk = [a[start:stop] for a in lanes]
        if stop - start < C:  # pad by replicating the last real lane
            pad = C - (stop - start)
            chunk = [np.concatenate([a] + [a[-1:]] * pad, axis=0)
                     for a in chunk]
        return chunk

    on_done = None
    if journal is not None:
        def on_done(job, out_chunk):
            start, stop = job.payload
            journal([(grid.specs[si],
                      _lane_result(grid, out_chunk, si, 0.0,
                                   lane_base=start))
                     for si in spec_of_chunk[(start, stop)]])

    policy = retry if retry is not None else joblib.RetryPolicy()
    if transport is not None:
        from repro.sim.runners import run_fleet_jobs

        ctx = {"kind": "lanes", "tick_impl": impl.name, "record": record,
               "S": S, "K": K, "n_months": grid.n_months,
               "shard": n_shards, "shared": list(shared)}

        def prepare(job):
            start, stop = job.payload
            return {"chunk": slice_chunk(start, stop), "n": stop - start}

        with tracer.span("simulate_packed.fleet", lanes=L, chunk=C,
                         workers=workers or 1, tick_impl=impl.name):
            chunk_results, registry = run_fleet_jobs(
                jobs_list, workers=workers or 1, transport=transport,
                ctx=ctx, prepare=prepare, policy=policy, faults=faults,
                on_done=on_done)
    else:
        runner = lane_chunk_runner(
            {"kind": "lanes", "tick_impl": impl.name, "record": record,
             "S": S, "K": K, "n_months": grid.n_months,
             "shard": n_shards, "shared": list(shared)})

        def run_one(job):
            start, stop = job.payload
            with tracer.span("simulate_packed.chunk", chunk=job.job_id,
                             lanes=stop - start, tick_impl=impl.name):
                return runner({"chunk": slice_chunk(start, stop),
                               "n": stop - start})

        chunk_results, registry = joblib.run_local_jobs(
            jobs_list, run_one, policy=policy, faults=faults,
            on_done=on_done)

    out: Dict[str, np.ndarray] = {}
    done_lanes: set = set()
    for job in registry.jobs.values():
        if job.state != joblib.DONE:
            continue
        start, stop = job.payload
        o = chunk_results[job.job_id]
        if not out:
            out = {k: np.zeros((L,) + v.shape[1:], dtype=v.dtype)
                   for k, v in o.items()}
        for k, v in o.items():
            out[k][start:stop] = v
        done_lanes.update(range(start, stop))
    return out, registry, set(range(L)) - done_lanes


def run_sweep_jax(specs: Sequence["ScenarioSpec"], tick: float = 10.0,
                  progress: Optional[Callable] = None,
                  tick_impl: str = "auto",
                  lane_chunk: Optional[int] = None,
                  devices: Optional[Sequence] = None,
                  record_series=None,
                  retry=None, faults=None,
                  job_timeout: Optional[float] = None,
                  journal: Optional[Callable] = None,
                  workers: Optional[int] = None,
                  transport=None, shard: bool = False) -> SweepResult:
    """Execute a spec grid as one batched on-device program.

    Returns a ``SweepResult`` interchangeable with the process backend's
    (``events`` reports simulation ticks instead of event-loop pops, and
    per-config ``wall_s`` is the batch wall time split evenly). Specs that
    differ only in pricing (egress option, storage price) share one
    simulated dynamics lane and are billed separately.

    ``tick`` is the clock-step *duration* in seconds; ``tick_impl``
    selects the kernel *implementation* (see ``simulate_packed`` /
    ``repro.kernels.registry``) — independent axes despite the shared
    prefix.

    ``lane_chunk``/``devices``: see ``simulate_packed`` — bounded-memory
    chunked execution with optional multi-device round-robin.
    ``record_series`` turns on per-tick series capture (``True`` or a
    sample stride in ticks); each result then carries the same summary
    digests in ``.series`` that the process backend reports.

    ``retry``/``faults``/``job_timeout``/``journal`` engage the
    fault-tolerant lane-chunk job path (``_simulate_packed_jobs``):
    lanes execute as retryable chunk jobs, completions checkpoint
    through ``journal``, and chunks that exhaust their retries drop
    their specs from the (partial) result, reported in
    ``SweepResult.failures``. The plain path is untouched when none of
    ``retry``/``faults``/``transport`` is given. Multi-device
    round-robin is not combined with the job path.

    ``transport``/``workers`` drain the lane-chunk jobs through the
    persistent worker fleet (``repro.sim.runners``; the job path
    engages automatically). ``shard=True`` runs the lane axis as one
    ``shard_map`` program over the local-device lane mesh on whichever
    path executes (see ``simulate_packed``); both knobs preserve
    bitwise per-lane results.
    """
    from repro.core.scenarios import pack_specs

    resilient = (retry is not None or faults is not None
                 or transport is not None)
    if resilient and devices is not None:
        raise ValueError("devices round-robin is not supported on the "
                         "resilient job path (retry/faults/transport)")
    tracer = get_tracer()
    t0 = time.perf_counter()
    with tracer.span("pack_specs", n_specs=len(specs)):
        grid = pack_specs(specs, tick=tick)
    registry = None
    missing: set = set()
    if resilient:
        out, registry, missing = _simulate_packed_jobs(
            grid, tick_impl=tick_impl, lane_chunk=lane_chunk,
            record_series=record_series, faults=faults, retry=retry,
            job_timeout=job_timeout, journal=journal,
            workers=workers, transport=transport, shard=shard)
    else:
        out = simulate_packed(grid, tick_impl=tick_impl,
                              lane_chunk=lane_chunk, devices=devices,
                              record_series=record_series, shard=shard)
    wall = time.perf_counter() - t0
    reg = get_registry()
    reg.inc("sweep.jax.runs", help="Batched JAX sweep invocations")
    reg.inc("sweep.jax.lanes", grid.n_lanes - len(missing),
            help="Dynamics lanes simulated on device")
    reg.observe("sweep.jax.wall_s", wall,
                help="Batched JAX sweep wall time (s)")
    capture = _normalize_record(record_series, grid.n_ticks) is not None
    ok_sis = [si for si in range(grid.n_specs)
              if int(grid.lane_of[si]) not in missing]
    results: List[ScenarioResult] = []
    for si in ok_sis:
        r = _lane_result(grid, out, si, wall / max(len(ok_sis), 1))
        if capture:
            r.series = {name: ts.summary() for name, ts in
                        series_from_capture(grid, out, si,
                                            record_series).items()}
        results.append(r)
        if progress is not None:
            progress(len(results), len(ok_sis), results[-1])
    return SweepResult(results=results, wall_s=wall,
                       failures=registry.failures() if registry else [])
