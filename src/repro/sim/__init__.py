"""Discrete-event transfer simulation framework (paper §4, Fig. 3).

Four modules mirror the paper's architecture:
  - infrastructure: sites, storage elements, network links, files, replicas
  - cloud: commercial cloud storage (GCS buckets, cost model)
  - engine: BaseSimulation, Schedulable, event loop + integer clock
  - output: metric collectors

``transfer`` holds the transfer managers (the paper's two built-in tick
implementations plus an analytic event-driven fast path) and
``distributions`` the bounded random samplers fitted in Tables 1/3.
``sweep`` is the batched scenario-sweep engine for the §5.3 decision
workflow (grids of configs -> cost/throughput frontier); ``batched`` is
its vectorized lane-per-scenario JAX backend (``backend="jax"``);
``workload`` holds the pluggable access-pattern generators (diurnal /
campaign / popularity-drift / trace-replay arrival schedules) both
backends consume. ``decide`` (imported as ``repro.sim.decide``, not
re-exported here — it sits above ``repro.core`` in the layering) is the
decision-support layer that drives the sweep in a loop: adaptive frontier
refinement, displaced-disk and break-even-price bisections, seed-level
CI frontier membership.
"""

from repro.sim.engine import BaseSimulation, Schedulable
from repro.sim.infrastructure import (
    File,
    NetworkLink,
    Replica,
    Site,
    StorageElement,
)
from repro.sim.cloud import GCSBucket, GCSCostModel
from repro.sim.transfer import (
    BandwidthTransferManager,
    DurationTransferManager,
    LinkTickTable,
    Transfer,
    TransferState,
)
from repro.sim.sweep import (
    ScenarioResult,
    SweepResult,
    pareto_indices,
    run_scenario,
    run_sweep,
)
from repro.sim.workload import (
    WORKLOADS,
    Campaign,
    Diurnal,
    SteadyPoisson,
    TraceReplay,
    WorkloadModel,
    WorkloadSchedule,
    ZipfDrift,
    parse_workload,
)

__all__ = [
    "BaseSimulation",
    "Schedulable",
    "Site",
    "StorageElement",
    "NetworkLink",
    "File",
    "Replica",
    "GCSBucket",
    "GCSCostModel",
    "Transfer",
    "TransferState",
    "BandwidthTransferManager",
    "DurationTransferManager",
    "LinkTickTable",
    "ScenarioResult",
    "SweepResult",
    "pareto_indices",
    "run_scenario",
    "run_sweep",
    "WORKLOADS",
    "WorkloadModel",
    "WorkloadSchedule",
    "SteadyPoisson",
    "Diurnal",
    "Campaign",
    "ZipfDrift",
    "TraceReplay",
    "parse_workload",
]
