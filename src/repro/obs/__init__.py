"""``repro.obs`` — the unified telemetry layer (ISSUE 8).

- :mod:`repro.obs.metrics`: labeled Counter/Gauge/Histogram registry
  with process-safe snapshot/merge and Prometheus/JSON exporters.
- :mod:`repro.obs.trace`: span-based tracing emitting Chrome
  trace-event JSON (Perfetto-loadable), with an optional
  ``jax.profiler`` hook.
- :mod:`repro.obs.logs`: stdlib-``logging`` setup for the CLIs with a
  per-invocation run id shared with the tracer.

All modules are jax-free at import time. ``docs/observability.md`` is
the reference: metric catalogue (including the ``jobs.*`` resilience
and ``workers.*``/``dispatch.*`` fleet families), trace-span map, and
the worker snapshot/merge process model that keeps parallel sweeps'
totals equal to serial runs'.
"""

from repro.obs.logs import setup_logging
from repro.obs.metrics import MetricsRegistry, get_registry
from repro.obs.trace import Tracer, get_tracer, jax_device_profile

__all__ = ["MetricsRegistry", "get_registry", "Tracer", "get_tracer",
           "jax_device_profile", "setup_logging"]
