"""Optimizer + gradient-compression tests."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.train.optimizer import (
    OptConfig,
    adafactor,
    adamw,
    compress_gradients,
    make_optimizer,
)


def _quadratic_losses(opt, steps=60, lr=0.1):
    params = {"w": jnp.asarray([3.0, -2.0, 1.5]),
              "b": jnp.asarray([[1.0, -1.0], [0.5, 2.0]])}
    state = opt.init(params)
    losses = []

    def loss_fn(p):
        return jnp.sum(p["w"] ** 2) + jnp.sum(p["b"] ** 2)

    for _ in range(steps):
        g = jax.grad(loss_fn)(params)
        params, state = opt.update(g, state, params)
        losses.append(float(loss_fn(params)))
    return losses


@pytest.mark.parametrize("name", ["adamw", "adafactor"])
def test_optimizers_minimise_quadratic(name):
    opt = make_optimizer(name, OptConfig(lr=0.05, weight_decay=0.0))
    losses = _quadratic_losses(opt)
    assert losses[-1] < 0.1 * losses[0]


def test_adamw_moments_dtype_and_step():
    opt = adamw()
    params = {"w": jnp.ones((3,), jnp.bfloat16)}
    st = opt.init(params)
    assert st["m"]["w"].dtype == jnp.float32
    p2, st2 = opt.update({"w": jnp.ones((3,), jnp.bfloat16)}, st, params)
    assert int(st2["step"]) == 1
    assert p2["w"].dtype == jnp.bfloat16


def test_adafactor_memory_is_factored():
    opt = adafactor()
    params = {"w": jnp.ones((64, 128))}
    st = opt.init(params)
    stats = st["stats"]["w"]
    assert "vr" in stats and "vc" in stats
    assert stats["vr"].shape == (64,)
    assert stats["vc"].shape == (128,)


def test_compression_error_feedback_reduces_bias():
    """With error feedback, the accumulated quantised sum converges to the
    true sum (residual carried forward)."""
    rng = np.random.default_rng(0)
    g_true = {"w": jnp.asarray(rng.normal(size=(256,)).astype(np.float32))}
    err = None
    total_q = jnp.zeros((256,))
    for _ in range(50):
        q, err = compress_gradients(g_true, err)
        total_q = total_q + q["w"]
    mean_q = total_q / 50
    assert float(jnp.max(jnp.abs(mean_q - g_true["w"]))) < 0.01


def test_compression_output_matches_scale():
    g = {"w": jnp.asarray([1.0, -0.5, 0.25, 127.0])}
    q, err = compress_gradients(g, None)
    assert q["w"].shape == g["w"].shape
    assert float(jnp.max(jnp.abs(q["w"] - g["w"]))) <= 127.0 / 127.0
