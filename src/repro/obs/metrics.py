"""Labeled metrics registry with process-safe snapshots (ISSUE 8).

The decision workflow's operational story — "is the cache warm?", "how
many lanes did this run simulate?", "which kernel did ``auto``
resolve?" — was scattered across ad-hoc attributes (``CacheStats``,
``SweepDriver`` counters, nothing at all for ``tick_impl``). This module
is the one sink: a registry of labeled Counters, Gauges, and Histograms
that every layer increments, exported as Prometheus text exposition
format (``to_prometheus``) or JSON (``snapshot``/``to_json_dict``).

Process model: ``run_sweep``'s worker processes — spawned pool workers
and persistent fleet workers (``repro.sim.runners``) alike — each carry
their own process-global registry. Workers return a snapshot *delta*
with each task result / result frame (snapshot then reset), and the
parent folds it in with ``merge`` — counters and histograms add, gauges
last-write-wins — so a parallel sweep's metrics match a serial run's
(``docs/observability.md``, "Process model").

The registry is jax-free at import time (stdlib only): it is imported
from ``repro.kernels.registry``, whose concrete-name resolution must
never touch jax.

Performance: a disabled registry (``enabled = False``) turns every
``inc``/``set``/``observe`` into an early-out attribute check, and the
enabled fast path is one dict update under a lock. The
``sweep.obs.overhead`` bench row pins the enabled-registry cost on the
warm sweep path below 5%.

Naming: metric names are dotted (``cache.hits``, ``lanes.simulated``);
the Prometheus exporter rewrites characters outside ``[a-zA-Z0-9_:]``
to ``_`` (``cache_hits``). Snapshot keys keep the dotted form; labeled
series append ``{k=v,...}`` with label keys sorted. Labels are plain
keyword arguments, so the parameter names of the mutators (``name``,
``amount``, ``value``, ``help``, ``buckets``, ``default``) are reserved
and cannot be label keys.
"""

from __future__ import annotations

import json
import re
import threading
import time
from typing import Any, Dict, Iterable, Mapping, Optional, Tuple

#: Default histogram bucket upper bounds (seconds-flavoured; the +Inf
#: bucket is implicit). Matches the Prometheus convention of cumulative
#: ``le`` buckets.
DEFAULT_BUCKETS: Tuple[float, ...] = (
    0.001, 0.005, 0.01, 0.05, 0.1, 0.5, 1.0, 5.0, 10.0, 60.0)

_PROM_NAME = re.compile(r"[^a-zA-Z0-9_:]")


def _label_key(labels: Mapping[str, Any]) -> str:
    """Canonical series key for a label set ('' = unlabeled)."""
    if not labels:
        return ""
    return ",".join(f"{k}={labels[k]}" for k in sorted(labels))


def _series_name(name: str, label_key: str) -> str:
    """Snapshot key of one series: ``name`` or ``name{k=v,...}``."""
    return name if not label_key else f"{name}{{{label_key}}}"


def split_series_name(series: str) -> Tuple[str, Dict[str, str]]:
    """Inverse of the snapshot key: ``name{k=v}`` -> (name, labels)."""
    if not series.endswith("}") or "{" not in series:
        return series, {}
    name, _, rest = series.partition("{")
    labels = {}
    for part in rest[:-1].split(","):
        if part:
            k, _, v = part.partition("=")
            labels[k] = v
    return name, labels


class _Hist:
    """One histogram series: cumulative bucket counts + sum + count."""

    __slots__ = ("bounds", "counts", "sum", "count")

    def __init__(self, bounds: Tuple[float, ...]):
        self.bounds = bounds
        self.counts = [0] * (len(bounds) + 1)  # last bucket = +Inf
        self.sum = 0.0
        self.count = 0

    def observe(self, value: float) -> None:
        i = 0
        for i, b in enumerate(self.bounds):
            if value <= b:
                break
        else:
            i = len(self.bounds)
        self.counts[i] += 1
        self.sum += value
        self.count += 1

    def as_dict(self) -> Dict[str, Any]:
        return {"bounds": list(self.bounds), "counts": list(self.counts),
                "sum": self.sum, "count": self.count}

    def merge(self, other: Mapping[str, Any]) -> None:
        if list(other.get("bounds", [])) != list(self.bounds):
            # Different bucketing cannot be merged bucket-wise; fold the
            # mass into sum/count so totals stay right.
            self.sum += float(other.get("sum", 0.0))
            self.count += int(other.get("count", 0))
            return
        for i, c in enumerate(other.get("counts", [])):
            if i < len(self.counts):
                self.counts[i] += int(c)
        self.sum += float(other.get("sum", 0.0))
        self.count += int(other.get("count", 0))


class MetricsRegistry:
    """Process-local registry of labeled counters, gauges, histograms.

    All mutation goes through ``inc``/``set_gauge``/``observe`` (or the
    bound helpers returned by ``counter``/``gauge``/``histogram``);
    ``snapshot`` returns a JSON-safe dict and ``merge`` folds another
    snapshot in — the worker-pool round trip.
    """

    def __init__(self, enabled: bool = True):
        self.enabled = enabled
        self._lock = threading.Lock()
        self._counters: Dict[str, Dict[str, float]] = {}
        self._gauges: Dict[str, Dict[str, float]] = {}
        self._hists: Dict[str, Dict[str, _Hist]] = {}
        self._help: Dict[str, str] = {}
        self._buckets: Dict[str, Tuple[float, ...]] = {}

    # -- switches -----------------------------------------------------------
    def enable(self) -> None:
        self.enabled = True

    def disable(self) -> None:
        self.enabled = False

    # -- mutation -----------------------------------------------------------
    def inc(self, name: str, amount: float = 1.0, help: str = "",
            **labels: Any) -> None:
        """Add ``amount`` to a counter series (creating it at 0)."""
        if not self.enabled:
            return
        key = _label_key(labels)
        with self._lock:
            if help and name not in self._help:
                self._help[name] = help
            series = self._counters.setdefault(name, {})
            series[key] = series.get(key, 0.0) + amount

    def set_gauge(self, name: str, value: float, help: str = "",
                  **labels: Any) -> None:
        """Set a gauge series to ``value`` (last write wins)."""
        if not self.enabled:
            return
        key = _label_key(labels)
        with self._lock:
            if help and name not in self._help:
                self._help[name] = help
            self._gauges.setdefault(name, {})[key] = float(value)

    def observe(self, name: str, value: float, help: str = "",
                buckets: Tuple[float, ...] = DEFAULT_BUCKETS,
                **labels: Any) -> None:
        """Record one observation into a histogram series."""
        if not self.enabled:
            return
        key = _label_key(labels)
        with self._lock:
            if help and name not in self._help:
                self._help[name] = help
            bounds = self._buckets.setdefault(name, tuple(buckets))
            series = self._hists.setdefault(name, {})
            h = series.get(key)
            if h is None:
                h = series[key] = _Hist(bounds)
            h.observe(float(value))

    # -- lookup -------------------------------------------------------------
    def value(self, name: str, default: float = 0.0,
              **labels: Any) -> float:
        """Current value of a counter or gauge series (tests/benches)."""
        key = _label_key(labels)
        with self._lock:
            for store in (self._counters, self._gauges):
                if name in store and key in store[name]:
                    return store[name][key]
        return default

    # -- snapshot / merge ---------------------------------------------------
    def snapshot(self) -> Dict[str, Any]:
        """JSON-safe image of every series (the worker/export payload)."""
        with self._lock:
            return {
                "counters": {_series_name(n, k): v
                             for n, s in sorted(self._counters.items())
                             for k, v in sorted(s.items())},
                "gauges": {_series_name(n, k): v
                           for n, s in sorted(self._gauges.items())
                           for k, v in sorted(s.items())},
                "histograms": {_series_name(n, k): s[k].as_dict()
                               for n, s in sorted(self._hists.items())
                               for k in sorted(s)},
            }

    def merge(self, snap: Optional[Mapping[str, Any]]) -> None:
        """Fold a snapshot in: counters/histograms add, gauges assign."""
        if not snap:
            return
        for series, v in snap.get("counters", {}).items():
            name, labels = split_series_name(series)
            was, self.enabled = self.enabled, True
            try:
                self.inc(name, float(v), **labels)
            finally:
                self.enabled = was
        for series, v in snap.get("gauges", {}).items():
            name, labels = split_series_name(series)
            key = _label_key(labels)
            with self._lock:
                self._gauges.setdefault(name, {})[key] = float(v)
        for series, doc in snap.get("histograms", {}).items():
            name, labels = split_series_name(series)
            key = _label_key(labels)
            with self._lock:
                bounds = self._buckets.setdefault(
                    name, tuple(doc.get("bounds", DEFAULT_BUCKETS)))
                h = self._hists.setdefault(name, {}).get(key)
                if h is None:
                    h = self._hists[name][key] = _Hist(bounds)
                h.merge(doc)

    def reset(self) -> None:
        """Drop every recorded value (metric help/bucket defs survive)."""
        with self._lock:
            self._counters.clear()
            self._gauges.clear()
            self._hists.clear()

    # -- exporters ----------------------------------------------------------
    def to_prometheus(self) -> str:
        """Prometheus text exposition format (v0.0.4)."""

        def prom(name: str) -> str:
            return _PROM_NAME.sub("_", name)

        def labelstr(key: str, extra: str = "") -> str:
            parts = []
            if key:
                for part in key.split(","):
                    k, _, v = part.partition("=")
                    v = v.replace("\\", r"\\").replace('"', r"\"")
                    parts.append(f'{prom(k)}="{v}"')
            if extra:
                parts.append(extra)
            return "{" + ",".join(parts) + "}" if parts else ""

        lines = []
        with self._lock:
            for kind, store in (("counter", self._counters),
                                ("gauge", self._gauges)):
                for name, series in sorted(store.items()):
                    p = prom(name)
                    if name in self._help:
                        lines.append(f"# HELP {p} {self._help[name]}")
                    lines.append(f"# TYPE {p} {kind}")
                    for key, v in sorted(series.items()):
                        lines.append(f"{p}{labelstr(key)} {v:g}")
            for name, series in sorted(self._hists.items()):
                p = prom(name)
                if name in self._help:
                    lines.append(f"# HELP {p} {self._help[name]}")
                lines.append(f"# TYPE {p} histogram")
                for key, h in sorted(series.items()):
                    acc = 0
                    for bound, c in zip(h.bounds, h.counts):
                        acc += c
                        le = 'le="%g"' % bound
                        lines.append(f"{p}_bucket{labelstr(key, le)} {acc}")
                    inf = 'le="+Inf"'
                    lines.append(f"{p}_bucket{labelstr(key, inf)} {h.count}")
                    lines.append(f"{p}_sum{labelstr(key)} {h.sum:g}")
                    lines.append(f"{p}_count{labelstr(key)} {h.count}")
        return "\n".join(lines) + "\n"

    def to_json_dict(self) -> Dict[str, Any]:
        """Snapshot plus export metadata (for ``--metrics-out *.json``)."""
        doc = self.snapshot()
        doc["exported_unix"] = time.time()
        return doc

    def dump(self, path: str) -> None:
        """Write the registry to ``path``: Prometheus text unless the
        path ends in ``.json``."""
        if path.endswith(".json"):
            data = json.dumps(self.to_json_dict(), indent=2)
        else:
            data = self.to_prometheus()
        with open(path, "w") as f:
            f.write(data)


#: Process-global registry — every layer's default sink. Pool workers get
#: their own (fresh process); ``repro.sim.sweep`` merges worker snapshots
#: back into the parent's.
_REGISTRY = MetricsRegistry()


def get_registry() -> MetricsRegistry:
    """The process-global :class:`MetricsRegistry`."""
    return _REGISTRY


def snapshot_and_reset(registry: Optional[MetricsRegistry] = None
                       ) -> Dict[str, Any]:
    """Snapshot then clear — the pool-worker delta round trip."""
    reg = registry or _REGISTRY
    snap = reg.snapshot()
    reg.reset()
    return snap


__all__: Iterable[str] = [
    "DEFAULT_BUCKETS", "MetricsRegistry", "get_registry",
    "snapshot_and_reset", "split_series_name",
]
