"""Per-architecture smoke tests (required deliverable f).

Each assigned architecture instantiates a REDUCED same-family config and
runs one forward + one train step on CPU, asserting output shapes and
finite values.
"""

import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCHITECTURES, get_config, get_smoke_config
from repro.launch.mesh import make_debug_mesh
from repro.models import forward, init_params
from repro.parallel.sharding import ParallelPlan
from repro.train.train_step import make_train_step
from repro.train.optimizer import make_optimizer

B, T = 2, 32


def _batch(cfg, key):
    batch = {
        "tokens": jax.random.randint(key, (B, T), 0, cfg.vocab_size),
        "labels": jax.random.randint(key, (B, T), 0, cfg.vocab_size),
    }
    if cfg.frontend == "vision":
        batch["frontend"] = jnp.ones(
            (B, cfg.frontend_tokens, cfg.frontend_dim), cfg.dtype)
    if cfg.is_enc_dec:
        batch["enc_input"] = jnp.ones((B, 16, cfg.frontend_dim), jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ARCHITECTURES)
def test_smoke_forward_shapes_and_finite(arch):
    cfg = get_smoke_config(arch)
    key = jax.random.PRNGKey(0)
    params = init_params(cfg, key)
    batch = _batch(cfg, key)
    logits, aux = forward(cfg, params, batch)
    t_expected = T + (cfg.frontend_tokens if cfg.frontend == "vision" else 0)
    assert logits.shape == (B, t_expected, cfg.vocab_size)
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())
    assert bool(jnp.isfinite(aux))


@pytest.mark.parametrize("arch", ARCHITECTURES)
def test_smoke_train_step_no_nans(arch):
    cfg = get_smoke_config(arch)
    key = jax.random.PRNGKey(1)
    params = init_params(cfg, key)
    mesh = make_debug_mesh(1, 1)
    plan = ParallelPlan(microbatches=1)
    step = jax.jit(make_train_step(cfg, plan, mesh))
    opt = make_optimizer(plan.optimizer)
    opt_state = opt.init(params)
    batch = _batch(cfg, key)
    with mesh:
        new_params, new_opt, metrics = step(params, opt_state, batch)
    assert bool(jnp.isfinite(metrics["loss"]))
    assert bool(jnp.isfinite(metrics["grad_norm"]))
    # params actually changed
    delta = jax.tree.map(
        lambda a, b: float(jnp.max(jnp.abs(a.astype(jnp.float32)
                                           - b.astype(jnp.float32)))),
        new_params, params)
    assert max(jax.tree.leaves(delta)) > 0


@pytest.mark.parametrize("arch", ARCHITECTURES)
def test_full_config_param_counts(arch):
    """Full configs expose the published scale (sanity band per arch)."""
    cfg = get_config(arch)
    n = cfg.param_count()
    bands = {
        "arctic_480b": (4e11, 5.5e11),
        "olmoe_1b_7b": (5e9, 9e9),
        "falcon_mamba_7b": (6e9, 9e9),
        "command_r_35b": (3e10, 4.3e10),
        "qwen3_4b": (3.0e9, 6e9),
        "gemma3_27b": (2.2e10, 3.3e10),
        "mistral_large_123b": (1.1e11, 1.4e11),
        "hymba_1_5b": (1.2e9, 2.2e9),
        "phi_3_vision_4_2b": (3.5e9, 4.8e9),
        "seamless_m4t_large_v2": (1.2e9, 2.8e9),
    }
    lo, hi = bands[arch]
    assert lo <= n <= hi, f"{arch}: {n:.3e} outside [{lo:.1e}, {hi:.1e}]"


def test_loss_decreases_over_steps():
    cfg = get_smoke_config("qwen3_4b")
    key = jax.random.PRNGKey(2)
    params = init_params(cfg, key)
    mesh = make_debug_mesh(1, 1)
    plan = ParallelPlan(microbatches=1)
    step = jax.jit(make_train_step(cfg, plan, mesh))
    opt = make_optimizer("adamw")
    opt_state = opt.init(params)
    batch = _batch(cfg, key)  # overfit one batch
    losses = []
    with mesh:
        for _ in range(8):
            params, opt_state, m = step(params, opt_state, batch)
            losses.append(float(m["loss"]))
    assert losses[-1] < losses[0]


def test_microbatched_matches_single_batch_grads():
    """Grad accumulation (n_micro) must match the single-batch step."""
    cfg = get_smoke_config("qwen3_4b")
    key = jax.random.PRNGKey(3)
    params = init_params(cfg, key)
    mesh = make_debug_mesh(1, 1)
    opt = make_optimizer("adamw")
    batch = _batch(cfg, key)
    outs = {}
    for n_micro in (1, 2):
        plan = ParallelPlan(microbatches=n_micro)
        step = jax.jit(make_train_step(cfg, plan, mesh))
        with mesh:
            p2, _, m = step(params, opt.init(params), batch)
        outs[n_micro] = (m["loss"], p2)
    assert abs(float(outs[1][0]) - float(outs[2][0])) < 5e-2
    d = jax.tree.map(lambda a, b: float(jnp.mean(jnp.abs(
        a.astype(jnp.float32) - b.astype(jnp.float32)))),
        outs[1][1], outs[2][1])
    assert max(jax.tree.leaves(d)) < 5e-2
