"""Assigned input shapes and abstract input specs per (arch x shape).

Shapes (LM-family: seq_len x global_batch):
  train_4k     4,096 x 256   -> train_step
  prefill_32k  32,768 x 32   -> prefill_step
  decode_32k   32,768 x 128  -> serve_step (1 new token, seq_len KV cache)
  long_500k    524,288 x 1   -> serve_step; sub-quadratic archs only

``long_500k`` runs for ssm (falcon-mamba), hybrid (hymba) and
mostly-local gemma3; it is skipped for pure full-attention archs
(command-r, qwen3, mistral-large, arctic, olmoe, phi-3-vision, seamless)
— see DESIGN.md §Arch-applicability.

Modality interpretation (documented in DESIGN.md): phi-3-vision's 4k train
sequence = 256 stub patch tokens + 3,840 text tokens; seamless train feeds
seq_len stub audio frames to the encoder and seq_len/4 text tokens to the
decoder; seamless serve shapes decode against a seq_len decoder cache with
a fixed 4,096-frame encoder context.
"""

from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig

SHAPES = {
    "train_4k": {"seq": 4096, "batch": 256, "kind": "train"},
    "prefill_32k": {"seq": 32768, "batch": 32, "kind": "prefill"},
    "decode_32k": {"seq": 32768, "batch": 128, "kind": "decode"},
    "long_500k": {"seq": 524288, "batch": 1, "kind": "decode"},
}

LONG_CONTEXT_OK = {"falcon_mamba_7b", "hymba_1_5b", "gemma3_27b"}


def cell_supported(arch: str, shape: str) -> Tuple[bool, str]:
    if shape == "long_500k" and arch not in LONG_CONTEXT_OK:
        return False, "pure full-attention arch: long_500k skipped (quadratic)"
    return True, ""


def _sds(shape, dtype) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def input_specs(cfg: ModelConfig, shape_name: str) -> Dict[str, Any]:
    """ShapeDtypeStruct stand-ins for every model input (no allocation)."""
    s = SHAPES[shape_name]
    seq, batch, kind = s["seq"], s["batch"], s["kind"]
    i32 = jnp.int32

    if kind == "train":
        if cfg.frontend == "vision":
            text = seq - cfg.frontend_tokens
            return {
                "tokens": _sds((batch, text), i32),
                "labels": _sds((batch, text), i32),
                "frontend": _sds((batch, cfg.frontend_tokens, cfg.frontend_dim),
                                 cfg.dtype),
            }
        if cfg.is_enc_dec:
            return {
                "tokens": _sds((batch, seq // 4), i32),
                "labels": _sds((batch, seq // 4), i32),
                "enc_input": _sds((batch, seq, cfg.frontend_dim), jnp.float32),
            }
        return {
            "tokens": _sds((batch, seq), i32),
            "labels": _sds((batch, seq), i32),
        }

    if kind == "prefill":
        out = {"tokens": _sds((batch, seq), i32)}
        if cfg.frontend == "vision":
            out["tokens"] = _sds((batch, seq - cfg.frontend_tokens), i32)
            out["frontend"] = _sds((batch, cfg.frontend_tokens, cfg.frontend_dim),
                                   cfg.dtype)
        if cfg.is_enc_dec:
            out["enc_input"] = _sds((batch, 4096, cfg.frontend_dim), jnp.float32)
        return out

    # decode: one new token against a seq-length cache
    out = {"tokens": _sds((batch, 1), i32)}
    return out


def decode_cache_len(shape_name: str) -> int:
    return SHAPES[shape_name]["seq"]
