"""Train step: microbatched grad accumulation + optimizer, SPMD-ready.

``make_train_step(cfg, plan, mesh)`` returns a pure function
``(params, opt_state, batch) -> (params, opt_state, metrics)`` suitable for
``jax.jit`` with explicit in/out shardings:

- the global batch is reshaped to [n_micro, micro_b, T] and scanned;
  per-microbatch grads accumulate into f32 buffers whose sharding
  constraint carries BOTH the TP axis and the dp axes (ZeRO-2-style:
  XLA lowers the accumulation as per-microbatch reduce-scatters);
- optional int8 gradient compression with error feedback (plan-driven)
  before the final reduction;
- the optimizer update runs on the fully sharded state (ZeRO-1).
"""

from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models import loss_fn
from repro.models.config import ModelConfig
from repro.parallel.sharding import (
    ParallelPlan,
    expert_sharder,
    spec_for_param,
    _path_str,
    dp_axes,
)
from repro.parallel.ctx import sharding_ctx
from repro.train.optimizer import OptConfig, make_optimizer


def _grad_sharder(mesh: Mesh, plan: ParallelPlan):
    """Constraint grads to param sharding + dp axes on the first free dim."""
    import dataclasses

    fsdp_plan = dataclasses.replace(plan, fsdp=True)

    def constrain(path, g):
        spec = spec_for_param(_path_str(path), g.shape, mesh, fsdp_plan)
        return jax.lax.with_sharding_constraint(g, NamedSharding(mesh, spec))

    def apply(grads):
        return jax.tree_util.tree_map_with_path(constrain, grads)

    return apply


def make_train_step(cfg: ModelConfig, plan: ParallelPlan, mesh: Mesh,
                    opt_cfg: OptConfig = OptConfig(),
                    compress: bool = False) -> Callable:
    opt = make_optimizer(plan.optimizer, opt_cfg)
    shard_experts = expert_sharder(mesh) if cfg.family == "moe" else None
    grad_sharder = _grad_sharder(mesh, plan)

    def micro_loss(params, micro_batch):
        total, parts = loss_fn(cfg, params, micro_batch,
                               shard_experts=shard_experts)
        return total, parts

    grad_fn = jax.value_and_grad(micro_loss, has_aux=True)

    def train_step(params, opt_state, batch):
        return _train_step_inner(params, opt_state, batch)

    def _train_step_inner(params, opt_state, batch):
        n_micro = plan.microbatches

        if n_micro <= 1:
            (loss, parts), grads = grad_fn(params, batch)
            grads = grad_sharder(grads)
        else:
            import numpy as np

            daxes = dp_axes(mesh)
            dp_n = int(np.prod([mesh.shape[a] for a in daxes])) if daxes else 1

            def reshape(x):
                x = x.reshape((n_micro, x.shape[0] // n_micro) + x.shape[1:])
                # Keep the data axes on the row dim (not the scan dim) —
                # without this XLA re-propagates and replicates rows.
                if daxes and x.shape[1] % dp_n == 0:
                    x = jax.lax.with_sharding_constraint(
                        x, NamedSharding(mesh, P(None, daxes)))
                return x

            micro = jax.tree.map(reshape, batch)
            acc_dt = jnp.bfloat16 if plan.grad_accum_dtype == "bf16" \
                else jnp.float32
            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, dtype=acc_dt), params)
            zeros = grad_sharder(zeros)

            def body(carry, mb):
                acc, loss_acc = carry
                (loss, parts), g = grad_fn(params, mb)
                g = grad_sharder(jax.tree.map(lambda a: a.astype(acc_dt), g))
                acc = jax.tree.map(jnp.add, acc, g)
                return (acc, loss_acc + loss), None

            (grads, loss_sum), _ = jax.lax.scan(
                body, (zeros, jnp.zeros((), jnp.float32)), micro)
            grads = jax.tree.map(lambda g: g / n_micro, grads)
            loss = loss_sum / n_micro

        new_params, new_opt = opt.update(grads, opt_state, params)
        gnorm = jnp.sqrt(sum(
            jnp.sum(jnp.square(g.astype(jnp.float32)))
            for g in jax.tree.leaves(grads)))
        metrics = {"loss": loss, "grad_norm": gnorm}
        return new_params, new_opt, metrics

    def train_step_ctx(params, opt_state, batch):
        with sharding_ctx(mesh, moe_local_dispatch=plan.moe_local_dispatch,
                          no_ep=plan.no_ep):
            return train_step(params, opt_state, batch)

    return train_step_ctx


def init_train_state(cfg: ModelConfig, plan: ParallelPlan, key):
    """(params, opt_state) — concrete; use jax.eval_shape for abstract."""
    from repro.models import init_params

    params = init_params(cfg, key)
    opt = make_optimizer(plan.optimizer)
    return params, opt.init(params)
