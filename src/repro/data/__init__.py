"""Data substrate: HCDC tiered store + token pipeline."""

from repro.data.tiered_store import TieredStore, TierSpec, Shard
from repro.data.pipeline import TokenPipeline, SyntheticCorpus

__all__ = ["TieredStore", "TierSpec", "Shard", "TokenPipeline",
           "SyntheticCorpus"]
