"""Batched scenario-sweep engine (paper §5.3: the decision workflow).

The paper's stated purpose for the simulation is "to assist with the
decision process of using commercial cloud storage": compare many scenario
variants — hot-cache sizes, egress pricing/peering options, job arrival
rates, seeds — on a cost vs. throughput frontier. This module turns the
single-run ``HCDCScenario`` into that instrument:

- ``run_scenario(spec)``: one ``ScenarioSpec`` -> ``ScenarioResult``
  (metrics, monthly-bill breakdown, time-series digests, run stats). Specs
  are built via ``repro.core.scenarios`` and executed on the analytic
  ``EventDrivenTransferService`` fast path, so a reduced-scale config runs
  in seconds.
- ``run_sweep(specs)``: executes a batch with process-level parallelism
  (simulations are pure Python and CPU-bound, so threads would serialize on
  the GIL). Results are deterministic per spec — a parallel sweep is
  bit-identical to running each config serially with the same seed.
- ``SweepResult``: ordered results + CSV/JSON export + Pareto-front
  extraction (minimize cloud cost, maximize jobs done) + seed aggregation
  in the paper's Table 6/7/8 mean/sd% presentation.
"""

from __future__ import annotations

import json
import multiprocessing
import os
import time
from concurrent.futures import ProcessPoolExecutor, as_completed
from dataclasses import dataclass, field, replace
from typing import TYPE_CHECKING, Any, Callable, Dict, List, Optional, Sequence

from repro.obs.metrics import get_registry, snapshot_and_reset
from repro.obs.trace import get_tracer
from repro.sim.cloud import sum_bills
from repro.sim.output import mean_and_error, write_csv

if TYPE_CHECKING:  # repro.core imports repro.sim; keep runtime acyclic
    from repro.core.scenarios import ScenarioSpec


@dataclass
class ScenarioResult:
    """Outcome of one simulated configuration (picklable)."""

    spec: ScenarioSpec
    metrics: Dict[str, float]
    storage_usd: float
    network_usd: float
    ops_usd: float
    wall_s: float
    events: int
    series: Dict[str, Dict[str, float]] = field(default_factory=dict)
    #: Raw per-month billing inputs: ``{"gb_seconds": [...], "egress_bytes":
    #: [...], "class_a": [...], "class_b": [...], "full_months": int}``.
    #: Pricing-independent — feeding them through
    #: ``repro.sim.cloud.bills_from_monthly_totals`` under any cost model
    #: re-bills the run bit-exactly, which is how the persistent result
    #: cache (``repro.sim.cache``) serves pricing variants of one stored
    #: dynamics lane. Empty for synthetic results that never simulated.
    monthly: Dict[str, Any] = field(default_factory=dict)

    @property
    def cost_usd(self) -> float:
        return self.storage_usd + self.network_usd + self.ops_usd

    @property
    def jobs_done(self) -> float:
        return self.metrics["jobs_done"]

    @property
    def jobs_per_day(self) -> float:
        return self.jobs_done / self.spec.days

    def row(self) -> Dict[str, Any]:
        """Flat record for CSV/JSON export."""
        m = self.metrics
        r: Dict[str, Any] = {"label": self.spec.label}
        r.update(self.spec.to_dict())
        del r["curves"]
        r.update(
            jobs_done=m["jobs_done"],
            jobs_per_day=self.jobs_per_day,
            job_waiting_h_mean=m["job_waiting_h_mean"],
            download_pb=m["download_pb"],
            tape_to_disk_pb=sum(v for k, v in m.items()
                                if k.endswith(".tape_to_disk_pb")),
            gcs_to_disk_pb=m["gcs_to_disk_pb"],
            disk_to_gcs_pb=m["disk_to_gcs_pb"],
            gcs_used_pb=m["gcs_used_pb"],
            storage_usd=self.storage_usd,
            network_usd=self.network_usd,
            ops_usd=self.ops_usd,
            cost_usd=self.cost_usd,
            cost_per_kjob=1e3 * self.cost_usd / max(m["jobs_done"], 1.0),
            wall_s=self.wall_s,
            events=self.events,
        )
        return r


def _worker_init() -> None:
    """Initializer for spawned sweep workers.

    Pin JAX (should any import chain pull it in) to CPU before the worker
    touches a task: an accelerator-probing child process can hang on
    device initialization while the parent holds the device — the same
    failure class as the moe multi-device subprocess hang. An inherited
    JAX_PLATFORMS (e.g. the parent exported ``tpu``) is deliberately
    overridden: workers only ever need numpy, so CPU is always right.
    """
    os.environ["JAX_PLATFORMS"] = "cpu"
    # Fresh baseline for the worker's process-global metrics registry so
    # the per-task snapshot deltas it returns contain only its own work.
    get_registry().reset()


def run_scenario(spec: ScenarioSpec) -> ScenarioResult:
    """Build and run one configuration; the sweep's unit of work.

    Top-level (not a closure) so ``ProcessPoolExecutor`` can pickle it; all
    randomness is derived from ``spec.seed``, so the result is independent
    of which process runs it.
    """
    # Deferred imports: repro.core depends on repro.sim, so importing it at
    # module scope would make ``repro.sim`` circular.
    from repro.core.hcdc import HCDCScenario
    from repro.core.scenarios import build_config

    cfg = build_config(spec)
    t0 = time.perf_counter()
    with get_tracer().span("run_scenario", label=spec.label):
        scenario = HCDCScenario(cfg)
        metrics = scenario.run()
    wall = time.perf_counter() - t0
    reg = get_registry()
    reg.inc("scenario.runs", help="Event-engine scenario executions")
    reg.observe("scenario.wall_s", wall,
                help="Per-scenario event-engine wall time (s)")
    bill = sum_bills(scenario.gcs.bills)
    series = {name: ts.summary() for name, ts in scenario.out.series.items()}
    raw = scenario.gcs.monthly_raw
    monthly = {
        "gb_seconds": [float(r[0]) for r in raw],
        "egress_bytes": [float(r[1]) for r in raw],
        "class_a": [int(r[2]) for r in raw],
        "class_b": [int(r[3]) for r in raw],
        "full_months": int(scenario.gcs.full_months_closed),
    }
    return ScenarioResult(
        spec=spec,
        metrics=metrics,
        storage_usd=bill.storage_usd,
        network_usd=bill.network_usd,
        ops_usd=bill.ops_usd,
        wall_s=wall,
        events=scenario.sim.events_executed,
        series=series,
        monthly=monthly,
    )


def _run_scenario_with_metrics(spec: ScenarioSpec):
    """Pool-worker task: the result plus the worker registry's snapshot
    delta (snapshot-then-reset), so the parent can ``merge`` it and a
    parallel sweep's metrics match a serial run's. Top-level for pickling.
    """
    result = run_scenario(spec)
    return result, snapshot_and_reset()


def pareto_indices(costs: Sequence[float],
                   values: Sequence[float]) -> List[int]:
    """Indices of the non-dominated (min cost, max value) points.

    Returned sorted by cost ascending; of points with identical (cost,
    value) only the first is kept, so the front is a strictly increasing
    cost/value staircase.
    """
    if len(costs) != len(values):
        raise ValueError("costs and values must have equal length")
    order = sorted(range(len(costs)), key=lambda i: (costs[i], -values[i]))
    front: List[int] = []
    best = float("-inf")
    for i in order:
        if values[i] > best:
            front.append(i)
            best = values[i]
    return front


@dataclass
class SweepResult:
    """Ordered results of one sweep (same order as the input specs)."""

    results: List[ScenarioResult]
    wall_s: float = 0.0
    #: Distinct dynamics lanes actually *simulated* to answer this call
    #: (``None`` when the call ran without get-or-compute accounting). A
    #: fully warm cache read reports 0 here.
    lanes_simulated: Optional[int] = None
    #: Distinct requested specs answered from the persistent result cache.
    cache_hits: int = 0

    def __len__(self) -> int:
        return len(self.results)

    #: Below this wall-clock floor a throughput rate is noise, not signal.
    WALL_S_FLOOR = 1e-3

    @property
    def configs_per_sec(self) -> Optional[float]:
        """Throughput, or ``None`` when ``wall_s`` is under the 1 ms
        floor — a fully cache-warm (or empty) sweep finishes in
        microseconds, and dividing by that produces a meaningless
        6-digit "rate"."""
        if self.wall_s < self.WALL_S_FLOOR:
            return None
        return len(self.results) / self.wall_s

    # -- frontier ------------------------------------------------------------
    def pareto_front(self) -> List[ScenarioResult]:
        """Cost/throughput frontier: min cloud cost, max jobs done."""
        idx = pareto_indices([r.cost_usd for r in self.results],
                             [r.jobs_done for r in self.results])
        return [self.results[i] for i in idx]

    # -- tabulation ----------------------------------------------------------
    def rows(self) -> List[Dict[str, Any]]:
        front = {id(r) for r in self.pareto_front()}
        out = []
        for r in self.results:
            row = r.row()
            row["pareto"] = int(id(r) in front)
            out.append(row)
        return out

    def aggregate_seeds(self) -> List[Dict[str, Any]]:
        """Group by spec-minus-seed; mean and sd% across seeds (the paper's
        Table 6/7/8 multi-run presentation)."""
        groups: Dict[ScenarioSpec, List[ScenarioResult]] = {}
        for r in self.results:
            groups.setdefault(replace(r.spec, seed=0), []).append(r)
        rows = []
        for key, rs in groups.items():
            jobs_m, jobs_sd, _ = mean_and_error([r.jobs_done for r in rs])
            cost_m, cost_sd, _ = mean_and_error([r.cost_usd for r in rs])
            row: Dict[str, Any] = {"label": key.label.rsplit(",seed=", 1)[0]}
            row.update(key.to_dict())
            del row["curves"], row["seed"]
            row.update(n_seeds=len(rs), jobs_done_mean=jobs_m,
                       jobs_done_sd_pct=jobs_sd, cost_usd_mean=cost_m,
                       cost_usd_sd_pct=cost_sd,
                       cost_per_kjob_mean=1e3 * cost_m / max(jobs_m, 1.0))
            rows.append(row)
        return rows

    # -- export --------------------------------------------------------------
    def to_csv(self, path: str) -> None:
        write_csv(path, self.rows())

    def pareto_to_csv(self, path: str) -> None:
        write_csv(path, [r.row() for r in self.pareto_front()])

    def to_json(self, path: str) -> None:
        doc = {
            "wall_s": self.wall_s,
            "rows": self.rows(),
            "pareto": [r.spec.label for r in self.pareto_front()],
            "series": {r.spec.label: r.series
                       for r in self.results if r.series},
        }
        if self.configs_per_sec is not None:
            doc["configs_per_sec"] = self.configs_per_sec
        if os.path.dirname(path):
            os.makedirs(os.path.dirname(path), exist_ok=True)
        with open(path, "w") as f:
            json.dump(doc, f, indent=2)


def run_sweep(specs: Sequence[ScenarioSpec], workers: Optional[int] = None,
              progress: Optional[Callable[[int, int, ScenarioResult], None]]
              = None, backend: str = "process",
              tick: float = 10.0, tick_impl: str = "auto",
              lane_chunk: Optional[int] = None,
              devices: Optional[Sequence[Any]] = None,
              cache: Optional[Any] = None,
              record_series=None) -> SweepResult:
    """Execute every spec; results keep the input order.

    ``backend`` selects the execution engine:

    - ``"process"`` (default): the event-driven reference engine, one
      Python process per config. Ground truth; bit-deterministic per seed.
    - ``"jax"``: the fixed-tick lane-per-scenario engine
      (``repro.sim.batched``) — the whole grid runs as one ``jit`` +
      ``vmap`` program. Requires uniform ``days``/``n_files`` across the
      grid and matches the reference statistically (Table 2 tolerance),
      not bitwise; ``tick`` sets its clock step in seconds.

    ``tick_impl`` (jax backend only) selects the tick-engine *kernel
    implementation* — ``"jnp"`` | ``"pallas"`` | ``"pallas_interpret"``
    | ``"auto"`` (``repro.kernels.registry``; ``"auto"`` resolves to the
    compiled Pallas kernels on an accelerator and the jnp program on
    CPU). Not to be confused with ``tick``, the clock-step *duration*.

    ``workers``: process count for the process backend; ``None`` uses all
    CPUs (capped at the batch size), ``0``/``1`` runs serially in-process
    (useful under profilers and in tests of determinism).

    ``lane_chunk``/``devices`` (jax backend only): execute the packed
    grid's dynamics lanes in fixed-size chunks — bounded device memory
    and one compile reused across chunks and grids — optionally round-
    robined over several devices. Per-lane results are bitwise identical
    to the unchunked path.

    ``cache``: a ``repro.sim.cache.ResultCache`` (or a cache-directory
    path) turns the call into get-or-compute: specs whose dynamics entry
    is already stored are served from the cache (re-billed for their
    pricing fields, bit-identical to a fresh run on the same engine),
    only the misses are simulated, and their results are stored back.
    ``SweepResult.lanes_simulated``/``cache_hits`` report the split.
    ``tick_impl`` is resolved to its concrete implementation *before*
    keying, so entries from different kernel implementations never
    cross-serve (``"jnp"`` keeps the legacy key: it is bitwise the
    pre-registry engine).

    ``record_series`` (jax backend only): per-tick series capture —
    ``True`` samples every tick, an int is the sample stride in ticks;
    each result then carries the event-engine-schema summary digests in
    ``.series`` (see ``repro.sim.batched.series_from_capture``). The
    process backend records series via ``spec.curves`` instead.
    """
    if backend != "jax" and tick_impl != "auto":
        raise ValueError("tick_impl applies to backend='jax' only")
    if backend != "jax" and record_series not in (None, False):
        raise ValueError("record_series applies to backend='jax' only "
                         "(the process backend records curves via "
                         "spec.curves)")
    impl_name: Optional[str] = None
    if backend == "jax":
        from repro.kernels.registry import resolve_tick_impl

        impl_name = resolve_tick_impl(tick_impl).name
    if cache is not None:
        from repro.core.scenarios import dynamics_key
        from repro.sim.cache import as_cache  # deferred: cache imports us

        cache = as_cache(cache)
        specs = list(specs)
        t0 = time.perf_counter()
        hits = cache.fetch(specs, backend=backend, tick=tick,
                           tick_impl=impl_name)
        miss = [s for s in dict.fromkeys(specs) if s not in hits]
        computed: Dict["ScenarioSpec", ScenarioResult] = {}
        if miss:
            res = run_sweep(miss, workers=workers, progress=progress,
                            backend=backend, tick=tick,
                            tick_impl=impl_name or "auto",
                            lane_chunk=lane_chunk, devices=devices,
                            record_series=record_series)
            computed = dict(zip(miss, res.results))
            cache.store(computed.items(), backend=backend, tick=tick,
                        tick_impl=impl_name)
        merged = {**hits, **computed}
        return SweepResult(
            results=[merged[s] for s in specs],
            wall_s=time.perf_counter() - t0,
            lanes_simulated=len({dynamics_key(s) for s in miss}),
            cache_hits=len(hits))
    if backend == "jax":
        from repro.sim.batched import run_sweep_jax  # deferred: needs jax

        return run_sweep_jax(specs, tick=tick, progress=progress,
                             tick_impl=impl_name,
                             lane_chunk=lane_chunk, devices=devices,
                             record_series=record_series)
    if lane_chunk is not None or devices is not None:
        raise ValueError("lane_chunk/devices apply to backend='jax' only")
    if backend != "process":
        raise ValueError(f"unknown backend {backend!r} "
                         "(expected 'process' or 'jax')")
    specs = list(specs)
    if workers is None:
        workers = min(len(specs), os.cpu_count() or 1)
    t0 = time.perf_counter()
    results: List[Optional[ScenarioResult]] = [None] * len(specs)
    if workers <= 1 or len(specs) <= 1:
        for i, spec in enumerate(specs):
            results[i] = run_scenario(spec)
            if progress is not None:
                progress(i + 1, len(specs), results[i])
    else:
        # Spawn (not fork): callers may have JAX loaded, whose thread pools
        # make forked children deadlock-prone; the sweep worker itself only
        # needs numpy, so spawn startup stays cheap.
        ctx = multiprocessing.get_context("spawn")
        reg = get_registry()
        with ProcessPoolExecutor(max_workers=workers, mp_context=ctx,
                                 initializer=_worker_init) as pool:
            futures = {pool.submit(_run_scenario_with_metrics, s): i
                       for i, s in enumerate(specs)}
            done = 0
            for fut in as_completed(futures):
                i = futures[fut]
                results[i], worker_snap = fut.result()
                reg.merge(worker_snap)
                done += 1
                if progress is not None:
                    progress(done, len(specs), results[i])
    return SweepResult(results=list(results), wall_s=time.perf_counter() - t0)


class SweepDriver:
    """Iterative ``run_sweep`` front-end with cross-round memoization.

    The decision-support layer (``repro.sim.decide``) calls the sweep *in a
    loop* — adaptive grid refinement, break-even bisection — where
    successive rounds re-request many already-simulated specs plus a few
    new ones. The driver executes only the unseen specs (one ``run_sweep``
    call per round, so new specs still batch into one packed grid on the
    jax backend, whose K/J shape bucketing keeps the compiled program
    cached across rounds) and answers the rest from memory.

    It also keeps the books the decision layer reports on:

    - ``lanes_simulated``: distinct dynamics lanes ever *simulated* (the
      ``repro.core.scenarios.dynamics_key`` identity — the
      backend-independent lane-efficiency denominator). Note the memo is
      per exact spec: pricing-only variants of a memoized spec arriving
      in a *later* call still re-simulate their lane (``pack_specs``
      dedups within one packed grid only) unless a persistent cache
      serves them, which is why the decide solvers batch each round's
      pricing probes into one call;
    - ``configs_run`` / ``sweep_calls`` / ``wall_s``: raw work counters —
      cache-served specs never count as work;
    - ``cache_hits``: specs answered from the persistent result cache.

    ``cache`` (a ``repro.sim.cache.ResultCache`` or a cache-directory
    path) adds a persistent lookup tier between the in-memory memo and
    the engines: memo -> cache -> simulate. Simulated results are stored
    back, so a re-run of the same workflow — same process or next week's
    CI job — answers entirely from disk (``lanes_simulated`` stays 0).
    """

    def __init__(self, backend: str = "jax", tick: float = 10.0,
                 workers: Optional[int] = None,
                 tick_impl: str = "auto",
                 lane_chunk: Optional[int] = None,
                 devices: Optional[Sequence[Any]] = None,
                 progress: Optional[Callable[[int, int, ScenarioResult],
                                             None]] = None,
                 cache: Optional[Any] = None,
                 record_series=None):
        if backend != "jax" and tick_impl != "auto":
            raise ValueError("tick_impl applies to backend='jax' only")
        if backend != "jax" and record_series not in (None, False):
            raise ValueError("record_series applies to backend='jax' only")
        self.backend = backend
        self.tick = tick
        self.tick_impl = tick_impl
        self.record_series = record_series
        #: resolved lazily on first run (importing jax to resolve
        #: ``"auto"`` is deferred until the jax backend actually runs)
        self._impl_name: Optional[str] = None
        self.workers = workers
        self.lane_chunk = lane_chunk
        self.devices = devices
        self.progress = progress
        if cache is not None:
            from repro.sim.cache import as_cache  # deferred: imports us

            cache = as_cache(cache)
        self.cache = cache
        self._memo: Dict["ScenarioSpec", ScenarioResult] = {}
        self._lane_keys: set = set()
        self.sweep_calls = 0
        self.configs_run = 0
        self.cache_hits = 0
        self.wall_s = 0.0

    @property
    def lanes_simulated(self) -> int:
        return len(self._lane_keys)

    def __call__(self, specs: Sequence["ScenarioSpec"]) -> SweepResult:
        return self.run(specs)

    def _resolved_impl(self) -> Optional[str]:
        """The concrete ``tick_impl`` name for cache keying (jax backend
        only; resolving ``"auto"`` imports jax, so it happens on first
        use and is then pinned for the driver's lifetime)."""
        if self.backend != "jax":
            return None
        if self._impl_name is None:
            from repro.kernels.registry import resolve_tick_impl

            self._impl_name = resolve_tick_impl(self.tick_impl).name
        return self._impl_name

    def run(self, specs: Sequence["ScenarioSpec"]) -> SweepResult:
        """Results for ``specs`` in order, simulating only the unseen ones."""
        from repro.core.scenarios import dynamics_key

        specs = list(specs)
        new = [s for s in dict.fromkeys(specs) if s not in self._memo]
        t0 = time.perf_counter()
        hits = 0
        if new and self.cache is not None:
            served = self.cache.fetch(new, backend=self.backend,
                                      tick=self.tick,
                                      tick_impl=self._resolved_impl())
            self._memo.update(served)
            hits = len(served)
            self.cache_hits += hits
            new = [s for s in new if s not in served]
        lanes_before = len(self._lane_keys)
        if new:
            res = run_sweep(new, workers=self.workers,
                            progress=self.progress, backend=self.backend,
                            tick=self.tick,
                            tick_impl=self._resolved_impl() or "auto",
                            lane_chunk=self.lane_chunk,
                            devices=self.devices,
                            record_series=self.record_series)
            self.sweep_calls += 1
            self.configs_run += len(new)
            self.wall_s += res.wall_s
            for spec, result in zip(new, res.results):
                self._memo[spec] = result
                self._lane_keys.add(dynamics_key(spec))
            if self.cache is not None:
                self.cache.store(zip(new, res.results),
                                 backend=self.backend, tick=self.tick,
                                 tick_impl=self._resolved_impl())
        reg = get_registry()
        reg.set_gauge("lanes.simulated", self.lanes_simulated,
                      help="Distinct dynamics lanes simulated by the "
                           "driver (0 = fully cache-warm)")
        reg.set_gauge("configs.run", self.configs_run,
                      help="Specs actually executed by the driver")
        reg.set_gauge("sweep.calls", self.sweep_calls,
                      help="run_sweep invocations issued by the driver")
        reg.set_gauge("sweep.wall_s", self.wall_s,
                      help="Cumulative driver simulation wall time (s)")
        return SweepResult(results=[self._memo[s] for s in specs],
                           wall_s=time.perf_counter() - t0,
                           lanes_simulated=len(self._lane_keys) - lanes_before,
                           cache_hits=hits)
