"""Hot/Cold storage policies (paper §2.2 and §6 future work).

Strategy objects deciding (a) which evicted hot-tier data migrates to the
cold tier and (b) how the cold tier itself is trimmed. The paper's
implemented variation migrates *everything* prior to hot deletion and never
deletes from cold storage; it explicitly lists popularity thresholds for
migration and cold-tier deletion as variations/future work — both are
implemented here (beyond-paper, used by ``HCDCConfig.migration_policy`` /
``cold_deletion_policy`` and by the production tiered store).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Optional


@dataclass
class MigrationPolicy:
    """Hot -> cold migration decision at hot-tier eviction time.

    ``min_popularity``: only migrate data at least this popular (paper §2.2:
    "set a threshold based on the popularity metric and only allow
    transferring data to the cold storage that have a certain popularity ...
    to improve the hit/miss ratio"). 0 = migrate everything (paper's
    implemented variation).
    """

    min_popularity: int = 0

    def should_migrate(self, popularity: int) -> bool:
        return popularity >= self.min_popularity


@dataclass
class ColdDeletionPolicy:
    """Cold-tier trimming (paper §6: "essential feature" left as future work).

    When the cold tier's used volume exceeds ``capacity_threshold`` x limit,
    the least popular (ties: least recently used) data is deleted until the
    tier is back under the threshold. Disabled when the cold tier is
    unlimited (the paper's configuration III) or ``capacity_threshold`` is
    None.
    """

    capacity_threshold: Optional[float] = None  # fraction of the limit

    def trim_target(self, limit: Optional[float], used: float) -> float:
        """Bytes to free (0 if no trim needed)."""
        if self.capacity_threshold is None or limit is None:
            return 0.0
        cap = self.capacity_threshold * limit
        return max(0.0, used - cap)


@dataclass
class PopularityModel:
    """Static popularity assignment (paper Table 3) + selection weighting.

    ``selection_power``: jobs select input files with probability
    proportional to ``popularity ** selection_power``. The paper only states
    selection is "based on the popularity"; gamma = 3.5 is calibrated so the
    unique-file footprint reproduces Table 7 (6.75 PB tape->disk per site in
    configuration I; the literal gamma = 1 yields ~2x too many unique files
    — see EXPERIMENTS.md "Calibration").

    A non-stationary workload (``repro.sim.workload.ZipfDrift``) may
    override the power per generator tick via ``selection_weights``'s
    ``power`` argument; the static assignment above stays untouched.
    """

    p: float = 0.1
    lo: int = 1
    hi: int = 50
    selection_power: float = 3.5

    def sample_popularity(self, rng, n: int):
        import numpy as np

        return np.clip(rng.geometric(self.p, n), self.lo, self.hi - 1)

    def selection_weights(self, popularity, power: Optional[float] = None):
        p = self.selection_power if power is None else power
        return popularity.astype(float) ** p

    def selection_cdf(self, popularity, power: Optional[float] = None):
        """Normalized selection CDF for inverse-transform file draws
        (``searchsorted(cdf, u, side="right")``). The single definition
        both engines share — any change to the weighting/normalization
        stays backend-identical by construction.
        """
        import numpy as np

        cw = np.cumsum(self.selection_weights(popularity, power))
        return cw / cw[-1]
