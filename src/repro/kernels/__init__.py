"""Pallas TPU kernels for the framework's compute hot spots.

Each kernel directory contains the ``pl.pallas_call`` implementation with
explicit BlockSpec VMEM tiling, an ``ops.py`` jitted wrapper, and a
``ref.py`` pure-jnp oracle. On this CPU container kernels run in
interpret mode (correctness); on TPU the same calls compile to Mosaic.

Kernel selection is the ``tick_impl`` axis (``registry.py``): one name —
``"jnp" | "pallas" | "pallas_interpret" | "auto"`` — threaded from
``run_sweep``/``SweepDriver``/the CLIs down to the kernels, replacing
the former per-function ``use_pallas``/``interpret`` booleans (removed
after their one-release deprecation window).

- ``carousel_update``: the paper's transfer-manager tick (its stated
  linear-scaling hot loop) vectorized for the MXU: per-link counts and
  table lookups become one-hot matmuls; transfers tile across VMEM
  blocks with sequential-grid accumulation.
- ``lane_tick``: the batched sweep engine's fused tick — the carousel
  transfer math + completion billing per site block, the shared-GCS
  prefix-sum admission scan (refinement passes as a sequential grid
  axis), and the K/W candidate-window prefix recurrences; lane-blocked
  via ``vmap`` (the batch axis becomes a leading grid dimension).
- ``flash_attention``: blocked online-softmax attention (128x128 MXU
  tiles, GQA-aware, causal + sliding-window masks).
- ``mamba_scan``: chunked selective-scan; the carry persists in a VMEM
  scratch across sequential time-chunk grid steps, emitting y (not h) to
  keep HBM traffic O(T x d_inner).

The model's jnp reference paths (``models.attention.attention_core``,
``models.ssm.ssm_scan_y``) mirror these kernels' chunked structures, so
the dry-run HLO is representative; on TPU the kernels additionally keep
chunk intermediates in VMEM (the EXPERIMENTS §Perf notes quantify where
the jnp chunked paths over-count HBM bytes relative to the kernels).
"""
