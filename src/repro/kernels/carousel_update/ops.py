"""Jitted wrappers for the carousel tick kernel.

``carousel_tick`` executes one transfer-manager tick under the
``tick_impl`` selection axis (``repro.kernels.registry``): ``"jnp"``
runs the jnp reference, ``"pallas"`` the compiled kernel,
``"pallas_interpret"`` the kernel in interpret mode, and ``"auto"``
resolves per host (compiled on an accelerator, jnp on CPU — never
silently interpret). The pre-registry ``use_pallas=``/``interpret=``
booleans remain one release as deprecated aliases.

``simulate_ticks`` scans the tick over many steps — the fully
vectorized tick engine (the accelerator-native equivalent of the
paper's transfer-manager loop) used by the throughput benchmark.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.carousel_update.carousel_update import carousel_tick_pallas
from repro.kernels.carousel_update.ref import carousel_tick_ref
from repro.kernels.registry import (
    UNSET,
    resolve_tick_impl,
    tick_impl_from_use_pallas,
)


@functools.partial(jax.jit, static_argnames=("use_kernel", "interpret"))
def _carousel_tick(link_id, active, done, total, bw, mode, dt,
                   use_kernel: bool, interpret: bool):
    if use_kernel:
        return carousel_tick_pallas(link_id, active, done, total, bw, mode,
                                    dt, interpret=interpret)
    return carousel_tick_ref(link_id, active, done, total, bw, mode, dt)


def carousel_tick(link_id, active, done, total, bw, mode, dt,
                  tick_impl: str = "auto", use_pallas=UNSET,
                  interpret=UNSET):
    """One transfer-manager tick; implementation selected by ``tick_impl``.

    Deliberately a plain function around a jitted core so the
    deprecation warning for the legacy ``use_pallas=``/``interpret=``
    aliases fires on every call, not only at trace time. The aliases
    override ``tick_impl`` when given (``use_pallas=True`` maps to the
    legacy interpret-mode kernel on every host unless ``interpret=``
    pins it) and will be removed next release.
    """
    if use_pallas is not UNSET or interpret is not UNSET:
        mapped = tick_impl_from_use_pallas(
            True if use_pallas is UNSET else use_pallas,
            where="carousel_tick")
        if mapped != "jnp" and interpret is not UNSET:
            mapped = "pallas_interpret" if interpret else "pallas"
        tick_impl = mapped
    impl = resolve_tick_impl(tick_impl)
    return _carousel_tick(link_id, active, done, total, bw, mode, dt,
                          use_kernel=impl.use_kernel,
                          interpret=impl.interpret)


@functools.partial(jax.jit, static_argnames=("n_ticks",))
def simulate_ticks(link_id, active, done, total, bw, mode, dt, n_ticks: int):
    """Run n_ticks of the tick engine; transfers complete and deactivate."""

    def body(carry, _):
        act, dn = carry
        new_done, completed, _ = carousel_tick_ref(link_id, act, dn, total,
                                                   bw, mode, dt)
        act = jnp.logical_and(act, jnp.logical_not(completed))
        return (act, new_done), completed.sum()

    (act, dn), completions = jax.lax.scan(body, (active, done),
                                          None, length=n_ticks)
    return act, dn, completions
