"""Pallas kernel allclose sweeps vs. pure-jnp oracles (interpret mode)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.carousel_update.ops import carousel_tick
from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.mamba_scan.ops import mamba_scan


@pytest.mark.parametrize("n,m", [(64, 3), (1000, 17), (2049, 33)])
@pytest.mark.parametrize("dt", [1.0, 10.0])
def test_carousel_tick_shapes(n, m, dt):
    rng = np.random.default_rng(n + m)
    link_id = jnp.asarray(rng.integers(0, m, n), jnp.int32)
    active = jnp.asarray(rng.random(n) < 0.6)
    total = jnp.asarray(rng.exponential(1e9, n).astype(np.float32) + 1e6)
    done = jnp.asarray(rng.random(n).astype(np.float32)) * total
    bw = jnp.asarray(rng.uniform(1e6, 1e8, m).astype(np.float32))
    mode = jnp.asarray(rng.integers(0, 2, m), jnp.int32)
    k = carousel_tick(link_id, active, done, total, bw, mode, dt,
                      tick_impl="pallas_interpret")
    r = carousel_tick(link_id, active, done, total, bw, mode, dt,
                      tick_impl="jnp")
    np.testing.assert_allclose(k[0], r[0], rtol=1e-5)
    assert bool((k[1] == r[1]).all())
    np.testing.assert_allclose(k[2], r[2], rtol=1e-6)


def test_carousel_tick_scalar_semantics():
    """Kernel math matches the Python event engine's per-transfer rate."""
    link_id = jnp.asarray([0, 0, 1], jnp.int32)
    active = jnp.asarray([True, True, True])
    done = jnp.zeros(3, jnp.float32)
    total = jnp.asarray([100.0, 100.0, 100.0])
    bw = jnp.asarray([10.0, 8.0], jnp.float32)
    mode = jnp.asarray([0, 1], jnp.int32)  # link0 shared, link1 throughput
    nd, comp, counts = carousel_tick(link_id, active, done, total, bw, mode,
                                     2.0, tick_impl="pallas_interpret")
    # link0 shared: 10/2 x 2 s = 10 bytes each; link1: 8 x 2 = 16
    np.testing.assert_allclose(np.asarray(nd), [10.0, 10.0, 16.0])
    assert not bool(comp.any())


# ---------------------------------------------------------------------------
# tick_impl registry (ISSUE 7): backend-aware "auto" resolution
# ---------------------------------------------------------------------------

def test_tick_impl_auto_resolution(monkeypatch):
    """"auto" compiles on an accelerator and falls back to the jnp oracle
    on CPU — never silently interpret mode (which is a parity path, not a
    speed mode)."""
    from repro.kernels import registry

    for platform in ("tpu", "gpu"):
        monkeypatch.setattr(registry, "_platform", lambda p=platform: p)
        assert registry.on_accelerator()
        assert registry.default_tick_impl() == "pallas"
        impl = registry.resolve_tick_impl("auto")
        assert impl.name == "pallas"
        assert impl.use_kernel and not impl.interpret
        assert registry.default_interpret() is False

    monkeypatch.setattr(registry, "_platform", lambda: "cpu")
    assert not registry.on_accelerator()
    assert registry.default_tick_impl() == "jnp"
    impl = registry.resolve_tick_impl("auto")
    assert impl.name == "jnp" and not impl.use_kernel
    assert registry.default_interpret() is True
    # None means "auto"; a resolved TickImpl passes through unchanged
    assert registry.resolve_tick_impl(None).name == "jnp"
    assert registry.resolve_tick_impl(impl) is impl


def test_tick_impl_concrete_names_platform_independent(monkeypatch):
    """Concrete names never consult the backend (resolution is jax-free)."""
    from repro.kernels import registry

    def boom():
        raise AssertionError("concrete names must not probe the platform")

    monkeypatch.setattr(registry, "_platform", boom)
    for name in ("jnp", "pallas", "pallas_interpret"):
        assert registry.resolve_tick_impl(name).name == name


def test_tick_impl_unknown_name_rejected():
    from repro.kernels.registry import TICK_IMPL_CHOICES, resolve_tick_impl

    with pytest.raises(ValueError, match="tick_impl"):
        resolve_tick_impl("cuda")
    assert TICK_IMPL_CHOICES[0] == "auto"


def test_tick_impl_boolean_rejected_with_upgrade_pointer(monkeypatch):
    """A bool in the tick_impl slot (a legacy positional use_pallas
    call) gets a pointer at the removed flag and the tick_impl= upgrade
    path, not a bare KeyError — and the rejection never probes the
    platform (stays jax-free)."""
    from repro.kernels import registry

    def boom():
        raise AssertionError("boolean rejection must not probe the "
                             "platform")

    monkeypatch.setattr(registry, "_platform", boom)
    for legacy in (True, False):
        with pytest.raises(ValueError, match="use_pallas"):
            registry.resolve_tick_impl(legacy)
    assert not hasattr(registry, "tick_impl_from_use_pallas")


def test_tick_impl_resolution_counted():
    """Every resolve lands one labeled tick_impl.resolved increment."""
    from repro.kernels.registry import resolve_tick_impl
    from repro.obs.metrics import get_registry

    reg = get_registry()
    before = reg.value("tick_impl.resolved", impl="jnp", requested="jnp")
    resolve_tick_impl("jnp")
    assert reg.value("tick_impl.resolved", impl="jnp",
                     requested="jnp") == before + 1


def test_carousel_tick_use_pallas_removed():
    """The legacy keyword is gone from carousel_tick; tick_impl= is the
    only selection axis."""
    link_id = jnp.asarray([0, 1], jnp.int32)
    active = jnp.asarray([True, True])
    done = jnp.zeros(2, jnp.float32)
    total = jnp.asarray([50.0, 50.0])
    bw = jnp.asarray([10.0, 10.0], jnp.float32)
    mode = jnp.asarray([1, 1], jnp.int32)
    with pytest.raises(TypeError, match="use_pallas"):
        carousel_tick(link_id, active, done, total, bw, mode, 1.0,
                      use_pallas=False)
    new = carousel_tick(link_id, active, done, total, bw, mode, 1.0,
                        tick_impl="jnp")
    kern = carousel_tick(link_id, active, done, total, bw, mode, 1.0,
                         tick_impl="pallas_interpret")
    np.testing.assert_allclose(np.asarray(new[0]), np.asarray(kern[0]),
                               rtol=1e-6)


# ---------------------------------------------------------------------------
# lane_tick fused kernels vs. jnp oracles (interpret mode)
# ---------------------------------------------------------------------------

def _lane_transfer_oracle(link_id, active, done, total, sizes, bw, mode,
                          dt, month_onehot):
    """The pre-fusion jnp math from repro.sim.batched (eager, op-by-op)."""
    ltype = link_id % 3
    act = active.astype(np.float32)
    S, F = link_id.shape
    counts = np.zeros((S, 3), np.float32)
    for t in range(3):
        counts[:, t] = (act * (ltype == t)).sum(axis=1)
    cnt = np.take_along_axis(counts, ltype, axis=1)
    bw_f = np.take_along_axis(bw.reshape(S, 3), ltype, axis=1)
    mode_f = np.take_along_axis(mode.reshape(S, 3).astype(np.float32),
                                ltype, axis=1)
    rate = np.where(mode_f > 0.5, bw_f, bw_f / np.maximum(cnt, 1.0))
    new_done = np.minimum(total, done + act * rate * dt)
    comp = ((new_done >= total) & (act > 0.5)).astype(np.float32)
    comp_sz = sizes * comp
    tape = (comp_sz * (ltype == 0)).sum(axis=1)
    recall = (comp_sz * (ltype == 1)).sum(axis=1)
    mig = (comp_sz * (ltype == 2)).sum(axis=1)
    egress = month_onehot * recall.sum()
    cls_b = month_onehot * (comp * (ltype == 1)).sum()
    cls_a = month_onehot * (comp * (ltype == 2)).sum()
    return new_done, comp, tape, recall, mig, egress, cls_a, cls_b


def _lane_transfer_inputs(S=3, F=37, seed=0):
    rng = np.random.default_rng(seed)
    site = np.repeat(np.arange(S)[:, None], F, axis=1)
    link_id = (3 * site + rng.integers(0, 3, (S, F))).astype(np.int32)
    active = rng.random((S, F)) < 0.5
    total = (rng.exponential(1e8, (S, F)) + 1e3).astype(np.float32)
    done = (rng.random((S, F)).astype(np.float32)) * total
    sizes = total.copy()
    bw = rng.uniform(1e5, 1e7, 3 * S).astype(np.float32)
    mode = rng.integers(0, 2, 3 * S).astype(np.int32)
    month_onehot = np.zeros(4, np.float32)
    month_onehot[1] = 1.0
    return link_id, active, done, total, sizes, bw, mode, month_onehot


def test_lane_transfer_tick_matches_oracle():
    from repro.kernels import lane_tick

    (link_id, active, done, total, sizes, bw, mode,
     month_onehot) = _lane_transfer_inputs()
    dt = 50.0
    out = lane_tick.transfer_tick(
        jnp.asarray(link_id), jnp.asarray(active), jnp.asarray(done),
        jnp.asarray(total), jnp.asarray(sizes), jnp.asarray(bw),
        jnp.asarray(mode), dt, jnp.asarray(month_onehot), interpret=True)
    ref = _lane_transfer_oracle(link_id, active, done, total, sizes,
                                bw, mode, dt, month_onehot)
    # new_done can differ by FMA-fusion ulps between traces; the
    # completion mask and the billing classifications must agree exactly
    np.testing.assert_allclose(np.asarray(out[0]), ref[0], rtol=1e-6)
    np.testing.assert_array_equal(np.asarray(out[1]), ref[1])
    for got, want in zip(out[2:], ref[2:]):
        np.testing.assert_allclose(np.asarray(got), want, rtol=1e-5)


def test_lane_gcs_admit_matches_global_cumsum_oracle():
    from repro.kernels import lane_tick

    rng = np.random.default_rng(7)
    S, F, n_passes = 4, 33, 3
    want = rng.random((S, F)) < 0.4
    sizes = rng.uniform(1e6, 1e9, (S, F)).astype(np.float32)
    used0, limit = np.float32(2e9), np.float32(2e10)
    dt, month_onehot = 60.0, np.asarray([0.0, 1.0, 0.0], np.float32)

    # oracle: GCS_ADMIT_PASSES passes of a global cumsum over the
    # site-major flattened candidate vector (the jnp program's loop)
    admitted = np.zeros((S, F), bool)
    used = used0
    for _ in range(n_passes):
        rem = want & ~admitted
        csum = np.cumsum((sizes * rem).ravel()).reshape(S, F)
        new = rem & (used + csum <= limit)
        admitted |= new
        used = used + (sizes * new).sum(dtype=np.float64).astype(np.float32)

    adm, used_k, gbsec = lane_tick.gcs_admit(
        jnp.asarray(want), jnp.asarray(sizes), used0, limit, dt,
        jnp.asarray(month_onehot), n_passes=n_passes, interpret=True)
    np.testing.assert_array_equal(np.asarray(adm) > 0.5, admitted)
    np.testing.assert_allclose(float(used_k), used, rtol=1e-5)
    np.testing.assert_allclose(
        np.asarray(gbsec), month_onehot * (used / 1e9 * dt), rtol=1e-5)


@pytest.mark.parametrize("fifo", [False, True])
def test_lane_window_admit_bitwise(fifo):
    from repro.kernels import lane_tick

    rng = np.random.default_rng(13 + fifo)
    S, C = 5, 6
    live = rng.random((S, C)) < 0.7
    size = rng.uniform(1e6, 5e9, (S, C)).astype(np.float32)
    used = rng.uniform(0, 1e10, S).astype(np.float32)
    limit = np.full(S, 1e10, np.float32)

    # oracle: the jnp prefix recurrence from repro.sim.batched, verbatim
    extra = np.zeros(S, np.float32)
    blocked = np.zeros(S, bool)
    adm_ref = np.zeros((S, C), np.float32)
    for k in range(C):
        fit = used + extra + size[:, k] <= limit
        if fifo:
            adm = live[:, k] & fit & ~blocked
            blocked |= live[:, k] & ~fit
        else:
            adm = live[:, k] & fit
        adm_ref[:, k] = adm
        extra = extra + np.where(adm, size[:, k], 0.0).astype(np.float32)

    adm, extra_k = lane_tick.window_admit(
        jnp.asarray(live), jnp.asarray(size), jnp.asarray(used),
        jnp.asarray(limit), fifo=fifo, interpret=True)
    np.testing.assert_array_equal(np.asarray(adm), adm_ref)
    np.testing.assert_array_equal(np.asarray(extra_k), extra)


def test_lane_kernels_vmap_lane_blocking():
    """The wrappers are written per-lane and vmap-ed by the sweep engine:
    the batch axis becomes a leading grid dimension and per-lane results
    match per-lane calls."""
    from repro.kernels import lane_tick

    L = 3
    per_lane = [_lane_transfer_inputs(seed=s) for s in range(L)]
    stacked = [jnp.asarray(np.stack([p[i] for p in per_lane]))
               for i in range(8)]
    dt = jnp.full((L,), 25.0, jnp.float32)
    batched = jax.vmap(
        lambda a, b, c, d, e, f, g, t, h: lane_tick.transfer_tick(
            a, b > 0.5, c, d, e, f, g, t, h, interpret=True))(
        stacked[0], stacked[1].astype(jnp.float32), stacked[2], stacked[3],
        stacked[4], stacked[5], stacked[6], dt, stacked[7])
    for lane, p in enumerate(per_lane):
        single = lane_tick.transfer_tick(
            jnp.asarray(p[0]), jnp.asarray(p[1]), jnp.asarray(p[2]),
            jnp.asarray(p[3]), jnp.asarray(p[4]), jnp.asarray(p[5]),
            jnp.asarray(p[6]), 25.0, jnp.asarray(p[7]), interpret=True)
        for got, want in zip(batched, single):
            np.testing.assert_allclose(np.asarray(got[lane]),
                                       np.asarray(want), rtol=1e-6)


@pytest.mark.parametrize("B,nh,nkv,T,hd", [
    (1, 2, 1, 64, 32),
    (2, 4, 2, 200, 64),
    (1, 8, 8, 256, 128),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("window", [0, 64])
def test_flash_attention_sweep(B, nh, nkv, T, hd, dtype, window):
    rng = np.random.default_rng(T + hd)
    q = jnp.asarray(rng.normal(size=(B, nh, T, hd)), dtype)
    k = jnp.asarray(rng.normal(size=(B, nkv, T, hd)), dtype)
    v = jnp.asarray(rng.normal(size=(B, nkv, T, hd)), dtype)
    out_k = flash_attention(q, k, v, causal=True, window=window,
                            use_pallas=True)
    out_r = flash_attention(q, k, v, causal=True, window=window,
                            use_pallas=False)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(
        np.asarray(out_k, np.float32), np.asarray(out_r, np.float32),
        atol=tol, rtol=tol)


@pytest.mark.parametrize("B,T,D,N", [
    (1, 64, 128, 8),
    (2, 300, 130, 16),   # unaligned: exercises padding
    (1, 512, 256, 16),
])
def test_mamba_scan_sweep(B, T, D, N):
    rng = np.random.default_rng(T + D)
    dA = jnp.asarray(np.exp(-rng.random((B, T, D, N))).astype(np.float32))
    dBu = jnp.asarray(rng.normal(size=(B, T, D, N)).astype(np.float32) * 0.1)
    C = jnp.asarray(rng.normal(size=(B, T, N)).astype(np.float32))
    yk = mamba_scan(dA, dBu, C, use_pallas=True)
    yr = mamba_scan(dA, dBu, C, use_pallas=False)
    np.testing.assert_allclose(np.asarray(yk), np.asarray(yr),
                               atol=1e-4, rtol=1e-4)


def test_mamba_scan_carry_across_chunks():
    """State must persist across time-chunk grid steps (scratch carry)."""
    B, T, D, N = 1, 512, 128, 4  # T spans 2 chunks of 256
    dA = jnp.ones((B, T, D, N), jnp.float32) * 0.999
    dBu = jnp.ones((B, T, D, N), jnp.float32) * 0.01
    C = jnp.ones((B, T, N), jnp.float32)
    y = mamba_scan(dA, dBu, C, use_pallas=True)
    yr = mamba_scan(dA, dBu, C, use_pallas=False)
    # monotonically increasing accumulation; chunk boundary must not reset
    assert float(y[0, 256, 0]) > float(y[0, 255, 0]) > float(y[0, 0, 0])
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), rtol=1e-4)


def test_model_ssm_block_runs_finite():
    """Smoke: models.ssm's block runs end-to-end and stays finite (kernel
    vs. reference parity is covered by the mamba_scan tests above)."""
    from repro.configs import get_smoke_config
    from repro.models.ssm import init_ssm, ssm_block
    cfg = get_smoke_config("falcon_mamba_7b")
    params = init_ssm(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model),
                          dtype=cfg.dtype)

    ref_out = ssm_block(params, cfg, x)
    assert bool(jnp.isfinite(ref_out.astype(jnp.float32)).all())
