"""Decision-support layer: the paper's §5.3 question, answered by search.

The simulation exists "to assist with the decision process of using
commercial cloud storage" — when does a cloud cache beat buying on-prem
disk, and at what price point?  PRs 1–4 made evaluating a fixed scenario
grid fast; this module *drives* that engine in a loop:

- ``summarize`` / ``ci_frontier``: seed replicas (dedicated dynamics lanes
  on the batched backend) fold into mean ± CI intervals per configuration,
  and Pareto-frontier membership is decided on **interval overlap** — a
  point is only dropped when some other point is better beyond the
  uncertainty of both (Sim et al.: cache effectiveness is only trustworthy
  with run-to-run error bars).
- ``refine_frontier``: adaptive grid refinement. Start from a coarse
  ``ScenarioSpec`` grid, find the cost/throughput frontier, recursively
  bisect the continuous axes around the frontier until the local axis gap
  is within tolerance or the lane budget is hit. Bisection localizes the
  frontier at logarithmic cost where an equivalent-resolution dense grid
  pays linearly (``RefineResult.dense_lanes``).
- ``solve_displaced_disk``: the paper's headline claim, as a bisection —
  the smallest cloud-cache size whose jobs-done still matches a disk-only
  baseline's within CI bounds; the difference in provisioned on-prem
  capacity is what the cloud budget displaces.
- ``solve_break_even_price``: bisection on a billing-only price axis
  (flat egress USD/GiB by default) for the cloud price at which the
  cloud-cache configuration's total cost (cloud bill + on-prem cache
  disk) matches the disk-only baseline's. Each narrowing round evaluates
  its whole price ladder as one batch, so on the batched backend the
  round simulates the candidate's dynamics lane once and re-bills every
  probe from it (``pack_specs`` pricing-lane sharing).
- ``decide``: the orchestrated workflow producing a ``DecisionReport``
  (markdown/JSON) — the instrument the paper describes, pointed at a grid.

Every solver takes an ``evaluate`` callable (``specs -> SweepResult``;
normally a ``repro.sim.sweep.SweepDriver``, which memoizes across rounds
and reuses the batched backend's compiled program), so the numerical
machinery is testable against synthetic cost models without simulating.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field, replace
from typing import (Any, Callable, Dict, List, Mapping, Optional, Sequence,
                    Tuple)

from repro.core.scenarios import (
    PRICING_FIELDS,
    ScenarioSpec,
    axis_value,
    build_config,
    dynamics_key,
    expand_grid,
    refine_levels,
    strip_seed,
    with_axis,
    with_seeds,
)
from repro.obs.metrics import get_registry
from repro.obs.trace import get_tracer
from repro.sim.infrastructure import TB
from repro.sim.sweep import ScenarioResult, SweepResult

#: ``specs -> SweepResult`` — the solvers' evaluation protocol
#: (``SweepDriver`` satisfies it; tests inject synthetic models).
Evaluate = Callable[[Sequence[ScenarioSpec]], SweepResult]

#: Two-sided normal critical value for the default 95% confidence level.
Z_95 = 1.96


# --------------------------------------------------------------------------
# Seed-level uncertainty
# --------------------------------------------------------------------------

@dataclass(frozen=True)
class Interval:
    """A metric's seed-level mean ± normal CI (half-width ``z·sd/√n``).

    With a single seed the interval degenerates to the point estimate —
    comparisons then reduce to the classic point-dominance rule, which the
    report flags (single-seed decisions carry no uncertainty measure).
    """

    mean: float
    sd: float
    n: int
    lo: float
    hi: float

    @classmethod
    def from_samples(cls, xs: Sequence[float], z: float = Z_95) -> "Interval":
        if not xs:
            raise ValueError("cannot summarize an empty sample")
        n = len(xs)
        m = sum(xs) / n
        if n < 2:
            return cls(mean=m, sd=0.0, n=n, lo=m, hi=m)
        var = sum((x - m) ** 2 for x in xs) / (n - 1)
        sd = math.sqrt(var)
        half = z * sd / math.sqrt(n)
        return cls(mean=m, sd=sd, n=n, lo=m - half, hi=m + half)

    def overlaps(self, other: "Interval") -> bool:
        return self.lo <= other.hi and other.lo <= self.hi

    def shifted(self, delta: float) -> "Interval":
        """The interval of ``X + delta`` for a deterministic ``delta``."""
        return Interval(mean=self.mean + delta, sd=self.sd, n=self.n,
                        lo=self.lo + delta, hi=self.hi + delta)

    def __format__(self, fmt: str) -> str:
        if self.n < 2:
            return format(self.mean, fmt)
        return f"{self.mean:{fmt}} ± {(self.hi - self.lo) / 2:{fmt}}"


@dataclass
class DecisionPoint:
    """One configuration's across-seed summary (spec is seed-stripped)."""

    spec: ScenarioSpec
    jobs: Interval
    cost: Interval  # cloud bill, USD over the simulated window
    results: List[ScenarioResult] = field(default_factory=list)
    #: memo for ``OnPremDisk.provisioned_tb`` (price-model independent;
    #: frontier dominance would otherwise rebuild an HCDCConfig per
    #: pairwise comparison)
    _provisioned_tb: Optional[float] = field(default=None, repr=False,
                                             compare=False)

    @property
    def n_seeds(self) -> int:
        return self.jobs.n

    @property
    def label(self) -> str:
        return self.spec.label.rsplit(",seed=", 1)[0]


def summarize(results: Sequence[ScenarioResult],
              z: float = Z_95) -> List[DecisionPoint]:
    """Group results by spec-minus-seed into CI'd decision points.

    Order follows first appearance, so summaries of a sweep keep the grid
    order.
    """
    groups: Dict[ScenarioSpec, List[ScenarioResult]] = {}
    for r in results:
        groups.setdefault(strip_seed(r.spec), []).append(r)
    return [
        DecisionPoint(
            spec=key,
            jobs=Interval.from_samples([r.jobs_done for r in rs], z),
            cost=Interval.from_samples([r.cost_usd for r in rs], z),
            results=rs,
        )
        for key, rs in groups.items()
    ]


#: Maps a point to the cost interval frontier dominance is judged on.
#: Default: the cloud bill. ``OnPremDisk.total_interval`` judges on total
#: (cloud + pro-rated on-prem disk) cost instead, which separates
#: configurations whose cloud bills tie (pricing-deduped lanes) but whose
#: bought capacity differs.
CostOf = Callable[["DecisionPoint"], Interval]


def _cloud_cost(p: "DecisionPoint") -> Interval:
    return p.cost


def _seed_costs(p: DecisionPoint) -> Optional[Dict[int, float]]:
    """Per-seed cloud-bill samples, or ``None`` if seeds repeat."""
    out = {r.spec.seed: r.cost_usd for r in p.results}
    return out if len(out) == len(p.results) else None


def ci_dominates(a: DecisionPoint, b: DecisionPoint,
                 cost_of: CostOf = _cloud_cost) -> bool:
    """``a`` beats ``b`` beyond both uncertainties: a's cost interval lies
    at-or-below b's and a's jobs interval at-or-above b's, with at least
    one strict separation. Overlapping intervals never dominate — the data
    cannot distinguish the points, so both stay on the frontier.

    Exception — paired samples: two points with *identical* per-seed
    jobs-done samples ran the same dynamics realization (pricing variants
    billed off one shared lane, or a saturated-cache plateau where every
    size reproduces the same run). They are one experiment billed twice,
    not two noisy ones, so their costs compare **per seed** (shifted by
    each point's deterministic non-sample cost, e.g. on-prem disk), not
    interval-vs-interval. Without this, a strictly-pricier storage-price
    variant or a strictly-bigger cache with byte-identical dynamics would
    "survive" on CI overlap.
    """
    ca, cb = cost_of(a), cost_of(b)
    if a.jobs == b.jobs:  # same dynamics realization => paired comparison
        sa, sb = _seed_costs(a), _seed_costs(b)
        if sa is not None and sb is not None and set(sa) == set(sb):
            # per-seed total = cloud sample + deterministic shift
            da, db = ca.mean - a.cost.mean, cb.mean - b.cost.mean
            diffs = [(sa[s] + da) - (sb[s] + db) for s in sa]
            return all(d <= 0 for d in diffs) and any(d < 0 for d in diffs)
    ge_jobs = a.jobs.lo >= b.jobs.hi
    le_cost = ca.hi <= cb.lo
    strict = a.jobs.lo > b.jobs.hi or ca.hi < cb.lo
    return ge_jobs and le_cost and strict


def ci_frontier(points: Sequence[DecisionPoint],
                cost_of: CostOf = _cloud_cost) -> List[DecisionPoint]:
    """Non-dominated points under ``ci_dominates``, cost-ascending.

    Monotone in the evaluated set: for ``A ⊆ B``, every member of
    ``ci_frontier(B)`` that lies in ``A`` is also in ``ci_frontier(A)``
    (removing points can only remove dominators) — the property that lets
    adaptive refinement discard points without ever discarding one a dense
    grid would keep (pinned in ``tests/test_decide.py``).
    """
    front = [p for p in points
             if not any(q is not p and ci_dominates(q, p, cost_of)
                        for q in points)]
    return sorted(front, key=lambda p: (cost_of(p).mean, -p.jobs.mean))


# --------------------------------------------------------------------------
# On-prem disk economics
# --------------------------------------------------------------------------

@dataclass(frozen=True)
class OnPremDisk:
    """Total-cost-of-ownership model for on-prem cache disk.

    ``usd_per_tb_month`` amortizes purchase + power + operation over the
    hardware's service life (the default 15 USD/TB-month is a round
    mid-range figure for replicated spinning disk; pass your own). Cost is
    pro-rated over the simulated window — comparable to the cloud bill for
    the same window.
    """

    usd_per_tb_month: float = 15.0

    def provisioned_tb(self, point: DecisionPoint) -> float:
        """Disk capacity the configuration must buy, in TB.

        A finite ``cache_tb`` is bought per site. Unlimited sites
        (``cache_tb`` inf, or base default ``None`` resolving to no limit)
        must provision their peak usage; without a limit nothing is ever
        deleted, so usage grows monotonically and the final per-site
        ``disk_used`` *is* the peak (mean across seeds).

        Memoized on the point (price-model independent): frontier
        dominance evaluates this O(n²) times per round otherwise.
        """
        if point._provisioned_tb is not None:
            return point._provisioned_tb
        sites = build_config(point.spec).sites
        total = 0.0
        for name, limit in ((s.name, s.disk_limit) for s in sites):
            if limit is not None and math.isfinite(limit):
                total += limit / TB
            else:
                used = [r.metrics[f"{name}.disk_used_pb"] * 1000.0
                        for r in point.results]
                total += sum(used) / len(used)
        point._provisioned_tb = total
        return total

    def cost_usd(self, point: DecisionPoint) -> float:
        months = point.spec.days / 30.0
        return self.provisioned_tb(point) * self.usd_per_tb_month * months

    def total_usd(self, point: DecisionPoint) -> float:
        """Cloud bill + pro-rated on-prem disk for the simulated window."""
        return point.cost.mean + self.cost_usd(point)

    def total_interval(self, point: DecisionPoint) -> Interval:
        """Total-cost interval: the cloud bill's CI shifted by the
        (deterministic) on-prem disk cost — a ``CostOf`` for frontier
        dominance on total rather than cloud-only cost."""
        return point.cost.shifted(self.cost_usd(point))


# --------------------------------------------------------------------------
# Adaptive grid refinement
# --------------------------------------------------------------------------

@dataclass
class RefineRound:
    index: int
    new_specs: int
    new_lanes: int
    frontier_size: int


@dataclass
class RefineResult:
    points: List[DecisionPoint]  # every evaluated config, stable order
    frontier: List[DecisionPoint]
    rounds: List[RefineRound]
    axis_levels: Dict[str, List[float]]  # resolved levels per refined axis
    lanes_used: int  # distinct dynamics lanes the refinement simulated
    dense_lanes: int  # lanes of a uniform grid at the achieved resolution
    budget_hit: bool = False

    @property
    def lane_fraction(self) -> float:
        """Fraction of the equivalent dense grid's lanes actually paid."""
        return self.lanes_used / self.dense_lanes if self.dense_lanes else 1.0


def _dense_levels(levels: Sequence[float]) -> int:
    """Level count of a uniform grid matching the finest resolved gap."""
    finite = sorted({v for v in levels if v is not None and math.isfinite(v)})
    if len(finite) < 2:
        return len(finite)
    gaps = [b - a for a, b in zip(finite, finite[1:]) if b > a]
    if not gaps:
        return len(finite)
    span = finite[-1] - finite[0]
    return int(math.floor(span / min(gaps) + 1e-9)) + 1


def refine_frontier(axes: Mapping[str, Any], evaluate: Evaluate,
                    refine: Sequence[str] = ("cache_tb",), *,
                    n_seeds: int = 1, first_seed: int = 0,
                    rel_tol: float = 0.05, max_rounds: int = 4,
                    lane_budget: Optional[int] = None,
                    cost_of: CostOf = _cloud_cost,
                    z: float = Z_95) -> RefineResult:
    """Adaptively refine a coarse grid around its cost/throughput frontier.

    ``axes`` is an ``expand_grid`` mapping (without a ``seed`` axis — seed
    replication is the solver's job, ``n_seeds``/``first_seed``). Each
    round finds the CI frontier of everything evaluated so far, proposes
    the midpoints between every frontier point and its nearest evaluated
    neighbors on each refined axis (``repro.core.scenarios.refine_levels``),
    and evaluates the proposals as one batch. Refinement stops when every
    frontier-adjacent gap is within ``rel_tol`` of the axis span,
    ``max_rounds`` is reached, or evaluating another round would exceed
    ``lane_budget`` distinct dynamics lanes.

    ``dense_lanes`` reports what a uniform (non-adaptive) grid resolving
    the same finest axis gap over the full span would have simulated —
    bisection pays log where the dense grid pays linear, which is the
    lane-efficiency claim ``benchmarks/bench_sweep.py`` tracks.
    """
    if "seed" in axes:
        raise ValueError("pass seed replication via n_seeds, not a seed axis")
    unknown = [a for a in refine if a not in axes]
    if unknown:
        raise ValueError(f"refine axes not present in the grid: {unknown} "
                         f"(grid axes: {sorted(axes)})")
    refine = list(refine)
    for a in refine:
        axis_value(ScenarioSpec(), a)  # rejects non-continuous axes early
        vals = axes[a]
        if not isinstance(vals, (list, tuple)) or len(vals) < 2:
            raise ValueError(f"refined axis {a!r} needs >= 2 grid levels")
    base_specs = expand_grid(dict(axes))
    specs = with_seeds(base_specs, n_seeds, first_seed)

    results: Dict[ScenarioSpec, ScenarioResult] = {}
    lanes: set = set()
    axis_levels: Dict[str, set] = {
        a: {axis_value(s, a) for s in base_specs} for a in refine}
    rounds: List[RefineRound] = []
    budget_hit = False

    def run_batch(batch: List[ScenarioSpec]) -> int:
        new_lanes = {dynamics_key(s) for s in batch} - lanes
        res = evaluate(batch)
        for s, r in zip(batch, res.results):
            results[s] = r
        lanes.update(new_lanes)
        return len(new_lanes)

    pending = specs
    for i in range(max_rounds + 1):
        if lane_budget is not None and pending:
            would = len({dynamics_key(s) for s in pending} - lanes)
            if lanes and len(lanes) + would > lane_budget:
                budget_hit = True
                break
        with get_tracer().span("refine.round", round=i,
                               new_specs=len(pending)):
            n_lanes = run_batch(pending) if pending else 0
            points = summarize(list(results.values()), z)
            frontier = ci_frontier(points, cost_of)
        rounds.append(RefineRound(index=i, new_specs=len(pending),
                                  new_lanes=n_lanes,
                                  frontier_size=len(frontier)))
        if i == max_rounds:
            break
        # propose midpoints around the frontier on every refined axis
        proposals: List[ScenarioSpec] = []
        for a in refine:
            anchors = [axis_value(p.spec, a) for p in frontier]
            mids = refine_levels(sorted(
                v for v in axis_levels[a]
                if v is not None and math.isfinite(v)), anchors, rel_tol)
            for p in frontier:
                v = axis_value(p.spec, a)
                if v is None or not math.isfinite(v):
                    continue
                for m in mids:
                    # only bisect gaps adjacent to this frontier point
                    if min(abs(m - u) for u in axis_levels[a]
                           if u is not None
                           and math.isfinite(u)) >= abs(m - v) - 1e-12:
                        proposals.append(with_axis(p.spec, a, m))
            axis_levels[a].update(axis_value(s, a) for s in proposals)
        seen = set(results)
        pending = [s for s in dict.fromkeys(with_seeds(
            list(dict.fromkeys(proposals)), n_seeds, first_seed))
            if s not in seen]
        if not pending:
            break

    points = summarize(list(results.values()), z)
    frontier = ci_frontier(points, cost_of)
    # resolved levels come from *evaluated* specs only: on the budget-hit /
    # early-break paths axis_levels still carries proposed-but-never-run
    # midpoints, which would overstate the achieved resolution (and with
    # it dense_lanes / lane_fraction, the acceptance metric)
    resolved = {a: sorted({v for s in results
                           if (v := axis_value(s, a)) is not None
                           and math.isfinite(v)})
                for a in refine}
    # equivalent dense grid: per refined axis, a uniform grid at the finest
    # resolved gap; the non-refined axis combinations (pricing axes dedupe
    # away, seeds do not) multiply in unchanged. Billing-only refined axes
    # (PRICING_FIELDS) contribute no dynamics lanes on either side — a
    # dense price grid re-bills the same lanes — so they multiply by 1,
    # keeping lane_fraction honest for price-axis refinement.
    base_keys = {dynamics_key(s) for s in with_seeds(
        [_pin_axes(s, refine, axes) for s in base_specs],
        n_seeds, first_seed)}
    dense = len(base_keys)
    for a in refine:
        if a not in PRICING_FIELDS:
            dense *= max(_dense_levels(resolved[a]), 1)
    return RefineResult(points=points, frontier=frontier, rounds=rounds,
                        axis_levels=resolved, lanes_used=len(lanes),
                        dense_lanes=dense, budget_hit=budget_hit)


def _pin_axes(spec: ScenarioSpec, axes_to_pin: Sequence[str],
              axes: Mapping[str, Any]) -> ScenarioSpec:
    """Collapse refined axes to their first grid level (combo counting)."""
    for a in axes_to_pin:
        vals = axes[a]
        spec = with_axis(spec, a, vals[0])
    return spec


# --------------------------------------------------------------------------
# Break-even solvers
# --------------------------------------------------------------------------

@dataclass
class DisplacedDisk:
    """Result of the displaced-capacity bisection (the headline claim)."""

    min_cache_tb: Optional[float]
    candidate: Optional[DecisionPoint]  # at the trimmed cache size
    baseline_provisioned_tb: float
    candidate_provisioned_tb: float
    cloud_budget_usd: float  # the candidate's cloud bill for the window
    probes: List[DecisionPoint] = field(default_factory=list)
    rounds: int = 0
    converged: bool = False
    note: str = ""

    @property
    def displaced_tb(self) -> float:
        return self.baseline_provisioned_tb - self.candidate_provisioned_tb


def solve_displaced_disk(candidate: ScenarioSpec, baseline: DecisionPoint,
                         evaluate: Evaluate, onprem: OnPremDisk, *,
                         lo: Optional[float] = None,
                         n_seeds: int = 1, first_seed: int = 0,
                         rel_tol: float = 0.05, max_rounds: int = 12,
                         z: float = Z_95) -> DisplacedDisk:
    """Smallest cloud-cache size still matching the baseline's jobs-done.

    Bisection on ``cache_tb`` over ``[lo, candidate.cache_tb]`` with the
    predicate "jobs-done CI reaches the baseline's CI" (upper bound of the
    candidate's interval ≥ lower bound of the baseline's — the two are
    statistically indistinguishable or better). Jobs-done is monotone
    non-decreasing in cache size, so the predicate is bisectable; each
    probe simulates ``n_seeds`` fresh dynamics lanes. The difference in
    provisioned on-prem capacity between the baseline and the trimmed
    candidate is the disk the candidate's cloud budget displaces.

    ``lo`` defaults to 1/16 of the candidate's cache. Terminates when the
    bracket is within ``rel_tol`` of its initial width or ``max_rounds``
    probes ran.
    """
    if candidate.cache_tb is None or not math.isfinite(candidate.cache_tb):
        return DisplacedDisk(
            min_cache_tb=None, candidate=None,
            baseline_provisioned_tb=onprem.provisioned_tb(baseline),
            candidate_provisioned_tb=float("nan"), cloud_budget_usd=0.0,
            note="candidate has no explicit finite cache_tb to bisect "
                 "(base-default or unlimited cache)")
    hi = float(candidate.cache_tb)
    lo = hi / 16.0 if lo is None else float(lo)
    if not 0 < lo < hi:
        raise ValueError(f"need 0 < lo < candidate cache, got lo={lo!r} "
                         f"hi={hi!r}")
    probes: List[DecisionPoint] = []

    def probe(cache: float) -> DecisionPoint:
        spec = with_axis(candidate, "cache_tb", cache)
        res = evaluate(with_seeds([spec], n_seeds, first_seed))
        point = summarize(res.results, z)[0]
        probes.append(point)
        return point

    def ok(point: DecisionPoint) -> bool:
        return point.jobs.hi >= baseline.jobs.lo

    best = probe(hi)
    if not ok(best):
        return DisplacedDisk(
            min_cache_tb=None, candidate=best,
            baseline_provisioned_tb=onprem.provisioned_tb(baseline),
            candidate_provisioned_tb=onprem.provisioned_tb(best),
            cloud_budget_usd=best.cost.mean, probes=probes, rounds=1,
            note="candidate never matches the baseline's jobs-done")
    floor = probe(lo)
    rounds = 2
    if ok(floor):
        best, hi = floor, lo  # even the floor matches; report it
        converged = True
    else:
        width0 = hi - lo
        while hi - lo > rel_tol * width0 and rounds < max_rounds:
            mid = (lo + hi) / 2.0
            p = probe(mid)
            rounds += 1
            if ok(p):
                best, hi = p, mid
            else:
                lo = mid
        # max_rounds can exhaust before the bracket reaches tolerance
        converged = hi - lo <= rel_tol * width0
    return DisplacedDisk(
        min_cache_tb=hi, candidate=best,
        baseline_provisioned_tb=onprem.provisioned_tb(baseline),
        candidate_provisioned_tb=onprem.provisioned_tb(best),
        cloud_budget_usd=best.cost.mean, probes=probes, rounds=rounds,
        converged=converged,
        note=f"jobs {best.jobs:.0f} vs baseline {baseline.jobs:.0f}")


@dataclass
class BreakEven:
    """Result of the price-axis bisection."""

    axis: str
    price: Optional[float]  # None when no crossing exists in range
    lo: float
    hi: float
    baseline_total_usd: float
    candidate: Optional[DecisionPoint] = None  # billed at ~break-even price
    rounds: int = 0
    converged: bool = False
    note: str = ""


def solve_break_even_price(candidate: ScenarioSpec, baseline: DecisionPoint,
                           evaluate: Evaluate, onprem: OnPremDisk, *,
                           axis: str = "egress_price",
                           lo: float = 0.0, hi: float = 0.12,
                           n_seeds: int = 1, first_seed: int = 0,
                           rel_tol: float = 0.01, max_rounds: int = 8,
                           probes_per_round: int = 9,
                           z: float = Z_95) -> BreakEven:
    """Cloud price at which the candidate's total cost meets the baseline's.

    Total cost = cloud bill at the probed price + pro-rated on-prem cost of
    the candidate's own cache disk; the baseline's total is its (usually
    zero) cloud bill + its provisioned disk. The bill is monotone
    non-decreasing in any price axis, so the crossing is bisectable; below
    the returned price the cloud configuration is the cheaper way to reach
    its jobs-done level.

    ``axis`` must be a billing-only spec field (``egress_price`` USD/GiB by
    default, ``storage_price`` USD/GB-month also works), and each
    narrowing round evaluates its whole ``probes_per_round`` price ladder
    as **one** batch: on the batched backend the ladder dedupes to a
    single simulation of the candidate's dynamics lane per round
    (``pack_specs`` pricing-lane sharing is per packed grid, so batching
    — not the driver cache — is what makes probes cheap), and the bracket
    shrinks by ``probes_per_round - 1`` per round instead of 2. Returns
    ``price=None`` with an explanatory note when the crossing is not
    bracketed by ``[lo, hi]`` (cloud never / always breaks even in range).
    ``rounds`` counts evaluation batches.
    """
    if not lo < hi:
        raise ValueError(f"need lo < hi, got {lo!r} >= {hi!r}")
    if probes_per_round < 3:
        raise ValueError(f"probes_per_round must be >= 3, "
                         f"got {probes_per_round!r}")
    baseline_total = baseline.cost.mean + onprem.cost_usd(baseline)
    rounds = 0

    def batch(prices: List[float]) -> List[Tuple[float, DecisionPoint]]:
        """Total cost per probe price — one evaluate call for the ladder."""
        nonlocal rounds
        specs = [with_axis(candidate, axis, p) for p in prices]
        res = evaluate(with_seeds(specs, n_seeds, first_seed))
        points = summarize(res.results, z)
        rounds += 1
        return [(p.cost.mean + onprem.cost_usd(p), p) for p in points]

    (t_lo, p_lo), (t_hi, p_hi) = batch([lo, hi])
    if t_lo > baseline_total:
        return BreakEven(axis=axis, price=None, lo=lo, hi=hi,
                         baseline_total_usd=baseline_total, candidate=p_lo,
                         rounds=rounds,
                         note=f"cloud never breaks even in range: even at "
                              f"{axis}={lo:g} the total "
                              f"${t_lo:,.2f} > baseline "
                              f"${baseline_total:,.2f}")
    if t_hi <= baseline_total:
        return BreakEven(axis=axis, price=hi, lo=lo, hi=hi,
                         baseline_total_usd=baseline_total, candidate=p_hi,
                         rounds=rounds, converged=True,
                         note=f"cloud breaks even across the whole range "
                              f"(at {axis}={hi:g} total ${t_hi:,.2f} <= "
                              f"baseline ${baseline_total:,.2f})")
    width0 = hi - lo
    best = p_lo
    while hi - lo > rel_tol * width0 and rounds < max_rounds:
        step = (hi - lo) / (probes_per_round - 1)
        ladder = [lo + step * k for k in range(1, probes_per_round - 1)]
        results = batch(ladder)
        # monotone totals: the crossing sits between the last <=-baseline
        # probe (new lo) and its successor (new hi)
        below = [k for k, (t, _) in enumerate(results)
                 if t <= baseline_total]
        if below:
            k = below[-1]
            best = results[k][1]
            lo = ladder[k]
            hi = ladder[k + 1] if k + 1 < len(ladder) else hi
        else:
            hi = ladder[0]
    converged = hi - lo <= rel_tol * width0  # max_rounds may exhaust first
    return BreakEven(axis=axis, price=lo, lo=lo, hi=hi,
                     baseline_total_usd=baseline_total, candidate=best,
                     rounds=rounds, converged=converged,
                     note=f"bisected to {axis} in [{lo:.6g}, {hi:.6g}]")


# --------------------------------------------------------------------------
# The orchestrated decision workflow
# --------------------------------------------------------------------------

@dataclass
class DecisionReport:
    baseline: DecisionPoint
    refine: RefineResult
    frontier: List[DecisionPoint]  # final, incl. solver-discovered points
    chosen: Optional[DecisionPoint]
    displaced: DisplacedDisk
    breakeven: Optional[BreakEven]
    onprem: OnPremDisk
    z: float
    stats: Dict[str, Any] = field(default_factory=dict)
    #: True when the evaluator lost sweep work to exhausted retries
    #: (``SweepDriver.failures`` non-empty): the candidate grid was not
    #: fully explored, so the report refuses to assert the paper's
    #: claim — ``claim_holds()`` downgrades to ``False`` and the
    #: markdown/JSON exports flag the verdict as undetermined.
    degraded: bool = False

    def claim_holds(self) -> bool:
        """The paper's qualitative claim: some frontier configuration
        provisions less on-prem disk than the disk-only baseline while its
        jobs-done matches the baseline's within CI bounds.

        A degraded report never asserts the claim: with frontier-relevant
        lanes missing, a "holds" verdict could rest on the surviving
        subset of the grid."""
        if self.degraded:
            return False
        base_tb = self.onprem.provisioned_tb(self.baseline)
        for p in self.frontier:
            if (self.onprem.provisioned_tb(p) < base_tb
                    and p.jobs.hi >= self.baseline.jobs.lo):
                return True
        return False

    # -- export ------------------------------------------------------------
    def _point_row(self, p: DecisionPoint) -> Dict[str, Any]:
        return {
            "label": p.label,
            "n_seeds": p.n_seeds,
            "jobs_mean": p.jobs.mean, "jobs_lo": p.jobs.lo,
            "jobs_hi": p.jobs.hi,
            "cost_usd_mean": p.cost.mean, "cost_usd_lo": p.cost.lo,
            "cost_usd_hi": p.cost.hi,
            "onprem_tb": self.onprem.provisioned_tb(p),
            "total_usd": self.onprem.total_usd(p),
        }

    def to_json_dict(self) -> Dict[str, Any]:
        d = self.displaced
        return {
            "z": self.z,
            "claim_holds": self.claim_holds(),
            "degraded": self.degraded,
            "baseline": self._point_row(self.baseline),
            "chosen": self._point_row(self.chosen) if self.chosen else None,
            "frontier": [self._point_row(p) for p in self.frontier],
            "refine": {
                "rounds": [vars(r) for r in self.refine.rounds],
                "axis_levels": self.refine.axis_levels,
                "lanes_used": self.refine.lanes_used,
                "dense_lanes": self.refine.dense_lanes,
                "lane_fraction": self.refine.lane_fraction,
                "budget_hit": self.refine.budget_hit,
            },
            "displaced_disk": {
                "min_cache_tb": d.min_cache_tb,
                "baseline_provisioned_tb": d.baseline_provisioned_tb,
                # strict-JSON safety: the no-candidate path carries NaN
                "candidate_provisioned_tb": (
                    None if math.isnan(d.candidate_provisioned_tb)
                    else d.candidate_provisioned_tb),
                "displaced_tb": (None if math.isnan(d.displaced_tb)
                                 else d.displaced_tb),
                "cloud_budget_usd": d.cloud_budget_usd,
                "rounds": d.rounds,
                "converged": d.converged,
                "note": d.note,
            },
            "break_even": None if self.breakeven is None else {
                "axis": self.breakeven.axis,
                "price": self.breakeven.price,
                "bracket": [self.breakeven.lo, self.breakeven.hi],
                "baseline_total_usd": self.breakeven.baseline_total_usd,
                "rounds": self.breakeven.rounds,
                "converged": self.breakeven.converged,
                "note": self.breakeven.note,
            },
            "onprem_usd_per_tb_month": self.onprem.usd_per_tb_month,
            "stats": self.stats,
        }

    def to_markdown(self) -> str:
        base_tb = self.onprem.provisioned_tb(self.baseline)
        lines = [
            "# Cloud-cache decision report",
            "",
            f"Baseline (disk-only) `{self.baseline.label}`: "
            f"jobs {self.baseline.jobs:.0f}, provisions "
            f"{base_tb:,.1f} TB on-prem "
            f"(${self.onprem.total_usd(self.baseline):,.2f} for the "
            f"window at ${self.onprem.usd_per_tb_month:g}/TB-month).",
            "",
            "## Cost/throughput frontier (interval-overlap membership, "
            f"z={self.z:g})",
            "",
            "| config | jobs done | cloud $ | on-prem TB | total $ |",
            "|---|---|---|---|---|",
        ]
        for p in self.frontier:
            lines.append(
                f"| `{p.label}` | {p.jobs:.0f} | {p.cost:,.2f} | "
                f"{self.onprem.provisioned_tb(p):,.1f} | "
                f"{self.onprem.total_usd(p):,.2f} |")
        r = self.refine
        lines += [
            "",
            "## Adaptive refinement",
            "",
            f"{len(r.rounds)} round(s), {r.lanes_used} dynamics lanes "
            f"simulated vs {r.dense_lanes} for an equivalent-resolution "
            f"dense grid ({100 * r.lane_fraction:.0f}% of dense"
            + (", lane budget hit" if r.budget_hit else "") + ").",
            "",
        ]
        for a, levels in r.axis_levels.items():
            lines.append(f"- `{a}` resolved levels: "
                         + ", ".join(f"{v:g}" for v in levels))
        d = self.displaced
        lines += ["", "## Headline: displaced on-prem disk", ""]
        if d.min_cache_tb is not None:
            lines += [
                f"A `{d.candidate.label}` cloud cache "
                f"(${d.cloud_budget_usd:,.2f} cloud spend for the window) "
                f"matches the baseline's jobs-done within CI while "
                f"provisioning {d.candidate_provisioned_tb:,.1f} TB — "
                f"**displacing {d.displaced_tb:,.1f} TB of on-prem disk** "
                f"({d.rounds} bisection probes; {d.note}).",
            ]
        else:
            lines += [f"No displacement found: {d.note}"]
        if self.breakeven is not None:
            b = self.breakeven
            lines += ["", "## Break-even cloud price", ""]
            if b.price is not None:
                lines += [
                    f"On the `{b.axis}` axis the candidate's total cost "
                    f"meets the baseline's ${b.baseline_total_usd:,.2f} at "
                    f"**{b.price:.6g}** (bracket [{b.lo:.6g}, {b.hi:.6g}], "
                    f"{b.rounds} probes). Below that price the cloud cache "
                    "is the cheaper way to this throughput.",
                ]
            else:
                lines += [f"{b.note}."]
        if self.degraded:
            n_failed = len(self.stats.get("failures", []))
            lines += [
                "",
                "## ⚠ Degraded run",
                "",
                f"{n_failed} sweep job(s) exhausted their retry budget "
                "(see `stats.failures`): the candidate grid was not fully "
                "explored, so this report refuses to assert the paper's "
                "claim. Re-run with `--resume` against the same result "
                "cache to recompute only the missing work "
                "(docs/resilience.md).",
            ]
        verdict = ("is UNDETERMINED (degraded run)" if self.degraded
                   else "HOLDS" if self.claim_holds() else "does NOT hold")
        lines += [
            "",
            f"**Paper's claim {verdict}** "
            "at this scale: a frontier cloud-cache configuration "
            "provisions less on-prem disk than the disk-only baseline at "
            "matching jobs-done (within CI bounds).",
        ]
        if self.stats:
            lines += ["", "## Run stats", ""]
            lines += [f"- {k}: {v}" for k, v in self.stats.items()]
        return "\n".join(lines) + "\n"


def decide(axes: Mapping[str, Any], evaluate: Evaluate, *,
           baseline: Optional[ScenarioSpec] = None,
           refine: Sequence[str] = ("cache_tb",),
           n_seeds: int = 2, first_seed: int = 0,
           rel_tol: float = 0.05, max_rounds: int = 3,
           lane_budget: Optional[int] = None,
           onprem: OnPremDisk = OnPremDisk(),
           breakeven_axis: Optional[str] = "egress_price",
           breakeven_range: Tuple[float, float] = (0.0, 0.12),
           cache_floor: Optional[float] = None,
           z: float = Z_95) -> DecisionReport:
    """The full §5.3 decision workflow against a candidate grid.

    1. Evaluate the disk-only ``baseline`` (default: configuration I —
       unlimited on-prem disk, no cloud — at the grid's days/files).
    2. ``refine_frontier`` the candidate ``axes`` adaptively.
    3. Choose the frontier point matching the baseline's jobs-done within
       CI at the lowest total (cloud + on-prem) cost.
    4. ``solve_displaced_disk``: trim its cache to the smallest size still
       matching — the displaced on-prem capacity is the headline.
    5. ``solve_break_even_price`` on ``breakeven_axis`` (skipped when
       ``None``).

    The final frontier folds in the displacement solver's probe points
    (they are real configurations at real prices); break-even probes are
    excluded — their pricing is hypothetical.
    """
    if baseline is None:
        days = axes.get("days", 2.0)
        n_files = axes.get("n_files", 20_000)
        if isinstance(days, (list, tuple)) or isinstance(n_files,
                                                         (list, tuple)):
            raise ValueError("days/n_files must be scalars to derive the "
                             "default baseline; pass baseline= explicitly")
        baseline = ScenarioSpec(base="I", days=days, n_files=n_files,
                                gcs_limit_tb=0.0)
        # a scalar workload / arrival-rate axis applies to the whole grid;
        # the baseline must see the same access stream to be comparable
        for f in ("workload", "job_rate_scale"):
            v = axes.get(f)
            if v is not None and not isinstance(v, (list, tuple)):
                baseline = replace(baseline, **{f: v})
    base_res = evaluate(with_seeds([baseline], n_seeds, first_seed))
    if not base_res.results:
        lost = getattr(base_res, "failures", [])
        raise RuntimeError(
            "decide(): the baseline evaluation returned no results"
            + (f" ({len(lost)} job(s) abandoned after retries; "
               "see docs/resilience.md)" if lost else "")
            + " — without a baseline no claim can be made")
    base_point = summarize(base_res.results, z)[0]

    # Frontier dominance on *total* cost: pricing-deduped lanes tie on the
    # cloud bill, but bigger caches still buy more on-prem disk — total
    # cost separates them and points the refinement at the knee.
    cost_of = onprem.total_interval
    with get_tracer().span("decide.refine_frontier"):
        ref = refine_frontier(axes, evaluate, refine, n_seeds=n_seeds,
                              first_seed=first_seed, rel_tol=rel_tol,
                              max_rounds=max_rounds, lane_budget=lane_budget,
                              cost_of=cost_of, z=z)

    matching = [p for p in ref.frontier if p.jobs.hi >= base_point.jobs.lo]
    pool = matching or ref.frontier
    chosen = min(pool, key=onprem.total_usd) if pool else None

    if chosen is not None:
        with get_tracer().span("decide.displaced_disk"):
            disp = solve_displaced_disk(
                chosen.spec, base_point, evaluate, onprem, lo=cache_floor,
                n_seeds=n_seeds, first_seed=first_seed, z=z)
    else:
        disp = DisplacedDisk(min_cache_tb=None, candidate=None,
                             baseline_provisioned_tb=onprem.provisioned_tb(
                                 base_point),
                             candidate_provisioned_tb=float("nan"),
                             cloud_budget_usd=0.0,
                             note="no frontier candidate")

    breakeven = None
    # gate on a *successful* displacement solve: the failed path also
    # carries a candidate (the failing probe), and pricing a config that
    # under-delivers the baseline's throughput is not a break-even
    if breakeven_axis is not None and disp.min_cache_tb is not None:
        lo, hi = breakeven_range
        with get_tracer().span("decide.break_even",
                               axis=str(breakeven_axis)):
            breakeven = solve_break_even_price(
                disp.candidate.spec, base_point, evaluate, onprem,
                axis=breakeven_axis, lo=lo, hi=hi, n_seeds=n_seeds,
                first_seed=first_seed, z=z)

    pool = {p.spec: p for p in ref.points + disp.probes}  # dedupe re-probes
    frontier = ci_frontier(list(pool.values()), cost_of)
    report = DecisionReport(baseline=base_point, refine=ref,
                            frontier=frontier, chosen=chosen, displaced=disp,
                            breakeven=breakeven, onprem=onprem, z=z)
    # Driver-like evaluators (``SweepDriver``) carry run accounting — fold
    # it into the report so every caller (CLI, benches, tests) sees the
    # same sweep_calls/configs_run/lanes_simulated/cache_hits books
    # without re-plumbing them.
    for attr in ("backend", "sweep_calls", "configs_run", "lanes_simulated",
                 "cache_hits"):
        value = getattr(evaluate, attr, None)
        if isinstance(value, (int, str)) and not isinstance(value, bool):
            report.stats[attr] = value
    wall = getattr(evaluate, "wall_s", None)
    if isinstance(wall, (int, float)):
        report.stats["sweep_wall_s"] = round(float(wall), 2)
    cache = getattr(evaluate, "cache", None)
    cache_stats = getattr(cache, "stats", None)
    if cache_stats is not None and hasattr(cache_stats, "as_dict"):
        report.stats["cache"] = cache_stats.as_dict()
    # Resilient evaluators (``SweepDriver(retry=...)``) accumulate the
    # jobs that exhausted their retry budget; any loss degrades the
    # report — the grid the claim would rest on was not fully explored.
    lost = getattr(evaluate, "failures", None)
    if lost:
        report.degraded = True
        report.stats["failures"] = [
            f.as_dict() if hasattr(f, "as_dict") else f for f in lost]
    # Embed the process-global metrics snapshot: the report is the
    # decision workflow's one artifact, so its operational story (cache
    # warmth, lanes simulated, kernel resolution) travels with it.
    report.stats["metrics"] = get_registry().snapshot()
    return report
