"""Bounded random samplers used by the paper's fitted parameters.

The paper fits (by max-log-likelihood) exponential, geometric, and normal
distributions to ATLAS monitoring data (Tables 1 and 3). Three details are
reverse-engineered from the paper's own outputs and documented here:

- **File sizes are exponential in GiB.** Validation Table 2 reports a
  simulated mean file size of 1.73 GB with lambda = 0.61972; 1/0.61972 =
  1.6136 GiB = 1.7326 GB. The GB interpretation (1.61 GB) would not match.
- **Fractional count sampling.** Per-tick counts (transfers to generate,
  jobs to submit) are real-valued samples; the integer count emitted carries
  the fractional remainder to the next tick, so the long-run rate equals the
  distribution mean exactly. This reproduces Table 2's 1.80 transfers/10 s
  (= 6 links x 0.29995) and Table 6's 996k submitted jobs
  (= 2 sites x 777.6k ticks x 0.6407 truncated-normal mean).
- **Bounds are clamps** on the sampled value (Table 1/3 list explicit
  ranges). For the exponential this barely moves the mean in the validation
  scenario and shaves ~5 GiB off the HCDC input-size mean.
"""

from __future__ import annotations

import numpy as np

GiB = 1024.0**3


class BoundedExponential:
    """Exponential with rate ``lam`` (mean 1/lam), clamped to [lo, hi]."""

    def __init__(self, lam: float, lo: float = 0.0, hi: float = np.inf,
                 unit: float = 1.0):
        self.lam = lam
        self.lo = lo
        self.hi = hi
        self.unit = unit  # multiply samples by this (e.g. GiB)

    def sample(self, rng: np.random.Generator, n: int | None = None):
        x = rng.exponential(1.0 / self.lam, size=n)
        return np.clip(x, self.lo, self.hi) * self.unit

    @property
    def mean(self) -> float:
        """Mean of the clamped distribution (for napkin math/tests)."""
        lam, lo, hi = self.lam, self.lo, self.hi
        if not np.isfinite(hi):
            return (lo + 1.0 / lam) * self.unit
        # E[min(max(X, lo), hi)] for X ~ Exp(lam), lo ~ 0 assumed small.
        return (1.0 / lam - (hi - lo) / np.expm1(lam * (hi - lo)) + lo) * self.unit


class BoundedGeometric:
    """Geometric (support {1, 2, ...}), clamped to [lo, hi).

    HCDC popularity: p = 0.1, 1 <= x < 50 (paper Table 3).
    """

    def __init__(self, p: float, lo: int = 1, hi: int = 50):
        self.p = p
        self.lo = lo
        self.hi = hi

    def sample(self, rng: np.random.Generator, n: int | None = None):
        x = rng.geometric(self.p, size=n)
        return np.clip(x, self.lo, self.hi - 1)


class TruncatedNormalCount:
    """Normal(mu, sigma) truncated below at 0 — per-tick count rates."""

    def __init__(self, mu: float, sigma: float):
        self.mu = mu
        self.sigma = sigma

    def sample(self, rng: np.random.Generator, n: int | None = None):
        x = rng.normal(self.mu, self.sigma, size=n)
        return np.maximum(x, 0.0)

    @property
    def mean(self) -> float:
        from math import erf, exp, pi, sqrt

        a = self.mu / self.sigma
        phi = exp(-0.5 * a * a) / sqrt(2 * pi)
        Phi = 0.5 * (1 + erf(a / sqrt(2)))
        return self.mu * Phi + self.sigma * phi


class FractionalCounter:
    """Emit integer counts whose long-run rate equals the sampled mean.

    ``emit(x)`` adds the real sample to an accumulator and returns the integer
    part, carrying the remainder — the paper's generators create "a number of
    transfers/jobs" per tick from continuous fits; this is the only carry rule
    that reproduces the reported long-run rates exactly.
    """

    def __init__(self) -> None:
        self.acc = 0.0

    def emit(self, x: float) -> int:
        self.acc += float(x)
        n = int(self.acc)
        self.acc -= n
        return n
