"""Worker-fleet execution tests (``repro.sim.runners``): the frame
protocol, transport resolution, fleet dispatch through local and
subprocess transports, crash/hang/transient injection, worker
metrics-snapshot merging, and bitwise parity with the serial executors
on both backends.

The determinism assertions mirror ``tests/test_jobs.py``: every
fault-injected fleet run must converge to the byte-identical result of
its fault-free serial counterpart, because retries re-execute the same
pure function. The subprocess tests spawn real worker processes at a
tiny scenario scale; the jax-grid-over-subprocess parity test pays a
worker-side jax import + compile and is marked ``slow`` (nightly).
"""

import io
import pickle

import numpy as np
import pytest

from repro.core.scenarios import expand_grid
from repro.obs.metrics import get_registry
from repro.sim.jobs import Job, RetryPolicy
from repro.sim.runners import (
    LocalTransport,
    SubprocessTransport,
    TransportError,
    resolve_transport,
    run_fleet_jobs,
)
from repro.sim.runners.transport import recv_frame, send_frame
from repro.sim.sweep import run_sweep


def _grid(n=3, days=0.02, n_files=50):
    return expand_grid({"base": "III", "days": days, "n_files": n_files,
                        "cache_tb": [float(5 * (i + 1)) for i in range(n)]})


def _key(res):
    return [(r.spec, r.metrics, r.storage_usd, r.network_usd, r.ops_usd)
            for r in res.results]


# -- frame protocol -----------------------------------------------------------

def test_frame_round_trip():
    buf = io.BytesIO()
    msgs = [{"op": "init", "ctx": {"kind": "scenario"}},
            {"op": "job", "payload": np.arange(7.0), "directive": None},
            {"op": "stop"}]
    for m in msgs:
        send_frame(buf, m)
    buf.seek(0)
    got = [recv_frame(buf) for _ in msgs]
    assert got[0] == msgs[0]
    np.testing.assert_array_equal(got[1]["payload"], msgs[1]["payload"])
    assert got[2] == msgs[2]
    with pytest.raises(EOFError):
        recv_frame(buf)


def test_frame_eof_mid_frame():
    buf = io.BytesIO()
    send_frame(buf, {"op": "job", "payload": list(range(100))})
    truncated = io.BytesIO(buf.getvalue()[:-5])
    with pytest.raises(EOFError):
        recv_frame(truncated)


def test_resolve_transport():
    assert resolve_transport(None) is SubprocessTransport
    assert resolve_transport("subprocess") is SubprocessTransport
    assert resolve_transport("local") is LocalTransport
    factory = lambda: LocalTransport()  # noqa: E731
    assert resolve_transport(factory) is factory
    with pytest.raises(ValueError, match="unknown transport"):
        resolve_transport("carrier-pigeon")


# -- fleet dispatch, local transport ------------------------------------------

def test_fleet_local_matches_serial():
    specs = _grid(3)
    serial = run_sweep(specs, workers=1)
    fleet = run_sweep(specs, workers=2, transport="local")
    assert fleet.ok
    assert _key(fleet) == _key(serial)


def test_fleet_local_custom_factory_seam():
    built = []

    def factory():
        t = LocalTransport()
        built.append(t)
        return t

    specs = _grid(2)
    serial = run_sweep(specs, workers=1)
    fleet = run_sweep(specs, workers=2, transport=factory)
    assert _key(fleet) == _key(serial)
    assert built  # the custom transport actually carried the jobs


def test_fleet_crash_converges_bitwise():
    specs = _grid(3)
    baseline = run_sweep(specs, workers=1)
    res = run_sweep(specs, workers=2, transport="local",
                    faults="seed=7,crash=0.6")
    assert res.ok
    assert _key(res) == _key(baseline)


def test_fleet_transient_converges_bitwise():
    specs = _grid(3)
    baseline = run_sweep(specs, workers=1)
    res = run_sweep(specs, workers=2, transport="local",
                    faults="seed=3,transient=0.6")
    assert res.ok
    assert _key(res) == _key(baseline)


def test_fleet_hang_times_out_and_converges():
    specs = _grid(2)
    baseline = run_sweep(specs, workers=1)
    get_registry().reset()
    res = run_sweep(specs, workers=2, transport="local",
                    faults="seed=5,hang=0.9,hang_s=0.5", job_timeout=0.1)
    assert res.ok
    assert _key(res) == _key(baseline)
    assert get_registry().value("jobs.timeouts") >= 1


def test_fleet_exhausted_retries_partial_not_fatal():
    specs = _grid(2)
    res = run_sweep(specs, workers=2, transport="local",
                    faults="seed=11,crash=1.0,attempts=99",
                    retry=RetryPolicy(max_attempts=2, base_delay_s=0.01))
    assert not res.ok
    assert len(res.results) == 0
    assert all(f.kind == "crash" and f.attempts == 2 for f in res.failures)


def test_fleet_spawn_failure_abandons_instead_of_spinning():
    def broken_factory():
        raise OSError("no more processes")

    jobs = [Job(job_id=f"j{i}", payload=i) for i in range(3)]
    get_registry().reset()
    results, reg = run_fleet_jobs(jobs, workers=2, transport=broken_factory)
    assert results == {}
    failures = reg.failures()
    assert len(failures) == 3
    assert all("no fleet worker" in f.errors[-1] for f in failures)
    assert get_registry().value("workers.spawn_failures") >= 1


def test_fleet_send_failure_requeues_blamelessly():
    class FlakyPipe(LocalTransport):
        sends = 0

        def send(self, msg):
            if msg.get("op") == "job":
                FlakyPipe.sends += 1
                if FlakyPipe.sends == 1:  # first dispatch: dead channel
                    self._alive = False
                    raise TransportError("pipe burst")
            super().send(msg)

    jobs = [Job(job_id=f"j{i}", payload=i, labels=(f"j{i}",))
            for i in range(2)]
    ctx = {"kind": "scenario"}  # runner unused: payloads are ints
    results, reg = run_fleet_jobs(
        jobs, workers=1, transport=FlakyPipe, ctx=ctx,
        prepare=lambda job: _grid(1)[0])
    assert len(results) == 2
    # The lost send was requeued without charging an attempt.
    assert all(j.attempts == 1 for j in reg.jobs.values())


def test_fleet_workers_validation():
    with pytest.raises(ValueError, match="workers must be >= 1"):
        run_fleet_jobs([], workers=0, transport="local")


def test_run_sweep_shard_requires_jax_backend():
    with pytest.raises(ValueError, match="backend='jax' only"):
        run_sweep(_grid(1), backend="process", shard=True)


# -- fleet dispatch, subprocess transport -------------------------------------

def test_fleet_subprocess_matches_serial_and_merges_metrics():
    specs = _grid(3)
    serial = run_sweep(specs, workers=1)
    get_registry().reset()
    fleet = run_sweep(specs, workers=2, transport="subprocess")
    assert fleet.ok
    assert _key(fleet) == _key(serial)
    reg = get_registry()
    # Worker-side counters arrived via result-frame snapshot merge.
    assert reg.value("scenario.runs") == len(specs)
    assert reg.value("dispatch.results") == len(specs)
    assert reg.value("workers.spawned") >= 1


def test_fleet_subprocess_crash_mid_job_merges_survivor_metrics():
    specs = _grid(3)
    serial = run_sweep(specs, workers=1)
    get_registry().reset()
    res = run_sweep(specs, workers=2, transport="subprocess",
                    faults="seed=7,crash=0.5")
    assert res.ok
    assert _key(res) == _key(serial)
    reg = get_registry()
    assert reg.value("jobs.crashes") >= 1
    assert reg.value("workers.lost") >= 1
    # The crashed attempt died before reporting; every *successful*
    # attempt's snapshot still merged, so the fleet total matches a
    # serial run despite the mid-job worker loss.
    assert reg.value("scenario.runs") == len(specs)


# -- jax lane-chunk jobs over the fleet ---------------------------------------

def _jax_specs(n_seeds=4):
    return expand_grid({"base": "III", "days": 0.02, "n_files": 50,
                        "seed": list(range(n_seeds))})


def test_fleet_jax_local_bitwise_parity():
    jax = pytest.importorskip("jax")  # noqa: F841
    specs = _jax_specs()
    plain = run_sweep(specs, backend="jax", tick=60.0)
    fleet = run_sweep(specs, backend="jax", tick=60.0, transport="local",
                      workers=1, lane_chunk=2)
    assert fleet.ok
    assert _key(fleet) == _key(plain)


def test_simulate_shard_map_bitwise_parity():
    jax = pytest.importorskip("jax")  # noqa: F841
    specs = _jax_specs()
    plain = run_sweep(specs, backend="jax", tick=60.0)
    shard = run_sweep(specs, backend="jax", tick=60.0, shard=True)
    assert _key(shard) == _key(plain)
    # lane count not divisible by the mesh still pads + truncates right
    shard_chunk = run_sweep(specs, backend="jax", tick=60.0, shard=True,
                            lane_chunk=3)
    assert _key(shard_chunk) == _key(plain)


@pytest.mark.slow
def test_fleet_jax_subprocess_bitwise_parity():
    jax = pytest.importorskip("jax")  # noqa: F841
    specs = _jax_specs(6)
    plain = run_sweep(specs, backend="jax", tick=60.0)
    fleet = run_sweep(specs, backend="jax", tick=60.0,
                      transport="subprocess", workers=2, lane_chunk=2)
    assert fleet.ok
    assert _key(fleet) == _key(plain)
