"""The paper's contribution: the Hot/Cold Data Carousel (HCDC) model.

- ``carousel``: the data-carousel sliding window (allocate/stage/evict).
- ``hotcold``: hot/cold storage policies (popularity migration thresholds,
  cold-tier deletion strategies — the latter beyond-paper, paper §6).
- ``hcdc``: the full HCDC scenario (Fig. 4 sites, Fig. 5 job state machine,
  configurations I/II/III of Table 5).
- ``validation``: the §4.2 simulation-correctness scenario (Table 2).
- ``planner``: the §6 decision tool (sweep limits -> cost/throughput frontier).
- ``scenarios``: flat scenario-spec parameterization + grid expansion for
  the batched sweep engine (``repro.sim.sweep``).
"""

from repro.core.carousel import SlidingWindow
from repro.core.hcdc import HCDCConfig, HCDCScenario, CONFIG_I, CONFIG_II, CONFIG_III
from repro.core.scenarios import (
    ScenarioSpec,
    build_config,
    expand_grid,
    specs_from_mapping,
    with_seeds,
)
from repro.core.validation import ValidationConfig, ValidationScenario

__all__ = [
    "SlidingWindow",
    "HCDCConfig",
    "HCDCScenario",
    "CONFIG_I",
    "CONFIG_II",
    "CONFIG_III",
    "ScenarioSpec",
    "build_config",
    "expand_grid",
    "specs_from_mapping",
    "with_seeds",
    "ValidationConfig",
    "ValidationScenario",
]
