"""Cloud module: commercial cloud storage elements + cost model (paper §4.1/§5.3).

``GCSBucket`` extends ``StorageElement`` with the functionality the paper
lists: storage increase/decrease tracking, ingress/egress tracking, and cost
calculation implementing the provider's pricing policy.

Pricing (paper: "public pricing data from the GCP documentation on
2020/09/10", standard storage class, regional bucket, Europe):

- storage: USD per GB-month, integrated over time (byte-seconds). The
  default 0.026 USD/GB-month is back-derived from Table 8 (monthly storage
  cost / mean stored volume); 2020 regional standard prices ranged
  0.020-0.026 USD/GB-month depending on region.
- network egress to the grid: tiered internet egress (0-1 TiB: 0.12, 1-10
  TiB: 0.11, >10 TiB: 0.08 USD/GiB/month). The paper's Table 8 network cost
  divided by the Table 7 GCS->disk volume gives 0.080 USD/GiB — i.e.
  PB-scale traffic lands in the top tier. Peering alternatives (§5.3):
  direct 0.05, interconnect 0.02 USD/GiB.
- operations: class A (writes) 0.05 USD / 10k ops, class B (reads)
  0.004 USD / 10k ops.
- ingress: free.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional, Sequence, Tuple

from repro.sim.infrastructure import GiB, Site, StorageElement

MONTH_SECONDS = 30 * 24 * 3600

#: Flat egress prices (USD/GiB) for the paper's §5.3 peering alternatives
#: to tiered internet egress.
PEERING_PRICES = {"direct": 0.05, "interconnect": 0.02}


@dataclass
class GCSCostModel:
    """GCP price table (USD), 2020-09-10 snapshot."""

    storage_per_gb_month: float = 0.026
    # (tier upper bound in bytes/month, USD per GiB) — internet egress.
    egress_tiers: Tuple[Tuple[float, float], ...] = (
        (1 * 1024.0**4, 0.12),
        (10 * 1024.0**4, 0.11),
        (float("inf"), 0.08),
    )
    class_a_per_10k: float = 0.05
    class_b_per_10k: float = 0.004
    peering: Optional[str] = None  # None | "direct" | "interconnect"
    #: Flat egress price override (USD/GiB). Takes precedence over both the
    #: peering table and the internet tiers — the §5.3 break-even solvers
    #: sweep this axis continuously to find the price at which cloud
    #: caching matches an on-prem-disk baseline.
    flat_egress_per_gib: Optional[float] = None

    def egress_cost(self, monthly_bytes: float) -> float:
        if self.flat_egress_per_gib is not None:
            return self.flat_egress_per_gib * monthly_bytes / GiB
        if self.peering is not None:
            return PEERING_PRICES[self.peering] * monthly_bytes / GiB
        cost, prev, left = 0.0, 0.0, monthly_bytes
        for bound, price in self.egress_tiers:
            span = min(left, bound - prev)
            if span <= 0:
                break
            cost += price * span / GiB
            left -= span
            prev = bound
        return cost

    def storage_cost(self, gb_seconds: float) -> float:
        return self.storage_per_gb_month * gb_seconds / MONTH_SECONDS

    def ops_cost(self, class_a: int, class_b: int) -> float:
        return class_a / 1e4 * self.class_a_per_10k + class_b / 1e4 * self.class_b_per_10k


@dataclass
class MonthlyBill:
    storage_usd: float = 0.0
    network_usd: float = 0.0
    ops_usd: float = 0.0

    @property
    def total(self) -> float:
        return self.storage_usd + self.network_usd + self.ops_usd


def sum_bills(bills: List[MonthlyBill]) -> MonthlyBill:
    """Aggregate monthly bills into one run-total bill (sweep reporting)."""
    return MonthlyBill(
        storage_usd=sum(b.storage_usd for b in bills),
        network_usd=sum(b.network_usd for b in bills),
        ops_usd=sum(b.ops_usd for b in bills),
    )


def bills_from_monthly_totals(cost_model: GCSCostModel,
                              gb_seconds: Sequence[float],
                              egress_bytes: Sequence[float],
                              class_a: Sequence[float],
                              class_b: Sequence[float],
                              full_months: int) -> List[MonthlyBill]:
    """Tick adapter: fold per-month aggregate arrays into ``MonthlyBill``s.

    Fixed-tick engines (``repro.sim.batched``) accumulate the raw billing
    quantities per 30-day month bucket on device instead of through
    ``GCSBucket``'s lazy event-time integration. This applies the same price
    model with the bucket's emission rule: every *complete* month produces a
    bill (even an all-zero one — ``GCSBucket._sync`` closes each crossed
    boundary), while a trailing partial month is billed only if it saw any
    stored volume or egress (``GCSBucket.finalize``).
    """
    bills: List[MonthlyBill] = []
    for i in range(len(gb_seconds)):
        if i >= full_months and gb_seconds[i] <= 0 and egress_bytes[i] <= 0:
            continue
        bills.append(MonthlyBill(
            storage_usd=cost_model.storage_cost(float(gb_seconds[i])),
            network_usd=cost_model.egress_cost(float(egress_bytes[i])),
            ops_usd=cost_model.ops_cost(int(round(float(class_a[i]))),
                                        int(round(float(class_b[i])))),
        ))
    return bills


class GCSBucket(StorageElement):
    """A cloud bucket storage element with cost tracking.

    Integrates stored volume over time (GB-seconds) lazily: `_sync(now)` must
    be called before any volume change. Egress/ingress and operation counts
    accumulate per calendar month (30-day months from t=0, matching the
    paper's per-month Table 8).
    """

    def __init__(self, name: str, site: Site, limit: Optional[float] = None,
                 cost_model: Optional[GCSCostModel] = None):
        super().__init__(name, site, limit=limit, access_latency=0.0)
        self.cost_model = cost_model or GCSCostModel()
        self._last_sync: int = 0
        self._gb_seconds_month: float = 0.0
        self.egress_month: float = 0.0
        self.class_a_month: int = 0
        self.class_b_month: int = 0
        self._month_start: int = 0
        self.bills: List[MonthlyBill] = []
        #: Raw per-month billing inputs, one tuple (gb_seconds,
        #: egress_bytes, class_a, class_b) per closed month — the
        #: pricing-independent quantities ``bills_from_monthly_totals``
        #: turns back into ``self.bills`` under any cost model. The result
        #: cache (``repro.sim.cache``) persists these so a cached dynamics
        #: run can be re-billed for pricing variants bit-exactly.
        self.monthly_raw: List[Tuple[float, float, int, int]] = []
        #: Complete 30-day months closed by ``_sync`` (always billed);
        #: a trailing ``monthly_raw`` entry beyond this count is the
        #: partial month ``finalize`` closed because it saw activity.
        self.full_months_closed: int = 0
        # increase/decrease tracking (paper: "storage increase/decrease
        # tracking") — (time, +/- bytes) deltas for Fig-8 style curves.
        self.volume_deltas: List[Tuple[int, float]] = []

    # -- time integration ----------------------------------------------------
    def _sync(self, now: int) -> None:
        while now - self._month_start >= MONTH_SECONDS:
            boundary = self._month_start + MONTH_SECONDS
            self._gb_seconds_month += self.used / 1e9 * (boundary - self._last_sync)
            self._close_month()
            self.full_months_closed += 1
            self._last_sync = boundary
            self._month_start = boundary
        self._gb_seconds_month += self.used / 1e9 * (now - self._last_sync)
        self._last_sync = now

    def _close_month(self) -> None:
        self.monthly_raw.append((self._gb_seconds_month, self.egress_month,
                                 self.class_a_month, self.class_b_month))
        cm = self.cost_model
        self.bills.append(
            MonthlyBill(
                storage_usd=cm.storage_cost(self._gb_seconds_month),
                network_usd=cm.egress_cost(self.egress_month),
                ops_usd=cm.ops_cost(self.class_a_month, self.class_b_month),
            )
        )
        self._gb_seconds_month = 0.0
        self.egress_month = 0.0
        self.class_a_month = 0
        self.class_b_month = 0

    def finalize(self, now: int) -> List[MonthlyBill]:
        """Close the current (possibly partial) month and return all bills."""
        self._sync(now)
        if self._gb_seconds_month > 0 or self.egress_month > 0:
            self._close_month()
        return self.bills

    # -- tracked mutations ----------------------------------------------------
    def record_ingress(self, now: int, nbytes: float) -> None:
        self._sync(now)
        self.class_a_month += 1  # write op
        self.volume_deltas.append((now, nbytes))

    def record_egress(self, now: int, nbytes: float) -> None:
        self._sync(now)
        self.egress_month += nbytes
        self.class_b_month += 1  # read op

    def record_delete(self, now: int, nbytes: float) -> None:
        self._sync(now)
        self.class_a_month += 1
        self.volume_deltas.append((now, -nbytes))
