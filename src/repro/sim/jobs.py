"""Fault-tolerant job execution for sweeps (the worker-fleet resilience
layer).

The decision workflow (``repro.sim.decide``) assumes sweeps over large
scenario grids complete reliably; this module makes that hold under
component failure. Sweep work is sharded into ``Job``s — one scenario
per job on the process backend, one ``PackedGrid`` lane chunk per job on
the jax backend — tracked by a ``JobRegistry`` with explicit states::

    pending -> running -> done
                  |-> failed ----> pending   (retry after backoff)
                  |-> abandoned              (retry budget exhausted)

Failed attempts retry under a deterministic exponential backoff
(``RetryPolicy``): delays are bounded by ``max_delay_s``, monotone
non-decreasing in the attempt number, and bitwise-reproducible for a
fixed seed — the jitter term is a pure hash of ``(seed, job_id)``, so it
decorrelates jobs without introducing RNG state. Worker death
(``BrokenProcessPool``) recycles the pool and requeues only the lost
jobs; wall-clock deadlines reap hung workers the same way. A job that
exhausts its budget is *abandoned*, not fatal: executors return whatever
completed plus the registry, and ``run_sweep`` folds abandoned jobs into
``SweepResult.failures`` instead of raising.

Everything is instrumented through ``repro.obs``: ``jobs.retries`` /
``jobs.timeouts`` / ``jobs.crashes`` / ``jobs.requeued`` /
``jobs.abandoned`` counters, per-state ``jobs.state`` gauges, and a
``job.attempt`` span around every in-process attempt. Fault injection
(``repro.sim.faults``) hooks in front of each attempt, keyed by
``(plan.seed, job_id, attempt)``, so resilience behavior is testable
deterministically.

The registry is deliberately executor-agnostic: three executors drain
it today — ``run_local_jobs`` (serial in-process), ``run_process_jobs``
(anonymous spawned pool, recycled wholesale on crash), and
``repro.sim.runners.run_fleet_jobs`` (persistent worker fleet over a
pluggable transport, with per-worker crash attribution) — all observing
the same state machine, retry policy, and fault plan, and all producing
byte-identical results. See ``docs/resilience.md`` for the lifecycle /
retry / resume semantics and ``docs/distributed.md`` for the fleet.
"""

from __future__ import annotations

import multiprocessing
import time
from concurrent.futures import FIRST_COMPLETED, ProcessPoolExecutor, wait
from concurrent.futures.process import BrokenProcessPool
from dataclasses import dataclass, field
from typing import (Any, Callable, Dict, List, Optional, Sequence, Tuple)

from repro.obs.metrics import get_registry, snapshot_and_reset
from repro.obs.trace import get_tracer
from repro.sim.faults import (FaultPlan, JobTimeout, TransientFault,
                              WorkerCrash, perform_in_worker,
                              raise_local_fault, unit_hash)

#: Job lifecycle states.
PENDING = "pending"
RUNNING = "running"
DONE = "done"
FAILED = "failed"      # awaiting its backoff delay, will retry
ABANDONED = "abandoned"  # retry budget exhausted; reported as a failure

STATES = (PENDING, RUNNING, DONE, FAILED, ABANDONED)

#: Failure kinds that retry. Generic exceptions (``"error"``) do not:
#: a deterministic bug fails every attempt identically, so retrying it
#: only multiplies the wasted work — retries are for infrastructure
#: faults (lost workers, deadlines, declared-transient errors).
RETRYABLE_KINDS = ("crash", "timeout", "transient")


@dataclass(frozen=True)
class RetryPolicy:
    """Deterministic bounded exponential backoff.

    The delay after failed attempt ``a`` (1-based) of job ``j`` is::

        min(max_delay_s, base_delay_s * multiplier**(a-1) * (1 + jitter*u))

    with ``u = unit_hash(f"{seed}:{j}") in [0, 1)`` — jitter varies *per
    job*, not per attempt, so each job's delay sequence is monotone
    non-decreasing by construction while different jobs still spread out
    (no thundering herd on pool recycle). Pure function of its inputs:
    bounded, monotone, bitwise-reproducible for a fixed seed.
    """

    max_attempts: int = 3
    base_delay_s: float = 0.05
    multiplier: float = 2.0
    max_delay_s: float = 30.0
    jitter: float = 0.25
    seed: int = 0

    def __post_init__(self) -> None:
        if self.max_attempts < 1:
            raise ValueError(f"max_attempts must be >= 1, "
                             f"got {self.max_attempts!r}")
        if self.base_delay_s < 0 or self.max_delay_s < 0:
            raise ValueError("delays must be >= 0")
        if self.multiplier < 1.0:
            raise ValueError(f"multiplier must be >= 1, "
                             f"got {self.multiplier!r}")
        if not 0.0 <= self.jitter:
            raise ValueError(f"jitter must be >= 0, got {self.jitter!r}")

    def delay_s(self, job_id: str, attempt: int) -> float:
        """Backoff delay after the ``attempt``-th (1-based) failure."""
        if attempt < 1:
            raise ValueError(f"attempt is 1-based, got {attempt!r}")
        raw = self.base_delay_s * self.multiplier ** (attempt - 1)
        u = unit_hash(f"{self.seed}:{job_id}")
        return min(self.max_delay_s, raw * (1.0 + self.jitter * u))


@dataclass
class Job:
    """One retryable unit of sweep work."""

    job_id: str
    #: executor-defined work description (a ``ScenarioSpec`` on the
    #: process backend, a ``(lane_start, lane_stop)`` pair on jax)
    payload: Any = None
    #: human-readable tags (spec labels); fault plans filter on these
    labels: Tuple[str, ...] = ()
    #: wall-clock deadline per attempt; ``None`` = unlimited
    timeout_s: Optional[float] = None
    state: str = PENDING
    attempts: int = 0
    #: earliest monotonic time the next attempt may start (backoff)
    not_before: float = 0.0
    errors: List[str] = field(default_factory=list)
    last_kind: str = ""
    started_at: Optional[float] = None
    result: Any = None
    #: the fault directive injected into the current attempt, if any
    injected: Optional[Dict[str, Any]] = None


@dataclass
class JobFailure:
    """Structured report of one abandoned job (carried on
    ``SweepResult.failures`` instead of raising)."""

    job_id: str
    labels: Tuple[str, ...]
    kind: str
    attempts: int
    errors: List[str]

    def as_dict(self) -> Dict[str, Any]:
        return {"job_id": self.job_id, "labels": list(self.labels),
                "kind": self.kind, "attempts": self.attempts,
                "errors": list(self.errors)}


class JobRegistry:
    """State machine over a batch of jobs; executor-agnostic.

    Executors drive it through ``ready`` / ``mark_running`` /
    ``mark_done`` / ``mark_failed`` / ``requeue_lost`` and it keeps the
    books: attempt counts, backoff deadlines, error trails, and the
    ``jobs.*`` metrics (per-state gauges on every transition, counters
    for retries / timeouts / crashes / requeues / abandonments).
    """

    def __init__(self, policy: Optional[RetryPolicy] = None,
                 clock: Callable[[], float] = time.monotonic):
        self.policy = policy or RetryPolicy()
        self.clock = clock
        self.jobs: Dict[str, Job] = {}

    def add(self, job: Job) -> Job:
        if job.job_id in self.jobs:
            raise ValueError(f"duplicate job id {job.job_id!r}")
        self.jobs[job.job_id] = job
        self._publish()
        return job

    def counts(self) -> Dict[str, int]:
        out = {state: 0 for state in STATES}
        for job in self.jobs.values():
            out[job.state] += 1
        return out

    def _publish(self) -> None:
        reg = get_registry()
        for state, n in self.counts().items():
            reg.set_gauge("jobs.state", n, state=state,
                          help="Jobs currently in each lifecycle state")

    # -- scheduling ---------------------------------------------------------
    def ready(self, now: Optional[float] = None) -> List[Job]:
        """Jobs whose next attempt may start now (insertion order)."""
        if now is None:
            now = self.clock()
        return [j for j in self.jobs.values()
                if j.state == PENDING
                or (j.state == FAILED and j.not_before <= now)]

    def unsettled(self) -> bool:
        """True while any job can still change state."""
        return any(j.state in (PENDING, RUNNING, FAILED)
                   for j in self.jobs.values())

    def next_wake(self) -> Optional[float]:
        """Earliest time a non-running job becomes ready; ``None`` when
        nothing is waiting (all done/abandoned/running)."""
        wakes = [0.0 if j.state == PENDING else j.not_before
                 for j in self.jobs.values()
                 if j.state in (PENDING, FAILED)]
        return min(wakes) if wakes else None

    # -- transitions --------------------------------------------------------
    def mark_running(self, job: Job) -> None:
        job.state = RUNNING
        job.attempts += 1
        job.started_at = self.clock()
        self._publish()

    def mark_done(self, job: Job, result: Any = None) -> None:
        job.state = DONE
        job.result = result
        job.started_at = None
        self._publish()

    def mark_failed(self, job: Job, kind: str, error: str) -> bool:
        """Record a failed attempt; returns ``True`` if a retry was
        scheduled, ``False`` if the job is now abandoned. Only
        ``RETRYABLE_KINDS`` retry — a generic ``"error"`` abandons
        immediately (deterministic bugs fail every attempt)."""
        job.errors.append(f"attempt {job.attempts} [{kind}]: {error}")
        job.last_kind = kind
        job.started_at = None
        reg = get_registry()
        if kind == "timeout":
            reg.inc("jobs.timeouts",
                    help="Job attempts reaped at their wall-clock deadline")
        elif kind == "crash":
            reg.inc("jobs.crashes",
                    help="Job attempts lost to worker death")
        else:
            reg.inc("jobs.errors", kind=kind,
                    help="Job attempts that raised")
        retryable = (kind in RETRYABLE_KINDS
                     and job.attempts < self.policy.max_attempts)
        if not retryable:
            job.state = ABANDONED
            reg.inc("jobs.abandoned",
                    help="Jobs that exhausted their retry budget")
            self._publish()
            return False
        job.state = FAILED
        job.not_before = self.clock() + self.policy.delay_s(job.job_id,
                                                            job.attempts)
        reg.inc("jobs.retries",
                help="Retries scheduled after failed job attempts")
        self._publish()
        return True

    def requeue_lost(self, job: Job) -> None:
        """Return an in-flight job to the queue without charging an
        attempt — used when the job was collateral damage (its pool died
        because of a *different* job) rather than the failure itself."""
        job.attempts = max(job.attempts - 1, 0)
        job.state = PENDING
        job.not_before = 0.0
        job.started_at = None
        get_registry().inc(
            "jobs.requeued",
            help="In-flight jobs requeued after losing their worker pool")
        self._publish()

    # -- reporting ----------------------------------------------------------
    def failures(self) -> List[JobFailure]:
        return [JobFailure(job_id=j.job_id, labels=j.labels,
                           kind=j.last_kind or "error",
                           attempts=j.attempts, errors=list(j.errors))
                for j in self.jobs.values() if j.state == ABANDONED]


# -- in-process executor ------------------------------------------------------

def run_local_jobs(jobs: Sequence[Job],
                   run_one: Callable[[Job], Any], *,
                   policy: Optional[RetryPolicy] = None,
                   registry: Optional[JobRegistry] = None,
                   faults: Optional[FaultPlan] = None,
                   progress: Optional[Callable[[int, int, Any], None]] = None,
                   on_done: Optional[Callable[[Job, Any], None]] = None,
                   sleep: Callable[[float], None] = time.sleep,
                   ) -> Tuple[Dict[str, Any], JobRegistry]:
    """Run jobs serially in-process with retry/backoff and fault injection.

    Used by the serial process-backend path and the jax backend's
    lane-chunk jobs. Returns ``(results by job_id, registry)``; abandoned
    jobs are absent from the results and reported by
    ``registry.failures()``. ``on_done`` fires after each success (the
    checkpoint-journaling hook). Wall-clock deadlines cannot preempt
    in-process work, so they apply to injected hangs only (see
    ``repro.sim.faults.raise_local_fault``); the process executor
    enforces real deadlines.
    """
    reg = registry or JobRegistry(policy)
    for job in jobs:
        reg.add(job)
    total = len(reg.jobs)
    results: Dict[str, Any] = {}
    tracer = get_tracer()
    n_done = 0
    while True:
        now = reg.clock()
        batch = reg.ready(now)
        if not batch:
            wake = reg.next_wake()
            if wake is None:
                break
            sleep(max(wake - now, 0.0))
            continue
        for job in batch:
            reg.mark_running(job)
            job.injected = (faults.directive(job.job_id, job.labels,
                                             job.attempts)
                            if faults is not None else None)
            try:
                with tracer.span("job.attempt", job=job.job_id,
                                 attempt=job.attempts):
                    if job.injected is not None:
                        raise_local_fault(job.injected, job.timeout_s, sleep)
                    out = run_one(job)
            except JobTimeout as e:
                reg.mark_failed(job, "timeout", str(e))
            except WorkerCrash as e:
                reg.mark_failed(job, "crash", str(e))
            except TransientFault as e:
                reg.mark_failed(job, "transient", str(e))
            except Exception as e:
                reg.mark_failed(job, "error", f"{type(e).__name__}: {e}")
            else:
                reg.mark_done(job, out)
                results[job.job_id] = out
                n_done += 1
                if on_done is not None:
                    on_done(job, out)
                if progress is not None:
                    progress(n_done, total, out)
    return results, reg


# -- process-pool executor ----------------------------------------------------

def _pool_attempt(spec: Any, directive: Optional[Dict[str, Any]]):
    """Worker-side task: act out any injected fault, then run the
    scenario. Returns the result plus the worker registry's snapshot
    delta (see ``repro.sim.sweep._run_scenario_with_metrics``).
    Top-level for pickling."""
    perform_in_worker(directive)
    from repro.sim.sweep import run_scenario

    result = run_scenario(spec)
    return result, snapshot_and_reset()


def _kill_pool(pool: ProcessPoolExecutor) -> None:
    """Tear a pool down *now*: running futures cannot be cancelled, so a
    deadline overrun or unattributable crash recycles the whole pool."""
    procs = list((getattr(pool, "_processes", None) or {}).values())
    pool.shutdown(wait=False, cancel_futures=True)
    for p in procs:
        try:
            p.terminate()
        except Exception:
            pass
    for p in procs:
        try:
            p.join(timeout=2.0)
        except Exception:
            pass


def run_process_jobs(jobs: Sequence[Job], *, workers: int,
                     policy: Optional[RetryPolicy] = None,
                     registry: Optional[JobRegistry] = None,
                     faults: Optional[FaultPlan] = None,
                     progress: Optional[Callable[[int, int, Any], None]]
                     = None,
                     on_done: Optional[Callable[[Job, Any], None]] = None,
                     poll_s: float = 0.1,
                     ) -> Tuple[Dict[str, Any], JobRegistry]:
    """Run scenario jobs on a spawned process pool with crash recovery.

    Each ``job.payload`` must be a picklable ``ScenarioSpec``. The loop
    keeps at most ``workers`` jobs in flight (so ``started_at`` measures
    run time, not queue time), polls every ``poll_s`` seconds for
    deadline overruns, and survives worker death: ``BrokenProcessPool``
    fails the implicated job (when a crash directive identifies it),
    requeues the innocent in-flight jobs without charging an attempt,
    and respawns the pool. When no directive attributes the crash, every
    in-flight job is charged — bounded retries keep a genuine repeat-
    crasher from cycling the pool forever.

    Returns ``(results by job_id, registry)``; abandoned jobs are
    reported by ``registry.failures()`` instead of raising.
    """
    reg = registry or JobRegistry(policy)
    for job in jobs:
        reg.add(job)
    total = len(reg.jobs)
    results: Dict[str, Any] = {}
    metrics = get_registry()
    tracer = get_tracer()
    ctx = multiprocessing.get_context("spawn")
    pool: Optional[ProcessPoolExecutor] = None
    inflight: Dict[Any, Job] = {}
    n_done = 0

    from repro.sim.sweep import _worker_init  # deferred: sweep imports us

    def ensure_pool() -> ProcessPoolExecutor:
        nonlocal pool
        if pool is None:
            pool = ProcessPoolExecutor(max_workers=workers, mp_context=ctx,
                                       initializer=_worker_init)
        return pool

    def recycle_pool() -> None:
        nonlocal pool
        if pool is not None:
            _kill_pool(pool)
            pool = None
        inflight.clear()

    try:
        while reg.unsettled():
            now = time.monotonic()
            overdue = [job for job in inflight.values()
                       if job.timeout_s is not None
                       and job.started_at is not None
                       and now - job.started_at > job.timeout_s]
            if overdue:
                # A running pool future cannot be cancelled: fail the
                # overdue jobs, requeue the innocent ones, recycle.
                innocent = [j for j in inflight.values()
                            if j not in overdue]
                for job in overdue:
                    reg.mark_failed(
                        job, "timeout",
                        f"exceeded the {job.timeout_s:g}s deadline")
                for job in innocent:
                    reg.requeue_lost(job)
                recycle_pool()
                continue
            broken_on_submit = False
            for job in reg.ready(now):
                if len(inflight) >= workers:
                    break
                reg.mark_running(job)
                job.injected = (faults.directive(job.job_id, job.labels,
                                                 job.attempts)
                                if faults is not None else None)
                try:
                    fut = ensure_pool().submit(_pool_attempt, job.payload,
                                               job.injected)
                except BrokenProcessPool:
                    reg.requeue_lost(job)
                    broken_on_submit = True
                    break
                inflight[fut] = job
            if broken_on_submit:
                for job in inflight.values():
                    reg.requeue_lost(job)
                recycle_pool()
                continue
            if not inflight:
                wake = reg.next_wake()
                if wake is None:
                    break
                time.sleep(min(max(wake - now, 0.0), poll_s))
                continue
            done_futs, _ = wait(set(inflight), timeout=poll_s,
                                return_when=FIRST_COMPLETED)
            crashed: List[Job] = []
            for fut in done_futs:
                job = inflight.pop(fut)
                try:
                    result, snap = fut.result()
                except BrokenProcessPool:
                    crashed.append(job)
                    continue
                except TransientFault as e:
                    reg.mark_failed(job, "transient", str(e))
                except Exception as e:
                    reg.mark_failed(job, "error",
                                    f"{type(e).__name__}: {e}")
                else:
                    metrics.merge(snap)
                    reg.mark_done(job, result)
                    results[job.job_id] = result
                    n_done += 1
                    tracer.instant("job.attempt", job=job.job_id,
                                   attempt=job.attempts, state=DONE)
                    if on_done is not None:
                        on_done(job, result)
                    if progress is not None:
                        progress(n_done, total, result)
            if crashed:
                # BrokenProcessPool fails every in-flight future at once.
                # Charge the jobs a crash directive implicates; the rest
                # are collateral and requeue free — unless nothing is
                # implicated, in which case everyone is charged (bounded
                # retries stop a real repeat-crasher).
                implicated = [j for j in crashed
                              if (j.injected or {}).get("kind") == "crash"]
                victims = implicated or crashed
                for job in crashed:
                    if job in victims:
                        reg.mark_failed(job, "crash",
                                        "worker died (BrokenProcessPool)")
                    else:
                        reg.requeue_lost(job)
                for job in list(inflight.values()):
                    reg.requeue_lost(job)
                recycle_pool()
    finally:
        if pool is not None:
            pool.shutdown(wait=True, cancel_futures=True)
    return results, reg


__all__ = [
    "ABANDONED", "DONE", "FAILED", "PENDING", "RUNNING", "STATES",
    "RETRYABLE_KINDS", "Job", "JobFailure", "JobRegistry", "RetryPolicy",
    "run_local_jobs", "run_process_jobs",
]
