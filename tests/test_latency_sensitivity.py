"""Paper §5.4 tape-latency sensitivity (reduced scale).

The paper: random normal access latency 30±10 min barely changes any
configuration; raising the mean to 60 min (±15) cuts configuration II's
finished jobs by ≈20 % while I and III lose only 2–4 % (the cloud cache
insulates job throughput from tape latency).
"""

import pytest

from repro.core.hcdc import HCDCScenario, make_config
from repro.sim.engine import DAY, MINUTE

DAYS, FILES = 3, 15_000


def _run(name, mean_min, sigma_min=0.0, seed=21):
    cfg = make_config(name, simulated_time=DAYS * DAY,
                      n_files_per_site=FILES, seed=seed)
    cfg.tape_latency = mean_min * MINUTE
    cfg.tape_latency_sigma = sigma_min * MINUTE
    return HCDCScenario(cfg).run()["jobs_done"]


@pytest.fixture(scope="module")
def jobs():
    out = {}
    for name in ("II", "III"):
        out[name, 30] = _run(name, 30)
        out[name, 60] = _run(name, 60, 15.0)
    return out


def test_latency_hurts_cfg_ii_most(jobs):
    drop_ii = 1 - jobs["II", 60] / jobs["II", 30]
    drop_iii = 1 - jobs["III", 60] / jobs["III", 30]
    # cfg II (no cloud cache) must be hit substantially harder
    assert drop_ii > drop_iii + 0.02
    assert drop_ii > 0.05


def test_cloud_cache_insulates_throughput(jobs):
    # cfg III loses only a few percent even at doubled latency (paper: ~4 %)
    drop_iii = 1 - jobs["III", 60] / jobs["III", 30]
    assert drop_iii < 0.08


def test_random_latency_30_noop():
    """30±10 min random latency ~= constant 30 min (paper §5.4)."""
    j_const = _run("III", 30, 0.0)
    j_rand = _run("III", 30, 10.0)
    assert abs(j_rand - j_const) / j_const < 0.02
