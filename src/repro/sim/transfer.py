"""Transfers and transfer managers (paper §4.1).

The paper's framework has two event types: *transfer generators* (model
logic; implemented per scenario in ``repro.core``) and *transfer managers*
(update active transfers each tick). Two built-in tick managers exist:

- ``BandwidthTransferManager``: each tick advances every active transfer by
  ``rate * dt`` where the rate is the link's shared-bandwidth share or fixed
  per-transfer throughput (the paper's two link modes).
- ``DurationTransferManager``: advances each transfer by a fixed increment so
  it completes after a configured duration.

Additionally ``EventDrivenTransferService`` is a beyond-paper analytic fast
path valid for *throughput-mode* links (the only mode the HCDC scenario
uses): a transfer's completion time is ``start + access_latency +
size/throughput`` under a FIFO ``max_active`` slot queue, so it schedules
completion events directly instead of ticking — identical aggregate
statistics at ~100x less work (cross-validated in tests).

The tick update math is also what ``repro.kernels.carousel_update``
implements as a TPU Pallas kernel (the paper's stated linear-scaling hot
loop, vectorized over transfers).
"""

from __future__ import annotations

import enum
from collections import deque
from dataclasses import dataclass
from typing import Callable, Dict, List, Optional, Sequence

import numpy as np

from repro.sim.cloud import GCSBucket
from repro.sim.engine import BaseSimulation, Schedulable
from repro.sim.infrastructure import File, NetworkLink, Replica


class TransferState(enum.Enum):
    QUEUED = 0
    LATENCY = 1  # slot held, deferred by tape access latency
    ACTIVE = 2
    DONE = 3


class Transfer:
    __slots__ = (
        "file", "link", "dst_replica", "state", "created", "started",
        "completed", "latency", "on_complete", "rate",
    )

    def __init__(self, file: File, link: NetworkLink, dst_replica: Replica,
                 created: int):
        self.file = file
        self.link = link
        self.dst_replica = dst_replica
        self.state = TransferState.QUEUED
        self.created = created
        self.started: Optional[int] = None
        self.completed: Optional[int] = None
        self.latency: float = 0.0
        self.rate: float = 0.0
        self.on_complete: List[Callable[[BaseSimulation, int, "Transfer"], None]] = []

    @property
    def duration(self) -> Optional[float]:
        """Transfer duration excluding queue wait (paper Table 2 metric)."""
        if self.completed is None or self.started is None:
            return None
        return self.completed - self.started


def _finish(sim: BaseSimulation, now: int, t: Transfer) -> None:
    t.state = TransferState.DONE
    t.completed = now
    t.dst_replica.size_done = t.file.size
    t.link.active -= 1
    t.link.traffic += t.file.size
    src, dst = t.link.src, t.link.dst
    if isinstance(src, GCSBucket):
        src.record_egress(now, t.file.size)
    if isinstance(dst, GCSBucket):
        dst.record_ingress(now, t.file.size)
    for cb in list(t.on_complete):
        cb(sim, now, t)


class EventDrivenTransferService:
    """Analytic completion scheduling for throughput-mode links."""

    def __init__(self, sim: BaseSimulation, rng):
        self.sim = sim
        self.rng = rng
        self._queues: Dict[int, deque] = {}  # keyed by id(link): names are not unique across sites
        self.completed_count = 0
        self.completed_bytes = 0.0
        self.durations_sum = 0.0

    def submit(self, file: File, link: NetworkLink,
               on_complete: Optional[Callable] = None) -> Transfer:
        if link.throughput is None:
            raise ValueError("EventDrivenTransferService requires throughput links")
        dst_replica = link.dst.allocate(file)
        t = Transfer(file, link, dst_replica, self.sim.now)
        if on_complete is not None:
            t.on_complete.append(on_complete)
        q = self._queues.setdefault(id(link), deque())
        if link.has_slot():
            self._start(t)
        else:
            link.queued += 1
            q.append(t)
        return t

    def _start(self, t: Transfer) -> None:
        link = t.link
        link.active += 1
        t.latency = link.src.sample_latency(self.rng)
        t.rate = link.throughput
        t.state = TransferState.LATENCY if t.latency > 0 else TransferState.ACTIVE
        t.started = self.sim.now + int(round(t.latency))
        done_at = t.started + max(1, int(round(t.file.size / t.rate)))
        self.sim.call_at(done_at, lambda sim, now, t=t: self._complete(sim, now, t))

    def _complete(self, sim: BaseSimulation, now: int, t: Transfer) -> None:
        _finish(sim, now, t)
        self.completed_count += 1
        self.completed_bytes += t.file.size
        self.durations_sum += t.duration
        q = self._queues.get(id(t.link))
        while q and t.link.has_slot():
            nxt = q.popleft()
            t.link.queued -= 1
            self._start(nxt)


@dataclass(frozen=True)
class LinkTickTable:
    """Dense link-parameter arrays for fixed-tick (batched/kernel) engines.

    The tick adapter between object-graph links and the vectorized
    transfer-tick math (``repro.kernels.carousel_update`` and the
    ``repro.sim.batched`` lane-per-scenario backend): link ``m`` advances an
    active transfer by ``bw[m] * dt`` bytes per tick (throughput mode) or
    ``bw[m]/count * dt`` (shared mode), holds at most ``slots[m]`` concurrent
    transfers, and defers progress by ``latency[m]`` seconds after a slot is
    taken (tape access latency).
    """

    bw: np.ndarray  # [M] f32, bytes/s
    slots: np.ndarray  # [M] f32, max concurrent transfers (inf = unlimited)
    latency: np.ndarray  # [M] f32, seconds before progress starts
    mode: np.ndarray  # [M] i32, 1 = per-transfer throughput, 0 = shared

    @classmethod
    def from_values(cls, rates: Sequence[float],
                    slots: Sequence[Optional[float]],
                    latencies: Sequence[float],
                    modes: Optional[Sequence[int]] = None) -> "LinkTickTable":
        m = len(rates)
        if modes is None:
            modes = [1] * m
        return cls(
            bw=np.asarray(rates, dtype=np.float32),
            slots=np.asarray([np.inf if s is None else float(s)
                              for s in slots], dtype=np.float32),
            latency=np.asarray(latencies, dtype=np.float32),
            mode=np.asarray(modes, dtype=np.int32),
        )

    @classmethod
    def from_links(cls, links: Sequence[NetworkLink]) -> "LinkTickTable":
        return cls.from_values(
            rates=[ln.throughput if ln.throughput is not None
                   else ln.bandwidth for ln in links],
            slots=[ln.max_active for ln in links],
            latencies=[ln.src.access_latency for ln in links],
            modes=[1 if ln.throughput is not None else 0 for ln in links],
        )

    def __len__(self) -> int:
        return int(self.bw.shape[0])


class BandwidthTransferManager(Schedulable):
    """Paper built-in tick manager #1: progress by link rate x dt.

    Handles both link modes: shared bandwidth (divided among active
    transfers) and fixed per-transfer throughput. Also enforces
    ``max_active`` FIFO slot queues and tape access latency.
    """

    def __init__(self, interval: int = 1, rng=None):
        super().__init__(interval=interval, priority=-1)  # run before generators
        self.rng = rng
        self.active: List[Transfer] = []
        self._queues: Dict[int, deque] = {}  # keyed by id(link): names are not unique across sites
        self._last_update: Optional[int] = None
        self.completed_count = 0
        self.completed_bytes = 0.0
        self.durations_sum = 0.0
        self.tick_traffic: float = 0.0  # bytes moved during the last tick

    def submit(self, sim: BaseSimulation, file: File, link: NetworkLink,
               on_complete: Optional[Callable] = None) -> Transfer:
        dst_replica = link.dst.allocate(file)
        t = Transfer(file, link, dst_replica, sim.now)
        if on_complete is not None:
            t.on_complete.append(on_complete)
        if link.has_slot():
            self._activate(sim, t)
        else:
            link.queued += 1
            self._queues.setdefault(id(link), deque()).append(t)
        return t

    def _activate(self, sim: BaseSimulation, t: Transfer) -> None:
        link = t.link
        link.active += 1
        t.latency = link.src.sample_latency(self.rng)
        t.started = sim.now + int(round(t.latency))
        t.state = TransferState.LATENCY if t.latency > 0 else TransferState.ACTIVE
        self.active.append(t)

    def on_update(self, sim: BaseSimulation, now: int) -> None:
        last = self._last_update if self._last_update is not None else now - self.interval
        dt = now - last
        self._last_update = now
        if dt <= 0:
            return
        self.tick_traffic = 0.0
        # Count active (past-latency) transfers per bandwidth link first —
        # the share each transfer gets this tick.
        n_active: Dict[int, int] = {}
        for t in self.active:
            if now >= t.started:
                t.state = TransferState.ACTIVE
                n_active[id(t.link)] = n_active.get(id(t.link), 0) + 1
        finished: List[Transfer] = []
        for t in self.active:
            if t.state is not TransferState.ACTIVE:
                continue
            rate = t.link.rate_per_transfer(n_active[id(t.link)])
            t.rate = rate
            inc = min(rate * dt, t.file.size - t.dst_replica.size_done)
            t.dst_replica.size_done += inc
            self.tick_traffic += inc
            if t.dst_replica.size_done >= t.file.size:
                finished.append(t)
        for t in finished:
            self.active.remove(t)
            _finish(sim, now, t)
            self.completed_count += 1
            self.completed_bytes += t.file.size
            self.durations_sum += t.duration
            q = self._queues.get(id(t.link))
            while q and t.link.has_slot():
                nxt = q.popleft()
                t.link.queued -= 1
                self._activate(sim, nxt)


class DurationTransferManager(Schedulable):
    """Paper built-in tick manager #2: fixed increment per tick so the
    replica completes after a configured duration."""

    def __init__(self, duration: int, interval: int = 1):
        super().__init__(interval=interval, priority=-1)
        self.duration = max(1, int(duration))
        self.active: List[Transfer] = []
        self.completed_count = 0

    def submit(self, sim: BaseSimulation, file: File, link: NetworkLink,
               on_complete: Optional[Callable] = None) -> Transfer:
        dst_replica = link.dst.allocate(file)
        t = Transfer(file, link, dst_replica, sim.now)
        if on_complete is not None:
            t.on_complete.append(on_complete)
        t.started = sim.now
        t.state = TransferState.ACTIVE
        t.link.active += 1
        self.active.append(t)
        return t

    def on_update(self, sim: BaseSimulation, now: int) -> None:
        finished = []
        for t in self.active:
            inc = t.file.size * self.interval / self.duration
            t.dst_replica.size_done = min(t.file.size, t.dst_replica.size_done + inc)
            if now - t.started >= self.duration:
                t.dst_replica.size_done = t.file.size
                finished.append(t)
        for t in finished:
            self.active.remove(t)
            _finish(sim, now, t)
            self.completed_count += 1
