"""Distribution layer: mesh axes, logical sharding rules, parallel plans."""

from repro.parallel.sharding import (
    LANES_AXIS,
    ParallelPlan,
    lane_mesh,
    param_shardings,
    batch_shardings,
    cache_shardings,
    plan_for,
)

__all__ = [
    "LANES_AXIS",
    "ParallelPlan",
    "lane_mesh",
    "param_shardings",
    "batch_shardings",
    "cache_shardings",
    "plan_for",
]
