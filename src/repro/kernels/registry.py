"""Kernel-selection registry: the ``tick_impl`` axis.

One name — ``"jnp" | "pallas" | "pallas_interpret" | "auto"`` — selects
how the batched tick engine (``repro.sim.batched``) and the carousel
tick wrapper (``repro.kernels.carousel_update.ops``) execute their hot
loop, replacing the scattered ``use_pallas``/``interpret`` booleans that
previously leaked through ``simulate_packed``/``run_sweep_jax``/
``carousel_tick``:

- ``"jnp"``: the pure-``jax.numpy`` program — the numerical oracle and
  the CPU fast path (scatter-free one-hot formulation; see
  ``repro.sim.batched``). Bitwise identical to the pre-registry default,
  so its cache fingerprint stays the legacy ``jax:<tick>`` key.
- ``"pallas"``: the fused lane-tick Pallas kernels
  (``repro.kernels.lane_tick``) compiled for the local accelerator
  (Mosaic on TPU, Triton on GPU). Requires an accelerator backend.
- ``"pallas_interpret"``: the same kernels in Pallas interpret mode —
  traced to regular XLA ops, so they run (slowly) on any backend. This
  is the CI-runnable parity path, not a performance mode.
- ``"auto"``: resolve per host — ``"pallas"`` when
  ``jax.default_backend()`` is an accelerator, else ``"jnp"``. ``auto``
  never silently selects interpret mode: pinning ``JAX_PLATFORMS=cpu``
  on an accelerator host makes ``jax.default_backend()`` report ``cpu``
  and resolution lands on ``"jnp"``, and an unpinned accelerator host
  gets the compiled kernel or a loud compile error — never a 100x-slow
  interpret run.

Naming note: ``tick_impl`` selects the *kernel implementation*; the
neighbouring ``tick=`` float on ``run_sweep``/``SweepDriver``/the CLIs
is the *clock step duration in seconds*. The two axes are independent
(``--tick 60 --tick-impl pallas_interpret`` is a coarse-clock interpret
run).

``jax`` is imported lazily — resolving a concrete name ("jnp",
"pallas", "pallas_interpret") never touches jax, so jax-free flows
(the process backend, cache keying of concrete impls) stay jax-free.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Tuple

from repro.obs.metrics import get_registry


@dataclass(frozen=True)
class TickImpl:
    """One resolved tick-engine implementation.

    ``use_kernel`` — run the fused Pallas kernels instead of the jnp
    program; ``interpret`` — trace those kernels in Pallas interpret
    mode rather than compiling them for the accelerator.
    """

    name: str
    use_kernel: bool
    interpret: bool


TICK_IMPLS = {
    "jnp": TickImpl("jnp", use_kernel=False, interpret=False),
    "pallas": TickImpl("pallas", use_kernel=True, interpret=False),
    "pallas_interpret": TickImpl("pallas_interpret", use_kernel=True,
                                 interpret=True),
}

#: Valid ``tick_impl=`` / ``--tick-impl`` values, resolution aliases
#: included (CLI ``choices=`` uses this tuple).
TICK_IMPL_CHOICES: Tuple[str, ...] = ("auto",) + tuple(TICK_IMPLS)


def _platform() -> str:
    """The active JAX backend platform (monkeypatch point for tests)."""
    import jax

    return jax.default_backend()


def on_accelerator() -> bool:
    """True when the default JAX backend is an accelerator (tpu/gpu)."""
    return _platform() in ("tpu", "gpu")


def default_tick_impl() -> str:
    """Resolve ``"auto"`` for this host: compiled Pallas on an
    accelerator, the jnp program on CPU (never interpret mode)."""
    return "pallas" if on_accelerator() else "jnp"


def default_interpret() -> bool:
    """Backend-aware interpret default for bare kernel calls: compile on
    an accelerator, interpret elsewhere (the only way the kernel runs on
    CPU). Kernel entry points (``carousel_tick_pallas``, the
    ``lane_tick`` wrappers) use this when ``interpret`` is not given."""
    return not on_accelerator()


def resolve_tick_impl(name: Optional[str] = "auto") -> TickImpl:
    """Resolve a ``tick_impl`` name to its :class:`TickImpl` record.

    ``"auto"`` (or ``None``) resolves per host via
    :func:`default_tick_impl`; concrete names resolve without importing
    jax. Unknown names raise ``ValueError``.
    """
    if name is None:
        name = "auto"
    if isinstance(name, TickImpl):
        return name
    if isinstance(name, bool):
        raise ValueError(
            f"tick_impl={name!r} is a boolean — this looks like the "
            "removed use_pallas= flag landing in the tick_impl slot; "
            "use tick_impl="
            f"{'pallas_interpret' if name else 'jnp'!r} "
            "(or 'pallas'/'auto' to compile on an accelerator)")
    requested = name
    if name == "auto":
        name = default_tick_impl()
    try:
        impl = TICK_IMPLS[name]
    except KeyError:
        raise ValueError(
            f"unknown tick_impl {name!r} "
            f"(expected one of {', '.join(TICK_IMPL_CHOICES)})") from None
    get_registry().inc("tick_impl.resolved",
                       help="tick_impl resolutions by resolved name",
                       impl=impl.name, requested=requested)
    return impl
