# Convenience targets; everything runs from the source tree (PYTHONPATH=src).

PY := PYTHONPATH=src python

.PHONY: test test-fast bench bench-smoke lint clean

test:
	$(PY) -m pytest -x -q

test-fast:
	$(PY) -m pytest -x -q -m "not slow"

bench:
	$(PY) benchmarks/run.py

bench-smoke:
	FAST=1 BENCH_JSON=BENCH_ci.json $(PY) benchmarks/run.py

lint:
	ruff check src tests benchmarks scripts

# Remove interpreter droppings (bytecode caches shipped by accident break
# nothing but pollute diffs and wheels).
clean:
	find src tests benchmarks scripts examples -name __pycache__ -type d -prune -exec rm -rf {} + 2>/dev/null || true
	find src tests benchmarks scripts examples -name '*.pyc' -delete 2>/dev/null || true
	rm -rf .pytest_cache .ruff_cache
