"""Batched scenario sweep CLI (paper §5.3 decision workflow).

Runs a grid of HCDC configurations in parallel and emits the cost vs.
throughput table, its Pareto front, and optional per-seed aggregates.

Grid from inline axes (comma-separated values expand the grid)::

    PYTHONPATH=src python scripts/run_sweep.py \
        --cache-tb 20,50,100 --egress internet,direct,interconnect \
        --seeds 2 --days 1 --files 10000 --out results/sweep.csv

Access-pattern (workload) models are an axis too — repeat ``--workload``
per model (``docs/workloads.md`` has the catalogue)::

    PYTHONPATH=src python scripts/run_sweep.py \
        --workload steady --workload diurnal:amplitude=0.8 \
        --cache-tb 20,50 --days 1 --out results/workloads.csv

or from a YAML/JSON spec file (see docs/simulation.md)::

    PYTHONPATH=src python scripts/run_sweep.py --spec sweep.yaml

Spec-file shape: top-level fixed fields plus either ``axes`` (mapping of
spec field -> value or list, Cartesian product) or ``scenarios`` (explicit
list of spec mappings).

Long sweeps can run fault-tolerantly (``--retries``/``--job-timeout``),
checkpoint finished jobs into the result cache (``--resume``), and be
stress-tested under deterministic fault injection (``--faults`` /
``$REPRO_FAULTS``) — see docs/resilience.md. Exit status 3 means the
sweep finished but returned a partial result (some jobs abandoned).
"""

from __future__ import annotations

import argparse
import json
import logging
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core.scenarios import EGRESS_OPTIONS, specs_from_mapping
from repro.kernels.registry import TICK_IMPL_CHOICES
from repro.obs.logs import LOG_LEVELS, setup_logging
from repro.obs.metrics import get_registry
from repro.obs.trace import get_tracer, jax_device_profile
from repro.sim.jobs import RetryPolicy
from repro.sim.output import write_csv
from repro.sim.sweep import run_sweep

log = logging.getLogger("run_sweep")


def _floats(text: str) -> list:
    """Comma list of floats; 'inf' = unlimited, 'base' = keep base config."""
    out = []
    for tok in text.split(","):
        tok = tok.strip().lower()
        out.append(None if tok == "base" else float(tok))
    return out


def _build_axes(args: argparse.Namespace) -> dict:
    axes: dict = {
        "base": args.base,
        "days": args.days,
        "n_files": args.files,
        "seed": list(range(args.first_seed, args.first_seed + args.seeds)),
        "curves": args.curves,
    }
    if args.cache_tb:
        axes["cache_tb"] = _floats(args.cache_tb)
    if args.gcs_tb:
        axes["gcs_limit_tb"] = _floats(args.gcs_tb)
    if args.egress:
        axes["egress"] = [e.strip() for e in args.egress.split(",")]
    if args.storage_price:
        axes["storage_price"] = _floats(args.storage_price)
    if args.egress_price:
        axes["egress_price"] = _floats(args.egress_price)
    if args.rate_scale:
        axes["job_rate_scale"] = _floats(args.rate_scale)
    if args.workload:
        # Repeated --workload flags each add one model; a flag without
        # ':' parameters may also carry a plain comma list. (Parameterized
        # models embed commas, so those need their own flag.)
        wl: list = []
        for tok in args.workload:
            tok = tok.strip()
            if ":" in tok:
                if "," in tok.partition(":")[0]:
                    raise ValueError(
                        f"--workload {tok!r}: comma lists cannot include "
                        "parameterized models (their parameters themselves "
                        "contain commas) — repeat --workload once per model")
                wl.append(tok)
            else:
                wl += [t.strip() for t in tok.split(",") if t.strip()]
        axes["workload"] = wl
    return axes


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="Batched HCDC scenario sweep (cost/throughput frontier)")
    ap.add_argument("--spec", help="YAML/JSON sweep spec file (overrides axis flags)")
    ap.add_argument("--base", default="III", choices=["I", "II", "III"],
                    help="Table 5 base configuration (default III)")
    ap.add_argument("--days", type=float, default=1.0, help="simulated days")
    ap.add_argument("--files", type=int, default=10_000,
                    help="files per site (catalogue size)")
    ap.add_argument("--cache-tb", default="",
                    help="comma list of per-site disk cache limits in TB "
                         "('inf' unlimited, 'base' keep)")
    ap.add_argument("--gcs-tb", default="",
                    help="comma list of cold-tier limits in TB (0 disables)")
    ap.add_argument("--egress", default="",
                    help=f"comma list from {','.join(EGRESS_OPTIONS)}")
    ap.add_argument("--storage-price", default="",
                    help="comma list of USD/GB-month storage prices")
    ap.add_argument("--egress-price", default="",
                    help="comma list of flat USD/GiB egress prices "
                         "(overrides the egress option's price table; "
                         "billing-only, shares dynamics lanes)")
    ap.add_argument("--rate-scale", default="",
                    help="comma list of job-arrival-rate multipliers")
    ap.add_argument("--workload", action="append", metavar="MODEL",
                    help="access-pattern model axis; repeat per model "
                         "(steady | diurnal | campaign | zipf-drift | "
                         "trace:PATH, parameters as 'name:key=val,...' — "
                         "see docs/workloads.md). Default: steady")
    ap.add_argument("--seeds", type=int, default=1,
                    help="replica seeds per config (default 1)")
    ap.add_argument("--first-seed", type=int, default=0)
    ap.add_argument("--curves", action="store_true",
                    help="record Fig 6/8 time-series digests (JSON output)")
    ap.add_argument("--backend", default="process",
                    choices=["process", "jax"],
                    help="process = event-driven reference engine (one "
                         "process per config); jax = batched lane-per-"
                         "scenario engine (whole grid as one jit+vmap "
                         "program; requires uniform --days/--files)")
    ap.add_argument("--tick", type=float, default=10.0,
                    help="jax backend clock step in seconds (default 10, "
                         "the paper's generator interval; larger ticks "
                         "trade temporal resolution for speed). Distinct "
                         "from --tick-impl, which picks the kernel")
    ap.add_argument("--tick-impl", default="auto",
                    choices=TICK_IMPL_CHOICES,
                    help="jax backend kernel implementation: jnp (the "
                         "oracle program), pallas (compiled kernels; "
                         "accelerator), pallas_interpret (kernels traced "
                         "through the Pallas interpreter — parity/CI "
                         "path, not a speed mode), or auto (default: "
                         "pallas on an accelerator, jnp on CPU). See "
                         "docs/simulation.md, 'Kernel selection'")
    ap.add_argument("--lane-chunk", type=int, default=None, metavar="N",
                    help="jax backend: simulate at most N dynamics lanes "
                         "per device dispatch (bounded memory for large "
                         "grids, one compile reused across chunks; "
                         "per-lane results are bitwise identical to the "
                         "unchunked run). Default: all lanes at once")
    ap.add_argument("--workers", type=int, default=None,
                    help="worker processes (default: all CPUs)")
    ap.add_argument("--transport", default=None,
                    choices=["subprocess", "local"],
                    help="run jobs on a persistent worker fleet "
                         "(repro.sim.runners) instead of the anonymous "
                         "pool: 'subprocess' spawns --workers local "
                         "worker processes, 'local' executes inline "
                         "(testing). Works with both backends; composes "
                         "with --retries/--faults/--job-timeout "
                         "(docs/distributed.md)")
    ap.add_argument("--shard", action="store_true",
                    help="jax backend: run each lane batch as one "
                         "jax.shard_map program over the local device "
                         "mesh instead of the per-chunk Python loop "
                         "(bitwise-identical per lane; needs more than "
                         "one device to help). See docs/distributed.md")
    ap.add_argument("--cache-dir", default=os.environ.get("REPRO_CACHE_DIR"),
                    metavar="DIR",
                    help="persistent result-cache directory (default: "
                         "$REPRO_CACHE_DIR if set, else no cache): "
                         "already-simulated configurations are served "
                         "from disk, only the rest are simulated "
                         "(docs/simulation.md, 'Result cache')")
    ap.add_argument("--no-cache", action="store_true",
                    help="disable the result cache even if --cache-dir or "
                         "$REPRO_CACHE_DIR is set")
    ap.add_argument("--retries", type=int, default=None, metavar="N",
                    help="fault-tolerant execution: retry crashed/timed-"
                         "out/transiently-failing jobs up to N attempts "
                         "with exponential backoff, and return a partial "
                         "result (exit 3) instead of raising when a job "
                         "exhausts them (docs/resilience.md)")
    ap.add_argument("--job-timeout", type=float, default=None, metavar="S",
                    help="per-job wall-clock deadline in seconds; overdue "
                         "jobs are killed and retried (counts as a "
                         "retryable failure)")
    ap.add_argument("--faults", default=os.environ.get("REPRO_FAULTS"),
                    metavar="PLAN",
                    help="inject deterministic faults for resilience "
                         "testing, e.g. 'seed=7,crash=0.2,hang=0.1,"
                         "transient=0.2,corrupt=0.1' (default: "
                         "$REPRO_FAULTS if set). See docs/resilience.md")
    ap.add_argument("--resume", action="store_true",
                    help="journal each finished job into --cache-dir as it "
                         "completes, so a killed run re-run with the same "
                         "flags recomputes only unfinished jobs (requires "
                         "--cache-dir; implies --retries 3)")
    ap.add_argument("--out", default="", help="write the full table as CSV")
    ap.add_argument("--json", dest="json_out", default="",
                    help="write table + series digests as JSON")
    ap.add_argument("--pareto", default="", help="write the Pareto front as CSV")
    ap.add_argument("--aggregate", default="",
                    help="write the across-seed aggregate table as CSV")
    ap.add_argument("--record-series", type=int, default=None, metavar="N",
                    help="jax backend: capture per-tick time series on "
                         "device, sampled every N ticks (1 = every tick); "
                         "digests land in the JSON output's series block. "
                         "See docs/observability.md")
    ap.add_argument("--metrics-out", default="", metavar="PATH",
                    help="write the metrics-registry snapshot (Prometheus "
                         "text format, or JSON when PATH ends in .json)")
    ap.add_argument("--trace-out", default="", metavar="PATH",
                    help="enable span tracing and write Chrome trace-event "
                         "JSON (load in Perfetto / chrome://tracing)")
    ap.add_argument("--jax-profile", default="", metavar="DIR",
                    help="with --trace-out: bracket the sweep in "
                         "jax.profiler device tracing (TensorBoard "
                         "logdir; compiled-path deep dive)")
    ap.add_argument("--log-level", default="info", choices=LOG_LEVELS,
                    help="stderr logging verbosity (default info)")
    ap.add_argument("--quiet", action="store_true", help="no per-config progress")
    args = ap.parse_args(argv)

    run_id = setup_logging(args.log_level)
    if args.trace_out:
        get_tracer().enable(run_id)

    try:
        if args.spec:
            with open(args.spec) as f:
                if args.spec.endswith((".yaml", ".yml")):
                    import yaml

                    try:
                        doc = yaml.safe_load(f)
                    except yaml.YAMLError as e:
                        raise ValueError(f"invalid YAML in {args.spec}: {e}")
                else:
                    doc = json.load(f)
            specs = specs_from_mapping(doc)
        else:
            specs = specs_from_mapping({"axes": _build_axes(args)})
    except (ValueError, OSError) as e:
        log.error("%s", e)
        return 2
    if not specs:
        log.error("the grid expanded to 0 configs")
        return 2

    if args.lane_chunk is not None and args.backend != "jax":
        log.error("--lane-chunk requires --backend jax")
        return 2
    if args.tick_impl != "auto" and args.backend != "jax":
        log.error("--tick-impl requires --backend jax")
        return 2
    if args.record_series is not None and args.backend != "jax":
        log.error("--record-series requires --backend jax "
                  "(use --curves for the process backend)")
        return 2
    if args.shard and args.backend != "jax":
        log.error("--shard requires --backend jax")
        return 2
    if args.backend == "jax":
        chunk = ("" if args.lane_chunk is None
                 else f", lane_chunk={args.lane_chunk}")
        log.info("sweep: %d configs, backend=jax (tick=%gs, tick_impl=%s%s)",
                 len(specs), args.tick, args.tick_impl, chunk)
    else:
        workers = (min(len(specs), os.cpu_count() or 1)
                   if args.workers is None else args.workers)
        log.info("sweep: %d configs, workers=%d",
                 len(specs), max(workers, 1))

    def progress(done, total, result):
        if not args.quiet:
            log.info("[%3d/%d] %-55s jobs=%8.0f cost=$%s",
                     done, total, result.spec.label, result.jobs_done,
                     f"{result.cost_usd:12,.2f}")

    cache_dir = None if args.no_cache else args.cache_dir
    if args.resume and not cache_dir:
        log.error("--resume needs a result cache (--cache-dir or "
                  "$REPRO_CACHE_DIR) to journal completed jobs into")
        return 2
    if args.retries is not None and args.retries < 1:
        log.error("--retries must be >= 1")
        return 2
    retry = None
    if args.retries is not None:
        retry = RetryPolicy(max_attempts=args.retries)
    elif args.resume:
        retry = RetryPolicy()  # engage the jobs layer so completions journal
    if cache_dir:
        log.info("cache: %s", cache_dir)
    if args.faults:
        log.info("fault injection: %s", args.faults)
    try:
        with jax_device_profile(args.jax_profile or None):
            result = run_sweep(specs, workers=args.workers,
                               progress=progress,
                               backend=args.backend, tick=args.tick,
                               tick_impl=args.tick_impl,
                               lane_chunk=args.lane_chunk, cache=cache_dir,
                               record_series=args.record_series,
                               retry=retry, faults=args.faults,
                               job_timeout=args.job_timeout,
                               transport=args.transport,
                               shard=args.shard)
    except ValueError as e:  # e.g. non-uniform grid on the jax backend
        log.error("%s", e)
        return 2
    cps = result.configs_per_sec
    log.info("done in %.1fs%s", result.wall_s,
             "" if cps is None else f" ({cps:.2f} configs/sec)")
    if cache_dir:
        log.info("cache: %d of %d configs served from cache, "
                 "%d dynamics lane(s) simulated",
                 result.cache_hits, len(result), result.lanes_simulated)
    if result.failures:
        for f in result.failures:
            log.error("job %s abandoned after %d attempt(s): [%s] %s",
                      f.job_id, f.attempts, f.kind,
                      f.errors[-1] if f.errors else "")
        log.error("PARTIAL result: %d config(s) returned, %d job(s) "
                  "abandoned%s", len(result), len(result.failures),
                  " — re-run with --resume to retry only the missing jobs"
                  if cache_dir else "")

    front = result.pareto_front()
    print(f"\nPareto front (min cost, max jobs) — {len(front)} of "
          f"{len(result)} configs:")
    for r in front:
        print(f"  {r.spec.label:55s} jobs={r.jobs_done:8.0f} "
              f"cost=${r.cost_usd:12,.2f} (${1e3 * r.cost_usd / max(r.jobs_done, 1):,.2f}/kjob)")

    if args.out:
        result.to_csv(args.out)
        log.info("wrote %s (%d rows)", args.out, len(result))
    if args.json_out:
        result.to_json(args.json_out)
        log.info("wrote %s", args.json_out)
    if args.pareto:
        result.pareto_to_csv(args.pareto)
        log.info("wrote %s (%d rows)", args.pareto, len(front))
    if args.aggregate:
        rows = result.aggregate_seeds()
        write_csv(args.aggregate, rows)
        log.info("wrote %s (%d rows)", args.aggregate, len(rows))
    if args.metrics_out:
        get_registry().dump(args.metrics_out)
        log.info("wrote %s", args.metrics_out)
    if args.trace_out:
        get_tracer().dump(args.trace_out)
        log.info("wrote %s (%d spans)", args.trace_out,
                 len(get_tracer().events))
    return 3 if result.failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
