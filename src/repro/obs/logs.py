"""CLI logging setup with run-id correlation (ISSUE 8).

The scripts' operational chatter (sweep headers, per-config progress,
cache status) goes through stdlib ``logging`` so it carries a timestamp,
a level, and the run id that also tags every trace span — results and
tables still print to stdout. ``setup_logging`` is the one entry point:
it configures the root handler once, returns the run id it correlated,
and aligns the global tracer's ``run_id`` so ``--trace-out`` events and
log lines cross-reference.
"""

from __future__ import annotations

import logging
import sys
import uuid
from typing import Optional

from repro.obs.trace import get_tracer

#: ``--log-level`` choices, lowercase (argparse-friendly).
LOG_LEVELS = ("debug", "info", "warning", "error")


def setup_logging(level: str = "info",
                  run_id: Optional[str] = None) -> str:
    """Configure root logging for a CLI run; returns the run id.

    The format embeds the run id, so piped/teed logs from several runs
    stay attributable; the same id is pushed into the global tracer for
    span correlation. Idempotent per process (reconfigures handlers on
    repeat calls rather than stacking them).
    """
    rid = run_id or uuid.uuid4().hex[:8]
    logging.basicConfig(
        level=getattr(logging, level.upper()),
        format=f"%(asctime)s %(levelname)s [{rid}] %(name)s: %(message)s",
        datefmt="%H:%M:%S",
        stream=sys.stderr,
        force=True,
    )
    get_tracer().run_id = rid
    return rid


__all__ = ["LOG_LEVELS", "setup_logging"]
