"""HCDC scenario: Hot/Cold Data Carousel simulation (paper §5).

Infrastructure (Fig. 4): two grid sites, each with TAPE (archival), DISK
(hot, the carousel window), WORKER, and OUTPUT storage elements, plus a
single shared GCS bucket (cold). Directional throughput-mode links per
Table 4. Jobs follow the Fig. 5 state machine:

  waiting -> transferring -> queued -> active -> running -> (done)

Each generator tick (10 s) per site:
  1. deletions: obsolete disk replicas (no live consumer) are deleted if
     already on GCS, else migrated disk->GCS then deleted (only when the
     disk is limited; configuration I keeps everything);
  2. submission: a truncated-normal number of jobs is submitted, each
     selecting an input file by popularity;
  3. waiting queue: FIFO admission into the disk window as space frees.

Jobs whose input is already on disk skip straight to queued; queued jobs
start immediately (the paper configures no job-slot limit); active jobs
download disk->worker at fixed throughput, then run for an exponential
duration, then finish (uploads carry no configured volume — paper §5.3).
Multiple jobs waiting on the same file share one transfer.

Configurations (Table 5): I — unlimited disk, GCS disabled; II — 100 TB
disk, GCS disabled; III — 100 TB disk, unlimited GCS.
"""

from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field, replace
from typing import Dict, List, Optional

import numpy as np

from repro.core.carousel import SlidingWindow
from repro.core.hotcold import ColdDeletionPolicy, MigrationPolicy, PopularityModel
from repro.sim.cloud import GCSBucket, GCSCostModel
from repro.sim.distributions import (
    BoundedExponential,
    FractionalCounter,
    TruncatedNormalCount,
)
from repro.sim.engine import DAY, HOUR, MINUTE, BaseSimulation, Schedulable
from repro.sim.infrastructure import GiB, TB, File, NetworkLink, Site, StorageElement
from repro.sim.output import OutputCollector
from repro.sim.transfer import EventDrivenTransferService
from repro.sim.workload import SteadyPoisson, WorkloadModel

# File location states (per site, per file).
ABSENT, IN_FLIGHT, PRESENT = 0, 1, 2


@dataclass
class SiteSpec:
    name: str
    tape_to_disk_mb_s: float  # Table 4
    disk_limit: Optional[float]  # Table 5


@dataclass
class HCDCConfig:
    simulated_time: int = 90 * DAY
    gen_interval: int = 10
    n_files_per_site: int = 1_000_000
    # input file size ~ Exp(lambda) GiB clamped (Table 3; GiB per the
    # validation-scenario unit calibration).
    size_lam: float = 0.026
    size_lo: float = 9.76e6 / GiB
    size_hi: float = 134e9 / GiB
    # jobs submitted per tick per site ~ TruncNormal (Table 3)
    jobs_mu: float = 0.63366
    jobs_sigma: float = 0.37292
    # job duration ~ Exp(lambda) s, clamped below (Table 3)
    dur_lam: float = 0.00409
    dur_lo: float = 1000.0  # 16.666 minutes
    popularity: PopularityModel = field(default_factory=PopularityModel)
    # access-pattern shape: per-tick arrival-rate / popularity-skew schedule
    # (repro.sim.workload; the steady default is a bit-exact no-op)
    workload: WorkloadModel = field(default_factory=SteadyPoisson)
    # network (Table 4), bytes/s
    gcs_to_disk: float = 294.00e6
    disk_to_gcs: float = 500.00e6
    download: float = 88.24e6
    max_active: int = 100
    tape_latency: float = 30 * MINUTE
    tape_latency_sigma: float = 0.0  # >0: normal-random latency (paper §5.4)
    sites: List[SiteSpec] = field(default_factory=lambda: [
        SiteSpec("Site-1", 22.62e6, 100 * TB),
        SiteSpec("Site-2", 62.35e6, 100 * TB),
    ])
    gcs_limit: Optional[float] = None  # None = unlimited, 0.0 = disabled
    cost_model: GCSCostModel = field(default_factory=GCSCostModel)
    migration_policy: MigrationPolicy = field(default_factory=MigrationPolicy)
    cold_deletion_policy: ColdDeletionPolicy = field(default_factory=ColdDeletionPolicy)
    seed: int = 0
    curves: bool = False  # record Fig 6/8 time series

    @property
    def gcs_enabled(self) -> bool:
        return self.gcs_limit is None or self.gcs_limit > 0


def _cfg(disk_limit, gcs_limit) -> HCDCConfig:
    c = HCDCConfig(gcs_limit=gcs_limit)
    c.sites = [
        SiteSpec("Site-1", 22.62e6, disk_limit),
        SiteSpec("Site-2", 62.35e6, disk_limit),
    ]
    return c


CONFIG_I = _cfg(None, 0.0)
CONFIG_II = _cfg(100 * TB, 0.0)
CONFIG_III = _cfg(100 * TB, None)


class _Job:
    __slots__ = ("fid", "submitted", "queued_at", "resolved")

    def __init__(self, fid: int, submitted: int):
        self.fid = fid
        self.submitted = submitted
        self.queued_at: Optional[int] = None
        self.resolved = False  # left the waiting queue out-of-band


class _SiteState:
    """Per-site runtime state over fixed file arrays."""

    def __init__(self, scenario: "HCDCScenario", spec: SiteSpec, rng):
        cfg = scenario.cfg
        n = cfg.n_files_per_site
        self.spec = spec
        self.site = Site(spec.name)
        self.tape = StorageElement(
            "TAPE", self.site,
            access_latency=cfg.tape_latency,
            latency_sampler=(
                (lambda r: float(np.clip(r.normal(cfg.tape_latency,
                                                  cfg.tape_latency_sigma), 0, 90 * MINUTE)))
                if cfg.tape_latency_sigma > 0 else None
            ),
        )
        self.disk = StorageElement("DISK", self.site, limit=spec.disk_limit)
        self.worker = StorageElement("WORKER", self.site)
        self.output = StorageElement("OUTPUT", self.site)
        # file attributes
        size_dist = BoundedExponential(cfg.size_lam, cfg.size_lo, cfg.size_hi, unit=GiB)
        self.sizes = size_dist.sample(rng, n)
        self.pop = cfg.popularity.sample_popularity(rng, n)
        self.popularity = cfg.popularity
        self.cum_w = cfg.popularity.selection_cdf(self.pop)
        self._cum_w_cache: Dict[float, np.ndarray] = {}
        # location state
        self.disk_state = np.zeros(n, dtype=np.int8)
        self.gcs_state = np.zeros(n, dtype=np.int8)
        self.consumers = np.zeros(n, dtype=np.int32)
        # bookkeeping
        self.window = SlidingWindow(spec.disk_limit)
        self.waiting: deque = deque()
        self.waiting_by_fid: Dict[int, List[_Job]] = {}
        self.jobs_for_fid: Dict[int, List[_Job]] = {}
        self.deletable: set = set()
        self.counters = FractionalCounter()
        # links
        self.l_tape_disk = NetworkLink(self.tape, self.disk,
                                       throughput=spec.tape_to_disk_mb_s,
                                       max_active=cfg.max_active)
        self.l_gcs_disk: Optional[NetworkLink] = None
        self.l_disk_gcs: Optional[NetworkLink] = None
        self.l_download = NetworkLink(self.disk, self.worker, throughput=cfg.download)
        # stats
        self.jobs_done = 0
        self.jobs_submitted = 0
        self.running = 0  # jobs between data-ready and completion
        self.download_bytes = 0.0
        self.tape_disk_bytes = 0.0
        self.gcs_disk_bytes = 0.0
        self.disk_gcs_bytes = 0.0
        self.gcs_recalls = np.zeros(n, dtype=np.int32)

    def select_file(self, u: float, power: Optional[float] = None) -> int:
        return int(np.searchsorted(self.cum_w_for(power), u, side="right"))

    def cum_w_for(self, power: Optional[float]) -> np.ndarray:
        """Selection CDF for a workload-scheduled popularity power.

        ``None`` keeps the precomputed base CDF (the stationary fast path);
        drifting workloads quantize the power into a handful of
        piecewise-constant values, so the cache stays tiny.
        """
        if power is None:
            return self.cum_w
        cw = self._cum_w_cache.get(power)
        if cw is None:
            cw = self.popularity.selection_cdf(self.pop, power=power)
            self._cum_w_cache[power] = cw
        return cw


class HCDCScenario:
    def __init__(self, cfg: HCDCConfig):
        self.cfg = cfg
        self.rng = np.random.default_rng(cfg.seed)
        self.sim = BaseSimulation(seed=cfg.seed)
        self.out = OutputCollector()
        self.svc = EventDrivenTransferService(self.sim, self.rng)
        gcs_site = Site("GCS")
        self.gcs = GCSBucket("BUCKET", gcs_site,
                             limit=(None if cfg.gcs_limit is None else cfg.gcs_limit),
                             cost_model=cfg.cost_model)
        self.sites = [_SiteState(self, s, self.rng) for s in cfg.sites]
        for st in self.sites:
            st.l_gcs_disk = NetworkLink(self.gcs, st.disk,
                                        throughput=cfg.gcs_to_disk,
                                        max_active=cfg.max_active)
            st.l_disk_gcs = NetworkLink(st.disk, self.gcs,
                                        throughput=cfg.disk_to_gcs,
                                        max_active=cfg.max_active)
        # Pre-sample job streams (throughput optimization; statistically
        # identical to per-tick sampling), then modulate them with the
        # workload schedule. The schedule draws no randomness and the
        # steady default multiplies by exactly 1.0, so the stationary
        # workload stays bit-identical to the pre-workload engine.
        n_ticks = cfg.simulated_time // cfg.gen_interval + 1
        self._job_counts = TruncatedNormalCount(cfg.jobs_mu, cfg.jobs_sigma).sample(
            self.rng, (len(self.sites), n_ticks))
        sched = cfg.workload.compile(n_ticks, cfg.gen_interval)
        self._job_counts = self._job_counts * sched.rate_mult
        self._sel_power = sched.sel_power
        self._dur_dist = BoundedExponential(cfg.dur_lam, lo=cfg.dur_lo)

    # ------------------------------------------------------------------ jobs
    def _submit_job(self, sim: BaseSimulation, now: int, st: _SiteState,
                    power: Optional[float] = None) -> None:
        fid = st.select_file(float(self.rng.random()), power)
        job = _Job(fid, now)
        st.jobs_submitted += 1
        st.consumers[fid] += 1
        st.deletable.discard(fid)
        ds = st.disk_state[fid]
        if ds == PRESENT:
            self._job_data_ready(sim, now, st, job)
        elif ds == IN_FLIGHT:
            st.jobs_for_fid.setdefault(fid, []).append(job)  # transferring
        else:
            if not self._try_start_input_transfer(sim, now, st, job):
                st.waiting.append(job)
                st.waiting_by_fid.setdefault(fid, []).append(job)

    def _try_start_input_transfer(self, sim: BaseSimulation, now: int,
                                  st: _SiteState, job: _Job) -> bool:
        """Allocate disk space + submit the tape/GCS -> disk transfer."""
        fid = job.fid
        if st.disk_state[fid] == PRESENT:
            self._job_data_ready(sim, now, st, job)
            return True
        if st.disk_state[fid] == IN_FLIGHT:
            st.jobs_for_fid.setdefault(fid, []).append(job)
            return True
        size = float(st.sizes[fid])
        if not st.disk.can_allocate(size):
            return False
        from_gcs = self.cfg.gcs_enabled and st.gcs_state[fid] == PRESENT
        link = st.l_gcs_disk if from_gcs else st.l_tape_disk
        file = File(fid, size, popularity=int(st.pop[fid]))
        st.disk_state[fid] = IN_FLIGHT
        st.jobs_for_fid.setdefault(fid, []).append(job)
        # All jobs waiting on this data enter the transferring state (paper
        # §5.2 'Waiting'): pull them from the FIFO out-of-band.
        for w in st.waiting_by_fid.pop(fid, []):
            if not w.resolved and w is not job:
                w.resolved = True
                st.jobs_for_fid[fid].append(w)

        def done(sim_, now_, t, st=st, fid=fid, from_gcs=from_gcs):
            st.disk_state[fid] = PRESENT
            if from_gcs:
                st.gcs_disk_bytes += t.file.size
                st.gcs_recalls[fid] += 1
            else:
                st.tape_disk_bytes += t.file.size
            for j in st.jobs_for_fid.pop(fid, []):
                self._job_data_ready(sim_, now_, st, j)
            if st.consumers[fid] == 0 and st.disk.limit is not None:
                st.deletable.add(fid)

        self.svc.submit(file, link, on_complete=done)
        return True

    def _gcs_off(self, st: _SiteState) -> int:
        """Global fid offset so the shared bucket keys files per site."""
        return self.sites.index(st) * self.cfg.n_files_per_site

    def _job_data_ready(self, sim: BaseSimulation, now: int,
                        st: _SiteState, job: _Job) -> None:
        """queued -> active -> running -> done, collapsed into one event.

        Downloads are unlimited-concurrency fixed-throughput and job slots
        are unlimited (paper §5.3), so no resource interaction happens
        between 'queued' and completion; the job finishes at
        now + size/download_rate + run_duration.
        """
        job.queued_at = now
        self.out.hist("job_waiting_h").record((now - job.submitted) / HOUR)
        size = float(st.sizes[job.fid])
        dl = size / self.cfg.download
        run = float(self._dur_dist.sample(self.rng))
        st.download_bytes += size
        st.l_download.traffic += size
        st.running += 1

        def finish(sim_, now_, st=st, fid=job.fid):
            st.jobs_done += 1
            st.running -= 1
            st.consumers[fid] -= 1
            if (st.consumers[fid] == 0 and st.disk_state[fid] == PRESENT
                    and st.disk.limit is not None):
                st.deletable.add(fid)

        sim.call_at(now + max(1, int(dl + run)), lambda s, n_: finish(s, n_))

    # ------------------------------------------------------------- deletions
    def _process_deletions(self, sim: BaseSimulation, now: int,
                           st: _SiteState) -> None:
        if st.disk.limit is None or not st.deletable:
            return
        gcs_on = self.cfg.gcs_enabled
        done_fids = []
        for fid in st.deletable:
            if st.consumers[fid] != 0 or st.disk_state[fid] != PRESENT:
                done_fids.append(fid)
                continue
            gfid = fid + self._gcs_off(st)
            if not gcs_on:
                st.disk.delete(fid)
                st.disk_state[fid] = ABSENT
                done_fids.append(fid)
                continue
            if st.gcs_state[fid] == PRESENT:
                st.disk.delete(fid)
                st.disk_state[fid] = ABSENT
                done_fids.append(fid)
            elif st.gcs_state[fid] == ABSENT:
                if not self.cfg.migration_policy.should_migrate(int(st.pop[fid])):
                    st.disk.delete(fid)
                    st.disk_state[fid] = ABSENT
                    done_fids.append(fid)
                    continue
                if not self.gcs.can_allocate(float(st.sizes[fid])):
                    continue  # cold tier full; retry next tick
                st.gcs_state[fid] = IN_FLIGHT
                file = File(gfid, float(st.sizes[fid]), popularity=int(st.pop[fid]))

                def migrated(sim_, now_, t, st=st, fid=fid):
                    st.gcs_state[fid] = PRESENT
                    st.disk_gcs_bytes += t.file.size
                    # delete the hot copy unless it is needed again
                    if st.consumers[fid] == 0 and st.disk_state[fid] == PRESENT:
                        st.disk.delete(fid)
                        st.disk_state[fid] = ABSENT

                self.svc.submit(file, st.l_disk_gcs, on_complete=migrated)
                done_fids.append(fid)
            else:
                done_fids.append(fid)  # migration already in flight
        for fid in done_fids:
            st.deletable.discard(fid)

    # --------------------------------------------------------------- waiting
    def _process_waiting(self, sim: BaseSimulation, now: int,
                         st: _SiteState) -> None:
        while st.waiting:
            job = st.waiting[0]
            if job.resolved:  # left out-of-band (transfer appeared for its data)
                st.waiting.popleft()
                continue
            if self._try_start_input_transfer(sim, now, st, job):
                st.waiting.popleft()
                job.resolved = True
            else:
                break  # strict FIFO for window space (paper §5.2)

    # ------------------------------------------------------------------ tick
    def _make_generator(self) -> Schedulable:
        scenario = self

        class Generator(Schedulable):
            def __init__(self) -> None:
                super().__init__(interval=scenario.cfg.gen_interval)
                self.tick = 0

            def on_update(self, sim: BaseSimulation, now: int) -> None:
                power = (None if scenario._sel_power is None
                         else float(scenario._sel_power[self.tick]))
                for i, st in enumerate(scenario.sites):
                    scenario._process_deletions(sim, now, st)
                    n = st.counters.emit(scenario._job_counts[i][self.tick])
                    for _ in range(n):
                        scenario._submit_job(sim, now, st, power)
                    scenario._process_waiting(sim, now, st)
                if scenario.cfg.curves and self.tick % 360 == 0:  # hourly
                    for st in scenario.sites:
                        scenario.out.ts(f"{st.spec.name}.disk_used").record(now, st.disk.used)
                        scenario.out.ts(f"{st.spec.name}.running_jobs").record(now, st.running)
                    scenario.out.ts("gcs_used").record(now, scenario.gcs.used)
                self.tick += 1

        return Generator()

    # ------------------------------------------------------------------- run
    def run(self) -> Dict[str, float]:
        self.sim.schedule(self._make_generator(), 0)
        self.sim.run(self.cfg.simulated_time)
        self.gcs.finalize(self.cfg.simulated_time)
        return self.metrics()

    def metrics(self) -> Dict[str, float]:
        m: Dict[str, float] = {
            "jobs_done": sum(st.jobs_done for st in self.sites),
            "jobs_submitted": sum(st.jobs_submitted for st in self.sites),
            "download_pb": sum(st.download_bytes for st in self.sites) / 1e15,
            "gcs_to_disk_pb": sum(st.gcs_disk_bytes for st in self.sites) / 1e15,
            "disk_to_gcs_pb": sum(st.disk_gcs_bytes for st in self.sites) / 1e15,
            "gcs_used_pb": self.gcs.used / 1e15,
            "job_waiting_h_mean": self.out.hist("job_waiting_h").mean,
        }
        for st in self.sites:
            m[f"{st.spec.name}.tape_to_disk_pb"] = st.tape_disk_bytes / 1e15
            m[f"{st.spec.name}.jobs_done"] = st.jobs_done
            m[f"{st.spec.name}.disk_used_pb"] = st.disk.used / 1e15
        for i, bill in enumerate(self.gcs.bills):
            m[f"month{i+1}.storage_usd"] = bill.storage_usd
            m[f"month{i+1}.network_usd"] = bill.network_usd
        return m


# Paper reference values (Tables 6/7/8) for benchmark comparison.
PAPER_TABLE6 = {
    "I": {"jobs_done": 996_000, "download_pb": 41.11},
    "II": {"jobs_done": 853_000, "download_pb": 35.28},
    "III": {"jobs_done": 996_000, "download_pb": 41.02},
}
PAPER_TABLE7 = {
    "I": {"Site-1.tape_to_disk_pb": 6.75, "Site-2.tape_to_disk_pb": 6.74},
    "II": {"Site-1.tape_to_disk_pb": 8.85, "Site-2.tape_to_disk_pb": 13.04},
    "III": {"Site-1.tape_to_disk_pb": 6.74, "Site-2.tape_to_disk_pb": 6.75,
            "gcs_to_disk_pb": 24.99},
}
PAPER_TABLE8 = {
    "month1.storage_usd": 82_000, "month1.network_usd": 330_000,
    "month2.storage_usd": 211_000, "month2.network_usd": 729_000,
    "month3.storage_usd": 293_000, "month3.network_usd": 807_000,
}


def make_config(name: str, **overrides) -> HCDCConfig:
    base = {"I": CONFIG_I, "II": CONFIG_II, "III": CONFIG_III}[name]
    cfg = replace(base, **overrides)
    # ``replace`` copies fields shallowly, so mutable sub-configs would be
    # shared with the module-level CONFIG_* constants — callers that tweak
    # e.g. ``cfg.sites[0].disk_limit`` (planner, sweep) would corrupt every
    # later run. Re-wrap any sub-config the caller did not supply.
    for attr in ("cost_model", "popularity", "migration_policy",
                 "cold_deletion_policy"):
        if attr not in overrides:
            setattr(cfg, attr, replace(getattr(cfg, attr)))
    if "sites" not in overrides:
        cfg.sites = [replace(s) for s in cfg.sites]
    return cfg
