"""Fused lane-blocked Pallas kernels for the batched sweep tick.

See ``lane_tick.py`` for the kernel design notes. Public wrappers:

- :func:`transfer_tick` — carousel transfer advance + completion
  classification + month-bucketed billing, fused per site block;
- :func:`gcs_admit` — the shared-GCS prefix-sum admission scan
  (``GCS_ADMIT_PASSES`` refinement passes as a sequential grid axis)
  fused with the GB-second storage integration;
- :func:`window_admit` — the [S, K]/[S, W] candidate-window prefix
  recurrences (non-blocking job window, strict-FIFO wait queue).
"""

from repro.kernels.lane_tick.lane_tick import (  # noqa: F401
    gcs_admit,
    transfer_tick,
    window_admit,
)
