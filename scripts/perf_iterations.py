"""§Perf hillclimb: hypothesis -> change -> re-lower -> measure.

Three cells (chosen per EXPERIMENTS.md §Perf):
  - hymba_1_5b  prefill_32k  (worst roofline fraction, memory-bound)
  - olmoe_1b_7b train_4k     (most collective-bound)
  - arctic_480b train_4k     (paper-representative: biggest data-intensive
                              training cell; memory + collective bound)

Each iteration re-runs the dry-run cell with a tagged plan override; the
EXPERIMENTS.md §Perf log interprets before/after.
"""

import os
import sys

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
sys.path.insert(0, "src")

from repro.launch.dryrun import run_cell

OUT = "results/dryrun"


def show(rec):
    if rec["status"] != "ok":
        print(f"  !! {rec['status']}: {rec.get('error','')[:200]}")
        return
    r = rec["roofline"]
    temp = (rec["memory"]["temp_bytes"] or 0) / 1e9
    coll = rec["collectives"]["per_kind"]
    ck = " ".join(f"{k}={v/1e9:.1f}GB" for k, v in sorted(coll.items()))
    print(f"  comp={r['compute_s']:8.3f}s mem={r['memory_s']:8.3f}s "
          f"coll={r['collective_s']:8.3f}s dom={r['dominant'][:-2]} "
          f"rf={r['roofline_fraction']:.4f} temp={temp:.1f}GB\n"
          f"  wire: {ck}")


RUNS = [
    # (arch, shape, tag, overrides, hypothesis-one-liner)
    ("hymba_1_5b", "prefill_32k", "it1_ssmchunk", {},
     "chunked SSM scan stops materializing [B,T,di,N]"),
    ("olmoe_1b_7b", "train_4k", "it1_micro4", {"microbatches": 4},
     "4x fewer grad-accum rounds -> grad all-reduce wire /4"),
    ("olmoe_1b_7b", "train_4k", "it2_micro4_bf16",
     {"microbatches": 4, "grad_accum_dtype": "bf16"},
     "bf16 accumulators halve remaining grad wire"),
    ("arctic_480b", "train_4k", "it1_micro4", {"microbatches": 4},
     "FSDP weight gathers amortize over 4x bigger microbatches"),
    ("arctic_480b", "train_4k", "it2_micro4_chunk",
     {"microbatches": 4, "attn_chunk_threshold": 2048},
     "chunked attention removes replicated 56-head score tensors"),
    ("arctic_480b", "train_4k", "it3_micro2_chunk_bf16",
     {"microbatches": 2, "attn_chunk_threshold": 2048,
      "grad_accum_dtype": "bf16"},
     "push further: 2 microbatches + bf16 accum"),
    ("hymba_1_5b", "prefill_32k", "it2_chunk2048",
     {"attn_chunk_threshold": 2048},
     "smaller attention chunks cut transient scores further"),
    ("olmoe_1b_7b", "train_4k", "it3_micro1_bf16",
     {"microbatches": 1, "grad_accum_dtype": "bf16"},
     "single batch: no accumulation at all (16 rows/device fit)"),
]


def main():
    only = sys.argv[1] if len(sys.argv) > 1 else None
    for arch, shape, tag, over, hyp in RUNS:
        if only and only not in tag and only not in arch:
            continue
        print(f"== {arch} {shape} [{tag}] — {hyp}")
        rec = run_cell(arch, shape, False, out_dir=OUT,
                       plan_overrides=over, tag=tag)
        show(rec)
        sys.stdout.flush()


if __name__ == "__main__":
    main()
