# Convenience targets; everything runs from the source tree (PYTHONPATH=src).

PY := PYTHONPATH=src python

.PHONY: test test-fast bench bench-smoke sweep-demo lint clean

test:
	$(PY) -m pytest -x -q

test-fast:
	$(PY) -m pytest -x -q -m "not slow"

bench:
	$(PY) benchmarks/run.py

bench-smoke:
	FAST=1 BENCH_JSON=BENCH_ci.json $(PY) benchmarks/run.py

# Tiny 2-workload grid (steady vs diurnal) on both sweep backends — the
# workload-subsystem smoke demo (docs/workloads.md).
sweep-demo:
	$(PY) scripts/run_sweep.py --days 0.1 --files 1000 --cache-tb 20 \
	    --workload steady --workload diurnal:amplitude=0.8 --quiet
	$(PY) scripts/run_sweep.py --days 0.1 --files 1000 --cache-tb 20 \
	    --workload steady --workload diurnal:amplitude=0.8 \
	    --backend jax --quiet

lint:
	ruff check src tests benchmarks scripts

# Remove interpreter droppings (bytecode caches shipped by accident break
# nothing but pollute diffs and wheels).
clean:
	find src tests benchmarks scripts examples -name __pycache__ -type d -prune -exec rm -rf {} + 2>/dev/null || true
	find src tests benchmarks scripts examples -name '*.pyc' -delete 2>/dev/null || true
	rm -rf .pytest_cache .ruff_cache
