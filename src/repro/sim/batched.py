"""Lane-per-scenario batched sweep backend (``run_sweep(..., backend="jax")``).

The event-driven reference engine (``repro.core.hcdc``) runs one scenario
per Python interpreter; the §5.3 decision workflow wants *grids* of
scenarios. This module runs an entire packed grid as **one** ``jit`` +
``vmap`` JAX program: lane ``l`` is one ``ScenarioSpec``, every lane steps
a shared fixed-tick clock, and per-lane transfer/link state advances
through the ``repro.kernels.carousel_update`` tick math (Pallas on TPU,
the jnp reference elsewhere). The paper's billing quantities — GCS
byte-seconds, tiered egress volume, class A/B operation counts — are
accumulated on device per 30-day month bucket and folded into the
existing ``GCSCostModel`` / ``MonthlyBill`` machinery on the way out, so
``backend="jax"`` returns the same ``SweepResult`` shape as the process
backend.

Workloads (``repro.sim.workload``): a spec's access-pattern model
compiles to a deterministic per-generator-tick rate/popularity schedule
that ``pack_specs`` folds into the packed per-lane job stream
(``jobs_per_tick``, ``job_*``; the multipliers are exported as
``PackedGrid.rate_mult``), so non-stationary arrival shapes ride through
this backend with zero device-program changes and the grid stays a single
jit+vmap program. Workload-differing specs get distinct dynamics lanes;
only pricing-only variants share one.

Fidelity contract (cross-validated in ``tests/test_batched.py``): the
packed grid replicates the reference engine's catalogue and job-arrival
randomness draw-for-draw, while per-job file selection and run durations
come from the continuation of the same per-lane stream; the fixed tick
quantizes event times by at most one ``dt``. Per-lane jobs-done and bill
totals therefore agree with the event-driven engine within the paper's
Table 2 validation tolerance rather than bitwise (see
``docs/simulation.md`` for when the two clocks can diverge).

Per-tick phase order mirrors the reference generator: transfer advance +
completions -> link-slot FIFO admission -> hot-tier deletions & hot->cold
migrations -> job submissions -> pending-job resolution -> waiting-queue
(disk window) FIFO admission -> storage integration.
"""

from __future__ import annotations

import functools
import time
from typing import TYPE_CHECKING, Callable, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.carousel_update.carousel_update import carousel_tick_pallas
from repro.kernels.carousel_update.ref import carousel_tick_ref
from repro.sim.cloud import bills_from_monthly_totals
from repro.sim.sweep import ScenarioResult, SweepResult

if TYPE_CHECKING:  # repro.core imports repro.sim; keep runtime acyclic
    from repro.core.scenarios import PackedGrid, ScenarioSpec

# File-location states; must match repro.core.hcdc.
ABSENT, IN_FLIGHT, PRESENT = 0, 1, 2

#: Disk-window (waiting queue) admissions attempted per site per tick. The
#: event engine admits any number per tick; bounding the vectorized window
#: is safe because arrivals are ~0.64 jobs/tick/site (Table 3), far below
#: it — a burst simply drains over the next few ticks.
WAIT_ADMITS_PER_TICK = 4

_INF = jnp.float32(jnp.inf)
_BIG_TICKET = jnp.int32(2 ** 30)


def _lane_step_fns(S: int, K: int, n_months: int, use_pallas: bool):
    """Build the per-lane tick body and post-scan reduction (closures over
    the static dimensions).

    Vectorization notes: the per-tick candidate sets (this tick's job
    arrivals, the waiting-queue window) are tiny, so their sequential
    semantics — later candidates see earlier reservations — are computed as
    unrolled scalar recurrences over K/W-vectors, and the results land in
    the big ``[S, F]`` state arrays through *one* scatter per array.
    Scatters use duplicate-safe combinators (``add`` of deltas, ``max``/
    ``min`` for flags) because the same file id can appear several times in
    a candidate window.
    """

    def tick_fn(state, xs, const):
        now, dt, month, t, jobs_now = xs
        (sizes, pop, job_fid, job_submit_tick, job_tail, disk_limit,
         gcs_enabled, gcs_limit, min_pop, bw, slots, latency, mode) = const
        F = sizes.shape[1]
        J = job_fid.shape[1]
        M = bw.shape[0]
        st = dict(state)
        site_rows = jnp.arange(S, dtype=jnp.int32)

        # -- consumer counts (jobs submitted strictly before this tick that
        # have not finished by ``now``; deletions run before submissions in
        # the reference generator, so this tick's arrivals are excluded).
        submitted = job_submit_tick < t
        finished = (st["job_ready"] < _INF) & \
            (st["job_ready"] + job_tail <= now)
        active_job = submitted & ~finished
        flat_fid = (job_fid + site_rows[:, None] * F)
        consumers = jax.ops.segment_sum(
            active_job.reshape(-1).astype(jnp.int32),
            flat_fid.reshape(-1), num_segments=S * F).reshape(S, F)

        # -- advance transfers one tick (the carousel hot-loop kernel) ----
        now_prev = now - dt
        t_active = st["tr_slot"] & (st["tr_start"] <= now_prev + 0.5)
        tick = carousel_tick_pallas if use_pallas else carousel_tick_ref
        new_done, completed, _ = tick(
            st["tr_link"].reshape(-1), t_active.reshape(-1),
            st["tr_done"].reshape(-1), st["tr_total"].reshape(-1),
            bw, mode, dt)
        comp = completed.reshape(S, F)
        new_done = new_done.reshape(S, F)
        ltype = st["tr_link"] % 3  # 0 tape->disk, 1 gcs->disk, 2 disk->gcs
        comp_tape = comp & (ltype == 0)
        comp_recall = comp & (ltype == 1)
        comp_mig = comp & (ltype == 2)
        inbound = comp_tape | comp_recall

        st["disk_state"] = jnp.where(inbound, PRESENT, st["disk_state"])
        st["tape_b"] += jnp.sum(sizes * comp_tape, axis=1)
        st["gcsdisk_b"] += jnp.sum(sizes * comp_recall, axis=1)
        recall_bytes = jnp.sum(sizes * comp_recall)
        st["egress_mo"] = st["egress_mo"].at[month].add(recall_bytes)
        st["cls_b_mo"] = st["cls_b_mo"].at[month].add(
            jnp.sum(comp_recall).astype(jnp.float32))
        st["gcs_state"] = jnp.where(comp_mig, PRESENT, st["gcs_state"])
        st["diskgcs_b"] += jnp.sum(sizes * comp_mig, axis=1)
        st["cls_a_mo"] = st["cls_a_mo"].at[month].add(
            jnp.sum(comp_mig).astype(jnp.float32))
        # migrated with no remaining consumer: drop the hot copy now
        drop_hot = comp_mig & (consumers == 0) & (st["disk_state"] == PRESENT)
        st["disk_used"] -= jnp.sum(sizes * drop_hot, axis=1)
        st["disk_state"] = jnp.where(drop_hot, ABSENT, st["disk_state"])
        st["tr_slot"] = st["tr_slot"] & ~comp
        st["tr_done"] = jnp.where(comp, 0.0, new_done)
        st["tr_total"] = jnp.where(comp, _INF, st["tr_total"])
        st["tr_start"] = jnp.where(comp, _INF, st["tr_start"])

        # -- link-slot FIFO admission (tickets are contiguous per link) ---
        occ = jnp.zeros((M,), jnp.float32).at[st["tr_link"].reshape(-1)].add(
            st["tr_slot"].reshape(-1).astype(jnp.float32))
        free = jnp.maximum(slots - occ, 0.0)
        n_q = (st["lq_next"] - st["lq_serve"]).astype(jnp.float32)
        admit = jnp.minimum(free, n_q).astype(jnp.int32)
        new_serve = st["lq_serve"] + admit
        adm_row = st["lq_queued"] & \
            (st["lq_ticket"] < new_serve[st["tr_link"]])
        st["tr_slot"] = st["tr_slot"] | adm_row
        st["tr_start"] = jnp.where(adm_row, now + latency[st["tr_link"]],
                                   st["tr_start"])
        st["lq_queued"] = st["lq_queued"] & ~adm_row
        st["lq_serve"] = new_serve
        occ = occ + admit.astype(jnp.float32)

        # -- hot-tier deletions + hot->cold migrations --------------------
        limited = jnp.isfinite(disk_limit)[:, None]
        cand = (consumers == 0) & (st["disk_state"] == PRESENT) & limited
        gs = st["gcs_state"]
        migratable = gcs_enabled & (gs == ABSENT) & (pop >= min_pop)
        delete = cand & (~gcs_enabled | (gs == PRESENT)
                         | ((gs == ABSENT) & ~(pop >= min_pop)))
        want_mig = cand & migratable
        # shared GCS capacity is consumed site-sequentially (only the
        # scalar offset is sequential; the mask algebra stays vectorized).
        # The reference admits every *individually* fitting file (a too-big
        # candidate is skipped, not head-blocking): a cumulative-prefix
        # gate refined over a few passes approximates that greedy scan —
        # each pass admits the next fitting run past a blocker.
        migs = []
        gcs_used = st["gcs_used"]
        for s in range(S):
            admitted = jnp.zeros((F,), bool)
            for _ in range(3):
                rem = want_mig[s] & ~admitted
                csum = jnp.cumsum(sizes[s] * rem)
                new = rem & (gcs_used + csum <= gcs_limit)
                gcs_used = gcs_used + jnp.sum(sizes[s] * new)
                admitted = admitted | new
            migs.append(admitted)
        mig = jnp.stack(migs)
        st["gcs_used"] = gcs_used
        st["gcs_state"] = jnp.where(mig, IN_FLIGHT, gs)
        st["disk_used"] -= jnp.sum(sizes * delete, axis=1)
        st["disk_state"] = jnp.where(delete, ABSENT, st["disk_state"])
        # submit migrations on each site's disk->gcs link (FIFO: direct
        # slots only while the link queue is empty, overflow queues)
        mlink = 3 * site_rows + 2  # [S]
        rank = jnp.cumsum(mig.astype(jnp.float32), axis=1) - 1.0
        q_empty = (st["lq_next"][mlink] == st["lq_serve"][mlink])[:, None]
        free_m = jnp.maximum(slots[mlink] - occ[mlink], 0.0)[:, None]
        direct = mig & q_empty & (rank < free_m)
        queued = mig & ~direct
        qrank = jnp.cumsum(queued.astype(jnp.int32), axis=1) - 1
        st["tr_slot"] = st["tr_slot"] | direct
        st["tr_link"] = jnp.where(mig, mlink[:, None], st["tr_link"])
        st["tr_total"] = jnp.where(mig, sizes, st["tr_total"])
        st["tr_done"] = jnp.where(mig, 0.0, st["tr_done"])
        st["tr_start"] = jnp.where(direct, now, st["tr_start"])
        st["lq_ticket"] = jnp.where(
            queued, st["lq_next"][mlink][:, None] + qrank, st["lq_ticket"])
        st["lq_queued"] = st["lq_queued"] | queued
        st["lq_next"] = st["lq_next"].at[mlink].add(
            jnp.sum(queued, axis=1).astype(jnp.int32))
        occ = occ.at[mlink].add(jnp.sum(direct, axis=1).astype(jnp.float32))

        # =================================================================
        # Candidate-window planning. This tick's job arrivals (K per site)
        # and the waiting-queue heads (W per site) are tiny windows; their
        # sequential semantics — later candidates see earlier reservations
        # — run as scalar prefix recurrences on gathered vectors, and every
        # resulting state change is DEFERRED and applied below as a single
        # duplicate-safe scatter per array (scatter passes over the big
        # [S, F] state dominate the tick cost).
        # =================================================================
        W = WAIT_ADMITS_PER_TICK
        plans = []  # per group: dict of planned per-candidate vectors

        def plan_links(s, fids, fire, occ):
            """Assign link slots / FIFO queue tickets to fired candidates.

            Mutates only the small [M] occupancy/ticket counters; returns
            the per-candidate plan (direct slot, queue ticket, start time).
            """
            from_gcs = gcs_enabled & (st["gcs_state"][s, fids] == PRESENT)
            link_local = jnp.where(from_gcs, 1, 0)
            direct = jnp.zeros_like(fire)
            queued = jnp.zeros_like(fire)
            tstart = jnp.full(fire.shape, jnp.inf, jnp.float32)
            lq_val = jnp.zeros(fire.shape, jnp.int32)
            for loc in (0, 1):  # tape->disk, gcs->disk
                m = 3 * s + loc
                mask = fire & (link_local == loc)
                q_empty = st["lq_next"][m] == st["lq_serve"][m]
                free_m = jnp.maximum(slots[m] - occ[m], 0.0)
                rk = jnp.cumsum(mask.astype(jnp.float32)) - 1.0
                d = mask & q_empty & (rk < free_m)
                qd = mask & ~d
                qrk = jnp.cumsum(qd.astype(jnp.int32)) - 1
                direct = direct | d
                queued = queued | qd
                tstart = jnp.where(d, now + latency[m], tstart)
                lq_val = jnp.where(qd, st["lq_next"][m] + qrk, lq_val)
                st["lq_next"] = st["lq_next"].at[m].add(
                    jnp.sum(qd).astype(jnp.int32))
                occ = occ.at[m].add(jnp.sum(d).astype(jnp.float32))
            return occ, dict(rows=s * F + fids, fire=fire,
                             m_vec=3 * s + link_local, direct=direct,
                             queued=queued, tstart=tstart, lq_val=lq_val)

        # -- group 1: job submissions for this tick (only the first arrival
        # of a file starts its transfer; later same-tick jobs attach) -----
        if K > 0:
            ks = jnp.arange(K, dtype=jnp.int32)
            for s in range(S):
                jid = jnp.minimum(st["ptr"][s] + ks, J - 1)
                valid = (st["ptr"][s] + ks < J) & \
                    (job_submit_tick[s, jid] == t)
                fids = job_fid[s, jid]
                same = (fids[None, :] == fids[:, None]) & valid[None, :] \
                    & (ks[None, :] < ks[:, None])
                first = valid & ~jnp.any(same, axis=1)
                size = sizes[s, fids]
                ds = st["disk_state"][s, fids]
                ww = st["wq_wait"][s, fids]
                absent = first & (ds == ABSENT)
                started_list = []
                extra = jnp.float32(0.0)
                for k in range(K):  # scalar prefix recurrence, K is tiny
                    fit = st["disk_used"][s] + extra + size[k] \
                        <= disk_limit[s]
                    st_k = absent[k] & fit
                    started_list.append(st_k)
                    extra = extra + jnp.where(st_k, size[k], 0.0)
                started = jnp.stack(started_list)
                st["disk_used"] = st["disk_used"].at[s].add(extra)
                to_wait = absent & ~started & ~ww
                wrank = jnp.cumsum(to_wait.astype(jnp.int32)) - 1
                occ, plan = plan_links(s, fids, started, occ)
                plan["to_wait"] = to_wait
                plan["wq_val"] = jnp.where(to_wait,
                                           st["wq_next"][s] + wrank, 0)
                st["wq_next"] = st["wq_next"].at[s].add(
                    jnp.sum(to_wait).astype(jnp.int32))
                plan["stale"] = jnp.zeros_like(started)
                plans.append(plan)
        st["ptr"] = st["ptr"] + jobs_now

        # -- group 2: waiting-queue admission — strict FIFO on the disk
        # window; the head blocks admission until its file fits (§5.2).
        # Planned from the pre-scatter queue state: entries started above
        # (queue-jump) are excluded by fid comparison; entries enqueued
        # above are not yet visible (they join next tick, matching a tail
        # position in the FIFO).
        sub_started = [jnp.where(p["fire"], p["rows"], -1) for p in plans]
        for s in range(S):
            tickets = jnp.where(st["wq_wait"][s], st["wq_ticket"][s],
                                _BIG_TICKET)
            neg, idx = jax.lax.top_k(-tickets, W)  # W lowest tickets
            validw = (neg > -_BIG_TICKET)
            rows = s * F + idx
            jumped = jnp.zeros(idx.shape, bool)
            for started_rows in sub_started:
                jumped = jumped | jnp.any(
                    rows[:, None] == started_rows[None, :], axis=1)
            ds = st["disk_state"][s, idx]
            stale = validw & ((ds != ABSENT) | jumped)
            size = sizes[s, idx]
            adm_list = []
            extra = jnp.float32(0.0)
            blocked = jnp.asarray(False)
            for k in range(W):
                fit = st["disk_used"][s] + extra + size[k] <= disk_limit[s]
                live = validw[k] & ~stale[k]
                adm = live & fit & ~blocked
                blocked = blocked | (live & ~fit)
                adm_list.append(adm)
                extra = extra + jnp.where(adm, size[k], 0.0)
            admitted = jnp.stack(adm_list)
            st["disk_used"] = st["disk_used"].at[s].add(extra)
            occ, plan = plan_links(s, idx, admitted, occ)
            plan["to_wait"] = jnp.zeros_like(admitted)
            plan["wq_val"] = jnp.zeros(idx.shape, jnp.int32)
            plan["stale"] = stale
            plans.append(plan)

        # -- pending jobs whose input is on disk enter queued -> running;
        # completion is analytic (ready + download + duration). Planned
        # starts only flip ABSENT -> IN_FLIGHT, so the pre-scatter
        # disk_state is PRESENT-accurate here. ----------------------------
        pending = (job_submit_tick <= t) & (st["job_ready"] >= _INF)
        on_disk = jnp.take_along_axis(st["disk_state"], job_fid,
                                      axis=1) == PRESENT
        st["job_ready"] = jnp.where(pending & on_disk, now, st["job_ready"])

        # -- apply the planned windows: one scatter per state array -------
        if plans:
            rows = jnp.concatenate([p["rows"] for p in plans])
            fire = jnp.concatenate([p["fire"] for p in plans])
            to_wait = jnp.concatenate([p["to_wait"] for p in plans])
            stale = jnp.concatenate([p["stale"] for p in plans])
            wq_val = jnp.concatenate([p["wq_val"] for p in plans])
            m_vec = jnp.concatenate([p["m_vec"] for p in plans])
            direct = jnp.concatenate([p["direct"] for p in plans])
            queued = jnp.concatenate([p["queued"] for p in plans])
            tstart = jnp.concatenate([p["tstart"] for p in plans])
            lq_val = jnp.concatenate([p["lq_val"] for p in plans])
            size_c = sizes.reshape(-1)[rows]

            def flat(name, update):
                st[name] = update(st[name].reshape(-1)).reshape(S, F)

            cur_link = st["tr_link"].reshape(-1)[rows]
            cur_lqt = st["lq_ticket"].reshape(-1)[rows]
            cur_wqt = st["wq_ticket"].reshape(-1)[rows]
            flat("disk_state", lambda a: a.at[rows].add(
                jnp.where(fire, IN_FLIGHT - ABSENT, 0)))
            # started/stale entries leave the wait queue; new waiters join
            flat("wq_wait", lambda a: a.at[rows].min(~(fire | stale)))
            flat("wq_wait", lambda a: a.at[rows].max(to_wait))
            flat("wq_ticket", lambda a: a.at[rows].add(
                jnp.where(to_wait, wq_val - cur_wqt, 0)))
            flat("tr_link", lambda a: a.at[rows].add(
                jnp.where(fire, m_vec - cur_link, 0)))
            flat("tr_total", lambda a: a.at[rows].min(
                jnp.where(fire, size_c, _INF)))
            flat("tr_slot", lambda a: a.at[rows].max(direct))
            flat("tr_start", lambda a: a.at[rows].min(tstart))
            flat("lq_ticket", lambda a: a.at[rows].add(
                jnp.where(queued, lq_val - cur_lqt, 0)))
            flat("lq_queued", lambda a: a.at[rows].max(queued))

        # -- integrate stored cloud volume (GB-seconds) per month ---------
        st["gbsec_mo"] = st["gbsec_mo"].at[month].add(
            st["gcs_used"] / 1e9 * dt)
        return st, None

    def post_fn(st, lane, horizon):
        (sizes, job_fid, job_submit_time, job_tail) = lane
        ready = st["job_ready"] < _INF
        done = ready & (st["job_ready"] + job_tail <= horizon)
        job_sizes = jnp.take_along_axis(sizes, job_fid, axis=1)
        wait_h = (st["job_ready"] - job_submit_time) / 3600.0
        return {
            "jobs_done_site": jnp.sum(done, axis=1),
            "download_b": jnp.sum(job_sizes * ready, axis=1),
            "wait_h_sum": jnp.sum(jnp.where(ready, wait_h, 0.0)),
            "wait_n": jnp.sum(ready),
            "disk_used": st["disk_used"],
            "gcs_used": st["gcs_used"],
            "tape_b": st["tape_b"],
            "gcsdisk_b": st["gcsdisk_b"],
            "diskgcs_b": st["diskgcs_b"],
            "egress_mo": st["egress_mo"],
            "cls_a_mo": st["cls_a_mo"],
            "cls_b_mo": st["cls_b_mo"],
            "gbsec_mo": st["gbsec_mo"],
        }

    return tick_fn, post_fn


@functools.lru_cache(maxsize=16)
def _grid_program(S: int, K: int, n_months: int, use_pallas: bool):
    """The jitted lane-vmapped simulation (cached per static shape family;
    XLA additionally retraces per concrete array shape)."""
    tick_fn, post_fn = _lane_step_fns(S, K, n_months, use_pallas)

    def lane_sim(times, dts, month_idx, t_idx, horizon,
                 disk_limit, gcs_enabled, gcs_limit, min_pop,
                 bw, slots, latency, mode, sizes, pop,
                 job_fid, job_submit_tick, job_submit_time, job_tail,
                 jobs_per_tick):
        F = sizes.shape[1]
        J = job_fid.shape[1]
        M = bw.shape[0]
        const = (sizes, pop, job_fid, job_submit_tick, job_tail,
                 disk_limit, gcs_enabled, gcs_limit, min_pop,
                 bw, slots, latency, mode)
        init = dict(
            disk_state=jnp.zeros((S, F), jnp.int32),
            gcs_state=jnp.zeros((S, F), jnp.int32),
            disk_used=jnp.zeros((S,), jnp.float32),
            gcs_used=jnp.float32(0.0),
            tr_slot=jnp.zeros((S, F), bool),
            tr_link=jnp.zeros((S, F), jnp.int32),
            tr_done=jnp.zeros((S, F), jnp.float32),
            tr_total=jnp.full((S, F), jnp.inf, jnp.float32),
            tr_start=jnp.full((S, F), jnp.inf, jnp.float32),
            lq_ticket=jnp.zeros((S, F), jnp.int32),
            lq_queued=jnp.zeros((S, F), bool),
            lq_serve=jnp.zeros((M,), jnp.int32),
            lq_next=jnp.zeros((M,), jnp.int32),
            wq_wait=jnp.zeros((S, F), bool),
            wq_ticket=jnp.zeros((S, F), jnp.int32),
            wq_next=jnp.zeros((S,), jnp.int32),
            job_ready=jnp.full((S, J), jnp.inf, jnp.float32),
            ptr=jnp.zeros((S,), jnp.int32),
            tape_b=jnp.zeros((S,), jnp.float32),
            gcsdisk_b=jnp.zeros((S,), jnp.float32),
            diskgcs_b=jnp.zeros((S,), jnp.float32),
            egress_mo=jnp.zeros((n_months,), jnp.float32),
            cls_a_mo=jnp.zeros((n_months,), jnp.float32),
            cls_b_mo=jnp.zeros((n_months,), jnp.float32),
            gbsec_mo=jnp.zeros((n_months,), jnp.float32),
        )
        final, _ = jax.lax.scan(
            lambda c, xs: tick_fn(c, xs, const), init,
            (times, dts, month_idx, t_idx, jobs_per_tick))
        return post_fn(final, (sizes, job_fid, job_submit_time, job_tail),
                       horizon)

    lane_axes = (None, None, None, None, None,  # shared tick grid
                 0, 0, 0, 0, 0, 0, 0, 0, 0, 0, 0, 0, 0, 0, 0)
    return jax.jit(jax.vmap(lane_sim, in_axes=lane_axes))


def simulate_packed(grid: "PackedGrid", use_pallas: Optional[bool] = None):
    """Run a packed grid on device; returns the raw per-lane aggregate dict
    (numpy arrays, lane-leading)."""
    if use_pallas is None:
        use_pallas = jax.default_backend() == "tpu"
    program = _grid_program(len(grid.site_names), grid.max_jobs_per_tick,
                            grid.n_months, bool(use_pallas))
    T = grid.n_ticks
    out = program(
        jnp.asarray(grid.times), jnp.asarray(grid.dts),
        jnp.asarray(grid.month_idx), jnp.arange(T, dtype=jnp.int32),
        jnp.float32(grid.horizon),
        jnp.asarray(grid.disk_limit), jnp.asarray(grid.gcs_enabled),
        jnp.asarray(grid.gcs_limit), jnp.asarray(grid.min_migrate_pop),
        jnp.asarray(grid.link_bw), jnp.asarray(grid.link_slots),
        jnp.asarray(grid.link_latency), jnp.asarray(grid.link_mode),
        jnp.asarray(grid.sizes), jnp.asarray(grid.pop),
        jnp.asarray(grid.job_fid), jnp.asarray(grid.job_submit_tick),
        jnp.asarray(grid.job_submit_time), jnp.asarray(grid.job_tail),
        jnp.asarray(grid.jobs_per_tick))
    return {k: np.asarray(v) for k, v in out.items()}


def _lane_result(grid: "PackedGrid", out: dict, si: int,
                 wall_s: float) -> ScenarioResult:
    """Fold one spec's dynamics-lane aggregates into a ``ScenarioResult``
    with the same metric keys the event-driven ``HCDCScenario.metrics``
    emits. Several specs may share one simulated lane (pricing-only
    variants); each is billed with its own cost model."""
    spec = grid.specs[si]
    li = int(grid.lane_of[si])
    names = grid.site_names
    jobs_done_site = out["jobs_done_site"][li]
    m = {
        "jobs_done": float(jobs_done_site.sum()),
        "jobs_submitted": float(grid.n_jobs[li].sum()),
        "download_pb": float(out["download_b"][li].sum()) / 1e15,
        "gcs_to_disk_pb": float(out["gcsdisk_b"][li].sum()) / 1e15,
        "disk_to_gcs_pb": float(out["diskgcs_b"][li].sum()) / 1e15,
        "gcs_used_pb": float(out["gcs_used"][li]) / 1e15,
        "job_waiting_h_mean": (float(out["wait_h_sum"][li])
                               / max(float(out["wait_n"][li]), 1.0)),
    }
    for s, name in enumerate(names):
        m[f"{name}.tape_to_disk_pb"] = float(out["tape_b"][li, s]) / 1e15
        m[f"{name}.jobs_done"] = float(jobs_done_site[s])
        m[f"{name}.disk_used_pb"] = float(out["disk_used"][li, s]) / 1e15
    bills = bills_from_monthly_totals(
        grid.cost_models[si], out["gbsec_mo"][li], out["egress_mo"][li],
        out["cls_a_mo"][li], out["cls_b_mo"][li], grid.full_months)
    for i, bill in enumerate(bills):
        m[f"month{i+1}.storage_usd"] = bill.storage_usd
        m[f"month{i+1}.network_usd"] = bill.network_usd
    return ScenarioResult(
        spec=spec,
        metrics=m,
        storage_usd=sum(b.storage_usd for b in bills),
        network_usd=sum(b.network_usd for b in bills),
        ops_usd=sum(b.ops_usd for b in bills),
        wall_s=wall_s,
        events=grid.n_ticks,
    )


def run_sweep_jax(specs: Sequence["ScenarioSpec"], tick: float = 10.0,
                  progress: Optional[Callable] = None,
                  use_pallas: Optional[bool] = None) -> SweepResult:
    """Execute a spec grid as one batched on-device program.

    Returns a ``SweepResult`` interchangeable with the process backend's
    (``events`` reports simulation ticks instead of event-loop pops, and
    per-config ``wall_s`` is the batch wall time split evenly). Specs that
    differ only in pricing (egress option, storage price) share one
    simulated dynamics lane and are billed separately.
    """
    from repro.core.scenarios import pack_specs

    t0 = time.perf_counter()
    grid = pack_specs(specs, tick=tick)
    out = simulate_packed(grid, use_pallas=use_pallas)
    wall = time.perf_counter() - t0
    results: List[ScenarioResult] = []
    for si in range(grid.n_specs):
        results.append(_lane_result(grid, out, si, wall / grid.n_specs))
        if progress is not None:
            progress(si + 1, grid.n_specs, results[-1])
    return SweepResult(results=results, wall_s=wall)
