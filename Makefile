# Convenience targets; everything runs from the source tree (PYTHONPATH=src).

PY := PYTHONPATH=src python

.PHONY: test test-fast bench bench-baseline bench-smoke bench-fleet \
	sweep-demo decide-demo crash-soak lint clean

test:
	$(PY) -m pytest -x -q

test-fast:
	$(PY) -m pytest -x -q -m "not slow"

# Full-scale benchmarks. BENCH_JSON defaults to BENCH_4.json for local
# trajectory tracking; note the *committed* BENCH_4.json is smoke-scale
# (fast=true, what CI compares against) — refresh it with
# `make bench-baseline`, not `make bench`, or the CI diff will fail on
# the scale mismatch.
bench:
	BENCH_JSON=$${BENCH_JSON:-BENCH_4.json} $(PY) benchmarks/run.py

# Regenerate the committed perf baseline at the CI smoke scale.
bench-baseline:
	FAST=1 BENCH_JSON=BENCH_4.json $(PY) benchmarks/run.py

# Full worker-fleet lane-scaling panel (1024/10k lanes x workers axis,
# docs/distributed.md) + the bitwise parity gate; regenerates the
# committed BENCH_fleet.json. Takes several minutes.
bench-fleet:
	$(PY) benchmarks/bench_fleet.py --json BENCH_fleet.json

# Exit code 4 = baseline missing (skip with a note); 3 = scale mismatch
# and 1 = regression both still fail (scripts/check_bench_regression.py).
bench-smoke:
	FAST=1 BENCH_JSON=BENCH_ci.json $(PY) benchmarks/run.py
	$(PY) scripts/check_bench_regression.py BENCH_4.json BENCH_ci.json || \
	    { ec=$$?; if [ $$ec -eq 4 ]; then \
	        echo "bench-diff: no baseline, comparison skipped"; \
	    else exit $$ec; fi; }

# Tiny 2-workload grid (steady vs diurnal) on both sweep backends — the
# workload-subsystem smoke demo (docs/workloads.md).
sweep-demo:
	$(PY) scripts/run_sweep.py --days 0.1 --files 1000 --cache-tb 20 \
	    --workload steady --workload diurnal:amplitude=0.8 --quiet
	$(PY) scripts/run_sweep.py --days 0.1 --files 1000 --cache-tb 20 \
	    --workload steady --workload diurnal:amplitude=0.8 \
	    --backend jax --quiet

# Decision-layer smoke demo (docs/decision.md): coarse 2-round adaptive
# refinement + displaced-disk and break-even solves on the batched
# backend, then the decision points re-run on the event-driven backend
# (--cross-check) so both engines vouch for the recommendation. Runs
# through a persistent result cache (docs/simulation.md, 'Result cache'):
# a repeated invocation simulates zero lanes and answers from disk.
decide-demo:
	$(PY) scripts/decide.py --days 0.1 --files 1000 --cache-tb 5,20,80 \
	    --storage-price '' --egress internet,direct --max-rounds 2 \
	    --cache-dir results/decide_cache \
	    --metrics-out results/decide_metrics.prom \
	    --trace-out results/decide_trace.json \
	    --cross-check --quiet --json results/decide_demo.json

# Resilience soak (docs/resilience.md): SIGKILL a checkpointed sweep
# mid-run and resume it, then run a sweep to completion under
# deterministic crash/hang/transient/corrupt injection. Nightly CI runs
# this; locally it takes ~1 minute.
crash-soak:
	$(PY) scripts/crash_soak.py

lint:
	ruff check src tests benchmarks scripts
	python scripts/check_docs.py

# Remove interpreter droppings (bytecode caches shipped by accident break
# nothing but pollute diffs and wheels).
clean:
	find src tests benchmarks scripts examples -name __pycache__ -type d -prune -exec rm -rf {} + 2>/dev/null || true
	find src tests benchmarks scripts examples -name '*.pyc' -delete 2>/dev/null || true
	rm -rf .pytest_cache .ruff_cache
