"""Benchmark: scenario-sweep engine throughput (configs/sec, lanes/sec).

Part 1 times the event-driven reference engine on the same reduced-scale
grid twice — serially in-process and through the process pool — so the
derived column shows absolute configs/sec and the parallel speedup.

Part 2 times the batched lane-per-scenario JAX backend
(``run_sweep(..., backend="jax")``) on a pricing-heavy §5.3 decision grid
(cache sizes x egress options x storage prices x seeds). Pricing axes are
billing-only, so the packed grid simulates one dynamics lane per
(cache, seed) point and bills every pricing variant from it. The jax rows
report both configs/sec (completed configurations, including the pricing
fan-out) and raw simulated lanes/sec; ``sweep.jax_speedup`` compares
batched configs/sec (warm, after the one-off XLA compile reported
separately as ``cold``) against the process pool measured on an
evenly-sampled subset of the *same* grid.

Part 3 is the lane-scaling panel (``sweep.jax.lane_scaling.<N>lane``):
simulated lanes/sec at 16/64/256-lane grids, executed through the
bounded-memory ``lane_chunk`` path so every grid size reuses one compiled
chunk program. CI diffs the warm/lanes-per-sec rows against the committed
``BENCH_4.json`` baseline (``scripts/check_bench_regression.py``).

Part 4 is the workload-sensitivity panel: one batched grid sweeping the
``repro.sim.workload`` access-pattern axis on a fixed cache point. Each
``sweep.workload.<model>`` row's derived column is that model's jobs-done
relative to the stationary baseline — how much the access-stream *shape*
(day/night cycles, reprocessing bursts, popularity drift) moves the
paper's throughput observable at unchanged mean pricing knobs.

Part 5 drives the decision layer (``repro.sim.decide``) end-to-end on the
pricing grid: adaptive frontier refinement plus the displaced-disk and
break-even solves. ``sweep.decide.lane_fraction`` tracks refinement lane
efficiency vs an equivalent-resolution dense grid and
``sweep.decide.displaced_tb`` the headline displaced-capacity figure.

Part 6 is the persistent result cache (``repro.sim.cache``, ISSUE 6):
the pricing grid swept cold (empty cache directory, every lane simulated
and stored) and then warm through a *fresh* ``SweepDriver`` (every config
served from disk, zero lanes simulated). ``sweep.cache.warm``'s derived
column is the cold/warm wall-time ratio — the acceptance bar is >= 5x.

Part 7 is the telemetry-overhead row (``sweep.obs.overhead``, ISSUE 8):
the same warm pricing grid with the metrics registry enabled vs disabled,
interleaved min-of-3. Its derived column is the enabled/disabled wall
ratio; the acceptance bar is < 1.05 (< 5% of warm throughput).

Part 8 is the resilience-overhead row (``sweep.resilience.overhead``,
the fault-tolerant jobs layer of ``repro.sim.jobs``): the warm pricing
grid executed through the job registry (retries enabled, no faults
injected) vs the plain path, both at the same ``lane_chunk`` so only the
registry bookkeeping differs. Its derived column is the jobs/plain wall
ratio; the acceptance bar is < 1.05 (docs/resilience.md).

Spawned pool workers are pinned to ``JAX_PLATFORMS=cpu`` by
``run_sweep``'s worker initializer, so the process rows cannot hang
probing accelerator devices while this process holds them.
"""

from __future__ import annotations

import argparse
import os
import time
from typing import Dict, List, Optional

from repro.core.scenarios import (ScenarioSpec, dynamics_key, expand_grid,
                                  with_seeds)
from repro.sim.sweep import SweepDriver, run_sweep

#: Clock step (seconds) for the batched-backend throughput rows. Coarser
#: than the 10 s generator interval: the per-tick fixed cost dominates
#: batched wall time on CPU, and
#: ``test_batched.test_jax_backend_tick_coarsening_stays_close`` pins this
#: exact tick within 2%/5% (jobs/cost) of the 10 s clock.
JAX_BENCH_TICK = 60.0


def _cps(res) -> float:
    """``configs_per_sec`` as a number: the floor makes it ``None`` on
    sub-millisecond walls, which a derived column reports as 0."""
    return res.configs_per_sec or 0.0


def _grid(n_configs: int, days: float, n_files: int):
    cache = [20.0, 50.0, 100.0]
    egress = ["internet", "direct", "interconnect"]
    specs = expand_grid({"base": "III", "days": days, "n_files": n_files,
                         "cache_tb": cache, "egress": egress})
    seeds = max(1, -(-n_configs // len(specs)))  # ceil
    return with_seeds(specs, seeds)[:n_configs]


def _pricing_grid(days: float, n_files: int, n_prices: int, n_seeds: int):
    """§5.3 decision grid: 4 cache points x 3 egress x N storage prices
    x seeds. Dynamics lanes = 4 x seeds; the rest is billing fan-out."""
    prices = [round(0.018 + 0.002 * i, 3) for i in range(n_prices)]
    specs = expand_grid({"base": "III", "days": days, "n_files": n_files,
                         "cache_tb": [10.0, 20.0, 40.0, 80.0],
                         "egress": ["internet", "direct", "interconnect"],
                         "storage_price": prices})
    return with_seeds(specs, n_seeds)


#: Workload-sensitivity panel: the stationary baseline plus one
#: representative of each non-stationary family (docs/workloads.md).
#: Periods are short so the bench's sub-day horizon covers whole waves
#: (a 24 h period would pin the horizon inside the first peak phase).
WORKLOAD_PANEL = (
    "steady",
    "diurnal:amplitude=0.8,period_h=1.2",
    "campaign:period_h=1.2,duty=0.25,peak=3,off=0.5",
    "zipf-drift:power_end=1.5",
)


#: Fixed chunk size for the lane-scaling rows: every grid size reuses the
#: same compiled chunk program, so the scaling panel pays one XLA compile
#: and the rows measure pure execution throughput.
LANE_SCALING_CHUNK = 16


def _lane_scaling_rows(days: float, n_files: int,
                       lane_counts: List[int]) -> List[Dict]:
    """``sweep.jax.lane_scaling.<N>lane``: simulated dynamics lanes/sec at
    growing grid sizes, executed through the bounded-memory lane-chunked
    path (ISSUE 4). Each lane is a distinct seed, so nothing dedupes."""
    rows = []
    for n in lane_counts:
        specs = with_seeds([ScenarioSpec(base="III", days=days,
                                         n_files=n_files, cache_tb=20.0)], n)
        # Absorb the compile with the full grid itself: a sliced warm-up
        # can bucket K/J to a smaller power of two and leave an XLA
        # recompile inside the timed run. After the first grid size, the
        # shapes usually hit the cache and this run is nearly free.
        run_sweep(specs, backend="jax", tick=JAX_BENCH_TICK,
                  lane_chunk=LANE_SCALING_CHUNK)
        warm = run_sweep(specs, backend="jax", tick=JAX_BENCH_TICK,
                         lane_chunk=LANE_SCALING_CHUNK)
        rows.append({"name": f"sweep.jax.lane_scaling.{n}lane",
                     "us_per_call": warm.wall_s / n * 1e6,
                     "derived": n / warm.wall_s if warm.wall_s > 0 else 0.0})
    return rows


def _decide_rows(days: float, n_files: int, n_prices: int,
                 fast: bool) -> List[Dict]:
    """``sweep.decide.*``: the decision workflow driven end-to-end on the
    bench pricing grid (ISSUE 5). ``lane_fraction`` is the adaptive
    refinement's simulated-lane count relative to an equivalent-resolution
    dense grid (lower is better; the acceptance bar is <= 0.5, asserted in
    ``tests/test_decide.py``); ``displaced_tb`` is the headline quantity —
    on-prem disk displaced by the recommended cloud cache."""
    from repro.sim.decide import decide

    prices = [round(0.018 + 0.002 * i, 3) for i in range(n_prices)]
    axes = {"base": "III", "days": days, "n_files": n_files,
            "cache_tb": [10.0, 20.0, 40.0, 80.0],
            "egress": ["internet", "direct", "interconnect"],
            "storage_price": prices}
    g = 4 * 3 * n_prices * 2  # configs incl. pricing fan-out, 2 seeds
    driver = SweepDriver(backend="jax", tick=JAX_BENCH_TICK)
    t0 = time.perf_counter()
    report = decide(axes, driver, n_seeds=2,
                    max_rounds=2 if fast else 3)
    wall = time.perf_counter() - t0
    ref = report.refine
    return [
        {"name": f"sweep.decide.workflow.{g}cfg",
         "us_per_call": wall / g * 1e6,
         "derived": driver.configs_run / wall if wall > 0 else 0.0},
        {"name": f"sweep.decide.lane_fraction.{ref.lanes_used}of"
                 f"{ref.dense_lanes}",
         "us_per_call": wall * 1e6,
         "derived": ref.lane_fraction},
        {"name": "sweep.decide.displaced_tb",
         "us_per_call": wall * 1e6,
         "derived": report.displaced.displaced_tb
         if report.displaced.min_cache_tb is not None else 0.0},
    ]


def _cache_rows(days: float, n_files: int, n_prices: int) -> List[Dict]:
    """``sweep.cache.{cold,warm}``: the pricing grid through a
    tempdir-backed persistent result cache (ISSUE 6). Cold simulates and
    stores every dynamics lane; warm drives a *fresh* ``SweepDriver``
    (empty memo — only the on-disk store answers) and must simulate zero
    lanes. ``sweep.cache.warm``'s derived column is the cold/warm speedup
    (acceptance: >= 5x)."""
    import shutil
    import tempfile

    specs = _pricing_grid(days, n_files, n_prices=n_prices, n_seeds=2)
    tmp = tempfile.mkdtemp(prefix="bench_sweep_cache_")
    try:
        cold_drv = SweepDriver(backend="jax", tick=JAX_BENCH_TICK, cache=tmp)
        t0 = time.perf_counter()
        cold_drv.run(specs)
        cold_wall = time.perf_counter() - t0
        warm_drv = SweepDriver(backend="jax", tick=JAX_BENCH_TICK, cache=tmp)
        t0 = time.perf_counter()
        warm = warm_drv.run(specs)
        warm_wall = time.perf_counter() - t0
        if warm.lanes_simulated:
            raise RuntimeError(
                f"warm cache re-run simulated {warm.lanes_simulated} lanes "
                "(expected 0) — the result cache is not serving")
    finally:
        shutil.rmtree(tmp, ignore_errors=True)
    g = len(specs)
    return [
        {"name": f"sweep.cache.cold.{g}cfg",
         "us_per_call": cold_wall / g * 1e6,
         "derived": g / cold_wall if cold_wall > 0 else 0.0},
        {"name": f"sweep.cache.warm.{g}cfg",
         "us_per_call": warm_wall / g * 1e6,
         "derived": cold_wall / warm_wall if warm_wall > 0 else 0.0},
    ]


def _obs_overhead_rows(jspecs: List[ScenarioSpec]) -> List[Dict]:
    """``sweep.obs.overhead``: warm batched sweeps with the telemetry
    registry enabled vs disabled (ISSUE 8), interleaved min-of-3 so OS
    noise cancels. The derived column is enabled/disabled wall — the
    acceptance bar is < 1.05 (telemetry costs < 5% of warm throughput).
    The compile is already absorbed by the caller's warm run."""
    from repro.obs.metrics import get_registry

    reg = get_registry()
    on = off = float("inf")
    try:
        for _ in range(3):
            reg.disable()
            t0 = time.perf_counter()
            run_sweep(jspecs, backend="jax", tick=JAX_BENCH_TICK)
            off = min(off, time.perf_counter() - t0)
            reg.enable()
            t0 = time.perf_counter()
            run_sweep(jspecs, backend="jax", tick=JAX_BENCH_TICK)
            on = min(on, time.perf_counter() - t0)
    finally:
        reg.enable()
    return [{"name": f"sweep.obs.overhead.{len(jspecs)}cfg",
             "us_per_call": on / len(jspecs) * 1e6,
             "derived": on / off if off > 0 else 0.0}]


def _resilience_overhead_rows(jspecs: List[ScenarioSpec]) -> List[Dict]:
    """``sweep.resilience.overhead``: warm batched sweeps through the
    fault-tolerant jobs layer (registry + per-chunk journaling hooks,
    retries enabled, zero faults injected) vs the plain path, interleaved
    min-of-3 so OS noise cancels. Both sides use the same ``lane_chunk``
    so the chunked program is identical and only the job-registry
    bookkeeping differs. The derived column is jobs/plain wall — the
    acceptance bar is < 1.05 (resilience costs < 5% of warm throughput
    when nothing fails, docs/resilience.md). Display-only: tracked in
    the nightly summary, not the bench-smoke regression gate."""
    from repro.sim.jobs import RetryPolicy

    chunk = 2
    run_sweep(jspecs, backend="jax", tick=JAX_BENCH_TICK,
              lane_chunk=chunk)  # absorb the chunked-program compile
    plain = jobs = float("inf")
    for _ in range(3):
        t0 = time.perf_counter()
        run_sweep(jspecs, backend="jax", tick=JAX_BENCH_TICK,
                  lane_chunk=chunk)
        plain = min(plain, time.perf_counter() - t0)
        t0 = time.perf_counter()
        run_sweep(jspecs, backend="jax", tick=JAX_BENCH_TICK,
                  lane_chunk=chunk, retry=RetryPolicy())
        jobs = min(jobs, time.perf_counter() - t0)
    return [{"name": f"sweep.resilience.overhead.{len(jspecs)}cfg",
             "us_per_call": jobs / len(jspecs) * 1e6,
             "derived": jobs / plain if plain > 0 else 0.0}]


def _workload_rows(days: float, n_files: int) -> List[Dict]:
    specs = expand_grid({"base": "III", "days": days, "n_files": n_files,
                         "cache_tb": 20.0, "workload": list(WORKLOAD_PANEL)})
    res = run_sweep(specs, backend="jax", tick=JAX_BENCH_TICK)
    by = {r.spec.workload: r for r in res.results}
    steady_jobs = max(by["steady"].jobs_done, 1.0)
    rows = [
        {"name": f"sweep.workload.{wl.partition(':')[0]}",
         "us_per_call": res.wall_s / len(specs) * 1e6,
         "derived": by[wl].jobs_done / steady_jobs}
        for wl in WORKLOAD_PANEL
    ]
    rows.append({"name": f"sweep.workload.batch.{len(specs)}cfg",
                 "us_per_call": res.wall_s * 1e6,
                 "derived": _cps(res)})
    return rows


def run(n_configs: int = 8, days: float = 0.25, n_files: int = 4000,
        workers: Optional[int] = None, fast: bool = False) -> List[Dict]:
    specs = _grid(n_configs, days, n_files)
    workers = workers or min(len(specs), os.cpu_count() or 1)
    serial = run_sweep(specs, workers=1)
    par = run_sweep(specs, workers=workers)
    events = sum(r.events for r in serial.results)
    rows = [
        {"name": f"sweep.serial.{len(specs)}cfg",
         "us_per_call": serial.wall_s / len(specs) * 1e6,
         "derived": _cps(serial)},
        {"name": f"sweep.parallel{workers}.{len(specs)}cfg",
         "us_per_call": par.wall_s / len(specs) * 1e6,
         "derived": _cps(par)},
        {"name": "sweep.speedup",
         "us_per_call": par.wall_s * 1e6,
         "derived": serial.wall_s / par.wall_s if par.wall_s > 0 else 0.0},
        {"name": "sweep.events_per_sec_serial",
         "us_per_call": serial.wall_s * 1e6,
         "derived": events / serial.wall_s if serial.wall_s > 0 else 0.0},
    ]

    # -- batched (jax) backend vs the process pool on one decision grid --
    jdays, jfiles = (0.1, 1000) if fast else (0.25, 1000)
    jspecs = _pricing_grid(jdays, jfiles,
                           n_prices=3 if fast else 9, n_seeds=2)
    n_sub = 8 if fast else 24
    stride = max(1, len(jspecs) // n_sub)
    subset = jspecs[::stride][:n_sub]
    # dynamics-lane count for the row label (the pack-time dedup rule:
    # pricing-only fields do not change the simulated dynamics)
    n_lanes = len({dynamics_key(s) for s in jspecs})
    cold = run_sweep(jspecs, backend="jax", tick=JAX_BENCH_TICK)
    warm = run_sweep(jspecs, backend="jax", tick=JAX_BENCH_TICK)
    base = run_sweep(subset, workers=workers)
    warm_cps = _cps(warm)  # configs/sec (lanes x pricing fan-out)
    base_cps = _cps(base)
    g = len(jspecs)
    rows += [
        {"name": f"sweep.jax.cold.{g}cfg{n_lanes}lane",
         "us_per_call": cold.wall_s / g * 1e6,
         "derived": _cps(cold)},
        {"name": f"sweep.jax.warm.{g}cfg{n_lanes}lane",
         "us_per_call": warm.wall_s / g * 1e6,
         "derived": warm_cps},
        {"name": f"sweep.jax.lanes_per_sec.{n_lanes}lane",
         "us_per_call": warm.wall_s / n_lanes * 1e6,
         "derived": n_lanes / warm.wall_s if warm.wall_s > 0 else 0.0},
        {"name": f"sweep.jax.process_baseline.{len(subset)}cfg",
         "us_per_call": base.wall_s / len(subset) * 1e6,
         "derived": base_cps},
        {"name": "sweep.jax_speedup",
         "us_per_call": warm.wall_s * 1e6,
         "derived": warm_cps / base_cps if base_cps > 0 else 0.0},
    ]

    # -- fused Pallas tick kernels (ISSUE 7), interpret mode on CPU --
    # Plumbing/overhead measurement, not a speed claim (see
    # bench_tick_engine.py's row-naming note): tick.pallas.* rows must
    # stay out of the bench-smoke regression gate's default rows.
    run_sweep(jspecs, backend="jax", tick=JAX_BENCH_TICK,
              tick_impl="pallas_interpret")  # absorb the compile
    pallas_warm = run_sweep(jspecs, backend="jax", tick=JAX_BENCH_TICK,
                            tick_impl="pallas_interpret")
    rows += [
        {"name": f"tick.pallas.sweep_warm.{g}cfg{n_lanes}lane",
         "us_per_call": pallas_warm.wall_s / g * 1e6,
         "derived": _cps(pallas_warm)},
        # derived = interpret-mode wall / jnp wall on the identical warm
        # grid (values > 1 mean the interpreter overhead, expected on CPU)
        {"name": "tick.pallas.sweep_vs_jnp",
         "us_per_call": pallas_warm.wall_s * 1e6,
         "derived": pallas_warm.wall_s / warm.wall_s
         if warm.wall_s > 0 else 0.0},
    ]
    rows += _obs_overhead_rows(jspecs)
    rows += _resilience_overhead_rows(jspecs)
    rows += _lane_scaling_rows(0.1, jfiles,
                               [16, 64] if fast else [16, 64, 256])
    rows += _workload_rows(jdays, jfiles)
    rows += _decide_rows(jdays, jfiles, n_prices=3 if fast else 9,
                         fast=fast)
    rows += _cache_rows(jdays, jfiles, n_prices=3 if fast else 9)
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--configs", type=int, default=8)
    ap.add_argument("--days", type=float, default=0.25)
    ap.add_argument("--files", type=int, default=4000)
    ap.add_argument("--workers", type=int, default=None)
    ap.add_argument("--fast", action="store_true")
    args = ap.parse_args()
    for r in run(args.configs, args.days, args.files, args.workers,
                 fast=args.fast):
        print(f"{r['name']},{r['us_per_call']:.0f},{r['derived']:.4g}")


if __name__ == "__main__":
    main()
