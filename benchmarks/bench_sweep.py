"""Benchmark: batched scenario-sweep engine throughput (configs/sec).

Times the same reduced-scale config grid twice — serially in-process and
through the process pool — so the derived column shows both absolute
configs/sec and the parallel speedup the sweep engine buys on this machine.
"""

from __future__ import annotations

import argparse
import os
from typing import Dict, List, Optional

from repro.core.scenarios import expand_grid, with_seeds
from repro.sim.sweep import run_sweep


def _grid(n_configs: int, days: float, n_files: int):
    cache = [20.0, 50.0, 100.0]
    egress = ["internet", "direct", "interconnect"]
    specs = expand_grid({"base": "III", "days": days, "n_files": n_files,
                         "cache_tb": cache, "egress": egress})
    seeds = max(1, -(-n_configs // len(specs)))  # ceil
    return with_seeds(specs, seeds)[:n_configs]


def run(n_configs: int = 8, days: float = 0.25, n_files: int = 4000,
        workers: Optional[int] = None) -> List[Dict]:
    specs = _grid(n_configs, days, n_files)
    workers = workers or min(len(specs), os.cpu_count() or 1)
    serial = run_sweep(specs, workers=1)
    par = run_sweep(specs, workers=workers)
    events = sum(r.events for r in serial.results)
    rows = [
        {"name": f"sweep.serial.{len(specs)}cfg",
         "us_per_call": serial.wall_s / len(specs) * 1e6,
         "derived": serial.configs_per_sec},
        {"name": f"sweep.parallel{workers}.{len(specs)}cfg",
         "us_per_call": par.wall_s / len(specs) * 1e6,
         "derived": par.configs_per_sec},
        {"name": "sweep.speedup",
         "us_per_call": par.wall_s * 1e6,
         "derived": serial.wall_s / par.wall_s if par.wall_s > 0 else 0.0},
        {"name": "sweep.events_per_sec_serial",
         "us_per_call": serial.wall_s * 1e6,
         "derived": events / serial.wall_s if serial.wall_s > 0 else 0.0},
    ]
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--configs", type=int, default=8)
    ap.add_argument("--days", type=float, default=0.25)
    ap.add_argument("--files", type=int, default=4000)
    ap.add_argument("--workers", type=int, default=None)
    args = ap.parse_args()
    for r in run(args.configs, args.days, args.files, args.workers):
        print(f"{r['name']},{r['us_per_call']:.0f},{r['derived']:.4g}")


if __name__ == "__main__":
    main()
