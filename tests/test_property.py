"""Property-based tests (hypothesis) on system invariants."""

import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="dev extra: pip install -r requirements-dev.txt")

from hypothesis import given, settings, strategies as st

from repro.core.carousel import SlidingWindow
from repro.sim.cloud import GCSCostModel
from repro.sim.distributions import (
    BoundedExponential,
    FractionalCounter,
    TruncatedNormalCount,
)
from repro.sim.infrastructure import GiB


@given(st.lists(st.floats(0, 100, allow_nan=False), min_size=1, max_size=500))
def test_fractional_counter_long_run_rate(xs):
    """Emitted integer total differs from the real-valued total by < 1."""
    c = FractionalCounter()
    emitted = sum(c.emit(x) for x in xs)
    assert abs(emitted - sum(xs)) < 1.0


@given(st.floats(0.001, 5.0), st.floats(0.0, 1.0), st.floats(1.5, 100.0),
       st.integers(0, 2**31 - 1))
def test_bounded_exponential_within_bounds(lam, lo, hi, seed):
    d = BoundedExponential(lam, lo, hi)
    rng = np.random.default_rng(seed)
    x = d.sample(rng, 100)
    assert (x >= lo).all() and (x <= hi).all()


@given(st.floats(0.05, 3.0), st.floats(0.01, 2.0), st.integers(0, 2**31 - 1))
@settings(max_examples=25)
def test_truncated_normal_fractional_carry_preserves_rate(mu, sigma, seed):
    """The generator pipeline — truncated-normal count samples emitted
    through the fractional-remainder carry — preserves the long-run rate:
    total integer emissions track both the sampled total (within the one
    carried fraction) and the analytic clamped-normal mean."""
    d = TruncatedNormalCount(mu, sigma)
    rng = np.random.default_rng(seed)
    xs = d.sample(rng, 4000)
    counter = FractionalCounter()
    emitted = sum(counter.emit(x) for x in xs)
    assert abs(emitted - xs.sum()) < 1.0  # only the carry is ever pending
    assert 0.0 <= counter.acc < 1.0
    # long-run emission rate ~ the distribution mean (law of large numbers
    # bound: generous 5 sigma / sqrt(n) envelope keeps flakiness ~zero)
    assert abs(emitted / len(xs) - d.mean) \
        <= 5.0 * max(sigma, 0.05) / np.sqrt(len(xs)) + 1.0 / len(xs)


@given(st.floats(0.001, 5.0), st.floats(0.0, 1.0), st.floats(1.5, 100.0),
       st.sampled_from([1.0, 1e6, GiB]), st.integers(0, 2**31 - 1))
def test_bounded_exponential_clamps_scaled_by_unit(lam, lo, hi, unit, seed):
    """Samples always land in [lo, hi] x unit — the clamp applies before
    the unit scaling (sizes are drawn in GiB and stored in bytes)."""
    d = BoundedExponential(lam, lo, hi, unit=unit)
    rng = np.random.default_rng(seed)
    x = d.sample(rng, 200)
    assert (x >= lo * unit).all() and (x <= hi * unit).all()
    scalar = d.sample(rng)  # n=None: scalar draw obeys the same clamp
    assert lo * unit <= scalar <= hi * unit


@given(st.floats(0.01, 3.0), st.floats(0.01, 2.0), st.integers(0, 2**31 - 1))
@settings(max_examples=25)
def test_truncated_normal_mean_formula(mu, sigma, seed):
    d = TruncatedNormalCount(mu, sigma)
    rng = np.random.default_rng(seed)
    emp = d.sample(rng, 30_000).mean()
    assert abs(emp - d.mean) < 0.05 * max(d.mean, 0.1)


@given(st.lists(st.tuples(st.integers(0, 30), st.floats(1, 100)),
                min_size=1, max_size=100),
       st.floats(50, 500))
def test_sliding_window_never_exceeds_limit(ops, limit):
    w = SlidingWindow(limit)
    allocated = {}
    for key, size in ops:
        if key in allocated:
            w.release(key)
            del allocated[key]
        else:
            if w.allocate(key, size):
                allocated[key] = size
        assert w.used <= limit + 1e-9
        assert abs(w.used - sum(allocated.values())) < 1e-6
    for key in list(allocated):
        w.release(key)
    assert abs(w.used) < 1e-6  # float accumulation drift only


@given(st.floats(1e6, 1e17))
@settings(max_examples=50)
def test_egress_cost_monotone_and_tiered(nbytes):
    cm = GCSCostModel()
    c1 = cm.egress_cost(nbytes)
    c2 = cm.egress_cost(nbytes * 1.5)
    assert c2 >= c1 >= 0
    # effective rate never exceeds the top tier price and never drops
    # below the bottom tier price
    rate = c1 / (nbytes / GiB)
    assert 0.08 - 1e-9 <= rate <= 0.12 + 1e-9


@given(st.integers(1, 400), st.integers(1, 12), st.integers(0, 2**31 - 1),
       st.floats(0.5, 20.0))
@settings(max_examples=30, deadline=None)
def test_carousel_kernel_matches_ref_property(n, m, seed, dt):
    import jax.numpy as jnp
    from repro.kernels.carousel_update.ops import carousel_tick

    rng = np.random.default_rng(seed)
    link_id = jnp.asarray(rng.integers(0, m, n), jnp.int32)
    active = jnp.asarray(rng.random(n) < 0.5)
    total = jnp.asarray(rng.exponential(1e8, n).astype(np.float32) + 1e3)
    done = jnp.asarray(rng.random(n).astype(np.float32)) * total
    bw = jnp.asarray(rng.uniform(1e3, 1e7, m).astype(np.float32))
    mode = jnp.asarray(rng.integers(0, 2, m), jnp.int32)
    k = carousel_tick(link_id, active, done, total, bw, mode, float(dt),
                      tick_impl="pallas_interpret")
    r = carousel_tick(link_id, active, done, total, bw, mode, float(dt),
                      tick_impl="jnp")
    np.testing.assert_allclose(k[0], r[0], rtol=1e-4)
    assert bool((k[1] == r[1]).all())


@given(st.integers(2, 64), st.integers(1, 16))
@settings(max_examples=20)
def test_elastic_planner_divisibility(chips, tp_pow):
    from repro.ckpt.failover import ElasticPlanner

    tp = min(tp_pow, chips)
    planner = ElasticPlanner(model_tp=tp)
    plan = planner.plan(chips, global_batch=256)
    assert plan.model == tp
    assert plan.data >= 1
    assert plan.devices <= max(chips, tp)
    assert 256 % max(plan.data * plan.pods, 1) == 0 or plan.data == 1


# ---------------------------------------------------------------------------
# Decision-layer properties (ISSUE 5): interval-overlap frontier membership
# is subset-monotone, and adaptive refinement never drops an evaluated
# point that a dense grid over the same resolved levels would keep on its
# frontier.
# ---------------------------------------------------------------------------

def _decision_points(data):
    from repro.core.scenarios import ScenarioSpec
    from repro.sim.decide import summarize
    from repro.sim.sweep import ScenarioResult

    n_pts = data.draw(st.integers(3, 12))
    n_seeds = data.draw(st.integers(1, 4))
    results = []
    for i in range(n_pts):
        spec = ScenarioSpec(base="III", days=0.1, n_files=100,
                            cache_tb=float(i + 1))
        for s in range(n_seeds):
            jobs = data.draw(st.floats(0, 1000, allow_nan=False))
            cost = data.draw(st.floats(0, 500, allow_nan=False))
            results.append(ScenarioResult(
                spec=spec.__class__(**{**spec.to_dict(), "seed": s}),
                metrics={"jobs_done": jobs}, storage_usd=cost,
                network_usd=0.0, ops_usd=0.0, wall_s=0.0, events=0))
    return summarize(results)


@given(st.data())
@settings(max_examples=60, deadline=None)
def test_ci_frontier_subset_monotone_property(data):
    """For A ⊆ B: ci_frontier(B) ∩ A ⊆ ci_frontier(A). Removing points can
    only remove dominators, never create one — so a refinement that
    evaluates a subset of a dense grid can never discard a point the dense
    grid would keep."""
    from repro.sim.decide import ci_frontier

    points = _decision_points(data)
    mask = [data.draw(st.booleans()) for _ in points]
    subset = [p for p, keep in zip(points, mask) if keep]
    full_front = ci_frontier(points)
    sub_front = ci_frontier(subset)
    for p in full_front:
        if p in subset:
            assert p in sub_front


@given(st.floats(5.0, 40.0), st.floats(10.0, 60.0),
       st.floats(0.0, 10.0), st.integers(1, 3))
@settings(max_examples=15, deadline=None)
def test_refinement_never_drops_dense_frontier_point(jobs_tau, cost_tau,
                                                     seed_spread, n_seeds):
    """Refinement on a random monotone synthetic cost model: every
    evaluated point that the dense grid over the refinement's resolved
    levels keeps on its frontier is on the refined frontier too."""
    import math as _math

    from repro.core.scenarios import expand_grid, with_seeds
    from repro.sim.decide import ci_frontier, refine_frontier, summarize
    from repro.sim.sweep import ScenarioResult, SweepResult

    def jobs_fn(s):
        c = s.cache_tb if s.cache_tb is not None else 100.0
        return 1000.0 * (1 - _math.exp(-c / jobs_tau)) \
            + seed_spread * (s.seed % 3)

    def cost_fn(s):
        c = s.cache_tb if s.cache_tb is not None else 100.0
        return 15.0 + 150.0 * _math.exp(-c / cost_tau)

    def evaluate(specs):
        return SweepResult(results=[ScenarioResult(
            spec=s, metrics={"jobs_done": jobs_fn(s)},
            storage_usd=cost_fn(s), network_usd=0.0, ops_usd=0.0,
            wall_s=0.0, events=0) for s in specs])

    axes = {"base": "III", "days": 0.1, "n_files": 100,
            "cache_tb": [5.0, 20.0, 40.0, 80.0]}
    res = refine_frontier(axes, evaluate, ("cache_tb",), n_seeds=n_seeds,
                          rel_tol=0.05, max_rounds=4)
    dense_axes = dict(axes)
    dense_axes["cache_tb"] = res.axis_levels["cache_tb"]
    dense = summarize(evaluate(
        with_seeds(expand_grid(dense_axes), n_seeds)).results)
    dense_front = {p.spec for p in ci_frontier(dense)}
    evaluated = {p.spec for p in res.points}
    refined_front = {p.spec for p in res.frontier}
    assert dense_front & evaluated <= refined_front


# ---------------------------------------------------------------------------
# Result-cache key semantics (ISSUE 6): the content address must be a pure
# function of the dynamics identity — invariant under pricing-only changes,
# distinct for any dynamics-affecting change. (Restart stability is covered
# by a subprocess test in tests/test_cache.py.)
# ---------------------------------------------------------------------------

#: Valid value pools per ScenarioSpec field (chosen to satisfy
#: ``__post_init__`` validation, not to be exhaustive).
_SPEC_POOLS = {
    "base": ["I", "II", "III"],
    "days": [0.1, 0.25, 1.0, 2.0],
    "n_files": [100, 1000, 20_000],
    "seed": [0, 1, 2, 7],
    "cache_tb": [None, 5.0, 20.0, 80.0],
    "gcs_limit_tb": [None, 0.0, 50.0],
    "egress": ["internet", "direct", "interconnect"],
    "storage_price": [None, 0.018, 0.026],
    "egress_price": [None, 0.0, 0.05],
    "job_rate_scale": [0.5, 1.0, 2.0],
    "workload": ["steady", "diurnal", "zipf-drift"],
    "curves": [False, True],
}

_DYNAMICS_FIELDS = sorted(set(_SPEC_POOLS) -
                          {"egress", "storage_price", "egress_price"})


@st.composite
def _spec_strategy(draw):
    from repro.core.scenarios import ScenarioSpec

    return ScenarioSpec(**{name: draw(st.sampled_from(pool))
                           for name, pool in _SPEC_POOLS.items()})


@given(st.data())
@settings(max_examples=60, deadline=None)
def test_cache_key_invariant_under_pricing_only_changes(data):
    """Repricing any subset of the PRICING_FIELDS never moves the content
    address: pricing variants share one stored dynamics lane."""
    from dataclasses import replace

    from repro.core.scenarios import PRICING_FIELDS, cache_key

    spec = data.draw(_spec_strategy())
    repriced = replace(spec, **{f: data.draw(st.sampled_from(_SPEC_POOLS[f]))
                                for f in PRICING_FIELDS})
    assert cache_key(repriced) == cache_key(spec)
    assert cache_key(repriced, "jax", 60.0) == cache_key(spec, "jax", 60.0)


@given(st.data())
@settings(max_examples=60, deadline=None)
def test_cache_key_collides_iff_dynamics_identical(data):
    """Two independently drawn specs share a key exactly when their
    dynamics identities coincide — no accidental collisions, no spurious
    misses, for either engine fingerprint."""
    from repro.core.scenarios import cache_key, dynamics_key

    a, b = data.draw(_spec_strategy()), data.draw(_spec_strategy())
    same_dynamics = dynamics_key(a) == dynamics_key(b)
    assert (cache_key(a) == cache_key(b)) == same_dynamics
    assert (cache_key(a, "jax", 60.0) == cache_key(b, "jax", 60.0)) \
        == same_dynamics
    # engines never collide with each other regardless of the spec pair
    assert cache_key(a, "process") != cache_key(b, "jax", 60.0)


@given(st.data())
@settings(max_examples=60, deadline=None)
def test_cache_key_sensitive_to_every_dynamics_field(data):
    """Mutating any single dynamics-affecting field to a different valid
    value always produces a fresh content address."""
    from dataclasses import replace

    from repro.core.scenarios import cache_key

    spec = data.draw(_spec_strategy())
    field = data.draw(st.sampled_from(_DYNAMICS_FIELDS))
    alternatives = [v for v in _SPEC_POOLS[field]
                    if v != getattr(spec, field)]
    mutated = replace(spec, **{field: data.draw(st.sampled_from(alternatives))})
    assert cache_key(mutated) != cache_key(spec), field
    assert cache_key(mutated, "jax", 60.0) != cache_key(spec, "jax", 60.0)


# ---------------------------------------------------- retry backoff (ISSUE 9)
_backoff_policies = st.builds(
    lambda base, mult, cap, jit, seed: __import__(
        "repro.sim.jobs", fromlist=["RetryPolicy"]).RetryPolicy(
            max_attempts=10, base_delay_s=base, multiplier=mult,
            max_delay_s=cap, jitter=jit, seed=seed),
    st.floats(0.0, 10.0, allow_nan=False),
    st.floats(1.0, 8.0, allow_nan=False),
    st.floats(0.0, 100.0, allow_nan=False),
    st.floats(0.0, 2.0, allow_nan=False),
    st.integers(0, 2**31 - 1),
)


@given(_backoff_policies, st.text(min_size=0, max_size=24))
@settings(max_examples=80, deadline=None)
def test_retry_backoff_bounded_monotone_reproducible(policy, job_id):
    """The resilience layer's backoff guarantees, over the whole policy
    space: every delay lands in [0, max_delay_s], each job's delay
    sequence is monotone non-decreasing in the attempt number (the
    jitter term is per job, not per attempt), and the sequence is
    bitwise-reproducible from the policy parameters alone."""
    delays = [policy.delay_s(job_id, a) for a in range(1, 13)]
    assert all(0.0 <= d <= policy.max_delay_s for d in delays)
    assert all(b >= a for a, b in zip(delays, delays[1:]))
    from repro.sim.jobs import RetryPolicy

    clone = RetryPolicy(max_attempts=10, base_delay_s=policy.base_delay_s,
                        multiplier=policy.multiplier,
                        max_delay_s=policy.max_delay_s,
                        jitter=policy.jitter, seed=policy.seed)
    assert [clone.delay_s(job_id, a) for a in range(1, 13)] == delays


@given(st.integers(0, 2**31 - 1), st.floats(0.0, 0.33), st.floats(0.0, 0.33),
       st.floats(0.0, 0.33), st.integers(1, 3),
       st.lists(st.text(min_size=1, max_size=12), min_size=1, max_size=30))
@settings(max_examples=60, deadline=None)
def test_fault_plan_draws_deterministic_and_exclusive(seed, crash, hang,
                                                      transient, attempts,
                                                      job_ids):
    """Fault directives are a pure function of (seed, job, attempt), at
    most one kind fires per attempt, and nothing injects past the
    ``attempts`` gate — the convergence-under-retry property the
    end-to-end bitwise tests rest on."""
    from repro.sim.faults import FaultPlan

    plan = FaultPlan(seed=seed, crash=crash, hang=hang,
                     transient=transient, attempts=attempts)
    for job_id in job_ids:
        for attempt in range(1, attempts + 2):
            d1 = plan.directive(job_id, (), attempt)
            d2 = plan.directive(job_id, (), attempt)
            assert d1 == d2
            if attempt > attempts:
                assert d1 is None
            if d1 is not None:
                assert d1["kind"] in ("crash", "hang", "transient")
