"""phi-3-vision-4.2b [vlm]: 32L d_model=3072 32H (GQA kv=32) d_ff=8192
vocab=32064; phi3-mini backbone + CLIP frontend STUB (precomputed patch
embeddings via input_specs()). [hf:microsoft/Phi-3-vision-128k-instruct; hf]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="phi-3-vision-4.2b", family="vlm",
    n_layers=32, d_model=3072, n_heads=32, n_kv_heads=32,
    d_ff=8192, vocab_size=32064,
    frontend="vision", frontend_tokens=256, frontend_dim=1024,
)

def smoke_config() -> ModelConfig:
    return CONFIG.replace(n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
                          d_ff=128, vocab_size=512, frontend_tokens=8,
                          frontend_dim=32, remat=False)
