#!/usr/bin/env python
"""Documentation link-integrity checker (docs/README.md).

Scans the repo's markdown (README.md, ROADMAP.md, CHANGES.md, PAPER.md,
docs/**/*.md by default, or explicit paths given as arguments) and fails
on any *relative* link whose target does not exist in the working tree:

    python scripts/check_docs.py            # exit 0 = no broken links
    python scripts/check_docs.py docs/*.md  # check a subset

Checked: inline links/images ``[text](target)`` whose target is not a
URL (has no scheme) and not a pure in-page anchor (``#section``).
Targets are resolved relative to the file containing the link; a
``#fragment`` suffix is stripped before the existence check (fragments
themselves are not validated — headings move too often for that to stay
signal). Absolute paths (``/root/...``) are rejected outright: docs must
stay relocatable, so links out of the repo are broken by definition.

CI runs this in the lint job next to ruff; locally it is wired into
``make lint``. Exit codes: 0 clean, 1 broken links (each printed as
``file:line: broken link 'target'``), 2 usage error.
"""

from __future__ import annotations

import argparse
import glob
import os
import re
import sys
from typing import Iterator, List, Tuple

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

#: Default scan set: top-level markdown plus everything under docs/.
DEFAULT_GLOBS = ("*.md", "docs/**/*.md")

#: Inline markdown link/image: ``[text](target)`` / ``![alt](target)``.
#: The target group stops at the first unescaped ')' or whitespace-title
#: boundary, which covers this repo's plain-target house style.
_LINK = re.compile(r"!?\[[^\]]*\]\(([^)\s]+)(?:\s+\"[^\"]*\")?\)")

#: Anything with a scheme (https:, mailto:, ...) is out of scope.
_SCHEME = re.compile(r"^[a-zA-Z][a-zA-Z0-9+.-]*:")


def iter_links(path: str) -> Iterator[Tuple[int, str]]:
    """Yield ``(line_number, target)`` for every inline link in *path*.

    Fenced code blocks are skipped: bench tables and shell transcripts
    routinely contain ``[...]``-shaped text that is not a link.
    """
    in_fence = False
    with open(path, encoding="utf-8") as f:
        for lineno, line in enumerate(f, 1):
            if line.lstrip().startswith("```"):
                in_fence = not in_fence
                continue
            if in_fence:
                continue
            for m in _LINK.finditer(line):
                yield lineno, m.group(1)


def check_file(path: str) -> List[str]:
    """Return ``file:line: broken link`` messages for *path*."""
    problems: List[str] = []
    base = os.path.dirname(os.path.abspath(path))
    rel = os.path.relpath(path, REPO_ROOT)
    for lineno, target in iter_links(path):
        if _SCHEME.match(target) or target.startswith("#"):
            continue
        bare = target.split("#", 1)[0]
        if not bare:
            continue
        if os.path.isabs(bare):
            problems.append(f"{rel}:{lineno}: absolute-path link "
                            f"'{target}' (use a repo-relative link)")
            continue
        if not os.path.exists(os.path.join(base, bare)):
            problems.append(f"{rel}:{lineno}: broken link '{target}'")
    return problems


def default_files() -> List[str]:
    files: List[str] = []
    for pat in DEFAULT_GLOBS:
        files.extend(glob.glob(os.path.join(REPO_ROOT, pat), recursive=True))
    return sorted(set(files))


def main(argv: List[str]) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("paths", nargs="*",
                    help="markdown files to check (default: repo-level "
                         "*.md plus docs/**/*.md)")
    args = ap.parse_args(argv)

    files = args.paths or default_files()
    missing = [p for p in files if not os.path.isfile(p)]
    if missing:
        for p in missing:
            print(f"check_docs: no such file: {p}", file=sys.stderr)
        return 2

    problems: List[str] = []
    for path in files:
        problems.extend(check_file(path))
    for msg in problems:
        print(msg)
    n = len(files)
    if problems:
        print(f"check_docs: {len(problems)} broken link(s) "
              f"across {n} file(s)", file=sys.stderr)
        return 1
    print(f"check_docs: {n} file(s) clean")
    return 0


if __name__ == "__main__":
    raise SystemExit(main(sys.argv[1:]))
