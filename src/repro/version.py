"""Package version, recorded in cache provenance manifests.

``repro`` is distributed as a source tree (no wheel metadata), so the
version lives here instead of ``importlib.metadata``. Bump it when a
release-worthy behaviour change lands; the result cache stores it in each
entry's manifest (``repro.sim.cache``) so a cached result can always be
traced back to the code generation that produced it. Note the cache *key*
does not include this version — invalidation is driven by the explicit
``repro.core.scenarios.RESULT_SCHEMA_VERSION``, which changes only when
simulation outputs actually change meaning.
"""

__version__ = "0.6.0"
