"""Worked §5.3 decision example: is commercial cloud cache worth buying?

Sweeps hot-cache size x egress pricing (tiered internet vs. the paper's
peering alternatives) against an unlimited-disk baseline, then reads the
cost/throughput Pareto front the way the paper's decision process does:
pick the cheapest configuration that keeps (nearly) the baseline job
throughput.

    PYTHONPATH=src python examples/sweep_decision.py
"""

import math
import sys

sys.path.insert(0, "src")

from repro.core.scenarios import ScenarioSpec, expand_grid
from repro.sim.sweep import SweepResult, run_sweep

DAYS, FILES = 2.0, 20_000


def main() -> None:
    # Baseline: configuration I (unlimited site disk, no cloud involvement).
    baseline = ScenarioSpec(base="I", days=DAYS, n_files=FILES, seed=0)
    # Candidates: configuration III with a small hot cache, varying the
    # cache size and the egress pricing option (§5.3 peering alternatives).
    candidates = expand_grid({
        "base": "III", "days": DAYS, "n_files": FILES, "seed": 0,
        "cache_tb": [5.0, 20.0, 100.0],
        "egress": ["internet", "direct", "interconnect"],
    })

    print(f"sweeping {1 + len(candidates)} configs "
          f"({DAYS:g} days, {FILES} files/site) ...")
    res = run_sweep([baseline] + candidates)
    base_jobs = res.results[0].jobs_done

    print(f"\n{'config':52s} {'jobs':>8s} {'vs base':>8s} {'cloud cost':>12s}")
    for r in res.results:
        print(f"{r.spec.label:52s} {r.jobs_done:8.0f} "
              f"{100 * r.jobs_done / base_jobs:7.1f}% ${r.cost_usd:11,.2f}")

    # The frontier among the *cloud candidates* (the baseline trivially
    # dominates on cost — unlimited free disk is exactly what is not on
    # offer).
    cand = SweepResult(results=res.results[1:])
    print("\nPareto front among cloud candidates (min cost, max jobs):")
    for r in cand.pareto_front():
        print(f"  {r.spec.label:50s} jobs={r.jobs_done:8.0f} "
              f"cost=${r.cost_usd:,.2f}")

    # The decision rule: cheapest candidate keeping >= 97% of baseline jobs.
    ok = [r for r in cand.results if r.jobs_done >= 0.97 * base_jobs]
    if ok:
        best = min(ok, key=lambda r: r.cost_usd)
        cache = ("unlimited" if best.spec.cache_tb is None
                 or math.isinf(best.spec.cache_tb)
                 else f"{best.spec.cache_tb:g} TB")
        print(f"\ndecision: buy {cache} hot cache with '{best.spec.egress}' "
              f"egress — {100 * best.jobs_done / base_jobs:.1f}% of baseline "
              f"throughput at ${best.cost_usd:,.2f} cloud cost "
              f"for the simulated window.")
    else:
        print("\ndecision: no candidate keeps 97% of baseline throughput; "
              "grow the cache axis.")


# The guard is required: run_sweep's spawn-based worker processes re-import
# this module, and an unguarded sweep would recurse into the pool bootstrap.
if __name__ == "__main__":
    main()
