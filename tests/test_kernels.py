"""Pallas kernel allclose sweeps vs. pure-jnp oracles (interpret mode)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.carousel_update.ops import carousel_tick
from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.mamba_scan.ops import mamba_scan


@pytest.mark.parametrize("n,m", [(64, 3), (1000, 17), (2049, 33)])
@pytest.mark.parametrize("dt", [1.0, 10.0])
def test_carousel_tick_shapes(n, m, dt):
    rng = np.random.default_rng(n + m)
    link_id = jnp.asarray(rng.integers(0, m, n), jnp.int32)
    active = jnp.asarray(rng.random(n) < 0.6)
    total = jnp.asarray(rng.exponential(1e9, n).astype(np.float32) + 1e6)
    done = jnp.asarray(rng.random(n).astype(np.float32)) * total
    bw = jnp.asarray(rng.uniform(1e6, 1e8, m).astype(np.float32))
    mode = jnp.asarray(rng.integers(0, 2, m), jnp.int32)
    k = carousel_tick(link_id, active, done, total, bw, mode, dt,
                      use_pallas=True)
    r = carousel_tick(link_id, active, done, total, bw, mode, dt,
                      use_pallas=False)
    np.testing.assert_allclose(k[0], r[0], rtol=1e-5)
    assert bool((k[1] == r[1]).all())
    np.testing.assert_allclose(k[2], r[2], rtol=1e-6)


def test_carousel_tick_scalar_semantics():
    """Kernel math matches the Python event engine's per-transfer rate."""
    link_id = jnp.asarray([0, 0, 1], jnp.int32)
    active = jnp.asarray([True, True, True])
    done = jnp.zeros(3, jnp.float32)
    total = jnp.asarray([100.0, 100.0, 100.0])
    bw = jnp.asarray([10.0, 8.0], jnp.float32)
    mode = jnp.asarray([0, 1], jnp.int32)  # link0 shared, link1 throughput
    nd, comp, counts = carousel_tick(link_id, active, done, total, bw, mode,
                                     2.0, use_pallas=True)
    # link0 shared: 10/2 x 2 s = 10 bytes each; link1: 8 x 2 = 16
    np.testing.assert_allclose(np.asarray(nd), [10.0, 10.0, 16.0])
    assert not bool(comp.any())


@pytest.mark.parametrize("B,nh,nkv,T,hd", [
    (1, 2, 1, 64, 32),
    (2, 4, 2, 200, 64),
    (1, 8, 8, 256, 128),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("window", [0, 64])
def test_flash_attention_sweep(B, nh, nkv, T, hd, dtype, window):
    rng = np.random.default_rng(T + hd)
    q = jnp.asarray(rng.normal(size=(B, nh, T, hd)), dtype)
    k = jnp.asarray(rng.normal(size=(B, nkv, T, hd)), dtype)
    v = jnp.asarray(rng.normal(size=(B, nkv, T, hd)), dtype)
    out_k = flash_attention(q, k, v, causal=True, window=window,
                            use_pallas=True)
    out_r = flash_attention(q, k, v, causal=True, window=window,
                            use_pallas=False)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(
        np.asarray(out_k, np.float32), np.asarray(out_r, np.float32),
        atol=tol, rtol=tol)


@pytest.mark.parametrize("B,T,D,N", [
    (1, 64, 128, 8),
    (2, 300, 130, 16),   # unaligned: exercises padding
    (1, 512, 256, 16),
])
def test_mamba_scan_sweep(B, T, D, N):
    rng = np.random.default_rng(T + D)
    dA = jnp.asarray(np.exp(-rng.random((B, T, D, N))).astype(np.float32))
    dBu = jnp.asarray(rng.normal(size=(B, T, D, N)).astype(np.float32) * 0.1)
    C = jnp.asarray(rng.normal(size=(B, T, N)).astype(np.float32))
    yk = mamba_scan(dA, dBu, C, use_pallas=True)
    yr = mamba_scan(dA, dBu, C, use_pallas=False)
    np.testing.assert_allclose(np.asarray(yk), np.asarray(yr),
                               atol=1e-4, rtol=1e-4)


def test_mamba_scan_carry_across_chunks():
    """State must persist across time-chunk grid steps (scratch carry)."""
    B, T, D, N = 1, 512, 128, 4  # T spans 2 chunks of 256
    dA = jnp.ones((B, T, D, N), jnp.float32) * 0.999
    dBu = jnp.ones((B, T, D, N), jnp.float32) * 0.01
    C = jnp.ones((B, T, N), jnp.float32)
    y = mamba_scan(dA, dBu, C, use_pallas=True)
    yr = mamba_scan(dA, dBu, C, use_pallas=False)
    # monotonically increasing accumulation; chunk boundary must not reset
    assert float(y[0, 256, 0]) > float(y[0, 255, 0]) > float(y[0, 0, 0])
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), rtol=1e-4)


def test_model_ssm_block_runs_finite():
    """Smoke: models.ssm's block runs end-to-end and stays finite (kernel
    vs. reference parity is covered by the mamba_scan tests above)."""
    from repro.configs import get_smoke_config
    from repro.models.ssm import init_ssm, ssm_block
    cfg = get_smoke_config("falcon_mamba_7b")
    params = init_ssm(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model),
                          dtype=cfg.dtype)

    ref_out = ssm_block(params, cfg, x)
    assert bool(jnp.isfinite(ref_out.astype(jnp.float32)).all())
