"""Benchmark: distributed worker-fleet sweep throughput (ISSUE 10).

Extends the ``sweep.jax.lane_scaling.*`` panel to 1024- and 10k-lane
grids with a *workers* axis: each grid is executed through the
persistent worker fleet (``repro.sim.runners``, subprocess transport,
lane-chunk jobs) at 1 and 4 workers. Row names::

    sweep.jax.lane_scaling.1024lane.w1     derived = lanes/sec
    sweep.jax.lane_scaling.1024lane.w4
    sweep.jax.lane_scaling.10klane.w1
    sweep.jax.lane_scaling.10klane.w4
    sweep.jax.fleet_speedup.<N>lane        derived = w4 / w1 lanes-per-sec
    sweep.jax.fleet_parity.10klane         derived = 1.0 (bitwise gate)

The parity row re-runs the largest grid through the serial in-process
registry path (``run_local_jobs`` over the identical lane-chunk jobs)
and raises unless the fleet result is byte-identical per config — the
ISSUE 10 acceptance gate.

Scaling expectations: the fleet's speedup is bounded by the host's
physical cores. The numbers in the committed ``BENCH_fleet.json`` were
measured on this repo's 1-core dev container, where ``w4`` can only
match ``w1`` (documented there and in ``docs/distributed.md``); the
>= 3x acceptance bar is realized on the nightly CI runner (4 vCPUs),
whose table the workflow summary prints (``--baseline -`` mode).

Sized so the full panel stays under ~10 minutes on one core:
``days=0.05`` / ``n_files=250`` at the 60 s bench tick is ~80 lanes/sec
serially, so the 10k-lane grid is ~2 min per execution. FAST=1 drops to
a 64-lane / 2-worker smoke row (CI bench-smoke: plumbing, not
throughput).
"""

from __future__ import annotations

import argparse
import json
import time
from typing import Dict, List

from repro.core.scenarios import ScenarioSpec, with_seeds
from repro.sim.sweep import run_sweep

#: Same coarse clock as bench_sweep (validated against the 10 s tick by
#: ``test_batched.test_jax_backend_tick_coarsening_stays_close``).
JAX_BENCH_TICK = 60.0

#: Fleet lane-chunk size: big enough to amortize one frame round trip
#: per job, small enough that a 1024-lane grid still fans out 16 jobs.
FLEET_CHUNK = 64

#: Reduced per-lane scale for the big panels (one lane simulates in
#: ~12 ms, so 10k lanes ~= 2 min per serial execution).
DAYS, N_FILES = 0.05, 250


def _lane_specs(n: int) -> List[ScenarioSpec]:
    return with_seeds([ScenarioSpec(base="III", days=DAYS, n_files=N_FILES,
                                    cache_tb=20.0)], n)


def _key(res) -> List:
    return [(r.spec, r.metrics, r.storage_usd, r.network_usd, r.ops_usd)
            for r in res.results]


def _fleet(specs, workers: int):
    t0 = time.perf_counter()
    res = run_sweep(specs, backend="jax", tick=JAX_BENCH_TICK,
                    lane_chunk=FLEET_CHUNK, transport="subprocess",
                    workers=workers)
    wall = time.perf_counter() - t0
    if not res.ok:
        raise RuntimeError(f"fleet sweep lost {len(res.failures)} job(s)")
    return res, wall


def _label(n: int) -> str:
    return "10klane" if n == 10_000 else f"{n}lane"


def run(fast: bool = False, parity: bool = True) -> List[Dict]:
    panel = [64] if fast else [1024, 10_000]
    worker_axis = [2] if fast else [1, 4]
    rows: List[Dict] = []
    largest_fleet = None
    for n in panel:
        specs = _lane_specs(n)
        by_workers: Dict[int, float] = {}
        for w in worker_axis:
            res, wall = _fleet(specs, w)
            lps = n / wall if wall > 0 else 0.0
            by_workers[w] = lps
            rows.append({"name": f"sweep.jax.lane_scaling.{_label(n)}.w{w}",
                         "us_per_call": wall / n * 1e6,
                         "derived": lps})
            largest_fleet = (specs, res)
        if len(worker_axis) > 1:
            w_lo, w_hi = min(worker_axis), max(worker_axis)
            rows.append({"name": f"sweep.jax.fleet_speedup.{_label(n)}",
                         "us_per_call": 0.0,
                         "derived": by_workers[w_hi] / by_workers[w_lo]
                         if by_workers[w_lo] > 0 else 0.0})
    if parity and largest_fleet is not None:
        # Acceptance gate: the fleet result must be byte-identical to the
        # serial in-process registry path over the same lane-chunk jobs.
        specs, fleet_res = largest_fleet
        from repro.sim.jobs import RetryPolicy

        t0 = time.perf_counter()
        serial = run_sweep(specs, backend="jax", tick=JAX_BENCH_TICK,
                           lane_chunk=FLEET_CHUNK, retry=RetryPolicy())
        wall = time.perf_counter() - t0
        if _key(serial) != _key(fleet_res):
            raise RuntimeError(
                f"fleet result diverged from the serial registry path on "
                f"the {_label(len(specs))} grid")
        rows.append({"name": f"sweep.jax.fleet_parity.{_label(len(specs))}",
                     "us_per_call": wall / len(specs) * 1e6,
                     "derived": 1.0})
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="64-lane / 2-worker smoke panel")
    ap.add_argument("--no-parity", action="store_true",
                    help="skip the serial-registry bitwise gate")
    ap.add_argument("--json", default="",
                    help="also write rows as a bench JSON document")
    args = ap.parse_args()
    t0 = time.time()
    rows = run(fast=args.fast, parity=not args.no_parity)
    for r in rows:
        print(f"{r['name']},{r['us_per_call']:.0f},{r['derived']:.4g}",
              flush=True)
    if args.json:
        doc = {"wall_s": time.time() - t0, "fast": args.fast,
               "failures": [],
               "benches": [{"name": r["name"],
                            "us_per_call": float(r["us_per_call"]),
                            "derived": float(r["derived"])} for r in rows]}
        with open(args.json, "w") as f:
            json.dump(doc, f, indent=2)
        print(f"# wrote {args.json} ({len(rows)} rows)")


if __name__ == "__main__":
    main()
