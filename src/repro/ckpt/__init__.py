"""Checkpoint/restart + failure handling substrate."""

from repro.ckpt.checkpoint import CheckpointManager
from repro.ckpt.failover import FailureDetector, ElasticPlanner

__all__ = ["CheckpointManager", "FailureDetector", "ElasticPlanner"]
