"""Serving driver: batched greedy decoding on a (reduced) model.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-4b --requests 8
"""

from __future__ import annotations

import argparse
import time

import jax

from repro.configs import canonical, get_smoke_config
from repro.models import init_params
from repro.serve.engine import Request, ServeLoop


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=256)
    args = ap.parse_args()

    cfg = get_smoke_config(canonical(args.arch))
    params = init_params(cfg, jax.random.PRNGKey(0))
    loop = ServeLoop(cfg, params, batch_slots=args.slots,
                     max_len=args.max_len)
    reqs = [
        Request(rid=i,
                prompt=jax.random.randint(jax.random.PRNGKey(i), (16,), 0,
                                          cfg.vocab_size),
                max_new=args.max_new)
        for i in range(args.requests)
    ]
    t0 = time.time()
    out = loop.run(reqs)
    dt = time.time() - t0
    toks = sum(len(v) for v in out.values())
    print(f"served {len(out)} requests / {toks} tokens in {dt:.2f}s "
          f"({toks / dt:.1f} tok/s)")


if __name__ == "__main__":
    main()
