"""Pallas TPU kernel: vectorized transfer-manager tick (paper's hot loop).

The paper's transfer-manager update "scales linearly with the number of
active transfers" and motivated its C++ rewrite. TPU adaptation: all active
transfers are dense tensors; one tick is

  1. counts[m]  = #active transfers on link m        (segmented count)
  2. rate[i]    = bw[l_i]            (throughput mode)
                  bw[l_i]/counts[l_i] (shared-bandwidth mode)
  3. done'[i]   = min(total[i], done[i] + active_i x rate[i] x dt)
  4. completed  = done' >= total

TPU-native design notes:
  - the per-transfer link lookup is a *gather*; gathers are slow on the
    VPU, so both the count (step 1) and the lookup (step 2) become
    one-hot matmuls on the MXU: onehot[N_blk, M] @ bw[M] etc.
  - transfers are tiled into VMEM blocks of TR_BLOCK rows; the link table
    (M <= 512 links) is VMEM-resident and broadcast to every grid step;
  - counts are accumulated across the transfer grid in the output ref
    (sequential TPU grid => safe read-modify-write accumulation).

Two kernels: ``count_kernel`` (pass 1) and ``update_kernel`` (pass 2).
``ops.py`` fuses them behind one jitted call; ``ref.py`` is the jnp oracle
(and matches the scalar math of the Python event engine).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TR_BLOCK = 1024  # transfers per grid step (8 sublanes x 128 lanes)


def _onehot_links(link_id_blk: jnp.ndarray, n_links: int) -> jnp.ndarray:
    """[B] int32 -> [B, M] f32 one-hot (MXU operand)."""
    cols = jax.lax.broadcasted_iota(jnp.int32, (link_id_blk.shape[0], n_links), 1)
    return (link_id_blk[:, None] == cols).astype(jnp.float32)


def count_kernel(link_id_ref, active_ref, counts_ref):
    """Accumulate per-link active-transfer counts across transfer blocks."""
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        counts_ref[...] = jnp.zeros_like(counts_ref)

    onehot = _onehot_links(link_id_ref[...], counts_ref.shape[-1])
    active = active_ref[...].astype(jnp.float32)
    # [1, B] @ [B, M] on the MXU -> per-link partial counts
    partial = jnp.dot(active[None, :], onehot,
                      preferred_element_type=jnp.float32)[0]
    counts_ref[...] += partial


def update_kernel(link_id_ref, active_ref, done_ref, total_ref,
                  bw_ref, mode_ref, counts_ref, dt_ref,
                  new_done_ref, completed_ref):
    """Advance one tick for a block of transfers."""
    onehot = _onehot_links(link_id_ref[...], bw_ref.shape[-1])
    bw = jnp.dot(onehot, bw_ref[...][:, None],
                 preferred_element_type=jnp.float32)[:, 0]
    mode = jnp.dot(onehot, mode_ref[...][:, None].astype(jnp.float32),
                   preferred_element_type=jnp.float32)[:, 0]
    counts = jnp.dot(onehot, counts_ref[...][:, None],
                     preferred_element_type=jnp.float32)[:, 0]
    active = active_ref[...].astype(jnp.float32)
    shared = bw / jnp.maximum(counts, 1.0)
    rate = jnp.where(mode > 0.5, bw, shared)
    inc = active * rate * dt_ref[0]
    new_done = jnp.minimum(total_ref[...], done_ref[...] + inc)
    new_done_ref[...] = new_done
    completed_ref[...] = jnp.logical_and(new_done >= total_ref[...],
                                         active > 0.5)


def carousel_tick_pallas(link_id, active, done, total, bw, mode, dt,
                         interpret=None):
    """One transfer-manager tick over all transfers.

    link_id: [N] i32; active: [N] bool; done/total: [N] f32;
    bw: [M] f32 bytes/s; mode: [M] i32 (1 = per-transfer throughput,
    0 = shared bandwidth); dt: scalar seconds.
    Returns (new_done [N] f32, completed [N] bool, counts [M] f32).

    ``interpret`` defaults to the registry's backend-aware resolution
    (``repro.kernels.registry.default_interpret``): compiled on an
    accelerator, interpret mode elsewhere — the previous hardcoded
    ``True`` silently interpreted on TPU/GPU hosts too.
    """
    if interpret is None:
        from repro.kernels.registry import default_interpret

        interpret = default_interpret()
    n = link_id.shape[0]
    m = bw.shape[0]
    pad = (-n) % TR_BLOCK
    if pad:
        link_id = jnp.pad(link_id, (0, pad), constant_values=0)
        active = jnp.pad(active, (0, pad))
        done = jnp.pad(done, (0, pad))
        total = jnp.pad(total, (0, pad), constant_values=jnp.inf)
    npad = link_id.shape[0]
    grid = (npad // TR_BLOCK,)

    counts = pl.pallas_call(
        count_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((TR_BLOCK,), lambda i: (i,)),
            pl.BlockSpec((TR_BLOCK,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((m,), lambda i: (0,)),  # same block all steps
        out_shape=jax.ShapeDtypeStruct((m,), jnp.float32),
        interpret=interpret,
    )(link_id, active.astype(jnp.float32))

    dt_arr = jnp.asarray([dt], dtype=jnp.float32)
    new_done, completed = pl.pallas_call(
        update_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((TR_BLOCK,), lambda i: (i,)),
            pl.BlockSpec((TR_BLOCK,), lambda i: (i,)),
            pl.BlockSpec((TR_BLOCK,), lambda i: (i,)),
            pl.BlockSpec((TR_BLOCK,), lambda i: (i,)),
            pl.BlockSpec((m,), lambda i: (0,)),
            pl.BlockSpec((m,), lambda i: (0,)),
            pl.BlockSpec((m,), lambda i: (0,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((TR_BLOCK,), lambda i: (i,)),
            pl.BlockSpec((TR_BLOCK,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((npad,), jnp.float32),
            jax.ShapeDtypeStruct((npad,), jnp.bool_),
        ],
        interpret=interpret,
    )(link_id, active.astype(jnp.float32), done, total, bw,
      mode.astype(jnp.float32), counts, dt_arr)
    return new_done[:n], completed[:n], counts
