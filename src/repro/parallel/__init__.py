"""Distribution layer: mesh axes, logical sharding rules, parallel plans."""

from repro.parallel.sharding import (
    ParallelPlan,
    param_shardings,
    batch_shardings,
    cache_shardings,
    plan_for,
)

__all__ = [
    "ParallelPlan",
    "param_shardings",
    "batch_shardings",
    "cache_shardings",
    "plan_for",
]
