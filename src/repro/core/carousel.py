"""The data-carousel sliding window (paper §2.1).

The carousel stages data through a bounded window of fast storage: data
*allocates* space in the window, is transferred in, processed, then
*deallocated*. Only window-sized fast storage is required at any one time.

``SlidingWindow`` is the pure accounting object shared by the discrete-event
HCDC scenario (where it models the DISK storage element's limit) and the
production data pipeline (``repro.data.tiered_store``), where it bounds the
bytes of prefetched training shards resident on the hot tier.
"""

from __future__ import annotations

from collections import OrderedDict
from typing import Dict, Hashable, Optional


class SlidingWindow:
    """Bounded byte-budget window with FIFO waiter admission.

    The paper's window-size limits (§2.1): available storage, input volume,
    source throughput, and compute; this object enforces only the storage
    budget — throughput/compute pressure shows up as waiters queueing.
    """

    def __init__(self, limit: Optional[float]):
        self.limit = limit  # bytes; None = unbounded (configuration I)
        self.used: float = 0.0
        self._members: Dict[Hashable, float] = {}

    def can_allocate(self, size: float) -> bool:
        return self.limit is None or self.used + size <= self.limit

    def allocate(self, key: Hashable, size: float) -> bool:
        if key in self._members:
            return True
        if not self.can_allocate(size):
            return False
        self._members[key] = size
        self.used += size
        return True

    def release(self, key: Hashable) -> float:
        size = self._members.pop(key, 0.0)
        self.used -= size
        return size

    def __contains__(self, key: Hashable) -> bool:
        return key in self._members

    def __len__(self) -> int:
        return len(self._members)

    @property
    def free(self) -> float:
        return float("inf") if self.limit is None else self.limit - self.used


class LRUTracker:
    """Least-recently-used ordering over window members.

    The paper proposes LRU as the straightforward dynamic-popularity
    replacement (§6 future work (v)); the production tiered store uses it to
    pick hot-tier eviction victims.
    """

    def __init__(self) -> None:
        self._order: "OrderedDict[Hashable, None]" = OrderedDict()

    def touch(self, key: Hashable) -> None:
        self._order.pop(key, None)
        self._order[key] = None

    def evict_candidates(self):
        """Keys, least recently used first."""
        return iter(self._order.keys())

    def drop(self, key: Hashable) -> None:
        self._order.pop(key, None)

    def __len__(self) -> int:
        return len(self._order)
