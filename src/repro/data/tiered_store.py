"""HCDC tiered store: the paper's model as a production data-path feature.

Training shards live in three tiers mirroring the paper's QoS categories:

  archival (tape / cold object store)  — every shard, high latency
  cold     (cloud bucket)              — popularity-driven cache, elastic
  hot      (local disk/SSD)            — the carousel sliding window

``SlidingWindowPrefetcher`` is the data-carousel: it keeps the hot window
full of upcoming shards (allocate -> fetch -> consume -> evict), preferring
cold-tier hits over archival reads (the HCDC claim: equal throughput at a
fraction of hot storage). Evicted-but-popular shards migrate hot -> cold
(popularity threshold from ``repro.core.hotcold.MigrationPolicy``); the
cold tier trims via ``ColdDeletionPolicy`` (beyond-paper §6 feature). The
paper's GCS cost model meters cold-tier bills so a training run reports
its cloud cost alongside throughput.

Straggler mitigation: fetches outstanding longer than ``straggler_factor``
x the EWMA fetch latency are re-issued against the other tier (duplicate
fetch), the data-layer analogue of backup tasks — motivated directly by
the paper's Fig. 7 backlog analysis.
"""

from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Callable, Dict, List, Optional, Tuple

from repro.core.carousel import LRUTracker, SlidingWindow
from repro.core.hotcold import ColdDeletionPolicy, MigrationPolicy
from repro.sim.cloud import GCSCostModel


@dataclass
class TierSpec:
    name: str
    limit: Optional[float]           # bytes; None = unbounded
    latency_s: float                 # access latency
    bandwidth: float                 # bytes/s
    cost_model: Optional[GCSCostModel] = None  # billed tier (cold/cloud)


@dataclass
class Shard:
    sid: int
    size: float
    popularity: int = 1  # expected epochs-until-reuse proxy


class _Clock:
    """Injectable clock (tests use a manual clock)."""

    def __init__(self, fn: Optional[Callable[[], float]] = None):
        self.fn = fn or time.monotonic

    def now(self) -> float:
        return self.fn()


class TieredStore:
    def __init__(self, archival: TierSpec, cold: TierSpec, hot: TierSpec,
                 migration: MigrationPolicy = MigrationPolicy(),
                 cold_deletion: ColdDeletionPolicy = ColdDeletionPolicy(0.9),
                 clock: Optional[Callable[[], float]] = None):
        self.archival = archival
        self.cold = cold
        self.hot = hot
        self.migration = migration
        self.cold_deletion = cold_deletion
        self.clock = _Clock(clock)
        self.hot_window = SlidingWindow(hot.limit)
        self.cold_window = SlidingWindow(cold.limit)
        self.cold_lru = LRUTracker()
        self.shards: Dict[int, Shard] = {}
        # metrics
        self.stats = {
            "archival_reads": 0, "cold_hits": 0, "hot_hits": 0,
            "archival_bytes": 0.0, "cold_bytes": 0.0,
            "migrated_bytes": 0.0, "evicted_bytes": 0.0,
            "cold_egress_usd": 0.0, "straggler_refetches": 0,
        }

    def register(self, shards: List[Shard]) -> None:
        for s in shards:
            self.shards[s.sid] = s

    # ------------------------------------------------------------ fetch path
    def locate(self, sid: int) -> str:
        if sid in self.hot_window:
            return "hot"
        if sid in self.cold_window:
            return "cold"
        return "archival"

    def fetch_latency(self, sid: int) -> float:
        """Simulated fetch time into the hot tier."""
        s = self.shards[sid]
        tier = self.locate(sid)
        if tier == "hot":
            return 0.0
        src = self.cold if tier == "cold" else self.archival
        return src.latency_s + s.size / src.bandwidth

    def fetch_to_hot(self, sid: int) -> Tuple[str, float]:
        """Bring a shard into the hot window. Returns (source, latency)."""
        s = self.shards[sid]
        tier = self.locate(sid)
        if tier == "hot":
            self.stats["hot_hits"] += 1
            return "hot", 0.0
        if not self.hot_window.allocate(sid, s.size):
            raise RuntimeError("hot window full: evict before fetch")
        lat = self.fetch_latency(sid)
        if tier == "cold":
            self.stats["cold_hits"] += 1
            self.stats["cold_bytes"] += s.size
            if self.cold.cost_model is not None:
                self.stats["cold_egress_usd"] += \
                    self.cold.cost_model.egress_cost(s.size)
            self.cold_lru.touch(sid)
        else:
            self.stats["archival_reads"] += 1
            self.stats["archival_bytes"] += s.size
        return tier, lat

    # ------------------------------------------------------------- eviction
    def evict_from_hot(self, sid: int) -> None:
        """Carousel deallocation; popular shards migrate to cold first."""
        s = self.shards[sid]
        size = self.hot_window.release(sid)
        self.stats["evicted_bytes"] += size
        if sid in self.cold_window:
            return
        if not self.migration.should_migrate(s.popularity):
            return
        self._trim_cold(s.size)
        if self.cold_window.allocate(sid, s.size):
            self.stats["migrated_bytes"] += s.size
            self.cold_lru.touch(sid)

    def _trim_cold(self, incoming: float) -> None:
        """Beyond-paper cold-tier deletion (paper §6 'essential feature')."""
        target = self.cold_deletion.trim_target(
            self.cold_window.limit,
            self.cold_window.used + incoming)
        if target <= 0:
            return
        victims = []
        for sid in self.cold_lru.evict_candidates():
            if target <= 0:
                break
            sz = self.shards[sid].size
            victims.append(sid)
            target -= sz
        for sid in victims:
            self.cold_window.release(sid)
            self.cold_lru.drop(sid)


class SlidingWindowPrefetcher:
    """The data carousel over a schedule of shard ids.

    Keeps the hot window filled with the next shards of the schedule;
    ``next_batch`` blocks (simulated latency accounting) until the head
    shard is resident, then consumes + evicts it. Duplicate-fetch
    straggler mitigation re-sources fetches that exceed
    ``straggler_factor`` x EWMA latency.
    """

    def __init__(self, store: TieredStore, schedule: List[int],
                 straggler_factor: float = 3.0):
        self.store = store
        self.schedule = list(schedule)
        self.straggler_factor = straggler_factor
        self._inflight: Dict[int, float] = {}  # sid -> expected latency
        self._ewma: float = 0.0
        self.pos = 0
        self.total_wait_s = 0.0

    def _prefetch(self) -> None:
        i = self.pos
        while i < len(self.schedule):
            sid = self.schedule[i]
            s = self.store.shards[sid]
            if sid in self.store.hot_window or sid in self._inflight:
                i += 1
                continue
            if not self.store.hot_window.can_allocate(s.size):
                break
            src, lat = self.store.fetch_to_hot(sid)
            if lat > 0:
                # straggler check: a fetch predicted far beyond EWMA gets
                # re-sourced if the other tier is faster (duplicate fetch)
                if (self._ewma > 0 and
                        lat > self.straggler_factor * self._ewma and
                        src == "archival" and sid in self.store.cold_window):
                    self.store.stats["straggler_refetches"] += 1
                    lat = self.store.cold.latency_s + s.size / self.store.cold.bandwidth
                self._inflight[sid] = lat
                self._ewma = 0.8 * self._ewma + 0.2 * lat if self._ewma else lat
            i += 1

    def next_shard(self) -> Tuple[int, float]:
        """Consume the next scheduled shard. Returns (sid, wait_s)."""
        if self.pos >= len(self.schedule):
            raise StopIteration
        sid = self.schedule[self.pos]
        self._prefetch()
        wait = self._inflight.pop(sid, 0.0)
        self.total_wait_s += wait
        self.pos += 1
        # consumed: carousel eviction (hot -> cold migration inside)
        self.store.evict_from_hot(sid)
        return sid, wait

    def drain(self) -> Dict[str, float]:
        while self.pos < len(self.schedule):
            self.next_shard()
        return dict(self.store.stats, total_wait_s=self.total_wait_s)
