import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this builds abstract inputs (ShapeDtypeStruct — zero
allocation), derives in/out shardings from the parallel plan, and runs
``jax.jit(step).lower(...).compile()`` on the production mesh. Success
proves the distribution config is coherent; the compiled artifact yields
``memory_analysis()`` (fits-check) and ``cost_analysis()`` + collective
bytes (roofline terms), recorded as JSON under results/dryrun/.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-4b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] [--out DIR]
"""

import argparse
import json
import time
import traceback
from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.configs import ARCHITECTURES, canonical, get_config
from repro.launch.mesh import make_production_mesh
from repro.launch.shapes import (
    SHAPES,
    cell_supported,
    decode_cache_len,
    input_specs,
)
from repro.models import init_cache, init_params
from repro.models.config import ModelConfig
from repro.parallel.sharding import (
    batch_shardings,
    cache_shardings,
    param_shardings,
    plan_for,
)
from repro.roofline.analysis import roofline_report
from repro.roofline.hlo_cost import analyze_hlo
from repro.serve.engine import make_decode_step, make_prefill_step
from repro.train.optimizer import make_optimizer
from repro.train.train_step import make_train_step
from jax.sharding import NamedSharding, PartitionSpec as P


def _abstract_params(cfg: ModelConfig):
    return jax.eval_shape(lambda: init_params(cfg, jax.random.PRNGKey(0)))


def lower_cell(arch: str, shape_name: str, multi_pod: bool,
               plan_overrides: Dict[str, Any] | None = None,
               config_overrides: Dict[str, Any] | None = None):
    """Build + lower one cell; returns (lowered, mesh, plan, meta)."""
    cfg = get_config(arch)
    if config_overrides:
        cfg = cfg.replace(**config_overrides)
    mesh = make_production_mesh(multi_pod=multi_pod)
    plan = plan_for(cfg, shape_name, mesh)
    if plan_overrides:
        import dataclasses
        plan = dataclasses.replace(plan, **plan_overrides)
    kind = SHAPES[shape_name]["kind"]
    # perf knob: per-cell chunked-attention threshold override
    from repro.models import attention as _attn
    if plan.attn_chunk_threshold:
        _attn.CHUNKED_ATTN_THRESHOLD = plan.attn_chunk_threshold
    specs = input_specs(cfg, shape_name)
    p_shape = _abstract_params(cfg)
    p_shard = param_shardings(mesh, plan, p_shape)
    b_shard = batch_shardings(mesh, specs)

    with mesh:
        if kind == "train":
            step = make_train_step(cfg, plan, mesh)
            opt = make_optimizer(plan.optimizer)
            o_shape = jax.eval_shape(opt.init, p_shape)
            # ZeRO-1: optimizer state always carries the FSDP (data) axis on
            # top of TP, even when weights themselves stay replicated.
            import dataclasses as _dc
            o_shard = param_shardings(mesh, _dc.replace(plan, fsdp=True),
                                      o_shape)
            jitted = jax.jit(
                step,
                in_shardings=(p_shard, o_shard, b_shard),
                out_shardings=(p_shard, o_shard,
                               NamedSharding(mesh, P())),
            )
            lowered = jitted.lower(p_shape, o_shape, specs)
        elif kind == "prefill":
            batch = SHAPES[shape_name]["batch"]
            seq = SHAPES[shape_name]["seq"]
            cache_shape = jax.eval_shape(
                lambda: init_cache(cfg, batch, seq + 8))
            c_shard = cache_shardings(mesh, plan, cfg, cache_shape)
            stepfn = make_prefill_step(cfg, mesh, moe_local_dispatch=plan.moe_local_dispatch, no_ep=plan.no_ep)
            jitted = jax.jit(
                stepfn,
                in_shardings=(p_shard, b_shard, c_shard),
            )
            lowered = jitted.lower(p_shape, specs, cache_shape)
        else:  # decode
            batch = SHAPES[shape_name]["batch"]
            S = decode_cache_len(shape_name)
            cache_shape = jax.eval_shape(lambda: init_cache(cfg, batch, S))
            if cfg.is_enc_dec:  # cross-kv cache from a 4096-frame encoder pass
                ck = jax.ShapeDtypeStruct(
                    (cfg.n_layers, batch, 4096, cfg.n_kv_heads, cfg.hd),
                    cfg.dtype)
                cache_shape["cross_kv"] = (ck, ck)
            c_shard = cache_shardings(mesh, plan, cfg, cache_shape)
            stepfn = make_decode_step(cfg, mesh, moe_local_dispatch=plan.moe_local_dispatch, no_ep=plan.no_ep)
            jitted = jax.jit(
                stepfn,
                in_shardings=(p_shard, b_shard["tokens"], c_shard, None),
            )
            lowered = jitted.lower(p_shape, specs["tokens"], cache_shape,
                                   jax.ShapeDtypeStruct((), jnp.int32))
    return lowered, mesh, plan, {"cfg": cfg, "kind": kind}


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             out_dir: str | None = None,
             plan_overrides: Dict[str, Any] | None = None,
             config_overrides: Dict[str, Any] | None = None,
             tag: str = "") -> Dict[str, Any]:
    mesh_name = "2x16x16" if multi_pod else "16x16"
    ok, why = cell_supported(arch, shape_name)
    rec: Dict[str, Any] = {
        "arch": arch, "shape": shape_name, "mesh": mesh_name, "tag": tag,
    }
    if not ok:
        rec.update(status="skipped", reason=why)
        return rec
    t0 = time.time()
    try:
        lowered, mesh, plan, meta = lower_cell(arch, shape_name, multi_pod,
                                               plan_overrides,
                                               config_overrides)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
        hlo = analyze_hlo(compiled.as_text())  # trip-count corrected
        cfg = meta["cfg"]
        rec.update(
            status="ok",
            lower_s=round(t_lower, 1),
            compile_s=round(t_compile, 1),
            plan={k: getattr(plan, k) for k in
                  ("fsdp", "microbatches", "seq_shard_cache", "optimizer",
                   "shard_activation_seq", "remat_policy",
                   "grad_accum_dtype", "moe_local_dispatch")},
            memory={
                "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
                "output_bytes": getattr(mem, "output_size_in_bytes", None),
                "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
                "peak_bytes": getattr(mem, "peak_memory_in_bytes", None),
            },
            cost_raw={  # as reported (scan bodies counted once)
                "flops": cost.get("flops"),
                "bytes_accessed": cost.get("bytes accessed"),
            },
            cost={  # trip-count corrected per-chip program cost
                "flops": hlo["flops"],
                "bytes_accessed": hlo["bytes_accessed"],
            },
            collectives={
                "total_wire_bytes": hlo["collective_wire_bytes"],
                "per_kind": hlo["collective_per_kind"],
                "count": hlo["collective_counts"],
            },
            model_params=cfg.param_count(),
            model_params_active=cfg.active_param_count(),
            roofline=roofline_report(
                kind=meta["kind"], cfg=cfg, shape=SHAPES[shape_name],
                n_chips=mesh.size, flops=hlo["flops"],
                bytes_accessed=hlo["bytes_accessed"],
                coll={"total_wire_bytes": hlo["collective_wire_bytes"]}),
        )
    except Exception as e:  # record failures as first-class results
        rec.update(status="error", error=f"{type(e).__name__}: {e}",
                   traceback=traceback.format_exc()[-2000:])
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        suffix = f"_{tag}" if tag else ""
        path = os.path.join(out_dir,
                            f"{arch}_{shape_name}_{mesh_name}{suffix}.json")
        with open(path, "w") as f:
            json.dump(rec, f, indent=1, default=str)
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", type=str, default=None)
    ap.add_argument("--shape", type=str, default=None,
                    choices=list(SHAPES) + [None])
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", type=str, default="results/dryrun")
    ap.add_argument("--tag", type=str, default="")
    args = ap.parse_args()

    cells = []
    archs = ARCHITECTURES if (args.all or args.arch is None) else [canonical(args.arch)]
    shapes = list(SHAPES) if (args.all or args.shape is None) else [args.shape]
    for a in archs:
        for s in shapes:
            cells.append((a, s))
    for a, s in cells:
        rec = run_cell(a, s, args.multi_pod, out_dir=args.out, tag=args.tag)
        mem = rec.get("memory", {})
        peak = (mem.get("temp_bytes") or 0) / 1e9
        print(f"[{rec['status']:7s}] {a:24s} {s:12s} {rec['mesh']:8s} "
              f"temp={peak:7.2f}GB flops={rec.get('cost', {}).get('flops', 0):.3e} "
              f"{rec.get('reason', rec.get('error', ''))}",
              flush=True)


if __name__ == "__main__":
    main()
