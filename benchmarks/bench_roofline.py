"""Benchmark: roofline table from the dry-run artifacts (results/dryrun).

Reads every recorded cell JSON and prints the three roofline terms, the
dominant bottleneck, MODEL_FLOPS/HLO_FLOPs ratio, and the roofline
fraction. This is the §Roofline table generator for EXPERIMENTS.md.
"""

from __future__ import annotations

import glob
import json
import os
from typing import Dict, List

RESULTS = os.environ.get("DRYRUN_DIR", "results/dryrun")


def load_cells(tag: str = "") -> List[Dict]:
    cells = []
    for path in sorted(glob.glob(os.path.join(RESULTS, "*.json"))):
        with open(path) as f:
            rec = json.load(f)
        if rec.get("tag", "") != tag:
            continue
        cells.append(rec)
    return cells


def run() -> List[Dict]:
    rows = []
    for rec in load_cells():
        if rec.get("status") != "ok":
            rows.append({"name": f"dryrun.{rec['arch']}.{rec['shape']}.{rec['mesh']}",
                         "us_per_call": 0.0,
                         "derived": rec.get("status"),
                         })
            continue
        r = rec["roofline"]
        rows.append({
            "name": f"roofline.{rec['arch']}.{rec['shape']}.{rec['mesh']}",
            "us_per_call": max(r["compute_s"], r["memory_s"],
                               r["collective_s"]) * 1e6,
            "derived": r["roofline_fraction"],
            "compute_s": r["compute_s"],
            "memory_s": r["memory_s"],
            "collective_s": r["collective_s"],
            "dominant": r["dominant"],
            "useful": r["useful_flops_ratio"],
        })
    return rows


def main() -> None:
    rows = run()
    if not rows:
        print("no dryrun artifacts found; run: "
              "PYTHONPATH=src python -m repro.launch.dryrun --all")
        return
    for r in rows:
        extra = ""
        if "dominant" in r:
            extra = (f",dom={r['dominant']},c={r['compute_s']:.3f}s,"
                     f"m={r['memory_s']:.3f}s,coll={r['collective_s']:.3f}s,"
                     f"useful={r['useful']:.3f}")
        d = r['derived']
        d_str = f"{d:.4f}" if isinstance(d, float) else str(d)
        print(f"{r['name']},{r['us_per_call']:.0f},{d_str}{extra}")


if __name__ == "__main__":
    main()
