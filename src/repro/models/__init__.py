"""Model zoo: all assigned architectures built from one composable config.

Families: dense GQA transformers (command-r, qwen3, gemma3, mistral-large,
phi-3-vision backbone), MoE (arctic w/ dense residual, olmoe), SSM
(falcon-mamba), hybrid attn+SSM (hymba), enc-dec (seamless backbone).
Functional style: ``init_params(cfg, key)`` -> pytree, ``forward(cfg,
params, tokens)`` -> logits, plus prefill/decode entry points with KV/SSM
caches. Layers are scan-stacked for small HLO and fast compiles.
"""

from repro.models.config import ModelConfig
from repro.models.model import (
    init_params,
    forward,
    loss_fn,
    prefill,
    decode_step,
    init_cache,
)

__all__ = [
    "ModelConfig",
    "init_params",
    "forward",
    "loss_fn",
    "prefill",
    "decode_step",
    "init_cache",
]
