"""Pallas kernels: the fused lane-blocked batched-sweep tick.

The site-vectorized tick program (``repro.sim.batched``) spends its time
in three dense pieces; each becomes one Pallas kernel here, selected via
the ``tick_impl`` registry (``repro.kernels.registry``):

- ``transfer_kernel``: the carousel transfer advance (per-link active
  counts, bandwidth-share rates, progress integration, completion) fused
  with the completion *billing* that ``repro.sim.batched`` previously
  applied as separate jnp reductions — per-site tape/recall/migration
  byte totals plus the month-bucketed egress volume and class A/B
  operation counts. Grid is one step per site: a site's three links are
  private to its row (link id = 3*site + type), so per-link counts never
  cross blocks and the whole tick is block-local one-hot matmuls
  (``carousel_update`` design notes: gathers become MXU ``dot``s).
- ``gcs_admit_pass_kernel``: the shared-GCS prefix-sum admission gate.
  The jnp program runs ``GCS_ADMIT_PASSES`` passes of a *global* cumsum
  over the site-major flattened candidate vector; here each pass is one
  ``pallas_call`` over the sequential site grid, with the running byte
  totals carried across site blocks in a small VMEM-resident carry ref
  and the previous pass's admitted mask re-entering as a true (aliased)
  input, fused with the end-of-tick GB-second storage integration.
  (Passes cannot share one grid: compiled Pallas only preserves an
  output window's VMEM contents across *consecutive* grid steps on the
  same block, and a ``(passes, S)`` grid revisits each site block
  non-consecutively.) The blocked cumsum reassociates the float pass
  totals, so admission can differ from the jnp oracle by
  capacity-boundary ties — statistical (Table-2) parity, not bitwise;
  see ``docs/simulation.md``.
- ``window_kernel``: the [S, K] job-arrival and [S, W] waiting-queue
  admission windows — C-step prefix recurrences (later candidates see
  earlier reservations; the wait queue additionally head-blocks) over
  all sites at once. Identical operation order to the jnp loops, so this
  kernel is bitwise-equal to the oracle.

Lane blocking: the wrappers are written for one lane ([S, F] planes) and
are ``jax.vmap``-ed by the caller — Pallas turns the batch axis into an
extra leading grid dimension, so a packed sweep grid executes as
lane x site blocks from one ``pallas_call``.

Booleans cross the kernel boundary as f32 0/1 masks (TPU-friendly; the
callers threshold at 0.5). Scalars ride as shape-(1,) VMEM inputs, the
month selector as a precomputed one-hot over the month axis so billing
accumulates with a multiply instead of a scatter.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.registry import default_interpret

#: File-axis tile: [S, F] planes are zero-padded to a multiple of this
#: (8 sublanes x 128 lanes = one f32 TPU tile per 8 sites).
F_BLOCK = 128


def _pad_f(arr, fp: int, value=0):
    """Pad the trailing (file) axis of a [S, F] plane to ``fp`` columns."""
    f = arr.shape[-1]
    if f == fp:
        return arr
    return jnp.pad(arr, ((0, 0), (0, fp - f)), constant_values=value)


def _onehot3(ltype: jnp.ndarray) -> jnp.ndarray:
    """[F] int32 link-type -> [F, 3] f32 one-hot (MXU operand)."""
    cols = jax.lax.broadcasted_iota(jnp.int32, (ltype.shape[0], 3), 1)
    return (ltype[:, None] == cols).astype(jnp.float32)


# ---------------------------------------------------------------------------
# transfer advance + completion billing
# ---------------------------------------------------------------------------

def transfer_kernel(link_ref, act_ref, done_ref, total_ref, sizes_ref,
                    bw_ref, mode_ref, dt_ref, month_ref,
                    new_done_ref, comp_ref, tape_ref, recall_ref, mig_ref,
                    egress_ref, cls_a_ref, cls_b_ref):
    """One site's transfer tick + billing. Grid: (S,); blocks (1, F).

    The month-bucketed accumulators (egress bytes, class A/B counts) map
    every site to the same [n_months] block and accumulate across the
    sequential site grid (read-modify-write after an ``i == 0`` init,
    the ``carousel_update.count_kernel`` pattern).
    """
    s = pl.program_id(0)

    @pl.when(s == 0)
    def _init():
        egress_ref[...] = jnp.zeros_like(egress_ref)
        cls_a_ref[...] = jnp.zeros_like(cls_a_ref)
        cls_b_ref[...] = jnp.zeros_like(cls_b_ref)

    ltype = link_ref[0, :] % 3  # 0 tape->disk, 1 gcs->disk, 2 disk->gcs
    onehot = _onehot3(ltype)    # [F, 3]
    act = act_ref[0, :]
    # per-link-type active counts, then broadcast back per transfer — two
    # MXU matmuls instead of a segment-sum + gather
    counts3 = jnp.dot(act[None, :], onehot,
                      preferred_element_type=jnp.float32)  # [1, 3]
    cnt = jnp.dot(onehot, counts3.reshape(3, 1),
                  preferred_element_type=jnp.float32)[:, 0]
    bw = jnp.dot(onehot, bw_ref[...].reshape(3, 1),
                 preferred_element_type=jnp.float32)[:, 0]
    mode = jnp.dot(onehot, mode_ref[...].reshape(3, 1),
                   preferred_element_type=jnp.float32)[:, 0]
    shared = bw / jnp.maximum(cnt, 1.0)
    rate = jnp.where(mode > 0.5, bw, shared)
    total = total_ref[0, :]
    new_done = jnp.minimum(total, done_ref[0, :] + act * rate * dt_ref[0])
    comp = ((new_done >= total) & (act > 0.5)).astype(jnp.float32)
    new_done_ref[0, :] = new_done
    comp_ref[0, :] = comp

    # completion billing, classified by link type
    sz = sizes_ref[0, :]
    comp_sz = sz * comp
    tape_ref[0] = jnp.sum(comp_sz * onehot[:, 0])
    recall_b = jnp.sum(comp_sz * onehot[:, 1])
    recall_ref[0] = recall_b
    mig_ref[0] = jnp.sum(comp_sz * onehot[:, 2])
    month = month_ref[...]
    egress_ref[...] += month * recall_b
    cls_b_ref[...] += month * jnp.sum(comp * onehot[:, 1])
    cls_a_ref[...] += month * jnp.sum(comp * onehot[:, 2])


def transfer_tick(link_id, active, done, total, sizes, bw, mode, dt,
                  month_onehot, interpret: Optional[bool] = None):
    """One fused transfer tick over a lane's [S, F] transfer planes.

    link_id: [S, F] i32 (3*site + type); active: [S, F] bool;
    done/total/sizes: [S, F] f32; bw: [3*S] f32; mode: [3*S] i32/f32;
    dt: f32 scalar; month_onehot: [n_months] f32 selector.

    Returns ``(new_done [S,F] f32, completed [S,F] f32 mask,
    tape_bytes [S], recall_bytes [S], migrate_bytes [S],
    egress_mo [n_months], cls_a_mo [n_months], cls_b_mo [n_months])``.
    """
    if interpret is None:
        interpret = default_interpret()
    S, F = link_id.shape
    n_months = month_onehot.shape[0]
    fp = F + (-F) % F_BLOCK
    args = (
        _pad_f(link_id, fp),
        _pad_f(active.astype(jnp.float32), fp),
        _pad_f(done, fp),
        _pad_f(total, fp, value=jnp.inf),
        _pad_f(sizes, fp),
        bw.reshape(S, 3),
        mode.astype(jnp.float32).reshape(S, 3),
        jnp.reshape(dt, (1,)).astype(jnp.float32),
        month_onehot.astype(jnp.float32),
    )
    row = pl.BlockSpec((1, fp), lambda s: (s, 0))
    site = pl.BlockSpec((1,), lambda s: (s,))
    months = pl.BlockSpec((n_months,), lambda s: (0,))
    out = pl.pallas_call(
        transfer_kernel,
        grid=(S,),
        in_specs=[row, row, row, row, row,
                  pl.BlockSpec((1, 3), lambda s: (s, 0)),
                  pl.BlockSpec((1, 3), lambda s: (s, 0)),
                  pl.BlockSpec((1,), lambda s: (0,)),
                  months],
        out_specs=[row, row, site, site, site, months, months, months],
        out_shape=[
            jax.ShapeDtypeStruct((S, fp), jnp.float32),
            jax.ShapeDtypeStruct((S, fp), jnp.float32),
            jax.ShapeDtypeStruct((S,), jnp.float32),
            jax.ShapeDtypeStruct((S,), jnp.float32),
            jax.ShapeDtypeStruct((S,), jnp.float32),
            jax.ShapeDtypeStruct((n_months,), jnp.float32),
            jax.ShapeDtypeStruct((n_months,), jnp.float32),
            jax.ShapeDtypeStruct((n_months,), jnp.float32),
        ],
        interpret=interpret,
    )(*args)
    new_done, comp = out[0][:, :F], out[1][:, :F]
    return (new_done, comp) + tuple(out[2:])


# ---------------------------------------------------------------------------
# shared-GCS prefix-sum admission
# ---------------------------------------------------------------------------

def gcs_admit_pass_kernel(want_ref, sizes_ref, adm_in_ref, used0_ref,
                          limit_ref, dt_ref, month_ref,
                          adm_ref, used_ref, gbsec_ref, carry_ref):
    """One refinement pass. Grid: (S,) sequential.

    ``adm_in_ref`` is the previous pass's admitted mask entering as a
    true input (buffer-aliased onto ``adm_ref``): each site block is
    visited exactly once per call, so no output window is revisited
    after intervening blocks — compiled Pallas only guarantees VMEM
    persistence across *consecutive* grid steps on the same block.
    ``used0_ref`` is the pass-start occupancy, frozen for the whole pass
    exactly like the jnp oracle's ``gcs_used``. ``carry_ref`` is a
    2-slot accumulator (every step maps to the same block, hence
    persistent; written as an output the caller discards): [0] bytes
    admitted within this pass, [1] running candidate cumsum carried
    across site blocks (the blocked image of the jnp global cumsum)."""
    s = pl.program_id(0)

    @pl.when(s == 0)
    def _pass_init():
        carry_ref[0] = 0.0
        carry_ref[1] = 0.0

    adm_prev = adm_in_ref[...]
    want = want_ref[...] > 0.5
    rem = want & ~(adm_prev > 0.5)
    remf = rem.astype(jnp.float32)
    sz = sizes_ref[...]
    csum = jnp.cumsum(sz * remf, axis=-1) + carry_ref[1]
    new = rem & (used0_ref[0] + csum <= limit_ref[0])
    newf = new.astype(jnp.float32)
    adm_ref[...] = jnp.maximum(adm_prev, newf)
    carry_ref[0] += jnp.sum(sz * newf)
    carry_ref[1] += jnp.sum(sz * remf)
    used = used0_ref[0] + carry_ref[0]
    used_ref[0] = used
    # end-of-tick storage integration (last grid step's write wins, with
    # the pass-end occupancy; the caller keeps the final pass's value)
    gbsec_ref[...] = month_ref[...] * (used / 1e9 * dt_ref[0])


def gcs_admit(want, sizes, gcs_used, gcs_limit, dt, month_onehot,
              n_passes: int, interpret: Optional[bool] = None):
    """Shared-capacity admission over a lane's [S, F] candidate plane.

    want: [S, F] bool migration candidates; sizes: [S, F] f32 bytes;
    gcs_used/gcs_limit: f32 scalars; dt: f32 scalar tick length;
    month_onehot: [n_months] f32; n_passes: refinement passes (static).

    Returns ``(admitted [S, F] f32 mask, gcs_used' f32 scalar,
    gbsec_mo_delta [n_months])`` — the third output is the fused
    ``gcs_used'/1e9*dt`` month-bucketed GB-second integration.

    Each pass is one ``pallas_call`` (see ``gcs_admit_pass_kernel``);
    the admitted mask and the pass-start occupancy flow between passes
    as regular JAX values, the mask donated back in via
    ``input_output_aliases``.
    """
    if interpret is None:
        interpret = default_interpret()
    S, F = want.shape
    n_months = month_onehot.shape[0]
    fp = F + (-F) % F_BLOCK
    row = pl.BlockSpec((1, fp), lambda s: (s, 0))
    one = pl.BlockSpec((1,), lambda s: (0,))
    months = pl.BlockSpec((n_months,), lambda s: (0,))
    admit_pass = pl.pallas_call(
        gcs_admit_pass_kernel,
        grid=(S,),
        in_specs=[row, row, row, one, one, one, months],
        out_specs=[row, one, months, pl.BlockSpec((2,), lambda s: (0,))],
        out_shape=[
            jax.ShapeDtypeStruct((S, fp), jnp.float32),
            jax.ShapeDtypeStruct((1,), jnp.float32),
            jax.ShapeDtypeStruct((n_months,), jnp.float32),
            jax.ShapeDtypeStruct((2,), jnp.float32),
        ],
        input_output_aliases={2: 0},
        interpret=interpret,
    )
    wantf = _pad_f(want.astype(jnp.float32), fp)
    sizesf = _pad_f(sizes, fp)
    limit = jnp.reshape(gcs_limit, (1,)).astype(jnp.float32)
    dtv = jnp.reshape(dt, (1,)).astype(jnp.float32)
    monthf = month_onehot.astype(jnp.float32)
    admitted = jnp.zeros((S, fp), jnp.float32)
    used = jnp.reshape(gcs_used, (1,)).astype(jnp.float32)
    gbsec = monthf * (used[0] / 1e9 * dtv[0])  # n_passes == 0 degenerate
    for _ in range(n_passes):
        admitted, used, gbsec, _carry = admit_pass(
            wantf, sizesf, admitted, used, limit, dtv, monthf)
    return admitted[:, :F], used[0], gbsec


# ---------------------------------------------------------------------------
# candidate-window prefix recurrences
# ---------------------------------------------------------------------------

def window_kernel(live_ref, size_ref, used_ref, limit_ref,
                  adm_ref, extra_ref, *, n_cols: int, fifo: bool):
    """All sites' C-step admission recurrence in one block ([S, C] refs;
    the window is tiny, so C unrolls statically). ``fifo`` adds the
    wait-queue head-blocking carry; operation order matches the jnp
    loops in ``repro.sim.batched`` exactly (bitwise oracle parity)."""
    used = used_ref[:, 0]
    limit = limit_ref[:, 0]
    extra = jnp.zeros_like(used)
    blocked = jnp.zeros_like(used, dtype=jnp.bool_)
    cols = []
    for k in range(n_cols):
        size_k = size_ref[:, k]
        fit = used + extra + size_k <= limit
        live = live_ref[:, k] > 0.5
        if fifo:
            adm = live & fit & ~blocked
            blocked = blocked | (live & ~fit)
        else:
            adm = live & fit
        cols.append(adm.astype(jnp.float32))
        extra = extra + jnp.where(adm, size_k, 0.0)
    adm_ref[...] = jnp.stack(cols, axis=1)
    extra_ref[:, 0] = extra


def window_admit(live, size, disk_used, disk_limit, fifo: bool,
                 interpret: Optional[bool] = None):
    """Admission over a [S, C] candidate window against per-site disk
    headroom. ``fifo=False``: this tick's job arrivals (a non-fitting
    candidate is skipped); ``fifo=True``: the waiting queue (a
    non-fitting live head blocks everything behind it, §5.2).

    Returns ``(admitted [S, C] f32 mask, extra_bytes [S] f32)``.
    """
    if interpret is None:
        interpret = default_interpret()
    S, C = live.shape
    kern = functools.partial(window_kernel, n_cols=C, fifo=bool(fifo))
    adm, extra = pl.pallas_call(
        kern,
        out_shape=[
            jax.ShapeDtypeStruct((S, C), jnp.float32),
            jax.ShapeDtypeStruct((S, 1), jnp.float32),
        ],
        interpret=interpret,
    )(live.astype(jnp.float32), size,
      disk_used.reshape(S, 1), disk_limit.reshape(S, 1))
    return adm, extra[:, 0]
