"""MoE dispatch/combine correctness, including the shard-local EP path."""

import subprocess
import sys
import textwrap

import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.moe import moe_combine, moe_dispatch, router_topk


def test_dispatch_combine_roundtrip_identity():
    """With ample capacity and identity 'experts', combine(dispatch(x))
    reproduces gate-weighted copies of x."""
    rng = np.random.default_rng(0)
    T, d, E, k = 32, 8, 4, 2
    x = jnp.asarray(rng.normal(size=(T, d)).astype(np.float32))
    logits = jnp.asarray(rng.normal(size=(T, E)).astype(np.float32))
    gates, idx, aux = router_topk(logits, k)
    cap = T * k  # dropless
    buf, e_sel, p_sel = moe_dispatch(x, idx, cap, E)
    out = moe_combine(buf, gates, e_sel, p_sel)
    # identity experts: out == sum_k gate_k * x = x (gates normalized)
    np.testing.assert_allclose(np.asarray(out), np.asarray(x),
                               rtol=1e-5, atol=1e-5)
    assert float(aux) > 0


def test_dispatch_capacity_drops_overflow():
    T, d, E, k = 16, 4, 2, 1
    x = jnp.ones((T, d), jnp.float32)
    idx = jnp.zeros((T, k), jnp.int32)  # every token -> expert 0
    cap = 4
    buf, e_sel, p_sel = moe_dispatch(x, idx, cap, E)
    assert buf.shape == (E, cap + 1, d)
    # only `cap` tokens land in real slots; rest in the dead column
    assert float(buf[0, :cap].sum()) == cap * d
    gates = jnp.ones((T, k), jnp.float32)
    out = moe_combine(buf, gates, e_sel, p_sel)
    kept = float((out.sum(-1) > 0).sum())
    assert kept == cap


def test_router_topk_normalized_gates():
    logits = jnp.asarray(np.random.default_rng(1).normal(size=(64, 8)),
                         jnp.float32)
    gates, idx, aux = router_topk(logits, 3)
    np.testing.assert_allclose(np.asarray(gates.sum(-1)),
                               np.ones(64), rtol=1e-5)
    assert int(idx.max()) < 8


@pytest.mark.slow
def test_local_dispatch_matches_global_multidevice():
    """Shard-local dispatch + A2A must match global dispatch (4 host
    devices, dropless capacity). Runs in a subprocess so the forced
    device count does not leak into this test session."""
    prog = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from repro.configs import get_smoke_config
        from repro.models.moe import init_moe, moe_layer
        from repro.parallel.ctx import sharding_ctx

        cfg = get_smoke_config("olmoe_1b_7b").replace(capacity_factor=16.0)
        mesh = jax.make_mesh((2, 2), ("data", "model"))
        p = init_moe(jax.random.PRNGKey(0), cfg)
        x = jax.random.normal(jax.random.PRNGKey(1), (4, 8, cfg.d_model),
                              dtype=cfg.dtype)
        with mesh:
            with sharding_ctx(mesh, moe_local_dispatch=False):
                ref, _ = jax.jit(lambda p, x: moe_layer(p, cfg, x))(p, x)
            with sharding_ctx(mesh, moe_local_dispatch=True):
                loc, _ = jax.jit(lambda p, x: moe_layer(p, cfg, x))(p, x)
        err = float(jnp.max(jnp.abs(ref.astype(jnp.float32)
                                    - loc.astype(jnp.float32))))
        assert err < 0.05, f"local vs global dispatch mismatch: {err}"
        print("OK", err)
    """)
    res = subprocess.run([sys.executable, "-c", prog], capture_output=True,
                         text=True, timeout=300,
                         env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
                              "HOME": "/root",
                              # without this jax hangs probing for non-CPU
                              # backends on machines without accelerators
                              "JAX_PLATFORMS": "cpu"})
    assert "OK" in res.stdout, res.stderr[-2000:]
