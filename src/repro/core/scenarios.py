"""Scenario-config parameterization (paper §5.3 decision workflow).

The paper's decision process compares many HCDC variants — cache (disk)
sizes, cloud egress pricing/peering options, job arrival rates, replica
seeds — against cost and throughput. ``ScenarioSpec`` is the flat,
picklable description of one such variant; ``build_config`` materialises it
into an ``HCDCConfig``; ``expand_grid`` produces the Cartesian product of
spec axes for ``repro.sim.sweep``.

A spec is deliberately a *parameterization*, not a config: it stays tiny
(plain scalars, trivially serialisable to YAML/JSON/CSV and across process
boundaries), while the heavyweight ``HCDCConfig`` (policies, site lists,
distributions) is rebuilt deterministically inside each worker.
"""

from __future__ import annotations

import hashlib
import itertools
import json
import math
from dataclasses import asdict, dataclass, fields, replace
from typing import Any, Dict, Iterable, List, Mapping, Optional, Sequence

import numpy as np

from repro.core.hcdc import HCDCConfig, make_config
from repro.sim.cloud import MONTH_SECONDS, PEERING_PRICES
from repro.sim.distributions import BoundedExponential, TruncatedNormalCount
from repro.sim.engine import DAY
from repro.sim.infrastructure import GiB, TB
from repro.sim.transfer import LinkTickTable
from repro.sim.workload import parse_workload

#: Valid ``ScenarioSpec.egress`` values: tiered internet egress or one of
#: the paper's §5.3 peering alternatives.
EGRESS_OPTIONS = ("internet",) + tuple(sorted(PEERING_PRICES))


@dataclass(frozen=True)
class ScenarioSpec:
    """One point of the §5.3 decision grid.

    ``None`` always means "keep the base configuration's value"; use
    ``float('inf')`` to request an explicitly unlimited cache/cold tier.
    """

    base: str = "III"  # Table 5 configuration name: I | II | III
    days: float = 2.0  # simulated horizon
    n_files: int = 20_000  # catalogue size per site
    seed: int = 0
    cache_tb: Optional[float] = None  # per-site hot (disk) cache limit, TB
    gcs_limit_tb: Optional[float] = None  # cold-tier limit, TB (0 = disabled)
    egress: str = "internet"  # internet | direct | interconnect
    storage_price: Optional[float] = None  # USD per GB-month override
    egress_price: Optional[float] = None  # flat USD/GiB egress override
    job_rate_scale: float = 1.0  # scales the job arrival rate
    # access-pattern model: "steady" | "diurnal" | "campaign" | "zipf-drift"
    # | "trace:PATH", with optional "name:key=value,..." parameters
    # (repro.sim.workload.parse_workload syntax; see docs/workloads.md)
    workload: str = "steady"
    curves: bool = False  # record Fig 6/8 time series

    def __post_init__(self) -> None:
        if self.base not in ("I", "II", "III"):
            raise ValueError(f"unknown base configuration {self.base!r}")
        if self.egress not in EGRESS_OPTIONS:
            raise ValueError(
                f"egress must be one of {EGRESS_OPTIONS}, got {self.egress!r}")
        if not self.days or self.days <= 0:
            raise ValueError(f"days must be > 0, got {self.days!r}")
        if self.n_files <= 0:
            raise ValueError(f"n_files must be > 0, got {self.n_files!r}")
        if not self.job_rate_scale or self.job_rate_scale <= 0:
            raise ValueError(
                f"job_rate_scale must be > 0, got {self.job_rate_scale!r}")
        if self.egress_price is not None and self.egress_price < 0:
            raise ValueError(
                f"egress_price must be >= 0, got {self.egress_price!r}")
        # Unknown workload names, bad parameters, and missing/malformed
        # trace CSVs fail here — at spec-parse time — not in a worker.
        parse_workload(self.workload)

    @property
    def label(self) -> str:
        """Compact human-readable identifier, stable across runs."""
        cache = ("base" if self.cache_tb is None
                 else "inf" if math.isinf(self.cache_tb)
                 else f"{self.cache_tb:g}TB")
        parts = [f"cfg{self.base}", f"cache={cache}", f"egress={self.egress}"]
        if self.gcs_limit_tb is not None:
            gcs = "inf" if math.isinf(self.gcs_limit_tb) else f"{self.gcs_limit_tb:g}TB"
            parts.append(f"gcs={gcs}")
        if self.storage_price is not None:
            parts.append(f"stor={self.storage_price:g}")
        if self.egress_price is not None:
            parts.append(f"egp={self.egress_price:g}")
        if self.job_rate_scale != 1.0:
            parts.append(f"rate={self.job_rate_scale:g}x")
        if self.workload != "steady":
            parts.append(f"wl={self.workload}")
        parts.append(f"seed={self.seed}")
        return ",".join(parts)

    def to_dict(self) -> Dict[str, Any]:
        return asdict(self)


def build_config(spec: ScenarioSpec) -> HCDCConfig:
    """Materialise a spec into a fully independent ``HCDCConfig``."""
    cfg = make_config(spec.base,
                      simulated_time=int(spec.days * DAY),
                      n_files_per_site=spec.n_files,
                      seed=spec.seed,
                      curves=spec.curves)
    if spec.cache_tb is not None:
        limit = None if math.isinf(spec.cache_tb) else spec.cache_tb * TB
        for site in cfg.sites:
            site.disk_limit = limit
    if spec.gcs_limit_tb is not None:
        cfg.gcs_limit = (None if math.isinf(spec.gcs_limit_tb)
                         else spec.gcs_limit_tb * TB)
    if spec.egress != "internet":
        cfg.cost_model = replace(cfg.cost_model, peering=spec.egress)
    if spec.storage_price is not None:
        cfg.cost_model = replace(cfg.cost_model,
                                 storage_per_gb_month=spec.storage_price)
    if spec.egress_price is not None:
        cfg.cost_model = replace(cfg.cost_model,
                                 flat_egress_per_gib=spec.egress_price)
    if spec.job_rate_scale != 1.0:
        # Scaling mu and sigma together scales the truncated-normal mean
        # exactly: max(kX, 0) = k max(X, 0) for k > 0.
        cfg.jobs_mu *= spec.job_rate_scale
        cfg.jobs_sigma *= spec.job_rate_scale
    cfg.workload = parse_workload(spec.workload)
    return cfg


_SPEC_FIELDS = {f.name for f in fields(ScenarioSpec)}


def expand_grid(axes: Mapping[str, Any]) -> List[ScenarioSpec]:
    """Cartesian product of spec axes into a spec list.

    Values may be scalars (fixed for the whole sweep) or sequences (swept).
    ``{"cache_tb": [50, 100], "egress": ["internet", "direct"], "seed":
    [0, 1], "days": 1}`` expands to 2 x 2 x 2 = 8 specs. Axis order in the
    result follows the mapping's iteration order, last axis fastest.
    """
    unknown = set(axes) - _SPEC_FIELDS
    if unknown:
        raise ValueError(f"unknown spec fields: {sorted(unknown)} "
                         f"(valid: {sorted(_SPEC_FIELDS)})")
    names: List[str] = []
    levels: List[Sequence[Any]] = []
    for name, value in axes.items():
        if isinstance(value, (list, tuple)):
            names.append(name)
            levels.append(value)
        else:
            names.append(name)
            levels.append([value])
    return [ScenarioSpec(**dict(zip(names, combo)))
            for combo in itertools.product(*levels)]


def specs_from_mapping(doc: Mapping[str, Any]) -> List[ScenarioSpec]:
    """Parse a sweep document (already-loaded YAML/JSON) into specs.

    Two accepted shapes::

        {"axes": {...}, "days": 1, ...}     # grid + shared fixed fields
        {"scenarios": [{...}, {...}], ...}  # explicit spec list + shared

    Shared top-level fields apply to every spec unless the axis/scenario
    overrides them.
    """
    doc = dict(doc)
    axes = doc.pop("axes", None)
    scenarios = doc.pop("scenarios", None)
    shared = {k: v for k, v in doc.items() if k in _SPEC_FIELDS}
    extra = set(doc) - _SPEC_FIELDS
    if extra:
        raise ValueError(f"unknown top-level fields: {sorted(extra)}")
    if (axes is None) == (scenarios is None):
        raise ValueError("provide exactly one of 'axes' or 'scenarios'")
    if axes is not None:
        merged = dict(shared)
        merged.update(axes)
        return expand_grid(merged)
    specs = []
    for s in scenarios:
        s = dict(s)
        unknown = set(s) - _SPEC_FIELDS
        if unknown:
            raise ValueError(f"unknown scenario fields: {sorted(unknown)} "
                             f"(valid: {sorted(_SPEC_FIELDS)})")
        specs.append(ScenarioSpec(**{**shared, **s}))
    return specs


def with_seeds(specs: Iterable[ScenarioSpec], n_seeds: int,
               first_seed: int = 0) -> List[ScenarioSpec]:
    """Replicate each spec across ``n_seeds`` consecutive seeds.

    On the batched backend each seed replica is a dedicated dynamics lane
    (the seed feeds the catalogue/job-stream draw), so an N-seed grid packs
    as N× the lanes and every reported metric can carry a seed-level
    mean ± CI (``repro.sim.decide.summarize``).
    """
    if n_seeds < 1:
        raise ValueError(f"n_seeds must be >= 1, got {n_seeds!r}")
    return [replace(s, seed=first_seed + k)
            for s in specs for k in range(n_seeds)]


#: Spec fields that enter only the bill, never the simulated dynamics.
#: Specs differing only here share one simulated lane on the batched
#: backend and are billed separately (``pack_specs``); the decision layer
#: exploits the same fact to price-sweep a lane for free.
PRICING_FIELDS = ("egress", "storage_price", "egress_price")


def dynamics_key(spec: ScenarioSpec) -> ScenarioSpec:
    """Canonical per-lane identity: the spec with pricing-only fields reset.

    Two specs with equal dynamics keys simulate identically (same catalogue,
    same job stream, same tick dynamics) and differ at most in how the run
    is billed. ``seed`` is *not* stripped: seed replicas are distinct lanes.
    """
    return replace(spec, egress="internet", storage_price=None,
                   egress_price=None)


def strip_seed(spec: ScenarioSpec) -> ScenarioSpec:
    """Canonical across-seed group identity (seed reset to 0)."""
    return replace(spec, seed=0)


#: Version of the persisted result-entry schema (``repro.sim.cache``).
#: Part of every cache key and stored inside every entry: bump it whenever
#: the meaning of a stored payload changes — simulation dynamics, metric
#: definitions, the monthly-totals billing contract — and every stale
#: entry becomes unreachable (new keys) *and* rejected on direct reads
#: (entry-side version check), forcing recomputation.
RESULT_SCHEMA_VERSION = 1


def engine_fingerprint(backend: str = "process",
                       tick: Optional[float] = None,
                       tick_impl: Optional[str] = None) -> str:
    """Canonical engine identity for result caching.

    The event-driven reference engine is bit-deterministic per spec, so
    ``"process"`` alone identifies it. The fixed-tick batched engine's
    outputs depend on its clock step, so the tick value is part of the
    fingerprint (``"jax:60"``); ``lane_chunk``/``devices`` are excluded —
    chunked execution is bitwise identical to the unchunked run. The two
    engines agree statistically, not bitwise, so their entries never
    substitute for each other.

    ``tick_impl`` (jax backend only) is the *resolved* kernel
    implementation (``repro.kernels.registry``): ``"jnp"`` (or ``None``)
    keeps the legacy ``jax:<tick>`` fingerprint — the jnp program *is*
    the pre-registry engine bit-for-bit, so its entries stay shared —
    while the Pallas implementations append their name
    (``"jax:60:pallas"``), because kernel results match the jnp oracle
    statistically (blocked-cumsum admission ties, fused-multiply-add
    rounding), not bitwise, and must never cross-serve. ``"auto"`` is
    rejected here: resolve it per host *before* keying
    (``resolve_tick_impl``), otherwise one key could name two different
    programs on two machines.

    Legacy-store caveat: entries written *before* this axis existed by a
    TPU host carry the bare ``jax:<tick>`` key but came from the old
    auto-selected interpret-mode kernel (~1 ulp off the jnp program), so
    they would cross-serve ``"jnp"`` requests within tolerance but not
    bitwise. No known store was written on an accelerator host; if one
    exists, drop its ``jax:*`` entries or bump
    :data:`RESULT_SCHEMA_VERSION` instead of sharing the key.
    """
    if backend == "process":
        return "process"
    if backend == "jax":
        t = 10.0 if tick is None else float(tick)
        impl = "jnp" if tick_impl is None else str(tick_impl)
        if impl == "jnp":
            return f"jax:{t:g}"
        if impl in ("pallas", "pallas_interpret"):
            return f"jax:{t:g}:{impl}"
        raise ValueError(
            f"tick_impl {tick_impl!r} cannot be fingerprinted (expected "
            "a resolved implementation: 'jnp', 'pallas' or "
            "'pallas_interpret'; resolve 'auto' first)")
    raise ValueError(f"unknown backend {backend!r} "
                     "(expected 'process' or 'jax')")


def cache_key(spec: ScenarioSpec, backend: str = "process",
              tick: Optional[float] = None,
              tick_impl: Optional[str] = None) -> str:
    """Content address of a spec's *dynamics* result (sha256 hex digest).

    The key hashes the canonical JSON of ``(schema version, engine
    fingerprint, dynamics_key(spec))``: pricing-only fields (the
    ``PRICING_FIELDS``) are reset first, so every pricing variant of one
    simulated lane maps to the same entry and is re-billed at read time;
    any dynamics-affecting field — seed included — lands on a different
    key. Pure content hashing (no ``hash()``/``id()``) keeps the key
    stable across process restarts and machines.
    """
    doc = {
        "schema": RESULT_SCHEMA_VERSION,
        "engine": engine_fingerprint(backend, tick, tick_impl),
        "spec": asdict(dynamics_key(spec)),
    }
    blob = json.dumps(doc, sort_keys=True, separators=(",", ":"))
    return hashlib.sha256(blob.encode("utf-8")).hexdigest()


# --------------------------------------------------------------------------
# Continuous-axis refinement helpers (the ``repro.sim.decide`` vocabulary).
# --------------------------------------------------------------------------

#: Spec axes that take ordered scalar values and can therefore be bisected
#: by the adaptive refinement / break-even solvers. ``None`` entries (keep
#: the base config) and ``inf`` (unlimited) are valid grid *levels* but are
#: never interpolated against.
CONTINUOUS_AXES = ("cache_tb", "gcs_limit_tb", "storage_price",
                   "egress_price", "job_rate_scale")


def axis_value(spec: ScenarioSpec, axis: str) -> Optional[float]:
    """The spec's value on a continuous axis (``None`` = base default)."""
    if axis not in CONTINUOUS_AXES:
        raise ValueError(f"axis must be one of {CONTINUOUS_AXES}, "
                         f"got {axis!r}")
    return getattr(spec, axis)


def with_axis(spec: ScenarioSpec, axis: str, value: float) -> ScenarioSpec:
    """The spec moved to ``value`` on a continuous axis (re-validated)."""
    if axis not in CONTINUOUS_AXES:
        raise ValueError(f"axis must be one of {CONTINUOUS_AXES}, "
                         f"got {axis!r}")
    return replace(spec, **{axis: value})


def refine_levels(values: Sequence[float], anchors: Sequence[float],
                  rel_tol: float) -> List[float]:
    """Midpoints to add around ``anchors`` in a sorted axis-level set.

    For every anchor value (an axis coordinate of a frontier point) the
    midpoint towards each finite neighbor in ``values`` is proposed, unless
    the gap is already within ``rel_tol`` of the finite axis span. The
    returned midpoints are deduplicated and sorted; non-finite levels
    (``inf`` = unlimited) and ``None`` levels are never interpolated.
    """
    finite = sorted({float(v) for v in values
                     if v is not None and math.isfinite(v)})
    if len(finite) < 2:
        return []
    span = finite[-1] - finite[0]
    if span <= 0:
        return []
    out = set()
    for a in anchors:
        if a is None or not math.isfinite(a) or a not in finite:
            continue
        i = finite.index(a)
        for j in (i - 1, i + 1):
            if 0 <= j < len(finite):
                gap = abs(finite[j] - a)
                if gap > rel_tol * span:
                    out.add((a + finite[j]) / 2.0)
    return sorted(out)


# --------------------------------------------------------------------------
# Spec grid -> dense lane arrays (the ``backend="jax"`` packing).
# --------------------------------------------------------------------------

def _pow2_bucket(n: int) -> int:
    """Round ``n`` up to the next power of two (0 stays 0).

    The batched backend's compiled-program cache keys on array shapes;
    bucketing the data-dependent job-window dimensions (K, J) keeps one
    bursty lane from forcing a fresh XLA trace for every grid it touches.
    """
    return 1 << (n - 1).bit_length() if n > 0 else 0


@dataclass
class PackedGrid:
    """A spec grid packed into dense per-lane arrays for ``repro.sim.batched``.

    Lane ``l`` is one ``ScenarioSpec``. All catalogue randomness (file sizes,
    popularity) and the per-tick job-count stream replicate the event-driven
    engine's host RNG draw order exactly, so both backends simulate the same
    files and the same arrival process; per-job file selection and run
    durations are drawn from the continuation of the same per-lane stream
    (the event engine interleaves those draws with event execution, so they
    are statistically — not bitwise — equivalent).

    Shapes: L lanes, S sites, F files/site, J jobs/site (padded),
    M = 3*S links (per site: tape->disk, gcs->disk, disk->gcs),
    T simulation ticks, Mo 30-day month buckets.
    """

    specs: List[ScenarioSpec]
    site_names: List[str]
    horizon: int  # simulated seconds
    tick: float  # simulation step dt (seconds)
    n_months: int  # month buckets covering the horizon
    full_months: int  # complete 30-day months (always billed)
    max_jobs_per_tick: int  # K bound for the per-tick submission loop
    #: spec index -> dynamics lane. The ``PRICING_FIELDS`` (egress option,
    #: storage price, flat egress price) only enter the bill, never the
    #: simulated dynamics, so specs that differ only in pricing (equal
    #: ``dynamics_key``) share one simulated lane and are billed separately
    #: (the paper's §5.3 "compare pricing options on the same workload").
    #: The ``workload`` axis *does* change the dynamics (it reshapes the
    #: packed job stream), so workload-only-differing specs never share a
    #: lane.
    lane_of: np.ndarray  # [n_specs] i32
    # per-lane scenario parameters
    disk_limit: np.ndarray  # [L,S] f32 bytes (inf = unlimited)
    gcs_enabled: np.ndarray  # [L] bool
    gcs_limit: np.ndarray  # [L] f32 bytes (inf = unlimited)
    min_migrate_pop: np.ndarray  # [L] f32 (migration-policy threshold)
    link_bw: np.ndarray  # [L,M] f32 bytes/s
    link_slots: np.ndarray  # [L,M] f32 (inf = unlimited)
    link_latency: np.ndarray  # [L,M] f32 seconds
    link_mode: np.ndarray  # [L,M] i32 (1 = per-transfer throughput)
    # per-lane catalogue + job stream
    sizes: np.ndarray  # [L,S,F] f32 bytes
    pop: np.ndarray  # [L,S,F] f32
    job_fid: np.ndarray  # [L,S,J] i32
    job_submit_tick: np.ndarray  # [L,S,J] i32 (== T for padding)
    job_submit_time: np.ndarray  # [L,S,J] f32 seconds
    job_tail: np.ndarray  # [L,S,J] f32: download + run duration, seconds
    jobs_per_tick: np.ndarray  # [L,T,S] i32
    n_jobs: np.ndarray  # [L,S] i32 (true, unpadded counts)
    #: compiled per-lane workload schedule: the arrival-rate multiplier on
    #: each *generator* tick (gen_interval spacing, not the simulation
    #: tick). Already folded into ``jobs_per_tick``/``job_*`` above — kept
    #: for inspection and cross-backend schedule tests.
    rate_mult: np.ndarray  # [L,G] f32
    # tick grid (shared by every lane)
    times: np.ndarray  # [T] f32 tick clock values (times[0] == 0)
    dts: np.ndarray  # [T] f32 step durations (dts[0] == 0)
    month_idx: np.ndarray  # [T] i32 month bucket per tick
    # host-side billing
    cost_models: List[Any]  # GCSCostModel per lane

    @property
    def n_specs(self) -> int:
        return len(self.specs)

    @property
    def n_lanes(self) -> int:
        """Distinct simulated dynamics lanes (<= ``n_specs``)."""
        return int(self.sizes.shape[0])

    @property
    def n_ticks(self) -> int:
        return int(self.times.shape[0])


def _require_uniform(name: str, values: Sequence[Any]) -> Any:
    distinct = set(values)
    if len(distinct) > 1:
        raise ValueError(
            f"backend='jax' requires a uniform {name!r} across the grid "
            f"(lanes share one tick/array layout), got {sorted(distinct)}")
    return values[0]


def pack_specs(specs: Sequence[ScenarioSpec], tick: float = 10.0,
               bucket: bool = True) -> PackedGrid:
    """Pack a spec grid into the dense arrays the batched backend consumes.

    Every lane must share ``days`` and ``n_files`` (they set the shared tick
    count and file-array width); all other axes — cache/GCS limits, egress
    pricing, storage price, job rate, workload model, seed — vary freely
    per lane (the workload schedule reshapes the packed job stream, so
    workload-differing specs get distinct dynamics lanes; only pricing-only
    variants share one). ``curves`` is not supported (time-series live on
    the event engine).

    Catalogue and job-stream sampling is memoized per (base, seed,
    n_files, rate, workload) draw key: lanes that differ only in capacity
    limits (``cache_tb``/``gcs_limit_tb``) replicate the reference
    engine's RNG stream *identically*, so the host draw runs once and the
    arrays are shared.

    ``bucket=True`` (default) rounds the data-dependent job-window shapes
    — K (``max_jobs_per_tick``) and J (padded jobs/site) — up to powers of
    two. Padding slots carry ``job_submit_tick == T`` (never reached), so
    the simulated per-lane state is bitwise unchanged (the two f32
    aggregates summed over the J axis move by reduction-order ulp only)
    while the batched backend's compile cache stops retracing per
    data-dependent shape (``tests/test_batched.py`` pins the claim).
    """
    specs = list(specs)
    if not specs:
        raise ValueError("cannot pack an empty spec list")
    if tick <= 0:
        raise ValueError(f"tick must be > 0 seconds, got {tick!r}")
    _require_uniform("days", [s.days for s in specs])
    _require_uniform("n_files", [s.n_files for s in specs])
    if any(s.curves for s in specs):
        raise ValueError("curves=True requires backend='process' "
                         "(the batched backend records no time series)")

    all_cfgs = [build_config(s) for s in specs]
    _require_uniform("site count", [len(c.sites) for c in all_cfgs])
    _require_uniform("gen_interval", [c.gen_interval for c in all_cfgs])
    for cfg in all_cfgs:
        if cfg.tape_latency_sigma > 0:
            raise ValueError("tape_latency_sigma > 0 requires "
                             "backend='process'")
        if cfg.cold_deletion_policy.capacity_threshold is not None:
            raise ValueError("cold-deletion trimming requires "
                             "backend='process'")

    # Deduplicate dynamics: the ``PRICING_FIELDS`` (egress choice, storage
    # price, flat egress price) feed only the cost model (``build_config``
    # touches nothing else for them), so specs that differ only there
    # simulate as one lane and are billed per spec.
    lane_index: Dict[ScenarioSpec, int] = {}
    lane_of = np.zeros(len(specs), dtype=np.int32)
    cfgs = []
    lane_specs: List[ScenarioSpec] = []
    for i, spec in enumerate(specs):
        key = dynamics_key(spec)
        if key not in lane_index:
            lane_index[key] = len(cfgs)
            cfgs.append(all_cfgs[i])
            lane_specs.append(key)
        lane_of[i] = lane_index[key]

    L = len(cfgs)
    S = len(cfgs[0].sites)
    F = cfgs[0].n_files_per_site
    horizon = cfgs[0].simulated_time

    # Shared tick grid: 0, tick, 2*tick, ..., horizon (final step may be
    # shorter so the horizon endpoint is always simulated, like the event
    # engine's ``run(until=horizon)``).
    grid = np.arange(0, horizon + 1e-9, tick, dtype=np.float64)
    if grid[-1] < horizon:
        grid = np.append(grid, float(horizon))
    times = grid.astype(np.float32)
    dts = np.diff(grid, prepend=0.0).astype(np.float32)
    T = len(times)
    n_months = max(1, int(np.ceil(horizon / MONTH_SECONDS)))
    full_months = int(horizon // MONTH_SECONDS)
    month_idx = np.minimum((grid // MONTH_SECONDS).astype(np.int32),
                           n_months - 1)

    disk_limit = np.full((L, S), np.inf, dtype=np.float32)
    gcs_enabled = np.zeros(L, dtype=bool)
    gcs_limit = np.full(L, np.inf, dtype=np.float32)
    min_pop = np.zeros(L, dtype=np.float32)
    sizes = np.zeros((L, S, F), dtype=np.float32)
    pop = np.zeros((L, S, F), dtype=np.float32)
    tables = []
    per_lane_jobs = []  # (fid, submit_tick, submit_time, tail) per site
    rate_mults = []  # [G] per lane: compiled workload arrival schedule

    def _draw_lane(cfg):
        """Host-side RNG work for one dynamics lane: catalogue (sizes,
        popularity) and the pre-sampled job stream. Replicates the event
        engine's draw order; memoized below because lanes differing only
        in capacity limits consume an identical stream."""
        rng = np.random.default_rng(cfg.seed)
        size_dist = BoundedExponential(cfg.size_lam, cfg.size_lo, cfg.size_hi,
                                       unit=GiB)
        l_sizes = np.zeros((S, F), dtype=np.float32)
        l_pop = np.zeros((S, F), dtype=np.float32)
        cum_ws = []
        for si in range(S):
            # Same draw order as ``hcdc._SiteState``: sizes, then popularity.
            l_sizes[si] = size_dist.sample(rng, F)
            l_pop[si] = cfg.popularity.sample_popularity(rng, F)
            cum_ws.append(cfg.popularity.selection_cdf(l_pop[si]))
        # Same draw as ``HCDCScenario.__init__``: the pre-sampled job
        # stream, modulated by the (deterministic, RNG-free) workload
        # schedule exactly as the event engine modulates its own stream.
        n_gen = cfg.simulated_time // cfg.gen_interval + 1
        counts = TruncatedNormalCount(cfg.jobs_mu, cfg.jobs_sigma).sample(
            rng, (S, n_gen))
        sched = cfg.workload.compile(n_gen, cfg.gen_interval)
        counts = counts * sched.rate_mult
        gen_times = np.arange(n_gen, dtype=np.float64) * cfg.gen_interval
        dur_dist = BoundedExponential(cfg.dur_lam, lo=cfg.dur_lo)
        lane_jobs = []
        for si in range(S):
            emitted = np.diff(np.floor(np.cumsum(counts[si])),
                              prepend=0.0).astype(np.int64)
            j_times = np.repeat(gen_times, emitted)
            u = rng.random(len(j_times))
            durs = dur_dist.sample(rng, len(j_times))
            if sched.sel_power is None:
                fid = np.searchsorted(cum_ws[si], u,
                                      side="right").astype(np.int32)
            else:
                # Popularity drift: each job selects with the power of its
                # generator tick. Powers are piecewise constant (a few
                # distinct values), so one CDF per value suffices — the
                # same quantization the event engine's cum_w cache uses.
                j_power = sched.sel_power[np.repeat(np.arange(n_gen),
                                                    emitted)]
                fid = np.zeros(len(u), dtype=np.int32)
                for p in np.unique(j_power):
                    cdf = cfg.popularity.selection_cdf(l_pop[si],
                                                      power=float(p))
                    sel = j_power == p
                    fid[sel] = np.searchsorted(cdf, u[sel], side="right")
            dl = l_sizes[si, fid].astype(np.float64) / cfg.download
            tail = np.maximum(1, (dl + durs).astype(np.int64))
            j_tick = np.searchsorted(grid, j_times, side="left").astype(np.int32)
            lane_jobs.append((fid, j_tick, j_times.astype(np.float32),
                              tail.astype(np.float32)))
        return l_sizes, l_pop, lane_jobs, sched.rate_mult.astype(np.float32)

    draw_cache: Dict[ScenarioSpec, tuple] = {}
    for li, cfg in enumerate(cfgs):
        # Capacity limits never touch the RNG stream: lanes that differ
        # only in cache_tb/gcs_limit_tb share one host-side draw.
        draw_key = replace(lane_specs[li], cache_tb=None, gcs_limit_tb=None)
        if draw_key not in draw_cache:
            draw_cache[draw_key] = _draw_lane(cfg)
        l_sizes, l_pop, lane_jobs, rate_mult = draw_cache[draw_key]
        sizes[li] = l_sizes
        pop[li] = l_pop
        per_lane_jobs.append(lane_jobs)
        rate_mults.append(rate_mult)
        for si, site in enumerate(cfg.sites):
            disk_limit[li, si] = (np.inf if site.disk_limit is None
                                  else site.disk_limit)

        gcs_enabled[li] = cfg.gcs_enabled
        gcs_limit[li] = np.inf if cfg.gcs_limit is None else cfg.gcs_limit
        min_pop[li] = cfg.migration_policy.min_popularity
        rates, slots, lats = [], [], []
        for site in cfg.sites:
            rates += [site.tape_to_disk_mb_s, cfg.gcs_to_disk, cfg.disk_to_gcs]
            slots += [cfg.max_active] * 3
            lats += [cfg.tape_latency, 0.0, 0.0]
        tables.append(LinkTickTable.from_values(rates, slots, lats))

    J = max(len(j[0]) for lane in per_lane_jobs for j in lane)
    if bucket:
        J = _pow2_bucket(J)
    job_fid = np.zeros((L, S, J), dtype=np.int32)
    job_submit_tick = np.full((L, S, J), T, dtype=np.int32)
    job_submit_time = np.zeros((L, S, J), dtype=np.float32)
    job_tail = np.zeros((L, S, J), dtype=np.float32)
    jobs_per_tick = np.zeros((L, T, S), dtype=np.int32)
    n_jobs = np.zeros((L, S), dtype=np.int32)
    for li, lane_jobs in enumerate(per_lane_jobs):
        for si, (fid, j_tick, j_time, tail) in enumerate(lane_jobs):
            n = len(fid)
            n_jobs[li, si] = n
            job_fid[li, si, :n] = fid
            job_submit_tick[li, si, :n] = j_tick
            job_submit_time[li, si, :n] = j_time
            job_tail[li, si, :n] = tail
            jobs_per_tick[li, :, si] = np.bincount(j_tick, minlength=T)
    max_jobs_per_tick = int(jobs_per_tick.max()) if jobs_per_tick.size else 0
    if bucket:
        # Extra window slots read padded/later-tick entries, which the
        # kernel's validity mask rejects — bitwise no-op, stable trace.
        max_jobs_per_tick = _pow2_bucket(max_jobs_per_tick)

    return PackedGrid(
        specs=specs,
        site_names=[s.name for s in cfgs[0].sites],
        horizon=horizon,
        tick=float(tick),
        n_months=n_months,
        full_months=full_months,
        max_jobs_per_tick=max_jobs_per_tick,
        lane_of=lane_of,
        disk_limit=disk_limit,
        gcs_enabled=gcs_enabled,
        gcs_limit=gcs_limit,
        min_migrate_pop=min_pop,
        link_bw=np.stack([t.bw for t in tables]),
        link_slots=np.stack([t.slots for t in tables]),
        link_latency=np.stack([t.latency for t in tables]),
        link_mode=np.stack([t.mode for t in tables]),
        sizes=sizes,
        pop=pop,
        job_fid=job_fid,
        job_submit_tick=job_submit_tick,
        job_submit_time=job_submit_time,
        job_tail=job_tail,
        jobs_per_tick=jobs_per_tick,
        n_jobs=n_jobs,
        rate_mult=np.stack(rate_mults),
        times=times,
        dts=dts,
        month_idx=month_idx,
        cost_models=[c.cost_model for c in all_cfgs],
    )
