"""Serving steps + a minimal batched serving loop.

``make_prefill_step`` / ``make_decode_step`` return pure functions for
``jax.jit`` lowering: prefill consumes the prompt and fills per-layer
caches (ring buffers for local-attention layers); decode advances one
token for the whole batch. ``ServeLoop`` is the batched request driver
used by ``examples/serve_small.py``: greedy sampling, round-based
continuous batching with slot recycling.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Dict, List

import jax
import jax.numpy as jnp

from repro.models import decode_step, init_cache, prefill
from repro.models.config import ModelConfig
from repro.parallel.ctx import sharding_ctx


def make_prefill_step(cfg: ModelConfig, mesh=None, **ctx_opts) -> Callable:
    def prefill_step(params, batch, cache):
        with sharding_ctx(mesh, **ctx_opts):
            return prefill(cfg, params, batch, cache)

    return prefill_step


def make_decode_step(cfg: ModelConfig, mesh=None, **ctx_opts) -> Callable:
    def serve_step(params, tokens, cache, t):
        with sharding_ctx(mesh, **ctx_opts):
            logits, new_cache = decode_step(cfg, params, tokens, cache, t)
        next_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
        return next_tok, logits, new_cache

    return serve_step


@dataclass
class Request:
    rid: int
    prompt: jnp.ndarray  # [T] int32
    max_new: int = 16
    out: List[int] = field(default_factory=list)
    done: bool = False


class ServeLoop:
    """Small continuous-batching loop (slot-per-request, greedy)."""

    def __init__(self, cfg: ModelConfig, params, batch_slots: int,
                 max_len: int):
        self.cfg = cfg
        self.params = params
        self.slots = batch_slots
        self.max_len = max_len
        self.decode = jax.jit(make_decode_step(cfg))
        self.prefill = jax.jit(make_prefill_step(cfg))

    def run(self, requests: List[Request]) -> Dict[int, List[int]]:
        """Serve requests in waves of `slots` (simple admission policy)."""
        results: Dict[int, List[int]] = {}
        queue = list(requests)
        while queue:
            wave = queue[: self.slots]
            queue = queue[len(wave):]
            # pad the wave to full slots by repeating the last prompt
            prompts = [r.prompt for r in wave]
            T = max(p.shape[0] for p in prompts)
            toks = jnp.stack([
                jnp.pad(p, (T - p.shape[0], 0)) for p in prompts
            ] + [jnp.zeros((T,), jnp.int32)] * (self.slots - len(wave)))
            cache = init_cache(self.cfg, self.slots, self.max_len)
            logits, cache = self.prefill(self.params, {"tokens": toks}, cache)
            cur = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
            t = T
            max_new = max(r.max_new for r in wave)
            outs = [cur]
            for _ in range(max_new - 1):
                cur, _, cache = self.decode(self.params, cur, cache,
                                            jnp.int32(t))
                outs.append(cur)
                t += 1
            gen = jnp.concatenate(outs, axis=1)
            for i, r in enumerate(wave):
                results[r.rid] = [int(x) for x in gen[i][: r.max_new]]
        return results
