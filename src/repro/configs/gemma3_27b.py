"""gemma3-27b [dense]: 62L d_model=5376 32H (GQA kv=16) d_ff=21504
vocab=262144, 5:1 local:global (window 1024), 128k context.
[hf:google/gemma-3-1b-pt; unverified]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-27b", family="dense",
    n_layers=62, d_model=5376, n_heads=32, n_kv_heads=16,
    d_ff=21504, vocab_size=262144,
    sliding_window=1024, global_every=6,  # layers 5, 11, ... are global
    rope_theta=1_000_000.0,
)

def smoke_config() -> ModelConfig:
    return CONFIG.replace(n_layers=6, d_model=64, n_heads=4, n_kv_heads=2,
                          d_ff=128, vocab_size=512, sliding_window=8,
                          remat=False)
