"""Persistent, content-addressed scenario-result cache (ISSUE 6).

The paper's decision workflow re-runs the same simulation grid from CI
jobs, nightly benchmarks, and interactive ``decide.py`` sessions — the
compute-vs-store tradeoff Yuan et al. analyze for derived scientific data
applies to our own results. This module stores each simulated *dynamics
lane* once, on disk, keyed by content:

- **Key** (``repro.core.scenarios.cache_key``): sha256 over the canonical
  JSON of ``(RESULT_SCHEMA_VERSION, engine fingerprint,
  dynamics_key(spec))``. Pricing-only spec fields (egress option, storage
  price, flat egress price) are stripped by ``dynamics_key``, so every
  pricing variant of a lane shares one entry; any dynamics-affecting
  field — seed included — produces a different key. Keys are stable
  across processes and machines (no ``hash()`` randomization).
- **Entry**: one JSON file holding the pricing-independent payload — the
  dynamics metrics (per-month bill keys stripped), the raw monthly
  billing inputs, events, wall time, series digests — plus a provenance
  manifest (spec, engine, package/python/numpy versions, host, creation
  time). Serving a spec re-bills the stored monthly totals through the
  spec's own cost model (``bills_from_monthly_totals``), which is
  bit-identical to a fresh run on the same engine: the same floats flow
  through the same pricing formulas.
- **Durability**: entries are committed via write-to-temp + ``os.replace``
  (atomic on POSIX), so concurrent writers and killed processes can never
  publish a torn entry — the last complete writer wins. Reads treat *any*
  malformed entry (truncated, zero-byte, garbage, wrong schema version)
  as a miss: the entry is deleted and the caller recomputes, rewriting a
  valid one. A cache can lose work, never correctness.
- **Backends**: ``StorageBackend`` is a three-method protocol
  (read/write/delete over opaque names) — ``LocalDirBackend`` implements
  it on a directory; an object-store backend slots in by mapping names to
  object keys and implementing atomic-visibility puts.

``run_sweep(cache=...)`` and ``SweepDriver(cache=...)`` read through this
module (get-or-compute), so refinement rounds, ``decide()`` solvers,
cross-backend checks, and benchmarks all share one store. See
``docs/simulation.md`` ("Result cache & provenance").

The store doubles as the *checkpoint journal* for fault-tolerant sweeps
(``repro.sim.jobs``): completed jobs are written through as they finish,
so a killed run leaves a valid prefix and ``--resume`` recomputes only
what is missing, while the corruption-is-a-miss repair path above is
what makes injected corrupted reads (``repro.sim.faults``) recoverable.
See ``docs/resilience.md``.
"""

from __future__ import annotations

import json
import os
import socket
import sys
import time
import uuid
from dataclasses import asdict, dataclass

import numpy as np
from typing import (TYPE_CHECKING, Any, Dict, Iterable, Iterator, List,
                    Optional, Protocol, Tuple, Union, runtime_checkable)

from repro.obs.metrics import get_registry
from repro.obs.trace import get_tracer
from repro.sim.cloud import bills_from_monthly_totals
from repro.sim.sweep import ScenarioResult
from repro.version import __version__

if TYPE_CHECKING:  # repro.core imports repro.sim; keep runtime acyclic
    from repro.core.scenarios import ScenarioSpec

#: Metric-key prefix of the pricing-dependent per-month bill entries both
#: engines add (``month1.storage_usd`` ...). Stripped before an entry is
#: stored and recomputed from the spec's cost model at serve time.
_MONTH_METRIC_PREFIX = "month"

#: Keys every stored ``monthly`` block must carry, all list-valued and of
#: equal length (one element per closed billing month).
_MONTHLY_ARRAYS = ("gb_seconds", "egress_bytes", "class_a", "class_b")


@runtime_checkable
class StorageBackend(Protocol):
    """Minimal blob-store interface the cache runs on.

    Names are opaque relative identifiers (``ab/ab12...f.json``). ``write``
    MUST be atomic-visibility: a concurrent ``read`` sees either a previous
    complete blob or the new complete blob, never a prefix — on a local
    filesystem that is write-to-temp + rename; on an object store, a
    single-request put. ``read`` returns ``None`` for a missing name and
    ``delete`` ignores one: the cache treats every storage hiccup as a
    miss, never an error.
    """

    def read(self, name: str) -> Optional[bytes]:
        ...

    def write(self, name: str, data: bytes) -> None:
        ...

    def delete(self, name: str) -> None:
        ...


class LocalDirBackend:
    """``StorageBackend`` on a local directory (one file per entry)."""

    def __init__(self, root: Union[str, os.PathLike]):
        self.root = os.fspath(root)

    def __repr__(self) -> str:
        return f"LocalDirBackend({self.root!r})"

    def _path(self, name: str) -> str:
        return os.path.join(self.root, name)

    def read(self, name: str) -> Optional[bytes]:
        try:
            with open(self._path(name), "rb") as f:
                return f.read()
        except OSError:
            return None

    def write(self, name: str, data: bytes) -> None:
        path = self._path(name)
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        # Unique temp name per writer (pid + random suffix), published via
        # os.replace: atomic on POSIX, so a reader never observes a torn
        # entry and concurrent same-key writers race to an arbitrary but
        # *complete* winner. A killed writer leaves only a .tmp. orphan,
        # which readers never look at.
        tmp = f"{path}.tmp.{os.getpid()}.{uuid.uuid4().hex[:8]}"
        try:
            with open(tmp, "wb") as f:
                f.write(data)
                f.flush()
                os.fsync(f.fileno())
            os.replace(tmp, path)
        except OSError:
            try:
                os.remove(tmp)
            except OSError:
                pass
            raise

    def delete(self, name: str) -> None:
        try:
            os.remove(self._path(name))
        except OSError:
            pass

    def names(self) -> Iterator[str]:
        """All published entry names (maintenance/stats; not part of the
        ``StorageBackend`` protocol)."""
        for dirpath, _dirs, files in os.walk(self.root):
            for fn in files:
                if fn.endswith(".json") and ".tmp." not in fn:
                    yield os.path.relpath(os.path.join(dirpath, fn),
                                          self.root)


@dataclass
class CacheStats:
    """Counters for one ``ResultCache`` instance's lifetime."""

    hits: int = 0
    misses: int = 0
    corrupt: int = 0  # entries rejected (and deleted) on read
    writes: int = 0

    def as_dict(self) -> Dict[str, int]:
        return {"hits": self.hits, "misses": self.misses,
                "corrupt": self.corrupt, "writes": self.writes}


def entry_name(key: str) -> str:
    """Relative storage name of a key's entry, sharded by prefix so a
    local backend never accumulates millions of files in one directory."""
    return f"{key[:2]}/{key}.json"


class _BadEntry(ValueError):
    """An entry failed structural validation (treated as corrupt)."""


def _validate_entry(doc: Any) -> Dict[str, Any]:
    """Structural validation of a decoded entry; raises ``_BadEntry``.

    Anything that would make the serve path crash or lie — wrong shape,
    wrong schema version, mismatched monthly arrays, non-numeric values —
    rejects the entry so the caller recomputes instead.
    """
    from repro.core.scenarios import RESULT_SCHEMA_VERSION

    if not isinstance(doc, dict):
        raise _BadEntry("entry is not an object")
    if doc.get("schema_version") != RESULT_SCHEMA_VERSION:
        raise _BadEntry(f"schema_version {doc.get('schema_version')!r} != "
                        f"{RESULT_SCHEMA_VERSION}")
    payload = doc.get("payload")
    if not isinstance(payload, dict):
        raise _BadEntry("missing payload")
    if not isinstance(payload.get("metrics"), dict):
        raise _BadEntry("missing metrics")
    if not all(isinstance(v, (int, float))
               for v in payload["metrics"].values()):
        raise _BadEntry("non-numeric metric")
    monthly = payload.get("monthly")
    if not isinstance(monthly, dict):
        raise _BadEntry("missing monthly totals")
    n = None
    for k in _MONTHLY_ARRAYS:
        v = monthly.get(k)
        if not isinstance(v, list) or \
                not all(isinstance(x, (int, float)) for x in v):
            raise _BadEntry(f"monthly.{k} is not a numeric list")
        if n is None:
            n = len(v)
        elif len(v) != n:
            raise _BadEntry("monthly arrays disagree in length")
    if not isinstance(monthly.get("full_months"), int):
        raise _BadEntry("monthly.full_months is not an int")
    if not isinstance(payload.get("events"), int):
        raise _BadEntry("events is not an int")
    if not isinstance(payload.get("series", {}), dict):
        raise _BadEntry("series is not an object")
    return doc


def _dynamics_metrics(metrics: Dict[str, float]) -> Dict[str, float]:
    """The pricing-independent metrics: per-month bill keys stripped."""
    return {k: v for k, v in metrics.items()
            if not (k.startswith(_MONTH_METRIC_PREFIX)
                    and (k.endswith(".storage_usd")
                         or k.endswith(".network_usd")))}


def _serve(spec: "ScenarioSpec", payload: Dict[str, Any]) -> ScenarioResult:
    """Materialize a stored dynamics payload as the *requested* spec's
    result: re-bill the raw monthly totals through the spec's own cost
    model. Bit-identical to a fresh run on the same engine — the stored
    floats round-trip JSON exactly and pass through the same formulas."""
    from repro.core.scenarios import build_config

    cost_model = build_config(spec).cost_model
    mo = payload["monthly"]
    bills = bills_from_monthly_totals(
        cost_model, mo["gb_seconds"], mo["egress_bytes"],
        mo["class_a"], mo["class_b"], mo["full_months"])
    metrics = dict(payload["metrics"])
    for i, bill in enumerate(bills):
        metrics[f"month{i+1}.storage_usd"] = bill.storage_usd
        metrics[f"month{i+1}.network_usd"] = bill.network_usd
    return ScenarioResult(
        spec=spec,
        metrics=metrics,
        storage_usd=sum(b.storage_usd for b in bills),
        network_usd=sum(b.network_usd for b in bills),
        ops_usd=sum(b.ops_usd for b in bills),
        wall_s=float(payload.get("wall_s", 0.0)),
        events=int(payload["events"]),
        series={k: dict(v) for k, v in payload.get("series", {}).items()},
        monthly={"gb_seconds": list(mo["gb_seconds"]),
                 "egress_bytes": list(mo["egress_bytes"]),
                 "class_a": list(mo["class_a"]),
                 "class_b": list(mo["class_b"]),
                 "full_months": mo["full_months"]},
    )


class ResultCache:
    """Get-or-compute front of the persistent result store.

    ``get``/``put`` move single results; ``fetch``/``store`` are the batch
    forms ``run_sweep``/``SweepDriver`` use. All reads are fail-open: a
    missing, unreadable, or invalid entry is a miss (invalid ones are
    deleted so the recompute's ``put`` repairs the store), and ``stats``
    counts hits/misses/corrupt/writes for reporting.
    """

    def __init__(self, backend: Union[StorageBackend, str, os.PathLike]):
        if isinstance(backend, (str, os.PathLike)):
            backend = LocalDirBackend(backend)
        self.backend = backend
        self.stats = CacheStats()

    def __repr__(self) -> str:
        return f"ResultCache({self.backend!r}, stats={self.stats.as_dict()})"

    # -- single-entry interface ---------------------------------------------
    def get(self, spec: "ScenarioSpec", backend: str = "process",
            tick: Optional[float] = None,
            tick_impl: Optional[str] = None) -> Optional[ScenarioResult]:
        """The spec's result served from the store, or ``None`` (miss).

        ``tick_impl`` (jax backend only) must be a *resolved* kernel
        implementation name; it is part of the key, so entries from
        different implementations never cross-serve (``"jnp"``/``None``
        share the legacy key — see ``engine_fingerprint``).
        """
        from repro.core.scenarios import cache_key

        key = cache_key(spec, backend=backend, tick=tick,
                        tick_impl=tick_impl)
        reg = get_registry()
        with get_tracer().span("cache.get", key=key[:12]):
            data = self.backend.read(entry_name(key))
            if data is None:
                self.stats.misses += 1
                reg.inc("cache.misses", help="Result-cache lookup misses")
                return None
            try:
                doc = _validate_entry(json.loads(data.decode("utf-8")))
                with get_tracer().span("cache.rebill", key=key[:12]):
                    result = _serve(spec, doc["payload"])
            except Exception:
                # Truncated/garbage JSON, wrong schema version, structural
                # rot: never crash, never serve bad data — drop the entry
                # and let the caller recompute (whose put() rewrites a
                # valid one).
                self.stats.corrupt += 1
                self.stats.misses += 1
                reg.inc("cache.corrupt",
                        help="Result-cache entries dropped as invalid")
                reg.inc("cache.misses", help="Result-cache lookup misses")
                self.backend.delete(entry_name(key))
                return None
            self.stats.hits += 1
            reg.inc("cache.hits", help="Result-cache lookup hits")
            return result

    def put(self, spec: "ScenarioSpec", result: ScenarioResult,
            backend: str = "process", tick: Optional[float] = None,
            tick_impl: Optional[str] = None) -> bool:
        """Store a result's dynamics payload under the spec's key.

        Returns ``False`` (and stores nothing) for results without raw
        monthly totals — synthetic ``ScenarioResult``s that never
        simulated cannot be re-billed and must not populate the store.
        """
        from repro.core.scenarios import cache_key

        if not result.monthly:
            return False
        key = cache_key(spec, backend=backend, tick=tick,
                        tick_impl=tick_impl)
        self._write_entry(key, spec, result, backend, tick, tick_impl)
        return True

    # -- batch interface (what run_sweep/SweepDriver call) ------------------
    def fetch(self, specs: Iterable["ScenarioSpec"],
              backend: str = "process", tick: Optional[float] = None,
              tick_impl: Optional[str] = None
              ) -> Dict["ScenarioSpec", ScenarioResult]:
        """Served results for every spec with a stored entry (hits only)."""
        out: Dict["ScenarioSpec", ScenarioResult] = {}
        for spec in dict.fromkeys(specs):
            result = self.get(spec, backend=backend, tick=tick,
                              tick_impl=tick_impl)
            if result is not None:
                out[spec] = result
        return out

    def store(self, pairs: Iterable[Tuple["ScenarioSpec", ScenarioResult]],
              backend: str = "process", tick: Optional[float] = None,
              tick_impl: Optional[str] = None) -> int:
        """Store a batch of (spec, result) pairs; one write per distinct
        key (pricing variants of a lane collapse to one entry). Returns
        the number of entries written."""
        from repro.core.scenarios import cache_key

        written = 0
        done = set()
        for spec, result in pairs:
            if not result.monthly:
                continue
            key = cache_key(spec, backend=backend, tick=tick,
                            tick_impl=tick_impl)
            if key in done:
                continue
            done.add(key)
            self._write_entry(key, spec, result, backend, tick, tick_impl)
            written += 1
        return written

    # -- entry codec --------------------------------------------------------
    def _write_entry(self, key: str, spec: "ScenarioSpec",
                     result: ScenarioResult, backend: str,
                     tick: Optional[float],
                     tick_impl: Optional[str] = None) -> None:
        from repro.core.scenarios import (RESULT_SCHEMA_VERSION,
                                          dynamics_key, engine_fingerprint)

        doc = {
            "schema_version": RESULT_SCHEMA_VERSION,
            "key": key,
            "manifest": {
                "spec": asdict(dynamics_key(spec)),
                "engine": engine_fingerprint(backend, tick, tick_impl),
                "backend": backend,
                "tick": None if backend == "process" else float(
                    10.0 if tick is None else tick),
                "tick_impl": (None if backend == "process"
                              else tick_impl or "jnp"),
                "package_version": __version__,
                "python": sys.version.split()[0],
                "numpy": np.__version__,
                "host": socket.gethostname(),
                "created_unix": time.time(),
                "wall_s": result.wall_s,
            },
            "payload": {
                "metrics": _dynamics_metrics(result.metrics),
                "monthly": result.monthly,
                "events": int(result.events),
                "wall_s": result.wall_s,
                "series": result.series,
            },
        }
        with get_tracer().span("cache.put", key=key[:12]):
            self.backend.write(entry_name(key),
                               json.dumps(doc).encode("utf-8"))
        self.stats.writes += 1
        get_registry().inc("cache.writes",
                           help="Result-cache entries written")


def as_cache(cache: Union["ResultCache", StorageBackend, str, os.PathLike,
                          None]) -> Optional["ResultCache"]:
    """Coerce a user-supplied cache argument into a ``ResultCache``."""
    if cache is None or isinstance(cache, ResultCache):
        return cache
    return ResultCache(cache)


__all__: List[str] = [
    "StorageBackend", "LocalDirBackend", "CacheStats", "ResultCache",
    "as_cache", "entry_name",
]
