"""Pure-jnp oracle for flash attention (masked full-score softmax)."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def attention_ref(q, k, v, *, causal: bool = True, window: int = 0):
    """q: [B, nh, T, hd]; k/v: [B, nkv, S, hd]. Returns [B, nh, T, hd]."""
    B, nh, T, hd = q.shape
    nkv = k.shape[1]
    if nkv != nh:
        rep = nh // nkv
        k = jnp.repeat(k, rep, axis=1)
        v = jnp.repeat(v, rep, axis=1)
    s = jnp.einsum("bhtd,bhsd->bhts", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * (hd ** -0.5)
    S = k.shape[2]
    rel = jnp.arange(T)[:, None] - jnp.arange(S)[None, :]
    mask = jnp.ones((T, S), dtype=bool)
    if causal:
        mask &= rel >= 0
    if window > 0:
        mask &= rel < window
    s = jnp.where(mask[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhts,bhsd->bhtd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)
