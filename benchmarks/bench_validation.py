"""Benchmark: paper Table 2 — simulation correctness validation.

Runs the §4.2 validation scenario (full 59d19h horizon by default) and
prints every Table-2 metric against the paper's simulated values.
"""

from __future__ import annotations

import time
from typing import Dict, List

import numpy as np

from repro.core.validation import (
    PAPER_TABLE2,
    ValidationConfig,
    ValidationScenario,
)
from repro.sim.engine import DAY
from repro.sim.output import mean_and_error


def run(n_runs: int = 2, horizon_days: float = None) -> List[Dict]:
    rows = []
    per_run = {k: [] for k in PAPER_TABLE2}
    wall = []
    for seed in range(n_runs):
        cfg = ValidationConfig(seed=seed)
        if horizon_days is not None:
            cfg.simulated_time = int(horizon_days * DAY)
        t0 = time.time()
        m = ValidationScenario(cfg).run()
        wall.append(time.time() - t0)
        for k in per_run:
            per_run[k].append(m[k])
    for k, ref in PAPER_TABLE2.items():
        mean, sd, se = mean_and_error(per_run[k])
        rows.append({
            "name": f"table2.{k}",
            "us_per_call": np.mean(wall) * 1e6,
            "derived": mean,
            "paper": ref,
            "diff_pct": 100.0 * (mean - ref) / ref,
            "sd_pct": sd,
        })
    return rows


def main() -> None:
    for r in run(n_runs=2):
        print(f"{r['name']},{r['us_per_call']:.0f},{r['derived']:.4g},"
              f"paper={r['paper']:.4g},diff={r['diff_pct']:+.2f}%")


if __name__ == "__main__":
    main()
