"""Generate EXPERIMENTS.md roofline/dry-run tables from results/dryrun."""

import glob
import json
import os
import sys

RESULTS = sys.argv[1] if len(sys.argv) > 1 else "results/dryrun"


def fmt_bytes(b):
    if b is None:
        return "-"
    return f"{b/1e9:.2f}"


def main():
    cells = []
    for path in sorted(glob.glob(os.path.join(RESULTS, "*.json"))):
        with open(path) as f:
            cells.append(json.load(f))
    base = [c for c in cells if not c.get("tag")]
    print("### Dry-run grid (baseline)\n")
    print("| arch | shape | mesh | status | compile s | temp GB | args GB |"
          " plan |")
    print("|---|---|---|---|---|---|---|---|")
    for c in sorted(base, key=lambda c: (c["arch"], c["shape"], c["mesh"])):
        if c["status"] == "ok":
            m = c["memory"]
            plan = c["plan"]
            pl = (f"fsdp={'T' if plan['fsdp'] else 'F'},"
                  f"micro={plan['microbatches']},{plan['optimizer']}")
            print(f"| {c['arch']} | {c['shape']} | {c['mesh']} | ok | "
                  f"{c['compile_s']} | {fmt_bytes(m['temp_bytes'])} | "
                  f"{fmt_bytes(m['argument_bytes'])} | {pl} |")
        else:
            print(f"| {c['arch']} | {c['shape']} | {c['mesh']} | "
                  f"{c['status']} | - | - | - | "
                  f"{c.get('reason', c.get('error', ''))[:60]} |")

    print("\n### Roofline terms (single-pod 16x16 baseline)\n")
    print("| arch | shape | compute s | memory s | collective s | dominant |"
          " MODEL_FLOPS | useful ratio | roofline frac |")
    print("|---|---|---|---|---|---|---|---|---|")
    for c in sorted(base, key=lambda c: (c["arch"], c["shape"])):
        if c["status"] != "ok" or c["mesh"] != "16x16":
            continue
        r = c["roofline"]
        print(f"| {c['arch']} | {c['shape']} | {r['compute_s']:.4f} | "
              f"{r['memory_s']:.4f} | {r['collective_s']:.4f} | "
              f"{r['dominant'].replace('_s','')} | {r['model_flops']:.3e} | "
              f"{r['useful_flops_ratio']:.3f} | "
              f"{r['roofline_fraction']:.4f} |")

    finals = [c for c in cells if c.get("tag") == "final"]
    if finals:
        print("\n### Roofline terms — FINAL optimized framework\n")
        print("| arch | shape | mesh | compute s | memory s | collective s |"
              " dominant | roofline frac | temp GB |")
        print("|---|---|---|---|---|---|---|---|---|")
        for c in sorted(finals, key=lambda c: (c["arch"], c["shape"], c["mesh"])):
            if c["status"] != "ok":
                continue
            r = c["roofline"]
            print(f"| {c['arch']} | {c['shape']} | {c['mesh']} | "
                  f"{r['compute_s']:.4f} | {r['memory_s']:.4f} | "
                  f"{r['collective_s']:.4f} | {r['dominant'].replace('_s','')} | "
                  f"{r['roofline_fraction']:.4f} | "
                  f"{fmt_bytes(c['memory']['temp_bytes'])} |")

    tags = sorted({c.get("tag") for c in cells if c.get("tag")} - {"final"})
    if tags:
        print("\n### Perf iterations\n")
        print("| tag | arch | shape | compute s | memory s | collective s |"
              " dominant | roofline frac | temp GB |")
        print("|---|---|---|---|---|---|---|---|---|")
        for c in sorted(cells, key=lambda c: (c.get("tag", ""), c["arch"])):
            if not c.get("tag") or c.get("tag") == "final" or c["status"] != "ok":
                continue
            r = c["roofline"]
            print(f"| {c['tag']} | {c['arch']} | {c['shape']} | "
                  f"{r['compute_s']:.4f} | {r['memory_s']:.4f} | "
                  f"{r['collective_s']:.4f} | {r['dominant'].replace('_s','')} | "
                  f"{r['roofline_fraction']:.4f} | "
                  f"{fmt_bytes(c['memory']['temp_bytes'])} |")


if __name__ == "__main__":
    main()
