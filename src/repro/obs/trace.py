"""Span-based tracing with Chrome trace-event export (ISSUE 8).

``Tracer.span`` wraps a phase of the sweep/decision pipeline — spec
packing, device compile+dispatch, per-chunk ``simulate_packed``, cache
get/put/re-bill, refinement rounds — in a context manager that records a
complete-duration event. ``dump`` writes the Chrome trace-event JSON
format, loadable in Perfetto (https://ui.perfetto.dev) or
``chrome://tracing``; every event carries the tracer's ``run_id`` in its
``args`` so traces from multiple runs correlate.

The tracer is **disabled by default**: an idle span is one attribute
check and a no-op context manager, so library code can wrap hot phases
unconditionally. The CLIs enable it when ``--trace-out`` is given.

``jax_device_profile`` is the optional deep-dive hook: when tracing is
enabled and jax is importable it brackets the block with
``jax.profiler.start_trace``/``stop_trace`` (TensorBoard/XProf format,
per-HLO timing on the compiled path); otherwise it is a no-op, so the
module stays importable — and every caller runnable — without jax.

The span → call-site map lives in ``docs/observability.md``
("Trace-span map"). Spans are parent-process only: worker processes
(pool or fleet) ship metrics deltas back, not spans.
"""

from __future__ import annotations

import json
import os
import threading
import time
import uuid
from contextlib import contextmanager
from typing import Any, Dict, Iterable, List, Optional


class Tracer:
    """Process-local span recorder (Chrome trace-event JSON).

    Spans nest naturally per thread — the Chrome format reconstructs the
    flame graph from (tid, ts, dur) of complete ("ph": "X") events, so
    no explicit parent bookkeeping is needed.
    """

    def __init__(self, run_id: Optional[str] = None, enabled: bool = False):
        self.enabled = enabled
        self.run_id = run_id or uuid.uuid4().hex[:8]
        self._events: List[Dict[str, Any]] = []
        self._lock = threading.Lock()

    # -- switches -----------------------------------------------------------
    def enable(self, run_id: Optional[str] = None) -> None:
        if run_id is not None:
            self.run_id = run_id
        self.enabled = True

    def disable(self) -> None:
        self.enabled = False

    def reset(self) -> None:
        with self._lock:
            self._events.clear()

    # -- recording ----------------------------------------------------------
    @contextmanager
    def span(self, name: str, **args: Any):
        """Record a complete-duration event around the wrapped block.

        ``args`` become the event's ``args`` payload (JSON-safe values
        only; non-serializable values are ``repr``-ed at dump time).
        Exceptions propagate; the span still closes and is annotated
        with ``error=True``.
        """
        if not self.enabled:
            yield
            return
        t0 = time.perf_counter_ns()
        try:
            yield
        except BaseException:
            args = dict(args, error=True)
            raise
        finally:
            t1 = time.perf_counter_ns()
            self._append({
                "name": name, "ph": "X", "cat": "repro",
                "ts": t0 // 1000, "dur": max((t1 - t0) // 1000, 1),
                "pid": os.getpid(), "tid": threading.get_ident(),
                "args": {**args, "run_id": self.run_id},
            })

    def instant(self, name: str, **args: Any) -> None:
        """Record a zero-duration marker event."""
        if not self.enabled:
            return
        self._append({
            "name": name, "ph": "i", "s": "p", "cat": "repro",
            "ts": time.perf_counter_ns() // 1000,
            "pid": os.getpid(), "tid": threading.get_ident(),
            "args": {**args, "run_id": self.run_id},
        })

    def _append(self, event: Dict[str, Any]) -> None:
        with self._lock:
            self._events.append(event)

    # -- export -------------------------------------------------------------
    @property
    def events(self) -> List[Dict[str, Any]]:
        with self._lock:
            return list(self._events)

    def to_chrome_dict(self) -> Dict[str, Any]:
        """The Chrome trace-event JSON document (Perfetto-loadable)."""
        return {
            "traceEvents": self.events,
            "displayTimeUnit": "ms",
            "otherData": {"run_id": self.run_id,
                          "exported_unix": time.time()},
        }

    def dump(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.to_chrome_dict(), f, indent=1, default=repr)


#: Process-global tracer: disabled until a CLI (or test) enables it.
_TRACER = Tracer()


def get_tracer() -> Tracer:
    """The process-global :class:`Tracer`."""
    return _TRACER


@contextmanager
def jax_device_profile(logdir: Optional[str]):
    """Optional ``jax.profiler`` bracket for the compiled path.

    Active only when ``logdir`` is set, the global tracer is enabled,
    and jax imports cleanly — every other combination is a silent no-op
    so callers never need to gate on jax availability.
    """
    if not logdir or not _TRACER.enabled:
        yield
        return
    try:
        import jax
    except Exception:
        yield
        return
    jax.profiler.start_trace(logdir)
    try:
        yield
    finally:
        jax.profiler.stop_trace()


__all__: Iterable[str] = ["Tracer", "get_tracer", "jax_device_profile"]
