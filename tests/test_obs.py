"""Unit tests for the ``repro.obs`` telemetry layer (ISSUE 8)."""

import json

import pytest

from repro.obs.metrics import (
    DEFAULT_BUCKETS,
    MetricsRegistry,
    get_registry,
    snapshot_and_reset,
    split_series_name,
)
from repro.obs.trace import Tracer, get_tracer, jax_device_profile


# ------------------------------------------------------------------ metrics
class TestMetricsRegistry:
    def test_counter_inc_and_value(self):
        r = MetricsRegistry()
        r.inc("cache.hits")
        r.inc("cache.hits", 2.0)
        assert r.value("cache.hits") == 3.0
        assert r.value("cache.misses") == 0.0  # default

    def test_labels_are_sorted_into_one_series(self):
        r = MetricsRegistry()
        r.inc("x", b="2", a="1")
        r.inc("x", a="1", b="2")
        snap = r.snapshot()
        assert snap["counters"] == {"x{a=1,b=2}": 2.0}

    def test_split_series_name_round_trip(self):
        assert split_series_name("x{a=1,b=2}") == ("x", {"a": "1",
                                                        "b": "2"})
        assert split_series_name("plain") == ("plain", {})

    def test_gauge_last_write_wins(self):
        r = MetricsRegistry()
        r.set_gauge("lanes.simulated", 5)
        r.set_gauge("lanes.simulated", 0)
        assert r.value("lanes.simulated") == 0.0

    def test_histogram_observe(self):
        r = MetricsRegistry()
        for v in (0.002, 0.2, 100.0):
            r.observe("wall_s", v)
        h = r.snapshot()["histograms"]["wall_s"]
        assert h["count"] == 3
        assert h["sum"] == pytest.approx(100.202)
        assert sum(h["counts"]) == 3
        assert h["counts"][-1] == 1  # 100.0 lands in +Inf
        assert h["bounds"] == list(DEFAULT_BUCKETS)

    def test_disabled_registry_records_nothing(self):
        r = MetricsRegistry(enabled=False)
        r.inc("a")
        r.set_gauge("b", 1.0)
        r.observe("c", 1.0)
        snap = r.snapshot()
        assert snap == {"counters": {}, "gauges": {}, "histograms": {}}

    def test_merge_worker_delta(self):
        """The pool round trip: worker snapshot deltas fold into the
        parent — counters/histograms add, gauges assign."""
        parent, worker = MetricsRegistry(), MetricsRegistry()
        parent.inc("scenario.runs", 2)
        parent.observe("wall_s", 1.0)
        worker.inc("scenario.runs", 3)
        worker.set_gauge("lanes.simulated", 7)
        worker.observe("wall_s", 2.0)
        delta = snapshot_and_reset(worker)
        assert worker.snapshot()["counters"] == {}  # reset cleared it
        parent.merge(delta)
        assert parent.value("scenario.runs") == 5.0
        assert parent.value("lanes.simulated") == 7.0
        h = parent.snapshot()["histograms"]["wall_s"]
        assert h["count"] == 2 and h["sum"] == pytest.approx(3.0)

    def test_merge_into_disabled_registry_still_lands(self):
        # merge() is bookkeeping, not new measurement: a parent that
        # disabled collection still folds worker deltas faithfully.
        parent = MetricsRegistry(enabled=False)
        parent.merge({"counters": {"a": 1.0}})
        assert parent.value("a") == 1.0
        assert parent.enabled is False

    def test_prometheus_exposition(self):
        r = MetricsRegistry()
        r.inc("cache.hits", 3, help="Result-cache lookup hits")
        r.inc("tick_impl.resolved", impl="jnp")
        r.observe("wall_s", 0.3)
        text = r.to_prometheus()
        assert "# HELP cache_hits Result-cache lookup hits" in text
        assert "# TYPE cache_hits counter" in text
        assert "cache_hits 3" in text
        assert 'tick_impl_resolved{impl="jnp"} 1' in text
        assert 'wall_s_bucket{le="+Inf"} 1' in text
        assert "wall_s_count 1" in text

    def test_dump_json_vs_prometheus(self, tmp_path):
        r = MetricsRegistry()
        r.inc("a", 2)
        jpath, ppath = tmp_path / "m.json", tmp_path / "m.prom"
        r.dump(str(jpath))
        r.dump(str(ppath))
        doc = json.loads(jpath.read_text())
        assert doc["counters"] == {"a": 2.0}
        assert "exported_unix" in doc
        assert "# TYPE a counter" in ppath.read_text()

    def test_global_registry_is_a_singleton(self):
        assert get_registry() is get_registry()


# -------------------------------------------------------------------- trace
class TestTracer:
    def test_disabled_span_records_nothing(self):
        tr = Tracer()
        with tr.span("phase"):
            pass
        assert tr.events == []

    def test_enabled_span_records_complete_event(self):
        tr = Tracer(run_id="abc", enabled=True)
        with tr.span("simulate", lanes=4):
            pass
        (ev,) = tr.events
        assert ev["name"] == "simulate" and ev["ph"] == "X"
        assert ev["dur"] >= 1
        assert ev["args"] == {"lanes": 4, "run_id": "abc"}

    def test_span_annotates_and_propagates_exceptions(self):
        tr = Tracer(enabled=True)
        with pytest.raises(RuntimeError):
            with tr.span("boom"):
                raise RuntimeError("x")
        (ev,) = tr.events
        assert ev["args"]["error"] is True

    def test_chrome_dict_and_dump(self, tmp_path):
        tr = Tracer(run_id="rid1", enabled=True)
        with tr.span("a"):
            pass
        tr.instant("marker", note="hi")
        path = tmp_path / "trace.json"
        tr.dump(str(path))
        doc = json.loads(path.read_text())
        assert doc["otherData"]["run_id"] == "rid1"
        names = [e["name"] for e in doc["traceEvents"]]
        assert names == ["a", "marker"]

    def test_enable_sets_run_id_and_reset_clears(self):
        tr = Tracer()
        tr.enable(run_id="zz")
        assert tr.enabled and tr.run_id == "zz"
        with tr.span("a"):
            pass
        tr.reset()
        assert tr.events == []

    def test_global_tracer_disabled_by_default(self):
        assert get_tracer() is get_tracer()

    def test_jax_device_profile_noop_when_disabled(self):
        # tracer disabled -> silent no-op even with a logdir
        with jax_device_profile("/tmp/never-used"):
            pass
        with jax_device_profile(None):
            pass
