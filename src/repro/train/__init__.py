"""Training substrate: optimizers, train step, gradient compression."""

from repro.train.optimizer import adamw, adafactor, make_optimizer
from repro.train.train_step import make_train_step

__all__ = ["adamw", "adafactor", "make_optimizer", "make_train_step"]
