"""Serving correctness: prefill + decode vs. full forward, per arch."""

import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCHITECTURES, get_smoke_config
from repro.models import decode_step, forward, init_cache, init_params, prefill

B, T = 2, 16


def _setup(arch, key):
    cfg = get_smoke_config(arch)
    if cfg.family == "moe":
        cfg = cfg.replace(capacity_factor=8.0)  # dropless: exact compare
    params = init_params(cfg, key)
    tokens = jax.random.randint(key, (B, T), 0, cfg.vocab_size)
    batch = {"tokens": tokens}
    fe = 0
    if cfg.frontend == "vision":
        batch["frontend"] = jax.random.normal(
            key, (B, cfg.frontend_tokens, cfg.frontend_dim)).astype(cfg.dtype)
        fe = cfg.frontend_tokens
    if cfg.is_enc_dec:
        batch["enc_input"] = jax.random.normal(key, (B, 16, cfg.frontend_dim))
    return cfg, params, batch, tokens, fe


@pytest.mark.parametrize("arch", ARCHITECTURES)
def test_prefill_then_decode_matches_forward(arch):
    key = jax.random.PRNGKey(0)
    cfg, params, batch, tokens, fe = _setup(arch, key)
    cache = init_cache(cfg, B, max_len=T + fe + 8)
    logits_pf, cache = prefill(cfg, params, batch, cache)
    new_tok = jax.random.randint(jax.random.PRNGKey(7), (B, 1), 0,
                                 cfg.vocab_size)
    logits_dec, cache = decode_step(cfg, params, new_tok, cache,
                                    jnp.int32(T + fe))
    full = {**batch, "tokens": jnp.concatenate([tokens, new_tok], axis=1)}
    logits_full, _ = forward(cfg, params, full)
    e1 = float(jnp.max(jnp.abs(logits_pf - logits_full[:, T - 1 + fe])))
    e2 = float(jnp.max(jnp.abs(logits_dec - logits_full[:, -1])))
    assert e1 < 0.15, f"prefill mismatch {e1}"
    assert e2 < 0.15, f"decode mismatch {e2}"


def test_ring_buffer_cache_equals_full_cache():
    """Local-attention layers with ring buffers must decode identically to a
    full-length cache once the window covers the lookback."""
    key = jax.random.PRNGKey(1)
    cfg = get_smoke_config("gemma3_27b")  # sliding_window=8
    params = init_params(cfg, key)
    tokens = jax.random.randint(key, (B, 12), 0, cfg.vocab_size)
    cache = init_cache(cfg, B, max_len=32)
    # ring buffers exist: local layers' cache length == window
    lens = [e["kv"]["k"].shape[1] for e in cache["layers"]]
    assert min(lens) == cfg.sliding_window
    assert max(lens) == 32
    _, cache = prefill(cfg, params, {"tokens": tokens}, cache)
    nt = jax.random.randint(jax.random.PRNGKey(2), (B, 1), 0, cfg.vocab_size)
    logits, _ = decode_step(cfg, params, nt, cache, jnp.int32(12))
    full = jnp.concatenate([tokens, nt], axis=1)
    ref, _ = forward(cfg, params, {"tokens": full})
    assert float(jnp.max(jnp.abs(logits - ref[:, -1]))) < 0.15


def test_serve_loop_end_to_end():
    from repro.serve.engine import Request, ServeLoop

    cfg = get_smoke_config("qwen3_4b")
    params = init_params(cfg, jax.random.PRNGKey(0))
    loop = ServeLoop(cfg, params, batch_slots=2, max_len=64)
    reqs = [Request(rid=i,
                    prompt=jax.random.randint(jax.random.PRNGKey(i), (8,), 0,
                                              cfg.vocab_size),
                    max_new=4)
            for i in range(3)]
    out = loop.run(reqs)
    assert set(out) == {0, 1, 2}
    assert all(len(v) == 4 for v in out.values())
    assert all(0 <= t < cfg.vocab_size for v in out.values() for t in v)
