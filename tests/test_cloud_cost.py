"""GCSCostModel boundary tests: tiered egress edges, empty months,
peering vs. internet pricing, and the tick-adapter bill folding."""

import pytest

from repro.sim.cloud import (
    GCSBucket,
    GCSCostModel,
    MONTH_SECONDS,
    PEERING_PRICES,
    bills_from_monthly_totals,
)
from repro.sim.infrastructure import GiB, Site, TiB


CM = GCSCostModel()


# ------------------------------------------------------------ egress tiers
def test_egress_zero_volume():
    assert CM.egress_cost(0.0) == 0.0


def test_egress_below_first_tier():
    assert CM.egress_cost(512 * GiB) == pytest.approx(512 * 0.12)


def test_egress_exactly_one_tib():
    """The 1 TiB boundary bills entirely at the first-tier price."""
    assert CM.egress_cost(1 * TiB) == pytest.approx(1024 * 0.12)


def test_egress_just_past_one_tib():
    got = CM.egress_cost(1 * TiB + 1 * GiB)
    assert got == pytest.approx(1024 * 0.12 + 1 * 0.11)


def test_egress_exactly_ten_tib():
    """The 10 TiB boundary: 1 TiB at 0.12 + 9 TiB at 0.11, none at 0.08."""
    expect = 1024 * 0.12 + 9 * 1024 * 0.11
    assert CM.egress_cost(10 * TiB) == pytest.approx(expect)


def test_egress_top_tier_marginal_price():
    base = CM.egress_cost(10 * TiB)
    got = CM.egress_cost(10 * TiB + 100 * GiB)
    assert got == pytest.approx(base + 100 * 0.08)


def test_egress_petabyte_dominated_by_top_tier():
    """Paper Table 8 back-derivation: PB-scale egress lands at ~0.08/GiB."""
    vol = 1000 * TiB
    assert CM.egress_cost(vol) / (vol / GiB) == pytest.approx(0.08, rel=0.01)


# --------------------------------------------------------------- peering
def test_peering_prices_are_flat():
    vol = 10 * TiB + 123 * GiB
    for name, price in PEERING_PRICES.items():
        cm = GCSCostModel(peering=name)
        assert cm.egress_cost(vol) == pytest.approx(price * vol / GiB)


def test_peering_cheaper_than_internet_at_scale():
    vol = 50 * TiB
    internet = CM.egress_cost(vol)
    direct = GCSCostModel(peering="direct").egress_cost(vol)
    inter = GCSCostModel(peering="interconnect").egress_cost(vol)
    assert inter < direct < internet


def test_flat_egress_override_takes_precedence():
    """The break-even solvers' flat USD/GiB axis overrides both the
    peering table and the internet tiers (repro.sim.decide)."""
    flat = GCSCostModel(flat_egress_per_gib=0.007)
    assert flat.egress_cost(3 * TiB) == pytest.approx(3 * 1024 * 0.007)
    both = GCSCostModel(peering="direct", flat_egress_per_gib=0.007)
    assert both.egress_cost(1 * GiB) == pytest.approx(0.007)
    zero = GCSCostModel(flat_egress_per_gib=0.0)
    assert zero.egress_cost(5 * TiB) == 0.0


def test_egress_price_spec_flows_into_bill_and_shares_lane():
    """ScenarioSpec.egress_price reaches the built config's cost model and
    stays billing-only: pack_specs gives price variants one dynamics lane."""
    from repro.core.scenarios import ScenarioSpec, build_config, pack_specs

    spec = ScenarioSpec(base="III", days=0.1, n_files=200,
                        egress_price=0.007)
    assert build_config(spec).cost_model.flat_egress_per_gib == 0.007
    with pytest.raises(ValueError, match="egress_price"):
        ScenarioSpec(base="III", egress_price=-0.01)
    variants = [spec, ScenarioSpec(base="III", days=0.1, n_files=200),
                ScenarioSpec(base="III", days=0.1, n_files=200,
                             egress_price=0.05)]
    grid = pack_specs(variants)
    assert grid.n_specs == 3 and grid.n_lanes == 1
    assert [cm.flat_egress_per_gib for cm in grid.cost_models] == \
        [0.007, None, 0.05]


def test_peering_pricier_than_top_tier_refund_never_happens():
    # sanity: flat 0.05 < blended internet price for any volume
    for vol in (1 * GiB, 1 * TiB, 10 * TiB, 100 * TiB):
        assert GCSCostModel(peering="direct").egress_cost(vol) < \
            CM.egress_cost(vol) + 1e-9


# ---------------------------------------------------- months + tick folding
def test_bucket_empty_months_bill_zero():
    """A bucket idle across two month boundaries emits two zero bills and
    no partial-month bill."""
    gcs = GCSBucket("B", Site("GCS"))
    bills = gcs.finalize(2 * MONTH_SECONDS)
    assert len(bills) == 2
    assert all(b.total == 0.0 for b in bills)


def test_bills_from_monthly_totals_matches_bucket():
    """The tick adapter reproduces GCSBucket's event-time billing for a
    scripted month of activity (storage integration quantized alike)."""
    gcs = GCSBucket("B", Site("GCS"))
    size = 100 * GiB
    t_in = 5 * 24 * 3600
    gcs.record_ingress(t_in, size)
    gcs.used = size  # record_* tracks ops; volume is the SE's accounting
    t_out = 20 * 24 * 3600
    gcs.record_egress(t_out, 40 * GiB)
    horizon = MONTH_SECONDS + 10 * 24 * 3600
    bucket_bills = gcs.finalize(horizon)

    # same quantities as per-month aggregates
    gb = size / 1e9
    gb_seconds = [gb * (MONTH_SECONDS - t_in), gb * (horizon - MONTH_SECONDS)]
    adapter_bills = bills_from_monthly_totals(
        gcs.cost_model, gb_seconds, [40 * GiB, 0.0], [1, 0], [1, 0],
        full_months=1)
    assert len(adapter_bills) == len(bucket_bills) == 2
    for a, b in zip(adapter_bills, bucket_bills):
        assert a.storage_usd == pytest.approx(b.storage_usd, rel=1e-9)
        assert a.network_usd == pytest.approx(b.network_usd, rel=1e-9)
        assert a.ops_usd == pytest.approx(b.ops_usd, rel=1e-9)


def test_bills_from_monthly_totals_trailing_partial_rules():
    cm = GCSCostModel()
    # empty trailing partial month is skipped ...
    bills = bills_from_monthly_totals(cm, [100.0, 0.0], [0.0, 0.0],
                                      [0, 0], [0, 0], full_months=1)
    assert len(bills) == 1
    # ... but a complete zero month is billed (GCSBucket closes each
    # crossed boundary), and an active partial month is billed too
    bills = bills_from_monthly_totals(cm, [0.0, 50.0], [0.0, 1 * GiB],
                                      [0, 2], [0, 3], full_months=1)
    assert len(bills) == 2
    assert bills[0].total == 0.0
    assert bills[1].network_usd == pytest.approx(0.12)


def test_storage_and_ops_costs():
    assert CM.storage_cost(MONTH_SECONDS) == pytest.approx(0.026)  # 1 GB
    assert CM.ops_cost(10_000, 0) == pytest.approx(0.05)
    assert CM.ops_cost(0, 10_000) == pytest.approx(0.004)
    assert CM.ops_cost(0, 0) == 0.0
