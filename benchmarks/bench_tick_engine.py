"""Benchmark: transfer-manager tick engines (paper §4.1 hot loop).

Compares ticks/second of (a) the Python scalar tick manager (the paper's
C++ loop analogue), (b) the vectorized jnp reference, (c) the Pallas
kernels in interpret mode (``tick_impl="pallas_interpret"``). On TPU the
same calls compile to the MXU one-hot matmul form; interpret-mode numbers
here only validate plumbing, while the jnp path shows the vectorization
win that motivates the kernels.

Row naming: every ``tick.pallas.*`` row is an interpret-mode artifact on
this CPU container — a plumbing/compile-cost measurement, NOT a kernel
speed claim — so the bench-smoke regression gate
(``scripts/check_bench_regression.py``) must never include them in its
default rows. ``tick.pallas.interpret_coldstart`` (previously the
misleadingly bare ``tick.pallas_interpret``) is a deliberate one-shot:
trace + lower + first execution. The ``*_warm`` rows time steady-state
re-execution of the already-jitted call.
"""

from __future__ import annotations

import time
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import lane_tick
from repro.kernels.carousel_update.ops import carousel_tick, simulate_ticks


def run(n_transfers: int = 4096, n_links: int = 64,
        n_ticks: int = 200) -> List[Dict]:
    rng = np.random.default_rng(0)
    link_id = jnp.asarray(rng.integers(0, n_links, n_transfers), jnp.int32)
    active = jnp.ones(n_transfers, bool)
    total = jnp.asarray(rng.exponential(1e9, n_transfers).astype(np.float32))
    done = jnp.zeros(n_transfers, jnp.float32)
    bw = jnp.asarray(rng.uniform(1e6, 1e8, n_links).astype(np.float32))
    mode = jnp.asarray(rng.integers(0, 2, n_links), jnp.int32)

    rows = []

    # python scalar loop (paper-equivalent semantics)
    t0 = time.time()
    d = np.asarray(done).copy()
    act = np.ones(n_transfers, bool)
    counts = np.bincount(link_id[act], minlength=n_links)
    for _ in range(20):
        rate = np.where(mode[link_id] > 0, bw[link_id],
                        bw[link_id] / np.maximum(counts[link_id], 1))
        d = np.minimum(total, d + act * rate * 1.0)
    t_py = (time.time() - t0) / 20
    rows.append({"name": "tick.python_vectorized_numpy",
                 "us_per_call": t_py * 1e6,
                 "derived": n_transfers / t_py})

    # jnp scanned engine
    f = jax.jit(lambda: simulate_ticks(link_id, active, done, total, bw,
                                       mode, 1.0, n_ticks=n_ticks))
    f()  # compile
    t0 = time.time()
    jax.block_until_ready(f())
    t_scan = (time.time() - t0) / n_ticks
    rows.append({"name": "tick.jnp_scanned",
                 "us_per_call": t_scan * 1e6,
                 "derived": n_transfers / t_scan})

    # pallas interpret cold start (plumbing validation; TPU target form):
    # one-shot trace + lower + execute, deliberately unwarmed
    t0 = time.time()
    out = carousel_tick(link_id, active, done, total, bw, mode, 1.0,
                        tick_impl="pallas_interpret")
    jax.block_until_ready(out)
    t_pallas = time.time() - t0
    rows.append({"name": "tick.pallas.interpret_coldstart",
                 "us_per_call": t_pallas * 1e6,
                 "derived": n_transfers / t_pallas})

    # warmed carousel kernel: steady-state re-execution of the jitted call
    n_rep = 20
    t0 = time.time()
    for _ in range(n_rep):
        out = carousel_tick(link_id, active, done, total, bw, mode, 1.0,
                            tick_impl="pallas_interpret")
    jax.block_until_ready(out)
    t_warm = (time.time() - t0) / n_rep
    rows.append({"name": "tick.pallas.carousel_warm",
                 "us_per_call": t_warm * 1e6,
                 "derived": n_transfers / t_warm})

    # fused lane-blocked sweep-tick kernel (ISSUE 7): the batched
    # engine's transfer+billing kernel over [S, F] site planes, warmed
    S = 8
    F = n_transfers // S
    rng_l = np.random.default_rng(1)
    site = np.repeat(np.arange(S)[:, None], F, axis=1)
    l_link = jnp.asarray(3 * site + rng_l.integers(0, 3, (S, F)), jnp.int32)
    l_act = jnp.asarray(rng_l.random((S, F)) < 0.6)
    l_total = jnp.asarray(rng_l.exponential(1e9, (S, F)).astype(np.float32))
    l_done = jnp.zeros((S, F), jnp.float32)
    l_bw = jnp.asarray(rng_l.uniform(1e6, 1e8, 3 * S).astype(np.float32))
    l_mode = jnp.asarray(rng_l.integers(0, 2, 3 * S), jnp.int32)
    month = jnp.asarray([1.0], jnp.float32)
    lane = jax.jit(lambda: lane_tick.transfer_tick(
        l_link, l_act, l_done, l_total, l_total, l_bw, l_mode, 1.0, month,
        interpret=True))
    jax.block_until_ready(lane())  # compile
    t0 = time.time()
    for _ in range(n_rep):
        out = lane()
    jax.block_until_ready(out)
    t_lane = (time.time() - t0) / n_rep
    rows.append({"name": f"tick.pallas.lane_tick_warm.{S}site",
                 "us_per_call": t_lane * 1e6,
                 "derived": n_transfers / t_lane})
    return rows


def main() -> None:
    for r in run():
        print(f"{r['name']},{r['us_per_call']:.1f},{r['derived']:.4g}")


if __name__ == "__main__":
    main()
