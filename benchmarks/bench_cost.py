"""Benchmark: paper Table 8 — monthly GCS cost for configuration III."""

from __future__ import annotations

import time
from typing import Dict, List

import numpy as np

from repro.core.hcdc import HCDCScenario, PAPER_TABLE8, make_config
from repro.sim.engine import DAY


def run(n_runs: int = 1, days: int = 90,
        n_files: int = 1_000_000) -> List[Dict]:
    per: Dict[str, List[float]] = {}
    wall = []
    for seed in range(n_runs):
        cfg = make_config("III", simulated_time=days * DAY,
                          n_files_per_site=n_files, seed=11 + seed)
        t0 = time.time()
        m = HCDCScenario(cfg).run()
        wall.append(time.time() - t0)
        for k, v in m.items():
            if k.endswith("_usd"):
                per.setdefault(k, []).append(v)
    rows = []
    for k, ref in PAPER_TABLE8.items():
        if k not in per:
            continue
        mean = float(np.mean(per[k]))
        rows.append({
            "name": f"table8.{k}",
            "us_per_call": float(np.mean(wall)) * 1e6,
            "derived": mean,
            "paper": ref,
            "diff_pct": 100.0 * (mean - ref) / ref,
        })
    return rows


def main() -> None:
    for r in run():
        print(f"{r['name']},{r['us_per_call']:.0f},{r['derived']:.4g},"
              f"paper={r['paper']:.4g},diff={r['diff_pct']:+.2f}%")


if __name__ == "__main__":
    main()
