"""Roofline analysis from compiled dry-run artifacts."""

from repro.roofline.analysis import collective_bytes, roofline_report, HW

__all__ = ["collective_bytes", "roofline_report", "HW"]
