"""hymba-1.5b [hybrid]: 32L d_model=1600 25H (GQA kv=5) d_ff=5504
vocab=32001, ssm_state=16, parallel attn+mamba heads; sliding-window
attention except 3 global layers (first/middle/last). [arXiv:2411.13676; hf]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="hymba-1.5b", family="hybrid",
    n_layers=32, d_model=1600, n_heads=25, n_kv_heads=5,
    d_ff=5504, vocab_size=32001,
    ssm_state=16, ssm_conv=4, ssm_expand=2,
    sliding_window=1024, global_layers=(0, 15, 31),
)

def smoke_config() -> ModelConfig:
    return CONFIG.replace(n_layers=3, d_model=64, n_heads=4, n_kv_heads=2,
                          d_ff=128, vocab_size=512, sliding_window=8,
                          global_layers=(0,), remat=False)
