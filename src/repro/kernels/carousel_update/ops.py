"""Jitted wrappers for the carousel tick kernel.

``carousel_tick`` picks the Pallas kernel (interpret mode on CPU; compiled
on TPU) or the jnp reference. ``simulate_ticks`` scans the tick over many
steps — the fully vectorized tick engine (the accelerator-native
equivalent of the paper's transfer-manager loop) used by the throughput
benchmark.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.carousel_update.carousel_update import carousel_tick_pallas
from repro.kernels.carousel_update.ref import carousel_tick_ref


@functools.partial(jax.jit, static_argnames=("use_pallas", "interpret"))
def carousel_tick(link_id, active, done, total, bw, mode, dt,
                  use_pallas: bool = True, interpret: bool = True):
    if use_pallas:
        return carousel_tick_pallas(link_id, active, done, total, bw, mode,
                                    dt, interpret=interpret)
    return carousel_tick_ref(link_id, active, done, total, bw, mode, dt)


@functools.partial(jax.jit, static_argnames=("n_ticks",))
def simulate_ticks(link_id, active, done, total, bw, mode, dt, n_ticks: int):
    """Run n_ticks of the tick engine; transfers complete and deactivate."""

    def body(carry, _):
        act, dn = carry
        new_done, completed, _ = carousel_tick_ref(link_id, act, dn, total,
                                                   bw, mode, dt)
        act = jnp.logical_and(act, jnp.logical_not(completed))
        return (act, new_done), completed.sum()

    (act, dn), completions = jax.lax.scan(body, (active, done),
                                          None, length=n_ticks)
    return act, dn, completions
