"""seamless-m4t-large-v2 [audio]: enc-dec, 24L decoder (+24L encoder)
d_model=1024 16H (GQA kv=16) d_ff=8192 vocab=256206; speech frontend STUB
(precomputed frame embeddings). [arXiv:2308.11596; hf]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-large-v2", family="audio",
    n_layers=24, d_model=1024, n_heads=16, n_kv_heads=16,
    d_ff=8192, vocab_size=256206,
    encoder_layers=24,
    frontend="audio", frontend_dim=160,
)

def smoke_config() -> ModelConfig:
    return CONFIG.replace(n_layers=2, encoder_layers=2, d_model=64, n_heads=4,
                          n_kv_heads=4, d_ff=128, vocab_size=512,
                          frontend_dim=32, remat=False)
